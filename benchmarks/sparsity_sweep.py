"""Fig. 4 — SD speedup vs batch across sparsity (K in {1..32}), simulator vs
the fitted Alg. 1 model; adjusted by sigma_{K=8}/sigma_K as in Sec. 4.2."""
from __future__ import annotations

import numpy as np

from benchmarks.common import Timer, csv_row
from repro.configs.registry import get_config
from repro.core.analytics import sigma_from_alpha
from repro.core.perf_model import Measurement, SpeedupModel, stride_sample
from repro.core.simulator import Simulator

BATCHES = [1, 2, 4, 8, 12, 16, 20, 24, 28, 32, 40, 48, 56, 64, 80, 100, 128,
           192, 256]
KS = (1, 2, 4, 8, 16, 32)


def build_frame(sim, target, draft, alpha=0.8, gammas=(2, 4)):
    rows = []
    for K in KS:
        cfg = target.with_overrides(num_experts_per_tok=K)
        for g in gammas:
            s = float(sigma_from_alpha(alpha, g))
            for b in BATCHES:
                rows.append(Measurement(b, g, K, target.num_experts, s,
                                        sim.sd_speedup(cfg, draft, b, g, s)))
    return rows


def run() -> list:
    out = []
    target = get_config("qwen2-57b-a14b")
    draft = get_config("qwen2-0.5b")
    sim = Simulator()
    t0 = Timer()
    frame = build_frame(sim, target, draft)          # 228 "measurements"
    model = SpeedupModel(engine_semantics=True)
    fit = model.fit(stride_sample(frame, 21), target, draft)
    out.append(csv_row("fig4_fit_mse_m21", t0.us(), f"mse={fit['mse']:.4f}"))

    sigma8 = float(sigma_from_alpha(0.8, 4))
    for K in KS:
        cfg = target.with_overrides(num_experts_per_tok=K)
        curve = np.array([sim.sd_speedup(cfg, draft, b, 4, sigma8)
                          for b in BATCHES])
        pred = model.predict(BATCHES, [4] * len(BATCHES), [K] * len(BATCHES),
                             [64] * len(BATCHES), [sigma8] * len(BATCHES))
        i = int(np.argmax(curve))
        thr = curve[i] / np.sqrt(2)
        win = [b for b, s in zip(BATCHES, curve) if s >= thr]
        out.append(csv_row(
            f"fig4_K{K}", 0.0,
            f"peak={curve[i]:.3f};peak_B={BATCHES[i]};"
            f"window={min(win)}-{max(win)};"
            f"model_corr={np.corrcoef(pred, curve)[0, 1]:.3f}"))
    # headline claim: peak batch grows and window widens as K shrinks
    peaks = {}
    wins = {}
    for r in out:
        if r.startswith("fig4_K"):
            K = int(r.split(",")[0][6:])
            d = dict(kv.split("=") for kv in r.split(",")[2].split(";"))
            peaks[K] = int(d["peak_B"])
            lo, hi = d["window"].split("-")
            wins[K] = int(hi) - int(lo)
    out.append(csv_row(
        "fig4_claims", 0.0,
        f"peak_shifts_right={peaks[2] >= peaks[8] >= peaks[32]};"
        f"window_widens={wins[2] >= wins[8] >= wins[32]}"))
    return out

"""Table 3 — modeling MSE vs number of fitting measurements m (stride
sampling of the 228-row frame, Appendix C.2/C.3)."""
from __future__ import annotations

import numpy as np

from benchmarks.common import Timer, csv_row
from benchmarks.sparsity_sweep import build_frame
from repro.configs.registry import get_config
from repro.core.perf_model import SpeedupModel, stride_sample


def run() -> list:
    rows = []
    target = get_config("qwen2-57b-a14b")
    draft = get_config("qwen2-0.5b")
    from repro.core.simulator import Simulator
    frame = build_frame(Simulator(), target, draft)
    Y = np.array([r.speedup for r in frame])
    B = np.array([r.batch for r in frame])
    G = np.array([r.gamma for r in frame])
    K = np.array([r.top_k for r in frame])
    E = np.array([r.num_experts for r in frame])
    S = np.array([r.sigma for r in frame])
    assert len(frame) == 228, len(frame)
    for m in (10, 12, 15, 21, 38, 76, 228):
        t0 = Timer()
        model = SpeedupModel(engine_semantics=True)
        fit = model.fit(stride_sample(frame, m), target, draft, n_restarts=6)
        pred = model.predict(B, G, K, E, S)
        full_mse = float(np.mean((pred - Y) ** 2))
        batches = sorted({r.batch for r in stride_sample(frame, m)})
        rows.append(csv_row(
            f"table3_m{m}", t0.us(),
            f"fit_mse={fit['mse']:.4f};full_mse={full_mse:.4f};"
            f"batch_coverage={len(batches)}"))
    return rows

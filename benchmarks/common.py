"""Shared benchmark infra: trained reduced model pairs (cached), timers.

sigma/alpha in every benchmark come from REAL speculative-decoding runs of
reduced models trained on the synthetic workloads; timing terms come from
the v5e simulator (DESIGN.md §2).  Trained params are cached under
results/bench_models/ so the full bench suite trains each model once.
"""
from __future__ import annotations

import os
import time
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, TrainConfig
from repro.configs.registry import get_config
from repro.data.pipeline import packed_batches, prompt_batch
from repro.models.model import Model
from repro.training.checkpoint import (latest_checkpoint, restore_checkpoint,
                                       save_checkpoint)
from repro.training.train_loop import init_train_state, make_train_step

CACHE_DIR = os.environ.get("BENCH_MODEL_DIR", "results/bench_models")
TRAIN_STEPS = int(os.environ.get("BENCH_TRAIN_STEPS", "220"))
# drafting strategy for sigma/alpha measurement — any Proposer registry kind
# ("model" | "eagle" | "none"); benchmarks/run.py --proposer sets this
DEFAULT_PROPOSER = os.environ.get("BENCH_PROPOSER", "model")
# MoE dispatch for the DECODE/serve path ("onehot" | "gmm"); benchmarks/run.py
# --moe-dispatch sets this.  Serving defaults to the ragged gmm kernels;
# training always stays onehot (GSPMD/expert-parallel friendly).
DEFAULT_DISPATCH = os.environ.get("BENCH_MOE_DISPATCH", "gmm")


def _train(model: Model, steps: int, kind: str, seed: int):
    params, opt = init_train_state(model, jax.random.PRNGKey(seed))
    step = jax.jit(make_train_step(model, TrainConfig(
        learning_rate=3e-3, total_steps=steps, warmup_steps=steps // 10)),
        donate_argnums=(0, 1))
    it = packed_batches(model.cfg.vocab_size, 8, 64, kind=kind, seed=seed)
    for _ in range(steps):
        params, opt, _ = step(params, opt,
                              {k: jnp.asarray(v) for k, v in next(it).items()})
    return params


def trained_params(arch: str, kind: str, seed: int,
                   overrides: dict | None = None):
    """Train-or-load a reduced arch on a workload kind.

    Training runs the onehot dispatch (shardable dense combine); the
    returned model decodes with ``DEFAULT_DISPATCH`` so every downstream
    sigma/speedup measurement exercises the serving-default MoE path."""
    cfg = get_config(arch, reduced=True, **(overrides or {}))
    train_model = Model(cfg)
    serve_dispatch = DEFAULT_DISPATCH if cfg.num_experts else "onehot"
    model = Model(cfg, moe_dispatch=serve_dispatch)
    tag = f"{cfg.name}_{kind}_{seed}"
    ckdir = os.path.join(CACHE_DIR, tag)
    params = model.init(jax.random.PRNGKey(seed))  # template
    path = latest_checkpoint(ckdir)
    if path:
        restored, _ = restore_checkpoint(path, {"params": params})
        return model, restored["params"]
    params = _train(train_model, TRAIN_STEPS, kind, seed)
    save_checkpoint(ckdir, TRAIN_STEPS, {"params": params}, {"arch": cfg.name})
    return model, params


def trained_pair(target_arch: str = "qwen2-57b-a14b", kind: str = "code"):
    """(target model+params, draft model+params) trained on one workload."""
    t, pt = trained_params(target_arch, kind, seed=0)
    d, pd = trained_params("qwen2-0.5b", kind, seed=1,
                           overrides={"vocab_size":
                                      get_config(target_arch, reduced=True).vocab_size})
    return (t, pt), (d, pd)


def draft_cost_config(proposer: str, target_cfg: ModelConfig,
                      draft_cfg: ModelConfig) -> ModelConfig:
    """The config whose forward time prices T_D in speedup formulas — must
    match the drafter sigma was measured with ("eagle" is a one-block head
    on the target, not the standalone small model)."""
    if proposer == "eagle":
        from repro.core.eagle import EagleHead
        from repro.models.model import Model
        return EagleHead(Model(target_cfg)).cfg
    return draft_cfg


def measure_sigma(target, params_t, draft, params_d, *, batch: int,
                  gamma: int, temperature: float, kind: str,
                  max_new: int = 32, seed: int = 0,
                  proposer: str | None = None):
    """REAL sigma/alpha from the engine on a real prompt batch, under any
    registered drafting strategy (default: BENCH_PROPOSER or "model")."""
    from repro.core.proposer import make_proposer
    from repro.core.spec_decode import SDEngine

    proposer = proposer if proposer is not None else DEFAULT_PROPOSER
    from repro.core.eagle import EagleHead
    if proposer == "eagle" and not isinstance(draft, EagleHead):
        import warnings
        warnings.warn(
            "measure_sigma(proposer='eagle') was given a draft Model; "
            "substituting a freshly initialized (UNTRAINED) EagleHead — "
            "sigma/alpha will reflect an untrained head, not a tuned one",
            stacklevel=2)
        head = EagleHead(target)
        draft, params_d = head, head.init(jax.random.PRNGKey(seed + 101))
    pb = prompt_batch(target.cfg.vocab_size, batch, kind=kind, seed=seed)
    eng = SDEngine(target,
                   make_proposer(proposer, target, draft,
                                 temperature=temperature),
                   gamma=gamma, temperature=temperature)
    _, stats = eng.generate(params_t, params_d, jnp.asarray(pb["tokens"]),
                            max_new, lengths=jnp.asarray(pb["lengths"]),
                            key=jax.random.PRNGKey(seed))
    return stats


class Timer:
    def __init__(self):
        self.t0 = time.perf_counter()

    def us(self, n_calls: int = 1) -> float:
        return (time.perf_counter() - self.t0) * 1e6 / max(n_calls, 1)


def csv_row(name: str, us_per_call: float, derived: str) -> str:
    return f"{name},{us_per_call:.1f},{derived}"

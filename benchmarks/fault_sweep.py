"""Resilience under injected faults: throughput + recovery vs fault rate.

PR 7's resilience layer (docs/faults.md) claims two things this sweep
pins with numbers:

1. **Clean streams are free.**  The numerical sentinel runs inside the
   jitted verify every round regardless, and the host-side bookkeeping
   (watermark checks, deadlines, round budgets) is a handful of Python
   comparisons per round.  An armed-but-idle resilience config must cost
   < 2% wall time vs a default stream on the SAME warm engine workload
   (min-of-repeats on both arms, compile excluded by warmup).

2. **Faulty streams degrade, not die.**  A seeded ``FaultInjector``
   Bernoulli script (page exhaustion holds, transient admission
   failures, slow rounds) at increasing fault rates: every stream still
   completes with exactly one finish_reason per request and zero leaked
   pages; tokens/sec decays with the rate and
   ``fault_recovery_summary`` reports how many rounds preempted work
   waited before re-admission.

Writes BENCH_faults.json.
"""
from __future__ import annotations

import json
import time

import jax
import numpy as np

from benchmarks.common import csv_row
from repro.configs.base import ModelConfig
from repro.core.analytics import fault_recovery_summary
from repro.models.model import Model
from repro.serving.engine import ServingEngine
from repro.serving.faults import FaultInjector, ResilienceConfig

RATES = (0.0, 0.15, 0.3)
N_REQUESTS = 6
N_ROUNDS_SCRIPT = 40            # injector script horizon
REPEATS = 5
SEED = 11
INJ_SEED = 9     # chosen so every nonzero rate scripts all three kinds
                 # inside the stream's ~18-round horizon

TCFG = ModelConfig("flt-moe", "moe", 2, 128, 4, 2, 256, 512, num_experts=4,
                   num_experts_per_tok=2, dtype="float32")
DCFG = ModelConfig("flt-draft", "dense", 2, 64, 2, 2, 128, 512,
                   dtype="float32")


def _models():
    t, d = Model(TCFG), Model(DCFG)
    return t, d, t.init(jax.random.PRNGKey(0)), d.init(jax.random.PRNGKey(1))


def _submit(eng):
    """Staggered mixed-budget workload — identical for every arm."""
    rng = np.random.default_rng(SEED)
    for i in range(N_REQUESTS):
        plen = int(rng.integers(5, 9))
        eng.submit(np.arange(3, 3 + plen),
                   max_new_tokens=int(rng.choice((4, 6, 10))),
                   arrival_round=i * 2)


def _engine(t, d, pt, pd, resilience=None):
    return ServingEngine(t, d, pt, pd, max_batch=3, gamma=2,
                         force_sd=True, scheduler="continuous",
                         kv_layout="paged", page_size=8, seed=SEED,
                         resilience=resilience)


def _timed_stream(eng, injector=None):
    """One drained stream on a WARM engine; returns (report, wall_s)."""
    eng.fault_injector = injector
    _submit(eng)
    t0 = time.perf_counter()
    (report,) = eng.run()
    return report, time.perf_counter() - t0


def run(out_path: str = "BENCH_faults.json") -> list:
    t, d, pt, pd = _models()
    rows = []

    # ---- arm 1: clean-stream overhead of an ARMED resilience config.
    # Both arms execute the identical jitted round (the sentinel is
    # unconditional); the armed arm additionally evaluates deadline /
    # budget / pool-cap checks that never fire.  The watermark stays 0:
    # any positive watermark is admission POLICY — it defers work by
    # design (this pool's free fraction legitimately hits 0), which is a
    # schedule change, not bookkeeping overhead.  Warmup compiles, then
    # alternate timed repeats and take the min of each.
    armed_cfg = ResilienceConfig(round_deadline_s=60.0,
                                 max_rounds_per_request=10_000,
                                 max_pool_pages=4096)
    base = _engine(t, d, pt, pd)
    armed = _engine(t, d, pt, pd, resilience=armed_cfg)
    base_ref, _ = _timed_stream(base)            # warmup (compiles)
    armed_ref, _ = _timed_stream(armed)
    t_base, t_armed = [], []
    for _ in range(REPEATS):
        _, w = _timed_stream(base)
        t_base.append(w)
        _, w = _timed_stream(armed)
        t_armed.append(w)
    overhead = (min(t_armed) - min(t_base)) / min(t_base)
    rows.append(csv_row("faults_clean_base", min(t_base) * 1e6,
                        f"tokens={base_ref.tokens_out}"))
    rows.append(csv_row("faults_clean_armed", min(t_armed) * 1e6,
                        f"overhead={overhead:.4f}"))
    assert overhead < 0.02, \
        f"armed-but-idle resilience cost {overhead:.2%} (budget 2%)"
    # armed-but-idle means IDLE: nothing fired on either clean arm
    assert not base.fault_counters and not armed.fault_counters, \
        f"clean arms tripped counters: {base.fault_counters} " \
        f"{armed.fault_counters}"

    # ---- arm 2: degradation curve vs injected fault rate.  One warm
    # engine per rate (the injector perturbs admission shapes, so rates
    # must not share jit-cache luck); nan_row is excluded — it retires
    # requests outright, which is quarantine (tested), not recovery.
    sweep = []
    sweep_cfg = ResilienceConfig(max_pool_pages=16, admit_retries=4,
                                 faulty_rounds_to_ar=64,
                                 faulty_rounds_to_stop=128)
    for rate in RATES:
        eng = _engine(t, d, pt, pd, resilience=sweep_cfg)
        _timed_stream(eng, FaultInjector.poisson(
            rate, N_ROUNDS_SCRIPT, seed=INJ_SEED,
            kinds=("page_exhaustion", "admit_fail", "slow_round")))
        report, wall = _timed_stream(eng, FaultInjector.poisson(
            rate, N_ROUNDS_SCRIPT, seed=INJ_SEED,
            kinds=("page_exhaustion", "admit_fail", "slow_round")))
        eng._slot_scheduler._alloc.assert_no_leaks()
        reasons = report.finish_reasons or {}
        assert sum(reasons.values()) == N_REQUESTS
        assert all(k in ("length", "eos", "admit_failed") for k in reasons)
        rec = fault_recovery_summary(report.steps)
        rec["recovery_latency_rounds"] = [
            None if not np.isfinite(x) else x
            for x in rec["recovery_latency_rounds"]]
        if not np.isfinite(rec["mean_recovery_latency"]):
            rec["mean_recovery_latency"] = None
        tps = report.tokens_out / wall
        sweep.append({
            "rate": rate, "wall_s": round(wall, 4),
            "tokens_out": report.tokens_out,
            "tokens_discarded": report.tokens_discarded,
            "tokens_per_second": round(tps, 2),
            "finish_reasons": reasons,
            "injected": dict(eng.fault_injector.injected),
            "counters": dict(eng.fault_counters),
            "recovery": rec,
        })
        rows.append(csv_row(f"faults_rate{rate}", wall * 1e6,
                            f"tok_s={tps:.1f};injected="
                            f"{sum(eng.fault_injector.injected.values())}"))
        n_injected = sum(eng.fault_injector.injected.values())
        if rate == 0.0:
            assert n_injected == 0, "rate-0 injector must inject nothing"
        else:
            assert n_injected > 0, \
                f"rate-{rate} script injected nothing; raise N_ROUNDS"

    with open(out_path, "w") as f:
        json.dump({
            "sweep": "resilience_vs_fault_rate",
            "arch": TCFG.name, "requests": N_REQUESTS, "rates": list(RATES),
            "note": "clean arms: identical warm-engine workload, min of "
                    f"{REPEATS} alternated repeats; armed-but-idle "
                    "resilience must cost <2%.  Fault arms: seeded "
                    "Bernoulli(rate) scripts of page-exhaustion holds, "
                    "transient admission failures and slow rounds; every "
                    "stream completes with one finish_reason per request "
                    "and zero leaked pages; recovery = rounds from a "
                    "preemption to the next admission "
                    "(analytics.fault_recovery_summary).",
            "clean_overhead": {
                "base_s": [round(x, 4) for x in t_base],
                "armed_s": [round(x, 4) for x in t_armed],
                "overhead_fraction": round(overhead, 4),
            },
            "per_rate": sweep,
        }, f, indent=1)
    return rows


if __name__ == "__main__":
    for row in run():
        print(row)

"""Fig. 3 / Fig. 6 — target efficiency and end-to-end speedup: MoE vs dense.

MoE (Qwen2-57B-A14B) target efficiency first rises then falls; the dense
control (Qwen2-7B) falls monotonically — SD favours MoE beyond moderate B."""
from __future__ import annotations

import numpy as np

from benchmarks.common import csv_row
from repro.configs.registry import get_config
from repro.core.analytics import sigma_from_alpha
from repro.core.simulator import Simulator

BATCHES = [1, 2, 4, 8, 16, 32, 64, 128, 256, 512]


def run() -> list:
    rows = []
    sim = Simulator()
    moe = get_config("qwen2-57b-a14b")
    dense = get_config("qwen2-7b")
    draft = get_config("qwen2-0.5b")
    sigma = float(sigma_from_alpha(0.8, 4))
    eff_moe, eff_dense = [], []
    for B in BATCHES:
        em = sim.target_efficiency(moe, B, 4)
        ed = sim.target_efficiency(dense, B, 4)
        sm = sim.sd_speedup(moe, draft, B, 4, sigma)
        sd_ = sim.sd_speedup(dense, draft, B, 4, sigma)
        eff_moe.append(em)
        eff_dense.append(ed)
        rows.append(csv_row(
            f"fig3_B{B}", 0.0,
            f"eff_moe={em:.3f};eff_dense={ed:.3f};"
            f"speedup_moe={sm:.3f};speedup_dense={sd_:.3f}"))
    # paper claims: dense eff decreases monotonically; MoE rises then falls
    dense_monotone = all(a >= b - 1e-9 for a, b in
                         zip(eff_dense, eff_dense[1:]))
    moe_peak = int(np.argmax(eff_moe))
    cross = next((B for B, m, d_ in zip(BATCHES, eff_moe, eff_dense)
                  if m > d_), None)
    rows.append(csv_row(
        "fig3_claims", 0.0,
        f"dense_monotone_decreasing={dense_monotone};"
        f"moe_interior_peak={0 < moe_peak < len(BATCHES) - 1};"
        f"moe_overtakes_dense_at_B={cross}"))
    return rows

"""Fig. 1 — (a/b) theoretical vs ACTUAL activated experts N(t) on a real
trained router; (c) T̄_exp vs sparsity."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import Timer, csv_row, trained_params
from repro.core.analytics import expected_activated_experts, mean_tokens_per_expert
from repro.data.pipeline import packed_batches
from repro.models.moe import expert_activation_counts, router_topk


def run() -> list:
    rows = []
    # (a/b): trained reduced MoE router on real token batches
    model, params = trained_params("qwen2-57b-a14b", "chat", seed=0)
    cfg = model.cfg
    E, K = cfg.num_experts, cfg.num_experts_per_tok
    # layer params are scan-stacked (P, d, E): take the first period's router
    router_w = params["layers"][0]["ffn"]["router"][0]
    it = packed_batches(cfg.vocab_size, 1, 256, kind="chat", seed=3)
    embed = params["embed"]["table"]
    ts = [1, 2, 4, 8, 16, 32, 64, 128]
    t0 = Timer()
    n_meas = {t: [] for t in ts}
    for trial in range(40):
        toks = jnp.asarray(next(it)["tokens"])[0]
        x = embed[toks]
        for t in ts:
            _, idx, _ = router_topk({"router": router_w}, cfg, x[:t])
            counts = expert_activation_counts(idx, E)
            n_meas[t].append(int((counts > 0).sum()))
    for t in ts:
        theory = float(expected_activated_experts(t, E, K))
        actual = float(np.mean(n_meas[t]))
        rows.append(csv_row(
            f"fig1_activated_experts_t{t}", t0.us(40 * len(ts)),
            f"theory={theory:.2f};actual={actual:.2f};E={E};K={K}"))
    # (c): T̄_exp(T; rho) decreasing in rho→0
    for rho in (0.5, 0.25, 0.125, 0.0625, 0.03125):
        v64 = float(mean_tokens_per_expert(64, rho))
        v256 = float(mean_tokens_per_expert(256, rho))
        rows.append(csv_row(f"fig1c_tokens_per_expert_rho{rho}", 0.0,
                            f"T64={v64:.2f};T256={v256:.2f}"))
    return rows

"""Benchmark driver — one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--only fig2,table3]

Prints ``name,us_per_call,derived`` CSV rows (one per measurement)."""
from __future__ import annotations

import argparse
import sys
import time

MODULES = [
    ("fig1_activation", "benchmarks.activation"),
    ("fig2_speedup_vs_batch", "benchmarks.speedup_vs_batch"),
    ("fig3_moe_vs_dense", "benchmarks.moe_vs_dense"),
    ("fig4_sparsity_sweep", "benchmarks.sparsity_sweep"),
    ("table12_peak_speedup", "benchmarks.peak_speedup"),
    ("table3_fitting", "benchmarks.fitting"),
    ("sec34_offloading", "benchmarks.offloading"),
    ("sec2_prefetch_utility", "benchmarks.prefetch_utility"),
    ("spmoe_prefetch_sweep", "benchmarks.prefetch_sweep"),
    ("continuous_sweep", "benchmarks.continuous_sweep"),
    ("admission_sweep", "benchmarks.admission_sweep"),
    ("prefix_sweep", "benchmarks.prefix_sweep"),
    ("fault_sweep", "benchmarks.fault_sweep"),
    ("kernels", "benchmarks.kernels"),
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated substring filters")
    from repro.core.proposer import registered_proposers
    ap.add_argument("--proposer", default=None,
                    choices=sorted(registered_proposers()),
                    help="drafting strategy for sigma measurement "
                         "(Proposer registry kind)")
    ap.add_argument("--moe-dispatch", default=None,
                    choices=["onehot", "gmm"],
                    help="MoE dispatch for the decode path (default: gmm, "
                         "the ragged grouped-matmul serving kernels)")
    args = ap.parse_args()
    if args.proposer or args.moe_dispatch:
        # assign directly (not via env) so the flag wins regardless of
        # whether benchmarks.common was already imported
        import benchmarks.common as common
        if args.proposer:
            common.DEFAULT_PROPOSER = args.proposer
        if args.moe_dispatch:
            common.DEFAULT_DISPATCH = args.moe_dispatch
    filters = args.only.split(",") if args.only else None

    print("name,us_per_call,derived")
    failures = 0
    for name, modpath in MODULES:
        if filters and not any(f in name for f in filters):
            continue
        t0 = time.time()
        try:
            import importlib
            mod = importlib.import_module(modpath)
            for row in mod.run():
                print(row)
            print(f"{name}_total,{(time.time()-t0)*1e6:.0f},ok")
        except Exception as e:  # noqa: BLE001
            failures += 1
            print(f"{name}_total,{(time.time()-t0)*1e6:.0f},"
                  f"FAIL:{type(e).__name__}:{e}")
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()

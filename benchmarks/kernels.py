"""Kernel micro-bench: interpret-mode wall time (CPU, correctness-grade) +
v5e roofline projection per kernel call (the real perf number).

Also sweeps the three MoE FFN dispatch modes (onehot / capacity-gmm /
ragged) over SD-verify token counts B*(gamma+1) and writes the analytic
FLOP/byte/tile accounting to BENCH_moe_dispatch.json."""
from __future__ import annotations

import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import csv_row
from repro.core.simulator import V5E
from repro.kernels.decode_attention.ref import decode_attention_ref
from repro.kernels.flash_attention.ref import flash_attention_ref
from repro.kernels.gmm.ragged import _round_up, make_group_metadata
from repro.kernels.gmm.ref import (combine_ref, dispatch_ref,
                                   gmm_capacity_ref, moe_ffn_ref,
                                   ragged_moe_ffn_ref)


def _time(fn, *args, iters=3):
    fn(*args).block_until_ready()
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    out.block_until_ready()
    return (time.perf_counter() - t0) * 1e6 / iters


def _proj_us(flops, bytes_):
    return max(flops / (V5E.peak_flops * V5E.compute_eff),
               bytes_ / (V5E.hbm_bw * V5E.mem_eff)) * 1e6


def moe_dispatch_sweep(out_path: str = "BENCH_moe_dispatch.json") -> list:
    """onehot vs capacity-gmm vs ragged expert-FFN cost over the SD verify
    token counts N = B*(gamma+1).  Wall time comes from the jitted jnp
    oracles (CPU, correctness-grade); the derived columns are the analytic
    FLOPs / HBM bytes / m-tile counts that decide the v5e roofline."""
    E, K, D, F, bm = 8, 2, 256, 256, 128
    gamma = 4
    rows, records = [], []
    for B in (4, 16, 64):
        N = B * (gamma + 1)                      # verify tokens per round
        NK = N * K
        ks = jax.random.split(jax.random.PRNGKey(B), 5)
        x = jax.random.normal(ks[0], (N, D), jnp.float32)
        wg = jax.random.normal(ks[1], (E, D, F)) / np.sqrt(D)
        wu = jax.random.normal(ks[2], (E, D, F)) / np.sqrt(D)
        wd = jax.random.normal(ks[3], (E, F, D)) / np.sqrt(F)
        logits = jax.random.normal(ks[4], (N, E))
        w, idx = jax.lax.top_k(jax.nn.softmax(logits), K)
        sizes = jnp.bincount(idx.reshape(-1), length=E)
        xs = x[jnp.argsort(idx.reshape(-1)) // K]
        C = _round_up(NK, 128)                   # legacy worst-case bins
        n_pad = _round_up(NK, bm)
        visits = int(make_group_metadata(sizes, n_pad, bm).num_visits[0])
        w_bytes = 3 * E * D * F * 2              # all experts stream from HBM

        def capacity_ffn(x, wg, wu, wd, w, idx):
            # full capacity-path FFN (same scope as the other two modes)
            bins, slot, kept = dispatch_ref(x, idx, E, C)
            h = jax.nn.silu(gmm_capacity_ref(bins, wg)) \
                * gmm_capacity_ref(bins, wu)
            return combine_ref(gmm_capacity_ref(h, wd), idx, w, slot, kept)

        def act_bytes(rows: int, fused: bool) -> int:
            # activation traffic per FFN, reads + writes at 2 B/elem:
            # x reads for gate/up (1 with the fused kernel), h writes for
            # gate/up (1 fused), h read + y write for the down projection
            x_reads = (1 if fused else 2) * rows * D
            h_writes = (1 if fused else 2) * rows * F
            return (x_reads + h_writes + rows * F + rows * D) * 2

        modes = {
            # every token through all E experts: E/K x FLOP overhead
            "onehot": dict(
                us=_time(jax.jit(moe_ffn_ref,
                                 static_argnames=("activation",)),
                         x, wg, wu, wd, w, idx),
                flops=3 * 2 * E * N * D * F,
                bytes=w_bytes + act_bytes(E * N, fused=False),
                m_tiles=3 * E * _round_up(N, bm) // bm, launches=3),
            # densified (E, C) bins, C = round_up(N*K, 128)
            "gmm_capacity": dict(
                us=_time(jax.jit(capacity_ffn), x, wg, wu, wd, w, idx),
                flops=3 * 2 * E * C * D * F,
                bytes=w_bytes + act_bytes(E * C, fused=False),
                m_tiles=3 * E * C // bm, launches=3),
            # ragged: work scales with routed tokens; fused gate+up halves
            # the x reads of the up-projection stage
            "ragged": dict(
                us=_time(jax.jit(ragged_moe_ffn_ref,
                                 static_argnames=("activation",)),
                         xs, wg, wu, wd, sizes),
                flops=3 * 2 * NK * D * F,
                bytes=w_bytes + act_bytes(n_pad, fused=True),
                m_tiles=3 * visits, launches=2),
        }
        for mode, m in modes.items():
            proj = _proj_us(m["flops"], m["bytes"])
            rows.append(csv_row(
                f"moe_dispatch_{mode}_N{N}", m["us"],
                f"v5e_roofline_us={proj:.1f};m_tiles={m['m_tiles']};"
                f"launches={m['launches']}"))
            records.append({"mode": mode, "batch": B, "gamma": gamma,
                            "tokens": N, "E": E, "K": K, "D": D, "F": F,
                            "us_jnp_oracle": round(m["us"], 2),
                            "v5e_roofline_us": round(proj, 2),
                            "flops": m["flops"], "hbm_bytes": m["bytes"],
                            "m_tiles": m["m_tiles"],
                            "launches": m["launches"]})
    with open(out_path, "w") as f:
        json.dump({"sweep": "onehot_vs_gmm_vs_ragged",
                   "config": {"E": E, "K": K, "D": D, "F": F, "bm": bm,
                              "gamma": gamma},
                   "rows": records}, f, indent=1)
    return rows


def run() -> list:
    rows = []
    # gmm: one dbrx-132b MoE layer's verify workload (B=32, gamma+1=5 tokens)
    E, C, D, F = 16, 128, 512, 672
    x = jax.random.normal(jax.random.PRNGKey(0), (E, C, D), jnp.float32)
    w = jax.random.normal(jax.random.PRNGKey(1), (E, D, F), jnp.float32)
    us_ref = _time(jax.jit(gmm_capacity_ref), x, w)
    flops = 2 * E * C * D * F
    bytes_ = (E * C * D + E * D * F + E * C * F) * 2
    rows.append(csv_row("kernel_gmm_ECDF_16x128x512x672", us_ref,
                        f"v5e_roofline_us={_proj_us(flops, bytes_):.1f};"
                        f"ai={flops/bytes_:.1f}"))

    # flash attention: prefill tile
    B, Hq, Hkv, T, Dh = 1, 8, 2, 1024, 128
    q = jax.random.normal(jax.random.PRNGKey(2), (B, Hq, T, Dh), jnp.float32)
    k = jax.random.normal(jax.random.PRNGKey(3), (B, Hkv, T, Dh), jnp.float32)
    v = jax.random.normal(jax.random.PRNGKey(4), (B, Hkv, T, Dh), jnp.float32)
    us_ref = _time(jax.jit(lambda a, b, c: flash_attention_ref(a, b, c)),
                   q, k, v)
    flops = 2 * B * Hq * T * T * Dh * 2
    bytes_ = (q.size + k.size + v.size + q.size) * 2
    rows.append(csv_row("kernel_flash_prefill_1k", us_ref,
                        f"v5e_roofline_us={_proj_us(flops, bytes_):.1f};"
                        f"ai={flops/bytes_:.1f}"))

    # decode attention: the paper's verify hot spot (gamma+1=5 vs 32k KV)
    B, Hq, Hkv, T, S, Dh = 4, 8, 2, 5, 8192, 128
    q = jax.random.normal(jax.random.PRNGKey(5), (B, Hq, T, Dh), jnp.float32)
    k = jax.random.normal(jax.random.PRNGKey(6), (B, Hkv, S, Dh), jnp.float32)
    v = jax.random.normal(jax.random.PRNGKey(7), (B, Hkv, S, Dh), jnp.float32)
    lengths = jnp.full((B,), S - T, jnp.int32)
    us_ref = _time(jax.jit(lambda a, b, c, l: decode_attention_ref(a, b, c, l)),
                   q, k, v, lengths)
    flops = 2 * B * Hq * T * S * Dh * 2
    bytes_ = (k.size + v.size) * 2
    ai = flops / bytes_
    rows.append(csv_row("kernel_decode_verify_g4_8k", us_ref,
                        f"v5e_roofline_us={_proj_us(flops, bytes_):.1f};"
                        f"ai={ai:.2f};memory_bound={ai < V5E.ridge_point}"))
    # AR decode (T=1) same cache: verification is ~free vs 5x AR memory reads
    flops1 = 2 * B * Hq * 1 * S * Dh * 2
    rows.append(csv_row("kernel_decode_ar_8k", 0.0,
                        f"v5e_roofline_us={_proj_us(flops1, bytes_):.1f};"
                        "note=same_bytes_as_verify"))
    rows.extend(moe_dispatch_sweep())
    return rows

"""Kernel micro-bench: interpret-mode wall time (CPU, correctness-grade) +
v5e roofline projection per kernel call (the real perf number)."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import csv_row
from repro.core.simulator import V5E
from repro.kernels.decode_attention.ref import decode_attention_ref
from repro.kernels.flash_attention.ref import flash_attention_ref
from repro.kernels.gmm.ref import gmm_capacity_ref


def _time(fn, *args, iters=3):
    fn(*args).block_until_ready()
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    out.block_until_ready()
    return (time.perf_counter() - t0) * 1e6 / iters


def _proj_us(flops, bytes_):
    return max(flops / (V5E.peak_flops * V5E.compute_eff),
               bytes_ / (V5E.hbm_bw * V5E.mem_eff)) * 1e6


def run() -> list:
    rows = []
    # gmm: one dbrx-132b MoE layer's verify workload (B=32, gamma+1=5 tokens)
    E, C, D, F = 16, 128, 512, 672
    x = jax.random.normal(jax.random.PRNGKey(0), (E, C, D), jnp.float32)
    w = jax.random.normal(jax.random.PRNGKey(1), (E, D, F), jnp.float32)
    us_ref = _time(jax.jit(gmm_capacity_ref), x, w)
    flops = 2 * E * C * D * F
    bytes_ = (E * C * D + E * D * F + E * C * F) * 2
    rows.append(csv_row("kernel_gmm_ECDF_16x128x512x672", us_ref,
                        f"v5e_roofline_us={_proj_us(flops, bytes_):.1f};"
                        f"ai={flops/bytes_:.1f}"))

    # flash attention: prefill tile
    B, Hq, Hkv, T, Dh = 1, 8, 2, 1024, 128
    q = jax.random.normal(jax.random.PRNGKey(2), (B, Hq, T, Dh), jnp.float32)
    k = jax.random.normal(jax.random.PRNGKey(3), (B, Hkv, T, Dh), jnp.float32)
    v = jax.random.normal(jax.random.PRNGKey(4), (B, Hkv, T, Dh), jnp.float32)
    us_ref = _time(jax.jit(lambda a, b, c: flash_attention_ref(a, b, c)),
                   q, k, v)
    flops = 2 * B * Hq * T * T * Dh * 2
    bytes_ = (q.size + k.size + v.size + q.size) * 2
    rows.append(csv_row("kernel_flash_prefill_1k", us_ref,
                        f"v5e_roofline_us={_proj_us(flops, bytes_):.1f};"
                        f"ai={flops/bytes_:.1f}"))

    # decode attention: the paper's verify hot spot (gamma+1=5 vs 32k KV)
    B, Hq, Hkv, T, S, Dh = 4, 8, 2, 5, 8192, 128
    q = jax.random.normal(jax.random.PRNGKey(5), (B, Hq, T, Dh), jnp.float32)
    k = jax.random.normal(jax.random.PRNGKey(6), (B, Hkv, S, Dh), jnp.float32)
    v = jax.random.normal(jax.random.PRNGKey(7), (B, Hkv, S, Dh), jnp.float32)
    lengths = jnp.full((B,), S - T, jnp.int32)
    us_ref = _time(jax.jit(lambda a, b, c, l: decode_attention_ref(a, b, c, l)),
                   q, k, v, lengths)
    flops = 2 * B * Hq * T * S * Dh * 2
    bytes_ = (k.size + v.size) * 2
    ai = flops / bytes_
    rows.append(csv_row("kernel_decode_verify_g4_8k", us_ref,
                        f"v5e_roofline_us={_proj_us(flops, bytes_):.1f};"
                        f"ai={ai:.2f};memory_bound={ai < V5E.ridge_point}"))
    # AR decode (T=1) same cache: verification is ~free vs 5x AR memory reads
    flops1 = 2 * B * Hq * 1 * S * Dh * 2
    rows.append(csv_row("kernel_decode_ar_8k", 0.0,
                        f"v5e_roofline_us={_proj_us(flops1, bytes_):.1f};"
                        "note=same_bytes_as_verify"))
    return rows

"""Paper §2/§3.4 claim: expert prefetching/caching "lose efficiency under
moderate batch sizes since nearly all experts are activated".

Quantified: the utility of skipping an expert load is the probability the
expert is NOT activated this step, (1-ρ)^t (Eq. 7's complement); the
utility of caching a hot expert is the activation-probability spread,
which collapses as t grows.  Verified against a REAL trained router."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import csv_row, trained_params
from repro.core.analytics import expected_activated_experts
from repro.data.pipeline import packed_batches
from repro.models.moe import expert_activation_counts, router_topk


def run() -> list:
    rows = []
    for E, K in ((64, 8), (128, 8), (16, 4)):
        rho = K / E
        for t in (1, 8, 32, 128, 512):
            skip_util = (1 - rho) ** t           # P(expert idle) per step
            frac_active = float(expected_activated_experts(t, E, K)) / E
            rows.append(csv_row(
                f"prefetch_E{E}K{K}_t{t}", 0.0,
                f"p_idle={skip_util:.3f};frac_active={frac_active:.3f}"))
    # measured on a real trained router (reduced E=4,K=2): fraction of
    # experts idle per batch collapses with t exactly as predicted
    model, params = trained_params("qwen2-57b-a14b", "chat", seed=0)
    cfg = model.cfg
    router_w = params["layers"][0]["ffn"]["router"][0]
    it = packed_batches(cfg.vocab_size, 1, 256, kind="chat", seed=11)
    embed = params["embed"]["table"]
    for t in (1, 4, 32):
        idle = []
        for s in range(30):
            toks = jnp.asarray(next(it)["tokens"])[0]
            _, idx, _ = router_topk({"router": router_w}, cfg, embed[toks][:t])
            counts = expert_activation_counts(idx, cfg.num_experts)
            idle.append(float((counts == 0).mean()))
        pred = (1 - cfg.moe_sparsity) ** t
        rows.append(csv_row(
            f"prefetch_measured_t{t}", 0.0,
            f"idle_measured={np.mean(idle):.3f};idle_theory={pred:.3f}"))
    return rows

"""Fig. 2 — SD speedup + target efficiency vs batch size.

sigma/alpha: REAL reduced-model SD runs per batch size (they vary little
with B, matching the paper's observation); T_T/T_D: v5e simulator on the
FULL Qwen2-57B-A14B + Qwen2-0.5B configs."""
from __future__ import annotations

import numpy as np

from benchmarks import common
from benchmarks.common import Timer, csv_row, trained_pair, measure_sigma
from repro.configs.registry import get_config
from repro.core.simulator import Simulator

BATCHES = [1, 4, 8, 16, 32, 64, 128, 256]


def run() -> list:
    rows = []
    target_full = get_config("qwen2-57b-a14b")
    draft_full = get_config("qwen2-0.5b")
    sim = Simulator()
    (t, pt), (d, pd) = trained_pair(kind="code")
    t0 = Timer()
    n = 0
    proposer = common.DEFAULT_PROPOSER
    draft_cost = common.draft_cost_config(proposer, target_full, draft_full)
    for gamma in (2, 4):
        for B in BATCHES:
            stats = measure_sigma(t, pt, d, pd, batch=min(B, 16), gamma=gamma,
                                  temperature=0.0, kind="code",
                                  proposer=proposer)
            n += 1
            # "none" IS the AR baseline: x = T_AR/T_AR = 1 by definition
            spd = 1.0 if proposer == "none" else sim.sd_speedup(
                target_full, draft_cost, B, gamma, stats.sigma)
            eff = sim.target_efficiency(target_full, B, gamma)
            rows.append(csv_row(
                f"fig2_qwen2moe_g{gamma}_B{B}", t0.us(n),
                f"speedup={spd:.3f};target_eff={eff:.3f};"
                f"sigma={stats.sigma:.3f};alpha={stats.alpha:.3f};"
                f"proposer={proposer}"))
    # trend assertions recorded as derived flags
    spds = [float(r.split("speedup=")[1].split(";")[0]) for r in rows
            if "_g4_" in r]
    peak = int(np.argmax(spds))
    rows.append(csv_row(
        "fig2_trend_check", 0.0,
        f"rises_then_falls={0 < peak}; peak_B={BATCHES[peak]}"))
    return rows

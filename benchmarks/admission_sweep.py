"""Admission overhead vs pool size: legacy full-pool vs row-sliced prefill.

PR 4's continuous scheduler admitted every refill through a POOL-shaped
prefill — the non-admitted rows were computed and discarded, so a 1-row
refill into an 8-slot pool paid ~8x the prefill work it needed, and the
prompt bucket ratcheted up for the stream's lifetime.  The row-sliced
path (``SDEngine.admit_rows``) prefills (admitted_rows, per-admission
bucket) instead, so admission work is ∝ what was admitted.

This sweep serves the SAME staggered-arrival stream (refills land one row
at a time — the steady-state serving case) at pool sizes 2/4/8 under both
admission modes and records the prefill row-tokens each mode dispatched
(``StepReport.admit_rows``/``admit_tokens``) plus wall time.  The
work-scaling acceptance is structural, not a timing: sliced row-tokens
stay FLAT as the pool grows while the full path's grow ∝ pool.

It also replays the robustness trace the paged KV layout exists for: a
mixed-length Poisson stream that receives a LATE long request mid-stream
— the dense layout was sized without it (and would have died with a
stream-sizing ValueError before this PR; it now rejects), the paged
layout grows its block-table pool and serves it to completion.

Writes BENCH_admission.json.
"""
from __future__ import annotations

import json
import time

import jax
import numpy as np

from benchmarks.common import csv_row
from repro.configs.base import ModelConfig
from repro.core.analytics import admission_work
from repro.models.model import Model
from repro.serving.engine import ServingEngine

POOLS = (2, 4, 8)
N_REQUESTS = 12                 # FIXED workload across pool sizes
MAX_NEW = 6
SEED = 7

TCFG = ModelConfig("adm-moe", "moe", 2, 128, 4, 2, 256, 512, num_experts=4,
                   num_experts_per_tok=2, dtype="float32")
DCFG = ModelConfig("adm-draft", "dense", 2, 64, 2, 2, 128, 512,
                   dtype="float32")


def _models():
    t, d = Model(TCFG), Model(DCFG)
    return t, d, t.init(jax.random.PRNGKey(0)), d.init(jax.random.PRNGKey(1))


def _serve(t, d, pt, pd, pool: int, admit_mode: str, **kw):
    """Staggered FIXED-size stream: ``pool`` initial requests, the rest
    arriving one per few rounds, so each refill is a 1-row admission.
    Total admitted rows is constant across pool sizes — any extra
    admission work a bigger pool pays is pure overhead."""
    eng = ServingEngine(t, d, pt, pd, max_batch=pool, gamma=2,
                        force_sd=True, scheduler="continuous",
                        admit_mode=admit_mode, seed=SEED, **kw)
    rng = np.random.default_rng(SEED)
    for i in range(N_REQUESTS):
        plen = int(rng.integers(5, 9))
        eng.submit(np.arange(3, 3 + plen),
                   max_new_tokens=MAX_NEW,
                   arrival_round=0 if i < pool else 2 + (i - pool) * 3)
    t0 = time.perf_counter()
    (report,) = [r for r in [eng.step_continuous()] if r]
    wall = time.perf_counter() - t0
    return eng, report, wall


class _LateLong:
    """Tuner stub that submits one 48-token request mid-stream."""

    gammas = (2,)

    def __init__(self):
        self.eng, self.uid, self.calls = None, None, 0

    def plan(self, batch):
        self.calls += 1
        if self.calls == 3 and self.uid is None:
            self.uid = self.eng.submit(np.arange(3, 51), max_new_tokens=6)
        return {"use_sd": True, "gamma": 2, "predicted_speedup": 2.0}

    def update_alpha(self, alpha):
        pass


def run(out_path: str = "BENCH_admission.json") -> list:
    t, d, pt, pd = _models()
    rows, sweep = [], []
    for pool in POOLS:
        per_mode = {}
        for mode in ("full", "sliced"):
            eng, report, wall = _serve(t, d, pt, pd, pool, mode)
            prefill_rows = sum(s.admit_rows for s in report.steps)
            prefill_tokens = sum(s.admit_tokens for s in report.steps)
            admitted = sum(s.admitted for s in report.steps)
            per_mode[mode] = {
                "wall_s": round(wall, 4),
                "admitted": admitted,
                "prefill_rows": prefill_rows,
                "prefill_tokens": prefill_tokens,
                "admit_traces": eng.session_stats()["model"]["admit_traces"],
            }
            rows.append(csv_row(
                f"admission_pool{pool}_{mode}", wall * 1e6,
                f"prefill_tokens={prefill_tokens};admitted={admitted}"))
        ratio = per_mode["full"]["prefill_tokens"] \
            / max(per_mode["sliced"]["prefill_tokens"], 1)
        sweep.append({"pool": pool, **per_mode,
                      "full_over_sliced_tokens": round(ratio, 3)})
    # sliced admission work is ∝ admitted rows: FLAT across pool sizes
    # (same workload shape), while the full path scales with the pool
    sliced_tok = [s["sliced"]["prefill_tokens"] for s in sweep]
    full_tok = [s["full"]["prefill_tokens"] for s in sweep]
    assert full_tok[-1] > full_tok[0], "full path should scale with pool"
    assert max(sliced_tok) <= 2 * min(sliced_tok), \
        "sliced admission work must not scale with the pool"

    # ---- robustness trace: late long request, paged growth, no ValueError
    tuner = _LateLong()
    eng = ServingEngine(t, d, pt, pd, max_batch=2, gamma=2, tuner=tuner,
                        force_sd=True, scheduler="continuous",
                        kv_layout="paged", page_size=8, prefill_chunk=8,
                        seed=SEED)
    tuner.eng = eng
    rng = np.random.default_rng(SEED)
    for i in range(6):
        plen = int(rng.integers(5, 12))
        eng.submit(np.arange(3, 3 + plen),
                   max_new_tokens=int(rng.choice((4, 6, 10))),
                   arrival_round=i)
    eng.run()
    late = eng.done[tuner.uid]
    stats = eng.session_stats()["model"]
    assert late.finish_reason == "length" and len(late.output) == 6, \
        "late long request must complete under paged growth"
    rows.append(csv_row(
        "admission_paged_late_long", 0.0,
        f"finish={late.finish_reason};growths={len(stats['growths'])}"))

    agg = admission_work(
        [(tp, r) for s in sweep for tp, r in s["sliced"]["admit_traces"]],
        pool=max(POOLS), full_bucket=8)
    with open(out_path, "w") as f:
        json.dump({
            "sweep": "admission_overhead_vs_pool",
            "arch": TCFG.name, "max_new": MAX_NEW, "pools": list(POOLS),
            "note": "same staggered 1-row-refill stream per pool size; "
                    "prefill_tokens = rows*bucket the admission prefills "
                    "actually dispatched (StepReport accounting); sliced "
                    "work is flat in pool, full work ∝ pool.  The paged "
                    "trace receives a 48-token request MID-STREAM (unknown "
                    "at sizing) and completes via block-table growth.",
            "per_pool": sweep,
            "sliced_work_model": agg,
            "paged_late_long": {
                "finish_reason": late.finish_reason,
                "tokens_out": int(len(late.output)),
                "growths": stats["growths"],
                "chunk_traces": len(stats["chunk_traces"]),
            },
        }, f, indent=1)
    return rows


if __name__ == "__main__":
    for row in run():
        print(row)

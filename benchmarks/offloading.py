"""Paper §3.4 extended configurations — expert offloading.

When MoE expert weights are offloaded to host memory (KTransformers-style),
their load bandwidth drops from HBM (819 GB/s) to PCIe-class DMA; the FFN
becomes more memory-bound and SD gains a wider, higher window.  Also checks
the EP observation: more aggregate bandwidth (chips) re-shrinks the
small-batch SD penalty.

``run(dry=True)`` evaluates each configuration at two batch points instead
of the full sweep — a structural smoke (finite, positive speedups; window
arithmetic) cheap enough for tier-1 tests and CI.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import csv_row
from repro.configs.registry import get_config
from repro.core.analytics import sigma_from_alpha
from repro.core.simulator import Hardware, Simulator

BATCHES = [1, 2, 4, 8, 16, 32, 64, 128, 256]
DRY_BATCHES = [1, 8]


def run(dry: bool = False) -> list:
    """Offloading/EP speedup rows; ``dry`` shrinks the batch sweep.

    Every configuration's speedup curve is validated finite and positive
    before any window arithmetic — a simulator regression (zero bandwidth,
    overflowed load time) fails HERE with the offending curve instead of
    surfacing as a nonsense CSV row downstream."""
    batches = DRY_BATCHES if dry else BATCHES
    rows = []
    target = get_config("qwen2-57b-a14b")
    draft = get_config("qwen2-0.5b")
    sigma = float(sigma_from_alpha(0.8, 4))
    for name, sim in (
        ("hbm", Simulator()),
        ("offload_pcie64", Simulator(expert_offload_bw=64e9)),
        ("offload_pcie16", Simulator(expert_offload_bw=16e9)),
    ):
        curve = [sim.sd_speedup(target, draft, b, 4, sigma) for b in batches]
        if not all(np.isfinite(s) and s > 0 for s in curve):
            raise RuntimeError(
                f"offloading: non-finite/non-positive speedup curve for "
                f"{name}: {curve} — simulator bandwidth/latency terms are "
                "corrupted")
        i = int(np.argmax(curve))
        thr = curve[i] / np.sqrt(2)
        win = [b for b, s in zip(batches, curve) if s >= thr] \
            or [batches[i]]
        rows.append(csv_row(
            f"offload_{name}", 0.0,
            f"peak={curve[i]:.2f};peak_B={batches[i]};"
            f"window={min(win)}-{max(win)};B1={curve[0]:.2f}"))
    # EP aggregate-bandwidth observation: 4-chip group recovers small-batch SD
    for chips in (1, 4):
        sim = Simulator(hw=Hardware(num_chips=chips))
        s1 = sim.sd_speedup(target, draft, 1, 4, sigma)
        if not (np.isfinite(s1) and s1 > 0):
            raise RuntimeError(
                f"offloading: non-finite EP speedup at chips={chips}: {s1}")
        rows.append(csv_row(f"offload_ep_chips{chips}_B1", 0.0,
                            f"speedup_B1={s1:.2f}"))
    return rows


if __name__ == "__main__":
    for row in run(dry=True):
        print(row)

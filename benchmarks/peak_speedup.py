"""Tables 1-2 — peak SD speedup x across (dataset, temperature, gamma) with
REAL sigma from trained reduced pairs; absolute times from the v5e
simulator on the full configs; plus the multi-chip scaling observation."""
from __future__ import annotations

import numpy as np

from benchmarks import common
from benchmarks.common import Timer, csv_row, trained_pair, measure_sigma
from repro.configs.registry import get_config
from repro.core.simulator import Hardware, Simulator

BATCHES = [1, 4, 8, 16, 32, 64, 128, 256]


def run() -> list:
    rows = []
    t0 = Timer()
    n = 0
    sim = Simulator()
    full_t = {"qwen2": get_config("qwen2-57b-a14b"),
              "mixtral": get_config("mixtral-8x7b")}
    full_d = get_config("qwen2-0.5b")
    pairs = {}
    for kind in ("code", "chat"):
        pairs[("qwen2", kind)] = trained_pair("qwen2-57b-a14b", kind)
        pairs[("mixtral", kind)] = trained_pair("mixtral-8x7b", kind)

    for model_name in ("qwen2", "mixtral"):
        draft_cost = common.draft_cost_config(
            common.DEFAULT_PROPOSER, full_t[model_name], full_d)
        for kind, ds in (("code", "humaneval-like"), ("chat", "mtbench-like")):
            (t, pt), (d, pd) = pairs[(model_name, kind)]
            for temp in (0.0, 1.0):
                for gamma in (2, 3, 4):
                    stats = measure_sigma(t, pt, d, pd, batch=8, gamma=gamma,
                                          temperature=temp, kind=kind,
                                          proposer=common.DEFAULT_PROPOSER)
                    n += 1
                    curve = [1.0 if common.DEFAULT_PROPOSER == "none"
                             else sim.sd_speedup(full_t[model_name],
                                                 draft_cost, B, gamma,
                                                 stats.sigma)
                             for B in BATCHES]
                    i = int(np.argmax(curve))
                    t_ar = sim.forward_time(full_t[model_name], BATCHES[i], 1)
                    rows.append(csv_row(
                        f"table1_{model_name}_{ds}_T{temp}_g{gamma}",
                        t0.us(n),
                        f"x={curve[i]:.2f};peak_B={BATCHES[i]};"
                        f"sigma={stats.sigma:.2f};alpha={stats.alpha:.2f};"
                        f"T_AR_ms={t_ar*1e3:.2f};"
                        f"proposer={common.DEFAULT_PROPOSER}"))

    # Table 2 analogue: chip-count scaling (2 vs 4 chips):
    # larger groups cut absolute time but draft stays single-chip → x drops
    (t, pt), (d, pd) = pairs[("qwen2", "code")]
    stats = measure_sigma(t, pt, d, pd, batch=8, gamma=4, temperature=0.0,
                          kind="code")
    for chips in (1, 2, 4):
        sim_c = Simulator(hw=Hardware(num_chips=chips))
        sim_d = Simulator(hw=Hardware(num_chips=1))     # draft not sharded
        curve = []
        for B in BATCHES:
            t_ar = sim_c.forward_time(full_t["qwen2"], B, 1)
            rt = (5 * sim_d.forward_time(full_d, B, 1)
                  + sim_c.forward_time(full_t["qwen2"], B, 5)
                  + sim_c.reject_time(B, 4, full_t["qwen2"].vocab_size))
            curve.append(stats.sigma * 5 * t_ar / rt)
        i = int(np.argmax(curve))
        rows.append(csv_row(
            f"table2_chips{chips}", 0.0,
            f"x={curve[i]:.2f};peak_B={BATCHES[i]};"
            f"T_AR_ms={sim_c.forward_time(full_t['qwen2'], BATCHES[i], 1)*1e3:.2f}"))
    return rows

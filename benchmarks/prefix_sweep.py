"""Prefix-sharing admission sweep: shared-prompt streams, fork vs prefill.

The private-serving workload the paper targets (Sec. 3.4) is dominated by
requests that share a long system prompt.  Without sharing, every
admission re-prefills that common prefix; with ``prefix_sharing=True``
(serving/scheduler.py, docs/paged_attention.md) the first request becomes
the fork leader and every sibling maps its prefix to the leader's KV
pages (refcounted, copy-on-write at the tail boundary), target-prefilling
only its private tail.

This sweep serves the SAME shared-prompt stream at system-prompt lengths
{0, 16, 32} with sharing off and on and records:

  * ``StepReport.admit_tokens`` — target prefill row-tokens dispatched
    (the work sharing removes) and ``shared_tokens`` — prompt tokens
    mapped to forked pages instead of prefilled,
  * greedy OUTPUT PARITY — the shared stream must be token-identical to
    the unshared one (forked prefix KV is bit-equal to recomputed KV),
  * model-side pricing — ``SpeedupModel.prefix_admission_time`` vs
    ``admission_time`` (illustrative fitted params) and the paged-extend
    HBM traffic ratio of the dense ``pool[table]`` gather fallback vs the
    block-table-walking kernel (``paged_extend_traffic_time``).

Writes BENCH_prefix.json.
"""
from __future__ import annotations

import json
import time

import jax
import numpy as np

from benchmarks.common import csv_row
from repro.configs.base import ModelConfig
from repro.core.perf_model import SpeedupModel
from repro.models.model import Model
from repro.serving.engine import ServingEngine

SHARED = (0, 16, 32)
N_REQUESTS = 5
MAX_NEW = 4
PAGE = 8
SEED = 11

TCFG = ModelConfig("px-moe", "moe", 2, 128, 4, 2, 256, 512, num_experts=4,
                   num_experts_per_tok=2, dtype="float32")
DCFG = ModelConfig("px-draft", "dense", 2, 64, 2, 2, 128, 512,
                   dtype="float32")

# illustrative fitted parameters (bias, k1, k2, k3, draft_bias, draft_k,
# reject_bias, reject_k, lam, s) — the admission-time RATIO is what the
# sweep reports, and it is parameter-shape-stable
_PARAMS = np.array([1e-3, 2e-4, 1e-4, 1e-4, 1e-4, 2e-5,
                    1e-5, 1e-6, 0.5, 1.5])


def _models():
    t, d = Model(TCFG), Model(DCFG)
    return t, d, t.init(jax.random.PRNGKey(0)), d.init(jax.random.PRNGKey(1))


def _serve(t, d, pt, pd, shared: int, sharing: bool):
    """Serve N_REQUESTS requests with a ``shared``-token common system
    prompt + short private tails, all arriving at round 0 (the stagger
    path: the first admission becomes the fork leader)."""
    eng = ServingEngine(t, d, pt, pd, max_batch=3, gamma=2, force_sd=True,
                        scheduler="continuous", kv_layout="paged",
                        page_size=PAGE, prefix_sharing=sharing, seed=SEED)
    rng = np.random.default_rng(SEED)
    sys_toks = rng.integers(3, 250, size=shared)
    for _ in range(N_REQUESTS):
        tail = rng.integers(3, 250, size=int(rng.integers(4, 8)))
        eng.submit(np.concatenate([sys_toks, tail]).astype(np.int32),
                   max_new_tokens=MAX_NEW, arrival_round=0)
    t0 = time.perf_counter()
    report = eng.step_continuous()
    wall = time.perf_counter() - t0
    outs = {u: tuple(map(int, r.output)) for u, r in eng.done.items()}
    return eng, report, wall, outs


def run(out_path: str = "BENCH_prefix.json") -> list:
    t, d, pt, pd = _models()
    rows, sweep = [], []
    for shared in SHARED:
        per, outs_by_mode = {}, {}
        for sharing in (False, True):
            eng, report, wall, outs = _serve(t, d, pt, pd, shared, sharing)
            mode = "share" if sharing else "plain"
            admit_tok = sum(s.admit_tokens for s in report.steps)
            shared_tok = sum(s.shared_tokens for s in report.steps)
            per[mode] = {
                "wall_s": round(wall, 4),
                "admit_tokens": admit_tok,
                "shared_tokens": shared_tok,
                "prefix_hits": eng.fault_counters.get("prefix_hits", 0),
                "cow_copies": eng.fault_counters.get("cow_copies", 0),
            }
            outs_by_mode[mode] = outs
            rows.append(csv_row(
                f"prefix_shared{shared}_{mode}", wall * 1e6,
                f"admit_tokens={admit_tok};shared_tokens={shared_tok}"))
        # forked prefix KV must be bit-equal to recomputed KV: greedy
        # outputs byte-identical between the two modes
        assert outs_by_mode["share"] == outs_by_mode["plain"], \
            f"prefix sharing changed greedy tokens at shared={shared}"
        if shared >= 2 * PAGE:
            assert per["share"]["prefix_hits"] >= N_REQUESTS - 1, per
            assert per["share"]["shared_tokens"] \
                >= (N_REQUESTS - 1) * shared, per
            assert per["share"]["admit_tokens"] \
                < per["plain"]["admit_tokens"], per
        sweep.append({"shared": shared, **per})

    # ---- model-side pricing: tail-only admission + paged extend traffic
    sm = SpeedupModel()
    K, E = TCFG.num_experts_per_tok, TCFG.num_experts
    full_t = float(sm.admission_time(1, 48, K, E, params=_PARAMS))
    tail_t = float(sm.prefix_admission_time(1, 48, 32, K, E,
                                            params=_PARAMS))
    gather = float(sm.paged_extend_traffic_time(
        4, 48, 16, PAGE, TCFG.num_kv_heads, TCFG.head_dim, mode="gather"))
    kernel = float(sm.paged_extend_traffic_time(
        4, 48, 16, PAGE, TCFG.num_kv_heads, TCFG.head_dim, mode="kernel"))
    assert tail_t < full_t and kernel < gather
    rows.append(csv_row("prefix_model_admission", 0.0,
                        f"full={full_t:.2e};tail={tail_t:.2e};"
                        f"saving={1 - tail_t / full_t:.2f}"))
    rows.append(csv_row("prefix_model_extend_traffic", 0.0,
                        f"gather={gather:.2e};kernel={kernel:.2e};"
                        f"ratio={gather / kernel:.1f}"))

    with open(out_path, "w") as f:
        json.dump({
            "sweep": "prefix_sharing_vs_shared_prompt_len",
            "arch": TCFG.name, "requests": N_REQUESTS,
            "page_size": PAGE, "shared": list(SHARED),
            "note": "same shared-system-prompt stream with prefix_sharing "
                    "off/on; admit_tokens = target prefill row-tokens "
                    "dispatched, shared_tokens = prompt tokens mapped to "
                    "forked pages.  Greedy outputs are asserted "
                    "byte-identical between modes.  Model rows price the "
                    "tail-only admission and the gather-vs-kernel paged "
                    "extend HBM traffic.",
            "per_shared": sweep,
            "model": {"admission_full_s": full_t,
                      "admission_tail_s": tail_t,
                      "extend_traffic_gather_s": gather,
                      "extend_traffic_kernel_s": kernel},
        }, f, indent=1)
    return rows


if __name__ == "__main__":
    for row in run():
        print(row)

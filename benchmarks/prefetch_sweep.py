"""Prefetch-aware proposer sweep (SP-MoE, arXiv:2510.10302): measured
expert-warmup hit rates per wave + the perf-model's priced T_target
reduction, written to BENCH_prefetch.json.

Real runs: the trained reduced MoE target serves waves through
``ServingEngine(proposer="prefetch")``; every wave's WaveReport carries the
hit/miss counts the verify passes scored against the router-probe plan.
The analytic rows price what the measured hit rate h is worth: the verify
call's expert-load term shrinks to k2·N(t)·(1-h) (core/perf_model).
"""
from __future__ import annotations

import json

import numpy as np

from benchmarks.common import csv_row, trained_pair
from repro.core.perf_model import SpeedupModel
from repro.data.pipeline import prompt_batch

# synthetic-unit parameter vector (same convention as the perf-model tests):
# [bias, k1, k2, k3, draft_bias, draft_k, reject_bias, reject_k, lam, s]
UNIT_PARAMS = np.array([1.0, 0.5, 2.0, 1.5, 0.1, 0.05, 0.01, 0.001, 0.5, 1.2])


def run(out_path: str = "BENCH_prefetch.json") -> list:
    from repro.serving.engine import ServingEngine

    (target, pt), (draft, pd) = trained_pair("qwen2-57b-a14b", kind="chat")
    cfg = target.cfg
    E, K = cfg.num_experts, cfg.num_experts_per_tok
    gamma = 4
    # tight warm budget (half the experts): the reduced configs are small
    # enough that the default min(E, 2K) would warm EVERYTHING and measure
    # a trivial hit rate of 1.0 — halving it makes the probe's prediction
    # quality visible against the random-warm baseline top_m/E
    top_m = max(1, E // 2)
    rows, records = [], []
    for B in (1, 2, 4):
        eng = ServingEngine(target, draft, pt, pd, max_batch=B, gamma=gamma,
                            force_sd=True, proposer="prefetch", seed=B,
                            proposer_opts={"top_m": top_m})
        pb = prompt_batch(cfg.vocab_size, B, kind="chat", seed=B)
        for i in range(B):
            eng.submit(pb["tokens"][i][: pb["lengths"][i]],
                       max_new_tokens=16)
        report = eng.step()
        s = report.stats
        h = s.prefetch_hit_rate
        # what h is worth at the verify token count N = B*(gamma+1): the
        # warmed experts' load term is hidden under the propose phase
        model = SpeedupModel(dispatch="gmm")
        t_cold = float(model.target_time(B * (gamma + 1), K, E,
                                         params=UNIT_PARAMS,
                                         prefetch_hit_rate=0.0))
        t_warm = float(model.target_time(B * (gamma + 1), K, E,
                                         params=UNIT_PARAMS,
                                         prefetch_hit_rate=h))
        saved_pct = 100.0 * (t_cold - t_warm) / t_cold
        rows.append(csv_row(
            f"prefetch_sweep_B{B}", report.wall_time * 1e6,
            f"hit_rate={h:.3f};hits={s.prefetch_hits};"
            f"misses={s.prefetch_misses};t_target_saved_pct={saved_pct:.1f}"))
        records.append({
            "batch": B, "gamma": gamma, "E": E, "K": K, "top_m": top_m,
            "random_warm_baseline": top_m / max(E, 1),
            "rounds": s.rounds, "sigma": round(s.sigma, 4),
            "alpha": round(s.alpha, 4),
            "prefetch_hits": s.prefetch_hits,
            "prefetch_misses": s.prefetch_misses,
            "prefetch_predicted": s.prefetch_predicted,
            "hit_rate": round(h, 4),
            "tokens_per_second": round(report.tokens_per_second, 2),
            "t_target_cold": round(t_cold, 4),
            "t_target_warm": round(t_warm, 4),
            "t_target_saved_pct": round(saved_pct, 2),
        })
    with open(out_path, "w") as f:
        json.dump({"sweep": "prefetch_proposer_hit_rate",
                   "arch": cfg.name, "gamma": gamma,
                   "note": "hit_rate MEASURED from real SD waves; "
                           "t_target_saved_pct is MODELED (perf-model k2 "
                           "discount, synthetic UNIT_PARAMS) — realizing "
                           "it needs warmed-buffer donation (ROADMAP)",
                   "rows": records}, f, indent=1)
    return rows

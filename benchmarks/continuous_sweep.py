"""Continuous-vs-wave serving on a Poisson-arrival, mixed-length workload.

The wave scheduler decodes a static batch until its SLOWEST request
finishes — every finished row rides along as padding, and with mixed
``max_new_tokens`` that padding dominates.  The continuous slot scheduler
(serving/scheduler.py) retires rows the moment they finish and refills
them from the queue between rounds, so the pool stays near-full.

Both engines serve the IDENTICAL workload (same prompts, same mixed
budgets, same submission order; the wave engine admits FIFO and ignores
arrival rounds) after one identical warmup pass that pays all jit
compiles, so the measured walls compare steady-state scheduling, not
tracing.  The continuous stream additionally reports its measured N(t)
occupancy trajectory and the decay-aware PREDICTED speedup
(core/analytics.predicted_decay_speedup walked along the measured live
counts with the v5e-simulator AutoTuner) — the predicted-vs-measured
comparison the paper's batch-dependence claim calls for.

Writes BENCH_continuous.json.
"""
from __future__ import annotations

import json
import time

import numpy as np

from benchmarks.common import csv_row, trained_pair
from repro.configs.registry import draft_for, get_config
from repro.core.analytics import occupancy_timeline, predicted_decay_speedup
from repro.core.autotune import AutoTuner
from repro.data.pipeline import prompt_batch
from repro.serving.engine import ServingEngine
from repro.serving.scheduler import submit_poisson

N_REQUESTS = 10
POOL = 4
GAMMA = 4
MAX_NEW_CHOICES = (6, 12, 24)
ARRIVAL_RATE = 1.0          # mean arrivals per decode round
SEED = 7


def _serve(scheduler: str, target, pt, draft, pd):
    """One engine, one warmup pass + one measured pass of the workload."""
    cfg = target.cfg
    eng = ServingEngine(target, draft, pt, pd, max_batch=POOL, gamma=GAMMA,
                        force_sd=True, scheduler=scheduler, seed=SEED)
    pb = prompt_batch(cfg.vocab_size, N_REQUESTS, kind="chat", seed=SEED)
    for phase in ("warmup", "measure"):
        uids = submit_poisson(eng, pb["tokens"], pb["lengths"],
                              rate=ARRIVAL_RATE,
                              max_new_choices=MAX_NEW_CHOICES, seed=SEED)
        t0 = time.perf_counter()
        reports = eng.run()
        wall = time.perf_counter() - t0
    tokens = sum(len(eng.done[u].output) for u in uids)
    rounds = sum(r.stats.rounds for r in reports if r.stats)
    return {
        "engine": eng, "reports": reports, "wall": wall, "tokens": tokens,
        "rounds": rounds,
        "tokens_per_second": tokens / max(wall, 1e-9),
        "outputs": {u: eng.done[u].output for u in uids},
    }


def run(out_path: str = "BENCH_continuous.json") -> list:
    (target, pt), (draft, pd) = trained_pair("qwen2-57b-a14b", kind="chat")
    cfg = target.cfg
    wave = _serve("wave", target, pt, draft, pd)
    cont = _serve("continuous", target, pt, draft, pd)

    ratio = cont["tokens_per_second"] / max(wave["tokens_per_second"], 1e-9)
    # same requests, same budgets → identical token counts; rounds differ
    assert cont["tokens"] == wave["tokens"], \
        f"token accounting diverged: {cont['tokens']} != {wave['tokens']}"

    report = cont["reports"][-1]
    steps = report.steps
    live = [s.live for s in steps]
    committed = [s.committed for s in steps]
    occ = occupancy_timeline(live, committed)
    # decay-aware PREDICTED speedup: the v5e-simulator tuner's
    # speedup-vs-batch curve walked along the MEASURED N(t) trajectory
    full_cfg = get_config("qwen2-57b-a14b")
    tuner = AutoTuner(full_cfg, draft_for(full_cfg),
                      alpha=max(report.stats.alpha, 0.05))
    pred = predicted_decay_speedup(
        live, [s.gamma for s in steps],
        tuner.speedup, committed=committed)

    rows = [
        csv_row("continuous_sweep_wave", wave["wall"] * 1e6,
                f"tok_s={wave['tokens_per_second']:.2f};"
                f"rounds={wave['rounds']}"),
        csv_row("continuous_sweep_continuous", cont["wall"] * 1e6,
                f"tok_s={cont['tokens_per_second']:.2f};"
                f"rounds={cont['rounds']};speedup_vs_wave={ratio:.2f}"),
        csv_row("continuous_sweep_occupancy", 0.0,
                f"token_weighted_live={occ['token_weighted_live']:.2f};"
                f"predicted_decay_speedup={pred['token_weighted']:.2f}"),
    ]
    with open(out_path, "w") as f:
        json.dump({
            "sweep": "continuous_vs_wave_scheduler",
            "arch": cfg.name, "pool": POOL, "gamma": GAMMA,
            "requests": N_REQUESTS, "arrival_rate": ARRIVAL_RATE,
            "max_new_choices": list(MAX_NEW_CHOICES),
            "note": "identical Poisson-arrival mixed-length workload after "
                    "an identical warmup pass (jit compile excluded); the "
                    "wave engine admits FIFO and ignores arrival rounds; "
                    "predicted_decay_speedup is MODELED (v5e simulator "
                    "walked along the MEASURED N(t) trajectory)",
            "wave": {
                "wall_s": round(wave["wall"], 4),
                "tokens_out": wave["tokens"],
                "rounds": wave["rounds"],
                "tokens_per_second": round(wave["tokens_per_second"], 2),
            },
            "continuous": {
                "wall_s": round(cont["wall"], 4),
                "tokens_out": cont["tokens"],
                "rounds": cont["rounds"],
                "tokens_per_second": round(cont["tokens_per_second"], 2),
                "sigma": round(report.stats.sigma, 4),
                "alpha": round(report.stats.alpha, 4),
                "live_per_round": live,
                "admitted": sum(s.admitted for s in steps),
                "retired": sum(s.retired for s in steps),
                "occupancy": {k: round(v, 4) for k, v in occ.items()},
                "predicted_decay_speedup": {
                    "mean": round(pred["mean"], 4),
                    "token_weighted": round(pred["token_weighted"], 4),
                },
            },
            "speedup_continuous_vs_wave": round(ratio, 4),
        }, f, indent=1)
    return rows

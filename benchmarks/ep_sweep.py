"""Expert-parallel serving sweep: ep_degree ∈ {1, 2, 4, 8} on forced host
devices, asserting token-identical outputs vs single-device serving and
recording modeled-vs-measured a2a dispatch cost.

The sweep serves one fixed continuous workload (paged KV, staggered
arrivals, mixed token budgets) per ep_degree.  ep=1 is the meshless ragged
gmm engine — the oracle every sharded run must match byte-for-byte; ep>1
shards the experts over a ``("data","model")`` mesh and routes tokens
through the a2a→per-shard-ragged-gmm dispatch (distributed/collectives.py).

Two caveats the numbers must be read with (recorded in the JSON):

* forced host devices share ONE physical CPU, so walls measure dispatch
  and collective OVERHEAD, not expert-parallel speedup — the point of the
  sweep is the parity + accounting contract, not a throughput claim;
* the modeled a2a cost prices the volume ``2·N·K·d·bytes/ep`` against the
  v5e ICI bandwidth (core/perf_model.SpeedupModel.ep_a2a_time), while the
  measured column is the verify-phase wall delta vs ep=1 on that shared
  CPU — they are reported side by side, not asserted against each other.

Run with ``python -m benchmarks.ep_sweep`` (spawns its own subprocess with
``XLA_FLAGS=--xla_force_host_platform_device_count=8``).  Writes
BENCH_ep.json.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import textwrap

from repro.core.perf_model import SpeedupModel

# mirrored by the child script below — keep in sync
D_MODEL = 128
TOP_K = 2
N_MOE_LAYERS = 4
GAMMA = 3
EP_DEGREES = (1, 2, 4, 8)

_CHILD = textwrap.dedent("""
    import json, os, sys, time
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, numpy as np
    from repro.configs.base import ModelConfig
    from repro.launch.mesh import make_ep_mesh
    from repro.models.model import Model
    from repro.serving.engine import ServingEngine

    TCFG = ModelConfig("ep-bench-t", "moe", 4, 128, 4, 2, 256, 512,
                       num_experts=8, num_experts_per_tok=2,
                       dtype="float32")
    DCFG = ModelConfig("ep-bench-d", "dense", 2, 64, 2, 2, 128, 512,
                       dtype="float32")
    PROMPTS = [(np.arange(3 + i, 3 + i + 6 + (i % 3)) % 500 + 1)
               for i in range(8)]
    MAX_NEW = [16, 8, 12, 16, 8, 12, 16, 8]

    def serve(ep):
        mesh = make_ep_mesh(ep) if ep > 1 else None
        t = Model(TCFG, moe_dispatch="ep" if mesh is not None else "gmm",
                  mesh=mesh)
        d = Model(DCFG)
        pt = t.init(jax.random.PRNGKey(0))
        pd = d.init(jax.random.PRNGKey(1))
        eng = ServingEngine(t, d, pt, pd, max_batch=4, gamma=3,
                            force_sd=True, scheduler="continuous",
                            kv_layout="paged", page_size=16, seed=0,
                            timed=True, mesh=mesh)

        def stream():
            uids = [eng.submit(p, max_new_tokens=m, arrival_round=i // 3)
                    for i, (p, m) in enumerate(zip(PROMPTS, MAX_NEW))]
            t0 = time.perf_counter()
            reports = eng.run()
            return uids, reports, time.perf_counter() - t0

        stream()                           # warmup: pay every jit compile
        uids, reports, wall = stream()     # measured steady-state replay
        outputs = [eng.done[u].output.tolist() for u in uids]
        stats = [r.stats for r in reports if r.stats]
        ep_rep = next((r.ep for r in reversed(reports)
                       if r.ep is not None), None)
        return {
            "ep_degree": ep,
            "wall_s": wall,
            "tokens": sum(len(o) for o in outputs),
            "tokens_per_second": sum(len(o) for o in outputs)
                                 / max(wall, 1e-9),
            "rounds": sum(s.rounds for s in stats),
            "verify_positions": sum(s.max_possible for s in stats),
            "phase_times_s": {
                "propose": sum(s.propose_time for s in stats),
                "verify": sum(s.verify_time for s in stats),
                "reject": sum(s.reject_time for s in stats),
                "round": sum(s.round_time for s in stats),
            },
            "a2a_bytes_per_device": (ep_rep or {}).get(
                "a2a_bytes_per_device"),
            "per_shard_load": (ep_rep or {}).get("per_shard_load"),
            "outputs": outputs,
        }

    print(json.dumps([serve(ep) for ep in (1, 2, 4, 8)]))
""")


def run(out_path: str = "BENCH_ep.json") -> dict:
    env = dict(os.environ)
    env["PYTHONPATH"] = env.get("PYTHONPATH") or "src"
    env.setdefault("JAX_PLATFORMS", "cpu")
    proc = subprocess.run([sys.executable, "-c", _CHILD],
                          capture_output=True, text=True, timeout=1800,
                          env=env, cwd=os.path.dirname(
                              os.path.dirname(os.path.abspath(__file__))))
    if proc.returncode != 0:
        raise RuntimeError(f"ep sweep child failed:\n{proc.stderr[-3000:]}")
    rows = json.loads(proc.stdout.strip().splitlines()[-1])

    # token-identity contract: every sharded run ≡ the single-device run
    base = rows[0]
    assert base["ep_degree"] == 1
    for row in rows[1:]:
        assert row["outputs"] == base["outputs"], (
            f"ep={row['ep_degree']} outputs diverged from single-device")
        assert row["tokens"] == base["tokens"]
        row["token_identical_to_single_device"] = True

    # modeled vs measured a2a cost per sharded row
    model = SpeedupModel()
    for row in rows:
        ep = row["ep_degree"]
        vtpr = row["verify_positions"] / max(row["rounds"], 1)
        row["a2a_cost"] = {
            "modeled_s": row["rounds"] * float(model.ep_a2a_time(
                vtpr, TOP_K, D_MODEL, ep, n_layers=N_MOE_LAYERS)),
            "measured_verify_delta_s":
                row["phase_times_s"]["verify"]
                - base["phase_times_s"]["verify"],
        }
        del row["outputs"]      # parity asserted above; keep the JSON small

    out = {
        "benchmark": "ep_sweep",
        "workload": {"requests": 8, "gamma": GAMMA, "max_batch": 4,
                     "kv_layout": "paged", "scheduler": "continuous",
                     "d_model": D_MODEL, "top_k": TOP_K,
                     "n_moe_layers": N_MOE_LAYERS, "num_experts": 8},
        "note": ("forced host devices share one CPU: walls measure "
                 "dispatch/collective overhead, not EP speedup; modeled "
                 "a2a prices v5e ICI bandwidth"),
        "rows": rows,
    }
    with open(out_path, "w") as f:
        json.dump(out, f, indent=2)
        f.write("\n")
    for row in rows:
        print(f"ep={row['ep_degree']}: {row['tokens_per_second']:.1f} tok/s "
              f"verify={row['phase_times_s']['verify']:.3f}s "
              f"a2a modeled={row['a2a_cost']['modeled_s'] * 1e6:.2f}us "
              f"measured_delta={row['a2a_cost']['measured_verify_delta_s']:.3f}s")
    print(f"wrote {out_path}")
    return out


if __name__ == "__main__":
    run()

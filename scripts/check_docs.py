#!/usr/bin/env python
"""Docs gate for scripts/ci.sh: two fast, dependency-free checks.

1. LINK CHECK — every relative markdown link in README.md and docs/*.md
   must resolve to an existing file (anchors stripped; http(s)/mailto and
   pure-anchor links skipped).  Broken pointers into a moving codebase are
   how docs rot.
2. DOCSTRING PRESENCE — the public API surface named in docs/ must stay
   documented: protocol methods, serving entry points, kernel ops.

Exit code 1 with one line per failure.
"""
from __future__ import annotations

import os
import re
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

# [text](target) — excluding images is unnecessary (we have none), but skip
# reference-style and autolinks; multiline code fences are stripped first
_LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
_FENCE_RE = re.compile(r"```.*?```", re.S)


def iter_markdown_files():
    yield os.path.join(REPO, "README.md")
    docs = os.path.join(REPO, "docs")
    if os.path.isdir(docs):
        for name in sorted(os.listdir(docs)):
            if name.endswith(".md"):
                yield os.path.join(docs, name)


def check_links() -> list:
    errors = []
    for md in iter_markdown_files():
        with open(md) as f:
            text = _FENCE_RE.sub("", f.read())
        base = os.path.dirname(md)
        for target in _LINK_RE.findall(text):
            if target.startswith(("http://", "https://", "mailto:", "#")):
                continue
            path = target.split("#", 1)[0]
            if not path:
                continue
            resolved = os.path.normpath(os.path.join(base, path))
            if not os.path.exists(resolved):
                errors.append(
                    f"broken link in {os.path.relpath(md, REPO)}: "
                    f"({target}) -> {os.path.relpath(resolved, REPO)}")
    return errors


# object paths whose __doc__ must be a non-trivial string: the API surface
# docs/architecture.md points readers at
DOCUMENTED_API = [
    ("repro.core.proposer", ["Proposer.init_state", "Proposer.propose",
                             "Proposer.commit", "register_proposer",
                             "make_proposer", "registered_proposers"]),
    ("repro.core.prefetch", ["PrefetchProposer", "router_probe"]),
    ("repro.core.spec_decode", ["SDEngine", "SDEngine.start",
                                "SDEngine.round", "SDEngine.admit",
                                "SDEngine.admit_rows",
                                "SDEngine.begin_admit_chunked",
                                "SDEngine.admit_chunk",
                                "SDEngine.grow_session",
                                "SDEngine.admit_rows_prefix",
                                "SessionState", "RoundResult",
                                "PendingAdmission", "generate_ar"]),
    ("repro.serving.engine", ["ServingEngine.step",
                              "ServingEngine.step_continuous",
                              "ServingEngine.submit",
                              "ServingEngine.session_stats",
                              "finish_output"]),
    ("repro.serving.scheduler", ["ContinuousScheduler",
                                 "ContinuousScheduler.run_stream",
                                 "SlotState", "StepReport",
                                 "submit_poisson"]),
    ("repro.serving.faults", ["logits_finite", "poison_cache_row",
                              "FaultInjector", "FaultInjector.poisson",
                              "FaultInjector.page_service",
                              "ResilienceConfig", "Fault"]),
    ("repro.models.model", ["merge_cache_rows", "scatter_cache_rows",
                            "PageAllocator", "grow_cache_pages",
                            "grow_cache_seq", "Model.init_cache",
                            "PageAllocator.reserve", "PageAllocator.release",
                            "PageAllocator.assert_no_leaks",
                            "PageAllocator.fork_prefix",
                            "PageAllocator.extend_row",
                            "PageAllocator.cow_range",
                            "PageAllocator.shared_page_count",
                            "copy_cache_pages"]),
    ("repro.core.analytics", ["occupancy_timeline",
                              "predicted_decay_speedup",
                              "admission_work", "fault_recovery_summary"]),
    ("repro.kernels.gmm.ops", ["gmm", "gmm_legacy", "moe_ffn_gmm",
                               "expert_capacity"]),
    ("repro.kernels.decode_attention.ops", ["decode_attention",
                                            "paged_decode_attention"]),
    ("repro.models.moe", ["moe_forward", "warm_experts", "PrefetchPlan"]),
    ("repro.distributed.collectives", ["moe_ep_forward", "ep_a2a_bytes",
                                       "ep_load_report"]),
    ("repro.distributed.constraints", ["resolve_mesh", "set_mesh",
                                       "constrain", "data_axes_of"]),
    ("repro.distributed.sharding", ["shard_params", "shard_cache",
                                    "cache_spec", "param_spec"]),
    ("repro.launch.mesh", ["make_ep_mesh"]),
    ("repro.core.perf_model", ["SpeedupModel", "SpeedupModel.target_time",
                               "SpeedupModel.predict_decay",
                               "SpeedupModel.admission_time",
                               "SpeedupModel.prefix_admission_time",
                               "SpeedupModel.paged_extend_traffic_time",
                               "SpeedupModel.ep_a2a_time",
                               "SpeedupModel.ep_target_time"]),
    ("repro.analysis", ["analyze_paths", "compile_guard", "CompileGuard",
                        "compile_count", "compilation_events_available",
                        "transfer_guard", "TransferGuard",
                        "sharding_guard", "ShardingGuard", "pass_of",
                        "Finding", "Report", "ratchet", "load_baseline",
                        "write_baseline"]),
    ("repro.analysis.registry", ["KnownEntry", "lookup_entry",
                                 "DonationCandidate"]),
    ("repro.analysis.sharding_lint", ["run"]),
    ("repro.analysis.prng_lint", ["run"]),
    ("repro.analysis.donation_lint", ["run"]),
]


def check_docstrings() -> list:
    sys.path.insert(0, os.path.join(REPO, "src"))
    import importlib
    errors = []
    for modname, names in DOCUMENTED_API:
        try:
            mod = importlib.import_module(modname)
        except Exception as e:  # noqa: BLE001
            errors.append(f"cannot import {modname}: {type(e).__name__}: {e}")
            continue
        for dotted in names:
            obj = mod
            try:
                for part in dotted.split("."):
                    obj = getattr(obj, part)
            except AttributeError:
                errors.append(f"{modname}.{dotted}: missing attribute")
                continue
            doc = getattr(obj, "__doc__", None)
            if not doc or len(doc.strip()) < 20:
                errors.append(f"{modname}.{dotted}: missing/trivial docstring")
    return errors


def main() -> int:
    errors = check_links() + check_docstrings()
    for e in errors:
        print(f"check_docs: {e}", file=sys.stderr)
    if not errors:
        n_md = len(list(iter_markdown_files()))
        n_api = sum(len(names) for _, names in DOCUMENTED_API)
        print(f"check_docs: OK ({n_md} markdown files, {n_api} API objects)")
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main())

#!/usr/bin/env bash
# Fast CI gate: the tier1 subset (fast, deterministic) with a hard timeout
# so slow end-to-end decode tests never block iteration.
#
#   scripts/ci.sh              # tier1 only, 1200s budget
#   CI_TIMEOUT=300 scripts/ci.sh -k rejection
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
export JAX_PLATFORMS="${JAX_PLATFORMS:-cpu}"
# static-analysis gate: tracer-safety + cache-key + Pallas-contract +
# sharding/collective + PRNG-hygiene + donation lint over src, examples,
# benchmarks and scripts, ratcheted against scripts/lint_baseline.txt
# (AST-only, no jax import)
timeout 120 bash scripts/lint.sh
# docs gate: broken relative links in README/docs + docstring presence on
# the public API surface the docs point at
timeout 120 python scripts/check_docs.py
# interpret-mode kernel-parity smoke: ragged + fused gmm vs ref.py oracles
timeout 120 python -m repro.kernels.gmm.ragged
# paged decode-attention kernel parity: block-table-walking Pallas kernel
# vs the paged + dense oracles across page sizes / GQA / logit caps
timeout 120 python -m repro.kernels.decode_attention.decode_attention
# continuous-serving smoke: slot scheduler end-to-end on a tiny config
# (Poisson arrivals, mixed budgets, row-sliced + chunked admission into
# paged KV slots, live re-planning)
timeout 300 python -m repro.launch.serve --arch qwen2-57b-a14b --reduced \
  --requests 4 --max-batch 2 --max-new 6 --gamma 2 --mixed-max-new 4,6 \
  --scheduler continuous --arrival-rate 1.0 --no-autotune \
  --prefill-chunk 4 --kv-layout paged --page-size 16
# shared-prefix smoke: every request carries one common system prompt;
# prefix sharing forks it (refcounted CoW pages) and prefills only tails,
# with the paged kernel on the decode/verify path
timeout 300 python -m repro.launch.serve --arch qwen2-57b-a14b --reduced \
  --requests 4 --max-batch 2 --max-new 4 --gamma 2 \
  --scheduler continuous --no-autotune --kv-layout paged --page-size 16 \
  --prefix-sharing --shared-prefix 24 --admission-order pressure
# expert-parallel smoke: continuous paged serving with experts sharded
# over a 1x4 ("data","model") mesh of forced host devices — the a2a →
# per-shard ragged gmm dispatch plus per-wave EP telemetry
# (docs/distributed.md); the reduced arch has E=4 experts, so ep=4 puts
# one expert per shard
# --transfer-guard replays the same stream through the warm engine and
# fails on any implicit host<->device transfer or a second input-sharding
# signature on a cached program (docs/analysis.md runtime guards)
XLA_FLAGS="--xla_force_host_platform_device_count=8" \
timeout 300 python -m repro.launch.serve --arch qwen2-57b-a14b --reduced \
  --requests 4 --max-batch 2 --max-new 6 --gamma 2 \
  --scheduler continuous --no-autotune --kv-layout paged --page-size 16 \
  --ep-degree 4 --mesh-layout tp --transfer-guard
# fault-injection smoke: a seeded injector stream (page exhaustion +
# preemption/requeue, NaN quarantine, slow round, admission retry) must
# complete with the expected finish_reasons, zero leaked pages, and a
# zero-compile replay on the warm engine (docs/faults.md)
timeout 300 python -m repro.serving.faults
exec timeout "${CI_TIMEOUT:-1200}" python -m pytest -q -m tier1 "$@"

#!/usr/bin/env bash
# Static analysis gate: tracer-safety lint, jit-cache-key audit and Pallas
# kernel-contract checks over the serving stack, ratcheted against
# scripts/lint_baseline.txt (which ships empty — new findings fail).
#
#   scripts/lint.sh                 # lint src/repro against the baseline
#   scripts/lint.sh --json src/     # machine-readable findings
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
exec python -m repro.analysis "$@"

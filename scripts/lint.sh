#!/usr/bin/env bash
# Static analysis gate: tracer-safety lint, jit-cache-key audit, Pallas
# kernel-contract checks, shard_map/collective + host-boundary lint (S4xx),
# PRNG key-dataflow lint (R5xx) and buffer-donation lint (D6xx) over the
# serving stack AND its callers (examples, benchmarks, scripts), ratcheted
# against scripts/lint_baseline.txt (which ships empty — new findings fail).
#
#   scripts/lint.sh                 # lint the default tree vs the baseline
#   scripts/lint.sh --json src/     # machine-readable findings (per_pass)
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
if [ "$#" -eq 0 ]; then
  exec python -m repro.analysis src/repro examples benchmarks scripts
fi
exec python -m repro.analysis "$@"

"""End-to-end dry-run CLI smoke: one real 512-device lowering (the smallest
arch x shape) in a subprocess, validating the full launch path + JSON
contract.  ~60 s; the 80-combo production evidence lives in results/."""
import json
import subprocess
import sys

import pytest


@pytest.mark.slow
def test_dryrun_cli_smallest_combo():
    proc = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun",
         "--arch", "whisper-base", "--shape", "decode_32k"],
        capture_output=True, text=True, timeout=600,
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin", "HOME": "/root"},
        cwd="/root/repo")
    assert proc.returncode == 0, proc.stderr[-2000:]
    rec = json.loads(proc.stdout.strip().splitlines()[-1])
    assert rec["status"] == "ok"
    assert rec["devices"] == 256
    assert rec["mesh"] == "16x16"
    assert rec["flops_per_device"] > 0
    assert rec["collective_bytes_per_device"]["total"] >= 0
    assert {"in_loop", "outside"} <= set(rec["collective_bytes_per_device"])
    assert rec["memory"]["peak_bytes"] > 0

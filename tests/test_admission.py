"""Row-sliced / chunked / paged admission: the pool-width-overhead fix.

The contracts this PR adds on top of the continuous scheduler:
  * work scaling — the sliced admission prefill is jit-keyed on
    (admitted_rows, per-admission prompt-bucket), never on
    (pool, stream-global bucket); the prompt bucket RESETS per refill
    instead of ratcheting up for the stream's lifetime,
  * robustness — a mid-stream request the stream wasn't sized for is
    rejected (dense) or admitted via paged growth (kv_layout="paged"),
    never a stream-killing ValueError,
  * parity — chunked prefill ≡ one-shot prefill and paged ≡ dense caches
    are greedy token-identical,
  * determinism — every admission consumes its own PRNG split, so
    identical streams replay exactly and identical prompts admitted in
    different rounds never share sample streams.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ModelConfig
from repro.core.analytics import admission_work
from repro.models.model import Model, merge_cache_rows, scatter_cache_rows
from repro.serving.engine import ServingEngine

pytestmark = pytest.mark.tier1

TCFG = ModelConfig("ad-moe", "moe", 2, 128, 4, 2, 256, 512, num_experts=4,
                   num_experts_per_tok=2, dtype="float32")
DCFG = ModelConfig("ad-draft", "dense", 2, 64, 2, 2, 128, 512,
                   dtype="float32")


@pytest.fixture(scope="module")
def models():
    t, d = Model(TCFG), Model(DCFG)
    return t, d, t.init(jax.random.PRNGKey(0)), d.init(jax.random.PRNGKey(1))


def _engine(t, d, pt, pd, **kw):
    kw.setdefault("max_batch", 2)
    kw.setdefault("gamma", 2)
    kw.setdefault("force_sd", True)
    kw.setdefault("scheduler", "continuous")
    return ServingEngine(t, d, pt, pd, **kw)


class _MidStreamSubmitter:
    """Stub tuner that injects one LONG request while the stream runs —
    the "late-submitted" case stream-start sizing cannot see."""

    gammas = (2,)

    def __init__(self, engine_ref, at_call=3, prompt_len=40):
        self.engine_ref = engine_ref
        self.at_call = at_call
        self.prompt_len = prompt_len
        self.calls = 0
        self.uid = None

    def plan(self, batch):
        self.calls += 1
        if self.calls == self.at_call and self.uid is None:
            self.uid = self.engine_ref[0].submit(
                np.arange(3, 3 + self.prompt_len), max_new_tokens=6)
        return {"use_sd": True, "gamma": 2, "predicted_speedup": 2.0}

    def update_alpha(self, alpha):
        pass


# ---------------------------------------------------------------- tentpole
def test_sliced_admit_jit_keyed_on_admitted_rows(models):
    """The sliced-admit jit signature is (admitted_rows, prompt-bucket):
    a 1-row refill into a pool of 4 traces at rows=1, and the legacy full
    path traces at rows=pool for the identical workload."""
    t, d, pt, pd = models

    def run(mode):
        eng = _engine(t, d, pt, pd, max_batch=4, admit_mode=mode)
        for m in (4, 10, 6, 8):
            eng.submit(np.arange(3, 9), max_new_tokens=m)
        eng.submit(np.arange(3, 9), max_new_tokens=4, arrival_round=4)
        eng.run()
        return eng, eng.session_stats()["model"]["admit_traces"]

    eng, sliced = run("sliced")
    assert len(eng.done) == 5
    # initial 4-row fill + 1-row refills — never a (bucket, pool) entry
    # for a 1-row refill
    assert (8, 4) in sliced and (8, 1) in sliced
    _, full = run("full")
    assert all(r == 4 for _, r in full)        # legacy path: pool always
    work = admission_work(sliced, pool=4, full_bucket=8)
    assert work["sliced_tokens"] < work["full_tokens"]


def test_admission_bucket_resets_per_refill(models):
    """One long prompt must not ratchet the admission bucket for the whole
    stream: later short refills prefill at their OWN (smaller) bucket."""
    t, d, pt, pd = models
    eng = _engine(t, d, pt, pd)
    eng.submit(np.arange(3, 19), max_new_tokens=4)            # bucket 16
    eng.submit(np.arange(3, 9), max_new_tokens=4)             # bucket 8
    eng.submit(np.arange(3, 9), max_new_tokens=4, arrival_round=3)
    eng.submit(np.arange(3, 9), max_new_tokens=4, arrival_round=5)
    eng.run()
    traces = eng.session_stats()["model"]["admit_traces"]
    assert (16, 2) in traces                   # the mixed initial fill
    assert (8, 1) in traces                    # refills came back DOWN
    assert all(t <= 16 for t, _ in traces)


def test_late_oversize_request_rejected_not_fatal(models):
    """Dense stream: a mid-stream request exceeding the stream's sizing is
    rejected with finish_reason="rejected"; everything else completes."""
    t, d, pt, pd = models
    ref = []
    tuner = _MidStreamSubmitter(ref)
    eng = ServingEngine(t, d, pt, pd, max_batch=2, gamma=2, tuner=tuner,
                        force_sd=True, scheduler="continuous")
    ref.append(eng)
    uids = [eng.submit(np.arange(3, 9), max_new_tokens=8),
            eng.submit(np.arange(3, 10), max_new_tokens=12)]
    eng.run()
    assert tuner.uid is not None
    assert eng.done[tuner.uid].finish_reason == "rejected"
    assert len(eng.done[tuner.uid].output) == 0
    assert all(eng.done[u].finish_reason == "length" for u in uids)
    assert all(len(eng.done[u].output) == m
               for u, m in zip(uids, (8, 12)))


def test_paged_session_grows_for_late_long_prompt(models):
    """Paged stream: the same late long request is ADMITTED via pool/
    capacity growth (logged), serves to completion, and the short
    requests' outputs match the dense stream's token-for-token."""
    t, d, pt, pd = models

    def run(**kw):
        ref = []
        tuner = _MidStreamSubmitter(ref)
        eng = ServingEngine(t, d, pt, pd, max_batch=2, gamma=2, tuner=tuner,
                            force_sd=True, scheduler="continuous", **kw)
        ref.append(eng)
        uids = [eng.submit(np.arange(3, 9), max_new_tokens=8),
                eng.submit(np.arange(3, 10), max_new_tokens=12)]
        eng.run()
        return eng, uids, tuner.uid

    dense, d_uids, _ = run()
    paged, p_uids, long_uid = run(kv_layout="paged", page_size=8)
    assert paged.done[long_uid].finish_reason == "length"
    assert len(paged.done[long_uid].output) == 6
    assert paged.session_stats()["model"]["growths"]
    for du, pu in zip(d_uids, p_uids):
        np.testing.assert_array_equal(dense.done[du].output,
                                      paged.done[pu].output)


def test_chunked_prefill_matches_one_shot(models):
    """Chunked prefill (here: 16-token prompts in 4-token chunks) is
    greedy token-identical to the one-shot sliced admission."""
    t, d, pt, pd = models
    outs = {}
    for chunk in (None, 4):
        eng = _engine(t, d, pt, pd, prefill_chunk=chunk)
        uids = [eng.submit(np.arange(3, 19), max_new_tokens=m)
                for m in (6, 9, 5)]
        (report,) = eng.run()
        outs[chunk] = [eng.done[u].output for u in uids]
        if chunk:
            stats = eng.session_stats()["model"]
            assert stats["chunk_traces"]       # the chunk path really ran
            assert {s for s, _, _ in stats["chunk_traces"]} == \
                {"first", "mid", "final"}
    for a, b in zip(outs[None], outs[4]):
        np.testing.assert_array_equal(a, b)


def test_paged_matches_dense_rounds(models):
    """Paged ≡ dense round parity on a mixed-budget refill stream."""
    t, d, pt, pd = models
    outs = {}
    for layout in ("dense", "paged"):
        eng = _engine(t, d, pt, pd, kv_layout=layout, page_size=8)
        uids = [eng.submit(np.arange(3, 9), max_new_tokens=m)
                for m in (4, 12, 6, 9)]
        eng.run()
        outs[layout] = [eng.done[u].output for u in uids]
    for a, b in zip(outs["dense"], outs["paged"]):
        np.testing.assert_array_equal(a, b)


def test_eagle_proposer_sliced_admission(models):
    """The sliced scatter covers every proposer: an eagle continuous
    stream with refills matches its own wave decode."""
    t, _, pt, _ = models
    from repro.core.eagle import EagleHead
    head = EagleHead(t)
    ph = head.init(jax.random.PRNGKey(2))
    outs = {}
    for sched in ("wave", "continuous"):
        eng = ServingEngine(t, head, pt, ph, max_batch=2, gamma=2,
                            force_sd=True, proposer="eagle",
                            scheduler=sched)
        uids = [eng.submit(np.arange(3, 9), max_new_tokens=6)
                for _ in range(2)]
        eng.run()
        outs[sched] = [eng.done[u].output for u in uids]
    for a, b in zip(outs["wave"], outs["continuous"]):
        np.testing.assert_array_equal(a, b)


def test_paged_growth_swa_target():
    """SWA targets under paged KV: the logical capacity is floored at the
    full ring width (window + pad), so a mid-stream growth never has to
    resize a live ring — the late long request still admits and the short
    requests match the dense stream."""
    swa_cfg = ModelConfig("ad-swa", "dense", 2, 128, 4, 2, 256, 512,
                          layer_pattern=("swa", "attn"), sliding_window=6,
                          dtype="float32")
    t, d = Model(swa_cfg), Model(DCFG)
    pt, pd = t.init(jax.random.PRNGKey(0)), d.init(jax.random.PRNGKey(1))

    def run(**kw):
        ref = []
        tuner = _MidStreamSubmitter(ref)
        eng = ServingEngine(t, d, pt, pd, max_batch=2, gamma=2, tuner=tuner,
                            force_sd=True, scheduler="continuous", **kw)
        ref.append(eng)
        uids = [eng.submit(np.arange(3, 9), max_new_tokens=8),
                eng.submit(np.arange(3, 10), max_new_tokens=10)]
        eng.run()
        return eng, uids, tuner.uid

    dense, d_uids, _ = run()
    paged, p_uids, long_uid = run(kv_layout="paged", page_size=8)
    assert paged.done[long_uid].finish_reason == "length"
    assert len(paged.done[long_uid].output) == 6
    assert paged.session_stats()["model"]["growths"]
    for du, pu in zip(d_uids, p_uids):
        np.testing.assert_array_equal(dense.done[du].output,
                                      paged.done[pu].output)


# ------------------------------------------------------------- determinism
def test_admission_prng_deterministic_and_unshared(models):
    """Sampled decoding: identical seeds replay the stream exactly, and
    two IDENTICAL prompts admitted in different rounds draw different
    sample streams (each admission consumes its own key split)."""
    t, d, pt, pd = models

    def serve(seed):
        eng = _engine(t, d, pt, pd, max_batch=1, temperature=1.0,
                      seed=seed)
        uids = [eng.submit(np.arange(3, 9), max_new_tokens=8),
                eng.submit(np.arange(3, 9), max_new_tokens=8,
                           arrival_round=2)]
        eng.run()
        return [eng.done[u].output for u in uids]

    a, b = serve(11), serve(11)
    for x, y in zip(a, b):
        np.testing.assert_array_equal(x, y)    # replay is exact
    # same prompt, different admission round → different stream
    assert not np.array_equal(a[0], a[1])


# ----------------------------------------------------------------- pricing
def test_admission_work_and_pricing():
    """analytics/perf_model price admission ∝ admitted tokens: sliced
    shapes cost less than the pool-wide path, monotone in rows/tokens."""
    shapes = [(8, 4), (8, 1), (8, 1), (8, 1)]
    w = admission_work(shapes, pool=4, full_bucket=8)
    assert w["admissions"] == 4
    assert w["sliced_tokens"] == 8 * 7
    assert w["full_tokens"] == 4 * 4 * 8
    assert 0.0 < w["savings"] < 1.0
    from repro.core.perf_model import SpeedupModel
    p = np.array([1.0, 0.5, 2.0, 1.5, 0.1, 0.05, 0.01, 0.001, 0.5, 1.2])
    m = SpeedupModel(params=p)
    t_1 = float(m.admission_time(1, 8, 2, 8))
    t_pool = float(m.admission_time(4, 8, 2, 8))
    t_long = float(m.admission_time(1, 32, 2, 8))
    assert t_1 < t_pool                        # rows monotone
    assert t_1 < t_long                        # tokens monotone


# -------------------------------------------------------------------- unit
def test_scatter_cache_rows_matches_merge(models):
    """scatter (compact fresh rows) ≡ merge (full-bucket fresh rows) on a
    dense cache — the two admission primitives agree where both apply."""
    t, _, pt, _ = models
    B, R, max_seq = 4, 2, 32
    toks_full = jnp.asarray(np.random.default_rng(0).integers(
        3, 200, (B, 6)), jnp.int32)
    lengths = jnp.full((B,), 6, jnp.int32)
    live = t.init_cache(B, max_seq)
    _, live = t.prefill(pt, toks_full, live, lengths=lengths)
    fresh_full = t.init_cache(B, max_seq)
    _, fresh_full = t.prefill(pt, toks_full + 1, fresh_full,
                              lengths=lengths)
    rows = np.array([1, 3])
    mask = np.zeros((B,), bool)
    mask[rows] = True
    merged = merge_cache_rows(live, fresh_full, jnp.asarray(mask))
    fresh_rows = t.init_cache(R, max_seq)
    _, fresh_rows = t.prefill(pt, toks_full[rows] + 1, fresh_rows,
                              lengths=lengths[rows])
    scattered = scatter_cache_rows(live, fresh_rows, jnp.asarray(rows))
    np.testing.assert_array_equal(np.asarray(merged["lengths"]),
                                  np.asarray(scattered["lengths"]))
    for lm, ls in zip(merged["layers"], scattered["layers"]):
        for k in lm:
            np.testing.assert_allclose(np.asarray(lm[k]),
                                       np.asarray(ls[k]), rtol=0, atol=0)


# ------------------------------------------------------- exhaustion edges
def test_watermark_backpressure_defers_then_admits(models):
    """free_page_watermark defers an admission that would drain the pool
    below the watermark while other slots are live, admits it once the
    pool idles (watermark never deadlocks an idle pool), and leaks no
    pages — greedy outputs byte-identical to an unthrottled stream."""
    from repro.serving.faults import ResilienceConfig
    t, d, pt, pd = models

    def run(watermarked):
        res = ResilienceConfig(free_page_watermark=0.5,
                               max_pool_pages=8) if watermarked else None
        eng = _engine(t, d, pt, pd, kv_layout="paged", page_size=8,
                      resilience=res)
        ua = eng.submit(np.arange(3, 9), max_new_tokens=16)
        ub = eng.submit(np.arange(4, 10), max_new_tokens=8,
                        arrival_round=1)
        eng.run()
        return eng, (ua, ub)

    ref, (ra, rb) = run(watermarked=False)
    eng, (ua, ub) = run(watermarked=True)
    # B's 3 pages would leave 0 of 7 free (< 0.5) while A is live: defer
    assert eng.fault_counters["admit_deferred"] >= 1
    for u_ref, u in ((ra, ua), (rb, ub)):
        assert eng.done[u].finish_reason == "length"
        np.testing.assert_array_equal(eng.done[u].output,
                                      ref.done[u_ref].output)
    # B landed strictly after A retired (the pool idled first)
    assert eng.done[ub].readmit_round is None  # deferral, not preemption
    eng._slot_scheduler._alloc.assert_no_leaks()


def test_oversize_request_at_pool_cap_rejected(models):
    """A request that cannot fit even a fully-drained pool at
    max_pool_pages is rejected (finish_reason="rejected"), not deferred
    forever; co-streamed work completes and no page leaks."""
    from repro.serving.faults import ResilienceConfig
    t, d, pt, pd = models
    eng = _engine(t, d, pt, pd, kv_layout="paged", page_size=8,
                  resilience=ResilienceConfig(max_pool_pages=8))
    ua = eng.submit(np.arange(3, 9), max_new_tokens=8)
    # 6 + 64 + margin ≈ 10 pages > cap-1 = 7 allocatable: impossible
    ub = eng.submit(np.arange(3, 9), max_new_tokens=64, arrival_round=1)
    eng.run()
    assert eng.done[ub].finish_reason == "rejected"
    assert len(eng.done[ub].output) == 0
    assert eng.done[ua].finish_reason == "length"
    assert len(eng.done[ua].output) == 8
    eng._slot_scheduler._alloc.assert_no_leaks()

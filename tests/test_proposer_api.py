"""Unified Proposer API: cross-proposer parity, registry, session reuse.

The contracts the serving redesign rests on:
  * every registered proposer runs through the ONE SDEngine loop and is
    greedy-lossless (token-identical to the AR baseline),
  * the registry is extensible (register_proposer) and fails loudly on
    unknown kinds,
  * ServingEngine holds persistent sessions: each proposer kind is
    constructed exactly once across waves, and a tuner-driven gamma change
    reuses already-compiled rounds (no retrace when returning to a seen
    (gamma, batch) shape),
  * per-wave PRNG keys are split, not reused,
  * timed mode records real per-phase timings.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ModelConfig
from repro.core.eagle import EagleHead
from repro.core.proposer import (ModelProposer, make_proposer,
                                 register_proposer, registered_proposers)
from repro.core.spec_decode import SDEngine, SpecDecoder, generate_ar
from repro.serving.engine import ServingEngine

pytestmark = pytest.mark.tier1

TCFG = ModelConfig("pp-moe", "moe", 2, 128, 4, 2, 256, 512, num_experts=4,
                   num_experts_per_tok=2, dtype="float32")
DCFG = ModelConfig("pp-draft", "dense", 2, 64, 2, 2, 128, 512,
                   dtype="float32")


@pytest.fixture(scope="module")
def setup():
    from repro.models.model import Model
    t, d = Model(TCFG), Model(DCFG)
    pt, pd = t.init(jax.random.PRNGKey(0)), d.init(jax.random.PRNGKey(7))
    head = EagleHead(t)
    pe = head.init(jax.random.PRNGKey(3))
    prompts = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0, 512)
    return t, d, pt, pd, head, pe, prompts


@pytest.mark.parametrize("kind", ["model", "eagle", "none"])
def test_every_proposer_greedy_matches_ar(setup, kind):
    """Cross-proposer parity: greedy SDEngine output == AR baseline for
    every registered proposer, through the single generic loop."""
    t, d, pt, pd, head, pe, prompts = setup
    draft = {"model": d, "eagle": head, "none": None}[kind]
    params_p = {"model": pd, "eagle": pe, "none": None}[kind]
    gamma = 0 if kind == "none" else 3
    eng = SDEngine(t, make_proposer(kind, t, draft), gamma=gamma)
    out, stats = eng.generate(pt, params_p, prompts, 16)
    out_ar = generate_ar(t, pt, prompts, 16)
    np.testing.assert_array_equal(out, out_ar)
    assert stats.rounds >= 1
    if kind == "none":
        # degenerate path: exactly one committed token per round, no drafts
        assert stats.draft_events == 0
        assert stats.generated == stats.rounds * prompts.shape[0]


def test_registry_unknown_kind_raises(setup):
    t = setup[0]
    with pytest.raises(KeyError, match="unknown proposer"):
        make_proposer("nope", t)
    assert {"model", "eagle", "none"} <= set(registered_proposers())


def test_registry_is_extensible(setup):
    """A user-registered drafter drops into the same engine loop."""
    t, d, pt, pd, *_ , prompts = setup

    register_proposer(
        "selfdraft",
        lambda target, draft, temperature=0.0: ModelProposer(
            target, target, temperature=temperature))
    try:
        eng = SDEngine(t, make_proposer("selfdraft", t), gamma=3)
        out, stats = eng.generate(pt, pt, prompts, 12)
        np.testing.assert_array_equal(out, generate_ar(t, pt, prompts, 12))
        assert stats.alpha == 1.0              # self-draft accepts everything
    finally:
        from repro.core import proposer as proposer_mod
        proposer_mod._REGISTRY.pop("selfdraft", None)


def test_shims_still_work(setup):
    """Legacy SpecDecoder entry point rides the new engine unchanged."""
    t, d, pt, pd, *_ , prompts = setup
    sd = SpecDecoder(t, d, gamma=2)
    out, _ = sd.generate(pt, pd, prompts, 10)
    np.testing.assert_array_equal(out, generate_ar(t, pt, prompts, 10))


def test_gamma_change_reuses_session_and_compiles(setup):
    """A single SDEngine session serves multiple gammas; re-running a seen
    (gamma, batch) shape hits the compiled round (no retrace)."""
    t, d, pt, pd, *_ , prompts = setup
    eng = SDEngine(t, make_proposer("model", t, d))
    max_seq = 64
    for gamma in (2, 3, 2, 3, 2):
        eng.generate(pt, pd, prompts, 8, gamma=gamma, max_seq=max_seq)
    # only the first visit to each gamma traced; the revisits were cache hits
    assert eng.trace_log == [(2, 2), (3, 2)]
    assert sorted(eng._round_cache) == [2, 3]


class _FixedPlanTuner:
    """Stub tuner driving a per-wave gamma schedule."""

    def __init__(self, gammas):
        self.gammas = list(gammas)
        self.alphas = []

    def plan(self, batch):
        return {"use_sd": True, "gamma": self.gammas.pop(0),
                "predicted_speedup": 2.0}

    def update_alpha(self, alpha):
        self.alphas.append(alpha)


def test_serving_sessions_constructed_once_across_waves(setup):
    """≥3 waves with a tuner-driven gamma change: one session per proposer
    kind, no per-wave decoder instantiation, compiled rounds reused."""
    t, d, pt, pd, *_ = setup
    tuner = _FixedPlanTuner([2, 3, 2, 2])
    eng = ServingEngine(t, d, pt, pd, max_batch=2, tuner=tuner,
                        force_sd=True)
    for _ in range(8):                          # 4 waves of 2
        eng.submit(np.arange(3, 9), max_new_tokens=6)
    reports = eng.run()
    assert len(reports) == 4
    assert [r.gamma for r in reports] == [2, 3, 2, 2]
    stats = eng.session_stats()
    assert eng.session_constructions == {"model": 1}
    # identical wave shapes: gamma 2 and 3 each traced exactly once — the
    # waves that revisit gamma=2 hit the session's compiled round
    assert stats["model"]["traces"] == [(2, 2), (3, 2)]
    assert stats["model"]["gammas_compiled"] == [2, 3]
    assert len(tuner.alphas) == 4               # alpha fed back every wave


def test_serving_wave_keys_are_split():
    """Waves must not share a PRNG key: identical sampled requests served
    in different waves should (a.s.) produce different outputs."""
    from repro.models.model import Model
    t, d = Model(TCFG), Model(DCFG)
    pt, pd = t.init(jax.random.PRNGKey(0)), d.init(jax.random.PRNGKey(7))
    eng = ServingEngine(t, d, pt, pd, max_batch=1, gamma=2,
                        temperature=1.0, force_sd=True)
    u1 = eng.submit(np.arange(3, 9), max_new_tokens=12)
    u2 = eng.submit(np.arange(3, 9), max_new_tokens=12)
    eng.run()
    assert not np.array_equal(eng.done[u1].output, eng.done[u2].output)


def test_timed_mode_records_phase_timings(setup):
    t, d, pt, pd, *_ , prompts = setup
    eng = SDEngine(t, make_proposer("model", t, d), gamma=2)
    out_timed, stats = eng.generate(pt, pd, prompts, 10, timed=True)
    assert stats.propose_time > 0
    assert stats.verify_time > 0
    assert stats.reject_time > 0
    assert stats.round_time >= (stats.propose_time + stats.verify_time
                                + stats.reject_time) * 0.5
    # timed staging must not change tokens
    out_fused, _ = eng.generate(pt, pd, prompts, 10)
    np.testing.assert_array_equal(out_timed, out_fused)


def test_wave_report_surfaces_timings(setup):
    t, d, pt, pd, *_ = setup
    eng = ServingEngine(t, d, pt, pd, max_batch=2, gamma=2, force_sd=True,
                        timed=True)
    eng.submit(np.arange(3, 9), max_new_tokens=6)
    (report,) = eng.run()
    assert report.propose_time > 0
    assert report.verify_time > 0
    assert report.reject_time > 0
    assert report.round_time > 0

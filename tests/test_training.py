"""Training substrate: optimizer, loss chunking, checkpointing, pipeline."""
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, TrainConfig
from repro.data.pipeline import packed_batches, prompt_batch, synthetic_text
from repro.models.model import Model
from repro.training.checkpoint import (latest_checkpoint, restore_checkpoint,
                                       save_checkpoint)
from repro.training.optimizer import (adamw_update, clip_by_global_norm,
                                      cosine_schedule, init_adam)
from repro.training.train_loop import (chunked_lm_loss, init_train_state,
                                       lm_loss, make_train_step)

CFG = ModelConfig("tr-moe", "moe", 2, 128, 4, 2, 256, 512, num_experts=4,
                  num_experts_per_tok=2, dtype="float32",
                  router_aux_loss_coef=0.01)


def test_loss_decreases():
    model = Model(CFG, remat=True)
    params, opt = init_train_state(model, jax.random.PRNGKey(0))
    step = jax.jit(make_train_step(model, TrainConfig(
        learning_rate=3e-3, total_steps=30, warmup_steps=2)))
    it = packed_batches(CFG.vocab_size, 8, 64, kind="code")
    losses = []
    for _ in range(30):
        b = {k: jnp.asarray(v) for k, v in next(it).items()}
        params, opt, m = step(params, opt, b)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] - 0.5, losses[::6]
    assert np.isfinite(losses).all()


def test_chunked_loss_equals_dense_loss():
    model = Model(CFG)
    params = model.init(jax.random.PRNGKey(0))
    B, T = 2, 48
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, T), 0, 512)
    labels = jax.random.randint(jax.random.PRNGKey(2), (B, T), 0, 512)
    hidden, _ = model.forward_hidden(params, toks)
    dense = lm_loss(model._head(params, hidden), labels)
    for chunk in (8, 16, 48):
        ch = chunked_lm_loss(model, params, hidden, labels, None, chunk=chunk)
        np.testing.assert_allclose(float(ch), float(dense), rtol=1e-5)
    # non-divisible chunk exercises the padding path
    ch = chunked_lm_loss(model, params, hidden, labels, None, chunk=20)
    np.testing.assert_allclose(float(ch), float(dense), rtol=1e-5)


def test_adamw_moves_toward_minimum():
    params = {"w": jnp.array([10.0, -4.0])}
    opt = init_adam(params)
    cfg = TrainConfig(learning_rate=0.5, weight_decay=0.0, warmup_steps=0,
                      total_steps=100)
    for _ in range(60):
        grads = {"w": 2 * params["w"]}
        params, opt, m = adamw_update(params, grads, opt, cfg)
    assert float(jnp.abs(params["w"]).max()) < 1.0


def test_grad_clip():
    g = {"a": jnp.full((4,), 100.0)}
    clipped, norm = clip_by_global_norm(g, 1.0)
    assert abs(float(jnp.linalg.norm(clipped["a"])) - 1.0) < 1e-5
    assert float(norm) > 100


def test_cosine_schedule_shape():
    cfg = TrainConfig(learning_rate=1e-3, warmup_steps=10, total_steps=100)
    lr5 = float(cosine_schedule(jnp.asarray(5), cfg))
    lr10 = float(cosine_schedule(jnp.asarray(10), cfg))
    lr100 = float(cosine_schedule(jnp.asarray(100), cfg))
    assert lr5 < lr10
    assert abs(lr10 - cfg.learning_rate) < 1e-9
    assert lr100 < 0.2 * cfg.learning_rate


def test_checkpoint_roundtrip_and_mismatch():
    model = Model(CFG)
    params, opt = init_train_state(model, jax.random.PRNGKey(0))
    tree = {"params": params, "opt": opt}
    with tempfile.TemporaryDirectory() as d:
        save_checkpoint(d, 3, tree, {"arch": CFG.name})
        path = latest_checkpoint(d)
        restored, manifest = restore_checkpoint(path, tree)
        assert manifest["step"] == 3
        for a, b in zip(jax.tree.leaves(restored), jax.tree.leaves(tree)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        # structure mismatch must raise
        import pytest
        with pytest.raises(ValueError):
            restore_checkpoint(path, {"params": params})


def test_pipeline_determinism_and_shapes():
    it1 = packed_batches(512, 4, 32, kind="chat", seed=1)
    it2 = packed_batches(512, 4, 32, kind="chat", seed=1)
    b1, b2 = next(it1), next(it2)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    assert b1["tokens"].shape == (4, 32)
    # labels are tokens shifted by one within the packed stream
    np.testing.assert_array_equal(b1["tokens"].reshape(-1)[1:],
                                  b1["labels"].reshape(-1)[:-1])
    # host sharding gives disjoint streams
    h0 = next(packed_batches(512, 2, 16, host_id=0, num_hosts=2))
    h1 = next(packed_batches(512, 2, 16, host_id=1, num_hosts=2))
    assert not np.array_equal(h0["tokens"], h1["tokens"])


def test_workload_classes_differ():
    code = synthetic_text("code", 0)
    chat = synthetic_text("chat", 0)
    assert "def " in code or "for " in code or "class " in code
    assert "def " not in chat
    pb = prompt_batch(512, 5, kind="code", seed=3)
    assert (pb["lengths"] >= 16).all()

"""MoE layer: router, dispatch equivalence, load-balance metrics, N(t)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ModelConfig
from repro.core.analytics import expected_activated_experts
from repro.models.moe import (expert_activation_counts, init_moe,
                              load_balance_loss, moe_forward, router_topk)

pytestmark = pytest.mark.tier1

CFG = ModelConfig("m", "moe", 2, 64, 4, 2, 128, 256, num_experts=8,
                  num_experts_per_tok=2, moe_d_ff=128, dtype="float32")


def _params():
    return init_moe(jax.random.PRNGKey(0), CFG, jnp.float32)


def test_router_topk_normalized():
    p = _params()
    x = jax.random.normal(jax.random.PRNGKey(1), (32, 64))
    w, idx, probs = router_topk(p, CFG, x)
    np.testing.assert_allclose(np.asarray(w.sum(-1)), 1.0, rtol=1e-5)
    assert idx.shape == (32, 2)
    assert (np.asarray(idx) < 8).all()
    # top-k really is top-k of probs
    np.testing.assert_array_equal(
        np.asarray(idx), np.asarray(jnp.argsort(probs, -1)[:, ::-1][:, :2]))


def test_gmm_dispatch_matches_onehot():
    p = _params()
    x = jax.random.normal(jax.random.PRNGKey(2), (2, 16, 64)) * 0.5
    y1, _ = moe_forward(p, CFG, x, dispatch="onehot")
    y2, _ = moe_forward(p, CFG, x, dispatch="gmm")
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), rtol=2e-4,
                               atol=2e-4)


def test_load_balance_loss_minimal_when_uniform():
    E = 8
    probs = jnp.full((64, E), 1 / E)
    idx = jnp.stack([jnp.arange(64) % E, (jnp.arange(64) + 1) % E], 1)
    lb = load_balance_loss(probs, idx, E)
    assert abs(float(lb) - 2.0) < 1e-5          # K * E * (K/E) * (1/E) * E = K


def test_activation_counts_follow_eq8():
    """Real router activations track N(t) (Fig. 1a/b reproduction, micro)."""
    E, K = 16, 2
    cfg = CFG.with_overrides(num_experts=E, num_experts_per_tok=K)
    p = init_moe(jax.random.PRNGKey(3), cfg, jnp.float32)
    for t in (4, 16, 64):
        acts = []
        for s in range(30):
            x = jax.random.normal(jax.random.PRNGKey(100 + s), (t, 64))
            _, idx, _ = router_topk(p, cfg, x)
            counts = expert_activation_counts(idx, E)
            acts.append(int((counts > 0).sum()))
        pred = float(expected_activated_experts(t, E, K))
        # untrained router is roughly-but-not-exactly uniform: generous band
        assert abs(np.mean(acts) - pred) < 0.30 * E + 1


def test_shared_experts_add():
    cfg = CFG.with_overrides(num_shared_experts=1)
    p = init_moe(jax.random.PRNGKey(4), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(5), (1, 8, 64))
    y, _ = moe_forward(p, cfg, x)
    p2 = dict(p)
    p2.pop("shared")
    y2, _ = moe_forward(p2, cfg, x)
    assert float(jnp.abs(y - y2).max()) > 1e-6

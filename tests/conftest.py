import numpy as np
import pytest

# NOTE: never set --xla_force_host_platform_device_count here — smoke tests
# and benches must see exactly 1 device (the 512-device override belongs to
# launch/dryrun.py ONLY).  Mesh integration tests spawn subprocesses.


@pytest.fixture(autouse=True)
def _seed_numpy():
    np.random.seed(0)

import numpy as np
import pytest

# NOTE: never set --xla_force_host_platform_device_count here — smoke tests
# and benches must see exactly 1 device (the 512-device override belongs to
# launch/dryrun.py ONLY).  Mesh integration tests spawn subprocesses.


@pytest.fixture(autouse=True)
def _seed_numpy():
    np.random.seed(0)


def pytest_runtest_setup(item):
    # compile_guard tests assert on XLA compile counts; on jax builds that
    # emit no monitoring events the counter stays at 0 and every assertion
    # would pass vacuously — skip loudly instead
    if "compile_guard" in item.keywords:
        from repro.analysis import compilation_events_available
        if not compilation_events_available():
            pytest.skip("jax.monitoring compilation events unavailable "
                        "on this backend")

"""Performance model (Alg. 1) + simulator + autotuner."""
import numpy as np
import pytest

from repro.configs.registry import draft_for, get_config
from repro.core.analytics import sigma_from_alpha
from repro.core.autotune import AutoTuner
from repro.core.perf_model import Measurement, SpeedupModel, stride_sample
from repro.core.simulator import Simulator, V5E

pytestmark = pytest.mark.tier1

TARGET = get_config("qwen2-57b-a14b")
DRAFT = get_config("qwen2-0.5b")


def _frame(sim, gammas=(2, 4), Ks=(1, 2, 4, 8, 16, 32), alpha=0.8):
    batches = [1, 2, 4, 8, 12, 16, 20, 24, 28, 32, 40, 48, 56, 64, 80, 100,
               128, 192, 256]
    rows = []
    for K in Ks:
        t = TARGET.with_overrides(num_experts_per_tok=K)
        for g in gammas:
            s = float(sigma_from_alpha(alpha, g))
            for b in batches:
                rows.append(Measurement(b, g, K, TARGET.num_experts, s,
                                        sim.sd_speedup(t, DRAFT, b, g, s)))
    return rows


def test_ridge_point():
    assert abs(V5E.ridge_point - 197e12 / 819e9) < 1e-6


def test_simulator_paper_trends():
    """The paper's two headline claims hold in the simulator:
    (1) speedup rises then falls with batch; (2) the peak batch moves right
    and the >= peak/sqrt(2) window widens as the MoE gets sparser."""
    sim = Simulator()
    sigma = float(sigma_from_alpha(0.8, 4))
    batches = [1, 2, 4, 8, 16, 24, 32, 48, 64, 96, 128, 256, 512, 1024, 2048]
    peaks, windows = {}, {}
    for K in (32, 8, 2):
        t = TARGET.with_overrides(num_experts_per_tok=K)
        curve = [sim.sd_speedup(t, DRAFT, b, 4, sigma) for b in batches]
        i = int(np.argmax(curve))
        assert 0 < i < len(batches) - 1, (K, curve)   # interior peak
        thr = curve[i] / np.sqrt(2)
        win = [b for b, s in zip(batches, curve) if s >= thr]
        peaks[K] = batches[i]
        windows[K] = max(win) - min(win)     # batch-range span of the plateau
    assert peaks[2] >= peaks[8] >= peaks[32]
    assert windows[2] >= windows[8]


def test_target_efficiency_tracks_speedup():
    sim = Simulator()
    sigma = float(sigma_from_alpha(0.8, 4))
    batches = [4, 16, 64, 256]
    eff = [sim.target_efficiency(TARGET, b, 4) for b in batches]
    spd = [sim.sd_speedup(TARGET, DRAFT, b, 4, sigma) for b in batches]
    assert np.corrcoef(eff, spd)[0, 1] > 0.9


def test_fit_recovers_simulator():
    sim = Simulator()
    rows = _frame(sim)
    model = SpeedupModel(engine_semantics=True)
    res = model.fit(stride_sample(rows, 21), TARGET, DRAFT, n_restarts=6)
    assert res["mse"] < 1.0                      # paper's own fits are ~1.5
    B = np.array([r.batch for r in rows])
    G = np.array([r.gamma for r in rows])
    K = np.array([r.top_k for r in rows])
    E = np.array([r.num_experts for r in rows])
    S = np.array([r.sigma for r in rows])
    Y = np.array([r.speedup for r in rows])
    pred = model.predict(B, G, K, E, S)
    assert np.corrcoef(pred, Y)[0, 1] > 0.7


def test_fit_bounds_respected():
    sim = Simulator()
    model = SpeedupModel()
    res = model.fit(stride_sample(_frame(sim), 15), TARGET, DRAFT,
                    n_restarts=3)
    p = res["params"]
    lo, hi = model.bounds(TARGET, DRAFT, 1e-3)
    x = np.array([p[k] for k in
                  ("bias", "k1", "k2", "k3", "draft_bias", "draft_k",
                   "reject_bias", "reject_k", "lam", "s")])
    assert (x >= lo - 1e-12).all() and (x <= hi + 1e-12).all()
    assert 0.2 <= p["lam"] <= 1.0 and 1.0 <= p["s"] <= 2.0


def test_dispatch_cost_gmm_cheaper_than_onehot():
    """T_target under the gmm (K-sparse) dispatch is monotonically cheaper
    than onehot (E-dense) for E > K, and the gap widens with E: the dense
    one-hot combine pays k2*E + full-t expert GEMMs regardless of routing."""
    p = np.array([1.0, 0.5, 2.0, 1.5, 0.1, 0.05, 0.01, 0.001, 0.5, 1.2])
    model = SpeedupModel()
    K, t = 2.0, 40.0
    gaps = []
    for E in (2, 4, 8, 16, 64):
        t_gmm = float(model.target_time(t, K, E, dispatch="gmm", params=p))
        t_onehot = float(model.target_time(t, K, E, dispatch="onehot",
                                           params=p))
        if E == K:
            assert abs(t_gmm - t_onehot) < 1e-9       # dense MoE: same cost
        else:
            assert t_gmm < t_onehot
        gaps.append(t_onehot - t_gmm)
    assert all(b > a for a, b in zip(gaps, gaps[1:]))  # monotone in E
    # the dispatch mode threads through the full speedup prediction too
    sd_gmm = SpeedupModel(dispatch="gmm")
    sd_onehot = SpeedupModel(dispatch="onehot")
    args = (np.array([8.0]), np.array([4.0]), np.array([2.0]),
            np.array([64.0]), np.array([0.8]))
    assert not np.allclose(sd_gmm.compute_speedup(p, *args),
                           sd_onehot.compute_speedup(p, *args))


def test_prefetch_overlap_pricing():
    """Draft-phase expert warming discounts only the verify call's k2
    (expert-load) term: T_target falls monotonically with the hit rate
    under gmm dispatch, onehot is untouched (no separable load to hide),
    and the full speedup prediction rises with the hit rate because the AR
    numerator is priced cold."""
    p = np.array([1.0, 0.5, 2.0, 1.5, 0.1, 0.05, 0.01, 0.001, 0.5, 1.2])
    model = SpeedupModel(dispatch="gmm")
    K, E, t = 2.0, 64.0, 40.0
    times = [float(model.target_time(t, K, E, params=p,
                                     prefetch_hit_rate=h))
             for h in (0.0, 0.3, 0.7, 1.0)]
    assert all(b < a for a, b in zip(times, times[1:]))
    # h=1 removes exactly the k2*N(t) load term
    from repro.core.analytics import expected_activated_experts
    expect_gap = p[2] * float(expected_activated_experts(t, E, K))
    assert times[0] - times[-1] == pytest.approx(expect_gap)
    # onehot: dense GEMM reads every expert regardless — no discount
    cold = float(model.target_time(t, K, E, params=p, dispatch="onehot",
                                   prefetch_hit_rate=0.0))
    warm = float(model.target_time(t, K, E, params=p, dispatch="onehot",
                                   prefetch_hit_rate=0.9))
    assert cold == warm
    # end-to-end: speedup is monotone in the measured hit rate
    args = (np.array([8.0]), np.array([4.0]), np.array([K]),
            np.array([E]), np.array([0.8]))
    spd = [float(SpeedupModel(dispatch="gmm", prefetch_hit_rate=h)
                 .compute_speedup(p, *args)[0])
           for h in (0.0, 0.5, 1.0)]
    assert spd[0] < spd[1] < spd[2]


def test_stride_sample_counts():
    rows = list(range(228))
    for m in (10, 21, 57):
        got = stride_sample(rows, m)
        assert len(got) >= m // 2  # ceil semantics as in Appendix C.2


def test_autotuner_prefers_moderate_batch():
    at = AutoTuner(TARGET, DRAFT, alpha=0.8)
    win = at.speedup_window()
    assert win["peak_batch"] > 1
    assert win["peak"] > at.speedup(1, 4)
    g_small, _ = at.best_gamma(2)
    g_mod, _ = at.best_gamma(win["peak_batch"])
    assert g_mod >= g_small                      # more free verification slack

"""Speculative-decoding engine: losslessness + round accounting."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ModelConfig
from repro.core.spec_decode import SpecDecoder, generate_ar
from repro.models.model import Model

DENSE_DRAFT = ModelConfig("t-draft", "dense", 2, 64, 2, 2, 128, 512,
                          dtype="float32")

TARGETS = {
    "dense": ModelConfig("t-dense", "dense", 4, 128, 4, 2, 256, 512,
                         dtype="float32"),
    "moe": ModelConfig("t-moe", "moe", 4, 128, 4, 2, 256, 512,
                       num_experts=4, num_experts_per_tok=2, dtype="float32"),
    "hybrid": ModelConfig("t-hybrid", "hybrid", 4, 128, 4, 2, 256, 512,
                          layer_pattern=("mamba", "attn"),
                          moe_pattern=(True, False), num_experts=4,
                          num_experts_per_tok=2, dtype="float32"),
    "xlstm": ModelConfig("t-xlstm", "ssm", 2, 128, 4, 4, 0, 512,
                         layer_pattern=("mlstm", "slstm"), rope_type="none",
                         dtype="float32"),
    "swa": ModelConfig("t-swa", "dense", 3, 128, 4, 2, 256, 512,
                       layer_pattern=("swa", "swa", "attn"), sliding_window=8,
                       dtype="float32"),
    "mla": ModelConfig("t-mla", "dense", 2, 128, 4, 4, 256, 512,
                       layer_pattern=("mla",), mla_kv_lora_rank=32,
                       mla_q_lora_rank=24, mla_qk_rope_dim=16,
                       mla_qk_nope_dim=32, mla_v_head_dim=32, head_dim=48,
                       dtype="float32"),
}


@pytest.mark.parametrize("family", sorted(TARGETS))
def test_greedy_sd_equals_greedy_ar(family):
    """THE losslessness contract: greedy SD output == greedy AR output,
    token for token, for every target family."""
    tcfg = TARGETS[family]
    t, d = Model(tcfg), Model(DENSE_DRAFT)
    pt, pd = t.init(jax.random.PRNGKey(0)), d.init(jax.random.PRNGKey(7))
    prompts = jax.random.randint(jax.random.PRNGKey(1), (3, 8), 0, 512)
    sd = SpecDecoder(t, d, gamma=3, temperature=0.0)
    out_sd, stats = sd.generate(pt, pd, prompts, 20)
    out_ar = generate_ar(t, pt, prompts, 20)
    np.testing.assert_array_equal(out_sd, out_ar)
    assert stats.rounds >= 1
    # the prefill-sampled token is free, so rounds generate >= max_new - 1
    assert stats.generated >= 3 * (20 - 1)


def test_self_draft_accepts_everything():
    tcfg = TARGETS["moe"]
    t = Model(tcfg)
    pt = t.init(jax.random.PRNGKey(0))
    prompts = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0, 512)
    for temp in (0.0, 1.0):
        sd = SpecDecoder(t, t, gamma=4, temperature=temp)
        _, stats = sd.generate(pt, pt, prompts, 16, key=jax.random.PRNGKey(3))
        assert stats.alpha == 1.0
        assert stats.sigma == 1.0
        # alpha=1: every round commits gamma+1 tokens
        assert stats.rounds <= int(np.ceil(16 / 5)) + 1


def test_recurrent_draft_lossless():
    tcfg = TARGETS["dense"]
    dcfg = ModelConfig("t-rnn-draft", "ssm", 2, 64, 2, 2, 0, 512,
                       layer_pattern=("mlstm", "slstm"), rope_type="none",
                       dtype="float32")
    t, d = Model(tcfg), Model(dcfg)
    pt, pd = t.init(jax.random.PRNGKey(0)), d.init(jax.random.PRNGKey(9))
    prompts = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0, 512)
    sd = SpecDecoder(t, d, gamma=3, temperature=0.0)
    out_sd, _ = sd.generate(pt, pd, prompts, 16)
    out_ar = generate_ar(t, pt, prompts, 16)
    np.testing.assert_array_equal(out_sd, out_ar)


def test_ragged_prompts():
    """Per-sequence prompt lengths thread through prefill + SD rounds."""
    tcfg = TARGETS["dense"]
    t, d = Model(tcfg), Model(DENSE_DRAFT)
    pt, pd = t.init(jax.random.PRNGKey(0)), d.init(jax.random.PRNGKey(7))
    B, T = 3, 10
    prompts = jax.random.randint(jax.random.PRNGKey(1), (B, T), 3, 512)
    lengths = jnp.array([4, 10, 7], jnp.int32)
    sd = SpecDecoder(t, d, gamma=2, temperature=0.0)
    out_sd, _ = sd.generate(pt, pd, prompts, 12, lengths=lengths)
    # reference: AR per sequence with its true prompt
    for b in range(B):
        ref = generate_ar(t, pt, prompts[b: b + 1, : int(lengths[b])], 12)
        np.testing.assert_array_equal(out_sd[b], ref[0])

"""Seeded tracer-safety violations; test_analysis asserts codes AND lines.

Editing this file moves line numbers — update tests/test_analysis.py.
"""
import jax


def leaky(x, n):
    if x > 0:                            # T101 @ line 9
        x = x + 1
    while x < n:                         # T102 @ line 11
        x = x + 1
    k = int(x)                           # T103 @ line 13
    v = x.item()                         # T104 @ line 14
    s = f"value={x}"                     # T105 @ line 15
    assert x >= 0                        # T107 @ line 16
    for i in range(x):                   # T108 @ line 17
        k = k + i
    return x + k + v + len(s)


log = []


def mutator(x):
    log.append(x)                        # T106 @ line 26
    return x * 2


fn = jax.jit(leaky)
fn2 = jax.jit(mutator)

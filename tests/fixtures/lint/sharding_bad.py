"""Sharding/collective contract violations the S4xx pass must flag.

Self-contained: carries its own ``cache_spec`` definition so the S404
placement-rule check resolves patterns without importing the real
``distributed/sharding`` module.
"""
import re

import numpy as np
import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P


def cache_spec(path):
    if re.search(r"pages/table$", path):
        return P(None)
    if re.search(r"(k|v)_pages$", path):
        return P("model", None)
    return P("data")


def _body(a, b):
    y = jax.lax.psum(a, "tensor")
    return y + b


def bad_axis_and_arity(mesh, a, b):
    f = shard_map(_body, mesh=mesh,
                  in_specs=(P("data"), P("data"), P("data")),
                  out_specs=P("data"))
    return f(a, b)


def _body_pair(a):
    return a, a


def bad_out_arity(mesh, a):
    f = shard_map(_body_pair, mesh=mesh, in_specs=(P("data"),),
                  out_specs=(P("data"), P("data"), P("data")))
    return f(a)


class Engine:
    def __init__(self):
        self._c = {}

    def _host(self, x, dt):
        return jnp.asarray(x, dt)

    def _build(self):
        fn = self._c.get("step")
        if fn is None:
            fn = jax.jit(lambda t: t + 1)
            self._c["step"] = fn
        return fn

    def step(self):
        fn = self._build()
        toks = np.zeros((4,), np.int32)
        return fn(toks)


def init_cache(pages, page_size):
    return {"k_pages": jnp.zeros((1, pages, page_size, 1, 4)),
            "q_pages": jnp.zeros((1, pages, page_size, 1, 4))}


def lookup_rule():
    return cache_spec("layers/0/q_pages")


def misconfigure(mesh):
    from repro.distributed.constraints import set_mesh
    set_mesh(mesh)

"""PRNG-hygiene violations the R5xx pass must flag."""
import jax
import jax.numpy as jnp


def double_sample(key):
    a = jax.random.normal(key, (4,))
    b = jax.random.uniform(key, (4,))
    return a + b


def double_split(key):
    k1, k2 = jax.random.split(key)
    k3, k4 = jax.random.split(key)
    return (jax.random.normal(k1, (2,)) + jax.random.normal(k2, (2,)) +
            jax.random.normal(k3, (2,)) + jax.random.normal(k4, (2,)))


def discard(key):
    jax.random.split(key)
    return jnp.zeros((2,))


def derive_unused(key):
    k1, k2 = jax.random.split(key)
    return jnp.zeros((2,))


def make_sampler(key):
    def sample(x):
        return x + jax.random.normal(key, (2,))
    return jax.jit(sample)


def loop_fold(key, xs):
    out = []
    for i in range(4):
        k = jax.random.fold_in(key, 7)
        out.append(jax.random.normal(k, (2,)))
    return out


def _helper(data, key):
    return jax.random.normal(key, data.shape)


def pass_twice(key, x):
    a = _helper(x, key)
    b = _helper(x, key)
    return a + b

"""Tracer-SAFE idioms the analyzer must NOT flag (false-positive guard).

Every pattern here appears in the real serving stack: static ``.shape``
reads, ``is None`` checks, string-key pytree membership, range() over a
static bound, ref-mutation inside a Pallas-style nested def, a
correctly-keyed compiled-fn cache, an arity/axis-correct shard_map site,
host arrays rebound through a ``_host`` boundary, split-then-consume key
discipline (fold_in on the loop index), and a donate-and-rebind loop.
"""
import numpy as np
import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P


def safe(x, n):
    b = int(x.shape[0])                  # .shape is static — no T103
    if n > 2:                            # n is static_argnames — no T101
        x = x + 1.0
    if x is None:                        # identity compare — no T101
        return jnp.zeros((n,))
    mask = jnp.where(x > 0, x, 0.0)
    for i in range(b):                   # static range — no T108
        mask = mask + i
    return mask


fn = jax.jit(safe, static_argnames=("n",))


def outer(x):
    acc = {"v": x}

    def step():
        acc["v"] = acc["v"] * 2.0        # traced base — no T106

    step()
    return acc["v"]


fn2 = jax.jit(outer)


class Cache:
    def __init__(self):
        self._c = {}

    def build(self, m):
        fn = self._c.get(m)
        if fn is None:
            def inner(x):
                return x * m

            fn = jax.jit(inner)
            self._c[m] = fn              # key covers every builder param
        return fn


def _shard_body(a, b):
    return jax.lax.psum(a * b, "data")


def good_shard_site(mesh, a, b):
    f = shard_map(_shard_body, mesh=mesh,
                  in_specs=(P("data"), P("data")), out_specs=P("data"))
    return f(a, b)                           # arity + axis match — no S4xx


class Boundary:
    def __init__(self):
        self._c = {}

    def _host(self, x, dt):
        return jnp.asarray(x, dt)

    def _build(self):
        fn = self._c.get("step")
        if fn is None:
            fn = jax.jit(lambda t: t + 1)
            self._c["step"] = fn
        return fn

    def step(self):
        fn = self._build()
        toks = np.zeros((4,), np.int32)
        toks = self._host(toks, jnp.int32)   # rebound at the boundary — no S403
        return fn(toks)


def key_discipline(key):
    for i in range(4):
        key, sub = jax.random.split(key)     # rebind parent — no R501
        _ = jax.random.normal(sub, (2,))
    step_key = jax.random.fold_in(key, 1)
    return jax.random.normal(step_key, (2,))


def per_step_fold(key, xs):
    out = []
    for i in range(len(xs)):
        k = jax.random.fold_in(key, i)       # loop-index fold_in — no R504
        out.append(jax.random.normal(k, (2,)))
    return out


def donate_and_rebind(state, batch):
    fn = jax.jit(lambda s, b: s + b, donate_argnums=(0,))
    for _ in range(3):
        state = fn(state, batch)             # rebound each step — no D601
    return state

"""Tracer-SAFE idioms the analyzer must NOT flag (false-positive guard).

Every pattern here appears in the real serving stack: static ``.shape``
reads, ``is None`` checks, string-key pytree membership, range() over a
static bound, ref-mutation inside a Pallas-style nested def, and a
correctly-keyed compiled-fn cache.
"""
import jax
import jax.numpy as jnp


def safe(x, n):
    b = int(x.shape[0])                  # .shape is static — no T103
    if n > 2:                            # n is static_argnames — no T101
        x = x + 1.0
    if x is None:                        # identity compare — no T101
        return jnp.zeros((n,))
    mask = jnp.where(x > 0, x, 0.0)
    for i in range(b):                   # static range — no T108
        mask = mask + i
    return mask


fn = jax.jit(safe, static_argnames=("n",))


def outer(x):
    acc = {"v": x}

    def step():
        acc["v"] = acc["v"] * 2.0        # traced base — no T106

    step()
    return acc["v"]


fn2 = jax.jit(outer)


class Cache:
    def __init__(self):
        self._c = {}

    def build(self, m):
        fn = self._c.get(m)
        if fn is None:
            def inner(x):
                return x * m

            fn = jax.jit(inner)
            self._c[m] = fn              # key covers every builder param
        return fn

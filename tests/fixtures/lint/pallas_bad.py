"""Seeded Pallas kernel-contract violations; test_analysis asserts codes.

Editing this file moves line numbers — update tests/test_analysis.py.
"""
import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(x_ref, o_ref, acc_ref):
    o_ref[...] = x_ref[...]


def bad_call(x):
    return pl.pallas_call(
        _kernel,
        grid=(4, 4),
        in_specs=[pl.BlockSpec((7, 100), lambda i: (i, 0))],  # P301+P303 @ 19
        out_specs=pl.BlockSpec((8, 128), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((64, 512), jnp.float32),
        scratch_shapes=[pltpu.VMEM((4096, 4096), jnp.float32)],  # P304
    )(x)


def bad_spec_call(x, lens):
    return pl.pallas_call(                     # P302 + P305 (overlap) @ 26
        _kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(4,),
            in_specs=[pl.BlockSpec((8, 128), lambda i, l: (i, 0))],
            out_specs=pl.BlockSpec((8, 128), lambda i, l: (i, 0)),
            scratch_shapes=[pltpu.VMEM((8, 128), jnp.float32)],
        ),
        grid=(4,),
        out_shape=jax.ShapeDtypeStruct((32, 128), jnp.float32),
    )(lens, x)

"""Buffer-donation violations the D6xx pass must flag."""
import jax
import jax.numpy as jnp


def step(state, batch):
    return state + batch


def use_after_donate(state, batch):
    fn = jax.jit(step, donate_argnums=(0,))
    new = fn(state, batch)
    return new + state


def bad_index():
    return jax.jit(step, donate_argnums=(5,))


def static_donate():
    return jax.jit(step, static_argnums=(1,), donate_argnums=(1,))

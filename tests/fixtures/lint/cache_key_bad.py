"""Seeded jit-cache-key violations; test_analysis asserts the codes.

Editing this file moves line numbers — update tests/test_analysis.py.
"""
import jax


class Engine:
    def __init__(self):
        self._fn_cache = {}
        self.weights = [1.0, 2.0]

    def build(self, b, t, extra):        # K201 (extra) @ line 13
        fn = self._fn_cache.get((b,))
        if fn is None:
            def inner(x, flag):
                if flag:                 # K202 (flag) @ line 17
                    x = x * 2
                return x * b * t * extra

            fn = jax.jit(inner, static_argnames=("nope",))  # K203 @ line 21
            self._fn_cache[(b, t)] = fn  # K205 @ line 22
        return fn

    def build2(self, b):
        fn = self._fn_cache.get(b)
        if fn is None:
            for w in self.weights:
                pass

            def inner2(x):               # K204 (captures w) @ line 31
                return x * w

            fn = jax.jit(inner2)
            self._fn_cache[b] = fn
        return fn

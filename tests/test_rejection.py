"""Rejection sampling: distribution preservation (hypothesis) + mechanics."""
import jax
import jax.numpy as jnp
import numpy as np
from _hypothesis_compat import given, settings, st

from repro.core.analytics import sigma_from_alpha
from repro.core.rejection import probs_from_logits, rejection_sample
import pytest

pytestmark = pytest.mark.tier1


def _dist(rng, V, sharp=1.0):
    x = rng.standard_normal(V) * sharp
    e = np.exp(x - x.max())
    return e / e.sum()


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 10_000), st.integers(2, 6), st.floats(0.5, 3.0))
def test_lossless_distribution(seed, vocab, sharp):
    """Emitted-token marginal == target distribution p0, for arbitrary
    (p, q) pairs: the Leviathan correctness property, checked by exact
    enumeration over (draft token, accept/reject) outcomes."""
    rng = np.random.default_rng(seed)
    gamma = 1
    p0 = _dist(rng, vocab, sharp)
    p1 = _dist(rng, vocab, sharp)
    q0 = _dist(rng, vocab, sharp)

    # enumerate: P(first emitted token = v)
    #  = q0(v)*min(1, p0(v)/q0(v))            [accepted draft]
    #  + sum_d q0(d)*(1-min(1,p0/q0)) * residual(v)
    acc = np.minimum(1.0, p0 / np.maximum(q0, 1e-30))
    residual = np.maximum(p0 - q0, 0)
    residual = residual / residual.sum() if residual.sum() > 1e-12 else p0
    marginal = q0 * acc + (q0 * (1 - acc)).sum() * residual
    np.testing.assert_allclose(marginal, p0, atol=1e-9)

    # Monte-Carlo through the actual implementation
    N = 4000
    p = jnp.asarray(np.stack([np.stack([p0, p1])] * N))      # (N, 2, V)
    q = jnp.asarray(np.stack([p0 * 0 + q0] * N))[:, None]    # (N, 1, V)
    key = jax.random.PRNGKey(seed)
    drafts = jax.random.categorical(
        key, jnp.log(jnp.asarray(q0))[None, :].repeat(N, 0))[:, None]
    n_acc, nxt, _ = rejection_sample(p, q, drafts, key, temperature=1.0)
    emitted = np.where(np.asarray(n_acc) > 0, np.asarray(drafts[:, 0]),
                       np.asarray(nxt))
    counts = np.bincount(emitted, minlength=vocab) / N
    assert np.abs(counts - p0).max() < 4.5 * np.sqrt(p0.max() / N) + 0.02


def test_greedy_one_hot_path():
    V = 8
    p = jax.nn.one_hot(jnp.array([[3, 5, 1]]), V)                 # (1,3,V)
    q = jax.nn.one_hot(jnp.array([[3, 0]]), V)                    # (1,2,V)
    drafts = jnp.array([[3, 0]])
    n, nxt, _ = rejection_sample(p, q, drafts, jax.random.PRNGKey(0), 0.0)
    assert int(n[0]) == 1          # first accepted (argmax match), second not
    assert int(nxt[0]) == 5        # corrected from p1's argmax


def test_sigma_formula_vs_monte_carlo():
    rng = np.random.default_rng(0)
    for alpha in (0.3, 0.7, 0.95):
        for gamma in (1, 3, 5):
            acc = rng.random((200_000, gamma)) < alpha
            n = np.cumprod(acc, 1).sum(1)
            sigma_mc = (n + 1).mean() / (gamma + 1)
            assert abs(sigma_mc - sigma_from_alpha(alpha, gamma)) < 5e-3


def test_probs_from_logits_greedy_is_onehot():
    logits = jnp.array([[0.1, 2.0, -1.0]])
    p = probs_from_logits(logits, 0.0)
    np.testing.assert_array_equal(np.asarray(p), [[0, 1, 0]])

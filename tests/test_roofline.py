"""Roofline methodology validation.

1. XLA cost_analysis counts scan bodies once (the reason we use the
   analytic census — documented in launch/roofline.py).
2. The analytic census agrees with HLO FLOPs on a scan-free lowering.
3. Collective-byte parsing finds the all-reduce/all-gather traffic of a
   known sharded computation.
"""
import re
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import get_config
from repro.core.simulator import Simulator
from repro.launch.dryrun import hlo_cost_analysis
import pytest

pytestmark = pytest.mark.tier1


def test_scan_body_counted_once():
    def f(x, w):
        def body(c, _):
            return jnp.tanh(c @ w), None
        out, _ = jax.lax.scan(body, x, None, length=10)
        return out

    xs = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    ws = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    flops = hlo_cost_analysis(jax.jit(f).lower(xs, ws).compile())["flops"]
    one_body = 2 * 128 ** 3
    assert flops < 2 * one_body          # NOT 10x — the documented behavior


def test_analytic_census_matches_hlo_scanfree():
    """One-period reduced config, unrolled: analytic FLOPs within 2x of HLO
    (HLO includes softmax/norm flops the census ignores; the census includes
    the causal-attention halving the HLO doesn't)."""
    cfg = get_config("qwen2-7b", reduced=True).with_overrides(
        num_layers=1, vocab_size=512)
    from repro.models.model import Model
    model = Model(cfg)
    B, T = 2, 128
    params = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    toks = jax.ShapeDtypeStruct((B, T), jnp.int32)

    def fwd(p, t):
        h, _ = model.forward_hidden(p, t)
        return h

    compiled = jax.jit(fwd).lower(params, toks).compile()
    hlo_flops = hlo_cost_analysis(compiled)["flops"]
    sim = Simulator()
    analytic = sim.forward_costs(cfg, B, T, context_len=T)["flops"]
    # remove head flops (fwd() stops at hidden)
    analytic -= 2.0 * B * cfg.d_model * cfg.vocab_size
    ratio = analytic / hlo_flops
    assert 0.4 < ratio < 2.5, (analytic, hlo_flops)


def test_collective_parser():
    from repro.launch.dryrun import _collective_bytes
    hlo = textwrap.dedent("""\
      %p0 = f32[1024,256] parameter(0)
      %ag = f32[1024,1024] all-gather(%p0), dimensions={1}
      %ar = f32[1024,1024] all-reduce(%ag), to_apply=%add
      %rs = f32[256,1024] reduce-scatter(%ar), dimensions={0}
    """)
    out = _collective_bytes(hlo)
    assert out["all-gather"] == 1024 * 256 * 4
    assert out["all-reduce"] == 1024 * 1024 * 4
    assert out["reduce-scatter"] == 1024 * 1024 * 4
    assert out["total"] == sum(out[k] for k in
                               ("all-gather", "all-reduce", "reduce-scatter",
                                "all-to-all", "collective-permute"))
    assert out["in_loop"] + out["outside"] == out["total"]


def test_collective_loop_attribution():
    from repro.launch.dryrun import _collective_bytes
    hlo = textwrap.dedent("""\
      %loop_body (p: f32[8]) -> f32[8] {
        %p = f32[8] parameter(0)
        ROOT %ar2 = f32[8] all-reduce(%p), to_apply=%add
      }
      ENTRY %main (x: f32[8]) -> f32[8] {
        %x = f32[8] parameter(0)
        %ag = f32[64] all-gather(%x), dimensions={0}
        ROOT %w = f32[8] while(%x), condition=%cond, body=%loop_body
      }
    """)
    out = _collective_bytes(hlo)
    assert out["in_loop"] == 8 * 4            # the all-reduce inside the body
    assert out["outside"] == 8 * 4            # the hoisted all-gather operand


def test_roofline_analyze_fields():
    from repro.launch.roofline import analyze
    rec = {
        "arch": "qwen2-7b", "shape": "decode_32k", "mesh": "16x16",
        "devices": 256, "gamma": 0,
        "params": get_config("qwen2-7b").param_count(),
        "active_params": get_config("qwen2-7b").active_param_count(),
        "flops_per_device": 1e9, "bytes_per_device": 1e9,
        "collective_bytes_per_device": {"all-gather": 0, "all-reduce": 1e6,
                                        "reduce-scatter": 0, "all-to-all": 0,
                                        "collective-permute": 0, "total": 1e6},
        "memory": {"temp_bytes": int(4e9)},
    }
    out = analyze(rec)
    assert out["dominant"] in ("compute", "memory", "collective")
    assert out["fits_16gb"] is True
    assert out["t_memory_s"] > 0 and out["t_compute_s"] > 0
    assert 0 < out["usefulness"] <= 1.5

"""Fallback for environments without ``hypothesis`` (offline CI image).

Exports ``given``, ``settings``, and ``st`` that are the real hypothesis
when available.  Otherwise a minimal deterministic stand-in runs each
property test over a fixed number of seeded draws — weaker than real
property testing (no shrinking, no fuzzing) but the deterministic cases
still execute and the invariants stay guarded.
"""
from __future__ import annotations

try:
    from hypothesis import given, settings, strategies as st  # noqa: F401
    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    import functools

    import numpy as np

    HAVE_HYPOTHESIS = False
    _FALLBACK_EXAMPLES = 5

    class _Strategy:
        def __init__(self, draw):
            self._draw = draw

        def draw(self, rng):
            return self._draw(rng)

    class _Strategies:
        @staticmethod
        def integers(min_value, max_value):
            return _Strategy(lambda rng: int(rng.integers(min_value,
                                                          max_value + 1)))

        @staticmethod
        def floats(min_value, max_value):
            return _Strategy(lambda rng: float(rng.uniform(min_value,
                                                           max_value)))

        @staticmethod
        def booleans():
            return _Strategy(lambda rng: bool(rng.integers(0, 2)))

        @staticmethod
        def sampled_from(elements):
            elements = list(elements)
            return _Strategy(
                lambda rng: elements[int(rng.integers(0, len(elements)))])

    st = _Strategies()

    def settings(**kwargs):                       # noqa: D401 - passthrough
        """No-op decorator (max_examples/deadline are fixed in fallback)."""
        def deco(fn):
            return fn
        return deco

    def given(*strategies):
        """Run the test over deterministic seeded draws of each strategy."""
        def deco(fn):
            @functools.wraps(fn)
            def wrapper():
                for example in range(_FALLBACK_EXAMPLES):
                    rng = np.random.default_rng(example)
                    fn(*[s.draw(rng) for s in strategies])
            # hide the original signature or pytest would treat the
            # strategy-filled parameters as fixtures
            del wrapper.__wrapped__
            return wrapper
        return deco

"""Runtime retrace-freedom: the compile guard proves the zero-retrace
discipline at the XLA level, not just via the engine's own trace logs.

The static analyzer (repro.analysis) shows the *code* cannot leak
tracers; these tests show the *runtime* stops compiling once warm:
steady-state rounds, occupancy churn, same-bucket admissions and whole
repeated continuous streams compile nothing, and a genuinely new
admission bucket compiles exactly one program.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis import compile_guard
from repro.configs.base import ModelConfig
from repro.core.proposer import ModelProposer
from repro.core.spec_decode import SDEngine
from repro.models.model import Model
from repro.serving.engine import ServingEngine

pytestmark = [pytest.mark.tier1, pytest.mark.compile_guard]

TCFG = ModelConfig("rg-moe", "moe", 2, 128, 4, 2, 256, 512, num_experts=4,
                   num_experts_per_tok=2, dtype="float32")
DCFG = ModelConfig("rg-draft", "dense", 2, 64, 2, 2, 128, 512,
                   dtype="float32")


@pytest.fixture(scope="module")
def models():
    t, d = Model(TCFG), Model(DCFG)
    return t, d, t.init(jax.random.PRNGKey(0)), d.init(jax.random.PRNGKey(1))


@pytest.fixture()
def session(models):
    t, d, pt, pd = models
    eng = SDEngine(t, ModelProposer(t, d), gamma=2)
    prompts = jnp.asarray(np.tile(np.arange(3, 9), (4, 1)))
    state = eng.start(pt, pd, prompts, max_seq=64)
    return eng, state


def test_steady_state_rounds_never_recompile(session):
    eng, state = session
    for _ in range(2):                            # warmup builds the round
        state, _ = eng.round(state)
    traces = len(eng.trace_log)
    with compile_guard() as guard:
        for _ in range(5):
            state, _ = eng.round(state)
    assert guard.count == 0
    assert len(eng.trace_log) == traces           # and no silent retrace


def test_occupancy_churn_is_data_not_shape(session):
    """Flipping the active mask between rounds (slot retire/refill) must
    reuse the one compiled round — active rows are data."""
    eng, state = session
    state, _ = eng.round(state)                   # warmup, all active
    masks = ([1, 1, 0, 0], [1, 0, 1, 1], [0, 1, 0, 1])
    with compile_guard() as guard:
        for m in masks:
            state, _ = eng.round(state, active=np.asarray(m, bool))
    assert guard.count == 0


def test_admissions_within_bucket_never_recompile(session):
    eng, state = session
    prompts = jnp.asarray(np.tile(np.arange(3, 9), (1, 1)))   # R=1 bucket
    lengths = np.array([6])
    state = eng.admit_rows(state, prompts, lengths, np.array([1]))  # warm
    with compile_guard() as guard:
        for row in (2, 3, 0):                     # refills: rows are data
            state = eng.admit_rows(state, prompts, lengths, np.array([row]))
    assert guard.count == 0


def test_new_row_bucket_compiles_exactly_once(session):
    eng, state = session
    one = jnp.asarray(np.tile(np.arange(3, 9), (1, 1)))
    state = eng.admit_rows(state, one, np.array([6]), np.array([1]))
    admits = len(eng.admit_trace_log)
    two = jnp.asarray(np.tile(np.arange(3, 9), (2, 1)))       # new R bucket
    with compile_guard() as guard:
        state = eng.admit_rows(state, two, np.array([6, 6]),
                               np.array([0, 1]))
    assert len(eng.admit_trace_log) == admits + 1  # one new jit signature
    assert guard.count == 1                        # exactly one XLA program
    # and the freshly-built bucket is itself steady from the first reuse
    with compile_guard() as guard2:
        state = eng.admit_rows(state, two, np.array([6, 6]),
                               np.array([2, 3]))
    assert guard2.count == 0


def test_continuous_stream_steady_state(models):
    """A second identical-shape request stream through the SAME serving
    engine (ContinuousScheduler; mixed budgets, admissions inside one
    prompt bucket) compiles nothing: the warm stream covered every
    (round, admission) signature."""
    t, d, pt, pd = models
    eng = ServingEngine(t, d, pt, pd, max_batch=2, gamma=2, force_sd=True,
                        scheduler="continuous")
    for m in (3, 7, 5):
        eng.submit(np.arange(3, 9), max_new_tokens=m)
    eng.run()                                     # warm stream
    with compile_guard() as guard:
        for m in (4, 6, 5):
            eng.submit(np.arange(3, 9), max_new_tokens=m)
        eng.run()
    assert guard.count == 0
    assert eng.session_constructions == {"model": 1}


def test_paged_kernel_shared_prefix_stream_steady_state(models):
    """A second identical-shape shared-prefix stream through the SAME
    paged engine compiles nothing: the block-table kernel's steady
    decode/verify rounds, the tail-bucket prefix admissions, the CoW
    page copies (pow2-padded pairs) and the table swaps are all DATA
    once the warm stream covered each signature."""
    t, d, pt, pd = models
    eng = ServingEngine(t, d, pt, pd, max_batch=2, gamma=2, force_sd=True,
                        scheduler="continuous", kv_layout="paged",
                        page_size=8, prefix_sharing=True)
    base = np.arange(3, 15)                       # 12-token shared prefix:
                                                  # boundary page gets CoW'd

    def stream(budgets, salt):
        for i, m in enumerate(budgets):
            tail = np.arange(0, 4) + 20 + salt + 4 * i
            eng.submit(np.concatenate([base, tail]), max_new_tokens=m)
        eng.run()

    stream((3, 7, 5), salt=0)                     # warm: every signature
    assert eng.fault_counters.get("prefix_hits", 0) >= 1
    assert eng.fault_counters.get("cow_copies", 0) >= 1
    with compile_guard() as guard:
        stream((4, 6, 5), salt=60)
    assert guard.count == 0
    assert eng.fault_counters["prefix_hits"] >= 2

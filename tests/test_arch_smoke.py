"""Per-architecture smoke tests: REDUCED variant of each assigned family
runs one forward + one train step + one decode step on CPU; asserts output
shapes and finiteness (deliverable f)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import TrainConfig
from repro.configs.registry import ASSIGNED, get_config
from repro.models.model import Model
from repro.training.train_loop import init_train_state, make_train_step

pytestmark = pytest.mark.tier1

ALL_ARCHS = list(ASSIGNED) + ["qwen2-57b-a14b", "mixtral-8x7b", "qwen2-0.5b"]


def _batch_for(cfg, B, T, key):
    batch = {
        "tokens": jax.random.randint(key, (B, T), 0, cfg.vocab_size),
        "labels": jax.random.randint(key, (B, T), 0, cfg.vocab_size),
        "mask": jnp.ones((B, T), jnp.float32),
    }
    if cfg.is_encoder_decoder:
        batch["encoder_embeds"] = 0.02 * jax.random.normal(
            key, (B, cfg.encoder_seq_len, cfg.d_model), jnp.dtype(cfg.dtype))
    return batch


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_reduced_smoke(arch):
    cfg = get_config(arch, reduced=True)
    assert cfg.num_layers <= 2 and cfg.d_model <= 512
    assert cfg.num_experts <= 4
    B, T = 2, 16
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = _batch_for(cfg, B, T, jax.random.PRNGKey(1))

    # forward
    kwargs = ({"encoder_embeds": batch["encoder_embeds"]}
              if cfg.is_encoder_decoder else {})
    logits, metrics = model.forward_train(params, batch["tokens"], **kwargs)
    assert logits.shape == (B, T, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits, np.float32)).all()

    # one train step
    step = jax.jit(make_train_step(model, TrainConfig(total_steps=10)))
    params2, opt = init_train_state(model, jax.random.PRNGKey(0))
    params2, opt, m = step(params2, opt, batch)
    assert np.isfinite(float(m["loss"]))
    assert np.isfinite(float(m["grad_norm"]))

    # prefill + decode step
    cache = model.init_cache(B, T + 4)
    last, cache = model.prefill(params, batch["tokens"], cache, **kwargs)
    assert last.shape == (B, cfg.vocab_size)
    tok = jnp.argmax(last, -1)
    lg, cache = model.decode_step(params, tok, cache)
    assert lg.shape == (B, cfg.vocab_size)
    assert np.isfinite(np.asarray(lg, np.float32)).all()
    assert int(cache["lengths"][0]) == T + 1


@pytest.mark.parametrize("arch", ASSIGNED)
def test_full_config_matches_assignment(arch):
    """Full configs carry the exact assigned hyperparameters."""
    spec = {
        "gemma-7b": (28, 3072, 16, 16, 24576, 256000),
        "minicpm3-4b": (62, 2560, 40, 40, 6400, 73448),
        "whisper-base": (6, 512, 8, 8, 2048, 51865),
        "qwen2-vl-2b": (28, 1536, 12, 2, 8960, 151936),
        "gemma3-12b": (48, 3840, 16, 8, 15360, 262144),
        "jamba-v0.1-52b": (32, 4096, 32, 8, 14336, 65536),
        "qwen2-7b": (28, 3584, 28, 4, 18944, 152064),
        "dbrx-132b": (40, 6144, 48, 8, 10752, 100352),
        "qwen3-moe-30b-a3b": (48, 2048, 32, 4, 768, 151936),
        "xlstm-1.3b": (48, 2048, 4, 4, 0, 50304),
    }[arch]
    cfg = get_config(arch)
    got = (cfg.num_layers, cfg.d_model, cfg.num_heads, cfg.num_kv_heads,
           cfg.d_ff, cfg.vocab_size)
    assert got == spec, (arch, got, spec)
    moe = {"jamba-v0.1-52b": (16, 2), "dbrx-132b": (16, 4),
           "qwen3-moe-30b-a3b": (128, 8)}
    if arch in moe:
        assert (cfg.num_experts, cfg.num_experts_per_tok) == moe[arch]

"""System invariants (hypothesis): batching must never change results.

These are the contracts a serving system quietly depends on:
  * batch-order equivariance of the forward pass,
  * per-sequence independence — a sequence decodes identically alone or
    inside any batch (ragged lengths, SD rounds included),
  * prompt-padding invariance — garbage beyond ``lengths`` cannot leak
    through the attention masks or the cache write discipline.
"""
import jax
import jax.numpy as jnp
import numpy as np
from _hypothesis_compat import given, settings, st

from repro.configs.base import ModelConfig
from repro.core.spec_decode import SpecDecoder
from repro.models.model import Model

CFG = ModelConfig("inv-moe", "moe", 2, 96, 4, 2, 192, 256, num_experts=4,
                  num_experts_per_tok=2, dtype="float32")
DRAFT = ModelConfig("inv-draft", "dense", 2, 48, 2, 2, 96, 256,
                    dtype="float32")

_model = Model(CFG)
_params = _model.init(jax.random.PRNGKey(0))
_draft = Model(DRAFT)
_dparams = _draft.init(jax.random.PRNGKey(5))


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 10_000))
def test_batch_order_equivariance(seed):
    key = jax.random.PRNGKey(seed)
    toks = jax.random.randint(key, (4, 12), 0, 256)
    perm = jax.random.permutation(jax.random.fold_in(key, 1), 4)
    out1, _ = _model.forward_train(_params, toks)
    out2, _ = _model.forward_train(_params, toks[perm])
    np.testing.assert_allclose(np.asarray(out1[perm]), np.asarray(out2),
                               rtol=2e-4, atol=2e-4)


@settings(max_examples=6, deadline=None)
@given(st.integers(0, 10_000))
def test_padding_beyond_length_is_invisible(seed):
    """Prefill logits at lengths-1 are unchanged by arbitrary pad content."""
    key = jax.random.PRNGKey(seed)
    B, T = 3, 10
    toks = jax.random.randint(key, (B, T), 0, 256)
    lengths = jnp.array([4, 10, 7])
    junk = jax.random.randint(jax.random.fold_in(key, 1), (B, T), 0, 256)
    mask = jnp.arange(T)[None, :] < lengths[:, None]
    toks2 = jnp.where(mask, toks, junk)
    for t in (toks, toks2):
        cache = _model.init_cache(B, T + 4)
        last, _ = _model.prefill(_params, t, cache, lengths=lengths)
        if t is toks:
            ref = last
    np.testing.assert_allclose(np.asarray(ref), np.asarray(last), rtol=2e-4,
                               atol=2e-4)


@settings(max_examples=4, deadline=None)
@given(st.integers(0, 10_000))
def test_sequence_independent_of_batchmates(seed):
    """Greedy SD output of a sequence is identical alone vs in a batch of
    strangers with different prompt lengths."""
    key = jax.random.PRNGKey(seed)
    B, T = 3, 9
    toks = jax.random.randint(key, (B, T), 3, 256)
    lengths = jnp.asarray(
        np.random.default_rng(seed).integers(3, T + 1, size=B), jnp.int32)
    sd = SpecDecoder(_model, _draft, gamma=2, temperature=0.0)
    out_batch, _ = sd.generate(_params, _dparams, toks, 10, lengths=lengths)
    for b in range(B):
        solo, _ = sd.generate(_params, _dparams,
                              toks[b: b + 1, : int(lengths[b])], 10)
        np.testing.assert_array_equal(out_batch[b], solo[0])

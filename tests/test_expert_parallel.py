"""Expert-parallel sharded serving end to end (subprocess, 8 forced host
devices): shard_map dispatch parity against the single-device oracles, greedy
SD-round byte parity, continuous-stream parity with admission + preemption
under sharding, and the zero-retrace guarantee on a warm sharded engine.

Everything runs in subprocesses because the forced-device XLA flag must be
set before jax imports; scripts print "OK" markers the tests assert on.
"""
import subprocess
import sys
import textwrap

import pytest

pytestmark = pytest.mark.tier1

_ENV = {"PYTHONPATH": "src", "PATH": "/usr/bin:/bin", "HOME": "/root",
        "JAX_PLATFORMS": "cpu"}


def _run(script: str, timeout: int = 600):
    proc = subprocess.run(
        [sys.executable, "-c", script], capture_output=True, text=True,
        timeout=timeout, env=_ENV, cwd="/root/repo")
    assert proc.returncode == 0, proc.stderr[-3000:]
    assert "OK" in proc.stdout, proc.stdout[-2000:]
    return proc


# ------------------------------------------------------- dispatch parity
_DISPATCH = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp, numpy as np
    from repro.configs.base import ModelConfig
    from repro.distributed.collectives import moe_ep_forward
    from repro.launch.mesh import make_ep_mesh
    from repro.models import moe as moe_mod

    cfg = ModelConfig("ep", "moe", 2, 64, 4, 2, 128, 256, num_experts=8,
                      num_experts_per_tok=2, moe_d_ff=128, dtype="float32",
                      num_shared_experts=1)
    params = moe_mod.init_moe(jax.random.PRNGKey(0), cfg, jnp.float32)
    # 14 rows: exercises the pad-to-even-split path on 8 shards
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 7, 64), jnp.float32)
    # bias the router so experts 5..7 are never picked: empty LOCAL experts
    # (and whole empty shards at ep=8) must cost nothing and stay correct
    params["router"] = params["router"].at[:, 5:].add(-100.0)
    ref_one = moe_mod.moe_forward(params, cfg, x, dispatch="onehot")[0]
    ref_gmm = moe_mod.moe_forward(params, cfg, x, dispatch="gmm")[0]
    np.testing.assert_allclose(np.asarray(ref_gmm), np.asarray(ref_one),
                               rtol=3e-4, atol=3e-4)
    for ep, dd in ((2, 1), (4, 2), (8, 1)):
        mesh = make_ep_mesh(ep, data_degree=dd)
        out = moe_ep_forward(params, cfg, x, mesh=mesh)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref_one),
                                   rtol=3e-4, atol=3e-4)
    # capacity-bounded slot buffers stay exact while capacity covers the skew
    p2 = moe_mod.init_moe(jax.random.PRNGKey(0), cfg, jnp.float32)
    ref2 = moe_mod.moe_forward(p2, cfg, x, dispatch="onehot")[0]
    out2 = moe_ep_forward(p2, cfg, x, mesh=make_ep_mesh(2),
                          capacity_factor=8.0)
    np.testing.assert_allclose(np.asarray(out2), np.asarray(ref2),
                               rtol=3e-4, atol=3e-4)
    print("OK")
""")


def test_ep_dispatch_matches_single_device_oracles():
    """a2a→ragged-gmm ≡ onehot ≡ gmm over imbalanced/empty routings,
    shared experts, non-even row counts and multi-axis meshes."""
    _run(_DISPATCH)


# ------------------------------------------------- SD-round token parity
_SD_ROUNDS = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, numpy as np
    from repro.configs.base import ModelConfig
    from repro.core.proposer import make_proposer
    from repro.core.spec_decode import SDEngine
    from repro.distributed.sharding import shard_params
    from repro.launch.mesh import make_ep_mesh
    from repro.models.model import Model

    TCFG = ModelConfig("ep-t", "moe", 2, 64, 4, 2, 128, 256, num_experts=8,
                       num_experts_per_tok=2, dtype="float32")
    DCFG = ModelConfig("ep-d", "dense", 2, 32, 2, 2, 64, 256,
                       dtype="float32")

    def run(mesh):
        t = Model(TCFG, moe_dispatch="ep" if mesh is not None else "gmm",
                  mesh=mesh)
        d = Model(DCFG)
        pt = t.init(jax.random.PRNGKey(0))
        pd = d.init(jax.random.PRNGKey(1))
        if mesh is not None:
            pt = jax.device_put(pt, shard_params(pt, mesh))
        eng = SDEngine(t, make_proposer("model", t, d), gamma=4, mesh=mesh)
        prompts = (np.arange(24).reshape(4, 6) % 250 + 1).astype(np.int32)
        state = eng.start(pt, pd, prompts, max_seq=64)
        rows = [np.asarray(state.last_token).tolist()]
        for g in (4, 0, 4, 4, 0):         # SD rounds AND the AR fallback
            state, res = eng.round(state, gamma=g)
            rows.append((res.n_commit.tolist(),
                         [res.committed[b, :res.n_commit[b]].tolist()
                          for b in range(4)]))
        return rows

    ref = run(None)
    ep = run(make_ep_mesh(8))
    assert ref == ep, (ref, ep)
    print("OK")
""")


def test_sd_rounds_token_identical_on_1xN_mesh():
    """Greedy propose/verify/reject/commit rounds at gamma 4 and gamma 0
    commit byte-identical tokens on an ep=8 mesh vs single-device gmm."""
    _run(_SD_ROUNDS)


# ------------------- continuous stream: admission + preemption + retrace
_CONTINUOUS = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, numpy as np
    from repro.configs.base import ModelConfig
    from repro.launch.mesh import make_ep_mesh
    from repro.models.model import Model
    from repro.serving.engine import ServingEngine
    from repro.serving.faults import ResilienceConfig

    TCFG = ModelConfig("ep-t", "moe", 2, 64, 4, 2, 128, 256, num_experts=8,
                       num_experts_per_tok=2, dtype="float32")
    DCFG = ModelConfig("ep-d", "dense", 2, 32, 2, 2, 64, 256,
                       dtype="float32")

    def build(mesh):
        t = Model(TCFG, moe_dispatch="ep" if mesh is not None else "gmm",
                  mesh=mesh)
        d = Model(DCFG)
        pt = t.init(jax.random.PRNGKey(0))
        pd = d.init(jax.random.PRNGKey(1))
        eng = ServingEngine(t, d, pt, pd, max_batch=3, gamma=2,
                            force_sd=True, scheduler="continuous",
                            kv_layout="paged", page_size=8, seed=0,
                            resilience=ResilienceConfig(max_pool_pages=8),
                            mesh=mesh)

        def stream():
            ua = eng.submit(np.arange(3, 9), max_new_tokens=16)
            ub = eng.submit(np.arange(4, 10), max_new_tokens=8,
                            arrival_round=1)
            uc = eng.submit(np.arange(5, 11), max_new_tokens=8,
                            arrival_round=2)
            eng.run()
            return [eng.done[u].output.tolist() for u in (ua, ub, uc)]

        return eng, stream

    ref_eng, ref_stream = build(None)
    ref = ref_stream()
    assert ref_eng.fault_counters["preemptions"] >= 1   # cap really binds
    eng, stream = build(make_ep_mesh(8))
    ep1 = stream()
    assert ep1 == ref, (ep1, ref)
    assert eng.fault_counters["preemptions"] >= 1
    rep = eng.reports[-1].ep
    assert rep is not None and len(rep["per_shard_load"]) == 8
    assert rep["imbalance"] >= 1.0 and rep["a2a_bytes_per_device"] > 0
    eng._slot_scheduler._alloc.assert_no_leaks()
    # warm sharded engine: the SAME stream again compiles ZERO programs,
    # makes ZERO implicit host<->device transfers, and every cached jit
    # program sees exactly one input-sharding signature
    from repro.analysis import compile_guard, sharding_guard, transfer_guard
    with compile_guard() as g, transfer_guard() as tg, \
            sharding_guard(eng) as sg:
        ep2 = stream()
    assert ep2 == ep1
    assert g.count == 0, g.count
    assert tg.count == 0, (tg.count, tg.lines[:5])
    assert sg.programs > 0 and sg.ok, sg.render()
    print("OK")
""")


def test_continuous_stream_parity_preemption_and_zero_retrace():
    """ep=8 continuous serving (paged KV, in-flight admission, page-pressure
    preemption + requeue) is token-identical to single-device serving; a
    second identical stream through the warm sharded engine compiles
    nothing, transfers nothing implicitly (transfer_guard) and keeps one
    sharding signature per cached program (sharding_guard)."""
    _run(_CONTINUOUS)


# ----------------------------------------------------- mesh API contracts
def test_mesh_api_validation_and_deprecation():
    """set_mesh is a hard error (explicit threading is the only path — no
    process-global mesh survives); resolve_mesh validates; make_ep_mesh
    and ServingEngine(mesh=...) fail loudly on malformed meshes."""
    import jax
    from jax.sharding import Mesh
    import numpy as np
    from repro.distributed.constraints import resolve_mesh, set_mesh
    from repro.launch.mesh import make_ep_mesh

    mesh = Mesh(np.asarray(jax.devices()[:1]).reshape(1, 1),
                ("data", "model"))
    # the removed process-global raises no matter the arguments
    with pytest.raises(RuntimeError, match="set_mesh was removed"):
        set_mesh(mesh)
    with pytest.raises(RuntimeError, match="mesh="):
        set_mesh(None)
    # resolve_mesh validates the explicitly threaded pair
    with pytest.raises(TypeError, match="Mesh"):
        resolve_mesh("not a mesh")
    with pytest.raises(ValueError, match="layout"):
        resolve_mesh(mesh, "bogus")
    with pytest.raises(ValueError, match="layout"):
        resolve_mesh(None, "bogus")
    m2, layout = resolve_mesh(mesh, "fsdp")
    assert m2 is mesh and layout == "fsdp"
    # None mesh means single-device — there is no global to fall back to
    assert resolve_mesh(None, None) == (None, "tp")
    with pytest.raises(ValueError, match="degrees"):
        make_ep_mesh(0)
    with pytest.raises(ValueError, match="devices"):
        make_ep_mesh(4096)
    from repro.configs.base import ModelConfig
    from repro.models.model import Model
    from repro.serving.engine import ServingEngine
    bad = Mesh(np.asarray(jax.devices()[:1]).reshape(1,), ("data",))
    cfg = ModelConfig("m", "dense", 1, 8, 1, 1, 16, 32, dtype="float32")
    with pytest.raises(ValueError, match="model"):
        ServingEngine(Model(cfg), mesh=bad)

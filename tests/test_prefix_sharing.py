"""Prefix sharing: shared prompt prefixes map to refcounted CoW pages.

The contracts docs/paged_attention.md specifies on top of the paged
continuous scheduler:

  * byte-identical outputs — a prefix-shared stream commits exactly the
    greedy tokens of an unshared stream, while target-prefilling the
    common prefix once (tail-bucket admission traces, shrunk
    admit_tokens, prefix_hits/shared_tokens accounting),
  * refcounts protect siblings — preempting or retiring one fork never
    frees pages another row still references; every stream ends with
    zero leaked or double-freed pages (``assert_no_leaks`` and
    ``free_row`` raise loudly instead of corrupting the free list),
  * page-exhaustion pressure composes — scripted exhaustion and a
    capped pool while shared pages are live recover with the same
    tokens,
  * misconfigurations fail at engine construction, not mid-stream.
"""
import os
import sys

import jax
import numpy as np
import pytest

from repro.configs.base import ModelConfig
from repro.models.model import Model, PageAllocator
from repro.serving.engine import ServingEngine
from repro.serving.faults import Fault, FaultInjector, ResilienceConfig

pytestmark = pytest.mark.tier1

TCFG = ModelConfig("pfx-moe", "moe", 2, 128, 4, 2, 256, 512, num_experts=4,
                   num_experts_per_tok=2, dtype="float32")
DCFG = ModelConfig("pfx-draft", "dense", 2, 64, 2, 2, 128, 512,
                   dtype="float32")
SWACFG = ModelConfig("pfx-swa", "dense", 2, 64, 4, 2, 128, 512,
                     layer_pattern=("swa",), sliding_window=6,
                     dtype="float32")


@pytest.fixture(scope="module")
def models():
    t, d = Model(TCFG), Model(DCFG)
    return t, d, t.init(jax.random.PRNGKey(0)), d.init(jax.random.PRNGKey(1))


def _engine(t, d, pt, pd, **kw):
    kw.setdefault("max_batch", 3)
    kw.setdefault("gamma", 2)
    kw.setdefault("force_sd", True)
    kw.setdefault("scheduler", "continuous")
    return ServingEngine(t, d, pt, pd, **kw)


# --------------------------------------------------- allocator fork/CoW/free
def test_fork_cow_refcount_mechanics():
    """fork_prefix bumps refcounts, cow_range detaches exactly the shared
    pages in range, and free order is irrelevant: a page returns to the
    free list only when its LAST reference drops."""
    a = PageAllocator(3, 8, 16, 4)
    a.alloc(0, 30)                            # 4 private pages
    with pytest.raises(ValueError, match="cannot share"):
        a.fork_prefix(2, 1, 8)                # src owns nothing
    assert a.fork_prefix(0, 1, 20) == 3       # 3 pages cover 20 positions
    with pytest.raises(ValueError, match="already owns"):
        a.fork_prefix(0, 1, 8)                # dst must start empty
    assert a.shared_page_count() == 3
    np.testing.assert_array_equal(a.table[1, :3], a.table[0, :3])
    a.extend_row(1, 30)                       # private tail page
    assert a.table[1, 3] != a.table[0, 3]

    # CoW the tail boundary: only the one shared page in [20, 30) detaches
    pairs = a.cow_range(1, 20, 30)
    assert len(pairs) == 1
    src, dst = pairs[0]
    assert src == a.owned[0][2] and a.owned[1][2] == dst and src != dst
    assert a.shared_page_count() == 2
    assert a.cow_range(1, 20, 30) == []       # already private: idempotent

    # retire the LEADER first — the follower's shared pages must survive
    follower_pages = list(a.owned[1])
    a.free_row(0)
    assert all(p not in a.free for p in follower_pages)
    assert a.shared_page_count() == 0         # last reference each
    a.free_row(1)
    a.assert_no_leaks()

    # leak check reports still-shared pages while forks are live
    a.alloc(0, 8)
    a.fork_prefix(0, 1, 8)
    with pytest.raises(RuntimeError, match="1 of them shared"):
        a.assert_no_leaks()
    a.free_row(1)
    a.free_row(0)
    a.assert_no_leaks()


def test_cow_without_free_pages_raises():
    """Detaching under a full pool fails loudly — never silently aliases
    a page two writers both think they own."""
    a = PageAllocator(2, 8, 3, 2)             # 2 allocatable pages
    a.alloc(0, 16)
    a.fork_prefix(0, 1, 16)
    with pytest.raises(ValueError, match="no free page"):
        a.cow_range(1, 0, 16)


def test_double_free_of_shared_page_detected():
    """A shared page that lands on the free list while still referenced
    is corruption — free_row raises instead of double-crediting."""
    a = PageAllocator(2, 8, 8, 2)
    a.alloc(0, 16)
    a.fork_prefix(0, 1, 16)
    a.free.append(a.owned[1][0])              # corrupt: shared AND free
    with pytest.raises(ValueError, match="double free"):
        a.free_row(1)


# --------------------------------------------------------- end-to-end stream
# 20 shared tokens with page_size 8: two whole shared pages + a shared
# BOUNDARY page every follower must CoW-detach before its tail prefill
SHARED, TAIL, N_REQ = 20, 4, 3


def _shared_stream(t, d, pt, pd, *, sharing, n=N_REQ, max_new=6, **kw):
    """n requests with one SHARED-token system prompt + distinct TAIL-token
    suffixes, all arriving at round 0 (exercises the stagger path)."""
    kw.setdefault("kv_layout", "paged")
    kw.setdefault("page_size", 8)
    eng = _engine(t, d, pt, pd, prefix_sharing=sharing, seed=3, **kw)
    rng = np.random.default_rng(3)
    sys_toks = rng.integers(3, 500, size=SHARED)
    uids = [eng.submit(
        np.concatenate([sys_toks,
                        rng.integers(3, 500, size=TAIL)]).astype(np.int32),
        max_new_tokens=max_new, arrival_round=0) for _ in range(n)]
    eng.run()
    return eng, uids


def test_shared_stream_byte_identical_and_prefilled_once(models):
    """The acceptance trace: N requests sharing one system prompt finish
    byte-identical to the unshared stream; the target prefills the common
    prefix exactly once (followers admit through tail-sized buckets) and
    the page pool drains to zero leaks."""
    t, d, pt, pd = models
    plain, pu = _shared_stream(t, d, pt, pd, sharing=False)
    share, su = _shared_stream(t, d, pt, pd, sharing=True)
    for a, b in zip(pu, su):
        assert share.done[b].finish_reason == "length"
        np.testing.assert_array_equal(plain.done[a].output,
                                      share.done[b].output)

    fc = share.fault_counters
    assert fc["prefix_hits"] == N_REQ - 1
    assert fc["prefix_shared_tokens"] == (N_REQ - 1) * SHARED
    assert fc["prefix_staggered"] == N_REQ - 1   # same-round siblings wait
    assert fc["cow_copies"] == N_REQ - 1         # one boundary page each

    # follower admissions are TAIL-sized, never full-prompt re-prefills
    traces = share.session_stats()["model"]["prefix_traces"]
    assert traces and all(tt < SHARED for tt, _ in traces)
    assert sum(r for _, r in traces) >= N_REQ - 1
    report = share.reports[-1]
    assert sum(s.shared_tokens for s in report.steps) == (N_REQ - 1) * SHARED
    assert sum(s.admit_tokens for s in report.steps) < \
        sum(s.admit_tokens for s in plain.reports[-1].steps)
    share._slot_scheduler._alloc.assert_no_leaks()


def test_preempting_a_fork_never_frees_sibling_pages(models):
    """A pool capped so a late arrival forces preemption while forked
    pages are live — and after the LEADER has already retired, so the
    shared pages survive on follower refcounts alone.  The preempted
    fork's siblings keep their prefix (outputs untouched), the requeued
    request resumes byte-identically, and the stream ends leak-free.
    free_row would raise on any double free."""
    t, d, pt, pd = models

    def run_with_late(**kw):
        kw.setdefault("kv_layout", "paged")
        kw.setdefault("page_size", 8)
        eng = _engine(t, d, pt, pd, seed=3, **kw)
        rng = np.random.default_rng(3)
        sys_toks = rng.integers(3, 500, size=SHARED)
        # leader (short budget) + two long-budget followers: the leader
        # retires first, leaving its 3 prefix pages alive only through
        # the followers' references
        uids = [eng.submit(
            np.concatenate([sys_toks, rng.integers(3, 500, size=TAIL)])
            .astype(np.int32), max_new_tokens=m, arrival_round=0)
            for m in (4, 10, 10)]
        # unrelated late LONG prompt (no shared prefix): needs 8 fresh
        # pages, the capped pool has at most 7 free (sharing saved 4) →
        # admission must preempt the youngest fork
        uids.append(eng.submit(rng.integers(3, 500, size=50)
                               .astype(np.int32), max_new_tokens=8,
                               arrival_round=4))
        eng.run()
        return eng, uids

    ref, ru = run_with_late(prefix_sharing=False)
    # the pool (pow2-sized at 16 for the initial three requests) is
    # capped at its initial size: no growth for the late arrival
    eng, uids = run_with_late(prefix_sharing=True,
                              resilience=ResilienceConfig(max_pool_pages=16))
    assert eng.fault_counters["prefix_hits"] >= 1
    assert eng.fault_counters["preemptions"] >= 1
    assert eng.fault_counters["requeues"] >= 1
    for a, b in zip(ru, uids):
        assert eng.done[b].finish_reason in ("length", "eos")
        np.testing.assert_array_equal(eng.done[b].output,
                                      ref.done[a].output)
    eng._slot_scheduler._alloc.assert_no_leaks()


def test_injected_page_exhaustion_with_sharing_recovers(models):
    """Scripted page-exhaustion holds (FaultInjector) while shared pages
    are live: admissions defer, nothing double-frees, and the stream
    still finishes byte-identical to an unshared, uninjected one."""
    t, d, pt, pd = models
    plain, pu = _shared_stream(t, d, pt, pd, sharing=False)
    inj = FaultInjector([Fault(round=1, kind="page_exhaustion",
                               hold_rounds=2)])
    eng, su = _shared_stream(t, d, pt, pd, sharing=True, fault_injector=inj)
    assert inj.injected["page_exhaustion"] >= 1
    for a, b in zip(pu, su):
        np.testing.assert_array_equal(plain.done[a].output,
                                      eng.done[b].output)
    eng._slot_scheduler._alloc.assert_no_leaks()


def test_pressure_admission_order_parity(models):
    """admission_order="pressure" reorders refills under a low free-page
    watermark but each request's greedy tokens never change."""
    t, d, pt, pd = models
    fifo, fu = _shared_stream(t, d, pt, pd, sharing=True)
    pres, qu = _shared_stream(t, d, pt, pd, sharing=True,
                              admission_order="pressure")
    for a, b in zip(fu, qu):
        np.testing.assert_array_equal(fifo.done[a].output,
                                      pres.done[b].output)


# ------------------------------------------------------------- construction
def test_misconfiguration_fails_at_construction(models):
    t, d, pt, pd = models
    with pytest.raises(ValueError, match="prefix_sharing"):
        _engine(t, d, pt, pd, prefix_sharing=True)          # dense KV
    with pytest.raises(ValueError, match="admission_order"):
        _engine(t, d, pt, pd, admission_order="lifo")
    with pytest.raises(ValueError, match="pressure"):
        _engine(t, d, pt, pd, admission_order="pressure")   # dense KV
    swa = Model(SWACFG)
    with pytest.raises(ValueError, match="cannot share"):
        _engine(swa, d, swa.init(jax.random.PRNGKey(2)), pd,
                kv_layout="paged", page_size=8, prefix_sharing=True)


# ------------------------------------------- satellite: offloading dry mode
def test_offloading_dry_mode(monkeypatch):
    """benchmarks/offloading.run(dry=True) is a cheap structural smoke:
    two batch points per configuration, validated finite rows with the
    expected names."""
    repo_root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    monkeypatch.syspath_prepend(repo_root)
    from benchmarks.offloading import DRY_BATCHES, run
    assert len(DRY_BATCHES) < 4                # dry really is small
    rows = run(dry=True)
    names = [r.split(",")[0] for r in rows]
    assert names == ["offload_hbm", "offload_offload_pcie64",
                     "offload_offload_pcie16", "offload_ep_chips1_B1",
                     "offload_ep_chips4_B1"]
    for r in rows[:3]:
        derived = dict(kv.split("=") for kv in r.split(",")[2].split(";"))
        assert float(derived["peak"]) > 0
        assert float(derived["B1"]) > 0

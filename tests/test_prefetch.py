"""Prefetch-aware proposer: parity, plan/warm shapes, stats plumbing.

The contracts the prefetch subsystem rests on:
  * wrapping a drafter with expert warming NEVER changes tokens — greedy
    outputs are identical to the wrapped "model" proposer and the AR
    baseline (the warm gather and the hit scoring are observation-only),
  * the router probe produces a static-shape PrefetchPlan (top-M experts
    per period-slot) and warm_experts gathers exactly those weights,
  * hit/miss counts flow end to end: moe_forward → extend_with_prefetch →
    SDStats → WaveReport → session_stats() aggregates,
  * `benchmarks/run --proposer prefetch` round-trips in dry mode (the lazy
    registry exposes the kind to argparse without importing the module).
"""
import os
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ModelConfig
from repro.core.prefetch import PrefetchProposer, router_probe
from repro.core.proposer import make_proposer, registered_proposers
from repro.core.spec_decode import SDEngine, generate_ar
from repro.models.model import Model
from repro.models.moe import (PrefetchPlan, init_moe, moe_forward,
                              prefetch_hit_stats, warm_experts)
from repro.serving.engine import ServingEngine

pytestmark = pytest.mark.tier1

TCFG = ModelConfig("pf-moe", "moe", 2, 128, 4, 2, 256, 512, num_experts=8,
                   num_experts_per_tok=2, dtype="float32")
DCFG = ModelConfig("pf-draft", "dense", 2, 64, 2, 2, 128, 512,
                   dtype="float32")


@pytest.fixture(scope="module")
def setup():
    t, d = Model(TCFG, moe_dispatch="gmm"), Model(DCFG)
    pt, pd = t.init(jax.random.PRNGKey(0)), d.init(jax.random.PRNGKey(7))
    prompts = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0, 512)
    return t, d, pt, pd, prompts


def test_prefetch_registered_and_lazy():
    assert "prefetch" in registered_proposers()


def test_prefetch_greedy_matches_model_and_ar(setup):
    """Warming is observation-only: token-identical to "model" and AR."""
    t, d, pt, pd, prompts = setup
    eng_pf = SDEngine(t, make_proposer("prefetch", t, d), gamma=3)
    out_pf, stats = eng_pf.generate(pt, pd, prompts, 14)
    eng_m = SDEngine(t, make_proposer("model", t, d), gamma=3)
    out_m, _ = eng_m.generate(pt, pd, prompts, 14)
    np.testing.assert_array_equal(out_pf, out_m)
    np.testing.assert_array_equal(out_pf, generate_ar(t, pt, prompts, 14))
    # and the observation actually happened
    assert stats.prefetch_actual > 0
    assert 0 <= stats.prefetch_hits <= stats.prefetch_actual
    assert stats.prefetch_misses == stats.prefetch_actual - stats.prefetch_hits


def test_router_probe_plan_and_warm_shapes(setup):
    t, d, pt, pd, prompts = setup
    cfg = t.cfg
    prop = make_proposer("prefetch", t, d)
    assert isinstance(prop, PrefetchProposer)
    assert prop.top_m == min(cfg.num_experts, 2 * cfg.num_experts_per_tok)
    plan = router_probe(pt, cfg, prompts[:, :4], top_m=prop.top_m)
    assert isinstance(plan, PrefetchPlan)
    P, E = cfg.num_periods, cfg.num_experts
    n_moe = 0
    for i, is_moe in enumerate(cfg.moe_pattern):
        assert plan.masks[i].shape == (P, E)
        if is_moe:
            n_moe += 1
            assert plan.expert_ids[i].shape == (P, prop.top_m)
            # each period warms exactly top_m distinct experts
            assert np.all(np.asarray(plan.masks[i]).sum(-1) == prop.top_m)
        else:
            assert plan.expert_ids[i].shape == (P, 0)
            assert not np.asarray(plan.masks[i]).any()
    warmed = warm_experts(pt["layers"], cfg, plan)
    assert len(warmed) == n_moe
    f = cfg.moe_d_ff
    for w in warmed:
        assert w["w_gate"].shape == (P, prop.top_m, cfg.d_model, f)
        assert w["w_down"].shape == (P, prop.top_m, f, cfg.d_model)


def test_hit_stats_exact():
    """moe_forward's prefetch metrics match a numpy recount."""
    cfg = TCFG
    p = init_moe(jax.random.PRNGKey(3), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(4), (1, 6, cfg.d_model),
                          jnp.float32)
    mask = jnp.asarray([True, False, True, False, True, False, True, False])
    y, m = moe_forward(p, cfg, x, dispatch="gmm", prefetch_mask=mask)
    assert y.shape == x.shape
    from repro.models.moe import router_topk
    _, idx, _ = router_topk(p, cfg, x.reshape(-1, cfg.d_model))
    actual = np.zeros(cfg.num_experts, bool)
    actual[np.asarray(idx).reshape(-1)] = True
    assert int(m["prefetch_actual"]) == actual.sum()
    assert int(m["prefetch_hits"]) == (actual & np.asarray(mask)).sum()
    assert int(m["prefetch_predicted"]) == 4
    # direct unit check of the scorer too
    s = prefetch_hit_stats(mask, idx, cfg.num_experts)
    assert int(s["prefetch_hits"]) == int(m["prefetch_hits"])


def test_wave_report_and_session_stats_aggregate(setup):
    """WaveReport carries hit/miss counts; session_stats() sums them."""
    t, d, pt, pd, _ = setup
    eng = ServingEngine(t, d, pt, pd, max_batch=2, gamma=2, force_sd=True,
                        proposer="prefetch")
    for _ in range(4):                                  # 2 waves of 2
        eng.submit(np.arange(3, 9), max_new_tokens=6)
    reports = eng.run()
    assert len(reports) == 2
    assert all(r.proposer == "prefetch" for r in reports)
    for r in reports:
        assert r.prefetch_hits + r.prefetch_misses == r.stats.prefetch_actual
        assert 0.0 <= r.prefetch_hit_rate <= 1.0
    assert sum(r.stats.prefetch_actual for r in reports) > 0
    stats = eng.session_stats()
    assert eng.session_constructions == {"prefetch": 1}
    agg = stats["prefetch"]["prefetch"]
    assert agg["hits"] == sum(r.prefetch_hits for r in reports)
    assert agg["actual"] == sum(r.stats.prefetch_actual for r in reports)
    assert agg["rounds"] == sum(r.stats.rounds for r in reports)
    assert agg["hit_rate"] == pytest.approx(
        agg["hits"] / max(agg["actual"], 1))


def test_proposer_opts_reach_the_session(setup):
    """ServingEngine(proposer_opts=...) parameterizes the factory — a tight
    warm budget (top_m) lands on the session's proposer and in the plans."""
    t, d, pt, pd, _ = setup
    eng = ServingEngine(t, d, pt, pd, max_batch=1, gamma=2, force_sd=True,
                        proposer="prefetch", proposer_opts={"top_m": 2})
    eng.submit(np.arange(3, 9), max_new_tokens=4)
    (report,) = eng.run()
    assert eng._sessions["prefetch"].proposer.top_m == 2
    # predicted = top_m * (#MoE layer instances) per round
    n_moe = sum(TCFG.moe_pattern) * TCFG.num_periods
    assert report.stats.prefetch_predicted == \
        report.stats.rounds * 2 * n_moe


def test_plain_model_waves_report_zero_prefetch(setup):
    """The accounting must not leak into non-prefetch proposers."""
    t, d, pt, pd, _ = setup
    eng = ServingEngine(t, d, pt, pd, max_batch=1, gamma=2, force_sd=True,
                        proposer="model")
    eng.submit(np.arange(3, 9), max_new_tokens=4)
    (report,) = eng.run()
    assert report.prefetch_hits == 0 and report.prefetch_misses == 0
    assert report.prefetch_hit_rate == 0.0
    assert eng.session_stats()["model"]["prefetch"]["actual"] == 0


def test_bench_run_dry_mode_roundtrip(monkeypatch, capsys):
    """--proposer prefetch is selectable and lands in benchmarks.common."""
    repo_root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    monkeypatch.syspath_prepend(repo_root)
    import benchmarks.common as common
    import benchmarks.run as bench_run
    old = common.DEFAULT_PROPOSER
    monkeypatch.setattr(sys, "argv",
                        ["run.py", "--proposer", "prefetch",
                         "--only", "zz_nothing_matches"])
    try:
        bench_run.main()                       # dry: every module filtered out
        assert common.DEFAULT_PROPOSER == "prefetch"
    finally:
        common.DEFAULT_PROPOSER = old
    out = capsys.readouterr().out
    assert "name,us_per_call,derived" in out
    assert "FAIL" not in out

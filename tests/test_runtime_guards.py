"""Runtime transfer & sharding-signature guards: the dynamic halves of the
S4xx host-boundary rules.

``transfer_guard()`` counts *implicit* host<->device transfers (a numpy
array silently fed to a jit program, a python scalar argument) while
explicit crossings — ``device_put``, ``jnp.asarray(np_array)``,
``np.asarray(dev_array)`` — stay free.  ``sharding_guard()`` wraps a warm
engine's cached jit programs and asserts each one sees exactly ONE input
sharding signature across a stream: the runtime proof of the
one-sharding-signature-per-program rule the static S403 check enforces at
the source level.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis import sharding_guard, transfer_guard
from repro.configs.base import ModelConfig
from repro.core.proposer import ModelProposer
from repro.core.spec_decode import SDEngine
from repro.models.model import Model
from repro.serving.engine import ServingEngine

pytestmark = pytest.mark.tier1

TCFG = ModelConfig("tg-moe", "moe", 2, 64, 4, 2, 128, 256, num_experts=4,
                   num_experts_per_tok=2, dtype="float32")
DCFG = ModelConfig("tg-draft", "dense", 2, 32, 2, 2, 64, 256,
                   dtype="float32")


@pytest.fixture(scope="module")
def models():
    t, d = Model(TCFG), Model(DCFG)
    return t, d, t.init(jax.random.PRNGKey(0)), d.init(jax.random.PRNGKey(1))


# ------------------------------------------------------- transfer_guard
def test_transfer_guard_counts_implicit_transfers():
    f = jax.jit(lambda x: x + 1)
    f(jnp.zeros((4,), jnp.float32))               # compile outside the guard
    with transfer_guard() as g:
        f(np.zeros((4,), np.float32))             # np array into jit: h2d
    assert g.count >= 1
    assert any("host-to-device" in ln for ln in g.lines)


def test_transfer_guard_clean_region_counts_zero():
    f = jax.jit(lambda x: x * 2)
    dev = jax.device_put(np.arange(4, dtype=np.float32))
    f(dev)                                        # warm
    with transfer_guard() as g:
        y = f(dev)                                # device-resident: free
        host = np.asarray(y)                      # explicit d2h: free
        dev2 = jax.device_put(host)               # explicit h2d: free
        f(dev2)
    assert g.count == 0, g.lines
    assert g.lines == []


def test_transfer_guard_count_is_live_then_frozen():
    f = jax.jit(lambda x: x - 1)
    f(jnp.zeros((2,), jnp.float32))
    with transfer_guard() as g:
        assert g.count == 0
        f(np.zeros((2,), np.float32))
        live = g.count
        assert live >= 1                          # visible while still open
    assert g.count == live                        # frozen at exit


def test_transfer_guard_disallow_raises_at_site():
    f = jax.jit(lambda x: x + 1)
    f(jnp.zeros((3,), jnp.float32))
    with pytest.raises(Exception, match="[Dd]isallowed"):
        with transfer_guard("disallow"):
            f(np.zeros((3,), np.float32))


# ------------------------------------------------------- sharding_guard
class _FakeEngine:
    """Minimal cache-bearing object: one cached program per dict."""

    def __init__(self):
        self._round_cache = {"r": jax.jit(lambda x: x + 1)}
        self._admit_cache = {}


def test_sharding_guard_single_signature_is_ok():
    eng = _FakeEngine()
    x = jax.device_put(np.arange(4, dtype=np.float32))
    with sharding_guard(eng) as g:
        eng._round_cache["r"](x)
        eng._round_cache["r"](x + 1)              # same aval, same sharding
    assert g.programs == 1 and g.ok
    assert "one sharding signature" in g.render()


def test_sharding_guard_equivalent_spellings_collapse():
    """Placements are compared by their device->slice maps, not by
    ``str(sharding)``: a ``SingleDeviceSharding`` and a replicated
    ``NamedSharding`` over a 1-device mesh are the SAME placement (jit
    would not specialize), so the guard must not flag them."""
    eng = _FakeEngine()
    mesh = jax.sharding.Mesh(np.asarray(jax.devices()[:1]), ("data",))
    named = jax.sharding.NamedSharding(mesh, jax.sharding.PartitionSpec())
    x = jax.device_put(np.arange(4, dtype=np.float32))
    with sharding_guard(eng) as g:
        eng._round_cache["r"](x)                  # SingleDeviceSharding
        eng._round_cache["r"](jax.device_put(x, named))   # NamedSharding
    assert g.ok, g.render()
    # original callables restored at exit
    assert not hasattr(eng._round_cache["r"], "__wrapped_guard__")


_SECOND_SIG = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
import jax, numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from repro.analysis import sharding_guard

class Eng:
    _round_cache = {"r": jax.jit(lambda x: x + 1)}

eng = Eng()
mesh = Mesh(np.asarray(jax.devices()).reshape(2), ("data",))
repl = NamedSharding(mesh, P())
split = NamedSharding(mesh, P("data"))
x = np.arange(4, dtype=np.float32)
with sharding_guard(eng) as g:
    eng._round_cache["r"](jax.device_put(x, repl))
    eng._round_cache["r"](jax.device_put(x, split))   # materially different
assert not g.ok, g.render()
(program, aval, shards), = g.violations
assert "r" in program and len(shards) == 2
assert "sharding signature" in g.render()
with sharding_guard(eng) as g2:                       # spelling-only delta
    eng._round_cache["r"](jax.device_put(x, repl))
    eng._round_cache["r"](jax.device_put(x, NamedSharding(mesh, P(None,))))
assert g2.ok, g2.render()
print("OK")
"""


def test_sharding_guard_detects_second_signature():
    """A program fed the same aval under two materially different
    placements (replicated vs split over a real 2-device axis) is a
    violation; an equivalent placement spelled differently is not.
    Needs >1 device, so it runs on forced host devices in a subprocess."""
    import subprocess
    import sys
    env = {"PYTHONPATH": "src", "PATH": "/usr/bin:/bin", "HOME": "/root",
           "JAX_PLATFORMS": "cpu"}
    res = subprocess.run([sys.executable, "-c", _SECOND_SIG],
                         capture_output=True, text=True, env=env,
                         timeout=300)
    assert res.returncode == 0, res.stdout + res.stderr
    assert "OK" in res.stdout


def test_sharding_guard_restores_cache_entries():
    eng = _FakeEngine()
    orig = eng._round_cache["r"]
    with sharding_guard(eng) as g:
        assert eng._round_cache["r"] is not orig  # wrapped inside
        eng._round_cache["r"](jnp.zeros((2,), jnp.float32))
    assert eng._round_cache["r"] is orig          # restored on exit
    assert g.programs == 1


# --------------------------------------- warm engines under both guards
def test_warm_sd_session_zero_transfers_one_signature(models):
    """A warm SDEngine session replays rounds with no implicit transfers
    and one sharding signature per cached program."""
    t, d, pt, pd = models
    eng = SDEngine(t, ModelProposer(t, d), gamma=2)
    prompts = jnp.asarray(np.tile(np.arange(3, 9), (2, 1)))
    state = eng.start(pt, pd, prompts, max_seq=48)
    for _ in range(2):                            # warm the round program
        state, _ = eng.round(state)
    with transfer_guard() as tg, sharding_guard(eng) as sg:
        for _ in range(3):
            state, _ = eng.round(state)
    assert tg.count == 0, (tg.count, tg.lines[:5])
    assert sg.programs > 0 and sg.ok, sg.render()


def test_warm_continuous_stream_zero_transfers_one_signature(models):
    """A second identical-shape stream through a warm continuous
    ServingEngine makes zero implicit host<->device transfers and keeps a
    single input-sharding signature on every cached program — the serving
    half of the ISSUE's runtime-guard acceptance (the sharded EP lane
    lives in test_expert_parallel.py)."""
    t, d, pt, pd = models
    eng = ServingEngine(t, d, pt, pd, max_batch=2, gamma=2, force_sd=True,
                        scheduler="continuous")
    for m in (3, 7, 5):
        eng.submit(np.arange(3, 9), max_new_tokens=m)
    eng.run()                                     # warm stream
    with transfer_guard() as tg, sharding_guard(eng) as sg:
        for m in (4, 6, 5):
            eng.submit(np.arange(3, 9), max_new_tokens=m)
        eng.run()
    assert tg.count == 0, (tg.count, tg.lines[:5])
    assert sg.programs > 0 and sg.ok, sg.render()

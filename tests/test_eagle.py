"""EAGLE speculation head: losslessness + feature-carry mechanics."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.eagle import EagleHead, EagleSpecDecoder
from repro.core.spec_decode import generate_ar
from repro.models.model import Model

TCFG = ModelConfig("eg-moe", "moe", 4, 128, 4, 2, 256, 512, num_experts=4,
                   num_experts_per_tok=2, dtype="float32")


def _setup():
    target = Model(TCFG)
    params_t = target.init(jax.random.PRNGKey(0))
    head = EagleHead(target)
    params_e = head.init(jax.random.PRNGKey(3))
    return target, params_t, head, params_e


def test_eagle_greedy_lossless():
    """Even an untrained Eagle head must be lossless (rejection sampling
    guarantees it; the head only affects HOW MANY tokens are accepted)."""
    target, params_t, head, params_e = _setup()
    prompts = jax.random.randint(jax.random.PRNGKey(1), (3, 8), 0, 512)
    sd = EagleSpecDecoder(target, head, gamma=3, temperature=0.0)
    out_sd, stats = sd.generate(params_t, params_e, prompts, 20)
    out_ar = generate_ar(target, params_t, prompts, 20)
    np.testing.assert_array_equal(out_sd, out_ar)
    assert stats.rounds >= 1


def test_eagle_ragged_prompts():
    target, params_t, head, params_e = _setup()
    B, T = 2, 10
    prompts = jax.random.randint(jax.random.PRNGKey(2), (B, T), 3, 512)
    lengths = jnp.array([5, 10], jnp.int32)
    sd = EagleSpecDecoder(target, head, gamma=2, temperature=0.0)
    out_sd, _ = sd.generate(params_t, params_e, prompts, 10, lengths=lengths)
    for b in range(B):
        ref = generate_ar(target, params_t,
                          prompts[b: b + 1, : int(lengths[b])], 10)
        np.testing.assert_array_equal(out_sd[b], ref[0])


def test_eagle_head_is_small():
    """Paper requirement: T_D/T_T ≪ 1 — the head is a small fraction of the
    target (here params; on equal hardware time follows bytes)."""
    target, params_t, head, params_e = _setup()
    n_t = sum(x.size for x in jax.tree.leaves(params_t))
    n_e = sum(x.size for x in jax.tree.leaves(params_e))
    assert n_e < 0.45 * n_t

"""Attention backends agree; cache semantics (ring, MLA, verify/commit)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.configs.base import ModelConfig
from repro.models.attention import _causal_mask, _sdpa, chunked_sdpa
from repro.models.model import Model


def test_chunked_matches_naive_causal():
    B, T, Hq, Hkv, D = 2, 64, 4, 2, 16
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, T, Hq, D))
    k = jax.random.normal(ks[1], (B, T, Hkv, D))
    v = jax.random.normal(ks[2], (B, T, Hkv, D))
    pos = jnp.broadcast_to(jnp.arange(T)[None], (B, T))
    ref = _sdpa(q, k, v, _causal_mask(pos, pos, 0), 0.25)
    out = chunked_sdpa(q, k, v, pos, pos, scale=0.25, chunk=16)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-5,
                               atol=2e-5)


def test_chunked_gradients_match_naive():
    B, T, Hq, Hkv, D = 1, 32, 2, 1, 8
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(ks[0], (B, T, Hq, D))
    k = jax.random.normal(ks[1], (B, T, Hkv, D))
    v = jax.random.normal(ks[2], (B, T, Hkv, D))
    pos = jnp.broadcast_to(jnp.arange(T)[None], (B, T))

    def loss_ref(q, k, v):
        return jnp.sum(jnp.tanh(_sdpa(q, k, v, _causal_mask(pos, pos, 8),
                                      0.3, 4.0)))

    def loss_chunked(q, k, v):
        return jnp.sum(jnp.tanh(chunked_sdpa(q, k, v, pos, pos, scale=0.3,
                                             window=8, logit_cap=4.0,
                                             chunk=8)))

    g1 = jax.grad(loss_ref, argnums=(0, 1, 2))(q, k, v)
    g2 = jax.grad(loss_chunked, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-4,
                                   atol=1e-4)


CFGS = {
    "dense": ModelConfig("c-dense", "dense", 2, 64, 4, 2, 128, 256,
                         dtype="float32"),
    "swa": ModelConfig("c-swa", "dense", 2, 64, 4, 2, 128, 256,
                       layer_pattern=("swa",), sliding_window=6,
                       dtype="float32"),
    "mla": ModelConfig("c-mla", "dense", 2, 64, 4, 4, 128, 256,
                       layer_pattern=("mla",), mla_kv_lora_rank=16,
                       mla_q_lora_rank=0, mla_qk_rope_dim=8,
                       mla_qk_nope_dim=16, mla_v_head_dim=16, head_dim=24,
                       dtype="float32"),
    "mamba": ModelConfig("c-mamba", "ssm", 2, 64, 4, 4, 128, 256,
                         layer_pattern=("mamba",), rope_type="none",
                         dtype="float32"),
}


@pytest.mark.parametrize("name", sorted(CFGS))
def test_decode_matches_teacher_forcing(name):
    """Prefill + T single decode steps reproduce the forward_train logits."""
    cfg = CFGS[name]
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    B, T0, T1 = 2, 6, 5
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, T0 + T1), 0, 256)
    full_logits, _ = model.forward_train(params, toks)
    cache = model.init_cache(B, T0 + T1 + 2)
    last, cache = model.prefill(params, toks[:, :T0], cache)
    np.testing.assert_allclose(np.asarray(last),
                               np.asarray(full_logits[:, T0 - 1]),
                               rtol=2e-4, atol=2e-4)
    for t in range(T1):
        lg, cache = model.decode_step(params, toks[:, T0 + t], cache)
        if t + 1 < T1:
            np.testing.assert_allclose(np.asarray(lg),
                                       np.asarray(full_logits[:, T0 + t]),
                                       rtol=2e-4, atol=2e-4)


@settings(max_examples=8, deadline=None)
@given(st.integers(0, 1000), st.integers(1, 4))
def test_verify_commit_equals_sequential(seed, gamma):
    """extend(T)+commit(n) == n single decode steps — for every n."""
    cfg = CFGS["swa"]
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    B, T0 = 2, 8
    key = jax.random.PRNGKey(seed)
    toks = jax.random.randint(key, (B, T0), 0, 256)
    drafts = jax.random.randint(jax.random.fold_in(key, 1), (B, gamma + 1),
                                0, 256)
    n_commit = jax.random.randint(jax.random.fold_in(key, 2), (B,), 1,
                                  gamma + 2)

    cache = model.init_cache(B, T0 + 16)
    _, cache = model.prefill(params, toks, cache)
    _, pend = model.extend(params, drafts, cache, collect=True)
    cacheA = model.commit(pend, n_commit, collected=True)

    cacheB = model.init_cache(B, T0 + 16)
    _, cacheB = model.prefill(params, toks, cacheB)
    for t in range(gamma + 1):
        # only advance sequences with n_commit > t: emulate by advancing all
        # then comparing only the final logits of a shared next token
        pass
    # compare next-token logits per sequence against a fresh prefix run
    probe = jnp.full((B, 1), 7, jnp.int32)
    lgA, _ = model.extend(params, probe, cacheA)
    for b in range(B):
        n = int(n_commit[b])
        prefix = jnp.concatenate([toks[b: b + 1], drafts[b: b + 1, :n]], 1)
        c = model.init_cache(1, T0 + 16)
        _, c = model.prefill(params, prefix, c)
        lgB, _ = model.extend(params, probe[:1], c)
        np.testing.assert_allclose(np.asarray(lgA[b]), np.asarray(lgB[0]),
                                   rtol=3e-4, atol=3e-4)

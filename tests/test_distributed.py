"""Distributed layer: sharding rules + a REAL reduced dry-run on a 4-device
host mesh (subprocess, so the 1-device test environment stays intact)."""
import json
import subprocess
import sys
import textwrap

import pytest

from repro.configs.registry import get_config

# ---------------------------------------------------------------------------
# pure rule tests (no devices needed)
# ---------------------------------------------------------------------------

def test_param_spec_rules():
    import jax
    from jax.sharding import PartitionSpec as P
    from repro.distributed.sharding import param_spec
    mesh = jax.make_mesh((1, 1), ("data", "model"))

    # expert weights: EP over model on the expert axis (+FSDP on d_ff)
    s = param_spec("layers/0/ffn/w_gate", (4, 16, 64, 128), mesh=mesh,
                   fsdp=True, stacked=True)
    assert s == P(None, "model", None, ("data",))
    # attention out-proj: row-parallel
    s = param_spec("layers/1/mixer/wo", (4, 256, 128), mesh=mesh, fsdp=False,
                   stacked=True)
    assert s == P(None, "model", None)
    # norms replicated
    s = param_spec("final_norm/scale", (128,), mesh=mesh, fsdp=False,
                   stacked=False)
    assert s == P(None)


def test_fsdp_layout_rules():
    """layout="fsdp": dense weights shard over ALL axes (no TP); MoE expert
    weights keep the expert axis on "model" (EP) + FSDP over data."""
    import jax
    from jax.sharding import PartitionSpec as P
    from repro.distributed.sharding import param_spec
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    s = param_spec("layers/0/mixer/wq", (4, 256, 128), mesh=mesh, fsdp=True,
                   stacked=True, layout="fsdp")
    assert s == P(None, ("data", "model"), None)
    s = param_spec("layers/0/ffn/w_gate", (4, 16, 64, 128), mesh=mesh,
                   fsdp=True, stacked=True, layout="fsdp")
    assert s == P(None, "model", None, ("data",))
    # dense FFN (2D leaf, same ffn/ path) loses TP under fsdp layout
    s = param_spec("layers/0/ffn/w_down", (4, 128, 64), mesh=mesh, fsdp=True,
                   stacked=True, layout="fsdp")
    assert s == P(None, ("data", "model"), None)


def test_rank_disambiguates_dense_vs_expert_ffn():
    """Dense FFN leaves share ffn/w_* paths with expert weights; rule
    selection is rank-aware (2D dense vs 3D experts)."""
    import jax
    from jax.sharding import PartitionSpec as P
    from repro.distributed.sharding import param_spec
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    dense = param_spec("layers/0/ffn/w_gate", (4, 64, 128), mesh=mesh,
                       fsdp=True, stacked=True)         # (P, d, f) — dense
    assert dense == P(None, ("data",), "model")          # column parallel
    expert = param_spec("layers/0/ffn/w_gate", (4, 16, 64, 128), mesh=mesh,
                        fsdp=True, stacked=True)         # (P, E, d, f)
    assert expert == P(None, "model", None, ("data",))   # expert parallel


def test_fit_drops_nondivisible():
    from jax.sharding import AbstractMesh, PartitionSpec as P
    from repro.distributed.sharding import _fit
    try:
        mesh = AbstractMesh((2,), ("model",))
    except TypeError:   # jax<=0.4.x signature: tuple of (name, size) pairs
        mesh = AbstractMesh((("model", 2),))
    assert _fit(mesh, P("model"), (7,)) == P(None)
    assert _fit(mesh, P("model"), (8,)) == P("model")


# ---------------------------------------------------------------------------
# subprocess integration: reduced configs on a forced 4-device host platform
# ---------------------------------------------------------------------------

_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import json, sys
    import jax, jax.numpy as jnp
    import numpy as np
    from repro.configs.base import ShapeConfig, TrainConfig
    from repro.configs.registry import get_config
    from repro.distributed import sharding as sh
    from repro.models.model import Model
    from repro.training.optimizer import init_adam
    from repro.training.train_loop import make_train_step
    from repro.serving.serve_step import make_verify_step

    arch = sys.argv[1]
    mesh = jax.make_mesh((2, 2), ("data", "model"))
    cfg = get_config(arch, reduced=True)
    model = Model(cfg, mesh=mesh)
    params = model.init(jax.random.PRNGKey(0))
    psh = sh.shard_params(params, mesh, fsdp=True)
    params = jax.device_put(params, psh)
    opt = jax.device_put(init_adam(params), sh.shard_opt_state(
        init_adam(params), psh, mesh))
    B, T = 4, 16
    batch = {"tokens": jnp.zeros((B, T), jnp.int32) + 3,
             "labels": jnp.zeros((B, T), jnp.int32) + 4,
             "mask": jnp.ones((B, T), jnp.float32)}
    if cfg.is_encoder_decoder:
        batch["encoder_embeds"] = jnp.zeros(
            (B, cfg.encoder_seq_len, cfg.d_model), jnp.dtype(cfg.dtype))
    bsh = sh.batch_sharding(mesh, batch)
    batch = jax.device_put(batch, bsh)
    with mesh:
        step = jax.jit(make_train_step(model, TrainConfig()),
                       in_shardings=(psh, sh.shard_opt_state(opt, psh, mesh),
                                     bsh))
        params2, opt2, metrics = step(params, opt, batch)
        loss = float(metrics["loss"])
        assert np.isfinite(loss), loss

        # verify-step (SD decode) with sharded cache — actually EXECUTES
        cache = model.init_cache(B, T + 8)
        csh = sh.shard_cache(cache, mesh)
        cache = jax.device_put(cache, csh)
        pkw = ({"encoder_embeds": batch["encoder_embeds"]}
               if cfg.is_encoder_decoder else {})
        _, cache = model.prefill(params, batch["tokens"], cache, **pkw)
        vstep = jax.jit(make_verify_step(model, 3))
        logits, cache = vstep(params, jnp.zeros((B, 4), jnp.int32) + 5,
                              jnp.ones((B,), jnp.int32) * 2, cache)
        assert np.isfinite(np.asarray(logits, np.float32)).all()
    print(json.dumps({"ok": True, "loss": loss}))
""")


@pytest.mark.parametrize("arch", ["qwen2-57b-a14b", "jamba-v0.1-52b",
                                  "gemma3-12b", "whisper-base"])
def test_reduced_mesh_execution(arch):
    """Sharded train step + SD verify step EXECUTE on a 2x2 host mesh."""
    proc = subprocess.run(
        [sys.executable, "-c", _SCRIPT, arch],
        capture_output=True, text=True, timeout=420,
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
             "HOME": "/root", "JAX_PLATFORMS": "cpu"},
        cwd="/root/repo")
    assert proc.returncode == 0, proc.stderr[-3000:]
    out = json.loads(proc.stdout.strip().splitlines()[-1])
    assert out["ok"]

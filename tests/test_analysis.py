"""Analyzer subsystem: fixture detection, false-positive guard, waivers,
ratchet baseline, CLI contract, and the dogfood check that the shipped
serving stack passes its own lint.
"""
import json
import os
import subprocess
import sys

import pytest

from repro.analysis import CODES, analyze_paths
from repro.analysis.findings import (Finding, apply_waivers, load_baseline,
                                     parse_waivers, ratchet, write_baseline)

pytestmark = pytest.mark.tier1

HERE = os.path.dirname(os.path.abspath(__file__))
REPO = os.path.dirname(HERE)
FIX = os.path.join(HERE, "fixtures", "lint")


def _run(name):
    return analyze_paths([os.path.join(FIX, name)], repo_root=REPO)


# --------------------------------------------------------------- fixtures
def test_tracer_fixture_codes_and_lines():
    got = {(f.code, f.line) for f in _run("tracer_bad.py")}
    assert got == {("T101", 9), ("T102", 11), ("T103", 13), ("T104", 14),
                   ("T105", 15), ("T107", 16), ("T108", 17), ("T106", 26)}


def test_cache_key_fixture_codes_and_lines():
    got = {(f.code, f.line) for f in _run("cache_key_bad.py")}
    # K202 and T101 both fire on the trace-time branch: the tracer pass
    # says "this branches on a tracer", the cache pass says "so it must be
    # static" — complementary diagnoses of the same line
    assert got == {("K201", 13), ("K202", 17), ("T101", 17), ("K203", 21),
                   ("K205", 22), ("K204", 31)}


def test_pallas_fixture_codes_and_lines():
    findings = _run("pallas_bad.py")
    got = {(f.code, f.line) for f in findings}
    assert got == {("P304", 16), ("P301", 19), ("P303", 19),
                   ("P302", 27), ("P305", 27)}
    # both block dims of the 7x100 spec are off: 100 % 128 and 7 % 8
    assert sum(1 for f in findings if f.code == "P303") == 2


def test_sharding_fixture_codes_and_lines():
    got = {(f.code, f.line) for f in _run("sharding_bad.py")}
    assert got == {("S401", 25), ("S402", 30), ("S402", 41), ("S403", 63),
                   ("S404", 68), ("S404", 72), ("S405", 77)}


def test_prng_fixture_codes_and_lines():
    got = {(f.code, f.line) for f in _run("prng_bad.py")}
    assert got == {("R501", 8), ("R501", 14), ("R502", 20), ("R502", 25),
                   ("R503", 32), ("R504", 38), ("R501", 49)}


def test_donation_fixture_codes_and_lines():
    got = {(f.code, f.line) for f in _run("donation_bad.py")}
    assert got == {("D601", 13), ("D603", 17), ("D603", 21)}


def test_clean_fixture_has_no_false_positives():
    assert _run("clean.py") == []


def test_src_tree_is_clean():
    """Dogfood: the shipped serving stack passes its own lint (intentional
    trace-time counters carry inline waivers, nothing else)."""
    assert analyze_paths([os.path.join(REPO, "src", "repro")],
                         repo_root=REPO) == []


# ----------------------------------------------------------- waiver model
def test_waiver_suppresses_only_named_code_nearby():
    src = ("x = 1\n"
           "y = 2  # lint: allow[T103] trusted host boundary\n"
           "z = 3\n")
    waivers = {"m.py": parse_waivers(src)}
    f_hit = Finding("m.py", 2, "T103", "a")
    f_below = Finding("m.py", 3, "T103", "b")     # line under the waiver
    f_other = Finding("m.py", 2, "T101", "c")     # different code
    f_far = Finding("m.py", 1, "T103", "d")
    kept = apply_waivers([f_hit, f_below, f_other, f_far], waivers)
    assert kept == [f_other, f_far]


def test_waiver_without_reason_is_w001():
    waivers = {"m.py": parse_waivers("y = 2  # lint: allow[T103]\n")}
    kept = apply_waivers([Finding("m.py", 1, "T103", "a")], waivers)
    assert [f.code for f in kept] == ["W001"]


# -------------------------------------------------------- ratchet baseline
def test_ratchet_roundtrip_and_stale_detection(tmp_path):
    base = str(tmp_path / "baseline.txt")
    old = Finding("a.py", 3, "T101", "legacy branch")
    write_baseline(base, [old])
    entries = load_baseline(base)
    assert old.fingerprint in entries

    # same finding on a DIFFERENT line still matches (fingerprint is
    # line-free); a brand-new finding does not; a fixed one goes stale
    moved = Finding("a.py", 99, "T101", "legacy branch")
    fresh = Finding("a.py", 5, "T103", "new coercion")
    rep = ratchet([moved, fresh], entries)
    assert rep.baselined == [moved] and rep.new == [fresh] and not rep.ok
    rep2 = ratchet([fresh], entries)
    assert rep2.stale and not rep2.ok


def test_shipped_baseline_is_empty():
    """The repo ships with every finding fixed or inline-waived; the
    ratchet file exists only as the mechanism for future debt."""
    assert load_baseline(os.path.join(REPO, "scripts",
                                      "lint_baseline.txt")) == {}


# ------------------------------------------------------------ CLI contract
def _cli(*args):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    return subprocess.run([sys.executable, "-m", "repro.analysis", *args],
                          capture_output=True, text=True, cwd=REPO, env=env)


def test_cli_exit_codes_and_json():
    bad = os.path.join(FIX, "tracer_bad.py")
    r = _cli(bad, "--no-baseline", "--json")
    assert r.returncode == 1
    payload = json.loads(r.stdout)
    assert not payload["ok"]
    assert {f["code"] for f in payload["new"]} >= {"T101", "T103"}
    ok = _cli(os.path.join(FIX, "clean.py"), "--no-baseline")
    assert ok.returncode == 0


def test_cli_json_reports_per_pass_counts():
    """The JSON report carries a per-pass breakdown with a stable key set
    covering every pass, zero or not."""
    r = _cli(os.path.join(FIX, "sharding_bad.py"),
             os.path.join(FIX, "prng_bad.py"),
             os.path.join(FIX, "donation_bad.py"), "--no-baseline", "--json")
    per = json.loads(r.stdout)["per_pass"]
    assert set(per) == {"tracer_lint", "cache_keys", "pallas_lint",
                        "sharding_lint", "prng_lint", "donation_lint",
                        "waivers"}
    assert per["sharding_lint"] == 7
    assert per["prng_lint"] == 7
    assert per["donation_lint"] == 3
    assert per["tracer_lint"] == 0


def test_baseline_stable_under_line_drift(tmp_path):
    """Baseline a file, then push every finding down 7 lines: the ratchet
    still reports clean because fingerprints are line-free."""
    import shutil
    target = str(tmp_path / "prng_bad.py")
    base = str(tmp_path / "b.txt")
    shutil.copy(os.path.join(FIX, "prng_bad.py"), target)
    before = analyze_paths([target], repo_root=str(tmp_path))
    assert before, "fixture must produce findings"
    write_baseline(base, before)
    with open(target) as fh:
        src = fh.read()
    with open(target, "w") as fh:
        fh.write("# drift\n" * 7 + src)      # every finding moves 7 lines
    after = analyze_paths([target], repo_root=str(tmp_path))
    assert {f.line for f in after} != {f.line for f in before}
    rep = ratchet(after, load_baseline(base))
    assert rep.ok and not rep.new and not rep.stale


def test_cli_update_baseline(tmp_path):
    base = str(tmp_path / "b.txt")
    bad = os.path.join(FIX, "tracer_bad.py")
    assert _cli(bad, "--update-baseline", "--baseline", base).returncode == 0
    r = _cli(bad, "--baseline", base)
    assert r.returncode == 0                      # everything baselined
    assert "0 new finding(s)" in r.stdout


# ------------------------------------------------------------------- docs
def test_docs_list_every_finding_code():
    with open(os.path.join(REPO, "docs", "analysis.md")) as fh:
        text = fh.read()
    missing = [c for c in CODES if c not in text]
    assert not missing, f"docs/analysis.md missing codes: {missing}"

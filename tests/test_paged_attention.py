"""Paged decode-attention kernel: differential parity at every level.

The block-table-walking Pallas kernel (kernels/decode_attention/) must be
numerically indistinguishable from the dense ``pool[table]`` gather it
replaces AND from a contiguous dense cache — at the kernel level (vs the
jnp oracles, across page sizes, ragged lengths, verify widths, logit
caps, shared tables and grown pools) and at the token level (greedy SD
rounds commit identical tokens through ``SDEngine`` under kernel /
gather / dense caches, including SWA ring layers and mid-stream pool
growth).  docs/paged_attention.md specifies the contract.
"""
from dataclasses import replace as dc_replace

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.configs.base import ModelConfig
from repro.core.proposer import ModelProposer
from repro.core.spec_decode import SDEngine
from repro.kernels.decode_attention.ops import (decode_attention,
                                                paged_decode_attention)
from repro.kernels.decode_attention.ref import (decode_attention_ref,
                                                paged_decode_attention_ref)
from repro.models.model import Model, PageAllocator

pytestmark = pytest.mark.tier1

B, HQ, HKV, D, MP = 3, 4, 2, 16, 4


def _paged_case(seed: int, ps: int, T: int):
    """Random pool + bijective table + ragged lengths; every physical
    page (trash page included) is noise, so any unmasked stale read
    shows up as a mismatch against the dense oracle."""
    rng = np.random.default_rng(seed)
    pool_n = B * MP + 1
    k_pages = rng.normal(size=(pool_n, ps, HKV, D)).astype(np.float32)
    v_pages = rng.normal(size=(pool_n, ps, HKV, D)).astype(np.float32)
    table = rng.permutation(np.arange(1, pool_n)).reshape(B, MP)
    lengths = rng.integers(0, MP * ps - T + 1, size=B).astype(np.int32)
    q = rng.normal(size=(B, T, HQ, D)).astype(np.float32)
    return q, k_pages, v_pages, lengths, table.astype(np.int32)


def _gathered(pool: np.ndarray, table: np.ndarray) -> np.ndarray:
    """The dense (B, MP*ps, Hkv, D) view the gather fallback attends."""
    g = pool[table]                                   # (B, MP, ps, Hkv, D)
    return g.reshape(B, -1, *pool.shape[2:])


def _all_four(q, k_pages, v_pages, lengths, table, cap):
    """(kernel, paged oracle, dense kernel, dense oracle) outputs."""
    kv = [jnp.asarray(x) for x in (q, k_pages, v_pages, lengths, table)]
    out_kernel = paged_decode_attention(*kv, logit_cap=cap, interpret=True)
    qh = kv[0].transpose(0, 2, 1, 3)
    ref_paged = paged_decode_attention_ref(
        qh, kv[1], kv[2], kv[3], kv[4], logit_cap=cap).transpose(0, 2, 1, 3)
    k_view = jnp.asarray(_gathered(k_pages, table))
    v_view = jnp.asarray(_gathered(v_pages, table))
    out_dense = decode_attention(kv[0], k_view, v_view, kv[3],
                                 logit_cap=cap, interpret=True)
    ref_dense = decode_attention_ref(
        qh, k_view.transpose(0, 2, 1, 3), v_view.transpose(0, 2, 1, 3),
        kv[3], logit_cap=cap).transpose(0, 2, 1, 3)
    return [np.asarray(o) for o in (out_kernel, ref_paged, out_dense,
                                    ref_dense)]


@settings(max_examples=6, deadline=None)
@given(st.integers(0, 10_000), st.sampled_from([8, 16, 64]),
       st.sampled_from([1, 2, 5]), st.booleans())
def test_kernel_matches_gather_and_dense(seed, ps, T, capped):
    """Property: kernel ≡ paged oracle ≡ dense kernel ≡ dense oracle on
    random pools across page sizes, verify widths and logit caps."""
    case = _paged_case(seed, ps, T)
    outs = _all_four(*case, cap=4.0 if capped else 0.0)
    for other in outs[1:]:
        np.testing.assert_allclose(outs[0], other, rtol=2e-5, atol=2e-5)


def test_kernel_reads_forked_tables_and_masks_stale_pages():
    """Prefix-sharing shape: rows 1..B-1 alias row 0's first two pages
    (a forked table is many-to-one, not a permutation), and pages beyond
    each row's live length hold huge garbage — parity with the oracle
    plus invariance to the garbage proves the masking contract that
    makes CoW-shared pages safe to read through any row's table."""
    ps, T = 8, 2
    q, k_pages, v_pages, lengths, table = _paged_case(3, ps, T)
    table = table.copy()
    table[1:, :2] = table[0, :2]                      # forked prefix pages
    lengths = np.array([2 * ps + 3, ps + 1, 2 * ps], np.int32)

    outs = _all_four(q, k_pages, v_pages, lengths, table, cap=0.0)
    for other in outs[1:]:
        np.testing.assert_allclose(outs[0], other, rtol=2e-5, atol=2e-5)

    # poison every position past length + T - 1 (per row, via its table)
    # and the trash page; the kernel's output must not move
    pk, pv = k_pages.copy(), v_pages.copy()
    pk[0], pv[0] = 1e3, -1e3
    for b in range(B):
        first_dead = int(lengths[b]) + T
        for lp in range(MP):
            page = table[b, lp]
            lo = max(0, first_dead - lp * ps)
            if lo < ps and page not in table[0, :2]:  # keep shared live
                pk[page, lo:], pv[page, lo:] = 1e3, -1e3
    poisoned = paged_decode_attention(
        jnp.asarray(q), jnp.asarray(pk), jnp.asarray(pv),
        jnp.asarray(lengths), jnp.asarray(table), interpret=True)
    np.testing.assert_allclose(np.asarray(poisoned), outs[0],
                               rtol=2e-5, atol=2e-5)


def test_kernel_invariant_under_pool_growth():
    """grow_cache_pages pads the pool with fresh physical pages and the
    table with trash entries; neither may perturb a live row's output."""
    ps, T = 16, 3
    q, k_pages, v_pages, lengths, table = _paged_case(11, ps, T)
    before = paged_decode_attention(
        jnp.asarray(q), jnp.asarray(k_pages), jnp.asarray(v_pages),
        jnp.asarray(lengths), jnp.asarray(table), interpret=True)
    rng = np.random.default_rng(12)
    extra = rng.normal(size=k_pages.shape).astype(np.float32)
    grown_k = np.concatenate([k_pages, extra])
    grown_v = np.concatenate([v_pages, -extra])
    grown_tbl = np.pad(table, ((0, 0), (0, MP)))      # new entries → trash
    after = paged_decode_attention(
        jnp.asarray(q), jnp.asarray(grown_k), jnp.asarray(grown_v),
        jnp.asarray(lengths), jnp.asarray(grown_tbl), interpret=True)
    np.testing.assert_allclose(np.asarray(after), np.asarray(before),
                               rtol=2e-5, atol=2e-5)


# ------------------------------------------------- token-level parity (SD)
TCFG = ModelConfig("pa-moe", "moe", 2, 128, 4, 2, 256, 512, num_experts=4,
                   num_experts_per_tok=2, dtype="float32")
SWACFG = ModelConfig("pa-swa", "dense", 2, 64, 4, 2, 128, 512,
                     layer_pattern=("attn", "swa"), sliding_window=6,
                     dtype="float32")
DCFG = ModelConfig("pa-draft", "dense", 2, 64, 2, 2, 128, 512,
                   dtype="float32")

PS, POOL_MP = 8, 4                                    # max_seq = 32


@pytest.fixture(scope="module")
def draft():
    d = Model(DCFG)
    return d, d.init(jax.random.PRNGKey(1))


def _token_trace(tcfg, draft_pair, *, paged_attention, paged, gamma,
                 rounds=4, grow_at=None):
    """Greedy committed-token trace over ``rounds`` SD rounds (fixed
    keys), optionally growing the paged pool mid-stream."""
    d, pd = draft_pair
    t = Model(tcfg, paged_attention=paged_attention)
    pt = t.init(jax.random.PRNGKey(0))
    eng = SDEngine(t, ModelProposer(t, d), gamma=max(gamma, 1))
    prompts = jnp.asarray(np.tile(np.arange(3, 9), (2, 1)))
    max_seq = POOL_MP * PS
    if paged:
        alloc = PageAllocator(2, PS, 2 * POOL_MP + 1, POOL_MP)
        for b in range(2):
            alloc.alloc(b, max_seq)
        state = eng.start(pt, pd, prompts, max_seq=max_seq,
                          key=jax.random.PRNGKey(7),
                          cache_opts={"paged": True, "page_size": PS,
                                      "pool_pages": alloc.pool_pages},
                          page_table=jnp.asarray(alloc.table))
    else:
        state = eng.start(pt, pd, prompts, max_seq=2 * max_seq,
                          key=jax.random.PRNGKey(7))
    trace = [np.asarray(state.last_token).copy()]
    for r in range(rounds):
        if paged and grow_at == r:
            state = eng.grow_session(state, 2 * max_seq,
                                     pool_pages=2 * alloc.pool_pages,
                                     max_pages=2 * POOL_MP)
            alloc.grow(2 * alloc.pool_pages, 2 * POOL_MP)
            for b in range(2):
                alloc.extend_row(b, 2 * max_seq)
            pages = dict(state.t_cache["pages"],
                         table=jnp.asarray(alloc.table))
            state = dc_replace(state,
                               t_cache=dict(state.t_cache, pages=pages))
        state, res = eng.round(state, gamma=gamma,
                               key=jax.random.PRNGKey(100 + r))
        for b in range(2):
            trace.append(res.committed[b, : res.n_commit[b]].copy())
    return trace


@pytest.mark.parametrize("gamma", [0, 1, 4])
def test_sd_rounds_token_identical_kernel_gather_dense(draft, gamma):
    """Exact greedy-token equality through whole SD rounds: the paged
    kernel, the gather fallback and a dense cache commit the SAME tokens
    at every round, for AR (gamma=0), minimal and wide speculation."""
    kernel = _token_trace(TCFG, draft, paged_attention="kernel",
                          paged=True, gamma=gamma)
    gather = _token_trace(TCFG, draft, paged_attention="gather",
                          paged=True, gamma=gamma)
    dense = _token_trace(TCFG, draft, paged_attention="kernel",
                         paged=False, gamma=gamma)
    for a, b, c in zip(kernel, gather, dense):
        np.testing.assert_array_equal(a, b)
        np.testing.assert_array_equal(a, c)


def test_sd_rounds_token_identical_swa_rows(draft):
    """Mixed attn+swa stacks: SWA layers keep their dense ring rows in a
    paged cache (they never enter the kernel), attn layers take the
    kernel — tokens still match gather and dense exactly."""
    kernel = _token_trace(SWACFG, draft, paged_attention="kernel",
                          paged=True, gamma=2)
    gather = _token_trace(SWACFG, draft, paged_attention="gather",
                          paged=True, gamma=2)
    dense = _token_trace(SWACFG, draft, paged_attention="kernel",
                         paged=False, gamma=2)
    for a, b, c in zip(kernel, gather, dense):
        np.testing.assert_array_equal(a, b)
        np.testing.assert_array_equal(a, c)


def test_sd_rounds_token_identical_across_growth(draft):
    """Mid-stream pool growth (grow_session + allocator extend): the
    grown kernel session stays token-identical to the grown gather
    session AND to a dense session sized for the final capacity."""
    kernel = _token_trace(TCFG, draft, paged_attention="kernel",
                          paged=True, gamma=2, rounds=6, grow_at=3)
    gather = _token_trace(TCFG, draft, paged_attention="gather",
                          paged=True, gamma=2, rounds=6, grow_at=3)
    dense = _token_trace(TCFG, draft, paged_attention="kernel",
                         paged=False, gamma=2, rounds=6)
    for a, b, c in zip(kernel, gather, dense):
        np.testing.assert_array_equal(a, b)
        np.testing.assert_array_equal(a, c)

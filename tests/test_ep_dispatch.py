"""Expert-parallel shard_map dispatch == dense one-hot dispatch (subprocess
with a forced 4-device mesh)."""
import subprocess
import sys
import textwrap
import pytest

pytestmark = pytest.mark.tier1

_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import jax, jax.numpy as jnp
    import numpy as np
    from repro.configs.base import ModelConfig
    from repro.models.moe import init_moe, moe_forward

    mesh = jax.make_mesh((2, 2), ("data", "model"))
    cfg = ModelConfig("ep", "moe", 2, 64, 4, 2, 128, 256, num_experts=8,
                      num_experts_per_tok=2, moe_d_ff=128, dtype="float32",
                      num_shared_experts=1)
    p = init_moe(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 16, 64)) * 0.5
    y_ref, _ = moe_forward(p, cfg, x, dispatch="onehot")
    y_ep, _ = jax.jit(lambda p, x: moe_forward(p, cfg, x, dispatch="ep",
                                               mesh=mesh))(p, x)
    np.testing.assert_allclose(np.asarray(y_ref), np.asarray(y_ep),
                               rtol=3e-4, atol=3e-4)
    # metrics path too
    y_ep2, m = moe_forward(p, cfg, x, dispatch="ep", mesh=mesh,
                           return_metrics=True)
    assert m["expert_counts"].sum() == 4 * 16 * 2
    print("OK")
""")


def test_ep_matches_onehot():
    proc = subprocess.run(
        [sys.executable, "-c", _SCRIPT], capture_output=True, text=True,
        timeout=300,
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin", "HOME": "/root",
             "JAX_PLATFORMS": "cpu"},
        cwd="/root/repo")
    assert proc.returncode == 0, proc.stderr[-2000:]
    assert "OK" in proc.stdout


_A2A_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import jax, jax.numpy as jnp
    import numpy as np
    from repro.configs.base import ModelConfig
    from repro.models.moe import init_moe, moe_forward

    mesh = jax.make_mesh((2, 2), ("data", "model"))
    cfg = ModelConfig("ep", "moe", 2, 64, 4, 2, 128, 256, num_experts=8,
                      num_experts_per_tok=2, moe_d_ff=128, dtype="float32")
    p = init_moe(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 16, 64)) * 0.5
    y_ref, _ = moe_forward(p, cfg, x, dispatch="onehot")
    # fsdp layout: tokens sharded over model too → the a2a path
    y_a2a, _ = jax.jit(lambda p, x: moe_forward(p, cfg, x, dispatch="ep",
                                                mesh=mesh,
                                                mesh_layout="fsdp"))(p, x)
    np.testing.assert_allclose(np.asarray(y_ref), np.asarray(y_a2a),
                               rtol=3e-4, atol=3e-4)
    print("OK")
""")


def test_a2a_ep_matches_onehot_under_fsdp_layout():
    """Two-hop all-to-all EP (§Perf C5) is numerically exact."""
    proc = subprocess.run(
        [sys.executable, "-c", _A2A_SCRIPT], capture_output=True, text=True,
        timeout=300,
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin", "HOME": "/root",
             "JAX_PLATFORMS": "cpu"},
        cwd="/root/repo")
    assert proc.returncode == 0, proc.stderr[-2000:]
    assert "OK" in proc.stdout

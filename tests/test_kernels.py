"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps in interpret mode."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.decode_attention.decode_attention import decode_attention_bhtd
from repro.kernels.decode_attention.ref import decode_attention_ref
from repro.kernels.flash_attention.flash_attention import flash_attention_bhtd
from repro.kernels.flash_attention.ref import flash_attention_ref
from repro.kernels.gmm.gmm import gmm_capacity
from repro.kernels.gmm.ops import expert_capacity, gmm, moe_ffn_gmm
from repro.kernels.gmm.ref import dispatch_ref, gmm_capacity_ref, moe_ffn_ref


# ---------------------------------------------------------------- gmm kernel

@pytest.mark.parametrize("E,C,D,F", [(2, 128, 256, 128), (4, 256, 512, 384),
                                     (1, 128, 1024, 256), (8, 128, 128, 128)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_gmm_capacity_matches_ref(E, C, D, F, dtype):
    k1, k2 = jax.random.split(jax.random.PRNGKey(0))
    x = jax.random.normal(k1, (E, C, D), dtype)
    w = jax.random.normal(k2, (E, D, F), dtype)
    out = gmm_capacity(x, w, interpret=True)
    ref = gmm_capacity_ref(x, w)
    tol = 1e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), rtol=tol, atol=tol)


def test_gmm_sorted_groups_exact():
    """Ragged sorted-token gmm == per-group matmul."""
    E, D, F = 3, 64, 32
    sizes = jnp.array([5, 0, 11])
    N = int(sizes.sum())
    k1, k2 = jax.random.split(jax.random.PRNGKey(1))
    xs = jax.random.normal(k1, (N, D))
    w = jax.random.normal(k2, (E, D, F))
    out = gmm(xs, w, sizes, interpret=True)
    ref = jnp.concatenate([xs[0:5] @ w[0], xs[5:16] @ w[2]])
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-5,
                               atol=2e-5)


def test_moe_ffn_gmm_vs_onehot_ref():
    """Full kernel-backed MoE FFN vs the exact one-hot reference; with ample
    capacity no tokens drop and results agree."""
    N, D, F, E, K = 64, 32, 48, 4, 2
    keys = jax.random.split(jax.random.PRNGKey(2), 6)
    x = jax.random.normal(keys[0], (N, D))
    wg = jax.random.normal(keys[1], (E, D, F)) / np.sqrt(D)
    wu = jax.random.normal(keys[2], (E, D, F)) / np.sqrt(D)
    wd = jax.random.normal(keys[3], (E, F, D)) / np.sqrt(F)
    logits = jax.random.normal(keys[4], (N, E))
    w, idx = jax.lax.top_k(jax.nn.softmax(logits), K)
    w = w / w.sum(-1, keepdims=True)
    cap = expert_capacity(N, K, E, capacity_factor=8.0)
    out = moe_ffn_gmm(x, wg, wu, wd, w, idx, capacity=cap, interpret=True)
    ref = moe_ffn_ref(x, wg, wu, wd, w, idx)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-4,
                               atol=1e-4)


def test_dispatch_capacity_drops():
    """Overflowing tokens are dropped deterministically in slot order."""
    x = jnp.ones((6, 4))
    idx = jnp.zeros((6, 1), jnp.int32)       # everyone wants expert 0
    bins, slot, kept = dispatch_ref(x, idx, num_experts=2, capacity=4)
    assert int(kept.sum()) == 4
    assert np.array_equal(np.asarray(slot[:, 0][:4]), [0, 1, 2, 3])


# ------------------------------------------------------------ flash attention

@pytest.mark.parametrize("T,window,cap", [(256, 0, 0.0), (256, 100, 0.0),
                                          (512, 0, 30.0), (128, 64, 20.0)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_sweep(T, window, cap, dtype):
    B, Hq, Hkv, D = 2, 4, 2, 64
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, Hq, T, D), dtype)
    k = jax.random.normal(ks[1], (B, Hkv, T, D), dtype)
    v = jax.random.normal(ks[2], (B, Hkv, T, D), dtype)
    out = flash_attention_bhtd(q, k, v, causal=True, window=window,
                               logit_cap=cap, interpret=True)
    ref = flash_attention_ref(q, k, v, causal=True, window=window,
                              logit_cap=cap)
    tol = 2e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), rtol=tol, atol=tol)


def test_flash_non_causal():
    B, H, T, D = 1, 2, 256, 32
    ks = jax.random.split(jax.random.PRNGKey(3), 3)
    q, k, v = (jax.random.normal(kk, (B, H, T, D)) for kk in ks)
    out = flash_attention_bhtd(q, k, v, causal=False, interpret=True)
    ref = flash_attention_ref(q, k, v, causal=False)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-5,
                               atol=2e-5)


# ------------------------------------------------------------ decode attention

@pytest.mark.parametrize("T", [1, 4, 5])
@pytest.mark.parametrize("g", [1, 2, 4])
def test_decode_attention_sweep(T, g):
    B, Hkv, S, D = 3, 2, 1024, 64
    Hq = Hkv * g
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, Hq, T, D))
    k = jax.random.normal(ks[1], (B, Hkv, S, D))
    v = jax.random.normal(ks[2], (B, Hkv, S, D))
    lengths = jnp.array([17, 512, 1024 - T], jnp.int32)
    out = decode_attention_bhtd(q, k, v, lengths, interpret=True)
    ref = decode_attention_ref(q, k, v, lengths)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=3e-5,
                               atol=3e-5)


def test_decode_attention_bf16():
    B, Hq, Hkv, T, S, D = 2, 4, 2, 3, 512, 128
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(ks[0], (B, Hq, T, D), jnp.bfloat16)
    k = jax.random.normal(ks[1], (B, Hkv, S, D), jnp.bfloat16)
    v = jax.random.normal(ks[2], (B, Hkv, S, D), jnp.bfloat16)
    lengths = jnp.array([100, 509], jnp.int32)
    out = decode_attention_bhtd(q, k, v, lengths, interpret=True)
    ref = decode_attention_ref(q, k, v, lengths)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), rtol=4e-2,
                               atol=4e-2)

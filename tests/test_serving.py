"""Serving engine + sampling + target-efficiency measurement."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.autotune import AutoTuner
from repro.core.target_efficiency import measure_target_efficiency
from repro.models.model import Model
from repro.serving.engine import ServingEngine
from repro.serving.sampling import SamplingParams, sample_logits
import pytest

pytestmark = pytest.mark.tier1

TCFG = ModelConfig("s-moe", "moe", 2, 128, 4, 2, 256, 512, num_experts=4,
                   num_experts_per_tok=2, dtype="float32")
DCFG = ModelConfig("s-draft", "dense", 2, 64, 2, 2, 128, 512, dtype="float32")


def _models():
    t, d = Model(TCFG), Model(DCFG)
    return t, d, t.init(jax.random.PRNGKey(0)), d.init(jax.random.PRNGKey(1))


def test_engine_serves_all_requests():
    t, d, pt, pd = _models()
    eng = ServingEngine(t, d, pt, pd, max_batch=4, gamma=2, force_sd=True)
    uids = [eng.submit(np.arange(3, 10 + i), max_new_tokens=8)
            for i in range(7)]
    reports = eng.run()
    assert len(eng.done) == 7
    assert sum(r.batch for r in reports) == 7
    assert all(len(eng.done[u].output) == 8 for u in uids)
    assert all(r.stats is not None for r in reports)


def test_engine_sd_matches_ar_greedy():
    t, d, pt, pd = _models()
    prompt = np.arange(3, 12)
    outs = {}
    for force in (True, False):
        eng = ServingEngine(t, d, pt, pd, max_batch=1, gamma=3,
                            force_sd=force)
        uid = eng.submit(prompt, max_new_tokens=12)
        eng.run()
        outs[force] = eng.done[uid].output
    np.testing.assert_array_equal(outs[True], outs[False])


def test_tuner_integration_updates_alpha():
    t, d, pt, pd = _models()
    tuner = AutoTuner(TCFG, DCFG, alpha=0.9)
    eng = ServingEngine(t, d, pt, pd, max_batch=4, tuner=tuner, force_sd=True)
    for i in range(4):
        eng.submit(np.arange(3, 11), max_new_tokens=6)
    eng.run()
    # random-weight pair: observed alpha ~0 drags the EMA down from 0.9
    assert tuner.alpha < 0.9


def test_engine_gmm_dispatch_no_retrace_across_waves():
    """The serving default: a gmm-dispatch target decodes through the ragged
    kernels, and the persistent session still reuses compiled rounds — a
    second same-shape wave adds zero retraces."""
    t = Model(TCFG, moe_dispatch="gmm")
    d = Model(DCFG)
    pt, pd = t.init(jax.random.PRNGKey(0)), d.init(jax.random.PRNGKey(1))
    eng = ServingEngine(t, d, pt, pd, max_batch=2, gamma=2, force_sd=True)
    assert eng.moe_dispatch == "gmm"
    for _ in range(4):                                 # 2 waves of 2
        eng.submit(np.arange(3, 9), max_new_tokens=4)
    reports = eng.run()
    assert len(reports) == 2
    assert all(r.moe_dispatch == "gmm" for r in reports)
    traces = eng.session_stats()["model"]["traces"]
    assert len(traces) == 1                            # wave 2: cache hit
    # gmm and onehot dispatch agree numerically on the verify forward
    # (logits-level: exact token equality would be argmax-tie sensitive)
    t2 = Model(TCFG)                                   # onehot
    toks = jnp.tile(jnp.arange(3, 9)[None, :], (2, 1))
    lg, cg = t.prefill(pt, toks, t.init_cache(2, 32))
    lo, co = t2.prefill(pt, toks, t2.init_cache(2, 32))
    np.testing.assert_allclose(np.asarray(lg), np.asarray(lo), rtol=2e-3,
                               atol=2e-3)
    ext = jnp.ones((2, 3), jnp.int32)
    vg, _ = t.extend(pt, ext, cg)
    vo, _ = t2.extend(pt, ext, co)
    np.testing.assert_allclose(np.asarray(vg), np.asarray(vo), rtol=2e-3,
                               atol=2e-3)


def test_sampling_params():
    logits = jnp.asarray(np.random.default_rng(0).standard_normal((4, 32)))
    greedy = sample_logits(logits, jax.random.PRNGKey(0),
                           SamplingParams(temperature=0.0))
    np.testing.assert_array_equal(np.asarray(greedy),
                                  np.asarray(jnp.argmax(logits, -1)))
    topk = sample_logits(logits, jax.random.PRNGKey(1),
                         SamplingParams(temperature=1.0, top_k=3))
    top3 = np.asarray(jnp.argsort(logits, -1)[:, -3:])
    assert all(t in row for t, row in zip(np.asarray(topk), top3))
    topp = sample_logits(logits, jax.random.PRNGKey(2),
                         SamplingParams(temperature=1.0, top_p=0.5))
    assert topp.shape == (4,)


def test_measured_target_efficiency_in_range():
    t, _, pt, _ = _models()
    cache = t.init_cache(4, 64)
    toks = jax.random.randint(jax.random.PRNGKey(2), (4, 16), 0, 512)
    _, cache = t.prefill(pt, toks, cache)
    te = measure_target_efficiency(t, pt, cache, gamma=4, iters=2)
    assert 0.0 < te["target_efficiency"] <= 1.5   # CPU noise tolerance
    assert te["T_T_1"] > 0 and te["T_T_gamma"] > 0

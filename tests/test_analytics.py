"""Paper theory (Eqs. 5-11): formulas vs Monte-Carlo + proven monotonicities."""
import numpy as np
from _hypothesis_compat import given, settings, st

import pytest

from repro.core.analytics import (
    activation_threshold, expected_activated_experts, mean_tokens_per_expert,
    roofline_response, sigma_from_alpha)

pytestmark = pytest.mark.tier1


@settings(max_examples=25, deadline=None)
@given(st.integers(4, 128), st.integers(1, 8), st.integers(1, 256),
       st.integers(0, 10_000))
def test_activated_experts_matches_simulation(E, K, t, seed):
    """Eq. 8 vs Monte-Carlo of uniform top-K routing (the i.i.d. assumption
    the paper validates on Deepseek/Qwen routers in Fig. 1a/b)."""
    if K > E:
        K = E
    rng = np.random.default_rng(seed)
    trials = 400
    counts = np.zeros(trials)
    for i in range(trials):
        active = set()
        for _ in range(t):
            active.update(rng.choice(E, size=K, replace=False))
        counts[i] = len(active)
    pred = expected_activated_experts(t, E, K)
    # i.i.d. approximation error is small; allow generous CI
    se = counts.std() / np.sqrt(trials) + 1e-9
    assert abs(counts.mean() - pred) < max(6 * se, 0.05 * E + 1.0)


@settings(max_examples=50, deadline=None)
@given(st.floats(0.01, 0.99), st.floats(0.01, 0.99), st.integers(2, 512))
def test_tokens_per_expert_monotone_in_rho(rho1, rho2, t):
    """Appendix B: T̄_exp(t; rho) increases with rho for t > 1."""
    lo, hi = sorted((rho1, rho2))
    if hi - lo < 1e-6:
        return
    assert mean_tokens_per_expert(t, lo) <= mean_tokens_per_expert(t, hi) + 1e-9


def test_tokens_per_expert_dense_limit():
    assert mean_tokens_per_expert(37, 1.0) == 37


@settings(max_examples=50, deadline=None)
@given(st.floats(0.02, 0.9), st.floats(0.5, 0.99))
def test_threshold_saturates(rho, tau):
    """Eq. 9: at T_thres, N(t) >= tau*E; below it, not yet."""
    E = 1000
    K = rho * E
    T = activation_threshold(rho, tau)
    assert expected_activated_experts(T, E, K) >= tau * E - 1e-6
    if T > 1:
        assert expected_activated_experts(T - 1, E, K) < tau * E + 1e-6


@settings(max_examples=40, deadline=None)
@given(st.floats(10, 400), st.floats(1.001, 2.0))
def test_roofline_response_c1_continuous(knee, s):
    """Eq. 11: G is continuous with continuous first derivative at the knee."""
    eps = 1e-4
    below = roofline_response(knee - eps, knee, s)
    above = roofline_response(knee + eps, knee, s)
    assert abs(above - below) < 1e-2 * max(below, 1.0)
    d_below = (roofline_response(knee - eps, knee, s)
               - roofline_response(knee - 2 * eps, knee, s)) / eps
    d_above = (roofline_response(knee + 2 * eps, knee, s)
               - roofline_response(knee + eps, knee, s)) / eps
    assert abs(d_above - d_below) < 2e-2 * max(abs(d_below), 1e-3)


def test_roofline_linear_beyond_knee():
    g1 = roofline_response(300, 100, 1.05)
    g2 = roofline_response(400, 100, 1.05)
    g3 = roofline_response(500, 100, 1.05)
    assert abs((g3 - g2) - (g2 - g1)) < 1e-9


@settings(max_examples=30, deadline=None)
@given(st.floats(0.0, 1.0), st.integers(1, 8))
def test_sigma_bounds(alpha, gamma):
    s = sigma_from_alpha(alpha, gamma)
    assert 1 / (gamma + 1) - 1e-9 <= s <= 1.0 + 1e-9

"""Continuous-batching slot scheduler: parity, determinism, zero-retrace.

The contracts the continuous serving mode rests on:
  * token parity — at fixed occupancy the continuous scheduler is greedy
    token-identical to wave mode (same rounds, same commits),
  * slot lifecycle — per-slot max_new_tokens budgets and eos early-exit
    retire slots, freed slots are refilled deterministically under split
    PRNG keys,
  * zero retraces — occupancy changes within a (pool, prompt-bucket) never
    retrace the round or the admission prefill (masks are data),
  * live re-planning — the tuner is consulted on the live slot count every
    round and the SD→AR handoff happens mid-stream, in-session (gamma=0),
  * honest accounting — tokens_out counts real generated tokens and every
    Request carries a finish_reason.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ModelConfig
from repro.core.analytics import occupancy_timeline, predicted_decay_speedup
from repro.models.model import Model
from repro.serving.engine import ServingEngine
from repro.serving.sampling import SamplingParams

pytestmark = pytest.mark.tier1

TCFG = ModelConfig("cs-moe", "moe", 2, 128, 4, 2, 256, 512, num_experts=4,
                   num_experts_per_tok=2, dtype="float32")
DCFG = ModelConfig("cs-draft", "dense", 2, 64, 2, 2, 128, 512,
                   dtype="float32")


@pytest.fixture(scope="module")
def models():
    t, d = Model(TCFG), Model(DCFG)
    return t, d, t.init(jax.random.PRNGKey(0)), d.init(jax.random.PRNGKey(1))


def _engine(t, d, pt, pd, **kw):
    kw.setdefault("max_batch", 4)
    kw.setdefault("gamma", 2)
    kw.setdefault("force_sd", True)
    return ServingEngine(t, d, pt, pd, **kw)


def test_continuous_matches_wave_greedy_fixed_occupancy(models):
    """Fixed occupancy (pool-sized batch, equal budgets): the continuous
    scheduler must be greedy token-identical to wave mode."""
    t, d, pt, pd = models
    outs = {}
    for sched in ("wave", "continuous"):
        eng = _engine(t, d, pt, pd, scheduler=sched)
        uids = [eng.submit(np.arange(3, 9), max_new_tokens=8)
                for _ in range(4)]
        (report,) = eng.run()
        outs[sched] = [eng.done[u].output for u in uids]
        assert report.scheduler == sched
        assert report.tokens_out == 4 * 8
        assert all(eng.done[u].finish_reason == "length" for u in uids)
    for a, b in zip(outs["wave"], outs["continuous"]):
        np.testing.assert_array_equal(a, b)


def test_slot_budgets_and_refill(models):
    """More requests than slots, mixed budgets: every request is served to
    exactly its own max_new_tokens and occupancy visibly varies."""
    t, d, pt, pd = models
    budgets = (4, 12, 6, 9, 5, 7)
    eng = _engine(t, d, pt, pd, max_batch=2, scheduler="continuous")
    uids = [eng.submit(np.arange(3, 9), max_new_tokens=m) for m in budgets]
    (report,) = eng.run()
    assert len(eng.done) == len(budgets)
    assert all(len(eng.done[u].output) == m for u, m in zip(uids, budgets))
    assert report.tokens_out == sum(budgets)
    lives = [s.live for s in report.steps]
    assert max(lives) == 2
    assert sum(s.admitted for s in report.steps) == len(budgets)
    assert sum(s.retired for s in report.steps) == len(budgets)


def test_retire_refill_deterministic_under_split_keys(models):
    """Sampled decoding: identical seeds replay the stream exactly
    (admissions and rounds each consume their own key split); different
    seeds diverge."""
    t, d, pt, pd = models

    def serve(seed):
        eng = _engine(t, d, pt, pd, max_batch=2, scheduler="continuous",
                      temperature=1.0, seed=seed)
        uids = [eng.submit(np.arange(3, 9), max_new_tokens=m)
                for m in (5, 9, 4, 7)]
        eng.run()
        return [eng.done[u].output for u in uids]

    a, b, c = serve(5), serve(5), serve(6)
    for x, y in zip(a, b):
        np.testing.assert_array_equal(x, y)
    assert any(not np.array_equal(x, y) for x, y in zip(a, c))


def test_no_retrace_when_occupancy_changes_within_bucket(models):
    """Retire/refill churn is data, not shape: a whole mixed-budget stream
    compiles ONE round and one admission prefill per (prompt-bucket,
    admitted-rows) shape — the initial 2-row fill plus the 1-row refill,
    reused for every later refill."""
    t, d, pt, pd = models
    eng = _engine(t, d, pt, pd, max_batch=2, scheduler="continuous")
    for m in (3, 7, 5, 4, 6):
        eng.submit(np.arange(3, 9), max_new_tokens=m)
    (report,) = eng.run()
    lives = [s.live for s in report.steps]
    assert len(set(lives)) > 1                 # occupancy really changed
    stats = eng.session_stats()["model"]
    assert stats["traces"] == [(2, 2)]         # one (gamma, pool) round
    assert stats["admit_traces"] == [(8, 2), (8, 1)]
    # 5 admissions landed but only the two shapes above ever traced
    assert sum(s.admitted for s in report.steps) == 5


class _WindowTuner:
    """Stub tuner: SD only while the live batch stays >= 2 slots."""

    def __init__(self):
        self.planned = []
        self.alphas = []

    def plan(self, batch):
        self.planned.append(batch)
        return {"use_sd": batch >= 2, "gamma": 2, "predicted_speedup": 2.0}

    def update_alpha(self, alpha):
        self.alphas.append(alpha)


def test_tuner_replans_live_count_and_hands_off_to_ar(models):
    """As slots drain, plan(live) sees the decayed N(t) and the stream
    hands off SD→AR mid-flight (gamma=0 rounds, same session) — with
    greedy outputs still token-identical to the all-SD wave decode."""
    t, d, pt, pd = models
    tuner = _WindowTuner()
    eng = ServingEngine(t, d, pt, pd, max_batch=2, gamma=2, tuner=tuner,
                        scheduler="continuous")
    budgets = (4, 12)
    uids = [eng.submit(np.arange(3, 9), max_new_tokens=m) for m in budgets]
    (report,) = eng.run()
    assert set(tuner.planned) == {1, 2}        # re-planned on live N(t)
    sd_flags = [s.used_sd for s in report.steps]
    assert True in sd_flags and False in sd_flags
    assert all(s.gamma == 0 for s in report.steps if not s.used_sd)
    # the handoff is in-session: one session, no "none" fallback session
    assert eng.session_constructions == {"model": 1}
    # greedy losslessness survives the mid-stream policy change
    ref = _engine(t, d, pt, pd, max_batch=2)
    ruids = [ref.submit(np.arange(3, 9), max_new_tokens=m) for m in budgets]
    ref.run()
    for u, ru in zip(uids, ruids):
        np.testing.assert_array_equal(eng.done[u].output,
                                      ref.done[ru].output)


def test_eos_early_exit_both_schedulers(models):
    """finish_reason="eos" + truncation at the first eos, wave and
    continuous alike (and token-identical between them)."""
    t, d, pt, pd = models
    probe = _engine(t, d, pt, pd, max_batch=1)
    u = probe.submit(np.arange(3, 9), max_new_tokens=8)
    probe.run()
    full = probe.done[u].output
    eos = int(full[2])                         # greedy stream is fixed
    cut = int(np.nonzero(full == eos)[0][0]) + 1
    outs = {}
    for sched in ("wave", "continuous"):
        eng = _engine(t, d, pt, pd, max_batch=1, scheduler=sched,
                      eos_id=eos)
        uu = eng.submit(np.arange(3, 9), max_new_tokens=8)
        (report,) = eng.run()
        r = eng.done[uu]
        assert r.finish_reason == "eos"
        assert len(r.output) == cut
        assert report.tokens_out == cut        # only real tokens counted
        outs[sched] = r.output
    np.testing.assert_array_equal(outs["wave"], outs["continuous"])


def test_wave_tokens_out_counts_real_tokens(models):
    """Mixed budgets in ONE wave: tokens_out is the sum of per-request
    budgets, not batch * max(max_new_tokens)."""
    t, d, pt, pd = models
    eng = _engine(t, d, pt, pd, max_batch=4)
    budgets = (4, 16, 8, 6)
    uids = [eng.submit(np.arange(3, 9), max_new_tokens=m) for m in budgets]
    (report,) = eng.run()
    assert report.tokens_out == sum(budgets)
    assert all(len(eng.done[u].output) == m
               for u, m in zip(uids, budgets))
    assert all(eng.done[u].finish_reason == "length" for u in uids)


def test_per_request_sampling_validated_loudly(models):
    """Request-level SamplingParams thread through (max_new_tokens) but a
    distribution-policy mismatch fails at submit, not silently at decode."""
    t, d, pt, pd = models
    eng = _engine(t, d, pt, pd)
    u = eng.submit(np.arange(3, 9),
                   sampling=SamplingParams(temperature=0.0,
                                           max_new_tokens=5))
    eng.run()
    assert len(eng.done[u].output) == 5        # sampling.max_new_tokens won
    with pytest.raises(ValueError, match="temperature"):
        eng.submit(np.arange(3, 9),
                   sampling=SamplingParams(temperature=0.7))
    with pytest.raises(ValueError, match="top_k/top_p"):
        eng.submit(np.arange(3, 9),
                   sampling=SamplingParams(top_k=5))


def test_poisson_arrivals_delay_admission(models):
    """Requests stay invisible to the scheduler until their
    arrival_round; the stream idles through gaps and still serves all."""
    t, d, pt, pd = models
    eng = _engine(t, d, pt, pd, max_batch=2, scheduler="continuous")
    eng.submit(np.arange(3, 9), max_new_tokens=4, arrival_round=0)
    u_late = eng.submit(np.arange(3, 9), max_new_tokens=4, arrival_round=6)
    (report,) = eng.run()
    assert len(eng.done) == 2
    assert len(eng.done[u_late].output) == 4
    late_admit = [s.round_index for s in report.steps if s.admitted
                  and s.round_index >= 6]
    assert late_admit                          # admitted at/after round 6


def test_occupancy_decay_helpers():
    """analytics: timeline summary + decay-aware predicted speedup."""
    live = [4, 4, 3, 2, 1]
    committed = [8, 8, 6, 4, 2]
    occ = occupancy_timeline(live, committed)
    assert occ["peak_live"] == 4 and occ["final_live"] == 1
    assert occ["mean_live"] == pytest.approx(2.8)
    # token weighting leans toward the full-occupancy rounds
    assert occ["token_weighted_live"] > occ["mean_live"]
    pred = predicted_decay_speedup(live, 4, lambda b, g: float(b),
                                   committed=committed)
    assert list(pred["per_round"]) == live
    assert pred["token_weighted"] == pytest.approx(
        occ["token_weighted_live"])
    # gamma=0 rounds (SD→AR handoff) are the AR baseline: speedup 1.0,
    # and the SD formula is never evaluated at gamma=0
    handoff = predicted_decay_speedup(
        [4, 1], [4, 0], lambda b, g: 1 / g if g else 1 / 0)
    assert list(handoff["per_round"]) == [0.25, 1.0]
    # perf-model wrapper rides the same helper
    from repro.core.perf_model import SpeedupModel
    p = np.array([1.0, 0.5, 2.0, 1.5, 0.1, 0.05, 0.01, 0.001, 0.5, 1.2])
    m = SpeedupModel(params=p)
    out = m.predict_decay(live, [4] * 5, 2, 8, 0.8, committed=committed)
    assert out["per_round"].shape == (5,)
    assert out["token_weighted"] > 0

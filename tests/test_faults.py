"""Resilience layer: preemption/requeue, sentinels, watchdog, injection.

The contracts this PR adds on top of the continuous scheduler
(docs/faults.md):

  * page-pressure preemption is RECOVERABLE — the preempted request
    requeues with its committed tokens, recompute-prefills
    ``prompt + committed`` on re-admission, and finishes with greedy
    tokens byte-identical to an uninjected stream,
  * the numerical sentinel QUARANTINES — a NaN row finishes
    ``numerical_fault`` without perturbing co-batched slots' tokens,
  * the degradation ladder ESCALATES — repeated faulty rounds force AR
    and then a stream-level safe stop that aborts cleanly (every request
    gets exactly one finish_reason, zero pages leak),
  * accounting stays HONEST — tokens of requests that did not finish
    cleanly are excluded from tokens/sec, and a requeue never
    double-counts,
  * fault injection is DETERMINISTIC — seeded scripts replay exactly.
"""
import jax
import numpy as np
import pytest

from repro.configs.base import ModelConfig
from repro.core.analytics import fault_recovery_summary
from repro.models.model import Model, PageAllocator
from repro.serving.engine import ServingEngine
from repro.serving.faults import Fault, FaultInjector, ResilienceConfig
from repro.serving.scheduler import StepReport, submit_poisson

pytestmark = pytest.mark.tier1

TCFG = ModelConfig("ft-moe", "moe", 2, 128, 4, 2, 256, 512, num_experts=4,
                   num_experts_per_tok=2, dtype="float32")
DCFG = ModelConfig("ft-draft", "dense", 2, 64, 2, 2, 128, 512,
                   dtype="float32")


@pytest.fixture(scope="module")
def models():
    t, d = Model(TCFG), Model(DCFG)
    return t, d, t.init(jax.random.PRNGKey(0)), d.init(jax.random.PRNGKey(1))


def _engine(t, d, pt, pd, **kw):
    kw.setdefault("max_batch", 3)
    kw.setdefault("gamma", 2)
    kw.setdefault("force_sd", True)
    kw.setdefault("scheduler", "continuous")
    return ServingEngine(t, d, pt, pd, **kw)


# ------------------------------------------------------ allocator edge cases
def test_allocator_exhaustion_and_reserve_edges():
    """Growth request at max_pages, watermark arithmetic, double-free and
    double-release detection — the host-side page bookkeeping the
    preemption path leans on."""
    a = PageAllocator(2, 8, 4, 4)            # 3 allocatable pages
    assert a.free_fraction() == 1.0
    a.alloc(0, 16)                           # 2 pages
    assert not a.can_alloc(17)               # 3 pages > 1 free
    assert a.free_fraction() == pytest.approx(1 / 3)
    # growth geometry past the free list stays pow2 and fits the request
    pool, maxp = a.grown_geometry(17)
    assert pool >= 8 and maxp >= 4
    # reserve() is real pressure: alloc cannot see reserved pages
    held = a.reserve(1)
    assert not a.can_alloc(8)
    with pytest.raises(ValueError, match="reserve"):
        a.reserve(1)                         # nothing left to reserve
    with pytest.raises(ValueError, match="not.*reserved|reserved"):
        a.release([99])                      # never-reserved page
    a.release(held)
    with pytest.raises(ValueError, match="not.*reserved"):
        a.release(held)                      # second release = double free
    a.free_row(0)
    a.assert_no_leaks()                      # clean end state: no leaks
    # leak check fires while a row still owns pages
    a.alloc(1, 8)
    with pytest.raises(RuntimeError, match="own pages"):
        a.assert_no_leaks()
    # double-free detection: a page both owned and free is corruption
    a.free.append(a.owned[1][0])
    with pytest.raises(ValueError, match="double free"):
        a.free_row(1)


def test_injector_determinism_and_validation():
    """Same seed → identical scripted fault rounds; unknown kinds fail."""
    a = FaultInjector.poisson(0.5, 20, seed=7)
    b = FaultInjector.poisson(0.5, 20, seed=7)
    assert a.faults == b.faults
    c = FaultInjector.poisson(0.5, 20, seed=8)
    assert a.faults != c.faults              # seed actually matters
    with pytest.raises(ValueError, match="unknown fault kind"):
        Fault(round=0, kind="meteor_strike")


def test_submit_poisson_validation(models):
    t, d, pt, pd = models
    eng = _engine(t, d, pt, pd)
    prompts = np.arange(12).reshape(2, 6) + 3
    with pytest.raises(ValueError, match="rate"):
        submit_poisson(eng, prompts, [6, 6], rate=-1.0)
    with pytest.raises(ValueError, match="empty workload"):
        submit_poisson(eng, prompts, [], rate=1.0)
    with pytest.raises(ValueError, match="prompt 1 is empty"):
        submit_poisson(eng, prompts, [6, 0], rate=1.0)
    with pytest.raises(ValueError, match="max_new_choices"):
        submit_poisson(eng, prompts, [6, 6], rate=1.0, max_new_choices=())
    assert not eng.queue                     # nothing half-submitted


# --------------------------------------------------- preemption and requeue
def test_preemption_requeues_byte_identical(models):
    """Page pressure at the pool cap preempts the youngest slot; the
    requeued request recompute-prefills prompt+committed and finishes
    with byte-identical greedy tokens — and zero pages leak."""
    t, d, pt, pd = models

    def run(capped):
        res = ResilienceConfig(max_pool_pages=8) if capped else None
        eng = _engine(t, d, pt, pd, kv_layout="paged", page_size=8,
                      resilience=res)
        ua = eng.submit(np.arange(3, 9), max_new_tokens=16)
        ub = eng.submit(np.arange(4, 10), max_new_tokens=8, arrival_round=1)
        uc = eng.submit(np.arange(5, 11), max_new_tokens=8, arrival_round=2)
        eng.run()
        return eng, (ua, ub, uc)

    ref, (ra, rb, rc) = run(capped=False)
    eng, (ua, ub, uc) = run(capped=True)
    # pool sized for A alone (4 pages of 7); B admits into the remainder;
    # C's arrival cannot grow past the cap → B (youngest) is preempted
    assert eng.fault_counters["preemptions"] >= 1
    assert eng.fault_counters["requeues"] >= 1
    b = eng.done[ub]
    assert b.preempt_count == 1
    assert b.requeue_round is not None
    assert b.readmit_round is not None and b.readmit_round > b.requeue_round
    for u_ref, u in ((ra, ua), (rb, ub), (rc, uc)):
        assert eng.done[u].finish_reason in ("length", "eos")
        np.testing.assert_array_equal(eng.done[u].output,
                                      ref.done[u_ref].output)
    report = eng.reports[-1]
    assert report.finish_reasons.get("length", 0) == 3
    assert sum(s.preempted for s in report.steps) >= 1
    eng._slot_scheduler._alloc.assert_no_leaks()


# ------------------------------------------------------ numerical sentinel
def test_nan_quarantine_isolates_co_batched_rows(models):
    """A NaN-poisoned row finishes ``numerical_fault``; its co-batched
    neighbour's greedy tokens are byte-identical to an uninjected run,
    and the faulted tokens are excluded from tokens_out."""
    t, d, pt, pd = models

    def run(inject):
        inj = FaultInjector([Fault(round=2, kind="nan_row", row=0)]) \
            if inject else None
        eng = _engine(t, d, pt, pd, max_batch=2, fault_injector=inj)
        ua = eng.submit(np.arange(3, 9), max_new_tokens=12)
        ub = eng.submit(np.arange(4, 10), max_new_tokens=12)
        eng.run()
        return eng, ua, ub

    ref, ra, rb = run(inject=False)
    eng, ua, ub = run(inject=True)
    a, b = eng.done[ua], eng.done[ub]
    assert a.finish_reason == "numerical_fault"
    assert len(a.output) < 12                # quarantined mid-stream
    # the healthy neighbour never saw the fault
    assert b.finish_reason == "length"
    np.testing.assert_array_equal(b.output, ref.done[rb].output)
    # accounting: faulted tokens discarded, not sold as throughput
    report = eng.reports[-1]
    assert report.tokens_out == len(b.output)
    assert report.tokens_discarded == len(a.output)
    assert sum(s.faults for s in report.steps) == 1
    assert eng.fault_counters["numerical_faults"] == 1


def test_ladder_escalates_to_safe_stop(models):
    """Consecutive faulty rounds walk the ladder to a stream-level safe
    stop: in-flight and queued requests finish ``aborted`` — exactly one
    finish_reason each — instead of hanging."""
    t, d, pt, pd = models
    inj = FaultInjector([Fault(round=1, kind="nan_row", row=0),
                         Fault(round=2, kind="nan_row", row=1)])
    eng = _engine(t, d, pt, pd, max_batch=2, fault_injector=inj,
                  resilience=ResilienceConfig(faulty_rounds_to_ar=1,
                                              faulty_rounds_to_stop=2))
    for i in range(3):                       # third stays queued (no slot)
        eng.submit(np.arange(3 + i, 9 + i), max_new_tokens=32)
    eng.run()
    reasons = sorted(r.finish_reason for r in eng.done.values())
    assert reasons == ["aborted", "numerical_fault", "numerical_fault"]
    assert eng.fault_counters["aborts"] == 1
    assert eng.fault_counters.get("ar_handoffs", 0) >= 1
    assert not eng.queue                     # nothing stranded


# ------------------------------------------------------- watchdog and retry
def test_round_budget_timeout(models):
    """Per-request round budgets retire over-budget slots with
    ``finish_reason='timeout'`` and keep their tokens out of tokens/sec."""
    t, d, pt, pd = models
    eng = _engine(t, d, pt, pd, max_batch=2,
                  resilience=ResilienceConfig(max_rounds_per_request=1))
    eng.submit(np.arange(3, 9), max_new_tokens=32)
    eng.submit(np.arange(4, 10), max_new_tokens=32)
    eng.run()
    assert [r.finish_reason for r in eng.done.values()] == \
        ["timeout", "timeout"]
    report = eng.reports[-1]
    assert report.tokens_out == 0
    assert report.tokens_discarded > 0       # partial work is visible
    assert eng.fault_counters["timeouts"] == 2


def test_admission_retry_backoff_and_exhaustion(models):
    """Transient admission failures retry with exponential backoff;
    exceeding the retry budget finishes ``admit_failed``."""
    t, d, pt, pd = models
    # fails at round 0 (attempt 1 → retry at 1), 1 (attempt 2 → retry at
    # 3), 3 (attempt 3 > admit_retries=2 → admit_failed)
    inj = FaultInjector([Fault(round=r, kind="admit_fail")
                         for r in (0, 1, 3)])
    eng = _engine(t, d, pt, pd, fault_injector=inj,
                  resilience=ResilienceConfig(admit_retries=2))
    uid = eng.submit(np.arange(3, 9), max_new_tokens=4)
    eng.run()
    r = eng.done[uid]
    assert r.finish_reason == "admit_failed"
    assert r.admit_attempts == 3
    assert len(r.output) == 0
    assert eng.fault_counters["admit_retries"] == 2
    assert eng.fault_counters["admit_failures"] == 1
    # a retry budget that survives the same script finishes cleanly
    inj2 = FaultInjector([Fault(round=0, kind="admit_fail")])
    eng2 = _engine(t, d, pt, pd, fault_injector=inj2,
                   resilience=ResilienceConfig(admit_retries=2))
    uid2 = eng2.submit(np.arange(3, 9), max_new_tokens=4)
    eng2.run()
    assert eng2.done[uid2].finish_reason == "length"
    assert eng2.done[uid2].admit_attempts == 1


# ------------------------------------------------------------- accounting
def test_fault_recovery_summary_reduction():
    """Pure-numpy recovery-latency reduction over StepReports: the
    latency of a preemption is rounds until the next re-admission."""
    mk = lambda i, **kw: StepReport(i, 1, 2, True, 1, kw.pop("admitted", 0),
                                    0, 0.01, **kw)
    steps = [mk(0, admitted=2), mk(1, preempted=1), mk(2), mk(3, admitted=1),
             mk(4, faults=1), mk(5, deferred=2)]
    s = fault_recovery_summary(steps)
    assert s["rounds"] == 6 and s["preempted"] == 1 and s["faults"] == 1
    assert s["deferred"] == 2
    assert s["recovery_latency_rounds"] == [2.0]
    assert s["mean_recovery_latency"] == 2.0
    assert s["disrupted_rounds"] == 3
    # a preemption that never re-admits is visible, not dropped
    s2 = fault_recovery_summary([mk(0, preempted=1), mk(1)])
    assert s2["recovery_latency_rounds"] == [float("inf")]

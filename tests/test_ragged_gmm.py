"""Ragged + fused grouped-matmul kernels vs the ref.py oracles.

Interpret-mode parity over the adversarial routing shapes the serving path
actually produces — empty experts, fully-imbalanced routing, group sizes
that aren't tile multiples — plus the tile-count assertion that kernel work
scales with the routed token count N·K, not with E·C capacity bins."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ModelConfig
from repro.kernels.gmm.ops import gmm, gmm_legacy, moe_ffn_gmm
from repro.kernels.gmm.ragged import (fused_gate_up, make_group_metadata,
                                      ragged_gmm, ragged_moe_ffn)
from repro.kernels.gmm.ref import (fused_gate_up_ref, moe_ffn_ref,
                                   ragged_gmm_ref, ragged_moe_ffn_ref)
from repro.models.moe import init_moe, moe_forward

pytestmark = pytest.mark.tier1

# empty experts / fully-imbalanced / unaligned group sizes / single expert
GROUP_CASES = [
    [5, 0, 11],                        # empty middle expert, tiny N
    [0, 0, 310, 0],                    # all tokens on ONE expert
    [37, 0, 1, 129, 0, 77, 13, 200],   # nothing tile-aligned, two empties
    [256],                             # E=1 degenerate
]


def _case(sizes, D, F, dtype=jnp.float32, seed=0):
    sizes_np = np.asarray(sizes, np.int64)
    E, N = len(sizes_np), int(sizes_np.sum())
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    xs = jax.random.normal(ks[0], (N, D), dtype)
    wg = (jax.random.normal(ks[1], (E, D, F)) / np.sqrt(D)).astype(dtype)
    wu = (jax.random.normal(ks[2], (E, D, F)) / np.sqrt(D)).astype(dtype)
    return jnp.asarray(sizes_np, jnp.int32), xs, wg, wu


def _tol(dtype):
    # bf16 inputs, fp32 accumulation: tolerance sized to bf16 rounding
    return 1e-4 if dtype == jnp.float32 else 3e-2


@pytest.mark.parametrize("sizes", GROUP_CASES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_ragged_gmm_matches_ref(sizes, dtype):
    sizes, xs, w, _ = _case(sizes, D=64, F=128, dtype=dtype)
    out = ragged_gmm(xs, w, sizes, interpret=True)
    ref = ragged_gmm_ref(xs, w, sizes)
    tol = _tol(dtype)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), rtol=tol, atol=tol)


@pytest.mark.parametrize("sizes", GROUP_CASES)
@pytest.mark.parametrize("activation", ["silu", "gelu"])
def test_fused_gate_up_matches_ref(sizes, activation):
    sizes, xs, wg, wu = _case(sizes, D=64, F=96)
    out = fused_gate_up(xs, wg, wu, sizes, activation=activation,
                        interpret=True)
    ref = fused_gate_up_ref(xs, wg, wu, sizes, activation)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-4,
                               atol=1e-4)


def test_ragged_moe_ffn_matches_ref():
    sizes, xs, wg, wu = _case([37, 0, 1, 129, 0, 77, 13, 200], D=64, F=96)
    wd = jax.random.normal(jax.random.PRNGKey(9),
                           (len(sizes), 96, 64)) / np.sqrt(96)
    out = ragged_moe_ffn(xs, wg, wu, wd, sizes, interpret=True)
    ref = ragged_moe_ffn_ref(xs, wg, wu, wd, sizes)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-4,
                               atol=2e-4)


@pytest.mark.parametrize("K", [1, 2, 8])
def test_moe_forward_gmm_parity_across_topk(K):
    """Full routed-FFN parity through moe_forward for K in {1, 2, 8}."""
    cfg = ModelConfig("m", "moe", 2, 64, 4, 2, 128, 256, num_experts=8,
                      num_experts_per_tok=K, moe_d_ff=128, dtype="float32")
    p = init_moe(jax.random.PRNGKey(K), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(K + 10), (2, 33, 64)) * 0.5
    y_ref, _ = moe_forward(p, cfg, x, dispatch="onehot")
    y, _ = moe_forward(p, cfg, x, dispatch="gmm")
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), rtol=2e-4,
                               atol=2e-4)


# ------------------------------------------------------------- work scaling

def test_tile_count_scales_with_routed_tokens_not_capacity_bins():
    """The metadata's num_visits IS the kernel's m-tile work (padding visits
    are pl.when-skipped): bounded by tiles(N) + boundary straddles, far
    below the E * C/bm tiles the capacity path launches."""
    E, bm = 64, 128
    sizes = np.zeros(E, np.int64)
    sizes[3], sizes[40] = 200, 56                  # N=256 on 2 of 64 experts
    N = int(sizes.sum())
    n_pad = -(-N // bm) * bm
    meta = make_group_metadata(jnp.asarray(sizes), n_pad, bm)
    visits = int(meta.num_visits[0])
    # expert 3 rows [0,200) -> tiles {0,1}; expert 40 rows [200,256) -> {1}
    assert visits == 3
    capacity_tiles = E * (n_pad // bm)             # gmm_capacity grid m-work
    assert visits * 16 <= capacity_tiles
    # work tracks routed tokens: doubling N roughly doubles visits
    sizes2 = sizes * 2
    n_pad2 = -(-int(sizes2.sum()) // bm) * bm
    visits2 = int(make_group_metadata(jnp.asarray(sizes2), n_pad2,
                                      bm).num_visits[0])
    nonempty = int((sizes > 0).sum())
    assert visits2 <= n_pad2 // bm + nonempty     # tiles(2N) + straddles


def test_empty_experts_cost_zero_visits():
    E, bm = 8, 128
    sizes = np.zeros(E, np.int64)
    sizes[2] = 128                                 # one expert, tile-aligned
    meta = make_group_metadata(jnp.asarray(sizes), 128, bm)
    assert int(meta.num_visits[0]) == 1            # 7 empty experts: 0 tiles


# ------------------------------------------------------- legacy + ffn paths

def test_gmm_legacy_matches_ragged():
    sizes, xs, w, _ = _case([37, 0, 1, 129, 0, 77, 13, 200], D=64, F=128)
    out_legacy = gmm_legacy(xs, w, sizes, interpret=True)
    out_ragged = gmm(xs, w, sizes, interpret=True)
    np.testing.assert_allclose(np.asarray(out_legacy), np.asarray(out_ragged),
                               rtol=2e-4, atol=2e-4)


def test_gmm_legacy_capacity_hint():
    """A static capacity bound >= max group size shrinks the bins but stays
    exact."""
    sizes, xs, w, _ = _case([5, 0, 11], D=64, F=128)
    out = gmm_legacy(xs, w, sizes, capacity=16, interpret=True)
    ref = ragged_gmm_ref(xs, w, sizes)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-4,
                               atol=2e-4)


def test_moe_ffn_gmm_counts_dropped_tokens():
    """Overflow is no longer silent: all tokens on one expert with a tight
    capacity reports exactly the overflow count."""
    N, D, F, E = 6, 32, 48, 2
    keys = jax.random.split(jax.random.PRNGKey(3), 4)
    x = jax.random.normal(keys[0], (N, D))
    wg = jax.random.normal(keys[1], (E, D, F)) / np.sqrt(D)
    wu = jax.random.normal(keys[2], (E, D, F)) / np.sqrt(D)
    wd = jax.random.normal(keys[3], (E, F, D)) / np.sqrt(F)
    weights = jnp.ones((N, 1))
    idx = jnp.zeros((N, 1), jnp.int32)             # everyone -> expert 0
    _, dropped = moe_ffn_gmm(x, wg, wu, wd, weights, idx, capacity=4,
                             interpret=True, return_dropped=True)
    assert int(dropped) == 2
    y, dropped0 = moe_ffn_gmm(x, wg, wu, wd, weights, idx, capacity=128,
                              interpret=True, return_dropped=True)
    assert int(dropped0) == 0
    ref = moe_ffn_ref(x, wg, wu, wd, weights, idx)
    np.testing.assert_allclose(np.asarray(y), np.asarray(ref), rtol=1e-4,
                               atol=1e-4)

"""Quickstart: the full API in ~60 lines.

1. build a small MoE target + tiny dense draft,
2. train both on a synthetic code corpus,
3. serve a batch with speculative decoding and verify it is lossless,
4. ask the paper's performance model where SD pays off.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, TrainConfig
from repro.configs.registry import get_config
from repro.core.autotune import AutoTuner
from repro.core.proposer import make_proposer
from repro.core.spec_decode import SDEngine, generate_ar
from repro.data.pipeline import packed_batches, prompt_batch
from repro.models.model import Model
from repro.training.train_loop import init_train_state, make_train_step


def train(model, steps, kind, seed, lr=3e-3):
    params, opt = init_train_state(model, jax.random.PRNGKey(seed))
    step = jax.jit(make_train_step(model, TrainConfig(
        learning_rate=lr, total_steps=steps, warmup_steps=steps // 10)))
    it = packed_batches(model.cfg.vocab_size, 8, 64, kind=kind, seed=seed)
    for i in range(steps):
        batch = {k: jnp.asarray(v) for k, v in next(it).items()}
        params, opt, m = step(params, opt, batch)
        if i % 50 == 0:
            print(f"  [{model.cfg.name}] step {i:4d} loss {float(m['loss']):.3f}")
    return params


def main():
    # 1. models — the paper's pairing, reduced: MoE target + small dense draft
    tcfg = get_config("qwen2-57b-a14b", reduced=True)
    dcfg = get_config("qwen2-0.5b", reduced=True)
    target, draft = Model(tcfg), Model(dcfg)

    # 2. train both on the same distribution so the draft can speculate
    print("training target (reduced Qwen2-57B-A14B)...")
    params_t = train(target, 200, "code", seed=0)
    print("training draft (reduced Qwen2-0.5B)...")
    params_d = train(draft, 200, "code", seed=1)

    # 3. batched speculative decoding — one SDEngine session, any proposer
    #    from the registry ("model" | "eagle" | "none") — and the
    #    losslessness check against the AR baseline (the "none" path)
    pb = prompt_batch(tcfg.vocab_size, 8, kind="code", seed=7)
    prompts, lengths = jnp.asarray(pb["tokens"]), jnp.asarray(pb["lengths"])
    sd = SDEngine(target, make_proposer("model", target, draft),
                  gamma=4, temperature=0.0)
    out_sd, stats = sd.generate(params_t, params_d, prompts, 32,
                                lengths=lengths)
    out_ar = generate_ar(target, params_t, prompts, 32, lengths=lengths)
    assert np.array_equal(out_sd, out_ar), "SD must be lossless"
    print(f"\nSD lossless ✓  alpha={stats.alpha:.2f} sigma={stats.sigma:.2f} "
          f"rounds={stats.rounds} (AR would need 32)")

    # 4. the paper's model: where does SD pay off for the FULL config?
    tuner = AutoTuner(get_config("qwen2-57b-a14b"), get_config("qwen2-0.5b"),
                      alpha=stats.alpha)
    win = tuner.speedup_window()
    print(f"predicted on TPU v5e: peak {win['peak']:.2f}x at batch "
          f"{win['peak_batch']}, SD-favourable window B∈{win['window']}")


if __name__ == "__main__":
    main()

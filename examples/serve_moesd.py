"""Batched SD serving of an MoE (the paper's private-serving scenario):
continuous waves of requests, auto-tuned gamma, per-wave sigma/alpha and
the target-efficiency measurement of Sec. 3.1 — drafted by the
prefetch-aware proposer (core/prefetch.py), which probes the target's
routers over each draft stream and warms the predicted experts' weights
during the propose phase; every wave reports the prediction's hit rate.

    PYTHONPATH=src python examples/serve_moesd.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import TrainConfig
from repro.configs.registry import get_config
from repro.core.autotune import AutoTuner
from repro.core.target_efficiency import measure_target_efficiency
from repro.data.pipeline import packed_batches, prompt_batch
from repro.models.model import Model
from repro.serving.engine import ServingEngine
from repro.training.train_loop import init_train_state, make_train_step


def quick_train(model, steps, kind, seed):
    params, opt = init_train_state(model, jax.random.PRNGKey(seed))
    step = jax.jit(make_train_step(model, TrainConfig(
        learning_rate=3e-3, total_steps=steps, warmup_steps=steps // 10)))
    it = packed_batches(model.cfg.vocab_size, 8, 64, kind=kind, seed=seed)
    for _ in range(steps):
        params, opt, _ = step(params, opt,
                              {k: jnp.asarray(v) for k, v in next(it).items()})
    return params


def main():
    tcfg = get_config("mixtral-8x7b", reduced=True)
    dcfg = get_config("qwen2-0.5b", reduced=True).with_overrides(
        vocab_size=tcfg.vocab_size)
    # train with the onehot dispatch (dense, shardable); serve with the
    # ragged gmm kernels — the decode-path default (kernels/gmm/ragged.py)
    draft = Model(dcfg)
    print("training reduced Mixtral target + draft on chat workload...")
    params_t = quick_train(Model(tcfg), 150, "chat", 0)
    params_d = quick_train(draft, 150, "chat", 1)
    target = Model(tcfg, moe_dispatch="gmm")

    # the tuner plans from the FULL Mixtral config on v5e
    tuner = AutoTuner(get_config("mixtral-8x7b"),
                      get_config("qwen2-0.5b"), alpha=0.6)
    # one persistent decoding session per proposer kind — waves reuse the
    # compiled SD rounds even as the tuner changes gamma between them.
    # "prefetch" wraps the small-model drafter with draft-phase expert
    # warming: greedy outputs are identical, and each wave scores how many
    # of the experts the verify pass hit were already warm.  top_m=2 warms
    # half the reduced config's experts — a tight budget, so the hit rate
    # reflects probe quality rather than "warmed everything"
    eng = ServingEngine(target, draft, params_t, params_d, max_batch=8,
                        tuner=tuner, proposer="prefetch",
                        proposer_opts={"top_m": 2}, seed=0)
    pb = prompt_batch(tcfg.vocab_size, 24, kind="chat", seed=5)
    for i in range(24):
        eng.submit(pb["tokens"][i][: pb["lengths"][i]], max_new_tokens=24)
    print("serving 24 requests in waves of ≤8 (prefetch-aware drafting)...")
    for r in eng.run():
        s = r.stats
        extra = (f"sigma={s.sigma:.2f} alpha={s.alpha:.2f} rounds={s.rounds}"
                 if r.used_sd and s else "AR mode")
        if r.used_sd and s and s.prefetch_actual:
            extra += (f" prefetch_hit={r.prefetch_hit_rate:.2f} "
                      f"({r.prefetch_hits}/{s.prefetch_actual})")
        print(f"  wave B={r.batch} gamma={r.gamma} sd={r.used_sd} "
              f"{r.tokens_per_second:6.1f} tok/s  {extra}")

    # continuous batching on the SAME persistent sessions: a fixed pool of
    # KV slots, retire/refill between rounds via masked admission prefills,
    # and the tuner re-planning {use_sd, gamma} on the LIVE slot count
    # every round (the paper's N(t)-dependence operated) — with a Poisson
    # arrival trace and mixed completion lengths, the traffic where wave
    # padding costs the most
    from repro.core.analytics import occupancy_timeline
    from repro.serving.scheduler import submit_poisson
    pb2 = prompt_batch(tcfg.vocab_size, 16, kind="chat", seed=11)
    submit_poisson(eng, pb2["tokens"], pb2["lengths"], rate=1.0,
                   max_new_choices=(8, 16, 24), seed=11)
    print("serving 16 Poisson arrivals through the continuous slot "
          "scheduler (pool of 8)...")
    r = eng.step_continuous()
    occ = occupancy_timeline([s.live for s in r.steps],
                             [s.committed for s in r.steps])
    print(f"  stream: {r.batch} requests, {r.tokens_out} tokens, "
          f"{r.tokens_per_second:6.1f} tok/s over {r.stats.rounds} rounds")
    print(f"  N(t): peak={occ['peak_live']:.0f} mean={occ['mean_live']:.2f} "
          f"token_weighted={occ['token_weighted_live']:.2f} "
          f"occupancy={occ['mean_occupancy']:.2f}")

    # target efficiency, measured on this backend (Sec. 3.1 metric)
    cache = target.init_cache(8, 128)
    toks = jnp.asarray(pb["tokens"][:8, :32])
    _, cache = target.prefill(params_t, toks, cache)
    te = measure_target_efficiency(target, params_t, cache, gamma=4, iters=3)
    print(f"measured target efficiency T(B,1)/T(B,5) = "
          f"{te['target_efficiency']:.2f} (CPU wall-clock)")
    print(f"tuner's final alpha estimate: {tuner.alpha:.2f}")
    for kind, s in eng.session_stats().items():
        if kind == "resilience":
            continue              # fault counters (empty on healthy waves)
        print(f"session[{kind}]: constructed {s['constructions']}x for "
              f"{len(eng.reports)} waves, gammas compiled "
              f"{s['gammas_compiled']}, {len(s['traces'])} round traces")


if __name__ == "__main__":
    main()

"""End-to-end training driver: a ~100M-parameter MoE for a few hundred steps
with checkpointing — the 'train a ~100M model' deliverable (b).

    PYTHONPATH=src python examples/train_100m.py [--steps 300]
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, TrainConfig
from repro.data.pipeline import packed_batches
from repro.models.model import Model
from repro.training.checkpoint import save_checkpoint
from repro.training.train_loop import init_train_state, make_train_step


def model_100m() -> ModelConfig:
    """~100M params: a miniature of the paper's Qwen2-57B-A14B shape
    (same family: GQA + shared-expert MoE, rho=1/8)."""
    return ModelConfig(
        name="moesd-100m", family="moe",
        num_layers=8, d_model=512, num_heads=8, num_kv_heads=2,
        head_dim=64, d_ff=1408, vocab_size=8192,
        num_experts=16, num_experts_per_tok=2, moe_d_ff=512,
        num_shared_experts=1, qkv_bias=True, dtype="float32",
        router_aux_loss_coef=0.01,
        source="scaled-down arXiv:2407.10671",
    )


def main():
    ap = argparse.ArgumentParser()
    # defaults sized for CPU smoke runs (~10 min); on accelerators raise
    # --steps/--batch/--seq freely — the step function is the same one the
    # dry-run lowers for the production mesh
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt-dir", default="results/ckpt_100m")
    args = ap.parse_args()

    cfg = model_100m()
    model = Model(cfg, remat=True)
    params, opt = init_train_state(model, jax.random.PRNGKey(0))
    n = sum(x.size for x in jax.tree.leaves(params))
    print(f"{cfg.name}: {n/1e6:.1f}M params "
          f"({cfg.active_param_count()/1e6:.1f}M active/token)")

    tcfg = TrainConfig(learning_rate=1e-3, total_steps=args.steps,
                       warmup_steps=args.steps // 10)
    step = jax.jit(make_train_step(model, tcfg), donate_argnums=(0, 1))
    it = packed_batches(cfg.vocab_size, args.batch, args.seq, kind="code")

    t0 = time.perf_counter()
    first = last = None
    for i in range(1, args.steps + 1):
        batch = {k: jnp.asarray(v) for k, v in next(it).items()}
        params, opt, m = step(params, opt, batch)
        if i == 1:
            first = float(m["loss"])
        if i % 25 == 0 or i == args.steps:
            last = float(m["loss"])
            tput = args.batch * args.seq * i / (time.perf_counter() - t0)
            counts = m["expert_counts"]
            imbalance = float(jnp.max(counts) / jnp.maximum(
                jnp.mean(counts.astype(jnp.float32)), 1))
            print(f"step {i:4d}  loss {last:.4f}  aux {float(m['aux_loss']):.3f}  "
                  f"expert-imbalance {imbalance:.2f}x  {tput:.0f} tok/s")
    path = save_checkpoint(args.ckpt_dir, args.steps,
                           {"params": params, "opt": opt},
                           {"arch": cfg.name, "loss": last})
    print(f"loss {first:.3f} → {last:.3f}; checkpoint at {path}")
    assert last < first - 1.0, "training must make real progress"


if __name__ == "__main__":
    main()

"""Mini-reproduction of Fig. 4: vary K on the paper's target, predict
speedup with the v5e simulator, fit the Alg. 1 model, print both curves.

    PYTHONPATH=src python examples/sparsity_sweep.py
"""
import numpy as np

from repro.configs.registry import get_config
from repro.core.analytics import activation_threshold, sigma_from_alpha
from repro.core.perf_model import Measurement, SpeedupModel, stride_sample
from repro.core.simulator import Simulator

BATCHES = [1, 2, 4, 8, 16, 24, 32, 48, 64, 96, 128, 192, 256]


def main():
    target = get_config("qwen2-57b-a14b")
    draft = get_config("qwen2-0.5b")
    sim = Simulator()
    sigma = float(sigma_from_alpha(0.8, 4))

    rows = []
    print(f"{'K':>3} {'rho':>6} {'T_thres':>8} {'peak x':>7} {'@B':>5}  curve")
    for K in (1, 2, 4, 8, 16, 32):
        cfg = target.with_overrides(num_experts_per_tok=K)
        curve = [sim.sd_speedup(cfg, draft, b, 4, sigma) for b in BATCHES]
        i = int(np.argmax(curve))
        print(f"{K:3d} {K/64:6.3f} {activation_threshold(K/64):8d} "
              f"{curve[i]:7.2f} {BATCHES[i]:5d}  "
              + " ".join(f"{x:.2f}" for x in curve))
        for b, s in zip(BATCHES, curve):
            rows.append(Measurement(b, 4, K, 64, sigma, s))

    model = SpeedupModel(engine_semantics=True)
    fit = model.fit(stride_sample(rows, 21), target, draft)
    print(f"\nAlg.1 model fitted on 21 points: MSE={fit['mse']:.3f}")
    pred = model.predict([16, 48, 128], [4] * 3, [8] * 3, [64] * 3, [sigma] * 3)
    act = [sim.sd_speedup(target, draft, b, 4, sigma) for b in (16, 48, 128)]
    for b, p, a in zip((16, 48, 128), pred, act):
        print(f"  B={b:3d}: model {p:.2f}x vs simulator {a:.2f}x")


if __name__ == "__main__":
    main()

"""Serving engine: request queue → batched speculative decoding → completions.

Private-serving shape (the paper's target scenario, Sec. 3.4): tens of
concurrent requests, batched together, decoded with SD.  The engine:

  * admits up to ``max_batch`` requests per generation wave (static batch
    per wave, continuous across waves — the moderate-batch regime),
  * consults the AutoTuner (core/autotune.py, beyond-paper) to pick
    {use_sd, gamma} for the admitted batch size from the fitted perf model,
  * runs SpecDecoder rounds until every sequence in the wave is done,
  * reports per-wave SDStats (sigma, alpha, rounds) and target-efficiency
    measurements, feeding alpha back into the tuner.
"""
from __future__ import annotations

import time
from collections import deque
from dataclasses import dataclass, field
from typing import Deque, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.autotune import AutoTuner
from repro.core.spec_decode import SDStats, SpecDecoder, generate_ar
from repro.data.tokenizer import PAD
from repro.models.model import Model


@dataclass
class Request:
    uid: int
    prompt: np.ndarray                   # (T,) token ids
    max_new_tokens: int = 64
    temperature: float = 0.0
    output: Optional[np.ndarray] = None
    submitted_at: float = field(default_factory=time.perf_counter)
    finished_at: Optional[float] = None


@dataclass
class WaveReport:
    batch: int
    gamma: int
    used_sd: bool
    stats: Optional[SDStats]
    wall_time: float
    tokens_out: int

    @property
    def tokens_per_second(self) -> float:
        return self.tokens_out / max(self.wall_time, 1e-9)


class ServingEngine:
    def __init__(
        self,
        target: Model,
        draft: Model,
        params_t,
        params_d,
        *,
        max_batch: int = 32,
        tuner: Optional[AutoTuner] = None,
        gamma: int = 4,
        temperature: float = 0.0,
        force_sd: Optional[bool] = None,
        draft_kind: str = "model",          # "model" | "eagle"
    ):
        self.draft_kind = draft_kind
        self.target, self.draft = target, draft
        self.params_t, self.params_d = params_t, params_d
        self.max_batch = max_batch
        self.tuner = tuner
        self.gamma = gamma
        self.temperature = temperature
        self.force_sd = force_sd
        self.queue: Deque[Request] = deque()
        self.done: Dict[int, Request] = {}
        self.reports: List[WaveReport] = []
        self._uid = 0

    # ----------------------------------------------------------------- queue
    def submit(self, prompt: np.ndarray, max_new_tokens: int = 64) -> int:
        self._uid += 1
        self.queue.append(Request(self._uid, np.asarray(prompt, np.int32),
                                  max_new_tokens))
        return self._uid

    def _admit(self) -> List[Request]:
        wave = []
        while self.queue and len(wave) < self.max_batch:
            wave.append(self.queue.popleft())
        return wave

    # ------------------------------------------------------------------ wave
    def _pad_prompts(self, wave: List[Request]):
        T = max(len(r.prompt) for r in wave)
        toks = np.full((len(wave), T), PAD, np.int32)
        lengths = np.zeros((len(wave),), np.int32)
        for i, r in enumerate(wave):
            toks[i, : len(r.prompt)] = r.prompt
            lengths[i] = len(r.prompt)
        return jnp.asarray(toks), jnp.asarray(lengths)

    def step(self, key: Optional[jax.Array] = None) -> Optional[WaveReport]:
        """Process one wave; returns its report (None if queue empty)."""
        wave = self._admit()
        if not wave:
            return None
        B = len(wave)
        gamma, use_sd = self.gamma, True
        if self.tuner is not None:
            plan = self.tuner.plan(B)
            gamma, use_sd = plan["gamma"], plan["use_sd"]
        if self.force_sd is not None:
            use_sd = self.force_sd
        max_new = max(r.max_new_tokens for r in wave)
        toks, lengths = self._pad_prompts(wave)
        key = key if key is not None else jax.random.PRNGKey(self._uid)

        t0 = time.perf_counter()
        if use_sd:
            if self.draft_kind == "eagle":
                from repro.core.eagle import EagleSpecDecoder
                sd = EagleSpecDecoder(self.target, self.draft, gamma=gamma,
                                      temperature=self.temperature)
            else:
                sd = SpecDecoder(self.target, self.draft, gamma=gamma,
                                 temperature=self.temperature)
            out, stats = sd.generate(self.params_t, self.params_d, toks,
                                     max_new, lengths=lengths, key=key)
            if self.tuner is not None and stats.draft_events:
                self.tuner.update_alpha(stats.alpha)
        else:
            out = generate_ar(self.target, self.params_t, toks, max_new,
                              temperature=self.temperature,
                              lengths=lengths, key=key)
            stats = None
        wall = time.perf_counter() - t0

        n_tokens = 0
        for i, r in enumerate(wave):
            r.output = out[i, : r.max_new_tokens]
            r.finished_at = time.perf_counter()
            n_tokens += len(r.output)
            self.done[r.uid] = r
        report = WaveReport(B, gamma, use_sd, stats, wall, n_tokens)
        self.reports.append(report)
        return report

    def run(self, key: Optional[jax.Array] = None) -> List[WaveReport]:
        reports = []
        while self.queue:
            r = self.step(key)
            if r:
                reports.append(r)
        return reports

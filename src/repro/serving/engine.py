"""Serving engine: request queue → batched speculative decoding → completions.

Private-serving shape (the paper's target scenario, Sec. 3.4): tens of
concurrent requests, batched together, decoded with SD.  Two schedulers:

  * ``scheduler="wave"`` — admit up to ``max_batch`` requests per
    generation wave (static batch per wave, continuous across waves), run
    SD rounds until EVERY sequence in the wave is done.  Finished rows ride
    along as padding until the slowest request completes, and the AutoTuner
    is consulted once per wave.
  * ``scheduler="continuous"`` — a fixed pool of KV-cache slots decoded
    round-by-round (serving/scheduler.py): slots retire the moment their
    request finishes (per-request ``max_new_tokens``, optional ``eos_id``),
    freed slots are refilled by a masked prefill BETWEEN rounds (zero
    retraces within a batch bucket), and the AutoTuner re-plans
    {use_sd, gamma} on the LIVE slot count every round — the paper's
    N(t)-dependence operated, not just measured.

Either way the engine:

  * consults the AutoTuner (core/autotune.py, beyond-paper) to pick
    {use_sd, gamma} from the fitted perf model,
  * holds ONE persistent decoding session (core/spec_decode.SDEngine) per
    proposer kind — "model" / "eagle" / "none" via the Proposer registry —
    so compiled SD rounds are reused across waves instead of re-jitting a
    fresh decoder every wave.  Batches are padded up to power-of-two
    buckets and cache lengths are bucketed too, so the jit cache is keyed
    on (proposer_kind, gamma, batch_bucket) and a tuner-driven gamma change
    only adds one cache entry (returning to a seen gamma is compile-free),
  * reports per-wave SDStats (sigma, alpha, rounds, phase timings) and
    target-efficiency measurements, feeding alpha back into the tuner.

Every wave gets its own PRNG key split from the engine's root key, so
sampling is never correlated across waves.

Per-request sampling: each ``Request`` carries ``SamplingParams``
(serving/sampling.py).  ``max_new_tokens`` is honored per request (and per
SLOT in continuous mode); ``temperature``/``top_k``/``top_p`` must match
the engine's global policy — batched rejection sampling shares one
temperature across the batch — and ``submit`` fails loudly on mismatch
rather than silently decoding with the wrong policy.
"""
from __future__ import annotations

import time
from collections import deque
from dataclasses import dataclass, field
from typing import Deque, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.autotune import AutoTuner
from repro.core.proposer import make_proposer
from repro.core.spec_decode import SDEngine, SDStats
from repro.data.tokenizer import PAD
from repro.distributed.collectives import ep_load_report
from repro.distributed.constraints import resolve_mesh
from repro.distributed.sharding import shard_params
from repro.models.model import Model
from repro.serving.sampling import SamplingParams


@dataclass
class Request:
    uid: int
    prompt: np.ndarray                   # (T,) token ids
    max_new_tokens: int = 64
    temperature: float = 0.0
    output: Optional[np.ndarray] = None
    submitted_at: float = field(default_factory=time.perf_counter)
    finished_at: Optional[float] = None
    sampling: Optional[SamplingParams] = None
    # "length" | "eos" | "rejected" | "numerical_fault" | "timeout" |
    # "admit_failed" | "aborted"  (docs/faults.md has the full table)
    finish_reason: Optional[str] = None
    arrival_round: int = 0               # continuous mode: visible from here
    # ---- resilience traceability (continuous mode; docs/faults.md) ----
    preempt_count: int = 0               # times page pressure evicted us
    requeue_round: Optional[int] = None  # round of the LAST preemption
    readmit_round: Optional[int] = None  # round of the last re-admission
    resume_tokens: Optional[List[int]] = None  # committed tokens to replay
    rounds_used: int = 0                 # decode rounds spent on this slot
    admit_attempts: int = 0              # transient admission failures seen


def finish_output(tokens: np.ndarray, eos_id: Optional[int]):
    """Truncate a generated stream at the first ``eos_id`` (inclusive).

    Returns ``(tokens, reason)`` with reason "eos" if an eos fired before
    the length budget, else "length" — the per-request accounting both
    schedulers share, so ``WaveReport.tokens_out`` counts only REAL
    generated tokens."""
    tokens = np.asarray(tokens)
    if eos_id is not None:
        hits = np.nonzero(tokens == eos_id)[0]
        if hits.size:
            return tokens[: int(hits[0]) + 1], "eos"
    return tokens, "length"


@dataclass
class WaveReport:
    batch: int
    gamma: int
    used_sd: bool
    stats: Optional[SDStats]
    wall_time: float
    tokens_out: int
    proposer: str = "model"
    bucket: int = 0                       # padded batch actually decoded
    moe_dispatch: str = "onehot"          # target's decode dispatch mode
    scheduler: str = "wave"               # "wave" | "continuous"
    steps: Optional[list] = None          # continuous: per-round StepReports
    # continuous-mode resilience accounting: committed tokens belonging to
    # requests that did NOT finish cleanly ("rejected"/"timeout"/
    # "numerical_fault"/"admit_failed"/"aborted") or that were discarded by
    # a preempt-and-requeue.  Excluded from ``tokens_out`` so tokens/sec
    # reflects only useful delivered work; never double-counts a requeued
    # request's recomputed prefix.
    tokens_discarded: int = 0
    finish_reasons: Optional[Dict[str, int]] = None  # reason -> count
    # expert-parallel telemetry (mesh-sharded "ep" dispatch only): the
    # finished outputs' per-shard expert-load counts, load imbalance
    # (max/mean) and modeled per-device a2a volume
    # (distributed.collectives.ep_load_report); None otherwise
    ep: Optional[dict] = None

    @property
    def tokens_per_second(self) -> float:
        return self.tokens_out / max(self.wall_time, 1e-9)

    # per-phase decode timings (propose/verify/reject populated when the
    # engine runs with timed=True; round_time is always real wall time)
    @property
    def round_time(self) -> float:
        return self.stats.round_time if self.stats else 0.0

    @property
    def propose_time(self) -> float:
        return self.stats.propose_time if self.stats else 0.0

    @property
    def verify_time(self) -> float:
        return self.stats.verify_time if self.stats else 0.0

    @property
    def reject_time(self) -> float:
        return self.stats.reject_time if self.stats else 0.0

    @property
    def warm_time(self) -> float:
        return self.stats.warm_time if self.stats else 0.0

    # expert-prefetch accounting (prefetch-aware waves; zero otherwise)
    @property
    def prefetch_hits(self) -> int:
        return self.stats.prefetch_hits if self.stats else 0

    @property
    def prefetch_misses(self) -> int:
        return self.stats.prefetch_misses if self.stats else 0

    @property
    def prefetch_hit_rate(self) -> float:
        return self.stats.prefetch_hit_rate if self.stats else 0.0


def _pow2_at_least(n: int) -> int:
    b = 1
    while b < n:
        b *= 2
    return b


class ServingEngine:
    def __init__(
        self,
        target: Model,
        draft=None,                         # Model | EagleHead | None
        params_t=None,
        params_d=None,
        *,
        max_batch: int = 32,
        tuner: Optional[AutoTuner] = None,
        gamma: int = 4,
        temperature: float = 0.0,
        force_sd: Optional[bool] = None,
        proposer: str = "model",            # registered proposer kind
        proposer_opts: Optional[dict] = None,  # extra factory kwargs for it
        draft_kind: Optional[str] = None,   # deprecated alias for proposer
        seed: int = 0,
        timed: bool = False,
        bucket_batches: bool = True,
        scheduler: str = "wave",            # "wave" | "continuous"
        eos_id: Optional[int] = None,       # early-exit token (both modes)
        kv_layout: str = "dense",           # "dense" | "paged" (continuous)
        page_size: int = 64,                # paged: positions per KV page
        prefill_chunk: Optional[int] = None,  # continuous: chunked prefill
        admit_mode: str = "sliced",         # "sliced" | "full" (legacy)
        prefix_sharing: bool = False,       # paged: fork shared prompt prefixes
        admission_order: str = "fifo",      # "fifo" | "pressure" refill order
        resilience=None,                    # Optional[ResilienceConfig]
        fault_injector=None,                # Optional[FaultInjector] (tests)
        mesh=None,                          # Optional[Mesh]: sharded serving
        mesh_layout: Optional[str] = None,  # "tp" | "fsdp" (with mesh)
    ):
        if scheduler not in ("wave", "continuous"):
            raise ValueError(f"scheduler must be 'wave' or 'continuous', "
                             f"got {scheduler!r}")
        if kv_layout not in ("dense", "paged"):
            raise ValueError(f"kv_layout must be 'dense' or 'paged', "
                             f"got {kv_layout!r}")
        if admit_mode not in ("sliced", "full"):
            raise ValueError(f"admit_mode must be 'sliced' or 'full', "
                             f"got {admit_mode!r}")
        if kv_layout == "paged":
            if scheduler != "continuous":
                raise ValueError("kv_layout='paged' is a continuous-serving "
                                 "layout; wave decoding sizes caches per "
                                 "wave already")
            if admit_mode == "full":
                raise ValueError("admit_mode='full' merges same-shape "
                                 "caches and cannot address a paged pool; "
                                 "use the sliced path with paged KV")
        if admission_order not in ("fifo", "pressure"):
            raise ValueError(f"admission_order must be 'fifo' or "
                             f"'pressure', got {admission_order!r}")
        if admission_order == "pressure" and kv_layout != "paged":
            raise ValueError("admission_order='pressure' orders refills by "
                             "page footprint; it requires kv_layout='paged'")
        if prefix_sharing:
            if kv_layout != "paged":
                raise ValueError(
                    "prefix_sharing maps common prompt prefixes to shared "
                    "KV pages; it requires kv_layout='paged' (continuous "
                    "scheduler)")
            bad = [k for k in target.cfg.layer_pattern
                   if k not in ("attn", "mla")]
            if bad:
                raise ValueError(
                    f"prefix_sharing forks block-table pages; target layer "
                    f"kinds {sorted(set(bad))} keep dense per-row state "
                    "(SWA rings / recurrent columns) that a table fork "
                    "cannot share — serve this model without sharing")
        if prefill_chunk is not None:
            if prefill_chunk < 1:
                raise ValueError(f"prefill_chunk must be >= 1, got "
                                 f"{prefill_chunk}")
            if scheduler != "continuous":
                raise ValueError("prefill_chunk interleaves with decode "
                                 "rounds; it requires scheduler="
                                 "'continuous'")
            from repro.models.attention import SWA_RING_PAD
            if (any(k == "swa" for k in target.cfg.layer_pattern)
                    and prefill_chunk > SWA_RING_PAD + 1):
                raise ValueError(
                    f"prefill_chunk={prefill_chunk} > SWA_RING_PAD+1="
                    f"{SWA_RING_PAD + 1}: a larger chunk evicts ring "
                    "entries still inside earlier chunk queries' windows")
        # ------- expert-parallel sharded serving (docs/distributed.md) ----
        # the mesh is threaded EXPLICITLY: engine → model constraints / ep
        # dispatch → SDEngine sessions (host placement + cache_spec); no
        # process-global mesh state (constraints.set_mesh is removed)
        if mesh is not None:
            mesh, mesh_layout = resolve_mesh(mesh, mesh_layout)
            if "model" not in mesh.axis_names:
                raise ValueError(
                    f"ServingEngine(mesh=...) needs a 'model' axis for the "
                    f"expert/TP dimension; got axes {mesh.axis_names} "
                    "(launch/mesh.make_ep_mesh builds a ('data','model') "
                    "mesh)")
            if getattr(target, "mesh", None) is None:
                target.mesh = mesh
                target.mesh_layout = mesh_layout
            if isinstance(draft, Model) and draft.mesh is None:
                draft.mesh = mesh
                draft.mesh_layout = mesh_layout
            # expert weights shard over "model" (EP), attention/router per
            # param_spec; placed once here so every session reuses them
            if params_t is not None:
                params_t = jax.device_put(
                    params_t, shard_params(params_t, mesh,
                                           layout=mesh_layout))
            if params_d is not None:
                params_d = jax.device_put(
                    params_d, shard_params(params_d, mesh,
                                           layout=mesh_layout))
        self.mesh = mesh
        self.mesh_layout = mesh_layout
        self.proposer_kind = draft_kind if draft_kind is not None else proposer
        self.proposer_opts = dict(proposer_opts or {})
        self.target, self.draft = target, draft
        self.params_t, self.params_d = params_t, params_d
        self.max_batch = max_batch
        self.tuner = tuner
        self.gamma = gamma
        self.temperature = temperature
        self.force_sd = force_sd
        self.timed = timed
        self.bucket_batches = bucket_batches
        self.scheduler = scheduler
        self.eos_id = eos_id
        self.kv_layout = kv_layout
        self.page_size = page_size
        self.prefill_chunk = prefill_chunk
        self.admit_mode = admit_mode
        self.prefix_sharing = prefix_sharing
        self.admission_order = admission_order
        if resilience is None:
            from repro.serving.faults import ResilienceConfig
            resilience = ResilienceConfig()
        if (resilience.round_deadline_s is not None
                or resilience.max_rounds_per_request is not None
                or fault_injector is not None) and scheduler != "continuous":
            raise ValueError(
                "resilience deadlines and fault injection are continuous-"
                "scheduler features (wave mode has no per-round requeue "
                "path); use scheduler='continuous'")
        self.resilience = resilience
        self.fault_injector = fault_injector
        # fault/preemption/recovery counters, filled by the continuous
        # scheduler and surfaced via session_stats()["resilience"]
        self.fault_counters: Dict[str, int] = {}
        self.queue: Deque[Request] = deque()
        self.done: Dict[int, Request] = {}
        self.reports: List[WaveReport] = []
        self._uid = 0
        self._key = jax.random.PRNGKey(seed)
        # persistent decoding sessions, one per proposer kind — constructed
        # exactly once and reused for every wave (compile-cache lives inside)
        self._sessions: Dict[str, SDEngine] = {}
        self.session_constructions: Dict[str, int] = {}
        self._slot_scheduler = None         # lazy ContinuousScheduler

    # ----------------------------------------------------------------- queue
    def submit(self, prompt: np.ndarray, max_new_tokens: int = 64, *,
               sampling: Optional[SamplingParams] = None,
               arrival_round: int = 0) -> int:
        """Queue one request.

        Parameters
        ----------
        prompt : array-like
            (T,) token ids.
        max_new_tokens : int
            Generation budget (ignored if ``sampling`` is given — its
            ``max_new_tokens`` wins).
        sampling : SamplingParams, optional
            Per-request sampling policy.  ``max_new_tokens`` is honored per
            request; ``temperature`` must equal the engine's and
            ``top_k``/``top_p`` must be off — batched rejection sampling
            shares one distribution policy across the batch, so a mismatch
            raises ``ValueError`` instead of silently decoding with the
            wrong policy (build one engine per policy).
        arrival_round : int
            Continuous mode: the request becomes admissible only from this
            decode round on (workload drivers use it to replay
            Poisson-arrival traces).  Wave mode ignores it.

        Returns
        -------
        int
            The request uid (key into ``self.done`` once finished).
        """
        sp = sampling if sampling is not None else SamplingParams(
            temperature=self.temperature, max_new_tokens=max_new_tokens)
        if sp.temperature != self.temperature:
            raise ValueError(
                f"per-request temperature {sp.temperature} != engine "
                f"temperature {self.temperature}: batched rejection sampling "
                "shares one temperature across the batch — submit matching "
                "requests or build an engine per policy")
        if sp.top_k > 0 or sp.top_p < 1.0:
            raise ValueError(
                "top_k/top_p are not supported on the speculative-decoding "
                "path (rejection sampling needs the full target/draft "
                "distributions); submit with default top_k=0, top_p=1.0")
        self._uid += 1
        self.queue.append(Request(self._uid, np.asarray(prompt, np.int32),
                                  sp.max_new_tokens, sp.temperature,
                                  sampling=sp, arrival_round=arrival_round))
        return self._uid

    def _admit(self) -> List[Request]:
        wave = []
        while self.queue and len(wave) < self.max_batch:
            wave.append(self.queue.popleft())
        return wave

    @property
    def moe_dispatch(self) -> str:
        """The target model's MoE dispatch mode for this engine's decodes
        (launch/serve defaults it to "gmm" — the ragged serving kernels)."""
        return getattr(self.target, "moe_dispatch", "onehot")

    def _ep_telemetry(self, outputs) -> Optional[dict]:
        """Per-wave expert-parallel load report over the finished outputs
        (``WaveReport.ep``): per-shard expert loads, imbalance, and modeled
        a2a volume.  None unless this is a mesh-sharded "ep" engine."""
        if self.mesh is None or self.moe_dispatch != "ep":
            return None
        toks = [np.asarray(o).reshape(-1) for o in outputs if o is not None]
        toks = (np.concatenate(toks) if toks
                else np.zeros((0,), np.int32))
        return ep_load_report(self.params_t, self.target.cfg, toks,
                              int(self.mesh.shape["model"]))

    # -------------------------------------------------------------- sessions
    def _session(self, kind: str) -> SDEngine:
        """The long-lived decoding session for one proposer kind."""
        sess = self._sessions.get(kind)
        if sess is None:
            # kind-specific factory opts only apply to the configured kind
            # (never to the "none" AR-fallback session)
            opts = self.proposer_opts if kind == self.proposer_kind else {}
            prop = make_proposer(kind, self.target,
                                 None if kind == "none" else self.draft,
                                 temperature=self.temperature, **opts)
            sess = SDEngine(self.target, prop, gamma=self.gamma,
                            temperature=self.temperature, mesh=self.mesh,
                            mesh_layout=self.mesh_layout)
            self._sessions[kind] = sess
            self.session_constructions[kind] = \
                self.session_constructions.get(kind, 0) + 1
        return sess

    def session_stats(self) -> Dict[str, dict]:
        """Per-proposer-kind session health: reuse, traces, prefetch totals.

        Returns
        -------
        dict
            One entry per proposer kind this engine has served, each with:

            ``constructions`` : int
                Times the session was built (always 1 per kind — waves
                reuse sessions; tests assert on it).
            ``gammas_compiled`` : list of int
                Gammas with a built (fused or staged) decode round.
            ``traces`` : list of (gamma, batch)
                Every jit retrace the session performed; a wave that reuses
                a compiled round adds nothing here.
            ``admit_traces`` : list of (prompt_bucket, rows)
                Every continuous-admission retrace.  Sliced admissions
                key on the ADMITTED row bucket (rows << pool for typical
                refills); the legacy full path keys on the pool.  Which
                rows admit is data and adds nothing here.
            ``chunk_traces`` : list of (stage, chunk, rows)
                Chunked-prefill retraces ("first"/"mid"/"final" stage
                functions, compiled once per shape).
            ``prefix_traces`` : list of (tail_bucket, rows)
                Prefix-shared tail-admission retraces
                (``SDEngine.admit_rows_prefix``; empty unless the engine
                runs with ``prefix_sharing=True``).
            ``growths`` : list of (new_max_seq, pool_pages)
                Paged-session capacity growths (each one retrace, pow2-
                amortized).
            ``prefetch`` : dict
                Session-lifetime expert-warmup aggregates ``{"hits",
                "actual", "predicted", "rounds", "hit_rate"}`` summed over
                all waves (all zero unless the kind is prefetch-aware).

            Plus ONE reserved non-kind entry, ``"resilience"``: the
            continuous scheduler's fault/preemption/recovery counters
            (``preemptions``, ``requeues``, ``numerical_faults``,
            ``slow_rounds``, ``timeouts``, ``admit_deferred``, ... —
            docs/faults.md).  Empty dict for wave mode / healthy streams.
        """
        out = {"resilience": dict(self.fault_counters)}
        for kind, sess in self._sessions.items():
            totals = dict(sess.prefetch_totals)
            totals["hit_rate"] = totals["hits"] / max(totals["actual"], 1)
            out[kind] = {
                "constructions": self.session_constructions.get(kind, 0),
                "gammas_compiled": sess.compiled_gammas(),
                "traces": list(sess.trace_log),
                "admit_traces": list(sess.admit_trace_log),
                "chunk_traces": list(sess.chunk_trace_log),
                "prefix_traces": list(sess.prefix_trace_log),
                "growths": list(sess.growth_log),
                "prefetch": totals,
            }
        return out

    # ------------------------------------------------------------------ wave
    def _bucket(self, B: int) -> int:
        if not self.bucket_batches:
            return B
        return min(_pow2_at_least(B), self.max_batch)

    def _pad_prompts(self, wave: List[Request], rows: int):
        """Pad the wave to ``rows`` sequences (bucket) x pow2 prompt length.
        Pad rows replicate real requests round-robin (so wave stats weight
        each request near-equally rather than over-counting one sequence)
        and are discarded after decode."""
        T = max(len(r.prompt) for r in wave)
        if self.bucket_batches:
            T = _pow2_at_least(T)
        toks = np.full((rows, T), PAD, np.int32)
        lengths = np.zeros((rows,), np.int32)
        for i in range(rows):
            r = wave[i % len(wave)]
            toks[i, : len(r.prompt)] = r.prompt
            lengths[i] = len(r.prompt)
        return jnp.asarray(toks), jnp.asarray(lengths)

    def _next_key(self) -> jax.Array:
        self._key, k = jax.random.split(self._key)
        return k

    def step(self, key: Optional[jax.Array] = None) -> Optional[WaveReport]:
        """Admit and decode one generation wave.

        Pops up to ``max_batch`` queued requests, consults the tuner for
        {use_sd, gamma} at the padded bucket size, decodes the wave through
        the persistent session for the active proposer kind, and records
        finished requests in ``self.done``.

        Parameters
        ----------
        key : jax.Array, optional
            PRNG key for this wave's sampling.  Default: a fresh split from
            the engine's root key (so waves are never key-correlated).

        Returns
        -------
        WaveReport or None
            The wave's report — batch/gamma/proposer, SDStats (sigma,
            alpha, per-phase timings, prefetch hit/miss counts for
            prefetch-aware waves), wall time and tokens/sec — or ``None``
            if the queue was empty.  ``tokens_out`` counts only real
            generated tokens: per-request ``max_new_tokens`` and eos
            truncation (``finish_reason``) are applied per request.
        """
        wave = self._admit()
        if not wave:
            return None
        B = len(wave)
        bucket = self._bucket(B)
        gamma, use_sd = self.gamma, True
        if self.tuner is not None:
            # plan for the batch size that actually executes (the padded
            # bucket), so policy and the alpha fed back describe one regime
            plan = self.tuner.plan(bucket)
            gamma, use_sd = plan["gamma"], plan["use_sd"]
        if self.force_sd is not None:
            use_sd = self.force_sd
        if self.proposer_kind == "none":
            use_sd = False
        kind = self.proposer_kind if use_sd else "none"
        if not use_sd:
            gamma = 0
        sess = self._session(kind)
        max_new = max(r.max_new_tokens for r in wave)
        toks, lengths = self._pad_prompts(wave, bucket)
        # bucket the cache length too so waves of similar shape share a
        # compiled round instead of retracing on every new max_seq
        max_seq = toks.shape[1] + max_new + gamma + 2
        if self.bucket_batches:
            max_seq = _pow2_at_least(max_seq)
        key = key if key is not None else self._next_key()

        t0 = time.perf_counter()
        out, stats = sess.generate(
            self.params_t, None if kind == "none" else self.params_d,
            toks, max_new, gamma=gamma, max_seq=max_seq, lengths=lengths,
            key=key, timed=self.timed)
        if use_sd and self.tuner is not None and stats.draft_events:
            self.tuner.update_alpha(stats.alpha)
        wall = time.perf_counter() - t0

        n_tokens = 0
        for i, r in enumerate(wave):                 # pad rows fall off here
            r.output, r.finish_reason = finish_output(
                out[i, : r.max_new_tokens], self.eos_id)
            r.finished_at = time.perf_counter()
            n_tokens += len(r.output)
            self.done[r.uid] = r
        report = WaveReport(B, gamma, use_sd, stats, wall, n_tokens,
                            proposer=kind, bucket=bucket,
                            moe_dispatch=self.moe_dispatch,
                            ep=self._ep_telemetry([r.output for r in wave]))
        self.reports.append(report)
        return report

    # ------------------------------------------------------------ continuous
    def step_continuous(self) -> Optional[WaveReport]:
        """Drain the queue through the continuous slot scheduler.

        One call serves the WHOLE queued stream (arrivals included, via
        ``Request.arrival_round``) round-by-round on a fixed pool of
        ``max_batch`` KV slots, re-planning {use_sd, gamma} on the live
        slot count every round.  Returns one aggregated WaveReport
        (``scheduler="continuous"``) whose ``steps`` carry the per-round
        StepReports, or ``None`` if the queue was empty.
        """
        from repro.serving.scheduler import ContinuousScheduler
        if self._slot_scheduler is None:
            self._slot_scheduler = ContinuousScheduler(self)
        before = set(self.done)
        report = self._slot_scheduler.run_stream()
        if report is not None:
            report.ep = self._ep_telemetry(
                [r.output for uid, r in self.done.items()
                 if uid not in before])
            self.reports.append(report)
        return report

    def run(self, key: Optional[jax.Array] = None) -> List[WaveReport]:
        """Drain the queue under the configured scheduler.  ``key``
        (optional) reseeds the engine's root key; every wave / round then
        decodes under its own split — never the same key twice."""
        if key is not None:
            self._key = key
        reports = []
        if self.scheduler == "continuous":
            while self.queue:
                r = self.step_continuous()
                if r:
                    reports.append(r)
            return reports
        while self.queue:
            r = self.step()
            if r:
                reports.append(r)
        return reports

"""Fault taxonomy, numerical sentinel and deterministic fault injection.

The serving stack's resilience layer (docs/faults.md) is only trustworthy
if every degraded path is exercised in CI, and degraded paths are — by
definition — hard to reach from a healthy stream.  This module closes
that gap with three pieces:

  * ``logits_finite`` — the jitted per-row NUMERICAL SENTINEL the verify
    stage runs on its raw logits every round (core/spec_decode.py).  It
    must see the logits BEFORE ``probs_from_logits``: the greedy branch
    is a one-hot argmax, and argmax of an all-NaN row returns a perfectly
    valid index — probabilities hide the fault, raw logits cannot.
  * ``poison_cache_row`` / ``FaultInjector`` — a seeded, scripted
    injector the continuous scheduler consults at fixed hook points
    (page pressure, NaN KV, slow rounds, admission failure), so fault
    handling is tested with DETERMINISTIC replays rather than luck.
  * ``ResilienceConfig`` — the knobs of the degradation ladder
    (watermarks, deadlines, budgets, retry/backoff, AR cooldown, safe
    stop) consumed by ``serving/scheduler.ContinuousScheduler``.

Run ``python -m repro.serving.faults`` for the CI smoke lane: a seeded
injector stream (page exhaustion + NaN row + slow round) must complete
with the expected finish_reasons, zero leaked pages, and — replayed on
the same warm engine — zero XLA compiles under the compile guard.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional, Tuple

import jax.numpy as jnp
import numpy as np

from repro.models.model import _PAGED_LEAF_PAIRS


def logits_finite(logits: jnp.ndarray) -> jnp.ndarray:
    """Per-row finite check on raw verify logits: (B, W, V) → (B,) bool.

    The numerical sentinel of the SD round (core/spec_decode.py): a row
    is healthy iff EVERY logit it produced this round is finite.  Runs
    inside the jitted verify stage — one fused reduction, no host sync —
    and must be evaluated on the raw logits, not on probabilities: the
    greedy ``probs_from_logits`` path is ``one_hot(argmax)``, and argmax
    over an all-NaN row still returns a valid index, silently laundering
    the fault into a legal-looking token.
    """
    return jnp.all(jnp.isfinite(logits),
                   axis=tuple(range(1, logits.ndim)))


def poison_cache_row(t_cache: dict, row: int) -> dict:
    """Return a copy of a target cache with one row's KV set to NaN.

    Fault-injection helper (never on the serving path): NaN-poisons every
    float leaf of pool row ``row`` so the NEXT verify pass over that row
    produces non-finite logits — the realistic presentation of a corrupted
    KV page or an overflowed activation.  Dense leaves (batch on axis 1)
    poison the whole row; paged leaves poison the physical pages the
    row's block table currently owns (trash page 0 excluded), so only
    positions attributable to this row are touched.  Co-batched rows are
    unaffected: attention masks by position with ``jnp.where`` and MoE
    routing is per-token, so the NaN cannot leak across rows — exactly
    the isolation property the quarantine test pins.
    """
    pages = t_cache.get("pages")
    pids = np.zeros((0,), np.int64)
    if pages is not None:
        trow = np.asarray(pages["table"])[row]
        pids = np.unique(trow[trow > 0])
    paged_keys = {k for k, _ in _PAGED_LEAF_PAIRS}
    layers = []
    for slot in t_cache["layers"]:
        out = {}
        for k, leaf in slot.items():
            if not jnp.issubdtype(leaf.dtype, jnp.floating):
                out[k] = leaf
            elif k in paged_keys:
                out[k] = (leaf.at[:, jnp.asarray(pids)].set(jnp.nan)
                          if pids.size else leaf)
            else:
                out[k] = leaf.at[:, row].set(jnp.nan)
        layers.append(out)
    return dict(t_cache, layers=layers)


@dataclass
class ResilienceConfig:
    """Knobs of the continuous scheduler's degradation ladder.

    All defaults are permissive: a default-constructed config changes
    NOTHING about a healthy stream (no watermark, no deadline, no
    budgets), so resilience is pay-for-what-you-configure.

    ``round_deadline_s``
        Per-round wall-clock deadline; a slower round counts as faulty
        toward the ladder (it is not killed — JAX dispatches are not
        interruptible — but repeated slow rounds escalate).
    ``max_rounds_per_request``
        Per-request round budget; a request still live after this many
        decode rounds finishes with ``finish_reason="timeout"``.
    ``free_page_watermark``
        Admission backpressure: defer an admission that would leave the
        paged pool's free fraction below this (unless the pool is idle,
        where deferring could deadlock).  Headroom protects in-flight
        growth; pair with ``max_pool_pages``.
    ``max_pool_pages``
        Hard cap on physical page-pool growth.  Once reached, page
        pressure is resolved by PREEMPTION (youngest non-protected slot
        is requeued, vLLM-style recompute) instead of growth.
    ``admit_retries`` / ``admit_backoff_rounds``
        Bounded retry for transient admission failures: attempt ``i``
        requeues the request ``backoff * 2**(i-1)`` rounds out; past the
        budget it finishes ``admit_failed``.
    ``faulty_rounds_to_ar`` / ``faulty_rounds_to_stop``
        The ladder: this many CONSECUTIVE faulty rounds (numerical fault,
        deadline overrun, or acceptance collapse) force gamma=0 AR
        rounds; this many force a stream-level safe stop (everything
        in flight finishes ``aborted`` rather than hanging).
    ``collapse_alpha``
        Acceptance-collapse detector: an SD round whose empirical
        acceptance falls below this counts as faulty (0 disables).
    ``stall_rounds``
        Watchdog: this many consecutive no-progress rounds (nothing
        committed, admitted, or advanced while work is queued) trigger
        the safe stop — the backstop against admission deadlock.
    """
    round_deadline_s: Optional[float] = None
    max_rounds_per_request: Optional[int] = None
    free_page_watermark: float = 0.0
    max_pool_pages: Optional[int] = None
    admit_retries: int = 3
    admit_backoff_rounds: int = 1
    faulty_rounds_to_ar: int = 2
    faulty_rounds_to_stop: int = 8
    collapse_alpha: float = 0.0
    stall_rounds: int = 512


@dataclass(frozen=True)
class Fault:
    """One scripted fault: ``kind`` fires at decode round ``round``.

    Kinds (the taxonomy in docs/faults.md):

    ``"nan_row"``
        NaN-poison the KV of pool row ``row`` (default: first active
        row) before the round decodes → the sentinel quarantines it.
    ``"page_exhaustion"``
        Reserve ``pages`` free pages (default: all of them) from the
        ``PageAllocator`` for ``hold_rounds`` rounds → admissions see
        real page pressure (watermark deferral / preemption).
    ``"slow_round"``
        Sleep ``delay_s`` inside the round's wall-clock window → the
        round watchdog sees a deadline overrun.
    ``"admit_fail"``
        Every admission attempted this round fails transiently → the
        retry-with-backoff path requeues it.
    """
    round: int
    kind: str
    row: Optional[int] = None
    pages: Optional[int] = None
    hold_rounds: int = 1
    delay_s: float = 0.0

    KINDS = ("nan_row", "page_exhaustion", "slow_round", "admit_fail")

    def __post_init__(self):
        if self.kind not in self.KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r}; "
                             f"one of {self.KINDS}")


class FaultInjector:
    """Seeded, deterministic fault script for one continuous stream.

    The scheduler consults the injector at fixed hook points each round
    (``page_service`` → ``admission_fails`` → ``nan_rows`` →
    ``slow_delay``); faults fire exactly at their scripted round, so a
    fault stream REPLAYS byte-identically — the property every test in
    tests/test_faults.py and the CI smoke lane rely on.  ``injected``
    counts fires per kind; an injector is single-use per stream (held
    pages carry state), build a fresh one per run.
    """

    def __init__(self, faults=(), *, seed: int = 0):
        self.faults: Tuple[Fault, ...] = tuple(
            sorted(faults, key=lambda f: (f.round, f.kind)))
        self.seed = seed
        self.injected: Dict[str, int] = {k: 0 for k in Fault.KINDS}
        self._held: List[Tuple[int, List[int]]] = []   # (release_round, pages)

    @classmethod
    def poisson(cls, rate: float, n_rounds: int, *, seed: int = 0,
                kinds: Tuple[str, ...] = ("nan_row", "page_exhaustion")
                ) -> "FaultInjector":
        """Build a scripted injector from a Bernoulli(rate)-per-round
        draw — the benchmark's fault-rate knob.  The script is derived
        ONCE from the seed (faults at fixed rounds), so two injectors
        with the same arguments replay identically."""
        rng = np.random.default_rng(seed)
        faults = []
        for r in range(n_rounds):
            if rng.random() < rate:
                kind = str(rng.choice(kinds))
                faults.append(Fault(round=r, kind=kind, hold_rounds=2))
        return cls(faults, seed=seed)

    def _due(self, round_idx: int, kind: str) -> List[Fault]:
        return [f for f in self.faults
                if f.round == round_idx and f.kind == kind]

    # ------------------------------------------------------------- hooks
    def page_service(self, round_idx: int, alloc) -> None:
        """Round-start hook: release expired page holds, then apply the
        holds scripted for this round (reserving real pages from the
        allocator's free list, so exhaustion is indistinguishable from
        organic pressure).  Holds are finite by construction — a
        scripted exhaustion can stall a stream, never deadlock it."""
        still = []
        for release_at, pages in self._held:
            if round_idx >= release_at:
                alloc.release(pages)
            else:
                still.append((release_at, pages))
        self._held = still
        for f in self._due(round_idx, "page_exhaustion"):
            n = len(alloc.free) if f.pages is None \
                else min(f.pages, len(alloc.free))
            if n:
                self._held.append((round_idx + max(f.hold_rounds, 1),
                                   alloc.reserve(n)))
                self.injected["page_exhaustion"] += 1

    def release_all(self, alloc) -> None:
        """End-of-stream hook: return every still-held page so the
        zero-leak invariant can be asserted unconditionally."""
        for _, pages in self._held:
            alloc.release(pages)
        self._held = []

    def admission_fails(self, round_idx: int) -> bool:
        """True iff admissions this round are scripted to fail
        transiently (exercises retry-with-backoff)."""
        due = self._due(round_idx, "admit_fail")
        if due:
            self.injected["admit_fail"] += len(due)
        return bool(due)

    def nan_rows(self, round_idx: int) -> List[Fault]:
        """The NaN-poison faults scripted for this round (the scheduler
        resolves ``row=None`` to the first active row and applies
        :func:`poison_cache_row`)."""
        due = self._due(round_idx, "nan_row")
        self.injected["nan_row"] += len(due)
        return due

    def slow_delay(self, round_idx: int) -> float:
        """Seconds of scripted stall inside this round's wall-clock
        window (0.0 on healthy rounds)."""
        total = sum(f.delay_s for f in self._due(round_idx, "slow_round"))
        if total:
            self.injected["slow_round"] += 1
        return total


# --------------------------------------------------------------------------
# CI smoke lane: seeded fault stream + zero-compile replay
# --------------------------------------------------------------------------

def _smoke_injector() -> FaultInjector:
    return FaultInjector([
        Fault(round=2, kind="page_exhaustion", hold_rounds=3),
        Fault(round=6, kind="nan_row"),
        Fault(round=7, kind="slow_round", delay_s=0.03),
        Fault(round=1, kind="admit_fail"),
    ], seed=0)


def _smoke_engine():
    import jax
    from repro.configs.base import ModelConfig
    from repro.models.model import Model
    from repro.serving.engine import ServingEngine
    tcfg = ModelConfig("fault-moe", "moe", 2, 128, 4, 2, 256, 512,
                       num_experts=4, num_experts_per_tok=2,
                       dtype="float32")
    dcfg = ModelConfig("fault-draft", "dense", 2, 64, 2, 2, 128, 512,
                       dtype="float32")
    t, d = Model(tcfg), Model(dcfg)
    pt, pd = t.init(jax.random.PRNGKey(0)), d.init(jax.random.PRNGKey(1))
    # ladder thresholds far above what the script can reach: warmup rounds
    # pay compile time (deadline overruns), and an AR handoff mid-warmup
    # would give warmup and replay different commit schedules — the replay
    # must retrace nothing, so both runs must take identical round shapes
    return ServingEngine(
        t, d, pt, pd, max_batch=3, gamma=2, force_sd=True,
        scheduler="continuous", kv_layout="paged", page_size=8,
        resilience=ResilienceConfig(round_deadline_s=0.02,
                                    max_pool_pages=16,
                                    faulty_rounds_to_ar=64,
                                    faulty_rounds_to_stop=128))


def _smoke_submit(eng):
    # budgets long enough that slots are still live when the round-6 NaN
    # and round-7 slow faults fire, even if every draft is accepted
    eng.submit(np.arange(3, 9), max_new_tokens=24)
    eng.submit(np.arange(4, 10), max_new_tokens=16, arrival_round=0)
    eng.submit(np.arange(5, 11), max_new_tokens=16, arrival_round=1)
    eng.submit(np.arange(6, 12), max_new_tokens=12, arrival_round=4)


def _smoke_stream(eng):
    eng.fault_injector = _smoke_injector()
    _smoke_submit(eng)
    reports = eng.run()
    reasons = sorted(r.finish_reason for r in eng.done.values())
    assert "numerical_fault" in reasons, reasons
    assert all(rr in ("length", "eos", "numerical_fault")
               for rr in reasons), reasons
    assert eng.fault_injector.injected["page_exhaustion"] >= 1
    assert eng.fault_injector.injected["slow_round"] >= 1
    assert eng.fault_counters["slow_rounds"] >= 1, eng.fault_counters
    assert eng.fault_counters["preemptions"] >= 1, eng.fault_counters
    assert eng.fault_counters["requeues"] >= 1, eng.fault_counters
    eng.done.clear()
    return reports


def main() -> int:
    """Fault-injection smoke: the scripted stream completes with the
    expected finish_reasons and zero leaked pages, and a REPLAY on the
    same warm engine performs zero XLA compiles (the fault paths are
    data, not shapes)."""
    from repro.analysis import compilation_events_available, compile_guard
    eng = _smoke_engine()
    _smoke_stream(eng)                       # warmup: pays every compile
    eng._slot_scheduler._alloc.assert_no_leaks()
    if compilation_events_available():
        with compile_guard() as guard:
            _smoke_stream(eng)
        if guard.count:
            raise SystemExit(
                f"fault smoke: replay compiled {guard.count}x; fault "
                "handling must be data, not shapes")
        print("fault smoke: OK (expected finish_reasons, zero leaked "
              "pages, zero replay compiles)")
    else:
        _smoke_stream(eng)
        print("fault smoke: OK (expected finish_reasons, zero leaked "
              "pages; compile telemetry unavailable)")
    eng._slot_scheduler._alloc.assert_no_leaks()
    counters = dict(eng.fault_counters)
    print(f"fault smoke counters: {counters}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())

"""Continuous-batching slot scheduler: round-level SD with in-flight admission.

The paper's central claim is that SD speedup for a sparse MoE is a function
of the LIVE batch size N(t).  Wave scheduling can only measure that —
finished sequences ride along as padding until the slowest request
completes, and {use_sd, gamma} is planned once per wave.  This module
*operates* it:

  * a fixed pool of ``max_batch`` KV-cache slots is decoded round-by-round
    through the session API (core/spec_decode.SDEngine.start/round/
    admit_rows),
  * a slot RETIRES the moment its request finishes (per-slot
    ``max_new_tokens``, optional ``eos_id`` early exit) — its row goes
    inactive via the round's ``active`` mask, which is data, so occupancy
    changes never retrace,
  * freed slots are REFILLED between rounds: queued requests (visible from
    their ``arrival_round`` on, so Poisson traces replay exactly) prefill
    into the retired rows via ``SDEngine.admit_rows`` — a ROW-SLICED
    prefill whose cost scales with the admitted rows at their own
    per-admission prompt bucket, not the pool at a stream-global bucket,
  * long prompts optionally prefill in fixed-size CHUNKS
    (``prefill_chunk``), one chunk per round boundary, so a single long
    admission no longer stalls the round it lands in,
  * with ``kv_layout="paged"`` the target cache is block-table paged
    (models/model.py): per-row page lists from a growable pool, so
    ``max_seq`` is only an initial logical capacity — a late-submitted
    long request GROWS the session instead of raising.  Dense streams
    instead REJECT the oversize request (``finish_reason="rejected"``)
    and keep serving,
  * with ``prefix_sharing=True`` (paged only) an admission whose prompt
    shares a page-aligned prefix with a LIVE slot's prompt forks that
    slot's prefix pages (refcounted, copy-on-write at the tail boundary
    — ``PageAllocator.fork_prefix``/``cow_range``) and target-prefills
    only the unshared tail via ``SDEngine.admit_rows_prefix``; same-round
    siblings with a common prefix are staggered one round so the first
    becomes the fork leader (docs/paged_attention.md), and
    ``admission_order="pressure"`` refills smallest-footprint-first when
    the free-page fraction drops below half,
  * every round consults ``AutoTuner.plan()`` on the LIVE slot count: as
    occupancy decays out of the speedup window the stream hands off SD→AR
    mid-flight (a gamma=0 round in the SAME session — no session switch,
    no state rebuild, and the draft cache stays reconcilable for SD
    re-entry when admissions push N(t) back up).

Per-round ``StepReport``s aggregate into the engine's existing
``WaveReport`` / ``session_stats()`` surfaces; the occupancy trajectory
they carry feeds the decay-aware predicted-vs-measured comparison in
core/analytics.py, and their ``admit_rows``/``admit_tokens`` fields feed
the admission-work accounting (``core/analytics.admission_work``,
``benchmarks/admission_sweep.py``).

This mirrors in-flight batching in TensorRT-LLM / continuous batching in
vLLM at round granularity: admission is batched at round boundaries (not
token boundaries) because one SD round commits a variable 1..gamma+1
tokens per slot.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field, replace as dc_replace
from typing import TYPE_CHECKING, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.spec_decode import PendingAdmission, SDStats, SessionState
from repro.data.tokenizer import PAD
from repro.models.model import PageAllocator, copy_cache_pages
from repro.serving.engine import WaveReport, _pow2_at_least

if TYPE_CHECKING:                                    # avoid runtime cycle
    from repro.serving.engine import Request, ServingEngine


def submit_poisson(engine: "ServingEngine", prompts, lengths, *,
                   rate: float, max_new_choices=(8, 16, 32),
                   seed: int = 0) -> List[int]:
    """Submit a Poisson-arrival, mixed-length workload to an engine.

    The continuous scheduler's unit of time is the decode ROUND: request i
    arrives at ``cumsum(Exp(1/rate))`` rounds (``rate`` = mean arrivals per
    round; ``rate <= 0`` submits everything at round 0) with a
    ``max_new_tokens`` drawn uniformly from ``max_new_choices`` — the
    mixed-completion-length traffic where wave scheduling pays the most
    padding.  Wave engines ignore ``arrival_round`` (they admit FIFO), so
    the same submission order drives both schedulers comparably.

    Returns the submitted uids in arrival order.
    """
    rate = float(rate)
    if not np.isfinite(rate) or rate < 0:
        raise ValueError(
            f"arrival rate must be a finite value >= 0, got {rate!r} "
            "(rate=0 submits the whole workload at round 0; a positive "
            "rate is mean arrivals per decode round)")
    if len(lengths) == 0:
        raise ValueError("submit_poisson: empty workload (no lengths)")
    if len(prompts) < len(lengths):
        raise ValueError(
            f"submit_poisson: {len(prompts)} prompts for {len(lengths)} "
            "lengths — every length needs a prompt row")
    if not max_new_choices:
        raise ValueError("submit_poisson: max_new_choices must be "
                         "non-empty")
    for i in range(len(lengths)):
        if int(lengths[i]) < 1:
            raise ValueError(
                f"submit_poisson: prompt {i} is empty (length "
                f"{int(lengths[i])}); prefill needs >= 1 token — drop it "
                "from the workload instead")
    rng = np.random.default_rng(seed)
    t, uids = 0.0, []
    for i in range(len(lengths)):
        if rate > 0:
            t += rng.exponential(1.0 / rate)
        uids.append(engine.submit(
            np.asarray(prompts[i][: int(lengths[i])]),
            max_new_tokens=int(rng.choice(max_new_choices)),
            arrival_round=int(t)))
    return uids


@dataclass
class SlotState:
    """One KV-cache row of the continuous pool.

    ``active`` rows advance in SD rounds; inactive rows are shape-stable
    padding awaiting admission.  ``tokens`` accumulates the request's
    generated ids (the admission prefill's sampled token first), ``n_out``
    counts them against the request's ``max_new_tokens``.  ``admit_seq``
    is the stream-global admission sequence number — preemption picks its
    victim by it (youngest admitted, oldest protected).
    """
    index: int
    request: Optional["Request"] = None
    active: bool = False
    n_out: int = 0
    tokens: List[int] = field(default_factory=list)
    admit_seq: int = -1


@dataclass
class StepReport:
    """One SD round of a continuous stream.

    ``live`` is the active-slot count the round decoded (the N(t) the
    tuner planned on), ``committed`` the tokens credited to requests this
    round (budget/eos truncation applied), ``admitted``/``retired`` the
    slot churn at this round's boundary.  ``admit_rows``/``admit_tokens``
    are the rows and row-tokens the boundary's admission prefills actually
    processed (chunked-prefill chunk steps included) — the work the sliced
    path keeps ∝ what was admitted.

    Resilience fields (docs/faults.md; all zero on a healthy round):
    ``preempted`` slots evicted for page pressure at this boundary,
    ``faults`` rows quarantined by the numerical sentinel, ``timeouts``
    requests retired over their round budget, ``deferred`` admissions
    pushed back by watermark backpressure, transient admission failure,
    or a prefix-sharing stagger.

    ``shared_tokens`` counts prompt tokens this boundary's admissions did
    NOT prefill because prefix sharing mapped them to a sibling's pages
    (docs/paged_attention.md) — the per-round admission work saved.
    """
    round_index: int
    live: int
    gamma: int
    used_sd: bool
    committed: int
    admitted: int
    retired: int
    round_time: float
    admit_rows: int = 0
    admit_tokens: int = 0
    preempted: int = 0
    faults: int = 0
    timeouts: int = 0
    deferred: int = 0
    shared_tokens: int = 0


@dataclass
class _Chunking:
    """A slot reserved by an in-flight chunked admission."""
    slot: SlotState
    request: "Request"
    pa: PendingAdmission


class ContinuousScheduler:
    """Round-level slot scheduler over one persistent decoding session.

    Owns the slot pool, the round loop and the admission policy (sliced /
    full, chunked prefill, paged growth); the engine supplies sessions,
    tuner, PRNG splits, layout knobs and the request queue.  One
    ``run_stream()`` call drains the queue (idling through rounds where
    every admissible request is still in flight or yet to arrive) and
    returns an aggregated ``WaveReport`` with per-round ``StepReport``s in
    ``.steps``.
    """

    def __init__(self, engine: "ServingEngine", *,
                 slots: Optional[int] = None):
        self.engine = engine
        self.pool = slots if slots is not None else engine.max_batch
        self._alloc: Optional[PageAllocator] = None
        self._admit_seq = 0                  # stream-global admission order
        self._hiwater: dict = {}             # uid -> max tokens ever committed
        self._consec_faulty = 0
        self._consec_stall = 0
        self._forced_ar = False

    # ------------------------------------------------------------- admission
    def _pop_admissible(self, round_idx: int) -> Optional["Request"]:
        """Pop the first queued request visible at this round.

        Scans past non-admissible entries instead of head-checking: retry
        backoff and preemption requeue push ``arrival_round`` into the
        future, and a deferred request at the head must not block
        admissible work behind it.

        With ``admission_order="pressure"`` and a TIGHT pool (free page
        fraction below half), the smallest-page-footprint admissible
        request is picked instead of the oldest: more refills land per
        round under pressure, fewer growths/preemptions fire.  FIFO order
        resumes the moment pressure clears, and the preemption policy's
        oldest-slot protection is unaffected."""
        q = self.engine.queue
        pressured = (self.engine.admission_order == "pressure"
                     and self._alloc is not None
                     and self._alloc.free_fraction() < 0.5)
        best = None                           # (pages, queue index)
        for i, r in enumerate(q):
            if r.arrival_round <= round_idx:
                if not pressured:
                    del q[i]
                    return r
                key = (self._alloc.pages_for(self._need(r)), i)
                if best is None or key < best:
                    best = key
        if best is None:
            return None
        r = q[best[1]]
        del q[best[1]]
        return r

    def _has_admissible(self, round_idx: int) -> bool:
        return any(r.arrival_round <= round_idx for r in self.engine.queue)

    def _need(self, r: "Request") -> int:
        """Cache positions request ``r`` can touch over its lifetime.

        Re-admission after preemption needs no extra margin: the resumed
        tokens it recompute-prefills count against the same
        ``max_new_tokens`` budget they were first committed under."""
        return len(r.prompt) + r.max_new_tokens + self._g_max + 2

    def _admit_toks(self, r: "Request") -> np.ndarray:
        """The tokens a (re-)admission prefills: the prompt, plus — after
        a preemption — the already-committed tokens, so the recompute
        prefill reconstructs the row's KV exactly where it left off."""
        if r.resume_tokens:
            return np.concatenate([np.asarray(r.prompt, np.int32),
                                   np.asarray(r.resume_tokens, np.int32)])
        return np.asarray(r.prompt, np.int32)

    def _count(self, name: str, n: int = 1) -> None:
        c = self.engine.fault_counters
        c[name] = c.get(name, 0) + n

    # -------------------------------------------------------- prefix sharing
    @staticmethod
    def _common_prefix(a, b) -> int:
        n = min(len(a), len(b))
        if n == 0:
            return 0
        neq = np.nonzero(np.asarray(a[:n]) != np.asarray(b[:n]))[0]
        return int(neq[0]) if neq.size else n

    def _find_leader(self, slots: List[SlotState], r: "Request"
                     ) -> Tuple[Optional[SlotState], int]:
        """The ACTIVE slot whose prompt shares the longest common prefix
        with ``r``'s, or (None, 0).

        The share length is capped at ``len(r.prompt) - 1`` — the tail
        must keep at least one token for the admission extend to produce
        a next-token logit — and floored at ``page_size``: a sub-page
        overlap shares zero whole pages, so the fork would save nothing
        and the request admits normally."""
        best, best_len = None, 0
        for s in slots:
            if not s.active or s.request is None:
                continue
            share = min(self._common_prefix(s.request.prompt, r.prompt),
                        len(r.prompt) - 1)
            if share > best_len:
                best, best_len = s, share
        if best_len < self.engine.page_size:
            return None, 0
        return best, best_len

    def _should_stagger(self, r: "Request", batch_in, prefix_in, landed,
                        chunking) -> bool:
        """True when no ACTIVE leader exists but a sibling admitted at
        THIS round boundary shares >= one page of prompt prefix with
        ``r`` — pushing ``r`` one round lets it fork the sibling's pages
        once they are live instead of prefilling the prefix twice.  Each
        uid staggers at most once, so a sibling that never activates
        (instant eos, rejection) cannot orbit the queue."""
        if r.uid in self._staggered:
            return False
        ps = self.engine.page_size
        siblings = [q for _, q in batch_in] \
            + [q for _, q, _ in prefix_in] \
            + [q for _, q in landed] \
            + [c.request for c in chunking]
        for q in siblings:
            if min(self._common_prefix(q.prompt, r.prompt),
                   len(r.prompt) - 1) >= ps:
                return True
        return False

    def _bucket(self, n: int) -> int:
        return _pow2_at_least(n) if self.engine.bucket_batches else n

    def _swa_capacity_floor(self) -> int:
        """Minimum paged logical capacity so every SWA ring allocates at
        its FULL width (window + pad) from round 0.  Rings are dense and
        bounded — sizing them below full width only saves memory when the
        stream never grows, and a growth cannot resize a live ring
        (``pos % w`` would remap entries), so a paged session must never
        start below this."""
        from repro.models.attention import SWA_RING_PAD
        floor = 0
        for m in (self.engine.target, self.engine.draft):
            cfg = getattr(m, "cfg", None)
            if cfg is not None and any(
                    k == "swa" for k in getattr(cfg, "layer_pattern", ())):
                floor = max(floor, cfg.sliding_window + SWA_RING_PAD)
        return floor

    def _open_session(self, sess, max_seq: int) -> SessionState:
        """Open the pool with 1-token fillers; every REAL request then
        enters through the (sliced/chunked) admission path, so admission
        cost is accounted uniformly and the prompt bucket is always
        per-admission."""
        eng = self.engine
        B = self.pool
        toks = np.full((B, 1), PAD, np.int32)
        cache_opts, table = None, None
        if self._alloc is not None:
            cache_opts = {"paged": True, "page_size": eng.page_size,
                          "pool_pages": self._alloc.pool_pages}
            table = self._alloc.table
        params_d = None if eng.proposer_kind == "none" else eng.params_d
        # host arrays go in raw: the session's _host boundary places them
        # (replicated under a mesh) so admission keeps one jit signature
        return sess.start(eng.params_t, params_d, toks,
                          max_seq=max_seq,
                          lengths=np.ones((B,), np.int32),
                          key=eng._next_key(), cache_opts=cache_opts,
                          page_table=table)

    def _sync_table(self, state: SessionState) -> SessionState:
        """Push the allocator's (host) block table into the session —
        an input-array swap, never a retrace.  Under a mesh the swap is
        device_put with the SAME cache_spec placement the session opened
        with, so sharded rounds never see a placement flip."""
        eng = self.engine
        table = np.asarray(self._alloc.table, np.int32)
        if eng.mesh is not None:
            from jax.sharding import NamedSharding
            from repro.distributed.sharding import cache_spec
            new = jax.device_put(table, NamedSharding(
                eng.mesh, cache_spec("pages/table", table.shape,
                                     mesh=eng.mesh)))
        else:
            new = jnp.asarray(table)
        pages = dict(state.t_cache["pages"], table=new)
        return dc_replace(state, t_cache=dict(state.t_cache, pages=pages))

    def _grow(self, sess, state: SessionState, pool_pages: int,
              max_pages: int, chunking: List["_Chunking"]) -> SessionState:
        """Adopt a grown paged geometry: pad the session's page pool /
        logical capacity, mirror it in the allocator, and pad in-flight
        chunked admissions' compact caches so their final scatter still
        matches the grown session."""
        from repro.models.model import grow_cache_seq
        alloc = self._alloc
        new_cap = max_pages * alloc.page_size
        state = sess.grow_session(state, new_cap, pool_pages=pool_pages,
                                  max_pages=max_pages)
        alloc.grow(pool_pages, max_pages)
        state = self._sync_table(state)
        for c in chunking:
            if c.pa.t_cache is not None:
                c.pa = dc_replace(c.pa, t_cache=grow_cache_seq(
                    c.pa.t_cache, self.engine.target.cfg, new_cap))
        return state

    def _headroom_ok(self, need_pages: int, live: int) -> bool:
        """Watermark backpressure check: would admitting ``need_pages``
        leave the pool's free fraction above the configured watermark?
        Always true on an idle pool — deferring the only admissible work
        for headroom's sake would deadlock the stream."""
        wm = self.engine.resilience.free_page_watermark
        if wm <= 0 or live == 0:
            return True
        alloc = self._alloc
        left = len(alloc.free) - need_pages
        return left / max(alloc.pool_pages - 1, 1) >= wm

    def _preempt_victim(self, slots: List[SlotState],
                        incoming: "Request") -> Optional[SlotState]:
        """The youngest non-protected active slot, or None.

        Protected: the OLDEST admitted slot (head-of-line work always
        completes, so page pressure cannot livelock the stream), and the
        whole pool when the incoming request has itself been preempted —
        an already-requeued request waits for organic frees instead of
        starting an eviction cycle."""
        if incoming.preempt_count > 0:
            return None
        cands = [s for s in slots if s.active and s.request is not None]
        if len(cands) < 2:
            return None
        cands.sort(key=lambda s: s.admit_seq)
        return cands[-1]

    def _preempt(self, slot: SlotState, round_idx: int) -> None:
        """Evict one active slot under page pressure (vLLM-style
        recompute preemption): its pages return to the pool, the request
        requeues with its committed tokens saved in ``resume_tokens`` so
        re-admission recompute-prefills ``prompt + committed`` — no
        progress is lost, only recomputed."""
        r = slot.request
        r.resume_tokens = list(slot.tokens)
        r.preempt_count += 1
        r.requeue_round = round_idx
        r.arrival_round = round_idx + 1      # not re-admissible this round
        self._hiwater[r.uid] = max(self._hiwater.get(r.uid, 0),
                                   len(slot.tokens))
        slot.request = None
        slot.active = False
        slot.tokens = []
        self._alloc.free_row(slot.index)     # table row -> trash page 0
        self._table_dirty = True
        self.engine.queue.append(r)
        self._count("preemptions")
        self._round_preempted += 1

    def _make_room(self, sess, state: SessionState, r: "Request",
                   chunking: List["_Chunking"], round_idx: int, live: int,
                   slots: List[SlotState],
                   fresh_pages: Optional[int] = None
                   ) -> Tuple[SessionState, str]:
        """Make the paged pool able to admit ``r``; returns a verdict.

        ``"ok"``         — pages are available (caller allocs).
        ``"defer"``      — transient pressure (watermark, or exhaustion
                           with no preemptible victim); requeue and retry.
        ``"impossible"`` — the request cannot fit even a fully-drained
                           pool at ``max_pool_pages``; reject it.

        ``fresh_pages`` (prefix-sharing admissions) is how many pages the
        admission actually withdraws from the free list — the private
        tail plus the copy-on-write boundary page — which is less than
        the request's full footprint because the shared prefix pages are
        a sibling's.  Logical capacity (``max_seq``, table width) is
        still checked against the FULL footprint: the row's table must
        address every position it can ever touch.

        Resolution order under pressure: GROW (pow2, the cheap path) while
        ``max_pool_pages`` allows, then PREEMPT the youngest non-protected
        slot, then defer.  The loop terminates: every iteration either
        grows the pool (bounded by the cap) or frees a victim's pages
        (bounded by the active slot count).
        """
        alloc = self._alloc
        cap = self.engine.resilience.max_pool_pages
        need = self._need(r)
        need_pages = alloc.pages_for(need)
        fresh = need_pages if fresh_pages is None else fresh_pages
        if cap is not None and need_pages > cap - 1:
            return state, "impossible"
        while True:
            if (need > state.max_seq or need_pages > alloc.max_pages
                    or fresh > len(alloc.free)):
                pool_pages, max_pages = alloc.grown_geometry(need)
                if cap is not None and pool_pages > cap:
                    victim = self._preempt_victim(slots, r)
                    if victim is None:
                        return state, "defer"
                    self._preempt(victim, round_idx)
                    continue
                state = self._grow(sess, state, pool_pages, max_pages,
                                   chunking)
                continue
            if not self._headroom_ok(fresh, live):
                pool_pages = alloc.pool_pages * 2
                if cap is not None and pool_pages > cap:
                    return state, "defer"    # watermark backpressure
                state = self._grow(sess, state, pool_pages,
                                   alloc.max_pages, chunking)
                continue
            return state, "ok"

    def _finish_request(self, r: "Request", reason: str) -> None:
        """Finish a request that holds no slot (rejected / admit_failed /
        aborted from the queue).  A preempted request aborted before
        re-admission keeps its recoverable prefix as partial output."""
        if r.finish_reason is not None:
            raise RuntimeError(
                f"request {r.uid} already finished "
                f"{r.finish_reason!r}; refusing to overwrite with "
                f"{reason!r} — every request finishes exactly once")
        r.output = np.asarray(list(r.resume_tokens or []), np.int32)
        r.finish_reason = reason
        r.finished_at = time.perf_counter()
        self.engine.done[r.uid] = r
        self._finished.append(r)

    def _reject(self, r: "Request") -> None:
        """Refuse one request without killing the stream (dense layout:
        the cache was sized at stream start and cannot hold it; paged:
        it cannot fit even a drained pool at ``max_pool_pages``)."""
        self._finish_request(r, "rejected")

    def _admit_batch(self, sess, state: SessionState,
                     batch_in: List[Tuple[SlotState, "Request"]]
                     ) -> Tuple[SessionState, int, int]:
        """One admission prefill for this round's refills.

        Sliced (default): only the admitted rows, at a prompt bucket
        computed FRESH from this batch (no stream-lifetime ratchet), row-
        count bucketed pow2 with padding lanes replicated round-robin and
        dropped from the scatter.  Full (legacy, kept for the admission
        benchmark's old-vs-sliced comparison): the whole pool is prefilled
        and non-admitted rows discarded via the admit mask.

        Returns ``(state, prefill_rows, prefill_tokens)`` — the work the
        call actually dispatched.
        """
        eng = self.engine
        seqs = [self._admit_toks(r) for _, r in batch_in]
        t_new = max(len(t) for t in seqs)
        Tp = self._bucket(t_new)
        key = eng._next_key()                 # one fresh key per admission
        if eng.admit_mode == "full":
            B = self.pool
            toks = np.full((B, Tp), PAD, np.int32)
            lengths = np.ones((B,), np.int32)
            mask = np.zeros((B,), bool)
            for (s, _), t in zip(batch_in, seqs):
                toks[s.index, : len(t)] = t
                lengths[s.index] = len(t)
                mask[s.index] = True
            state = sess.admit(state, toks, lengths, mask, key=key)
            return state, B, B * Tp
        R = min(self._bucket(len(batch_in)), self.pool)
        toks = np.full((R, Tp), PAD, np.int32)
        lengths = np.ones((R,), np.int32)
        rows = np.zeros((R,), np.int32)
        valid = np.zeros((R,), bool)
        for i in range(R):
            s, _ = batch_in[i % len(batch_in)]     # pad lanes replicate
            t = seqs[i % len(batch_in)]
            toks[i, : len(t)] = t
            lengths[i] = len(t)
            rows[i] = s.index
            valid[i] = i < len(batch_in)
        state = sess.admit_rows(state, toks, lengths, rows, valid=valid,
                                key=key)
        return state, R, R * Tp

    def _admit_batch_prefix(self, sess, state: SessionState,
                            batch_in: List[Tuple[SlotState, "Request", int]]
                            ) -> Tuple[SessionState, int, int]:
        """One TAIL-ONLY admission prefill for this round's prefix-shared
        refills (``SDEngine.admit_rows_prefix``).

        The allocator already forked each admitted row's table onto its
        leader's prefix pages and detached the CoW boundary, so the target
        prefills only the unshared tail ``prompt[share_len:]`` as an
        extend at offset ``share_len`` — the tail queries attend across
        the shared prefix KV through the block table.  The proposer still
        prefills the full prompt (its dense cache is private per row).
        Pad lanes replicate real rows round-robin: their duplicate tail
        writes land identical values on the same pages and the admit mask
        drops their state merges, exactly like ``_admit_batch``.

        Returns ``(state, prefill_rows, prefill_tokens)`` counting the
        TARGET-side tail work — the saving prefix sharing exists for.
        """
        eng = self.engine
        tails = [np.asarray(r.prompt[sl:], np.int32)
                 for _, r, sl in batch_in]
        proms = [np.asarray(r.prompt, np.int32) for _, r, _ in batch_in]
        Tt = self._bucket(max(len(t) for t in tails))
        Tp = self._bucket(max(len(p) for p in proms))
        R = min(self._bucket(len(batch_in)), self.pool)
        tail_toks = np.full((R, Tt), PAD, np.int32)
        prom_toks = np.full((R, Tp), PAD, np.int32)
        tail_start = np.zeros((R,), np.int32)
        tail_len = np.ones((R,), np.int32)
        lengths = np.ones((R,), np.int32)
        rows = np.zeros((R,), np.int32)
        valid = np.zeros((R,), bool)
        for i in range(R):
            s, r, sl = batch_in[i % len(batch_in)]
            t = tails[i % len(batch_in)]
            p = proms[i % len(batch_in)]
            tail_toks[i, : len(t)] = t
            prom_toks[i, : len(p)] = p
            tail_start[i] = sl
            tail_len[i] = len(t)
            lengths[i] = len(p)
            rows[i] = s.index
            valid[i] = i < len(batch_in)
        state = sess.admit_rows_prefix(state, tail_toks, tail_start,
                                       tail_len, prom_toks, lengths, rows,
                                       valid=valid, key=eng._next_key())
        return state, R, R * Tt

    # ------------------------------------------------------------ completion
    def _append(self, slot: SlotState, tokens: List[int]) -> int:
        """Credit round tokens to a slot; retire it on budget/eos.

        Returns the number of tokens actually credited (commits past the
        request's budget or its eos are discarded — SD can overshoot
        within a round)."""
        r = slot.request
        eos = self.engine.eos_id
        credited = 0
        for t in tokens:
            if slot.n_out >= r.max_new_tokens:
                break
            slot.tokens.append(int(t))
            slot.n_out += 1
            credited += 1
            if eos is not None and int(t) == eos:
                self._finish(slot, "eos")
                return credited
        if slot.n_out >= r.max_new_tokens:
            self._finish(slot, "length")
        return credited

    def _finish(self, slot: SlotState, reason: str) -> None:
        r = slot.request
        if r.finish_reason is not None:
            raise RuntimeError(
                f"request {r.uid} already finished {r.finish_reason!r}; "
                f"refusing to overwrite with {reason!r} — every request "
                "finishes exactly once")
        if len(slot.tokens) < self._hiwater.get(r.uid, 0):
            raise RuntimeError(
                f"request {r.uid} finishing with {len(slot.tokens)} "
                f"tokens < high-water {self._hiwater[r.uid]} — committed "
                "tokens went BACKWARD across a requeue")
        r.output = np.asarray(slot.tokens, np.int32)
        r.finish_reason = reason
        r.finished_at = time.perf_counter()
        self.engine.done[r.uid] = r
        self._finished.append(r)
        slot.request = None
        slot.active = False
        slot.tokens = []
        self._retired_rows.append(slot.index)

    # ------------------------------------------------------------------ loop
    def run_stream(self) -> Optional[WaveReport]:
        """Serve the queued stream to completion; one aggregated report.

        The loop per round: (1) advance every in-flight chunked admission
        by one chunk (landed ones activate their slot); (2) retire/refill
        — admit every admissible request into free slots with one sliced
        prefill, rejecting (dense) or growing for (paged) requests the
        stream wasn't sized for; (3) re-plan — ``tuner.plan(live)`` on the
        live slot count, SD→AR handoff via gamma=0 when the plan says so;
        (4) decode one SD round with the active mask; (5) credit tokens
        per slot, applying per-slot budgets and eos, freeing pages of
        retired rows.  Returns ``None`` on an empty queue.
        """
        eng = self.engine
        if not eng.queue:
            return None
        kind = eng.proposer_kind
        sess = eng._session(kind)
        pending = list(eng.queue)
        # the cache must hold every plannable gamma's verify overshoot
        g_cands = [eng.gamma]
        if eng.tuner is not None:
            g_cands += [int(g) for g in getattr(eng.tuner, "gammas", ())]
        self._g_max = g_max = max(g_cands)

        paged = eng.kv_layout == "paged"
        if paged:
            ps = eng.page_size
            # logical capacity sized on what is VISIBLE at round 0 only —
            # later arrivals grow the session instead of inflating it now
            visible = [r for r in pending if r.arrival_round <= 0] \
                or pending[:1]
            cap = max(self._bucket(max(self._need(r) for r in visible)),
                      self._swa_capacity_floor())
            max_seq = -(-cap // ps) * ps
            pool_pages = 1 + sum(-(-self._need(r) // ps)
                                 for r in visible[: self.pool])
            self._alloc = PageAllocator(self.pool, ps,
                                        _pow2_at_least(pool_pages),
                                        max_seq // ps)
        else:
            # static sizing for the whole stream: the cache must hold the
            # longest KNOWN request; a later over-long submit is rejected
            # (finish_reason="rejected"), never fatal
            self._alloc = None
            max_seq = self._bucket(max(len(r.prompt) for r in pending)) \
                + max(r.max_new_tokens for r in pending) + g_max + 2
            if eng.bucket_batches:
                max_seq = _pow2_at_least(max_seq)

        slots = [SlotState(i) for i in range(self.pool)]
        state = self._open_session(sess, max_seq)
        stats = SDStats()
        steps: List[StepReport] = []
        self._finished: List["Request"] = []
        self._retired_rows: List[int] = []
        chunking: List[_Chunking] = []
        rescfg = eng.resilience
        inj = eng.fault_injector
        self._consec_faulty = 0              # ladder state is per-stream
        self._consec_stall = 0
        self._forced_ar = False
        self._staggered = set()              # uids prefix-staggered once
        # prefix sharing forks PAGED prefix pages; the engine ctor already
        # validated layout and layer kinds, so the stream-level gate is
        # just the flag
        prefix_ok = paged and eng.prefix_sharing
        used_sd_any = False
        aborted = False
        first_gamma: Optional[int] = None
        round_idx = 0
        t_start = time.perf_counter()
        while True:
            admit_credited, landed, n_retired = 0, [], 0
            admit_rows_n, admit_tokens, deferred_n = 0, 0, 0
            faults_n, timeouts_n, shared_tok_n = 0, 0, 0
            cow_pairs: List[Tuple[int, int]] = []
            prefix_in: List[Tuple[SlotState, "Request", int]] = []
            self._round_preempted = 0
            self._table_dirty = False
            had_admissible = self._has_admissible(round_idx)
            if inj is not None and self._alloc is not None:
                # scripted page holds: release expired, apply due ones
                inj.page_service(round_idx, self._alloc)
            # ---- advance chunked admissions: one chunk per round boundary
            for c in list(chunking):
                R, C = c.pa.prompts.shape[0], c.pa.chunk
                state, pa = sess.admit_chunk(state, c.pa)
                admit_rows_n += R
                admit_tokens += R * min(C, c.pa.remaining)
                if pa is None:
                    chunking.remove(c)
                    landed.append((c.slot, c.request))
                else:
                    c.pa = pa
            # ---- admit: one sliced prefill covers every refill this round
            # (slots whose chunked admission just landed activate below —
            # reserve them so the refill loop can't double-admit the row;
            # a preemption inside _make_room frees its victim's slot, so
            # the free set is recomputed every iteration)
            claimed = {c.slot.index for c in chunking} \
                | {s.index for s, _ in landed}
            batch_in: List[Tuple[SlotState, "Request"]] = []
            live_now = sum(1 for s in slots if s.active)
            if inj is not None and inj.admission_fails(round_idx):
                # scripted transient admission failure: bounded
                # retry-with-backoff for everything admissible this round
                deferred_n += self._defer_admissible(round_idx)
            else:
                while True:
                    free = [s for s in slots
                            if not s.active and s.index not in claimed]
                    if not free:
                        break
                    r = self._pop_admissible(round_idx)
                    if r is None:
                        break
                    if not paged and self._need(r) > max_seq:
                        self._reject(r)
                        continue
                    # ---- prefix sharing: fork a live sibling's prompt
                    # pages instead of re-prefilling the common prefix.
                    # Re-admissions after preemption never share — their
                    # resume stream diverges from every prompt — and a
                    # tail longer than the prefill chunk takes the plain
                    # chunked path instead of one oversized tail extend.
                    leader, share_len = None, 0
                    if prefix_ok and not r.resume_tokens:
                        leader, share_len = self._find_leader(slots, r)
                        if (leader is not None and eng.prefill_chunk
                                and len(r.prompt) - share_len
                                > eng.prefill_chunk):
                            leader, share_len = None, 0
                        if leader is None and self._should_stagger(
                                r, batch_in, prefix_in, landed, chunking):
                            r.arrival_round = round_idx + 1
                            eng.queue.append(r)
                            self._staggered.add(r.uid)
                            deferred_n += 1
                            self._count("prefix_staggered")
                            continue
                    if paged:
                        while True:
                            fresh = None
                            if leader is not None:
                                # private tail pages + the CoW boundary
                                # page; the fork itself draws nothing
                                fresh = (self._alloc.pages_for(
                                    self._need(r))
                                    - share_len // self._alloc.page_size)
                            state, verdict = self._make_room(
                                sess, state, r, chunking, round_idx,
                                live_now, slots, fresh_pages=fresh)
                            if leader is not None and not leader.active:
                                # _make_room preempted the leader; its
                                # pages are gone — re-budget unshared
                                leader, share_len = None, 0
                                continue
                            break
                        if verdict == "impossible":
                            self._reject(r)
                            continue
                        if verdict == "defer":
                            # backpressure applies to the whole boundary
                            r.arrival_round = round_idx + 1
                            eng.queue.append(r)
                            deferred_n += 1
                            self._count("admit_deferred")
                            break
                        free = [s for s in slots
                                if not s.active and s.index not in claimed]
                        row = free[0].index
                        if leader is not None:
                            self._alloc.fork_prefix(leader.index, row,
                                                    share_len)
                            self._alloc.extend_row(row, self._need(r))
                            pairs = self._alloc.cow_range(
                                row, share_len, self._need(r))
                            cow_pairs.extend(pairs)
                            shared_tok_n += share_len
                            self._count("prefix_hits")
                            self._count("prefix_shared_tokens", share_len)
                            self._count("cow_copies", len(pairs))
                        else:
                            self._alloc.alloc(row, self._need(r))
                        self._table_dirty = True
                    s = free[0]
                    claimed.add(s.index)
                    s.admit_seq = self._admit_seq
                    self._admit_seq += 1
                    if leader is not None:
                        prefix_in.append((s, r, share_len))
                        continue
                    toks = self._admit_toks(r)
                    if eng.prefill_chunk and len(toks) > eng.prefill_chunk:
                        chunking.append(_Chunking(
                            s, r, sess.begin_admit_chunked(
                                toks[None, :],
                                np.array([len(toks)], np.int32),
                                np.array([s.index], np.int32),
                                chunk=eng.prefill_chunk,
                                key=eng._next_key())))
                        continue
                    batch_in.append((s, r))
            if self._table_dirty:
                # one table upload covers every page assignment AND every
                # preemption this round: a freed victim's row must point
                # at trash page 0 before the next decode, or its frozen
                # lane would write into pages the pool has re-issued
                state = self._sync_table(state)
            if cow_pairs:
                # one bucketed device copy detaches every CoW boundary
                # page this round; (0, 0) trash self-copies pad to pow2 so
                # the copy dispatch stays shape-stable across rounds
                n = _pow2_at_least(len(cow_pairs)) if eng.bucket_batches \
                    else len(cow_pairs)
                padded = cow_pairs + [(0, 0)] * (n - len(cow_pairs))
                state = dc_replace(state, t_cache=copy_cache_pages(
                    state.t_cache, padded))
            if prefix_in:
                state, rows_n, toks_n = self._admit_batch_prefix(
                    sess, state, prefix_in)
                admit_rows_n += rows_n
                admit_tokens += toks_n
                landed.extend((s, r) for s, r, _ in prefix_in)
            if batch_in:
                state, rows_n, toks_n = self._admit_batch(sess, state,
                                                          batch_in)
                admit_rows_n += rows_n
                admit_tokens += toks_n
                landed.extend(batch_in)
            if landed:
                first = np.asarray(state.last_token)
                for s, r in landed:
                    s.request, s.active = r, True
                    resume = list(r.resume_tokens or [])
                    # a re-admission resumes the committed stream: the
                    # recompute prefill already holds these tokens' KV,
                    # and crediting them AGAIN would double-count across
                    # the requeue — preload, don't re-append
                    s.n_out, s.tokens = len(resume), resume
                    if resume:
                        r.readmit_round = round_idx
                        r.resume_tokens = None
                        self._count("requeues")
                    # the admission prefill's sample is the first token
                    admit_credited += self._append(s, [int(first[s.index])])
            n_retired = sum(1 for s, r in landed if not s.active)

            active_mask = np.array([s.active for s in slots], bool)
            live = int(active_mask.sum())
            if live == 0:
                if landed or admit_rows_n:
                    # every admitted slot finished on its prefill token
                    # (1-token budgets / instant eos) or only chunk work
                    # ran: record the churn so steps never undercount
                    steps.append(StepReport(round_idx, 0, 0, False,
                                            admit_credited, len(landed),
                                            n_retired, 0.0, admit_rows_n,
                                            admit_tokens,
                                            preempted=self._round_preempted,
                                            deferred=deferred_n,
                                            shared_tokens=shared_tok_n))
                self._free_retired()
                if not eng.queue and not chunking:
                    break
                if self._note_stall(had_admissible,
                                    landed or admit_rows_n or n_retired):
                    aborted = True
                    self._abort(slots, chunking)
                    break
                round_idx += 1                  # idle: awaiting arrivals
                continue

            # ---- re-plan on the LIVE slot count (the paper's N(t))
            gamma, use_sd = eng.gamma, True
            if eng.tuner is not None:
                plan = eng.tuner.plan(live)
                gamma, use_sd = plan["gamma"], plan["use_sd"]
            if eng.force_sd is not None:
                use_sd = eng.force_sd
            if kind == "none":
                use_sd = False
            if self._forced_ar:
                # degradation ladder rung 1: repeated faulty rounds force
                # plain AR (gamma=0 in the SAME session) until a healthy
                # round clears the cooldown — overrides even force_sd
                use_sd = False
            if not use_sd:
                gamma = 0                       # in-session SD→AR handoff
            if gamma > g_max:
                # the cache margin was sized for g_max at stream start; a
                # larger gamma would scatter verify KV past the allocated
                # pages/rows, which JAX clamps SILENTLY — fail loudly
                raise ValueError(
                    f"tuner planned gamma={gamma} > g_max={g_max} the "
                    "stream was sized for; expose the tuner's range via a "
                    "'gammas' attribute (AutoTuner does)")
            if first_gamma is None:
                first_gamma = gamma
            used_sd_any |= use_sd

            # ---- scripted pre-round faults (testing only; inj is None in
            # production streams)
            t_r0 = time.perf_counter()
            if inj is not None:
                from repro.serving.faults import poison_cache_row
                for f in inj.nan_rows(round_idx):
                    row = f.row if f.row is not None else next(
                        (s.index for s in slots if s.active), None)
                    if row is not None:
                        state = dc_replace(state, t_cache=poison_cache_row(
                            state.t_cache, row))
                delay = inj.slow_delay(round_idx)
                if delay:
                    time.sleep(delay)

            # ---- one SD round over the pool, retired rows masked out
            state, res = sess.round(state, gamma=gamma, key=eng._next_key(),
                                    active=active_mask,
                                    timed=eng.timed)
            round_wall = time.perf_counter() - t_r0

            # ---- numerical sentinel: quarantine non-finite rows before
            # crediting (their n_commit is already forced to 0 in-round,
            # so co-batched slots are untouched)
            if res.finite is not None and not bool(np.all(res.finite)):
                for s in slots:
                    if s.active and not bool(res.finite[s.index]):
                        self._count("numerical_faults")
                        self._finish(s, "numerical_fault")
                        faults_n += 1
                        n_retired += 1
            credited = 0
            for s in slots:
                if not s.active:
                    continue
                n = int(res.n_commit[s.index])
                credited += self._append(s, list(res.committed[s.index, :n]))
                if not s.active:
                    n_retired += 1
            # ---- per-request round budgets
            for s in slots:
                if not s.active:
                    continue
                s.request.rounds_used += 1
                if (rescfg.max_rounds_per_request is not None
                        and s.request.rounds_used
                        >= rescfg.max_rounds_per_request):
                    self._count("timeouts")
                    self._finish(s, "timeout")
                    timeouts_n += 1
                    n_retired += 1
            self._free_retired()

            # live-weighted accounting: retired rows' masked lanes commit
            # nothing, so sigma/alpha describe the work actually requested
            stats.absorb_round(res, live)
            alpha_round = (float(res.n_accept.sum()) / (res.width * live)
                           if (use_sd and res.width and live) else None)
            if alpha_round is not None and eng.tuner is not None:
                eng.tuner.update_alpha(alpha_round)
            steps.append(StepReport(round_idx, live, gamma, use_sd,
                                    admit_credited + credited,
                                    len(landed), n_retired,
                                    res.round_time, admit_rows_n,
                                    admit_tokens,
                                    preempted=self._round_preempted,
                                    faults=faults_n, timeouts=timeouts_n,
                                    deferred=deferred_n,
                                    shared_tokens=shared_tok_n))

            # ---- degradation ladder: consecutive faulty rounds escalate
            # healthy → forced AR → stream-level safe stop
            slow = (rescfg.round_deadline_s is not None
                    and round_wall > rescfg.round_deadline_s)
            if slow:
                self._count("slow_rounds")
            collapsed = (rescfg.collapse_alpha > 0
                         and alpha_round is not None
                         and alpha_round < rescfg.collapse_alpha)
            if faults_n or slow or collapsed:
                self._consec_faulty += 1
                if (not self._forced_ar and self._consec_faulty
                        >= rescfg.faulty_rounds_to_ar):
                    self._forced_ar = True
                    self._count("ar_handoffs")
                if self._consec_faulty >= rescfg.faulty_rounds_to_stop:
                    aborted = True
                    self._abort(slots, chunking)
                    break
            else:
                self._consec_faulty = 0
                self._forced_ar = False
            if self._note_stall(had_admissible,
                                admit_credited + credited or landed
                                or n_retired or admit_rows_n):
                aborted = True
                self._abort(slots, chunking)
                break
            round_idx += 1

        if inj is not None and self._alloc is not None:
            inj.release_all(self._alloc)
        self._check_invariants()
        sess.accumulate_prefetch_totals(stats)
        wall = time.perf_counter() - t_start
        clean = ("length", "eos")
        n_tokens = sum(len(r.output) for r in self._finished
                       if r.finish_reason in clean)
        discarded = sum(len(r.output) for r in self._finished
                        if r.finish_reason not in clean)
        reasons: dict = {}
        for r in self._finished:
            reasons[r.finish_reason] = reasons.get(r.finish_reason, 0) + 1
        if aborted:
            self._count("aborts")
        return WaveReport(
            batch=len(self._finished),
            gamma=first_gamma if first_gamma is not None else 0,
            used_sd=used_sd_any, stats=stats, wall_time=wall,
            tokens_out=n_tokens, proposer=kind, bucket=self.pool,
            moe_dispatch=eng.moe_dispatch, scheduler="continuous",
            steps=steps, tokens_discarded=discarded,
            finish_reasons=reasons)

    # ------------------------------------------------------------ resilience
    def _defer_admissible(self, round_idx: int) -> int:
        """Bounded retry-with-backoff for a transiently failing admission
        round: attempt ``i`` pushes a request ``backoff * 2**(i-1)``
        rounds out; past ``admit_retries`` it finishes ``admit_failed``."""
        eng = self.engine
        rescfg = eng.resilience
        deferred = 0
        while True:
            r = self._pop_admissible(round_idx)
            if r is None:
                return deferred
            r.admit_attempts += 1
            if r.admit_attempts > rescfg.admit_retries:
                self._count("admit_failures")
                self._finish_request(r, "admit_failed")
                continue
            backoff = max(1, rescfg.admit_backoff_rounds
                          * 2 ** (r.admit_attempts - 1))
            r.arrival_round = round_idx + backoff
            eng.queue.append(r)
            self._count("admit_retries")
            deferred += 1

    def _note_stall(self, had_admissible: bool, progress) -> bool:
        """Stall watchdog: count consecutive rounds where admissible work
        existed but NOTHING landed, committed, or retired (an admission
        deadlock — e.g. page pressure with no growable/preemptible way
        out).  Returns True once the configured budget is exhausted."""
        if had_admissible and not progress:
            self._consec_stall += 1
        else:
            self._consec_stall = 0
        if self._consec_stall >= self.engine.resilience.stall_rounds:
            self._count("stalls")
            return True
        return False

    def _abort(self, slots: List[SlotState],
               chunking: List[_Chunking]) -> None:
        """Stream-level safe stop (ladder rung 2 / stall watchdog): every
        in-flight and queued request finishes ``aborted`` — partial
        output preserved — and every page returns to the pool, so the
        engine object stays serviceable for the next stream."""
        for c in list(chunking):
            if self._alloc is not None:
                self._alloc.free_row(c.slot.index)
            self._finish_request(c.request, "aborted")
        chunking.clear()
        for s in slots:
            if s.active:
                self._finish(s, "aborted")
        while self.engine.queue:
            self._finish_request(self.engine.queue.popleft(), "aborted")
        self._free_retired()

    def _check_invariants(self) -> None:
        """End-of-stream invariant check (cheap, always on): every request
        that entered the stream left with exactly ONE finish_reason (the
        overwrite guards in ``_finish``/``_finish_request`` enforce
        uniqueness; this checks presence), committed token counts are
        monotonic across requeues (``_finish`` checks against the
        high-water marks), and — paged — no page leaked: after the final
        ``_free_retired`` and the injector's ``release_all`` the
        allocator must be exactly as full as it started."""
        for r in self._finished:
            if r.finish_reason is None:
                raise RuntimeError(
                    f"request {r.uid} left the stream without a "
                    "finish_reason")
        if self._alloc is not None:
            self._alloc.assert_no_leaks()

    def _free_retired(self) -> None:
        """Return retired rows' pages to the pool (paged layout)."""
        if self._alloc is None:
            self._retired_rows.clear()
            return
        for row in self._retired_rows:
            self._alloc.free_row(row)
        self._retired_rows.clear()

"""Continuous-batching slot scheduler: round-level SD with in-flight admission.

The paper's central claim is that SD speedup for a sparse MoE is a function
of the LIVE batch size N(t).  Wave scheduling can only measure that —
finished sequences ride along as padding until the slowest request
completes, and {use_sd, gamma} is planned once per wave.  This module
*operates* it:

  * a fixed pool of ``max_batch`` KV-cache slots is decoded round-by-round
    through the session API (core/spec_decode.SDEngine.start/round/admit),
  * a slot RETIRES the moment its request finishes (per-slot
    ``max_new_tokens``, optional ``eos_id`` early exit) — its row goes
    inactive via the round's ``active`` mask, which is data, so occupancy
    changes never retrace,
  * freed slots are REFILLED between rounds: queued requests (visible from
    their ``arrival_round`` on, so Poisson traces replay exactly) prefill
    into the retired rows via ``SDEngine.admit`` — a masked prefill into
    the existing cache, zero retraces within a (batch, prompt-bucket),
  * every round consults ``AutoTuner.plan()`` on the LIVE slot count: as
    occupancy decays out of the speedup window the stream hands off SD→AR
    mid-flight (a gamma=0 round in the SAME session — no session switch,
    no state rebuild, and the draft cache stays reconcilable for SD
    re-entry when admissions push N(t) back up).

Per-round ``StepReport``s aggregate into the engine's existing
``WaveReport`` / ``session_stats()`` surfaces; the occupancy trajectory
they carry feeds the decay-aware predicted-vs-measured comparison in
core/analytics.py (``occupancy_timeline`` / ``predicted_decay_speedup``).

This mirrors in-flight batching in TensorRT-LLM / continuous batching in
vLLM at round granularity: admission is batched at round boundaries (not
token boundaries) because one SD round commits a variable 1..gamma+1
tokens per slot.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import TYPE_CHECKING, List, Optional, Tuple

import jax.numpy as jnp
import numpy as np

from repro.core.spec_decode import SDStats, SessionState
from repro.data.tokenizer import PAD
from repro.serving.engine import WaveReport, _pow2_at_least

if TYPE_CHECKING:                                    # avoid runtime cycle
    from repro.serving.engine import Request, ServingEngine


def submit_poisson(engine: "ServingEngine", prompts, lengths, *,
                   rate: float, max_new_choices=(8, 16, 32),
                   seed: int = 0) -> List[int]:
    """Submit a Poisson-arrival, mixed-length workload to an engine.

    The continuous scheduler's unit of time is the decode ROUND: request i
    arrives at ``cumsum(Exp(1/rate))`` rounds (``rate`` = mean arrivals per
    round; ``rate <= 0`` submits everything at round 0) with a
    ``max_new_tokens`` drawn uniformly from ``max_new_choices`` — the
    mixed-completion-length traffic where wave scheduling pays the most
    padding.  Wave engines ignore ``arrival_round`` (they admit FIFO), so
    the same submission order drives both schedulers comparably.

    Returns the submitted uids in arrival order.
    """
    rng = np.random.default_rng(seed)
    t, uids = 0.0, []
    for i in range(len(lengths)):
        if rate > 0:
            t += rng.exponential(1.0 / rate)
        uids.append(engine.submit(
            np.asarray(prompts[i][: int(lengths[i])]),
            max_new_tokens=int(rng.choice(max_new_choices)),
            arrival_round=int(t)))
    return uids


@dataclass
class SlotState:
    """One KV-cache row of the continuous pool.

    ``active`` rows advance in SD rounds; inactive rows are shape-stable
    padding awaiting admission.  ``tokens`` accumulates the request's
    generated ids (the admission prefill's sampled token first), ``n_out``
    counts them against the request's ``max_new_tokens``.
    """
    index: int
    request: Optional["Request"] = None
    active: bool = False
    n_out: int = 0
    tokens: List[int] = field(default_factory=list)


@dataclass
class StepReport:
    """One SD round of a continuous stream.

    ``live`` is the active-slot count the round decoded (the N(t) the
    tuner planned on), ``committed`` the tokens credited to requests this
    round (budget/eos truncation applied), ``admitted``/``retired`` the
    slot churn at this round's boundary.
    """
    round_index: int
    live: int
    gamma: int
    used_sd: bool
    committed: int
    admitted: int
    retired: int
    round_time: float


class ContinuousScheduler:
    """Round-level slot scheduler over one persistent decoding session.

    Owns the slot pool and the round loop; the engine supplies sessions,
    tuner, PRNG splits, and the request queue.  One ``run_stream()`` call
    drains the queue (idling through rounds where every admissible request
    is still in flight or yet to arrive) and returns an aggregated
    ``WaveReport`` with per-round ``StepReport``s in ``.steps``.
    """

    def __init__(self, engine: "ServingEngine", *,
                 slots: Optional[int] = None):
        self.engine = engine
        self.pool = slots if slots is not None else engine.max_batch
        self._bucket_t = 1

    # ------------------------------------------------------------- admission
    def _admissible(self, round_idx: int) -> bool:
        q = self.engine.queue
        return bool(q) and q[0].arrival_round <= round_idx

    def _admit_rows(self, sess, state: Optional[SessionState],
                    batch_in: List[Tuple[SlotState, "Request"]],
                    max_seq: int) -> SessionState:
        """Prefill ``batch_in`` requests into their slots.

        First call opens the session (``start`` over the full pool, filler
        rows inactive); later calls are masked prefills into retired rows
        (``admit``) — the existing cache rows of in-flight slots are
        untouched and the admit mask is data, so refills within a
        (pool, prompt-bucket) shape never retrace.
        """
        eng = self.engine
        B = self.pool
        t_new = max(len(r.prompt) for _, r in batch_in)
        if eng.bucket_batches:
            self._bucket_t = max(self._bucket_t, _pow2_at_least(t_new))
        else:
            self._bucket_t = max(self._bucket_t, t_new)
        toks = np.full((B, self._bucket_t), PAD, np.int32)
        lengths = np.ones((B,), np.int32)     # fillers: 1 (prefill-safe)
        mask = np.zeros((B,), bool)
        for s, r in batch_in:
            toks[s.index, : len(r.prompt)] = r.prompt
            lengths[s.index] = len(r.prompt)
            mask[s.index] = True
        key = eng._next_key()
        if state is None:
            params_d = None if eng.proposer_kind == "none" else eng.params_d
            return sess.start(eng.params_t, params_d, jnp.asarray(toks),
                              max_seq=max_seq,
                              lengths=jnp.asarray(lengths), key=key)
        return sess.admit(state, toks, lengths, mask, key=key)

    # ------------------------------------------------------------ completion
    def _append(self, slot: SlotState, tokens: List[int]) -> int:
        """Credit round tokens to a slot; retire it on budget/eos.

        Returns the number of tokens actually credited (commits past the
        request's budget or its eos are discarded — SD can overshoot
        within a round)."""
        r = slot.request
        eos = self.engine.eos_id
        credited = 0
        for t in tokens:
            if slot.n_out >= r.max_new_tokens:
                break
            slot.tokens.append(int(t))
            slot.n_out += 1
            credited += 1
            if eos is not None and int(t) == eos:
                self._finish(slot, "eos")
                return credited
        if slot.n_out >= r.max_new_tokens:
            self._finish(slot, "length")
        return credited

    def _finish(self, slot: SlotState, reason: str) -> None:
        r = slot.request
        r.output = np.asarray(slot.tokens, np.int32)
        r.finish_reason = reason
        r.finished_at = time.perf_counter()
        self.engine.done[r.uid] = r
        self._finished.append(r)
        slot.request = None
        slot.active = False
        slot.tokens = []

    # ------------------------------------------------------------------ loop
    def run_stream(self) -> Optional[WaveReport]:
        """Serve the queued stream to completion; one aggregated report.

        The loop per round: (1) retire/refill — admit every admissible
        request into free slots with one masked prefill; (2) re-plan —
        ``tuner.plan(live)`` on the live slot count, SD→AR handoff via
        gamma=0 when the plan says so; (3) decode one SD round with the
        active mask; (4) credit tokens per slot, applying per-slot budgets
        and eos.  Returns ``None`` on an empty queue.
        """
        eng = self.engine
        if not eng.queue:
            return None
        kind = eng.proposer_kind
        sess = eng._session(kind)
        pending = list(eng.queue)
        # static sizing for the whole stream: the cache must hold the
        # longest admitted request under the largest plannable gamma
        g_cands = [eng.gamma]
        if eng.tuner is not None:
            g_cands += [int(g) for g in getattr(eng.tuner, "gammas", ())]
        g_max = max(g_cands)
        t_max = max(len(r.prompt) for r in pending)
        self._bucket_t = _pow2_at_least(t_max) if eng.bucket_batches else t_max
        max_seq = self._bucket_t + max(r.max_new_tokens for r in pending) \
            + g_max + 2
        if eng.bucket_batches:
            max_seq = _pow2_at_least(max_seq)

        slots = [SlotState(i) for i in range(self.pool)]
        state: Optional[SessionState] = None
        stats = SDStats()
        steps: List[StepReport] = []
        self._finished: List["Request"] = []
        used_sd_any = False
        first_gamma: Optional[int] = None
        round_idx = 0
        t_start = time.perf_counter()
        while True:
            # ---- admit: one masked prefill covers every refill this round
            free = [s for s in slots if not s.active]
            batch_in: List[Tuple[SlotState, "Request"]] = []
            while free and self._admissible(round_idx):
                r = eng.queue.popleft()
                need = len(r.prompt) + r.max_new_tokens + g_max + 2
                if need > max_seq:
                    raise ValueError(
                        f"request uid={r.uid} needs {need} cache slots > "
                        f"stream max_seq={max_seq} (sized at stream start); "
                        "submit before run() so sizing can see it")
                batch_in.append((free.pop(0), r))
            admit_credited = 0
            if batch_in:
                state = self._admit_rows(sess, state, batch_in, max_seq)
                first = np.asarray(state.last_token)
                for s, r in batch_in:
                    s.request, s.active = r, True
                    s.n_out, s.tokens = 0, []
                    # the admission prefill's sample is the first token
                    admit_credited += self._append(s, [int(first[s.index])])
            n_retired = sum(1 for s, r in batch_in if not s.active)

            active_mask = np.array([s.active for s in slots], bool)
            live = int(active_mask.sum())
            if live == 0:
                if batch_in:
                    # every admitted slot finished on its prefill token
                    # (1-token budgets / instant eos): record the churn so
                    # steps never undercount admitted/retired/committed
                    steps.append(StepReport(round_idx, 0, 0, False,
                                            admit_credited, len(batch_in),
                                            n_retired, 0.0))
                if not eng.queue:
                    break
                round_idx += 1                  # idle: awaiting arrivals
                continue

            # ---- re-plan on the LIVE slot count (the paper's N(t))
            gamma, use_sd = eng.gamma, True
            if eng.tuner is not None:
                plan = eng.tuner.plan(live)
                gamma, use_sd = plan["gamma"], plan["use_sd"]
            if eng.force_sd is not None:
                use_sd = eng.force_sd
            if kind == "none":
                use_sd = False
            if not use_sd:
                gamma = 0                       # in-session SD→AR handoff
            if gamma > g_max:
                # max_seq was sized for g_max at stream start; a larger
                # gamma would scatter verify KV past the cache, which JAX
                # clamps SILENTLY — fail loudly instead
                raise ValueError(
                    f"tuner planned gamma={gamma} > g_max={g_max} the "
                    "stream was sized for; expose the tuner's range via a "
                    "'gammas' attribute (AutoTuner does)")
            if first_gamma is None:
                first_gamma = gamma
            used_sd_any |= use_sd

            # ---- one SD round over the pool, retired rows masked out
            state, res = sess.round(state, gamma=gamma, key=eng._next_key(),
                                    active=jnp.asarray(active_mask),
                                    timed=eng.timed)
            credited = 0
            for s in slots:
                if not s.active:
                    continue
                n = int(res.n_commit[s.index])
                credited += self._append(s, list(res.committed[s.index, :n]))
                if not s.active:
                    n_retired += 1

            # live-weighted accounting: retired rows' masked lanes commit
            # nothing, so sigma/alpha describe the work actually requested
            stats.absorb_round(res, live)
            if use_sd and eng.tuner is not None and res.width and live:
                eng.tuner.update_alpha(
                    float(res.n_accept.sum()) / (res.width * live))
            steps.append(StepReport(round_idx, live, gamma, use_sd,
                                    admit_credited + credited,
                                    len(batch_in), n_retired,
                                    res.round_time))
            round_idx += 1

        sess.accumulate_prefetch_totals(stats)
        wall = time.perf_counter() - t_start
        n_tokens = sum(len(r.output) for r in self._finished)
        return WaveReport(
            batch=len(self._finished),
            gamma=first_gamma if first_gamma is not None else 0,
            used_sd=used_sd_any, stats=stats, wall_time=wall,
            tokens_out=n_tokens, proposer=kind, bucket=self.pool,
            moe_dispatch=eng.moe_dispatch, scheduler="continuous",
            steps=steps)

"""Continuous-batching slot scheduler: round-level SD with in-flight admission.

The paper's central claim is that SD speedup for a sparse MoE is a function
of the LIVE batch size N(t).  Wave scheduling can only measure that —
finished sequences ride along as padding until the slowest request
completes, and {use_sd, gamma} is planned once per wave.  This module
*operates* it:

  * a fixed pool of ``max_batch`` KV-cache slots is decoded round-by-round
    through the session API (core/spec_decode.SDEngine.start/round/
    admit_rows),
  * a slot RETIRES the moment its request finishes (per-slot
    ``max_new_tokens``, optional ``eos_id`` early exit) — its row goes
    inactive via the round's ``active`` mask, which is data, so occupancy
    changes never retrace,
  * freed slots are REFILLED between rounds: queued requests (visible from
    their ``arrival_round`` on, so Poisson traces replay exactly) prefill
    into the retired rows via ``SDEngine.admit_rows`` — a ROW-SLICED
    prefill whose cost scales with the admitted rows at their own
    per-admission prompt bucket, not the pool at a stream-global bucket,
  * long prompts optionally prefill in fixed-size CHUNKS
    (``prefill_chunk``), one chunk per round boundary, so a single long
    admission no longer stalls the round it lands in,
  * with ``kv_layout="paged"`` the target cache is block-table paged
    (models/model.py): per-row page lists from a growable pool, so
    ``max_seq`` is only an initial logical capacity — a late-submitted
    long request GROWS the session instead of raising.  Dense streams
    instead REJECT the oversize request (``finish_reason="rejected"``)
    and keep serving,
  * every round consults ``AutoTuner.plan()`` on the LIVE slot count: as
    occupancy decays out of the speedup window the stream hands off SD→AR
    mid-flight (a gamma=0 round in the SAME session — no session switch,
    no state rebuild, and the draft cache stays reconcilable for SD
    re-entry when admissions push N(t) back up).

Per-round ``StepReport``s aggregate into the engine's existing
``WaveReport`` / ``session_stats()`` surfaces; the occupancy trajectory
they carry feeds the decay-aware predicted-vs-measured comparison in
core/analytics.py, and their ``admit_rows``/``admit_tokens`` fields feed
the admission-work accounting (``core/analytics.admission_work``,
``benchmarks/admission_sweep.py``).

This mirrors in-flight batching in TensorRT-LLM / continuous batching in
vLLM at round granularity: admission is batched at round boundaries (not
token boundaries) because one SD round commits a variable 1..gamma+1
tokens per slot.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field, replace as dc_replace
from typing import TYPE_CHECKING, List, Optional, Tuple

import jax.numpy as jnp
import numpy as np

from repro.core.spec_decode import PendingAdmission, SDStats, SessionState
from repro.data.tokenizer import PAD
from repro.models.model import PageAllocator
from repro.serving.engine import WaveReport, _pow2_at_least

if TYPE_CHECKING:                                    # avoid runtime cycle
    from repro.serving.engine import Request, ServingEngine


def submit_poisson(engine: "ServingEngine", prompts, lengths, *,
                   rate: float, max_new_choices=(8, 16, 32),
                   seed: int = 0) -> List[int]:
    """Submit a Poisson-arrival, mixed-length workload to an engine.

    The continuous scheduler's unit of time is the decode ROUND: request i
    arrives at ``cumsum(Exp(1/rate))`` rounds (``rate`` = mean arrivals per
    round; ``rate <= 0`` submits everything at round 0) with a
    ``max_new_tokens`` drawn uniformly from ``max_new_choices`` — the
    mixed-completion-length traffic where wave scheduling pays the most
    padding.  Wave engines ignore ``arrival_round`` (they admit FIFO), so
    the same submission order drives both schedulers comparably.

    Returns the submitted uids in arrival order.
    """
    rng = np.random.default_rng(seed)
    t, uids = 0.0, []
    for i in range(len(lengths)):
        if rate > 0:
            t += rng.exponential(1.0 / rate)
        uids.append(engine.submit(
            np.asarray(prompts[i][: int(lengths[i])]),
            max_new_tokens=int(rng.choice(max_new_choices)),
            arrival_round=int(t)))
    return uids


@dataclass
class SlotState:
    """One KV-cache row of the continuous pool.

    ``active`` rows advance in SD rounds; inactive rows are shape-stable
    padding awaiting admission.  ``tokens`` accumulates the request's
    generated ids (the admission prefill's sampled token first), ``n_out``
    counts them against the request's ``max_new_tokens``.
    """
    index: int
    request: Optional["Request"] = None
    active: bool = False
    n_out: int = 0
    tokens: List[int] = field(default_factory=list)


@dataclass
class StepReport:
    """One SD round of a continuous stream.

    ``live`` is the active-slot count the round decoded (the N(t) the
    tuner planned on), ``committed`` the tokens credited to requests this
    round (budget/eos truncation applied), ``admitted``/``retired`` the
    slot churn at this round's boundary.  ``admit_rows``/``admit_tokens``
    are the rows and row-tokens the boundary's admission prefills actually
    processed (chunked-prefill chunk steps included) — the work the sliced
    path keeps ∝ what was admitted.
    """
    round_index: int
    live: int
    gamma: int
    used_sd: bool
    committed: int
    admitted: int
    retired: int
    round_time: float
    admit_rows: int = 0
    admit_tokens: int = 0


@dataclass
class _Chunking:
    """A slot reserved by an in-flight chunked admission."""
    slot: SlotState
    request: "Request"
    pa: PendingAdmission


class ContinuousScheduler:
    """Round-level slot scheduler over one persistent decoding session.

    Owns the slot pool, the round loop and the admission policy (sliced /
    full, chunked prefill, paged growth); the engine supplies sessions,
    tuner, PRNG splits, layout knobs and the request queue.  One
    ``run_stream()`` call drains the queue (idling through rounds where
    every admissible request is still in flight or yet to arrive) and
    returns an aggregated ``WaveReport`` with per-round ``StepReport``s in
    ``.steps``.
    """

    def __init__(self, engine: "ServingEngine", *,
                 slots: Optional[int] = None):
        self.engine = engine
        self.pool = slots if slots is not None else engine.max_batch
        self._alloc: Optional[PageAllocator] = None

    # ------------------------------------------------------------- admission
    def _admissible(self, round_idx: int) -> bool:
        q = self.engine.queue
        return bool(q) and q[0].arrival_round <= round_idx

    def _need(self, r: "Request") -> int:
        """Cache positions request ``r`` can touch over its lifetime."""
        return len(r.prompt) + r.max_new_tokens + self._g_max + 2

    def _bucket(self, n: int) -> int:
        return _pow2_at_least(n) if self.engine.bucket_batches else n

    def _swa_capacity_floor(self) -> int:
        """Minimum paged logical capacity so every SWA ring allocates at
        its FULL width (window + pad) from round 0.  Rings are dense and
        bounded — sizing them below full width only saves memory when the
        stream never grows, and a growth cannot resize a live ring
        (``pos % w`` would remap entries), so a paged session must never
        start below this."""
        from repro.models.attention import SWA_RING_PAD
        floor = 0
        for m in (self.engine.target, self.engine.draft):
            cfg = getattr(m, "cfg", None)
            if cfg is not None and any(
                    k == "swa" for k in getattr(cfg, "layer_pattern", ())):
                floor = max(floor, cfg.sliding_window + SWA_RING_PAD)
        return floor

    def _open_session(self, sess, max_seq: int) -> SessionState:
        """Open the pool with 1-token fillers; every REAL request then
        enters through the (sliced/chunked) admission path, so admission
        cost is accounted uniformly and the prompt bucket is always
        per-admission."""
        eng = self.engine
        B = self.pool
        toks = np.full((B, 1), PAD, np.int32)
        cache_opts, table = None, None
        if self._alloc is not None:
            cache_opts = {"paged": True, "page_size": eng.page_size,
                          "pool_pages": self._alloc.pool_pages}
            table = self._alloc.table
        params_d = None if eng.proposer_kind == "none" else eng.params_d
        return sess.start(eng.params_t, params_d, jnp.asarray(toks),
                          max_seq=max_seq,
                          lengths=jnp.ones((B,), jnp.int32),
                          key=eng._next_key(), cache_opts=cache_opts,
                          page_table=table)

    def _sync_table(self, state: SessionState) -> SessionState:
        """Push the allocator's (host) block table into the session —
        an input-array swap, never a retrace."""
        pages = dict(state.t_cache["pages"],
                     table=jnp.asarray(self._alloc.table))
        return dc_replace(state, t_cache=dict(state.t_cache, pages=pages))

    def _ensure_capacity(self, sess, state: SessionState, r: "Request",
                         chunking: List["_Chunking"]) -> SessionState:
        """Paged: make the session able to hold ``r`` — grow the logical
        capacity and/or the physical pool (pow2) if it cannot.  In-flight
        chunked admissions' compact caches are padded along, so their
        final scatter still matches the grown session."""
        from repro.models.model import grow_cache_seq
        need = self._need(r)
        alloc = self._alloc
        if need > state.max_seq or not alloc.can_alloc(need):
            pool_pages, max_pages = alloc.grown_geometry(need)
            new_cap = max_pages * alloc.page_size
            state = sess.grow_session(state, new_cap,
                                      pool_pages=pool_pages,
                                      max_pages=max_pages)
            alloc.grow(pool_pages, max_pages)
            state = self._sync_table(state)
            for c in chunking:
                if c.pa.t_cache is not None:
                    c.pa = dc_replace(c.pa, t_cache=grow_cache_seq(
                        c.pa.t_cache, self.engine.target.cfg, new_cap))
        return state

    def _reject(self, r: "Request") -> None:
        """Refuse one request without killing the stream (dense layout:
        the cache was sized at stream start and cannot hold it)."""
        r.output = np.zeros((0,), np.int32)
        r.finish_reason = "rejected"
        r.finished_at = time.perf_counter()
        self.engine.done[r.uid] = r
        self._finished.append(r)

    def _admit_batch(self, sess, state: SessionState,
                     batch_in: List[Tuple[SlotState, "Request"]]
                     ) -> Tuple[SessionState, int, int]:
        """One admission prefill for this round's refills.

        Sliced (default): only the admitted rows, at a prompt bucket
        computed FRESH from this batch (no stream-lifetime ratchet), row-
        count bucketed pow2 with padding lanes replicated round-robin and
        dropped from the scatter.  Full (legacy, kept for the admission
        benchmark's old-vs-sliced comparison): the whole pool is prefilled
        and non-admitted rows discarded via the admit mask.

        Returns ``(state, prefill_rows, prefill_tokens)`` — the work the
        call actually dispatched.
        """
        eng = self.engine
        t_new = max(len(r.prompt) for _, r in batch_in)
        Tp = self._bucket(t_new)
        key = eng._next_key()                 # one fresh key per admission
        if eng.admit_mode == "full":
            B = self.pool
            toks = np.full((B, Tp), PAD, np.int32)
            lengths = np.ones((B,), np.int32)
            mask = np.zeros((B,), bool)
            for s, r in batch_in:
                toks[s.index, : len(r.prompt)] = r.prompt
                lengths[s.index] = len(r.prompt)
                mask[s.index] = True
            state = sess.admit(state, toks, lengths, mask, key=key)
            return state, B, B * Tp
        R = min(self._bucket(len(batch_in)), self.pool)
        toks = np.full((R, Tp), PAD, np.int32)
        lengths = np.ones((R,), np.int32)
        rows = np.zeros((R,), np.int32)
        valid = np.zeros((R,), bool)
        for i in range(R):
            s, r = batch_in[i % len(batch_in)]     # pad lanes replicate
            toks[i, : len(r.prompt)] = r.prompt
            lengths[i] = len(r.prompt)
            rows[i] = s.index
            valid[i] = i < len(batch_in)
        state = sess.admit_rows(state, toks, lengths, rows, valid=valid,
                                key=key)
        return state, R, R * Tp

    # ------------------------------------------------------------ completion
    def _append(self, slot: SlotState, tokens: List[int]) -> int:
        """Credit round tokens to a slot; retire it on budget/eos.

        Returns the number of tokens actually credited (commits past the
        request's budget or its eos are discarded — SD can overshoot
        within a round)."""
        r = slot.request
        eos = self.engine.eos_id
        credited = 0
        for t in tokens:
            if slot.n_out >= r.max_new_tokens:
                break
            slot.tokens.append(int(t))
            slot.n_out += 1
            credited += 1
            if eos is not None and int(t) == eos:
                self._finish(slot, "eos")
                return credited
        if slot.n_out >= r.max_new_tokens:
            self._finish(slot, "length")
        return credited

    def _finish(self, slot: SlotState, reason: str) -> None:
        r = slot.request
        r.output = np.asarray(slot.tokens, np.int32)
        r.finish_reason = reason
        r.finished_at = time.perf_counter()
        self.engine.done[r.uid] = r
        self._finished.append(r)
        slot.request = None
        slot.active = False
        slot.tokens = []
        self._retired_rows.append(slot.index)

    # ------------------------------------------------------------------ loop
    def run_stream(self) -> Optional[WaveReport]:
        """Serve the queued stream to completion; one aggregated report.

        The loop per round: (1) advance every in-flight chunked admission
        by one chunk (landed ones activate their slot); (2) retire/refill
        — admit every admissible request into free slots with one sliced
        prefill, rejecting (dense) or growing for (paged) requests the
        stream wasn't sized for; (3) re-plan — ``tuner.plan(live)`` on the
        live slot count, SD→AR handoff via gamma=0 when the plan says so;
        (4) decode one SD round with the active mask; (5) credit tokens
        per slot, applying per-slot budgets and eos, freeing pages of
        retired rows.  Returns ``None`` on an empty queue.
        """
        eng = self.engine
        if not eng.queue:
            return None
        kind = eng.proposer_kind
        sess = eng._session(kind)
        pending = list(eng.queue)
        # the cache must hold every plannable gamma's verify overshoot
        g_cands = [eng.gamma]
        if eng.tuner is not None:
            g_cands += [int(g) for g in getattr(eng.tuner, "gammas", ())]
        self._g_max = g_max = max(g_cands)

        paged = eng.kv_layout == "paged"
        if paged:
            ps = eng.page_size
            # logical capacity sized on what is VISIBLE at round 0 only —
            # later arrivals grow the session instead of inflating it now
            visible = [r for r in pending if r.arrival_round <= 0] \
                or pending[:1]
            cap = max(self._bucket(max(self._need(r) for r in visible)),
                      self._swa_capacity_floor())
            max_seq = -(-cap // ps) * ps
            pool_pages = 1 + sum(-(-self._need(r) // ps)
                                 for r in visible[: self.pool])
            self._alloc = PageAllocator(self.pool, ps,
                                        _pow2_at_least(pool_pages),
                                        max_seq // ps)
        else:
            # static sizing for the whole stream: the cache must hold the
            # longest KNOWN request; a later over-long submit is rejected
            # (finish_reason="rejected"), never fatal
            self._alloc = None
            max_seq = self._bucket(max(len(r.prompt) for r in pending)) \
                + max(r.max_new_tokens for r in pending) + g_max + 2
            if eng.bucket_batches:
                max_seq = _pow2_at_least(max_seq)

        slots = [SlotState(i) for i in range(self.pool)]
        state = self._open_session(sess, max_seq)
        stats = SDStats()
        steps: List[StepReport] = []
        self._finished: List["Request"] = []
        self._retired_rows: List[int] = []
        chunking: List[_Chunking] = []
        used_sd_any = False
        first_gamma: Optional[int] = None
        round_idx = 0
        t_start = time.perf_counter()
        while True:
            admit_credited, landed, n_retired = 0, [], 0
            admit_rows_n, admit_tokens = 0, 0
            # ---- advance chunked admissions: one chunk per round boundary
            for c in list(chunking):
                R, C = c.pa.prompts.shape[0], c.pa.chunk
                state, pa = sess.admit_chunk(state, c.pa)
                admit_rows_n += R
                admit_tokens += R * min(C, c.pa.remaining)
                if pa is None:
                    chunking.remove(c)
                    landed.append((c.slot, c.request))
                else:
                    c.pa = pa
            # ---- admit: one sliced prefill covers every refill this round
            # (slots whose chunked admission just landed activate below —
            # reserve them so the refill loop can't double-admit the row)
            reserved = {c.slot.index for c in chunking} \
                | {s.index for s, _ in landed}
            free = [s for s in slots
                    if not s.active and s.index not in reserved]
            batch_in: List[Tuple[SlotState, "Request"]] = []
            table_dirty = False
            while free and self._admissible(round_idx):
                r = eng.queue.popleft()
                if not paged and self._need(r) > max_seq:
                    self._reject(r)
                    continue
                if paged:
                    state = self._ensure_capacity(sess, state, r, chunking)
                    self._alloc.alloc(free[0].index, self._need(r))
                    table_dirty = True
                s = free.pop(0)
                if eng.prefill_chunk and len(r.prompt) > eng.prefill_chunk:
                    chunking.append(_Chunking(s, r, sess.begin_admit_chunked(
                        np.asarray(r.prompt)[None, :],
                        np.array([len(r.prompt)], np.int32),
                        np.array([s.index], np.int32),
                        chunk=eng.prefill_chunk, key=eng._next_key())))
                    continue
                batch_in.append((s, r))
            if table_dirty:
                # one table upload covers every page assignment this round
                # (nothing reads it before the admission prefill below)
                state = self._sync_table(state)
            if batch_in:
                state, rows_n, toks_n = self._admit_batch(sess, state,
                                                          batch_in)
                admit_rows_n += rows_n
                admit_tokens += toks_n
                landed.extend(batch_in)
            if landed:
                first = np.asarray(state.last_token)
                for s, r in landed:
                    s.request, s.active = r, True
                    s.n_out, s.tokens = 0, []
                    # the admission prefill's sample is the first token
                    admit_credited += self._append(s, [int(first[s.index])])
            n_retired = sum(1 for s, r in landed if not s.active)

            active_mask = np.array([s.active for s in slots], bool)
            live = int(active_mask.sum())
            if live == 0:
                if landed or admit_rows_n:
                    # every admitted slot finished on its prefill token
                    # (1-token budgets / instant eos) or only chunk work
                    # ran: record the churn so steps never undercount
                    steps.append(StepReport(round_idx, 0, 0, False,
                                            admit_credited, len(landed),
                                            n_retired, 0.0, admit_rows_n,
                                            admit_tokens))
                self._free_retired()
                if not eng.queue and not chunking:
                    break
                round_idx += 1                  # idle: awaiting arrivals
                continue

            # ---- re-plan on the LIVE slot count (the paper's N(t))
            gamma, use_sd = eng.gamma, True
            if eng.tuner is not None:
                plan = eng.tuner.plan(live)
                gamma, use_sd = plan["gamma"], plan["use_sd"]
            if eng.force_sd is not None:
                use_sd = eng.force_sd
            if kind == "none":
                use_sd = False
            if not use_sd:
                gamma = 0                       # in-session SD→AR handoff
            if gamma > g_max:
                # the cache margin was sized for g_max at stream start; a
                # larger gamma would scatter verify KV past the allocated
                # pages/rows, which JAX clamps SILENTLY — fail loudly
                raise ValueError(
                    f"tuner planned gamma={gamma} > g_max={g_max} the "
                    "stream was sized for; expose the tuner's range via a "
                    "'gammas' attribute (AutoTuner does)")
            if first_gamma is None:
                first_gamma = gamma
            used_sd_any |= use_sd

            # ---- one SD round over the pool, retired rows masked out
            state, res = sess.round(state, gamma=gamma, key=eng._next_key(),
                                    active=jnp.asarray(active_mask),
                                    timed=eng.timed)
            credited = 0
            for s in slots:
                if not s.active:
                    continue
                n = int(res.n_commit[s.index])
                credited += self._append(s, list(res.committed[s.index, :n]))
                if not s.active:
                    n_retired += 1
            self._free_retired()

            # live-weighted accounting: retired rows' masked lanes commit
            # nothing, so sigma/alpha describe the work actually requested
            stats.absorb_round(res, live)
            if use_sd and eng.tuner is not None and res.width and live:
                eng.tuner.update_alpha(
                    float(res.n_accept.sum()) / (res.width * live))
            steps.append(StepReport(round_idx, live, gamma, use_sd,
                                    admit_credited + credited,
                                    len(landed), n_retired,
                                    res.round_time, admit_rows_n,
                                    admit_tokens))
            round_idx += 1

        sess.accumulate_prefetch_totals(stats)
        wall = time.perf_counter() - t_start
        n_tokens = sum(len(r.output) for r in self._finished)
        return WaveReport(
            batch=len(self._finished),
            gamma=first_gamma if first_gamma is not None else 0,
            used_sd=used_sd_any, stats=stats, wall_time=wall,
            tokens_out=n_tokens, proposer=kind, bucket=self.pool,
            moe_dispatch=eng.moe_dispatch, scheduler="continuous",
            steps=steps)

    def _free_retired(self) -> None:
        """Return retired rows' pages to the pool (paged layout)."""
        if self._alloc is None:
            self._retired_rows.clear()
            return
        for row in self._retired_rows:
            self._alloc.free_row(row)
        self._retired_rows.clear()

"""Serving-side sampling policies (temperature / top-k / top-p)."""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class SamplingParams:
    temperature: float = 0.0
    top_k: int = 0
    top_p: float = 1.0
    max_new_tokens: int = 64


def sample_logits(logits: jnp.ndarray, key: jax.Array, sp: SamplingParams) -> jnp.ndarray:
    """logits (B, V) → tokens (B,)."""
    if sp.temperature <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    logits = logits.astype(jnp.float32) / sp.temperature
    if sp.top_k > 0:
        kth = jnp.sort(logits, axis=-1)[:, -sp.top_k][:, None]
        logits = jnp.where(logits < kth, -jnp.inf, logits)
    if sp.top_p < 1.0:
        sorted_l = jnp.sort(logits, axis=-1)[:, ::-1]
        probs = jax.nn.softmax(sorted_l, axis=-1)
        csum = jnp.cumsum(probs, axis=-1)
        cutoff_idx = jnp.sum(csum < sp.top_p, axis=-1)
        cutoff = jnp.take_along_axis(sorted_l, cutoff_idx[:, None], axis=-1)
        logits = jnp.where(logits < cutoff, -jnp.inf, logits)
    return jax.random.categorical(key, logits).astype(jnp.int32)

"""The jit-facing serving step functions.

These are the exact functions the multi-pod dry-run lowers for decode
shapes (launch/dryrun.py): one new token per sequence against a KV cache of
``seq_len``, or a gamma+1-token SD verify — the paper's verification
workload as a first-class lowering target.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.models.model import Model


def make_decode_step(model: Model):
    """AR decode: (params, token (B,), cache) → (logits (B,V), cache)."""

    def decode_step(params, token, cache):
        logits, pend = model.extend(params, token[:, None], cache, collect=True)
        cache = model.commit(pend, jnp.ones_like(cache["lengths"]), collected=True)
        return logits[:, 0], cache

    return decode_step


def make_verify_step(model: Model, gamma: int):
    """SD verify: (params, tokens (B, gamma+1), n_commit (B,), cache) →
    (logits (B, gamma+1, V), cache).  n_commit is data (from rejection), so
    one lowering serves every acceptance outcome."""

    def verify_step(params, tokens, n_commit, cache):
        logits, pend = model.extend(params, tokens, cache, collect=True)
        cache = model.commit(pend, n_commit, collected=True)
        return logits, cache

    return verify_step


def make_prefill_step(model: Model):
    def prefill_step(params, tokens, cache, lengths=None, **kw):
        return model.prefill(params, tokens, cache, lengths=lengths, **kw)

    return prefill_step

"""Algorithm 1 — the paper's fitted SD-speedup model + TRR fitting.

  T_target(t) = bias + k1·G(t; λRP, s) + k2·N(t) + k3·G(T̄_exp(t); λRP, s)
  T_draft(t)  = draft_bias + draft_k·G(t; λRP, s)
  T_reject(t) = reject_bias + reject_k·t

  Speedup(B, γ, K, E, σ) =
      σ(γ+1) · T_target(B) / (γ·T_draft(B) + T_target(B·γ) + T_reject(B·γ))

Ten relaxation parameters are fitted against measurements with
scipy.optimize.least_squares (Trust Region Reflective) under the physical
bounds of Appendix C.2 — bias/k2/draft_bias bounded by [1×, 5×] the
theoretical minimum load time from hardware constants.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import List, Sequence

import numpy as np
from scipy.optimize import least_squares

from repro.configs.base import ModelConfig
from repro.core.analytics import (
    expected_activated_experts,
    mean_tokens_per_expert,
    roofline_response,
)
from repro.core.simulator import Hardware, V5E

PARAM_NAMES = ("bias", "k1", "k2", "k3", "draft_bias", "draft_k",
               "reject_bias", "reject_k", "lam", "s")


@dataclass
class Measurement:
    """One row of Alg. 1's measurement input M_i."""
    batch: int
    gamma: int
    top_k: int
    num_experts: int
    sigma: float
    speedup: float


@dataclass
class SpeedupModel:
    """``engine_semantics=False`` is the paper-faithful Alg. 1 (verify = B*gamma
    tokens, gamma draft forwards); True matches our engine (B*(gamma+1) verify
    tokens, gamma+1 draft forwards — the last draft forward only writes KV).

    ``dispatch`` selects the FFN cost regime priced by T_target:
      * "gmm"    — sparse grouped matmul (serving default): k2 scales with
                   N(t) activated experts, k3 with the per-ACTIVATED-expert
                   token response T̄_exp(t).
      * "onehot" — dense one-hot dispatch: every token runs through all E
                   experts, so k2 scales with E regardless of t and each
                   expert sees the full t tokens — the E/K× FLOP overhead
                   the ragged serving kernels remove.

    ``prefetch_hit_rate`` prices draft-phase expert warming (the prefetch
    proposer, core/prefetch.py): the k2 term is the expert-weight LOAD cost
    per activated expert, and a warmed expert's load was already streamed
    during the propose phase, so the VERIFY pass pays k2 · N(t) · (1 - h)
    where h is the measured hit rate.  Only the verify call benefits — the
    AR baseline has no propose phase to hide loads in — and only under the
    gmm regime (onehot reads every expert as part of the dense GEMM, there
    is no separable load to hide).
    """
    hw: Hardware = V5E
    params: np.ndarray | None = None
    engine_semantics: bool = False
    dispatch: str = "gmm"
    prefetch_hit_rate: float = 0.0

    # ------------------------------------------------------------ components
    def _terms(self, p: np.ndarray, dispatch: str | None = None):
        (bias, k1, k2, k3, draft_bias, draft_k, reject_bias, reject_k,
         lam, s) = p
        knee = lam * self.hw.ridge_point
        dispatch = self.dispatch if dispatch is None else dispatch

        def T_target(t, K, E, hit_rate=0.0):
            if dispatch == "onehot":
                n = E * np.ones_like(np.asarray(t, np.float64))
                t_exp = np.asarray(t, np.float64)
                k2_eff = k2                     # dense GEMM: no hidden loads
            else:
                n = expected_activated_experts(t, E, K)
                t_exp = mean_tokens_per_expert(t, K / E)
                k2_eff = k2 * (1.0 - np.clip(hit_rate, 0.0, 1.0))
            return (bias + k1 * roofline_response(t, knee, s)
                    + k2_eff * n + k3 * roofline_response(t_exp, knee, s))

        def T_draft(t):
            return draft_bias + draft_k * roofline_response(t, knee, s)

        def T_reject(t):
            return reject_bias + reject_k * t

        return T_target, T_draft, T_reject

    def target_time(self, t, top_k, num_experts, *, dispatch: str | None = None,
                    params: np.ndarray | None = None,
                    prefetch_hit_rate: float | None = None):
        """Predicted T_target(t) under a dispatch mode.

        Lets serving code compare the onehot (E-dense) and gmm (K-sparse)
        FFN regimes — and, via ``prefetch_hit_rate`` (default: the model's
        own), how much of the expert-load term draft-phase warming hides —
        with one fitted parameter set.
        """
        p = self.params if params is None else np.asarray(params, np.float64)
        assert p is not None, "fit() first or pass params"
        h = self.prefetch_hit_rate if prefetch_hit_rate is None \
            else prefetch_hit_rate
        T_target, _, _ = self._terms(p, dispatch)
        return T_target(np.asarray(t, np.float64),
                        np.asarray(top_k, np.float64),
                        np.asarray(num_experts, np.float64), hit_rate=h)

    def admission_time(self, rows, prompt_tokens, top_k, num_experts, *,
                       dispatch: str | None = None,
                       params: np.ndarray | None = None):
        """Predicted wall time of one admission prefill.

        A prefill forward processes ``rows * prompt_tokens`` tokens through
        the target in one call, so it is priced as
        ``T_target(rows * prompt_tokens)`` — admission work is ∝ ADMITTED
        tokens.  The legacy full-pool path pays
        ``admission_time(pool, global_bucket)`` per refill no matter how
        few rows were actually admitted; the row-sliced path pays
        ``admission_time(admitted, per_admission_bucket)``.  Monotone in
        both arguments, which is what makes the sliced path a strict win.
        """
        t = np.asarray(rows, np.float64) * np.asarray(prompt_tokens,
                                                      np.float64)
        return self.target_time(t, top_k, num_experts, dispatch=dispatch,
                                params=params, prefetch_hit_rate=0.0)

    def prefix_admission_time(self, rows, prompt_tokens, shared_tokens,
                              top_k, num_experts, *,
                              dispatch: str | None = None,
                              params: np.ndarray | None = None):
        """Predicted wall time of one PREFIX-SHARED admission prefill.

        Prefix sharing (serving/scheduler.py, docs/paged_attention.md)
        forks the common prompt prefix's KV pages from a live sibling, so
        the target prefills only the unshared tail: the admission
        processes ``rows * (prompt_tokens - shared_tokens)`` tokens
        (floored at one — the tail always keeps a token to extend with).
        Equal to :meth:`admission_time` at ``shared_tokens = 0``; the gap
        between the two curves is the model-side sharing win
        ``benchmarks/prefix_sweep.py`` holds against measurement.
        """
        tail = np.maximum(np.asarray(prompt_tokens, np.float64)
                          - np.asarray(shared_tokens, np.float64), 1.0)
        return self.admission_time(rows, tail, top_k, num_experts,
                                   dispatch=dispatch, params=params)

    def paged_extend_traffic_time(self, batch, mean_length, max_pages,
                                  page_size, kv_heads, head_dim, *,
                                  n_layers: int = 1, dtype_bytes: int = 2,
                                  mode: str = "kernel"):
        """Lower-bound HBM time of ONE paged decode/verify attention step.

        ``mode="gather"`` prices the dense ``pool[table]`` fallback: every
        extend MATERIALIZES the gathered (B, max_pages*page_size) K/V view
        — read the pages, write the dense copy, read it back inside the
        attention — so traffic scales with the table WIDTH, growing with
        every pool growth even when live contexts are short.
        ``mode="kernel"`` prices the block-table-walking Pallas kernel
        (kernels/decode_attention): K/V pages stream from the pool exactly
        once and only pages overlapping the live context are touched, so
        traffic scales with ``mean_length`` rounded up to a page.  The
        ratio of the two is the kernel's memory-boundedness headroom at a
        given occupancy — the quantity ``benchmarks/prefix_sweep.py``
        reports alongside the measured extend times.
        """
        if mode not in ("kernel", "gather"):
            raise ValueError(f"mode must be 'kernel' or 'gather', "
                             f"got {mode!r}")
        B = np.asarray(batch, np.float64)
        per_pos = 2.0 * kv_heads * head_dim * dtype_bytes    # K + V
        if mode == "gather":
            positions = float(max_pages) * float(page_size)
            passes = 3.0           # pool read + dense write + attend read
        else:
            positions = np.ceil(np.asarray(mean_length, np.float64)
                                / page_size) * page_size
            passes = 1.0
        return n_layers * B * positions * per_pos * passes / self.hw.hbm_bw

    def ep_a2a_time(self, tokens, top_k, d_model, ep_degree, *,
                    n_layers: int = 1, dtype_bytes: int = 2,
                    overlap_time: float = 0.0):
        """Modeled wall time of an EP MoE layer's all-to-all hops.

        The shard_map dispatch (distributed/collectives.py) moves each
        routed (token, k) payload across the interconnect twice — dispatch
        to the expert's shard and combine back — so per device the volume
        is ``tokens·K·d_model·2·dtype_bytes / ep_degree`` per MoE layer,
        priced against ``hw.ici_bw``.  ``overlap_time`` is the window of
        independent compute the dispatch is staggered against (the
        shared-expert matmul runs BETWEEN the two hops); the net cost
        clamps at zero when the collective hides entirely.  Returns 0 for
        ``ep_degree <= 1`` (no interconnect crossed).
        """
        from repro.distributed.collectives import ep_a2a_bytes
        toks = np.asarray(tokens, np.float64)
        vol = np.vectorize(
            lambda n: ep_a2a_bytes(float(n), top_k, d_model, ep_degree,
                                   dtype_bytes=dtype_bytes))(toks)
        raw = n_layers * vol / self.hw.ici_bw
        return np.maximum(raw - overlap_time, 0.0)

    def ep_target_time(self, t, top_k, num_experts, ep_degree, d_model, *,
                       n_moe_layers: int = 1, dtype_bytes: int = 2,
                       overlap_time: float = 0.0,
                       params: np.ndarray | None = None):
        """Predicted T_target(t) under expert-parallel sharded serving.

        Splits the fitted gmm-regime target time into its dense part
        (bias + k1·G(t): attention, router, shared experts — replicated
        work, unchanged by EP) and its expert part (k2·n(t) + k3·G(t̄_exp):
        expert weight loads + expert GEMMs — sharded E/ep per device), and
        adds the ``ep_a2a_time`` interconnect term net of overlap.  The
        EP deployment changes neither N(t) nor T̄_exp (§3.4), so the MoESD
        speedup analysis carries over with only this cost relabeling —
        ``benchmarks/ep_sweep.py`` holds the a2a term against measured
        per-phase timings.
        """
        p = self.params if params is None else np.asarray(params, np.float64)
        assert p is not None, "fit() first or pass params"
        (bias, k1, k2, k3, _db, _dk, _rb, _rk, lam, s) = p
        knee = lam * self.hw.ridge_point
        t = np.asarray(t, np.float64)
        dense = bias + k1 * roofline_response(t, knee, s)
        n = expected_activated_experts(t, num_experts, top_k)
        t_exp = mean_tokens_per_expert(t, top_k / num_experts)
        expert = k2 * n + k3 * roofline_response(t_exp, knee, s)
        a2a = self.ep_a2a_time(t, top_k, d_model, ep_degree,
                               n_layers=n_moe_layers,
                               dtype_bytes=dtype_bytes,
                               overlap_time=overlap_time)
        return dense + expert / max(ep_degree, 1) + a2a

    def compute_speedup(self, p: np.ndarray, batch, gamma, top_k,
                        num_experts, sigma):
        """Alg. 1 line 3 — vectorized over measurement arrays."""
        batch = np.asarray(batch, np.float64)
        gamma = np.asarray(gamma, np.float64)
        T_target, T_draft, T_reject = self._terms(p)
        gv = gamma + 1.0 if self.engine_semantics else gamma
        t_ar = T_target(batch, np.asarray(top_k, np.float64),
                        np.asarray(num_experts, np.float64))
        # only the VERIFY call sees warmed experts (hit_rate): the AR
        # baseline above has no draft phase to overlap the loads with
        t_ver = T_target(batch * gv, np.asarray(top_k, np.float64),
                         np.asarray(num_experts, np.float64),
                         hit_rate=self.prefetch_hit_rate)
        t_sd = gv * T_draft(batch) + t_ver + T_reject(batch * gv)
        return np.asarray(sigma, np.float64) * (gamma + 1.0) * t_ar / t_sd

    def predict(self, batch, gamma, top_k, num_experts, sigma):
        assert self.params is not None, "fit() first"
        return self.compute_speedup(self.params, batch, gamma, top_k,
                                    num_experts, sigma)

    def predict_decay(self, live, gammas, top_k, num_experts, sigma,
                      committed=None):
        """Occupancy-decay-aware speedup for a continuous stream.

        ``live``/``gammas`` are per-round arrays (the N(t) trajectory and
        the gammas a continuous scheduler actually planned —
        serving/scheduler.StepReport), ``committed`` the per-round token
        credits used as weights.  Returns ``{"per_round", "mean",
        "token_weighted"}``: the fitted speedup-vs-batch curve walked
        along the measured occupancy decay, with ``token_weighted`` the
        model-side number to hold against a measured continuous-vs-AR
        throughput ratio (see core/analytics.predicted_decay_speedup).
        """
        from repro.core.analytics import predicted_decay_speedup
        return predicted_decay_speedup(
            live, gammas,
            lambda b, g: float(self.predict(b, g, top_k, num_experts,
                                            sigma)),
            committed=committed)

    # ---------------------------------------------------------------- bounds
    def bounds(self, target_cfg: ModelConfig, draft_cfg: ModelConfig,
               t_rej_max: float, dtype_bytes: int = 2):
        """Appendix C.2 physically-grounded search bounds."""
        bw = self.hw.hbm_bw
        v_dense = (target_cfg.param_count()
                   - target_cfg.num_experts * 3 * target_cfg.d_model
                   * target_cfg.moe_d_ff
                   * sum(target_cfg.moe_pattern) * target_cfg.num_periods)
        v_dense = max(v_dense, 1)
        bias_min = v_dense * dtype_bytes / bw
        v_exp = 3 * target_cfg.d_model * target_cfg.moe_d_ff \
            * sum(target_cfg.moe_pattern) * target_cfg.num_periods
        k2_min = max(v_exp, 1) * dtype_bytes / bw / max(target_cfg.num_experts, 1)
        db_min = draft_cfg.param_count() * dtype_bytes / bw
        lo = np.array([bias_min, 0.0, k2_min, 0.0, db_min, 0.0,
                       0.0, 0.0, 0.2, 1.0])
        hi = np.array([5 * bias_min, np.inf, 5 * k2_min, np.inf, 5 * db_min,
                       np.inf, t_rej_max, t_rej_max, 1.0, 2.0])
        return lo, hi

    # ------------------------------------------------------------------ fit
    def fit(self, measurements: Sequence[Measurement],
            target_cfg: ModelConfig, draft_cfg: ModelConfig,
            t_rej_max: float = 1e-3, seed: int = 0,
            n_restarts: int = 8) -> dict:
        """Multi-start TRR: the loss surface has local minima, so we restart
        from ``n_restarts`` log-uniform points inside the bounds and keep the
        best solution (the paper fits once on GPU data; simulator data is
        smoother and rewards restarts)."""
        m = measurements
        B = np.array([x.batch for x in m], np.float64)
        G = np.array([x.gamma for x in m], np.float64)
        K = np.array([x.top_k for x in m], np.float64)
        E = np.array([x.num_experts for x in m], np.float64)
        S = np.array([x.sigma for x in m], np.float64)
        Y = np.array([x.speedup for x in m], np.float64)
        lo, hi = self.bounds(target_cfg, draft_cfg, t_rej_max)

        def resid(p):
            return self.compute_speedup(p, B, G, K, E, S) - Y

        rng = np.random.default_rng(seed)
        # scale for unbounded coefficients: draft-model load time is a
        # natural unit for the k's
        unit = lo[4] if lo[4] > 0 else 1e-4
        best = None
        total_nfev = 0
        for r in range(n_restarts):
            x0 = np.empty(10)
            for i in range(10):
                if np.isinf(hi[i]):
                    x0[i] = unit * 10 ** rng.uniform(-3, 1)
                else:
                    x0[i] = lo[i] + rng.uniform(0.05, 0.95) * (hi[i] - lo[i])
            sol = least_squares(resid, x0, bounds=(lo, hi), method="trf",
                                max_nfev=5_000)
            total_nfev += sol.nfev
            if best is None or sol.cost < best.cost:
                best = sol
        self.params = best.x
        mse = float(np.mean(best.fun ** 2))
        return {"params": dict(zip(PARAM_NAMES, best.x)), "mse": mse,
                "cost": float(best.cost), "nfev": total_nfev}


def stride_sample(rows: List[Measurement], m: int) -> List[Measurement]:
    """Appendix C.2 selection: M = df[::stride] with m = ceil(len/stride)."""
    stride = max(1, int(np.ceil(len(rows) / m)))
    return rows[::stride]

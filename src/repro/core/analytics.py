"""Closed-form theory from the paper (Sec. 3.2, Eqs. 6-11, Appendix B).

All functions are numpy-friendly scalars/arrays; jnp not required since
these feed benchmarks and the perf model, not the training graph.
"""
from __future__ import annotations

import numpy as np


def expected_activated_experts(t, num_experts: int, top_k: int):
    """Eq. 8:  N(t) = E * (1 - ((E-K)/E)^t)  — expected #activated experts
    for t tokens through the gate, i.i.d. uniform routing."""
    t = np.asarray(t, dtype=np.float64)
    E = np.asarray(num_experts, dtype=np.float64)
    K = np.asarray(top_k, dtype=np.float64)
    return E * (1.0 - ((E - K) / E) ** t)


def activation_threshold(rho: float, tau: float = 0.95) -> int:
    """Eq. 9:  T_thres = ceil(log_{1-rho}(1-tau)) — tokens needed so that
    N(t) >= tau * E (near-full expert activation)."""
    if rho >= 1.0:
        return 1
    return int(np.ceil(np.log(1.0 - tau) / np.log(1.0 - rho)))


def mean_tokens_per_expert(t, rho: float):
    """Eq. 10:  T̄_exp(t; rho) = rho * t / (1 - (1-rho)^t) — average tokens
    each *activated* expert processes.  Monotone increasing in rho for t>1
    (Appendix B), hence sparser MoE ⇒ fewer tokens/expert ⇒ more
    memory-bound."""
    t = np.asarray(t, dtype=np.float64)
    rho = np.asarray(rho, dtype=np.float64)
    denom = 1.0 - (1.0 - rho) ** t
    dense = rho >= 1.0
    return np.where(
        t == 0, 0.0,
        np.where(dense, t, rho * t / np.maximum(denom, 1e-300)))


def roofline_response(t, knee: float, s: float):
    """Eq. 11:  G(t; knee, s) — execution-time response to token count.
    Exponential (slow start) below the ridge-point knee, C^1-continuous
    linear beyond it."""
    t = np.asarray(t, dtype=np.float64)
    s = max(float(s), 1.0 + 1e-9)
    below = np.power(s, np.minimum(t, knee))
    above = (s ** knee) * (1.0 + np.log(s) * (t - knee))
    return np.where(t <= knee, below, above)


def sigma_from_alpha(alpha, gamma: int):
    """Eq. 5: sigma = (1 - alpha^(gamma+1)) / ((1 - alpha)(gamma+1))."""
    alpha = np.asarray(alpha, dtype=np.float64)
    safe = np.abs(1.0 - alpha) > 1e-9
    num = np.where(safe, (1.0 - alpha ** (gamma + 1)) / np.where(safe, 1.0 - alpha, 1.0),
                   gamma + 1.0)
    return num / (gamma + 1)


def expected_accepted_len(alpha, gamma: int):
    """S/R = sigma * (gamma + 1): mean tokens committed per SD round."""
    return sigma_from_alpha(alpha, gamma) * (gamma + 1)

"""Closed-form theory from the paper (Sec. 3.2, Eqs. 6-11, Appendix B).

All functions are numpy-friendly scalars/arrays; jnp not required since
these feed benchmarks and the perf model, not the training graph.
"""
from __future__ import annotations

import numpy as np


def expected_activated_experts(t, num_experts: int, top_k: int):
    """Eq. 8:  N(t) = E * (1 - ((E-K)/E)^t)  — expected #activated experts
    for t tokens through the gate, i.i.d. uniform routing."""
    t = np.asarray(t, dtype=np.float64)
    E = np.asarray(num_experts, dtype=np.float64)
    K = np.asarray(top_k, dtype=np.float64)
    return E * (1.0 - ((E - K) / E) ** t)


def activation_threshold(rho: float, tau: float = 0.95) -> int:
    """Eq. 9:  T_thres = ceil(log_{1-rho}(1-tau)) — tokens needed so that
    N(t) >= tau * E (near-full expert activation)."""
    if rho >= 1.0:
        return 1
    return int(np.ceil(np.log(1.0 - tau) / np.log(1.0 - rho)))


def mean_tokens_per_expert(t, rho: float):
    """Eq. 10:  T̄_exp(t; rho) = rho * t / (1 - (1-rho)^t) — average tokens
    each *activated* expert processes.  Monotone increasing in rho for t>1
    (Appendix B), hence sparser MoE ⇒ fewer tokens/expert ⇒ more
    memory-bound."""
    t = np.asarray(t, dtype=np.float64)
    rho = np.asarray(rho, dtype=np.float64)
    denom = 1.0 - (1.0 - rho) ** t
    dense = rho >= 1.0
    return np.where(
        t == 0, 0.0,
        np.where(dense, t, rho * t / np.maximum(denom, 1e-300)))


def roofline_response(t, knee: float, s: float):
    """Eq. 11:  G(t; knee, s) — execution-time response to token count.
    Exponential (slow start) below the ridge-point knee, C^1-continuous
    linear beyond it."""
    t = np.asarray(t, dtype=np.float64)
    s = max(float(s), 1.0 + 1e-9)
    below = np.power(s, np.minimum(t, knee))
    above = (s ** knee) * (1.0 + np.log(s) * (t - knee))
    return np.where(t <= knee, below, above)


def sigma_from_alpha(alpha, gamma: int):
    """Eq. 5: sigma = (1 - alpha^(gamma+1)) / ((1 - alpha)(gamma+1))."""
    alpha = np.asarray(alpha, dtype=np.float64)
    safe = np.abs(1.0 - alpha) > 1e-9
    num = np.where(safe, (1.0 - alpha ** (gamma + 1)) / np.where(safe, 1.0 - alpha, 1.0),
                   gamma + 1.0)
    return num / (gamma + 1)


def expected_accepted_len(alpha, gamma: int):
    """S/R = sigma * (gamma + 1): mean tokens committed per SD round."""
    return sigma_from_alpha(alpha, gamma) * (gamma + 1)


def occupancy_timeline(live, committed=None):
    """Summarize a continuous stream's live-batch trajectory N(t).

    ``live`` is the per-round active-slot count a continuous scheduler
    decoded (serving/scheduler.StepReport.live), ``committed`` the tokens
    credited per round (default: uniform).  Returns the occupancy numbers
    the decay-aware speedup comparison needs:

    ``mean_live``
        time-averaged N(t) (each round weighted equally),
    ``token_weighted_live``
        the batch size an average TOKEN was decoded at — this, not
        ``mean_live``, is what throughput-weighted speedup sees,
    ``peak_live`` / ``final_live`` / ``mean_occupancy``
        the decay shape: a wave scheduler pins ``mean_occupancy`` near the
        drained tail's value; continuous admission keeps it near 1.
    """
    live = np.asarray(live, dtype=np.float64)
    if live.size == 0:
        return {"rounds": 0, "peak_live": 0.0, "final_live": 0.0,
                "mean_live": 0.0, "token_weighted_live": 0.0,
                "mean_occupancy": 0.0}
    committed = (np.ones_like(live) if committed is None
                 else np.asarray(committed, dtype=np.float64))
    w = committed / max(committed.sum(), 1e-12)
    peak = float(live.max())
    return {
        "rounds": int(live.size),
        "peak_live": peak,
        "final_live": float(live[-1]),
        "mean_live": float(live.mean()),
        "token_weighted_live": float((w * live).sum()),
        "mean_occupancy": float(live.mean() / max(peak, 1.0)),
    }


def admission_work(admit_shapes, pool: int, full_bucket: int):
    """Prefill token-work of a stream's admissions, sliced vs full-pool.

    ``admit_shapes`` is a list of ``(prompt_bucket, rows)`` pairs — one
    per admission prefill, exactly the entries ``SDEngine.admit_trace_log``
    records plus repeats for shape-sharing refills (callers usually pass
    per-round ``StepReport.admit_rows``/``admit_tokens`` reconstructions
    or the raw per-admission shapes).  The sliced path's prefill work is
    ``sum(rows_i * bucket_i)`` — ∝ what was admitted; the legacy full path
    pays ``pool * full_bucket`` per admission regardless.  Returns both
    totals and the fraction of prefill row-tokens the sliced path avoids.
    """
    shapes = [(int(t), int(r)) for t, r in admit_shapes]
    sliced = sum(r * t for t, r in shapes)
    full = len(shapes) * int(pool) * int(full_bucket)
    return {
        "admissions": len(shapes),
        "sliced_tokens": sliced,
        "full_tokens": full,
        "savings": 1.0 - sliced / max(full, 1),
    }


def predicted_decay_speedup(live, gammas, speedup_fn, committed=None):
    """Occupancy-decay-aware predicted speedup for a continuous stream.

    Evaluates ``speedup_fn(batch, gamma)`` (e.g. ``AutoTuner.speedup`` or
    a fitted ``SpeedupModel`` closure) at every round's LIVE batch size —
    the paper's speedup-vs-batch curve walked along the measured N(t)
    trajectory instead of sampled at one static B.  Returns per-round
    predictions plus their committed-token-weighted mean, the model-side
    number a measured continuous-vs-AR throughput ratio should be compared
    against (rounds that committed more tokens matter more).

    gamma=0 rounds (the scheduler's in-session SD→AR handoff) are priced
    at exactly 1.0 — they ARE the AR baseline — so ``speedup_fn`` is never
    called with a gamma its SD formula can't express.
    """
    live = np.asarray(live, dtype=np.float64)
    gammas = np.broadcast_to(np.asarray(gammas, dtype=np.float64),
                             live.shape)
    per_round = np.array(
        [1.0 if int(g) == 0 else float(speedup_fn(int(b), int(g)))
         for b, g in zip(live, gammas)],
        dtype=np.float64)
    if per_round.size == 0:
        return {"per_round": per_round, "mean": 0.0, "token_weighted": 0.0}
    committed = (np.ones_like(per_round) if committed is None
                 else np.asarray(committed, dtype=np.float64))
    w = committed / max(committed.sum(), 1e-12)
    return {"per_round": per_round,
            "mean": float(per_round.mean()),
            "token_weighted": float((per_round * w).sum())}


def fault_recovery_summary(steps):
    """Fault/recovery accounting over one continuous stream's StepReports.

    Pure-numpy reduction of the resilience fields the scheduler threads
    through ``StepReport`` (serving/scheduler.py): totals per disruption
    kind, the fraction of rounds disrupted, and the RECOVERY LATENCY of
    every preemption — the number of rounds from a ``preempted > 0``
    boundary until the next boundary that re-admits a requeued request
    (an ``admitted > 0`` round after it).  Benchmarks plot its mean
    against the injected fault rate (benchmarks/fault_sweep.py); a stream
    whose preemptions never re-admit reports latency ``inf`` — visible,
    not silently dropped.

    Parameters
    ----------
    steps : sequence of StepReport
        One stream's per-round reports, in round order.

    Returns
    -------
    dict
        ``{"rounds", "preempted", "faults", "timeouts", "deferred",
        "disrupted_rounds", "disrupted_fraction",
        "recovery_latency_rounds": [..], "mean_recovery_latency"}``.
    """
    pre = np.asarray([s.preempted for s in steps], np.int64)
    fau = np.asarray([s.faults for s in steps], np.int64)
    tim = np.asarray([s.timeouts for s in steps], np.int64)
    def_ = np.asarray([s.deferred for s in steps], np.int64)
    adm = np.asarray([s.admitted for s in steps], np.int64)
    n = len(pre)
    disrupted = (pre > 0) | (fau > 0) | (tim > 0) | (def_ > 0)
    latencies = []
    for i in np.nonzero(pre > 0)[0]:
        after = np.nonzero(adm[i + 1:] > 0)[0]
        latencies.append(float(after[0] + 1) if after.size else float("inf"))
    return {
        "rounds": int(n),
        "preempted": int(pre.sum()),
        "faults": int(fau.sum()),
        "timeouts": int(tim.sum()),
        "deferred": int(def_.sum()),
        "disrupted_rounds": int(disrupted.sum()),
        "disrupted_fraction": float(disrupted.sum() / max(n, 1)),
        "recovery_latency_rounds": latencies,
        "mean_recovery_latency": (float(np.mean(latencies))
                                  if latencies else 0.0),
    }

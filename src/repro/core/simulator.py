"""Analytic TPU-v5e roofline simulator — the measurement substrate.

The paper times forwards on GPUs under vLLM; this container has no
accelerator, so per DESIGN.md §2 the wall-clock terms (T_T, T_D, T_reject)
come from a component-level roofline model of a TPU v5e chip group:

  per component: time = max(flops / (F_peak·eff_c), bytes / (BW·eff_m))

summed over layer components (attention projections, attention scores/KV
read, dense FFN, MoE experts, router, embedding head).  The MoE term embeds
the paper's two effects directly:

  * number of activated experts N(t)  →  expert weight bytes loaded,
  * per-expert token load T̄_exp(t;ρ)  →  per-expert compute-vs-load max().

σ/α always come from REAL runs of the SD engine; the simulator only prices
time.  It is deliberately simple — the paper's own Alg. 1 then fits a
10-parameter model against its outputs, exactly as the paper fits GPU
measurements (Appendix C).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional

import numpy as np

from repro.configs.base import ModelConfig
from repro.core.analytics import expected_activated_experts, mean_tokens_per_expert


@dataclass(frozen=True)
class Hardware:
    name: str = "tpu-v5e"
    peak_flops: float = 197e12            # bf16 FLOP/s per chip
    hbm_bw: float = 819e9                 # bytes/s per chip
    ici_bw: float = 50e9                  # bytes/s per link
    vmem_bytes: int = 16 * 2 ** 20
    compute_eff: float = 0.85             # achievable fraction of peak
    mem_eff: float = 0.75
    op_overhead: float = 2e-6             # fixed per-component dispatch cost
    num_chips: int = 1                    # tensor/expert-parallel group size

    @property
    def ridge_point(self) -> float:
        return self.peak_flops / self.hbm_bw


V5E = Hardware()


def _component_time(flops: float, bytes_: float, hw: Hardware) -> float:
    n = max(hw.num_chips, 1)
    tc = flops / (hw.peak_flops * hw.compute_eff * n)
    tm = bytes_ / (hw.hbm_bw * hw.mem_eff * n)
    return max(tc, tm) + hw.op_overhead


@dataclass
class Simulator:
    hw: Hardware = V5E
    dtype_bytes: int = 2                   # bf16 weights/activations
    context_len: int = 512                 # mean KV length (paper omits KV; kept small)
    expert_offload_bw: Optional[float] = None
    # paper §3.4 "extended configurations": when expert weights live in host
    # memory, their load bandwidth drops from HBM to PCIe/DMA — the system
    # becomes more memory-bound and the SD window widens.  Set e.g. 64e9.

    # ------------------------------------------------------------------ FFN
    def _dense_ffn_time(self, cfg: ModelConfig, t: int) -> float:
        f = cfg.d_ff
        flops = 2.0 * t * 3 * cfg.d_model * f
        bytes_ = 3.0 * cfg.d_model * f * self.dtype_bytes
        return _component_time(flops, bytes_, self.hw)

    def _moe_ffn_time(self, cfg: ModelConfig, t: int) -> float:
        E, K, f = cfg.num_experts, cfg.num_experts_per_tok, cfg.moe_d_ff
        n_act = expected_activated_experts(t, E, K)
        t_exp = mean_tokens_per_expert(t, cfg.moe_sparsity)
        expert_bytes = 3.0 * cfg.d_model * f * self.dtype_bytes
        expert_flops = 2.0 * t_exp * 3 * cfg.d_model * f
        load_bw = (self.expert_offload_bw if self.expert_offload_bw
                   else self.hw.hbm_bw * self.hw.mem_eff)
        per_expert = max(
            expert_flops / (self.hw.peak_flops * self.hw.compute_eff),
            expert_bytes / load_bw,
        )
        # experts execute across the parallel group; router is negligible
        n = max(self.hw.num_chips, 1)
        total = per_expert * float(n_act) / n + self.hw.op_overhead
        if cfg.num_shared_experts:
            total += self._dense_ffn_time(
                cfg.with_overrides(d_ff=f * cfg.num_shared_experts), t)
        return total

    # ------------------------------------------------------------ attention
    def _attn_time(self, cfg: ModelConfig, batch: int, s: int, kind: str) -> float:
        t = batch * s
        hd = cfg.head_dim
        if kind == "mla":
            pbytes = (cfg.d_model * (cfg.mla_kv_lora_rank + cfg.mla_qk_rope_dim)
                      + cfg.mla_kv_lora_rank * cfg.num_heads
                      * (cfg.mla_qk_nope_dim + cfg.mla_v_head_dim)
                      + cfg.d_model * cfg.num_heads * (cfg.mla_qk_nope_dim + cfg.mla_qk_rope_dim)
                      + cfg.num_heads * cfg.mla_v_head_dim * cfg.d_model) * self.dtype_bytes
            kv_entry = (cfg.mla_kv_lora_rank + cfg.mla_qk_rope_dim)
        else:
            pbytes = (cfg.d_model * (cfg.num_heads + 2 * cfg.num_kv_heads) * hd
                      + cfg.num_heads * hd * cfg.d_model) * self.dtype_bytes
            kv_entry = 2 * cfg.num_kv_heads * hd
        proj_flops = 2.0 * t * pbytes / self.dtype_bytes
        ctx = self.context_len if kind != "swa" else min(
            self.context_len, cfg.sliding_window or self.context_len)
        kv_bytes = batch * ctx * kv_entry * self.dtype_bytes
        score_flops = 2.0 * t * ctx * cfg.num_heads * hd * 2
        return (_component_time(proj_flops, pbytes, self.hw)
                + _component_time(score_flops, kv_bytes, self.hw))

    def _recurrent_time(self, cfg: ModelConfig, batch: int, s: int, kind: str) -> float:
        from repro.configs.base import _ssm_params
        t = batch * s
        pbytes = _ssm_params(cfg, kind) * self.dtype_bytes
        flops = 2.0 * t * pbytes / self.dtype_bytes
        # recurrent state read/write per step
        if kind == "mamba":
            state = batch * cfg.ssm_expand * cfg.d_model * cfg.ssm_state_dim * 4
        elif kind == "mlstm":
            d_in = 2 * cfg.d_model
            state = batch * cfg.num_heads * (d_in // cfg.num_heads) ** 2 * 4
        else:
            state = batch * cfg.d_model * 4
        return _component_time(flops, pbytes + state * s, self.hw)

    # -------------------------------------------------------------- forward
    def forward_time(self, cfg: ModelConfig, batch: int, s: int,
                     context_len: Optional[int] = None) -> float:
        """Seconds for one forward of ``s`` tokens per sequence, batch B."""
        if context_len is not None:
            old = self.context_len
            self.context_len = context_len
        t = batch * s
        total = 0.0
        for kind, is_moe in zip(cfg.layer_pattern, cfg.moe_pattern):
            if kind in ("attn", "swa", "mla"):
                lt = self._attn_time(cfg, batch, s, kind)
            else:
                lt = self._recurrent_time(cfg, batch, s, kind)
            if is_moe:
                lt += self._moe_ffn_time(cfg, t)
            elif kind not in ("mlstm", "slstm") and cfg.d_ff > 0:
                lt += self._dense_ffn_time(cfg, t)
            total += lt * cfg.num_periods
        # unembedding (head) — embedding gather is negligible
        head_bytes = cfg.vocab_size * cfg.d_model * self.dtype_bytes
        total += _component_time(2.0 * t * cfg.vocab_size * cfg.d_model,
                                 head_bytes, self.hw)
        if context_len is not None:
            self.context_len = old
        return total

    # ------------------------------------------------------- raw cost census
    def forward_costs(self, cfg: ModelConfig, batch: int, s: int,
                      context_len: Optional[int] = None,
                      train: bool = False) -> dict:
        """Analytic (FLOPs, HBM bytes) census for one forward (or train
        step) — the roofline numerator when HLO cost_analysis is unusable
        (XLA counts scan bodies once; see launch/roofline.py)."""
        ctx = context_len if context_len is not None else self.context_len
        t = batch * s
        flops = 0.0
        pbytes_total = 0.0
        act_bytes = 0.0
        kv_bytes = 0.0
        d = cfg.d_model
        for kind, is_moe in zip(cfg.layer_pattern, cfg.moe_pattern):
            if kind in ("attn", "swa", "mla"):
                if kind == "mla":
                    pb = (d * (cfg.mla_kv_lora_rank + cfg.mla_qk_rope_dim)
                          + cfg.mla_kv_lora_rank * cfg.num_heads
                          * (cfg.mla_qk_nope_dim + cfg.mla_v_head_dim)
                          + d * cfg.num_heads * (cfg.mla_qk_nope_dim + cfg.mla_qk_rope_dim)
                          + cfg.num_heads * cfg.mla_v_head_dim * d)
                    kv_entry = cfg.mla_kv_lora_rank + cfg.mla_qk_rope_dim
                else:
                    pb = (d * (cfg.num_heads + 2 * cfg.num_kv_heads) * cfg.head_dim
                          + cfg.num_heads * cfg.head_dim * d)
                    kv_entry = 2 * cfg.num_kv_heads * cfg.head_dim
                c = ctx if kind != "swa" else min(ctx, cfg.sliding_window or ctx)
                # causal masking halves effective score FLOPs when the
                # queries span the context (train/prefill); decode steps
                # (s << ctx) attend the full prefix
                causal_frac = 0.5 if s > 1 and s == ctx else 1.0
                flops += (2.0 * t * pb
                          + 2.0 * t * c * cfg.num_heads * cfg.head_dim * 2
                          * causal_frac)
                pbytes_total += pb * self.dtype_bytes
                kv_bytes += batch * c * kv_entry * self.dtype_bytes
            else:
                from repro.configs.base import _ssm_params
                pb = _ssm_params(cfg, kind)
                flops += 2.0 * t * pb
                pbytes_total += pb * self.dtype_bytes
            if is_moe:
                E, K, f = cfg.num_experts, cfg.num_experts_per_tok, cfg.moe_d_ff
                n_act = float(expected_activated_experts(t, E, K))
                flops += 2.0 * t * K * 3 * d * f
                pbytes_total += n_act * 3 * d * f * self.dtype_bytes
                if cfg.num_shared_experts:
                    fs = f * cfg.num_shared_experts
                    flops += 2.0 * t * 3 * d * fs
                    pbytes_total += 3 * d * fs * self.dtype_bytes
            elif kind not in ("mlstm", "slstm") and cfg.d_ff > 0:
                flops += 2.0 * t * 3 * d * cfg.d_ff
                pbytes_total += 3 * d * cfg.d_ff * self.dtype_bytes
            act_bytes += 4 * t * d * self.dtype_bytes
        flops *= cfg.num_periods
        pbytes_total *= cfg.num_periods
        kv_bytes *= cfg.num_periods
        act_bytes *= cfg.num_periods
        # head: train reads every position, inference only the sampled ones
        head_t = t if train else batch
        flops += 2.0 * head_t * d * cfg.vocab_size
        pbytes_total += cfg.vocab_size * d * self.dtype_bytes
        if cfg.is_encoder_decoder:
            enc_pb = cfg.encoder_layers * (
                (4 * d * d) + 3 * d * cfg.d_ff) * self.dtype_bytes
            pbytes_total += enc_pb
            flops += 2.0 * batch * cfg.encoder_seq_len * enc_pb / self.dtype_bytes
        if train:
            flops *= 3.0                                  # fwd + bwd
            pbytes_total *= 3.0                           # read + grad write + opt
            act_bytes *= 2.0
        return {"flops": flops,
                "bytes": pbytes_total + act_bytes + kv_bytes}

    def reject_time(self, batch: int, gamma: int, vocab: int) -> float:
        """Rejection sampling: O(B * gamma * V) elementwise + sampling."""
        bytes_ = 3.0 * batch * (gamma + 1) * vocab * 4
        return _component_time(batch * gamma * vocab * 4.0, bytes_, self.hw)

    # -------------------------------------------------------------- SD time
    def sd_round_time(self, target: ModelConfig, draft: ModelConfig,
                      batch: int, gamma: int) -> dict:
        propose = (gamma + 1) * self.forward_time(draft, batch, 1)
        verify = self.forward_time(target, batch, gamma + 1)
        reject = self.reject_time(batch, gamma, target.vocab_size)
        return {"propose": propose, "verify": verify, "reject": reject,
                "total": propose + verify + reject}

    def sd_speedup(self, target: ModelConfig, draft: ModelConfig,
                   batch: int, gamma: int, sigma: float) -> float:
        """Paper Eq. 4 with engine semantics (gamma+1-token verify)."""
        round_t = self.sd_round_time(target, draft, batch, gamma)["total"]
        t_ar = self.forward_time(target, batch, 1)
        return sigma * (gamma + 1) * t_ar / round_t

    def target_efficiency(self, target: ModelConfig, batch: int, gamma: int) -> float:
        return (self.forward_time(target, batch, 1)
                / self.forward_time(target, batch, gamma + 1))

"""Beyond-paper: closed-loop SD auto-tuning from the fitted model.

The paper stops at *explaining* speedup; here the same model drives policy:

  * ``best_gamma(B)``    — γ* = argmax predicted speedup at the current batch
  * ``speedup_window()`` — the batch-size band where predicted speedup stays
                           above x_peak/√2 (the paper's Fig. 4 plateau
                           criterion), i.e. when SD should be ON at all
  * ``plan(B)``          — {use_sd, gamma} decision for the serving engine

Works off either the analytic simulator or a fitted SpeedupModel; the
serving engine re-plans as the admitted batch size changes (engine.py).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Optional

import numpy as np

from repro.configs.base import ModelConfig
from repro.core.analytics import sigma_from_alpha
from repro.core.simulator import Simulator


@dataclass
class AutoTuner:
    target: ModelConfig
    draft: ModelConfig
    alpha: float = 0.8                 # measured acceptance rate (running est.)
    gammas: tuple = (1, 2, 3, 4, 5, 6, 8)
    sim: Optional[Simulator] = None
    predict: Optional[Callable] = None  # fitted SpeedupModel.predict

    def __post_init__(self):
        if self.sim is None:
            self.sim = Simulator()

    def speedup(self, batch: int, gamma: int, alpha: Optional[float] = None) -> float:
        a = self.alpha if alpha is None else alpha
        sigma = float(sigma_from_alpha(a, gamma))
        if self.predict is not None:
            return float(self.predict(batch, gamma, self.target.num_experts_per_tok,
                                      max(self.target.num_experts, 1), sigma))
        return self.sim.sd_speedup(self.target, self.draft, batch, gamma, sigma)

    def best_gamma(self, batch: int) -> tuple[int, float]:
        best = max(self.gammas, key=lambda g: self.speedup(batch, g))
        return best, self.speedup(batch, best)

    def speedup_window(self, batches=None) -> dict:
        """Fig. 4 analysis: peak batch, peak speedup, and the >= peak/sqrt(2)
        batch window, maximized over gamma per batch."""
        batches = batches if batches is not None else [1, 2, 4, 8, 16, 24, 32,
                                                       48, 64, 96, 128, 192, 256]
        curve = {b: self.best_gamma(b)[1] for b in batches}
        peak_b = max(curve, key=curve.get)
        thresh = curve[peak_b] / np.sqrt(2)
        window = [b for b, s in curve.items() if s >= thresh]
        return {"curve": curve, "peak_batch": peak_b, "peak": curve[peak_b],
                "window": (min(window), max(window)) if window else None}

    def plan(self, batch: int) -> dict:
        g, s = self.best_gamma(batch)
        return {"use_sd": s > 1.0, "gamma": g, "predicted_speedup": s}

    def update_alpha(self, alpha_observed: float, ema: float = 0.9):
        self.alpha = ema * self.alpha + (1 - ema) * alpha_observed

"""Prefetch-aware proposer (SP-MoE, arXiv:2510.10302; offload-hiding SD,
arXiv:2508.21706) — warm the target's expert weights during the draft phase.

MoESD's serving analysis says the remaining verify-phase bottleneck for a
sparse MoE target is expert-weight movement: at moderate batch sizes only
N(t) < E experts activate, so the verify forward streams a routing-dependent
subset of the FFN weights from HBM.  The propose phase is dead time for the
target — SP-MoE's observation is that the draft token stream *names* the
tokens the next verify pass will process, so a cheap probe of the target's
routers over those tokens predicts which experts verify will hit, and their
weights can be warmed while drafting is still running.

``PrefetchProposer`` wraps any registered drafter (default: the paper's
small-model drafter) and adds the cross-phase coupling:

  1. PROPOSE   — delegate to the inner proposer (identical drafts, identical
                 PRNG stream → greedy outputs match the wrapped drafter
                 exactly).
  2. PROBE     — record the round's speculated stream [last_token, drafts],
                 embed it with the target's table, and push it through every
                 MoE layer's router (fp32, (P, d, E) per period-slot).  The
                 top-M experts by probe votes per slot become a
                 ``models/moe.PrefetchPlan``.
  3. WARM      — the engine (core/spec_decode.SDEngine) dispatches
                 ``models/moe.warm_experts`` on the plan *between* the
                 propose and verify launches; the gather of the predicted
                 experts' weights executes ahead of verify on the device
                 queue, overlapping the (host-side) verify dispatch instead
                 of serializing with it.
  4. SCORE     — verify runs through ``Model.extend_with_prefetch``, which
                 counts hits (activated AND warmed) vs misses per round;
                 the engine aggregates them into ``SDStats`` /
                 ``WaveReport`` / ``session_stats()``.

The probe reads only the embedding table and router matrices — a (N, d) x
(d, E) matmul per MoE slot, orders of magnitude below a draft forward — so
it rides inside the jitted propose stage without moving the propose/verify
cost balance the paper's speedup model depends on.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.proposer import make_proposer, register_proposer
from repro.models.moe import PrefetchPlan


def router_probe(params_t: dict, cfg, tokens: jnp.ndarray, *,
                 top_m: int) -> PrefetchPlan:
    """Predict the experts a verify pass over ``tokens`` will activate.

    Parameters
    ----------
    params_t : dict
        Target model params (embedding table + per-slot router matrices).
    cfg : ModelConfig
        Target config — supplies ``moe_pattern``, ``num_experts``,
        ``num_experts_per_tok``, ``num_periods``.
    tokens : jnp.ndarray
        (B, T) speculated verify stream ([last_token, drafts]).
    top_m : int
        Static number of experts to warm per (slot, period) — the plan's
        gather shape.

    Returns
    -------
    PrefetchPlan
        Per-slot (P, E) predicted-hot masks + (P, M) warm ids.  The probe
        applies each router to the raw token
        *embeddings* (the lightweight stand-in for that layer's true hidden
        states — the same approximation benchmarks/prefetch_utility.py
        validates against a trained router); top-k routing per token, then
        top-M experts per period by vote count, mean router probability as
        the tie-break.
    """
    E = max(cfg.num_experts, 1)
    P = cfg.num_periods
    K = max(cfg.num_experts_per_tok, 1)
    x = params_t["embed"]["table"][tokens.reshape(-1)]          # (N, d)
    masks, ids = [], []
    for i, is_moe in enumerate(cfg.moe_pattern):
        if not is_moe:
            masks.append(jnp.zeros((P, E), bool))
            ids.append(jnp.zeros((P, 0), jnp.int32))
            continue
        router = params_t["layers"][i]["ffn"]["router"]          # (P, d, E)
        logits = jnp.einsum("nd,pde->pne", x.astype(jnp.float32), router)
        probs = jax.nn.softmax(logits, axis=-1)                  # (P, N, E)
        _, topk = jax.lax.top_k(probs, K)                        # (P, N, K)
        # scatter-add vote count — never materialize a (P, N, K, E) one-hot
        # on the propose hot path (the same rule that keeps (N, K, E)
        # one-hots out of decode/verify, see models/transformer.py)
        pidx = jnp.broadcast_to(jnp.arange(P)[:, None, None], topk.shape)
        votes = jnp.zeros((P, E), jnp.float32).at[pidx, topk].add(1.0)
        score = votes + jnp.mean(probs, axis=1)                  # tie-break
        _, top_ids = jax.lax.top_k(score, top_m)                 # (P, M)
        mask = jnp.zeros((P, E), bool).at[
            jnp.arange(P)[:, None], top_ids].set(True)
        masks.append(mask)
        ids.append(top_ids.astype(jnp.int32))
    return PrefetchPlan(masks=tuple(masks), expert_ids=tuple(ids))


class PrefetchProposer:
    """Wrap a drafter with draft-phase expert warming (module docstring).

    Drafting is fully delegated — same tokens, same q distributions, same
    PRNG consumption — so greedy outputs are token-identical to the wrapped
    proposer's.  The wrapper only adds the router probe to ``propose`` (the
    resulting ``PrefetchPlan`` rides in the round work-state) and exposes
    ``provides_prefetch`` so the engine runs warm + scored-verify stages.

    Under expert-parallel sharded serving the plan is mesh-agnostic (global
    expert ids); LOCALITY lives in the warm gather itself —
    ``models/moe.warm_experts(..., mesh=...)`` runs as a shard_map in which
    each shard touches only the predicted experts of ITS local slice, so
    warming never streams another shard's weights across the interconnect.
    """

    kind = "prefetch"
    provides_prefetch = True

    def __init__(self, target, draft, temperature: float = 0.0, *,
                 inner: str = "model", top_m: Optional[int] = None):
        self.target = target
        self.inner = make_proposer(inner, target, draft,
                                   temperature=temperature)
        cfg = target.cfg
        E, K = max(cfg.num_experts, 1), max(cfg.num_experts_per_tok, 1)
        # warm budget: 2K experts per period-slot by default — roughly the
        # N(t) regime where prediction beats "warm everything" (t small).
        # User-supplied budgets are clamped to [1, E]: top_k inside the
        # jitted probe would otherwise fail opaquely for top_m > E
        self.top_m = min(E, max(1, int(top_m))) if top_m is not None \
            else min(E, 2 * K)

    @property
    def needs_hidden(self) -> bool:
        return self.inner.needs_hidden

    def init_state(self, params, prompts, max_seq, *, lengths=None,
                   last_hidden=None):
        return {"inner": self.inner.init_state(
            params, prompts, max_seq, lengths=lengths,
            last_hidden=last_hidden)}

    def propose(self, params, state, last_token, gamma, key):
        drafts, q_dist, work = self.inner.propose(
            params, state["inner"], last_token, gamma, key)
        # this round's draft stream IS the upcoming verify stream: probe it
        stream = jnp.concatenate([last_token[:, None], drafts], axis=1)
        plan = router_probe(params["target"], self.target.cfg, stream,
                            top_m=self.top_m)
        return drafts, q_dist, {"inner": work, "plan": plan}

    def commit(self, params, state, *, base_len, n_accept, n_commit,
               verify_tokens, hidden):
        return {"inner": self.inner.commit(
            params, state["inner"], base_len=base_len, n_accept=n_accept,
            n_commit=n_commit, verify_tokens=verify_tokens, hidden=hidden)}

    def merge_state(self, old, new, mask):
        """Admission merge: fully delegated (the plan is round work-state,
        never part of the persistent between-rounds state)."""
        return {"inner": self.inner.merge_state(old["inner"], new["inner"],
                                                mask)}

    def scatter_state(self, old, new, rows, *, valid=None):
        """Sliced admission: fully delegated to the wrapped drafter."""
        return {"inner": self.inner.scatter_state(old["inner"], new["inner"],
                                                  rows, valid=valid)}

    def grow_state(self, state, new_max_seq):
        """Session growth: fully delegated to the wrapped drafter."""
        return {"inner": self.inner.grow_state(state["inner"], new_max_seq)}


register_proposer("prefetch", PrefetchProposer)

"""Unified Proposer API — the pluggable drafting seam of the SD engine.

The paper's claim is about *serving regimes*, not one drafting strategy:
speedup depends on batch size and target efficiency for ANY drafter whose
T_D/T_T is small.  So drafting is a protocol, and the engine
(core/spec_decode.SDEngine) is generic over it:

    proposer = make_proposer("model" | "eagle" | "none", target, draft)
    engine   = SDEngine(target, proposer, gamma=4)
    out, stats = engine.generate(params_t, params_d, prompts, max_new)

Protocol (all methods are pure and trace-safe; ``params`` is always the
dict ``{"target": params_t, "draft": params_p}``):

  * ``init_state(params, prompts, max_seq, *, lengths, last_hidden)``
    → opaque pytree ``state`` (draft cache, feature carry, ...) built once
    per generation after the target prefill.  ``last_hidden`` is the
    target's pre-head hidden state at the last prompt position, provided
    iff the proposer sets ``needs_hidden``.
  * ``propose(params, state, last_token, gamma, key)``
    → ``(drafts (B, g), q_dist (B, g, V), state)`` with g <= gamma.  The
    engine infers the actual speculation width from ``drafts``, so a
    degenerate proposer may return width 0 (the AR baseline).
  * ``commit(params, state, *, base_len, n_accept, n_commit,
    verify_tokens, hidden)`` → reconciled ``state`` after rejection
    sampling.  ``hidden`` is the target's (B, gamma+1, d) verify hidden
    states iff ``needs_hidden``.

Registry: ``register_proposer(name)`` + ``make_proposer(name, ...)`` map
strings to factories so serving configs / CLIs select drafters without
importing their modules ("eagle" is resolved lazily).  Future drafters —
prefetch-aware (SP-MoE, arXiv:2510.10302) or utility-driven
(arXiv:2506.20675) speculation — drop in behind the same three methods.
"""
from __future__ import annotations

import importlib
from typing import Any, Callable, Dict, Optional, Protocol, Tuple, runtime_checkable

import jax
import jax.numpy as jnp

from repro.core.rejection import probs_from_logits, sample_from


def stack_drafts(ds, qs, batch: int, vocab: int):
    """Stack per-step draft tokens/distributions into the (B, g) / (B, g, V)
    arrays `propose` returns, handling the zero-step (g=0) case."""
    drafts = (jnp.stack(ds, axis=1) if ds
              else jnp.zeros((batch, 0), jnp.int32))
    q_dist = (jnp.stack(qs, axis=1) if qs
              else jnp.zeros((batch, 0, vocab), jnp.float32))
    return drafts, q_dist


@runtime_checkable
class Proposer(Protocol):
    """Structural protocol every drafter implements (see module docstring).

    Class attributes: ``kind`` (the registry string) and ``needs_hidden``
    (True iff the engine should hand this proposer the target's pre-head
    hidden states).  An optional ``provides_prefetch = True`` marks a
    proposer whose ``propose`` work-state carries a ``"plan"`` entry (a
    ``models/moe.PrefetchPlan``) for draft-phase expert warming
    (core/prefetch.py).
    """

    kind: str
    needs_hidden: bool

    def init_state(self, params: dict, prompts: jnp.ndarray, max_seq: int, *,
                   lengths: Optional[jnp.ndarray] = None,
                   last_hidden: Optional[jnp.ndarray] = None) -> Any:
        """Build the proposer's opaque state once per generation.

        Parameters
        ----------
        params : dict
            ``{"target": params_t, "draft": params_p}``.
        prompts : jnp.ndarray
            (B, T) padded prompt tokens, already prefilled into the target.
        max_seq : int
            Static cache capacity for this generation.
        lengths : jnp.ndarray, optional
            (B,) true prompt lengths (``None`` means all rows are full).
        last_hidden : jnp.ndarray, optional
            (B, d) target pre-head hidden state at each sequence's last
            prompt position — provided iff ``needs_hidden``.

        Returns
        -------
        Any
            Opaque pytree threaded through ``propose``/``commit`` (draft KV
            cache, feature carry, ...).
        """
        ...

    def propose(self, params: dict, state: Any, last_token: jnp.ndarray,
                gamma: int, key: jax.Array
                ) -> Tuple[jnp.ndarray, jnp.ndarray, Any]:
        """Draft up to ``gamma`` tokens per sequence (pure / trace-safe).

        Parameters
        ----------
        params : dict
            ``{"target": params_t, "draft": params_p}``.
        state : Any
            Pytree returned by ``init_state`` or the previous ``commit``.
        last_token : jnp.ndarray
            (B,) the most recently committed token per sequence.
        gamma : int
            Requested speculation width (static per compiled round).
        key : jax.Array
            PRNG key for draft sampling.

        Returns
        -------
        drafts : jnp.ndarray
            (B, g) drafted tokens with g <= gamma (g = 0 is the AR
            baseline).
        q_dist : jnp.ndarray
            (B, g, V) draft distributions for rejection sampling.
        work_state : Any
            Round work-state handed to ``commit`` (may carry extras a
            pre-commit snapshot or a prefetch plan).
        """
        ...

    def commit(self, params: dict, state: Any, *, base_len: jnp.ndarray,
               n_accept: jnp.ndarray, n_commit: jnp.ndarray,
               verify_tokens: jnp.ndarray,
               hidden: Optional[jnp.ndarray]) -> Any:
        """Reconcile draft state to the accepted prefix after rejection.

        Parameters
        ----------
        params : dict
            ``{"target": params_t, "draft": params_p}``.
        state : Any
            The work-state ``propose`` returned this round.
        base_len : jnp.ndarray
            (B,) sequence lengths before this round's commit.
        n_accept : jnp.ndarray
            (B,) accepted draft tokens per sequence.
        n_commit : jnp.ndarray
            (B,) committed tokens (``n_accept + 1``, incl. bonus/residual).
        verify_tokens : jnp.ndarray
            (B, g+1) the tokens the target verified this round.
        hidden : jnp.ndarray, optional
            (B, g+1, d) target verify hidden states iff ``needs_hidden``.

        Returns
        -------
        Any
            The reconciled state for the next round's ``propose``.
        """
        ...

    def merge_state(self, old: Any, new: Any, mask: jnp.ndarray) -> Any:
        """Row-wise select between two same-shape proposer states.

        The continuous-batching admission hook (SDEngine.admit): ``new``
        is a freshly ``init_state``-built state for the full bucket; rows
        where ``mask`` (B,) is True take it, all other rows keep ``old``
        untouched.  Must be pure/trace-safe like the other methods.
        """
        ...

    def scatter_state(self, old: Any, new: Any, rows: jnp.ndarray, *,
                      valid: Optional[jnp.ndarray] = None) -> Any:
        """Row-scatter a COMPACT proposer state into the live one.

        The row-sliced admission hook (SDEngine.admit_rows): ``new`` is an
        ``init_state``-built state for only the R admitted rows; entry i
        goes to pool row ``rows[i]``.  ``valid`` (R,) bool drops padding
        lanes (row-count bucketing).  Must be pure/trace-safe — ``rows``
        and ``valid`` are data, so which rows get admitted never retraces.
        """
        ...

    def grow_state(self, state: Any, new_max_seq: int) -> Any:
        """Pad the state's sequence capacity to ``new_max_seq``.

        Called (host-side, between rounds) when a paged target session
        grows its logical capacity: the proposer's dense caches must be
        able to address the same positions.  States without a sequence
        axis return themselves unchanged.
        """
        ...


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------

_REGISTRY: Dict[str, Callable[..., "Proposer"]] = {}
# kinds whose factory lives in a module we only import on first use, so the
# serving engine never needs conditional imports in its hot path
_LAZY_KINDS = {"eagle": "repro.core.eagle",
               "prefetch": "repro.core.prefetch"}


def register_proposer(name: str, factory: Optional[Callable] = None):
    """Register ``factory(target, draft, temperature) -> Proposer``.

    Usable directly or as a decorator::

        @register_proposer("mykind")
        def _make(target, draft, temperature=0.0): ...
    """
    def _register(f):
        _REGISTRY[name] = f
        return f

    return _register(factory) if factory is not None else _register


def registered_proposers() -> Tuple[str, ...]:
    """All selectable kinds (registered + lazily importable)."""
    return tuple(sorted(set(_REGISTRY) | set(_LAZY_KINDS)))


def make_proposer(kind: str, target, draft=None, *,
                  temperature: float = 0.0, **opts) -> "Proposer":
    """Build a registered proposer by name.

    Parameters
    ----------
    kind : str
        A registered (or lazily importable) proposer kind.
    target : Model
        The target model the proposer drafts for.
    draft : object, optional
        Kind-specific drafter: a draft ``Model`` for "model", an
        ``EagleHead`` (or None to build one) for "eagle", ignored for
        "none".
    temperature : float
        Draft sampling temperature.
    **opts
        Extra kind-specific factory kwargs (e.g. ``top_m`` / ``inner`` for
        "prefetch").

    Returns
    -------
    Proposer
    """
    if kind not in _REGISTRY and kind in _LAZY_KINDS:
        importlib.import_module(_LAZY_KINDS[kind])   # module self-registers
    if kind not in _REGISTRY:
        raise KeyError(
            f"unknown proposer {kind!r}; registered: {registered_proposers()}")
    return _REGISTRY[kind](target, draft, temperature=temperature, **opts)


# ---------------------------------------------------------------------------
# "model": a standalone small draft model (the paper's main configuration)
# ---------------------------------------------------------------------------

class ModelProposer:
    """Drafts with an autoregressive small model (paper Sec. 3.1).

    State: ``{"cache": draft_cache}`` between rounds; within a round the
    returned work-state additionally carries the pre-round snapshot that
    recurrent drafts need to re-commit from (their propose loop advances
    state destructively).
    """

    kind = "model"
    needs_hidden = False

    def __init__(self, target, draft, temperature: float = 0.0):
        if draft is None:
            raise ValueError("ModelProposer requires a draft Model")
        self.draft = draft
        self.temperature = temperature

    def init_state(self, params, prompts, max_seq, *, lengths=None,
                   last_hidden=None):
        B = prompts.shape[0]
        cache = self.draft.init_cache(B, max_seq)
        _, cache = self.draft.prefill(params["draft"], prompts, cache,
                                      lengths=lengths)
        return {"cache": cache}

    def propose(self, params, state, last_token, gamma, key):
        """gamma single-token draft forwards + one extra that writes the
        last draft's KV so the cache is complete on full acceptance."""
        params_d = params["draft"]
        recurrent = self.draft.cfg.is_recurrent
        c = state["cache"]
        snapshot = c if recurrent else None          # pre-round state
        token = last_token
        qs, ds = [], []
        for _ in range(gamma):
            if recurrent:
                logits, pend = self.draft.extend(params_d, token[:, None], c,
                                                 collect=True)
                c = self.draft.commit(pend, jnp.ones_like(c["lengths"]),
                                      collected=True)
            else:
                logits, c = self.draft.extend(params_d, token[:, None], c)
                c = dict(c, lengths=c["lengths"] + 1)
            key, k_s = jax.random.split(key)
            q = probs_from_logits(logits[:, 0], self.temperature)
            token = sample_from(q, k_s, self.temperature)
            qs.append(q)
            ds.append(token)
        if recurrent:
            _, pend = self.draft.extend(params_d, token[:, None], c,
                                        collect=True)
            c = self.draft.commit(pend, jnp.ones_like(c["lengths"]),
                                  collected=True)
        else:
            _, c = self.draft.extend(params_d, token[:, None], c)
        drafts, q_dist = stack_drafts(ds, qs, last_token.shape[0],
                                      self.draft.cfg.vocab_size)
        return drafts, q_dist, {"cache": c, "snapshot": snapshot}

    def commit(self, params, state, *, base_len, n_accept, n_commit,
               verify_tokens, hidden):
        if self.draft.cfg.is_recurrent:
            # re-run from the pre-round snapshot and gather accepted state
            _, pend = self.draft.extend(params["draft"], verify_tokens,
                                        dict(state["snapshot"]), collect=True)
            cache = self.draft.commit(pend, n_commit, collected=True)
        else:
            # attention cache: rejected-suffix KV left stale (position-masked)
            cache = dict(state["cache"], lengths=base_len + n_commit)
        return {"cache": cache}

    def merge_state(self, old, new, mask):
        """Admission merge: the draft cache follows the model-cache layout,
        so row selection is the same primitive the target uses."""
        from repro.models.model import merge_cache_rows
        return {"cache": merge_cache_rows(old["cache"], new["cache"], mask)}

    def scatter_state(self, old, new, rows, *, valid=None):
        """Sliced admission: row-scatter the compact draft cache."""
        from repro.models.model import scatter_cache_rows
        return {"cache": scatter_cache_rows(old["cache"], new["cache"],
                                            rows, valid=valid)}

    def grow_state(self, state, new_max_seq):
        """Pad the draft cache's sequence axis on session growth."""
        from repro.models.model import grow_cache_seq
        return {"cache": grow_cache_seq(state["cache"], self.draft.cfg,
                                        new_max_seq)}


# ---------------------------------------------------------------------------
# "none": the degenerate drafter — SD round with zero drafts IS plain AR
# ---------------------------------------------------------------------------

class NoneProposer:
    """Zero-width proposer: the round degenerates to one target forward of
    ``last_token`` and a sample from its distribution — exactly the AR
    baseline (T_AR in the paper's speedup definition), sharing the engine
    loop, cache discipline, and SDStats with real SD."""

    kind = "none"
    needs_hidden = False

    def __init__(self, target, draft=None, temperature: float = 0.0):
        self.vocab_size = target.cfg.vocab_size

    def init_state(self, params, prompts, max_seq, *, lengths=None,
                   last_hidden=None):
        return None

    def propose(self, params, state, last_token, gamma, key):
        B = last_token.shape[0]
        return (jnp.zeros((B, 0), jnp.int32),
                jnp.zeros((B, 0, self.vocab_size), jnp.float32), state)

    def commit(self, params, state, *, base_len, n_accept, n_commit,
               verify_tokens, hidden):
        return state

    def merge_state(self, old, new, mask):
        """Stateless drafter: nothing to merge on admission."""
        return old

    def scatter_state(self, old, new, rows, *, valid=None):
        """Stateless drafter: nothing to scatter on admission."""
        return old

    def grow_state(self, state, new_max_seq):
        """Stateless drafter: nothing to grow."""
        return state


register_proposer("model", ModelProposer)
register_proposer("none", NoneProposer)

"""Batched rejection sampling for speculative decoding (Leviathan et al.).

Losslessness: for every sequence the emitted tokens are distributed exactly
as samples from the target model.  Accept draft token d_i with probability
min(1, p_i(d_i)/q_i(d_i)); on the first rejection sample from the residual
norm(max(p_i − q_i, 0)); if all gamma drafts are accepted, emit a bonus
token from p_gamma.  Greedy decoding is the temperature→0 limit: p and q
become one-hot, acceptance degenerates to argmax equality, and SD output is
token-for-token identical to autoregressive greedy decoding (tested).

Everything is vectorized over the batch: ``n_accept`` is per-sequence.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp


def probs_from_logits(logits: jnp.ndarray, temperature: float) -> jnp.ndarray:
    """Softmax with temperature; temperature <= 0 → one-hot argmax (greedy)."""
    if temperature <= 0.0:
        return jax.nn.one_hot(
            jnp.argmax(logits, axis=-1), logits.shape[-1], dtype=jnp.float32)
    return jax.nn.softmax(logits.astype(jnp.float32) / temperature, axis=-1)


def sample_from(probs: jnp.ndarray, key: jax.Array, temperature: float) -> jnp.ndarray:
    if temperature <= 0.0:
        return jnp.argmax(probs, axis=-1).astype(jnp.int32)
    return jax.random.categorical(key, jnp.log(jnp.maximum(probs, 1e-30))).astype(jnp.int32)


def rejection_sample(
    p: jnp.ndarray,            # (B, gamma+1, V) target distributions
    q: jnp.ndarray,            # (B, gamma,   V) draft distributions
    drafts: jnp.ndarray,       # (B, gamma)      proposed tokens
    key: jax.Array,
    temperature: float,
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Returns (n_accept (B,), next_token (B,), accept_mask (B, gamma)).

    Committed tokens per sequence = drafts[:n_accept] + [next_token], i.e.
    n_accept + 1 new tokens."""
    B, gamma = drafts.shape
    k_u, k_res = jax.random.split(key)

    p_d = jnp.take_along_axis(p[:, :gamma], drafts[..., None], axis=-1)[..., 0]
    q_d = jnp.take_along_axis(q, drafts[..., None], axis=-1)[..., 0]
    ratio = p_d / jnp.maximum(q_d, 1e-30)
    if temperature <= 0.0:
        accept = p_d > 0.5                              # one-hot match
    else:
        u = jax.random.uniform(k_u, (B, gamma))
        accept = u < ratio
    # n_accept = number of leading accepts
    prefix = jnp.cumprod(accept.astype(jnp.int32), axis=-1)
    n_accept = jnp.sum(prefix, axis=-1)                 # (B,)

    # distribution for the extra token: residual at the rejection position,
    # or the bonus distribution p_gamma when everything was accepted
    p_at = jnp.take_along_axis(p, n_accept[:, None, None], axis=1)[:, 0]   # (B,V)
    q_pad = jnp.concatenate([q, jnp.zeros_like(q[:, :1])], axis=1)
    q_at = jnp.take_along_axis(q_pad, n_accept[:, None, None], axis=1)[:, 0]
    rejected_somewhere = n_accept < gamma
    residual = jnp.maximum(p_at - q_at, 0.0)
    residual_sum = jnp.sum(residual, axis=-1, keepdims=True)
    # fall back to p when the residual vanishes (q == p pointwise)
    residual = jnp.where(residual_sum > 1e-12, residual / jnp.maximum(residual_sum, 1e-30), p_at)
    extra_dist = jnp.where(rejected_somewhere[:, None], residual, p_at)
    next_token = sample_from(extra_dist, k_res, temperature)
    return n_accept.astype(jnp.int32), next_token, accept


def sigma_from_alpha(alpha, gamma: int):
    """Eq. 5: expected generated / max possible per round."""
    import numpy as np
    alpha = np.asarray(alpha, dtype=np.float64)
    num = np.where(
        np.abs(1 - alpha) < 1e-9, gamma + 1.0, (1 - alpha ** (gamma + 1)) / (1 - alpha))
    return num / (gamma + 1)

"""Batched speculative-decoding engine (the paper's serving mechanism).

One SD round (Sec. 3.1), generic over any registered Proposer
(core/proposer.py):

  1. PROPOSE  — ``proposer.propose`` emits g <= gamma draft tokens per
     sequence with their draft distributions (a small model, an EAGLE
     head, or nothing at all for the AR baseline).
  2. VERIFY   — the target model processes [last_token, d_1..d_g]
     (g+1 tokens) in ONE forward, yielding g+1 next-token distributions.
  3. REJECT   — batched rejection sampling (rejection.py) accepts a per-
     sequence prefix of the drafts and emits one extra token (residual
     sample or bonus).  n_commit = n_accept + 1 ∈ [1, g+1].
  4. COMMIT   — target cache commit + ``proposer.commit`` reconcile both
     sides to the accepted prefix.

The AR baseline is the degenerate g=0 instance of the SAME loop (the
"none" proposer): the round collapses to one target forward of
``last_token`` plus a sample — so SD and AR timings come from identical
machinery, which is what the paper's speedup definition x = T_AR/T_SD
requires.

Session/round API (the continuous-batching seam):
  * ``start(params_t, params_p, prompts, max_seq=...)`` → ``SessionState``
    (target prefill + cache alloc + proposer state; the prefill-sampled
    token is the first generated token and lives in ``state.last_token``).
  * ``round(state, gamma=..., key=..., active=...)`` →
    ``(SessionState, RoundResult)`` — ONE propose/verify/reject/commit
    round.  ``active`` is a (B,) bool mask: inactive rows commit zero
    tokens (``lengths`` frozen, ``last_token`` unchanged), so a caller can
    retire finished sequences without changing the compiled shape.
  * ``admit(state, prompts, lengths, admit_mask)`` → ``SessionState`` —
    masked prefill of NEW requests into retired rows of a live session:
    the full bucket is prefilled into fresh caches and merged row-wise
    (models/model.merge_cache_rows + Proposer.merge_state), so occupancy
    changes within a batch bucket cause zero round retraces.
  * ``generate(...)`` is kept as the thin start+round loop for parity.

The caller owning the loop is what enables continuous batching
(serving/scheduler.py): slots retire on completion, new requests prefill
into freed rows between rounds, and {use_sd, gamma} can be re-planned on
the LIVE batch size every round — the paper's N(t)-dependence operated,
not just measured.

Cache discipline:
  * target/draft attention KV: fresh tokens are written at offsets
    ``lengths``; a rejected suffix is simply left stale (masked by
    position) and ``lengths += n_commit``.  Retired rows' stale entries
    are likewise harmless: every extend writes its positions before
    attending, so a re-admitted row overwrites exactly the entries that
    become visible.
  * recurrent states (SSM/xLSTM targets or drafts): verify collects
    per-step states and ``commit`` gathers the state of the last accepted
    token (models/model.py).  Recurrent drafts re-run the verify pass from
    a pre-round snapshot (γ+1 cheap draft tokens) since their propose loop
    advances state destructively.  A retired (inactive) row's recurrent
    state is garbage until re-admission rebuilds it.

Compile caching: each SDEngine instance is a long-lived *decoding
session*.  Per gamma it builds the fused round once (``_round_cache``)
and jax.jit then caches per batch/sequence shape; ``trace_log`` records
every (gamma, batch) retrace and ``admit_trace_log`` every admission
retrace, so serving code (and tests) can assert reuse.  The engine never
mixes tokens across sequences — per-sequence lengths make the batch
ragged, exactly like continuous batching in vLLM.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field, replace
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.proposer import Proposer, make_proposer
from repro.core.rejection import probs_from_logits, rejection_sample, sample_from
from repro.models.model import Model
from repro.models.moe import warm_experts as moe_warm_experts


@dataclass
class SDStats:
    rounds: int = 0
    generated: int = 0                      # total committed tokens (all seqs)
    max_possible: int = 0                   # rounds * (gamma+1) * B_live
    accept_events: int = 0                  # accepted draft tokens
    draft_events: int = 0                   # proposed draft tokens
    round_time: float = 0.0                 # wall time across all rounds
    propose_time: float = 0.0               # per-phase (timed=True only)
    verify_time: float = 0.0
    reject_time: float = 0.0
    # expert-prefetch accounting (prefetch-aware proposers only): summed
    # over rounds, layers and periods — hits = activated AND warmed
    prefetch_hits: int = 0
    prefetch_actual: int = 0                # experts the verify passes hit
    prefetch_predicted: int = 0             # experts the plans warmed
    warm_time: float = 0.0                  # warm DISPATCH time (timed only)

    @property
    def sigma(self) -> float:               # paper's σ (Eq. 5 empirical)
        return self.generated / max(self.max_possible, 1)

    @property
    def alpha(self) -> float:               # empirical acceptance rate
        return self.accept_events / max(self.draft_events, 1)

    @property
    def prefetch_misses(self) -> int:       # activated but NOT warmed
        return self.prefetch_actual - self.prefetch_hits

    @property
    def prefetch_hit_rate(self) -> float:   # P(activated expert was warm)
        return self.prefetch_hits / max(self.prefetch_actual, 1)

    def absorb_round(self, res: "RoundResult", live: int) -> None:
        """Fold one RoundResult into the aggregate.

        ``live`` is the number of rows the round was REQUESTED to advance
        (the active count; masked-out lanes commit nothing) — sigma/alpha
        are accounted against it, and against the requested gamma, so a
        proposer drafting fewer than gamma tokens honestly scores
        sigma < 1.  Shared by wave ``generate`` and the continuous
        scheduler so the two schedulers can never diverge in bookkeeping.
        """
        self.rounds += 1
        self.round_time += res.round_time
        if res.phase_times:
            self.propose_time += res.phase_times.get("propose", 0.0)
            self.verify_time += res.phase_times.get("verify", 0.0)
            self.reject_time += res.phase_times.get("reject", 0.0)
            self.warm_time += res.phase_times.get("warm", 0.0)
        self.generated += int(res.n_commit.sum())
        self.max_possible += (res.gamma + 1) * live
        self.accept_events += int(res.n_accept.sum())
        self.draft_events += res.width * live
        if res.pf is not None:
            self.prefetch_hits += res.pf["hits"]
            self.prefetch_actual += res.pf["actual"]
            self.prefetch_predicted += res.pf["predicted"]


@dataclass
class SessionState:
    """One live decoding batch: everything a round reads and writes.

    ``params`` is the ``{"target": ..., "draft": ...}`` dict,
    ``t_cache``/``p_state`` the target cache and proposer state,
    ``last_token`` (B,) the most recently committed token per row (after
    ``start``/``admit`` it holds the prefill-sampled FIRST generated token
    of each fresh row — the caller records it as output).  ``max_seq`` is
    the static cache capacity the state was allocated with.
    """
    params: dict
    t_cache: dict
    p_state: Any
    last_token: jnp.ndarray
    max_seq: int

    @property
    def batch(self) -> int:
        return int(self.last_token.shape[0])


@dataclass
class RoundResult:
    """Host-side outcome of one SD round.

    ``committed`` is (B, width+1); per row only the first ``n_commit[b]``
    entries are real (0 for rows that were inactive this round).
    ``n_accept`` is per-row accepted draft tokens; ``width`` the drafted
    tokens per sequence (g <= gamma); ``pf`` the prefetch hit/actual/
    predicted counts (prefetch-aware proposers, else None);
    ``phase_times`` the propose/verify/reject/warm wall times (timed
    rounds only, else None).
    """
    committed: np.ndarray
    n_commit: np.ndarray
    n_accept: np.ndarray
    width: int
    gamma: int
    pf: Optional[Dict[str, int]]
    round_time: float
    phase_times: Optional[Dict[str, float]] = None


class SDEngine:
    """One persistent decoding session: a target model + one Proposer.

    The propose/verify/reject/commit round is generic over the proposer;
    compiled rounds are cached per gamma (and, via jit, per shape), so a
    serving engine can hold one SDEngine per proposer kind and change
    gamma between waves — or per ROUND, via the ``start``/``round``/
    ``admit`` session API — without rebuilding anything.
    """

    def __init__(self, target: Model, proposer: Proposer, *,
                 gamma: int = 4, temperature: float = 0.0):
        self.target = target
        self.proposer = proposer
        self.gamma = gamma
        self.temperature = temperature
        self._round_cache: Dict[int, Callable] = {}      # gamma -> jitted round
        self._stage_cache: Dict[int, Tuple] = {}         # gamma -> stage jits
        self._admit_cache: Dict[Tuple[int, int, int], Callable] = {}
        self.trace_log: List[Tuple[int, int]] = []       # (gamma, B) per trace
        self.admit_trace_log: List[Tuple[int, int]] = []  # (T_prompt, B)
        # session-lifetime expert-prefetch aggregates (prefetch proposers):
        # summed across every generate() call this session served
        self.prefetch_totals: Dict[str, int] = {
            "hits": 0, "actual": 0, "predicted": 0, "rounds": 0}

    def compiled_gammas(self) -> List[int]:
        """Gammas with a built round (fused or staged) in this session."""
        return sorted(set(self._round_cache) | set(self._stage_cache))

    def accumulate_prefetch_totals(self, stats: SDStats) -> None:
        """Fold one generation/stream's prefetch counts into the
        session-lifetime totals (no-op for non-prefetch proposers)."""
        if getattr(self.proposer, "provides_prefetch", False):
            self.prefetch_totals["hits"] += stats.prefetch_hits
            self.prefetch_totals["actual"] += stats.prefetch_actual
            self.prefetch_totals["predicted"] += stats.prefetch_predicted
            self.prefetch_totals["rounds"] += stats.rounds

    # ----------------------------------------------------------- round pieces
    def _stages(self, gamma: int):
        """(propose, verify, finalize) pure stage functions for one gamma.

        Prefetch-aware proposers (``provides_prefetch``) get a verify stage
        that additionally takes the round's ``PrefetchPlan`` and returns the
        hit/miss counts scored by ``Model.extend_with_prefetch``; all other
        proposers' verify returns ``pf = None``.
        """
        target, proposer, temp = self.target, self.proposer, self.temperature
        pf_aware = getattr(proposer, "provides_prefetch", False)

        def propose(params, p_state, last_token, k_prop):
            return proposer.propose(params, p_state, last_token, gamma, k_prop)

        if pf_aware:
            def verify(params_t, t_cache, last_token, drafts, plan):
                verify_tokens = jnp.concatenate([last_token[:, None], drafts],
                                                1)
                logits, hidden, pend, pf = target.extend_with_prefetch(
                    params_t, verify_tokens, t_cache, plan, collect=True)
                if not proposer.needs_hidden:
                    hidden = None
                return probs_from_logits(logits, temp), hidden, pend, pf
        else:
            def verify(params_t, t_cache, last_token, drafts):
                verify_tokens = jnp.concatenate([last_token[:, None], drafts],
                                                1)
                if proposer.needs_hidden:
                    logits, hidden, pend = target.extend_with_hidden(
                        params_t, verify_tokens, t_cache, collect=True)
                else:
                    logits, pend = target.extend(params_t, verify_tokens,
                                                 t_cache, collect=True)
                    hidden = None
                return probs_from_logits(logits, temp), hidden, pend, None

        def finalize(params, pend, p_state, base_len, p_dist, q_dist, drafts,
                     hidden, last_token, active, k_rej):
            B, g = drafts.shape
            n_accept, next_token, _ = rejection_sample(
                p_dist, q_dist, drafts, k_rej, temp)
            # inactive (retired) rows commit nothing: lengths stay frozen
            # and last_token is carried over, so the row is shape-stable
            # padding until admit() refills it
            n_accept = jnp.where(active, n_accept, 0)
            n_commit = jnp.where(active, n_accept + 1, 0)
            t_cache = target.commit(pend, n_commit, collected=True)
            verify_tokens = jnp.concatenate([last_token[:, None], drafts], 1)
            p_state = proposer.commit(
                params, p_state, base_len=base_len, n_accept=n_accept,
                n_commit=n_commit, verify_tokens=verify_tokens, hidden=hidden)
            # committed new tokens this round: [d_1..d_n, next] (n_commit each)
            slot = jnp.arange(g + 1)[None, :]
            drafts_pad = jnp.concatenate(
                [drafts, jnp.zeros((B, 1), drafts.dtype)], 1)
            committed = jnp.where(slot < n_accept[:, None], drafts_pad,
                                  next_token[:, None])          # (B, g+1)
            new_last = jnp.where(active, next_token, last_token)
            return (t_cache, p_state, new_last, committed, n_commit, n_accept)

        return propose, verify, finalize

    def _round_fn(self, gamma: int) -> Callable:
        """Fused jitted round for one gamma (built once per session).

        Prefetch-aware proposers never take this path — inside one
        monolithic XLA computation the warm gather would be dead code, so
        rounds always run them staged (see ``_staged_jits``).
        """
        if getattr(self.proposer, "provides_prefetch", False):
            raise RuntimeError(
                "prefetch-aware proposers decode through staged rounds; "
                "the fused round cannot express the warm dispatch")
        fn = self._round_cache.get(gamma)
        if fn is None:
            propose, verify, finalize = self._stages(gamma)

            def round_fn(params, t_cache, p_state, last_token, active,
                         k_prop, k_rej):
                # trace-time side effect: lets callers assert compile reuse
                self.trace_log.append((gamma, int(last_token.shape[0])))
                base_len = t_cache["lengths"]
                drafts, q_dist, p_work = propose(params, p_state, last_token,
                                                 k_prop)
                p_dist, hidden, pend, pf = verify(params["target"], t_cache,
                                                  last_token, drafts)
                out = finalize(params, pend, p_work, base_len, p_dist,
                               q_dist, drafts, hidden, last_token, active,
                               k_rej)
                return out + (pf,)

            fn = jax.jit(round_fn)
            self._round_cache[gamma] = fn
        return fn

    def _staged_jits(self, gamma: int):
        """Separately-jitted (propose, verify, finalize, warm) stages.

        Used for ``timed=True`` (syncing between stages gives real per-phase
        wall times) and for prefetch-aware proposers even untimed: the round
        must be split so the host can dispatch the expert-warm gather
        *between* the propose and verify launches — that interleaving is the
        overlap (a fused round gives XLA one monolithic computation and the
        warm gather would be dead code).  ``warm`` is ``None`` for ordinary
        proposers.
        """
        fns = self._stage_cache.get(gamma)
        if fns is None:
            propose, verify, finalize = self._stages(gamma)

            def propose_logged(params, p_state, last_token, k_prop):
                self.trace_log.append((gamma, int(last_token.shape[0])))
                return propose(params, p_state, last_token, k_prop)

            warm = None
            if getattr(self.proposer, "provides_prefetch", False):
                target_cfg = self.target.cfg

                def warm(params_t, plan):
                    return moe_warm_experts(params_t["layers"], target_cfg,
                                            plan)
                warm = jax.jit(warm)

            fns = (jax.jit(propose_logged), jax.jit(verify),
                   jax.jit(finalize), warm)
            self._stage_cache[gamma] = fns
        return fns

    # --------------------------------------------------------------- prefill
    def prefill(self, params_t, params_p, prompts: jnp.ndarray, max_seq: int,
                *, lengths=None, key=None,
                prefill_kwargs: Optional[dict] = None):
        """Prefill target + proposer; returns (t_cache, p_state, last_token)."""
        B = prompts.shape[0]
        kw = prefill_kwargs or {}
        params = {"target": params_t, "draft": params_p}
        t_cache = self.target.init_cache(B, max_seq)
        if self.proposer.needs_hidden:
            last_t, last_hidden, t_cache = self.target.prefill_with_hidden(
                params_t, prompts, t_cache, lengths=lengths, **kw)
        else:
            last_t, t_cache = self.target.prefill(params_t, prompts, t_cache,
                                                  lengths=lengths, **kw)
            last_hidden = None
        p_state = self.proposer.init_state(params, prompts, max_seq,
                                           lengths=lengths,
                                           last_hidden=last_hidden)
        key = key if key is not None else jax.random.PRNGKey(0)
        p = probs_from_logits(last_t, self.temperature)
        last_token = sample_from(p, key, self.temperature)
        return t_cache, p_state, last_token

    # --------------------------------------------------------------- session
    def start(self, params_t, params_p, prompts: jnp.ndarray, *,
              max_seq: int, lengths=None, key=None,
              prefill_kwargs: Optional[dict] = None) -> SessionState:
        """Open a decoding batch: prefill + cache alloc → ``SessionState``.

        The prefill-sampled token is each row's FIRST generated token; the
        caller reads it from ``state.last_token``.  ``max_seq`` is the
        static cache capacity for the whole batch lifetime (continuous
        callers must size it for the longest admitted request).
        """
        t_cache, p_state, last_token = self.prefill(
            params_t, params_p, prompts, max_seq, lengths=lengths, key=key,
            prefill_kwargs=prefill_kwargs)
        return SessionState(params={"target": params_t, "draft": params_p},
                            t_cache=t_cache, p_state=p_state,
                            last_token=last_token, max_seq=max_seq)

    def round(self, state: SessionState, *, gamma: Optional[int] = None,
              key: Optional[jax.Array] = None, active=None,
              timed: bool = False) -> Tuple[SessionState, RoundResult]:
        """Run ONE propose/verify/reject/commit round on a live session.

        Parameters
        ----------
        state : SessionState
            From ``start``/``admit``/the previous ``round``.
        gamma : int, optional
            Speculation width for THIS round (default: the session's).
            gamma=0 is the in-session AR fallback: zero drafts, one target
            forward — the SD→AR handoff needs no session switch.
        key : jax.Array, optional
            Round PRNG key (split internally into propose/reject keys).
        active : array-like, optional
            (B,) bool — rows to advance.  Inactive rows commit 0 tokens and
            keep ``lengths``/``last_token`` frozen; the mask is data, so
            occupancy changes never retrace.  Default: all rows active.
        timed : bool
            Run staged with per-phase syncs (fills ``phase_times``).

        Returns
        -------
        (SessionState, RoundResult)
            The advanced state and the round's host-side outcome.
        """
        gamma = self.gamma if gamma is None else gamma
        if key is None:
            # greedy rounds are key-independent; at temperature>0 a fixed
            # default would silently reuse IDENTICAL propose/reject noise
            # every round of the caller's loop — fail loudly instead
            if self.temperature > 0.0:
                raise ValueError(
                    "round() needs a fresh per-round key at temperature>0 "
                    "(split one from a root key each round)")
            key = jax.random.PRNGKey(0)
        k_prop, k_rej = jax.random.split(key)
        B = state.batch
        active = (jnp.ones((B,), bool) if active is None
                  else jnp.asarray(active, bool))
        params = state.params
        pf_aware = getattr(self.proposer, "provides_prefetch", False)
        staged = timed or pf_aware
        phases: Dict[str, float] = {}
        t_round = time.perf_counter()
        if staged:
            j_prop, j_verify, j_fin, j_warm = self._staged_jits(gamma)
            t_cache, p_state, last_token = (state.t_cache, state.p_state,
                                            state.last_token)
            base_len = t_cache["lengths"]
            t0 = time.perf_counter()
            drafts, q_dist, p_work = j_prop(params, p_state, last_token,
                                            k_prop)
            if timed:
                jax.block_until_ready(drafts)
                phases["propose"] = time.perf_counter() - t0
            if j_warm is not None:
                # async dispatch, never blocked on: the gather of the
                # predicted experts' weights runs ahead of verify on the
                # device queue while the host assembles the verify call
                t0 = time.perf_counter()
                j_warm(params["target"], p_work["plan"])
                if timed:
                    # timed-only, like the other phase stats (and like
                    # them the first round includes trace+compile)
                    phases["warm"] = time.perf_counter() - t0
            t0 = time.perf_counter()
            if pf_aware:
                p_dist, hidden, pend, pf = j_verify(
                    params["target"], t_cache, last_token, drafts,
                    p_work["plan"])
            else:
                p_dist, hidden, pend, pf = j_verify(
                    params["target"], t_cache, last_token, drafts)
            if timed:
                jax.block_until_ready(p_dist)
                phases["verify"] = time.perf_counter() - t0
            t0 = time.perf_counter()
            (t_cache, p_state, last_token, committed, n_commit, n_acc) = \
                j_fin(params, pend, p_work, base_len, p_dist, q_dist,
                      drafts, hidden, last_token, active, k_rej)
            if timed:
                jax.block_until_ready(committed)
                phases["reject"] = time.perf_counter() - t0
        else:
            fn = self._round_fn(gamma)
            (t_cache, p_state, last_token, committed, n_commit, n_acc,
             pf) = fn(params, state.t_cache, state.p_state, state.last_token,
                      active, k_prop, k_rej)
        committed = np.asarray(committed)            # device sync
        n_commit_np = np.asarray(n_commit)
        round_time = time.perf_counter() - t_round
        pf_counts = None
        if pf is not None:
            pf_counts = {k: int(np.asarray(pf[k]))
                         for k in ("hits", "actual", "predicted")}
        new_state = replace(state, t_cache=t_cache, p_state=p_state,
                            last_token=last_token)
        result = RoundResult(
            committed=committed, n_commit=n_commit_np,
            n_accept=np.asarray(n_acc), width=committed.shape[1] - 1,
            gamma=gamma, pf=pf_counts, round_time=round_time,
            phase_times=phases if timed else None)
        return new_state, result

    # -------------------------------------------------------------- admission
    def _admit_fn(self, B: int, Tp: int, max_seq: int) -> Callable:
        fn = self._admit_cache.get((B, Tp, max_seq))
        if fn is None:
            target, proposer, temp = self.target, self.proposer, \
                self.temperature

            def admit_fn(params, t_cache, p_state, last_token, prompts,
                         lengths, mask, key):
                self.admit_trace_log.append((Tp, B))
                fresh_t = target.init_cache(B, max_seq)
                if proposer.needs_hidden:
                    last_l, last_h, fresh_t = target.prefill_with_hidden(
                        params["target"], prompts, fresh_t, lengths=lengths)
                else:
                    last_l, fresh_t = target.prefill(
                        params["target"], prompts, fresh_t, lengths=lengths)
                    last_h = None
                fresh_p = proposer.init_state(params, prompts, max_seq,
                                              lengths=lengths,
                                              last_hidden=last_h)
                first = sample_from(probs_from_logits(last_l, temp), key,
                                    temp)
                from repro.models.model import merge_cache_rows
                merged_t = merge_cache_rows(t_cache, fresh_t, mask)
                merged_p = proposer.merge_state(p_state, fresh_p, mask)
                merged_last = jnp.where(mask, first, last_token)
                return merged_t, merged_p, merged_last

            fn = jax.jit(admit_fn)
            self._admit_cache[(B, Tp, max_seq)] = fn
        return fn

    def admit(self, state: SessionState, prompts: jnp.ndarray, lengths,
              admit_mask, *, key: Optional[jax.Array] = None
              ) -> SessionState:
        """Masked prefill of new requests into retired rows of a session.

        The full (B, T_prompt) bucket is prefilled into FRESH target/
        proposer caches and the result is merged row-wise with the live
        state: rows where ``admit_mask`` is True take the fresh prefill,
        all other rows keep their in-flight cache untouched.  The mask is
        data, so WHICH rows get admitted never retraces — only a new
        (batch, prompt-bucket) shape does (logged in ``admit_trace_log``).

        Parameters
        ----------
        state : SessionState
            The live session (from ``start``/``round``).
        prompts : jnp.ndarray
            (B, T_prompt) tokens.  Admitted rows carry the new prompts;
            non-admitted rows are don't-care fillers (their prefill is
            computed and discarded — the price of a static shape).
        lengths : array-like
            (B,) true prompt lengths (>= 1 everywhere, fillers included).
        admit_mask : array-like
            (B,) bool — True rows are (re)initialised.
        key : jax.Array, optional
            PRNG key for the admitted rows' first sampled token (read it
            from ``state.last_token`` after this call).

        Returns
        -------
        SessionState
            The merged state; admitted rows are prefilled to their prompt
            and ready for the next ``round``.
        """
        B, Tp = prompts.shape
        if B != state.batch:
            raise ValueError(f"admit batch {B} != session batch "
                             f"{state.batch}")
        key = key if key is not None else jax.random.PRNGKey(0)
        mask = jnp.asarray(admit_mask, bool)
        fn = self._admit_fn(B, Tp, state.max_seq)
        t_cache, p_state, last_token = fn(
            state.params, state.t_cache, state.p_state, state.last_token,
            jnp.asarray(prompts), jnp.asarray(lengths, jnp.int32), mask, key)
        return replace(state, t_cache=t_cache, p_state=p_state,
                       last_token=last_token)

    # -------------------------------------------------------------- generate
    def generate(
        self,
        params_t,
        params_p,
        prompts: jnp.ndarray,               # (B, T_prompt)
        max_new_tokens: int,
        *,
        gamma: Optional[int] = None,
        max_seq: Optional[int] = None,
        lengths=None,
        key: Optional[jax.Array] = None,
        prefill_kwargs: Optional[dict] = None,
        timed: bool = False,
    ) -> Tuple[np.ndarray, SDStats]:
        """Run SD rounds until every sequence has >= max_new_tokens.

        A thin wave-mode wrapper over the session API: one ``start`` then
        ``round`` in a loop with every row active — continuous callers
        drive the same two methods with masks and mid-stream ``admit``.
        """
        B, Tp = prompts.shape
        gamma = self.gamma if gamma is None else gamma
        key = key if key is not None else jax.random.PRNGKey(0)
        if max_seq is None:
            max_seq = Tp + max_new_tokens + gamma + 2
        key, k_pre = jax.random.split(key)
        state = self.start(params_t, params_p, prompts, max_seq=max_seq,
                           lengths=lengths, key=k_pre,
                           prefill_kwargs=prefill_kwargs)

        out = np.zeros((B, max_new_tokens + gamma + 1), np.int32)
        n_out = np.zeros((B,), np.int32)
        # the first sampled token (from prefill) counts as generated
        out[:, 0] = np.asarray(state.last_token)
        n_out += 1

        stats = SDStats()
        while int(n_out.min()) < max_new_tokens:
            key, k_round = jax.random.split(key)
            state, res = self.round(state, gamma=gamma, key=k_round,
                                    timed=timed)
            for b in range(B):
                n = int(res.n_commit[b])
                w = min(n, out.shape[1] - n_out[b])
                out[b, n_out[b]: n_out[b] + w] = res.committed[b, :w]
                n_out[b] += w
            stats.absorb_round(res, B)
        self.accumulate_prefetch_totals(stats)
        return out[:, :max_new_tokens], stats


# ---------------------------------------------------------------------------
# backwards-compatible entry points (pre-Proposer API)
# ---------------------------------------------------------------------------

class SpecDecoder(SDEngine):
    """Legacy shim: target + draft *model* pair == SDEngine("model").

    Prefer ``SDEngine(target, make_proposer("model", target, draft))``.
    """

    def __init__(self, target: Model, draft: Model, gamma: int = 4,
                 temperature: float = 0.0):
        super().__init__(
            target,
            make_proposer("model", target, draft, temperature=temperature),
            gamma=gamma, temperature=temperature)
        self.draft = draft


def _ar_session(model: Model, temperature: float) -> SDEngine:
    """AR generation reuses one persistent "none" session per
    (model, temperature) so repeated generate_ar calls don't re-jit the
    decode round.  Sessions hang off the model instance itself (not a
    global registry): they share its lifetime, so dropping the model
    releases the compiled rounds too."""
    per_model = getattr(model, "_ar_sessions", None)
    if per_model is None:
        per_model = model._ar_sessions = {}
    eng = per_model.get(temperature)
    if eng is None:
        eng = SDEngine(model,
                       make_proposer("none", model, temperature=temperature),
                       gamma=0, temperature=temperature)
        per_model[temperature] = eng
    return eng


def generate_ar(model: Model, params, prompts: jnp.ndarray,
                max_new_tokens: int, *, temperature: float = 0.0,
                lengths=None, key=None,
                prefill_kwargs: Optional[dict] = None) -> np.ndarray:
    """Plain autoregressive baseline (T_AR in the paper's speedup
    definition) — the gamma=0 / "none"-proposer path of SDEngine."""
    out, _ = _ar_session(model, temperature).generate(
        params, None, prompts, max_new_tokens, lengths=lengths, key=key,
        prefill_kwargs=prefill_kwargs)
    return out

"""Batched speculative-decoding engine (the paper's serving mechanism).

One SD round (Sec. 3.1), generic over any registered Proposer
(core/proposer.py):

  1. PROPOSE  — ``proposer.propose`` emits g <= gamma draft tokens per
     sequence with their draft distributions (a small model, an EAGLE
     head, or nothing at all for the AR baseline).
  2. VERIFY   — the target model processes [last_token, d_1..d_g]
     (g+1 tokens) in ONE forward, yielding g+1 next-token distributions.
  3. REJECT   — batched rejection sampling (rejection.py) accepts a per-
     sequence prefix of the drafts and emits one extra token (residual
     sample or bonus).  n_commit = n_accept + 1 ∈ [1, g+1].
  4. COMMIT   — target cache commit + ``proposer.commit`` reconcile both
     sides to the accepted prefix.

The AR baseline is the degenerate g=0 instance of the SAME loop (the
"none" proposer): the round collapses to one target forward of
``last_token`` plus a sample — so SD and AR timings come from identical
machinery, which is what the paper's speedup definition x = T_AR/T_SD
requires.

Session/round API (the continuous-batching seam):
  * ``start(params_t, params_p, prompts, max_seq=...)`` → ``SessionState``
    (target prefill + cache alloc + proposer state; the prefill-sampled
    token is the first generated token and lives in ``state.last_token``).
  * ``round(state, gamma=..., key=..., active=...)`` →
    ``(SessionState, RoundResult)`` — ONE propose/verify/reject/commit
    round.  ``active`` is a (B,) bool mask: inactive rows commit zero
    tokens (``lengths`` frozen, ``last_token`` unchanged), so a caller can
    retire finished sequences without changing the compiled shape.
  * ``admit(state, prompts, lengths, admit_mask)`` → ``SessionState`` —
    masked prefill of NEW requests into retired rows of a live session:
    the full bucket is prefilled into fresh caches and merged row-wise
    (models/model.merge_cache_rows + Proposer.merge_state), so occupancy
    changes within a batch bucket cause zero round retraces.  Its cost is
    ∝ the POOL (non-admitted rows are prefilled and discarded).
  * ``admit_rows(state, prompts, lengths, rows)`` → ``SessionState`` —
    the row-SLICED admission path: only the R admitted rows are prefilled,
    at their own (R, prompt-bucket) shape, and the fresh KV/proposer state
    is row-scattered into the live session (models/model.
    scatter_cache_rows + Proposer.scatter_state).  Admission cost scales
    with what was admitted, not the pool.
  * ``begin_admit_chunked``/``admit_chunk`` — the sliced path split into
    fixed-size prompt chunks so a long-prompt admission interleaves with
    decode rounds instead of stalling the round it lands in.
  * ``grow_session(state, new_max_seq, ...)`` — pad a paged session's
    logical capacity (and the proposer's dense caches) so late-arriving
    long requests admit instead of crashing the stream.
  * ``generate(...)`` is kept as the thin start+round loop for parity.

The caller owning the loop is what enables continuous batching
(serving/scheduler.py): slots retire on completion, new requests prefill
into freed rows between rounds, and {use_sd, gamma} can be re-planned on
the LIVE batch size every round — the paper's N(t)-dependence operated,
not just measured.

Cache discipline:
  * target/draft attention KV: fresh tokens are written at offsets
    ``lengths``; a rejected suffix is simply left stale (masked by
    position) and ``lengths += n_commit``.  Retired rows' stale entries
    are likewise harmless: every extend writes its positions before
    attending, so a re-admitted row overwrites exactly the entries that
    become visible.
  * recurrent states (SSM/xLSTM targets or drafts): verify collects
    per-step states and ``commit`` gathers the state of the last accepted
    token (models/model.py).  Recurrent drafts re-run the verify pass from
    a pre-round snapshot (γ+1 cheap draft tokens) since their propose loop
    advances state destructively.  A retired (inactive) row's recurrent
    state is garbage until re-admission rebuilds it.

Compile caching: each SDEngine instance is a long-lived *decoding
session*.  Per gamma it builds the fused round once (``_round_cache``)
and jax.jit then caches per batch/sequence shape; ``trace_log`` records
every (gamma, batch) retrace and ``admit_trace_log`` every admission
retrace, so serving code (and tests) can assert reuse.  The engine never
mixes tokens across sequences — per-sequence lengths make the batch
ragged, exactly like continuous batching in vLLM.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field, replace
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec

from repro.core.proposer import Proposer, make_proposer
from repro.core.rejection import probs_from_logits, rejection_sample, sample_from
from repro.distributed.constraints import resolve_mesh
from repro.distributed.sharding import shard_cache
from repro.models.model import Model
from repro.models.moe import warm_experts as moe_warm_experts
from repro.serving.faults import logits_finite


def _device_cast(x, np_dtype):
    """Host-boundary dtype cast: convert in numpy FIRST so the transfer is
    a pure device_put.  ``jnp.asarray(host_array, jnp.int32)`` instead
    compiles a tiny convert_element_type program per shape — visible as a
    spurious XLA compile under ``repro.analysis.compile_guard`` at every
    new admission bucket.  Device arrays pass through untouched (a numpy
    round-trip would force a sync)."""
    if isinstance(x, jax.Array):
        return x
    # lint: allow[T104] tracers are jax.Array and return early above; only host values reach here
    return jnp.asarray(np.asarray(x, np_dtype))


@dataclass
class SDStats:
    rounds: int = 0
    generated: int = 0                      # total committed tokens (all seqs)
    max_possible: int = 0                   # rounds * (gamma+1) * B_live
    accept_events: int = 0                  # accepted draft tokens
    draft_events: int = 0                   # proposed draft tokens
    round_time: float = 0.0                 # wall time across all rounds
    propose_time: float = 0.0               # per-phase (timed=True only)
    verify_time: float = 0.0
    reject_time: float = 0.0
    # expert-prefetch accounting (prefetch-aware proposers only): summed
    # over rounds, layers and periods — hits = activated AND warmed
    prefetch_hits: int = 0
    prefetch_actual: int = 0                # experts the verify passes hit
    prefetch_predicted: int = 0             # experts the plans warmed
    warm_time: float = 0.0                  # warm DISPATCH time (timed only)

    @property
    def sigma(self) -> float:               # paper's σ (Eq. 5 empirical)
        return self.generated / max(self.max_possible, 1)

    @property
    def alpha(self) -> float:               # empirical acceptance rate
        return self.accept_events / max(self.draft_events, 1)

    @property
    def prefetch_misses(self) -> int:       # activated but NOT warmed
        return self.prefetch_actual - self.prefetch_hits

    @property
    def prefetch_hit_rate(self) -> float:   # P(activated expert was warm)
        return self.prefetch_hits / max(self.prefetch_actual, 1)

    def absorb_round(self, res: "RoundResult", live: int) -> None:
        """Fold one RoundResult into the aggregate.

        ``live`` is the number of rows the round was REQUESTED to advance
        (the active count; masked-out lanes commit nothing) — sigma/alpha
        are accounted against it, and against the requested gamma, so a
        proposer drafting fewer than gamma tokens honestly scores
        sigma < 1.  Shared by wave ``generate`` and the continuous
        scheduler so the two schedulers can never diverge in bookkeeping.
        """
        self.rounds += 1
        self.round_time += res.round_time
        if res.phase_times:
            self.propose_time += res.phase_times.get("propose", 0.0)
            self.verify_time += res.phase_times.get("verify", 0.0)
            self.reject_time += res.phase_times.get("reject", 0.0)
            self.warm_time += res.phase_times.get("warm", 0.0)
        self.generated += int(res.n_commit.sum())
        self.max_possible += (res.gamma + 1) * live
        self.accept_events += int(res.n_accept.sum())
        self.draft_events += res.width * live
        if res.pf is not None:
            self.prefetch_hits += res.pf["hits"]
            self.prefetch_actual += res.pf["actual"]
            self.prefetch_predicted += res.pf["predicted"]


@dataclass
class SessionState:
    """One live decoding batch: everything a round reads and writes.

    ``params`` is the ``{"target": ..., "draft": ...}`` dict,
    ``t_cache``/``p_state`` the target cache and proposer state,
    ``last_token`` (B,) the most recently committed token per row (after
    ``start``/``admit`` it holds the prefill-sampled FIRST generated token
    of each fresh row — the caller records it as output).  ``max_seq`` is
    the static cache capacity the state was allocated with.
    """
    params: dict
    t_cache: dict
    p_state: Any
    last_token: jnp.ndarray
    max_seq: int

    @property
    def batch(self) -> int:
        return int(self.last_token.shape[0])


@dataclass
class RoundResult:
    """Host-side outcome of one SD round.

    ``committed`` is (B, width+1); per row only the first ``n_commit[b]``
    entries are real (0 for rows that were inactive this round).
    ``n_accept`` is per-row accepted draft tokens; ``width`` the drafted
    tokens per sequence (g <= gamma); ``pf`` the prefetch hit/actual/
    predicted counts (prefetch-aware proposers, else None);
    ``phase_times`` the propose/verify/reject/warm wall times (timed
    rounds only, else None).  ``finite`` (B,) is the numerical
    sentinel's verdict on this round's raw verify logits
    (serving/faults.logits_finite): a False row committed NOTHING this
    round (quarantined inside ``finalize``) and should be retired by the
    caller with ``finish_reason="numerical_fault"``.
    """
    committed: np.ndarray
    n_commit: np.ndarray
    n_accept: np.ndarray
    width: int
    gamma: int
    pf: Optional[Dict[str, int]]
    round_time: float
    phase_times: Optional[Dict[str, float]] = None
    finite: Optional[np.ndarray] = None


@dataclass
class PendingAdmission:
    """A chunked sliced admission in flight (SDEngine.begin_admit_chunked).

    ``t_cache`` is the compact DENSE target cache under construction
    (None until the first chunk ran), ``consumed`` the prompt tokens
    prefilled so far.  The admitted rows join the live session only when
    ``admit_chunk`` returns ``None`` for the pending half.
    """
    prompts: np.ndarray                  # (R, Tp) host-side
    lengths: np.ndarray                  # (R,) true prompt lengths (equal)
    rows: np.ndarray                     # (R,) destination pool rows
    chunk: int
    key: jax.Array
    t_cache: Optional[dict] = None
    consumed: int = 0

    @property
    def remaining(self) -> int:
        return int(self.lengths[0]) - self.consumed


class SDEngine:
    """One persistent decoding session: a target model + one Proposer.

    The propose/verify/reject/commit round is generic over the proposer;
    compiled rounds are cached per gamma (and, via jit, per shape), so a
    serving engine can hold one SDEngine per proposer kind and change
    gamma between waves — or per ROUND, via the ``start``/``round``/
    ``admit`` session API — without rebuilding anything.
    """

    def __init__(self, target: Model, proposer: Proposer, *,
                 gamma: int = 4, temperature: float = 0.0,
                 mesh=None, mesh_layout: Optional[str] = None):
        self.target = target
        self.proposer = proposer
        self.gamma = gamma
        self.temperature = temperature
        # mesh defaults to the target model's (one mesh per session);
        # host-boundary inputs are then committed REPLICATED so every jit
        # call sees one placement signature (docs/distributed.md), and
        # session caches open device_put per distributed.sharding.cache_spec
        if mesh is None and getattr(target, "mesh", None) is not None:
            mesh = target.mesh
            mesh_layout = (mesh_layout if mesh_layout is not None
                           else target.mesh_layout)
        if mesh is not None:
            mesh, mesh_layout = resolve_mesh(mesh, mesh_layout)
        self.mesh = mesh
        self.mesh_layout = mesh_layout
        self._replicated = (NamedSharding(mesh, PartitionSpec())
                            if mesh is not None else None)
        self._greedy_key = None      # cached PRNGKey(0) for greedy rounds
        self._round_cache: Dict[int, Callable] = {}      # gamma -> jitted round
        self._stage_cache: Dict[int, Tuple] = {}         # gamma -> stage jits
        self._admit_cache: Dict[Tuple[int, int, int], Callable] = {}
        self._sliced_cache: Dict[Tuple[int, int, int], Callable] = {}
        self._chunk_cache: Dict[Tuple, Callable] = {}
        self._start_cache: Dict[Tuple, Callable] = {}    # session-open prefill
        self._prefix_cache: Dict[Tuple[int, int, int, int], Callable] = {}
        self.trace_log: List[Tuple[int, int]] = []       # (gamma, B) per trace
        # (T_prompt, rows): full-path entries carry rows == pool, sliced-
        # path entries rows == the admitted-row bucket — the jit-signature
        # contract tests assert on
        self.admit_trace_log: List[Tuple[int, int]] = []
        self.chunk_trace_log: List[Tuple[str, int, int]] = []  # (stage, C, R)
        # (T_tail, rows) per prefix-admission trace — the shared-prefix
        # counterpart of admit_trace_log
        self.prefix_trace_log: List[Tuple[int, int]] = []
        self.growth_log: List[Tuple[int, Optional[int]]] = []
        # session-lifetime expert-prefetch aggregates (prefetch proposers):
        # summed across every generate() call this session served
        self.prefetch_totals: Dict[str, int] = {
            "hits": 0, "actual": 0, "predicted": 0, "rounds": 0}

    def _host(self, x, np_dtype):
        """Host-boundary cast with mesh-aware placement: under a mesh every
        host value is committed REPLICATED, so repeated calls (new streams,
        new admission waves) present identical sharding signatures to the
        jit caches — an uncommitted single-device array next to sharded
        params would otherwise key (and retrace) on whatever placement the
        first call happened to see."""
        if self._replicated is not None and not isinstance(x, jax.Array):
            return jax.device_put(np.asarray(x, np_dtype), self._replicated)  # lint: allow[T104] tracers are jax.Array and take the _device_cast branch; only host values reach here
        return _device_cast(x, np_dtype)

    def _constrain_cache(self, t_cache):
        """In-graph placement pin for the session cache under a mesh.

        Every jitted program that RETURNS the session cache constrains it
        to the distributed.sharding.cache_spec placement the session
        opened with.  Without the pin XLA propagates its own output
        shardings (e.g. paged pools re-split over the kv-head/head dims),
        so a round compiled after an admission sees differently-sharded
        cache inputs than one compiled after a round — two live
        specializations of every program for one logical stream, which is
        exactly what the runtime ``sharding_guard`` flags.
        """
        if self.mesh is None:
            return t_cache
        from repro.distributed.sharding import shard_cache
        return jax.lax.with_sharding_constraint(
            t_cache, shard_cache(t_cache, self.mesh))

    def compiled_gammas(self) -> List[int]:
        """Gammas with a built round (fused or staged) in this session."""
        return sorted(set(self._round_cache) | set(self._stage_cache))

    def accumulate_prefetch_totals(self, stats: SDStats) -> None:
        """Fold one generation/stream's prefetch counts into the
        session-lifetime totals (no-op for non-prefetch proposers)."""
        if getattr(self.proposer, "provides_prefetch", False):
            self.prefetch_totals["hits"] += stats.prefetch_hits
            self.prefetch_totals["actual"] += stats.prefetch_actual
            self.prefetch_totals["predicted"] += stats.prefetch_predicted
            self.prefetch_totals["rounds"] += stats.rounds

    # ----------------------------------------------------------- round pieces
    def _stages(self, gamma: int):
        """(propose, verify, finalize) pure stage functions for one gamma.

        Prefetch-aware proposers (``provides_prefetch``) get a verify stage
        that additionally takes the round's ``PrefetchPlan`` and returns the
        hit/miss counts scored by ``Model.extend_with_prefetch``; all other
        proposers' verify returns ``pf = None``.
        """
        target, proposer, temp = self.target, self.proposer, self.temperature
        pf_aware = getattr(proposer, "provides_prefetch", False)

        def propose(params, p_state, last_token, k_prop):
            return proposer.propose(params, p_state, last_token, gamma, k_prop)

        # the numerical sentinel reads RAW verify logits: the greedy
        # probs_from_logits branch is one_hot(argmax), and argmax of an
        # all-NaN row returns a valid index — probabilities hide faults
        if pf_aware:
            def verify(params_t, t_cache, last_token, drafts, plan):
                verify_tokens = jnp.concatenate([last_token[:, None], drafts],
                                                1)
                logits, hidden, pend, pf = target.extend_with_prefetch(
                    params_t, verify_tokens, t_cache, plan, collect=True)
                if not proposer.needs_hidden:
                    hidden = None
                return (probs_from_logits(logits, temp), hidden, pend, pf,
                        logits_finite(logits))
        else:
            def verify(params_t, t_cache, last_token, drafts):
                verify_tokens = jnp.concatenate([last_token[:, None], drafts],
                                                1)
                if proposer.needs_hidden:
                    logits, hidden, pend = target.extend_with_hidden(
                        params_t, verify_tokens, t_cache, collect=True)
                else:
                    logits, pend = target.extend(params_t, verify_tokens,
                                                 t_cache, collect=True)
                    hidden = None
                return (probs_from_logits(logits, temp), hidden, pend, None,
                        logits_finite(logits))

        def finalize(params, pend, p_state, base_len, p_dist, q_dist, drafts,
                     hidden, last_token, active, finite, k_rej):
            B, g = drafts.shape
            n_accept, next_token, _ = rejection_sample(
                p_dist, q_dist, drafts, k_rej, temp)
            # inactive (retired) rows commit nothing: lengths stay frozen
            # and last_token is carried over, so the row is shape-stable
            # padding until admit() refills it.  Non-finite rows are
            # quarantined the same way — zero commits keep the fault out
            # of the caches and out of co-batched rows' bookkeeping; the
            # scheduler reads RoundResult.finite and retires them.
            ok = jnp.logical_and(active, finite)
            n_accept = jnp.where(ok, n_accept, 0)
            n_commit = jnp.where(ok, n_accept + 1, 0)
            t_cache = target.commit(pend, n_commit, collected=True)
            verify_tokens = jnp.concatenate([last_token[:, None], drafts], 1)
            p_state = proposer.commit(
                params, p_state, base_len=base_len, n_accept=n_accept,
                n_commit=n_commit, verify_tokens=verify_tokens, hidden=hidden)
            # committed new tokens this round: [d_1..d_n, next] (n_commit each)
            slot = jnp.arange(g + 1)[None, :]
            drafts_pad = jnp.concatenate(
                [drafts, jnp.zeros((B, 1), drafts.dtype)], 1)
            committed = jnp.where(slot < n_accept[:, None], drafts_pad,
                                  next_token[:, None])          # (B, g+1)
            new_last = jnp.where(ok, next_token, last_token)
            return (t_cache, p_state, new_last, committed, n_commit, n_accept)

        return propose, verify, finalize

    def _round_fn(self, gamma: int) -> Callable:
        """Fused jitted round for one gamma (built once per session).

        Prefetch-aware proposers never take this path — inside one
        monolithic XLA computation the warm gather would be dead code, so
        rounds always run them staged (see ``_staged_jits``).
        """
        if getattr(self.proposer, "provides_prefetch", False):
            raise RuntimeError(
                "prefetch-aware proposers decode through staged rounds; "
                "the fused round cannot express the warm dispatch")
        fn = self._round_cache.get(gamma)
        if fn is None:
            propose, verify, finalize = self._stages(gamma)

            def round_fn(params, t_cache, p_state, last_token, active,
                         k_prop, k_rej):
                # trace-time side effect: lets callers assert compile reuse
                self.trace_log.append((gamma, int(last_token.shape[0])))  # lint: allow[T106] intentional trace-time counter; tier-1 tests assert on it
                base_len = t_cache["lengths"]
                drafts, q_dist, p_work = propose(params, p_state, last_token,
                                                 k_prop)
                p_dist, hidden, pend, pf, finite = verify(
                    params["target"], t_cache, last_token, drafts)
                out = finalize(params, pend, p_work, base_len, p_dist,
                               q_dist, drafts, hidden, last_token, active,
                               finite, k_rej)
                out = (self._constrain_cache(out[0]),) + out[1:]
                return out + (finite, pf)

            fn = jax.jit(round_fn)
            self._round_cache[gamma] = fn
        return fn

    def _staged_jits(self, gamma: int):
        """Separately-jitted (propose, verify, finalize, warm) stages.

        Used for ``timed=True`` (syncing between stages gives real per-phase
        wall times) and for prefetch-aware proposers even untimed: the round
        must be split so the host can dispatch the expert-warm gather
        *between* the propose and verify launches — that interleaving is the
        overlap (a fused round gives XLA one monolithic computation and the
        warm gather would be dead code).  ``warm`` is ``None`` for ordinary
        proposers.
        """
        fns = self._stage_cache.get(gamma)
        if fns is None:
            propose, verify, finalize = self._stages(gamma)

            def propose_logged(params, p_state, last_token, k_prop):
                self.trace_log.append((gamma, int(last_token.shape[0])))  # lint: allow[T106] intentional trace-time counter; tier-1 tests assert on it
                return propose(params, p_state, last_token, k_prop)

            warm = None
            if getattr(self.proposer, "provides_prefetch", False):
                target_cfg = self.target.cfg
                warm_mesh = self.mesh

                def warm(params_t, plan):
                    # mesh threaded → each shard gathers only ITS expert
                    # slice (models/moe.warm_experts shard_map path)
                    return moe_warm_experts(params_t["layers"], target_cfg,
                                            plan, mesh=warm_mesh)
                warm = jax.jit(warm)

            def finalize_pinned(*a):
                out = finalize(*a)
                return (self._constrain_cache(out[0]),) + out[1:]

            fns = (jax.jit(propose_logged), jax.jit(verify),
                   jax.jit(finalize_pinned), warm)
            self._stage_cache[gamma] = fns
        return fns

    # --------------------------------------------------------------- prefill
    def prefill(self, params_t, params_p, prompts: jnp.ndarray, max_seq: int,
                *, lengths=None, key=None,
                prefill_kwargs: Optional[dict] = None,
                cache_opts: Optional[dict] = None, page_table=None):
        """Prefill target + proposer; returns (t_cache, p_state, last_token).

        ``cache_opts`` forwards to ``Model.init_cache`` (e.g.
        ``{"paged": True, "page_size": 64, "pool_pages": N}``);
        ``page_table`` pre-assigns the paged cache's block table (a
        ``PageAllocator``'s table) so the prefill writes land in the
        admitted rows' pages.  Proposer caches stay dense either way.

        The common path (no ``prefill_kwargs``) runs through a jitted
        session-open program cached per ``(max_seq, cache_opts)`` — jax
        then caches per shape, so re-opening a session for a new stream
        of a warm shape compiles NOTHING (eager execution instead paid a
        full prefill-scan recompile per stream; the retrace guard in
        tests/test_retrace_guard.py pins this).  Exotic prefill kwargs
        (encoder embeds, mrope positions, ...) fall back to the eager
        path rather than guessing their static/traced split."""
        params = {"target": params_t, "draft": params_p}
        key = key if key is not None else jax.random.PRNGKey(0)
        if not prefill_kwargs:
            fn = self._start_fn(max_seq, cache_opts)
            return fn(params, self._host(prompts, np.int32),
                      None if lengths is None
                      else self._host(lengths, np.int32),
                      None if page_table is None
                      else self._host(page_table, np.int32), key)
        t_cache, p_state, last_l = self._fresh_prefill(
            params, prompts, lengths, max_seq, cache_opts=cache_opts,
            page_table=page_table, prefill_kwargs=prefill_kwargs)
        p = probs_from_logits(last_l, self.temperature)
        last_token = sample_from(p, key, self.temperature)
        return t_cache, p_state, last_token

    def _start_fn(self, max_seq: int, cache_opts: Optional[dict]) -> Callable:
        opts_key = (None if not cache_opts
                    else tuple(sorted(cache_opts.items())))
        fn = self._start_cache.get((max_seq, opts_key))
        if fn is None:
            opts = dict(cache_opts) if cache_opts else None

            def start_fn(params, prompts, lengths, page_table, key):
                t_cache, p_state, last_l = self._fresh_prefill(
                    params, prompts, lengths, max_seq, cache_opts=opts,
                    page_table=page_table)
                p = probs_from_logits(last_l, self.temperature)
                return (self._constrain_cache(t_cache), p_state,
                        sample_from(p, key, self.temperature))

            fn = jax.jit(start_fn)
            self._start_cache[(max_seq, opts_key)] = fn
        return fn

    # --------------------------------------------------------------- session
    def start(self, params_t, params_p, prompts: jnp.ndarray, *,
              max_seq: int, lengths=None, key=None,
              prefill_kwargs: Optional[dict] = None,
              cache_opts: Optional[dict] = None,
              page_table=None) -> SessionState:
        """Open a decoding batch: prefill + cache alloc → ``SessionState``.

        The prefill-sampled token is each row's FIRST generated token; the
        caller reads it from ``state.last_token``.  ``max_seq`` is the
        static cache capacity for the whole batch lifetime — unless the
        session is PAGED (``cache_opts={"paged": True, ...}`` +
        ``page_table``), where it is only the initial logical capacity and
        ``grow_session`` raises it later without resizing any row.
        """
        t_cache, p_state, last_token = self.prefill(
            params_t, params_p, prompts, max_seq, lengths=lengths, key=key,
            prefill_kwargs=prefill_kwargs, cache_opts=cache_opts,
            page_table=page_table)
        if self.mesh is not None:
            # place the session cache per distributed.sharding.cache_spec
            # ONCE at open (batch over data axes, KV heads / page pools
            # over "model"); rounds then carry the placement forward
            t_cache = jax.device_put(t_cache,
                                     shard_cache(t_cache, self.mesh))
        return SessionState(params={"target": params_t, "draft": params_p},
                            t_cache=t_cache, p_state=p_state,
                            last_token=last_token, max_seq=max_seq)

    def round(self, state: SessionState, *, gamma: Optional[int] = None,
              key: Optional[jax.Array] = None, active=None,
              timed: bool = False) -> Tuple[SessionState, RoundResult]:
        """Run ONE propose/verify/reject/commit round on a live session.

        Parameters
        ----------
        state : SessionState
            From ``start``/``admit``/the previous ``round``.
        gamma : int, optional
            Speculation width for THIS round (default: the session's).
            gamma=0 is the in-session AR fallback: zero drafts, one target
            forward — the SD→AR handoff needs no session switch.
        key : jax.Array, optional
            Round PRNG key (split internally into propose/reject keys).
        active : array-like, optional
            (B,) bool — rows to advance.  Inactive rows commit 0 tokens and
            keep ``lengths``/``last_token`` frozen; the mask is data, so
            occupancy changes never retrace.  Default: all rows active.
        timed : bool
            Run staged with per-phase syncs (fills ``phase_times``).

        Returns
        -------
        (SessionState, RoundResult)
            The advanced state and the round's host-side outcome.
        """
        gamma = self.gamma if gamma is None else gamma
        if key is None:
            # greedy rounds are key-independent; at temperature>0 a fixed
            # default would silently reuse IDENTICAL propose/reject noise
            # every round of the caller's loop — fail loudly instead
            if self.temperature > 0.0:
                raise ValueError(
                    "round() needs a fresh per-round key at temperature>0 "
                    "(split one from a root key each round)")
            # built once: a fresh PRNGKey here would be one implicit
            # host-to-device transfer per round (transfer_guard counts it)
            if self._greedy_key is None:
                self._greedy_key = jax.random.PRNGKey(0)
            key = self._greedy_key
        k_prop, k_rej = jax.random.split(key)
        B = state.batch
        active = self._host(np.ones((B,), bool) if active is None
                            else active, bool)
        params = state.params
        pf_aware = getattr(self.proposer, "provides_prefetch", False)
        staged = timed or pf_aware
        phases: Dict[str, float] = {}
        t_round = time.perf_counter()
        if staged:
            j_prop, j_verify, j_fin, j_warm = self._staged_jits(gamma)
            t_cache, p_state, last_token = (state.t_cache, state.p_state,
                                            state.last_token)
            base_len = t_cache["lengths"]
            t0 = time.perf_counter()
            drafts, q_dist, p_work = j_prop(params, p_state, last_token,
                                            k_prop)
            if timed:
                jax.block_until_ready(drafts)
                phases["propose"] = time.perf_counter() - t0
            if j_warm is not None:
                # async dispatch, never blocked on: the gather of the
                # predicted experts' weights runs ahead of verify on the
                # device queue while the host assembles the verify call
                t0 = time.perf_counter()
                j_warm(params["target"], p_work["plan"])
                if timed:
                    # timed-only, like the other phase stats (and like
                    # them the first round includes trace+compile)
                    phases["warm"] = time.perf_counter() - t0
            t0 = time.perf_counter()
            if pf_aware:
                p_dist, hidden, pend, pf, finite = j_verify(
                    params["target"], t_cache, last_token, drafts,
                    p_work["plan"])
            else:
                p_dist, hidden, pend, pf, finite = j_verify(
                    params["target"], t_cache, last_token, drafts)
            if timed:
                jax.block_until_ready(p_dist)
                phases["verify"] = time.perf_counter() - t0
            t0 = time.perf_counter()
            (t_cache, p_state, last_token, committed, n_commit, n_acc) = \
                j_fin(params, pend, p_work, base_len, p_dist, q_dist,
                      drafts, hidden, last_token, active, finite, k_rej)
            if timed:
                jax.block_until_ready(committed)
                phases["reject"] = time.perf_counter() - t0
        else:
            fn = self._round_fn(gamma)
            (t_cache, p_state, last_token, committed, n_commit, n_acc,
             finite, pf) = fn(params, state.t_cache, state.p_state,
                              state.last_token, active, k_prop, k_rej)
        committed = np.asarray(committed)            # device sync
        n_commit_np = np.asarray(n_commit)
        round_time = time.perf_counter() - t_round
        pf_counts = None
        if pf is not None:
            pf_counts = {k: int(np.asarray(pf[k]))
                         for k in ("hits", "actual", "predicted")}
        new_state = replace(state, t_cache=t_cache, p_state=p_state,
                            last_token=last_token)
        result = RoundResult(
            committed=committed, n_commit=n_commit_np,
            n_accept=np.asarray(n_acc), width=committed.shape[1] - 1,
            gamma=gamma, pf=pf_counts, round_time=round_time,
            phase_times=phases if timed else None,
            finite=np.asarray(finite))
        return new_state, result

    # -------------------------------------------------------------- admission
    def _admit_fn(self, B: int, Tp: int, max_seq: int) -> Callable:
        fn = self._admit_cache.get((B, Tp, max_seq))
        if fn is None:
            target, proposer, temp = self.target, self.proposer, \
                self.temperature

            def admit_fn(params, t_cache, p_state, last_token, prompts,
                         lengths, mask, key):
                self.admit_trace_log.append((Tp, B))  # lint: allow[T106] intentional trace-time counter; tier-1 tests assert on it
                fresh_t = target.init_cache(B, max_seq)
                if proposer.needs_hidden:
                    last_l, last_h, fresh_t = target.prefill_with_hidden(
                        params["target"], prompts, fresh_t, lengths=lengths)
                else:
                    last_l, fresh_t = target.prefill(
                        params["target"], prompts, fresh_t, lengths=lengths)
                    last_h = None
                fresh_p = proposer.init_state(params, prompts, max_seq,
                                              lengths=lengths,
                                              last_hidden=last_h)
                first = sample_from(probs_from_logits(last_l, temp), key,
                                    temp)
                from repro.models.model import merge_cache_rows
                merged_t = self._constrain_cache(
                    merge_cache_rows(t_cache, fresh_t, mask))
                merged_p = proposer.merge_state(p_state, fresh_p, mask)
                merged_last = jnp.where(mask, first, last_token)
                return merged_t, merged_p, merged_last

            fn = jax.jit(admit_fn)
            self._admit_cache[(B, Tp, max_seq)] = fn
        return fn

    def admit(self, state: SessionState, prompts: jnp.ndarray, lengths,
              admit_mask, *, key: Optional[jax.Array] = None
              ) -> SessionState:
        """Masked prefill of new requests into retired rows of a session.

        The full (B, T_prompt) bucket is prefilled into FRESH target/
        proposer caches and the result is merged row-wise with the live
        state: rows where ``admit_mask`` is True take the fresh prefill,
        all other rows keep their in-flight cache untouched.  The mask is
        data, so WHICH rows get admitted never retraces — only a new
        (batch, prompt-bucket) shape does (logged in ``admit_trace_log``).

        Parameters
        ----------
        state : SessionState
            The live session (from ``start``/``round``).
        prompts : jnp.ndarray
            (B, T_prompt) tokens.  Admitted rows carry the new prompts;
            non-admitted rows are don't-care fillers (their prefill is
            computed and discarded — the price of a static shape).
        lengths : array-like
            (B,) true prompt lengths (>= 1 everywhere, fillers included).
        admit_mask : array-like
            (B,) bool — True rows are (re)initialised.
        key : jax.Array, optional
            PRNG key for the admitted rows' first sampled token (read it
            from ``state.last_token`` after this call).

        Returns
        -------
        SessionState
            The merged state; admitted rows are prefilled to their prompt
            and ready for the next ``round``.
        """
        B, Tp = prompts.shape
        if B != state.batch:
            raise ValueError(f"admit batch {B} != session batch "
                             f"{state.batch}")
        key = key if key is not None else jax.random.PRNGKey(0)
        mask = self._host(admit_mask, bool)
        fn = self._admit_fn(B, Tp, state.max_seq)
        t_cache, p_state, last_token = fn(
            state.params, state.t_cache, state.p_state, state.last_token,
            self._host(prompts, np.int32), self._host(lengths, np.int32),
            mask, key)
        return replace(state, t_cache=t_cache, p_state=p_state,
                       last_token=last_token)

    # ------------------------------------------------------ sliced admission
    def _fresh_prefill(self, params, prompts, lengths, max_seq, *,
                       cache_opts=None, page_table=None,
                       prefill_kwargs=None):
        """Prefill a batch into fresh caches + proposer state; returns
        (t_cache, p_state, last_logits).  The one shared implementation
        behind ``prefill``/``start`` (full batch, optionally paged), the
        sliced ``admit_rows`` path (compact R-row dense batch) and the
        final chunk of a chunked admission."""
        target, proposer = self.target, self.proposer
        kw = prefill_kwargs or {}
        B = prompts.shape[0]
        fresh_t = target.init_cache(B, max_seq, **(cache_opts or {}))
        if page_table is not None:
            fresh_t["pages"] = dict(fresh_t["pages"],
                                    table=jnp.asarray(page_table, jnp.int32))
        if proposer.needs_hidden:
            last_l, last_h, fresh_t = target.prefill_with_hidden(
                params["target"], prompts, fresh_t, lengths=lengths, **kw)
        else:
            last_l, fresh_t = target.prefill(
                params["target"], prompts, fresh_t, lengths=lengths, **kw)
            last_h = None
        fresh_p = proposer.init_state(params, prompts, max_seq,
                                      lengths=lengths, last_hidden=last_h)
        return fresh_t, fresh_p, last_l

    def _scatter_admitted(self, state_parts, fresh, rows, valid, key, Tp):
        """Scatter a compact fresh (cache, p_state, last_logits) into the
        live session arrays; shared by admit_rows and the final chunk."""
        from repro.models.model import scatter_cache_rows
        t_cache, p_state, last_token = state_parts
        fresh_t, fresh_p, last_l = fresh
        first = sample_from(probs_from_logits(last_l, self.temperature), key,
                            self.temperature)
        merged_t = self._constrain_cache(
            scatter_cache_rows(t_cache, fresh_t, rows, valid=valid,
                               n_prompt=Tp))
        merged_p = self.proposer.scatter_state(p_state, fresh_p, rows,
                                               valid=valid)
        B = last_token.shape[0]
        rows_eff = jnp.where(valid, jnp.asarray(rows, jnp.int32), B)
        merged_last = last_token.at[rows_eff].set(first, mode="drop")
        return merged_t, merged_p, merged_last

    def _admit_rows_fn(self, R: int, Tp: int, max_seq: int) -> Callable:
        fn = self._sliced_cache.get((R, Tp, max_seq))
        if fn is None:
            def admit_rows_fn(params, t_cache, p_state, last_token, prompts,
                              lengths, rows, valid, key):
                self.admit_trace_log.append((Tp, R))  # lint: allow[T106] intentional trace-time counter; tier-1 tests assert on it
                fresh = self._fresh_prefill(params, prompts, lengths,
                                            max_seq)
                return self._scatter_admitted(
                    (t_cache, p_state, last_token), fresh, rows, valid, key,
                    Tp)

            fn = jax.jit(admit_rows_fn)
            self._sliced_cache[(R, Tp, max_seq)] = fn
        return fn

    def admit_rows(self, state: SessionState, prompts: jnp.ndarray, lengths,
                   rows, *, valid=None, key: Optional[jax.Array] = None
                   ) -> SessionState:
        """Row-SLICED admission: prefill only the admitted rows.

        The compact counterpart of :meth:`admit`: ``prompts`` holds just
        the R admitted requests (R <= pool), the fresh prefill runs at the
        (R, T_prompt) shape — its cost scales with what was admitted — and
        the resulting target cache rows / proposer state rows / first
        sampled tokens are row-scattered into the live session
        (models/model.scatter_cache_rows + ``Proposer.scatter_state``).
        Works on dense and paged sessions alike (the fresh prefill is
        always dense; a paged session receives it through its block
        table, which the caller's ``PageAllocator`` must already map).

        Parameters
        ----------
        state : SessionState
            The live session.
        prompts : jnp.ndarray
            (R, T_prompt) admitted prompts, row-count-bucketed by the
            caller (pad lanes replicate real rows and are dropped via
            ``valid``).
        lengths : array-like
            (R,) true prompt lengths.
        rows : array-like
            (R,) pool row index each admitted request lands in.  DATA —
            which rows admit never retraces; only a new (R, T_prompt)
            shape does (logged in ``admit_trace_log`` as ``(T_prompt, R)``).
        valid : array-like, optional
            (R,) bool; False lanes are padding and scatter nothing.
        key : jax.Array, optional
            PRNG key for the admitted rows' first sampled tokens.

        Returns
        -------
        SessionState
            The live session with the admitted rows prefilled and ready
            for the next ``round``.
        """
        R, Tp = prompts.shape
        if key is None:
            if self.temperature > 0.0:
                raise ValueError(
                    "admit_rows() needs a fresh per-call key at "
                    "temperature>0 (split one per admission)")
            key = jax.random.PRNGKey(0)
        valid = (np.ones((R,), bool) if valid is None
                 else np.asarray(valid, bool))
        fn = self._admit_rows_fn(R, Tp, state.max_seq)
        t_cache, p_state, last_token = fn(
            state.params, state.t_cache, state.p_state, state.last_token,
            self._host(prompts, np.int32), self._host(lengths, np.int32),
            self._host(rows, np.int32), self._host(valid, bool), key)
        return replace(state, t_cache=t_cache, p_state=p_state,
                       last_token=last_token)

    # ------------------------------------------------ prefix-shared admission
    def _admit_prefix_fn(self, R: int, Tt: int, Tp: int,
                         max_seq: int) -> Callable:
        fn = self._prefix_cache.get((R, Tt, Tp, max_seq))
        if fn is None:
            target, proposer = self.target, self.proposer

            def prefix_fn(params, t_cache, p_state, last_token, tails,
                          tail_start, tail_len, prompts, lengths, rows,
                          valid, key):
                self.prefix_trace_log.append((Tt, R))  # lint: allow[T106] intentional trace-time counter; tier-1 tests assert on it
                rows_i = jnp.asarray(rows, jnp.int32)
                # compact R-row view of the LIVE paged cache: pool leaves
                # are batch-free (shared physical pages), so only the
                # block table and lengths need row-slicing.  The tail
                # extend writes through the sliced table into the rows'
                # private pages and attends across their shared-prefix
                # pages in the same forward.
                compact = {
                    "layers": t_cache["layers"],
                    "lengths": tail_start,
                    "pages": {"table": t_cache["pages"]["table"][rows_i]},
                }
                if proposer.needs_hidden:
                    logits, hidden, pend = target.extend_with_hidden(
                        params["target"], tails, compact, collect=False)
                else:
                    logits, pend = target.extend(params["target"], tails,
                                                 compact, collect=False)
                    hidden = None
                idx = (tail_len - 1)[:, None, None].astype(jnp.int32)
                last_l = jnp.take_along_axis(logits, idx, axis=1)[:, 0]
                last_h = (jnp.take_along_axis(hidden, idx, axis=1)[:, 0]
                          if hidden is not None else None)
                fresh_p = proposer.init_state(params, prompts, max_seq,
                                              lengths=lengths,
                                              last_hidden=last_h)
                first = sample_from(
                    probs_from_logits(last_l, self.temperature), key,
                    self.temperature)
                B = last_token.shape[0]
                rows_eff = jnp.where(valid, rows_i, B)
                # attention slots commit in place (pend carries the
                # written pools); the live lengths jump straight to the
                # full prompt length — shared prefix included
                merged_t = self._constrain_cache(dict(
                    t_cache, layers=pend["layers"],
                    lengths=t_cache["lengths"].at[rows_eff].set(
                        lengths, mode="drop")))
                merged_p = proposer.scatter_state(p_state, fresh_p, rows_i,
                                                  valid=valid)
                merged_last = last_token.at[rows_eff].set(first, mode="drop")
                return merged_t, merged_p, merged_last

            fn = jax.jit(prefix_fn)
            self._prefix_cache[(R, Tt, Tp, max_seq)] = fn
        return fn

    def admit_rows_prefix(self, state: SessionState, tails, tail_start,
                          tail_len, prompts, lengths, rows, *, valid=None,
                          key: Optional[jax.Array] = None) -> SessionState:
        """Prefix-SHARED sliced admission: target-prefill only the tails.

        The page-sharing counterpart of :meth:`admit_rows` for a PAGED
        session whose allocator already mapped each admitted row's table
        to a sibling's shared prefix pages (``PageAllocator.fork_prefix``
        + ``cow_range`` + private ``extend_row`` pages).  The target side
        prefills ONLY the unshared tail ``tails[i] = prompt[i][tail_start
        [i]:]`` as an extend at offset ``tail_start`` — the queries attend
        across the shared prefix KV through the row-sliced block table, so
        the common prefix is never recomputed.  The proposer still builds
        its (dense, cheap) state over the full prompt.

        Restriction: every target layer must be full-attention or MLA
        (pool-backed slots; SWA rings and recurrent states carry per-row
        dense state a tail extend cannot reconstruct) — callers gate on
        this and fall back to :meth:`admit_rows`.

        Parameters
        ----------
        state : SessionState
            The live PAGED session.
        tails : array-like
            (R, T_tail) unshared prompt tails, zero-padded per lane.
        tail_start : array-like
            (R,) shared-prefix length per row (where the tail starts).
        tail_len : array-like
            (R,) true tail lengths (``tail_start + tail_len == lengths``).
        prompts : array-like
            (R, T_prompt) FULL prompts — consumed by the proposer's fresh
            state build.
        lengths : array-like
            (R,) full prompt lengths.
        rows : array-like
            (R,) pool row of each admitted request (DATA, never retraces).
        valid : array-like, optional
            (R,) bool; False lanes are padding and scatter nothing.
        key : jax.Array, optional
            PRNG key for the admitted rows' first sampled tokens.

        Returns
        -------
        SessionState
            The live session with the admitted rows prefilled (shared
            prefix + fresh tail) and ready for the next ``round``.
        """
        tails = np.asarray(tails)
        prompts = np.asarray(prompts)
        R, Tt = tails.shape
        Tp = prompts.shape[1]
        if state.t_cache.get("pages") is None:
            raise ValueError("admit_rows_prefix needs a paged session")
        bad = [k for k in self.target.cfg.layer_pattern
               if k not in ("attn", "mla")]
        if bad:
            raise ValueError(
                f"admit_rows_prefix requires pool-backed layers only; "
                f"target has {sorted(set(bad))} (fall back to admit_rows)")
        if key is None:
            if self.temperature > 0.0:
                raise ValueError(
                    "admit_rows_prefix() needs a fresh per-call key at "
                    "temperature>0 (split one per admission)")
            key = jax.random.PRNGKey(0)
        valid = (np.ones((R,), bool) if valid is None
                 else np.asarray(valid, bool))
        fn = self._admit_prefix_fn(R, Tt, Tp, state.max_seq)
        t_cache, p_state, last_token = fn(
            state.params, state.t_cache, state.p_state, state.last_token,
            self._host(tails, np.int32),
            self._host(tail_start, np.int32),
            self._host(tail_len, np.int32),
            self._host(prompts, np.int32),
            self._host(lengths, np.int32),
            self._host(rows, np.int32), self._host(valid, bool), key)
        return replace(state, t_cache=t_cache, p_state=p_state,
                       last_token=last_token)

    # ----------------------------------------------------- chunked admission
    def begin_admit_chunked(self, prompts, lengths, rows, *, chunk: int,
                            key: Optional[jax.Array] = None
                            ) -> "PendingAdmission":
        """Open a chunked (incremental) sliced admission.

        Long prompts prefill ``chunk`` tokens at a time — one
        ``admit_chunk`` call per decode-round boundary — so a single long
        admission no longer stalls the round it lands in.  The compact
        cache under construction attends only to its own already-written
        positions (the ``extend``-at-offset discipline), so chunked and
        one-shot prefills are token-identical.  The admitted rows stay
        OUT of the live session (inactive, shape-stable) until the final
        chunk scatters them in.

        Restriction: one chunked admission holds requests of EQUAL prompt
        length (callers admit long prompts one request at a time), and SWA
        targets need ``chunk <= SWA_RING_PAD + 1`` (ring eviction) — the
        serving engine validates both.
        """
        prompts = np.asarray(prompts)
        lengths = np.asarray(lengths, np.int32)
        if len(set(int(x) for x in lengths)) != 1:
            raise ValueError("chunked admission requires equal prompt "
                             "lengths; admit long prompts one at a time")
        if int(lengths[0]) <= chunk:
            raise ValueError("prompt fits one chunk; use admit_rows")
        if key is None:
            if self.temperature > 0.0:
                raise ValueError(
                    "begin_admit_chunked() needs a fresh key at "
                    "temperature>0")
            key = jax.random.PRNGKey(0)
        return PendingAdmission(prompts=prompts, lengths=lengths,
                                rows=np.asarray(rows, np.int32),
                                chunk=int(chunk), key=key)

    def _chunk_fn(self, stage: str, R: int, C: int, Tp: int,
                  max_seq: int) -> Callable:
        # "first"/"mid" never touch the full prompt, so they share one
        # compile across prompt buckets; only "final" keys on Tp
        cache_key = (stage, R, C, Tp if stage == "final" else 0, max_seq)
        fn = self._chunk_cache.get(cache_key)
        if fn is not None:
            return fn
        target, proposer = self.target, self.proposer

        if stage == "first":
            def chunk_fn(params, toks, lens):
                self.chunk_trace_log.append((stage, C, R))  # lint: allow[T106] intentional trace-time counter; tier-1 tests assert on it
                fresh_t = target.init_cache(R, max_seq)
                _, fresh_t = target.prefill(params["target"], toks, fresh_t,
                                            lengths=lens)
                return fresh_t
        elif stage == "mid":
            def chunk_fn(params, fresh_t, toks, n_row):
                self.chunk_trace_log.append((stage, C, R))  # lint: allow[T106] intentional trace-time counter; tier-1 tests assert on it
                _, pend = target.extend(params["target"], toks, fresh_t,
                                        collect=True)
                return target.commit(pend, n_row, collected=True)
        else:                                        # "final"
            def chunk_fn(params, t_cache, p_state, last_token, fresh_t,
                         toks, prompts, lengths, n_row, rows, valid, key):
                self.chunk_trace_log.append((stage, C, R))  # lint: allow[T106] intentional trace-time counter; tier-1 tests assert on it
                logits, hidden, pend = target.extend_with_hidden(
                    params["target"], toks, fresh_t, collect=True)
                fresh_t = target.commit(pend, n_row, collected=True)
                idx = (n_row - 1)[:, None, None].astype(jnp.int32)
                last_l = jnp.take_along_axis(logits, idx, axis=1)[:, 0]
                last_h = jnp.take_along_axis(hidden, idx, axis=1)[:, 0] \
                    if proposer.needs_hidden else None
                fresh_p = proposer.init_state(params, prompts, max_seq,
                                              lengths=lengths,
                                              last_hidden=last_h)
                return self._scatter_admitted(
                    (t_cache, p_state, last_token),
                    (fresh_t, fresh_p, last_l), rows, valid, key, Tp)

        fn = jax.jit(chunk_fn)
        self._chunk_cache[cache_key] = fn
        return fn

    def admit_chunk(self, state: SessionState, pa: "PendingAdmission"
                    ) -> Tuple[SessionState, Optional["PendingAdmission"]]:
        """Advance a chunked admission by ONE chunk.

        Non-final chunks touch only the pending compact cache (the live
        session is returned unchanged — its slots keep decoding); the
        final chunk commits the tail, builds the proposer state over the
        full prompt, samples the first tokens and scatters everything into
        the live session exactly like :meth:`admit_rows`.

        Returns ``(state, pending)`` — ``pending`` is ``None`` once the
        admission landed (the rows are then live).
        """
        R, Tp = pa.prompts.shape
        C = pa.chunk
        done = pa.consumed
        total = int(pa.lengths[0])
        take = min(C, total - done)
        toks = np.full((R, C), 0, np.int32)
        toks[:, :take] = pa.prompts[:, done:done + take]
        toks = self._host(toks, np.int32)
        n_row = self._host(np.full((R,), take, np.int32), np.int32)
        final = done + take >= total
        params = state.params
        if done == 0:
            fn = self._chunk_fn("first", R, C, Tp, state.max_seq)
            fresh_t = fn(params, toks,
                         self._host(np.minimum(pa.lengths, C), np.int32))
            return state, replace(pa, t_cache=fresh_t, consumed=take)
        if not final:
            fn = self._chunk_fn("mid", R, C, Tp, state.max_seq)
            fresh_t = fn(params, pa.t_cache, toks, n_row)
            return state, replace(pa, t_cache=fresh_t,
                                  consumed=done + take)
        fn = self._chunk_fn("final", R, C, Tp, state.max_seq)
        valid = self._host(np.ones((R,), bool), bool)
        t_cache, p_state, last_token = fn(
            params, state.t_cache, state.p_state, state.last_token,
            pa.t_cache, toks, self._host(pa.prompts, np.int32),
            self._host(pa.lengths, np.int32), n_row,
            self._host(pa.rows, np.int32), valid, pa.key)
        new_state = replace(state, t_cache=t_cache, p_state=p_state,
                            last_token=last_token)
        return new_state, None

    # ---------------------------------------------------------------- growth
    def grow_session(self, state: SessionState, new_max_seq: int, *,
                     pool_pages: Optional[int] = None,
                     max_pages: Optional[int] = None) -> SessionState:
        """Grow a PAGED session's logical capacity to ``new_max_seq``.

        Pads the target's physical page pool / block table
        (models/model.grow_cache_pages) and the proposer's dense caches
        (``Proposer.grow_state``) so a late-arriving request longer than
        anything the stream was sized for admits instead of raising.  A
        growth changes compiled shapes, so the next round/admit retraces —
        pow2 geometry amortizes that; events land in ``growth_log``.
        """
        from repro.models.model import grow_cache_pages
        t_cache = state.t_cache
        if t_cache.get("pages") is None:
            raise ValueError("grow_session: dense sessions are statically "
                             "sized; use a paged session (kv_layout='paged')")
        if pool_pages is not None:
            t_cache = grow_cache_pages(t_cache, pool_pages, max_pages)
        p_state = self.proposer.grow_state(state.p_state, new_max_seq)
        self.growth_log.append((new_max_seq, pool_pages))
        return replace(state, t_cache=t_cache, p_state=p_state,
                       max_seq=new_max_seq)

    # -------------------------------------------------------------- generate
    def generate(
        self,
        params_t,
        params_p,
        prompts: jnp.ndarray,               # (B, T_prompt)
        max_new_tokens: int,
        *,
        gamma: Optional[int] = None,
        max_seq: Optional[int] = None,
        lengths=None,
        key: Optional[jax.Array] = None,
        prefill_kwargs: Optional[dict] = None,
        timed: bool = False,
    ) -> Tuple[np.ndarray, SDStats]:
        """Run SD rounds until every sequence has >= max_new_tokens.

        A thin wave-mode wrapper over the session API: one ``start`` then
        ``round`` in a loop with every row active — continuous callers
        drive the same two methods with masks and mid-stream ``admit``.
        """
        B, Tp = prompts.shape
        gamma = self.gamma if gamma is None else gamma
        key = key if key is not None else jax.random.PRNGKey(0)
        if max_seq is None:
            max_seq = Tp + max_new_tokens + gamma + 2
        key, k_pre = jax.random.split(key)
        state = self.start(params_t, params_p, prompts, max_seq=max_seq,
                           lengths=lengths, key=k_pre,
                           prefill_kwargs=prefill_kwargs)

        out = np.zeros((B, max_new_tokens + gamma + 1), np.int32)
        n_out = np.zeros((B,), np.int32)
        # the first sampled token (from prefill) counts as generated
        out[:, 0] = np.asarray(state.last_token)
        n_out += 1

        stats = SDStats()
        while int(n_out.min()) < max_new_tokens:
            key, k_round = jax.random.split(key)
            state, res = self.round(state, gamma=gamma, key=k_round,
                                    timed=timed)
            if res.finite is not None and not bool(np.all(res.finite)):
                # Wave mode has no quarantine path: a permanently
                # non-finite row commits nothing every round and the
                # min()-driven loop would never terminate.  Fail loudly;
                # the continuous scheduler is the layer that degrades
                # gracefully (finish_reason="numerical_fault").
                bad = np.where(~np.asarray(res.finite))[0].tolist()
                raise RuntimeError(
                    f"non-finite verify logits in wave-mode rows {bad}; "
                    "use the continuous scheduler for quarantine")
            for b in range(B):
                n = int(res.n_commit[b])
                w = min(n, out.shape[1] - n_out[b])
                out[b, n_out[b]: n_out[b] + w] = res.committed[b, :w]
                n_out[b] += w
            stats.absorb_round(res, B)
        self.accumulate_prefetch_totals(stats)
        return out[:, :max_new_tokens], stats


# ---------------------------------------------------------------------------
# backwards-compatible entry points (pre-Proposer API)
# ---------------------------------------------------------------------------

class SpecDecoder(SDEngine):
    """Legacy shim: target + draft *model* pair == SDEngine("model").

    Prefer ``SDEngine(target, make_proposer("model", target, draft))``.
    """

    def __init__(self, target: Model, draft: Model, gamma: int = 4,
                 temperature: float = 0.0):
        super().__init__(
            target,
            make_proposer("model", target, draft, temperature=temperature),
            gamma=gamma, temperature=temperature)
        self.draft = draft


def _ar_session(model: Model, temperature: float) -> SDEngine:
    """AR generation reuses one persistent "none" session per
    (model, temperature) so repeated generate_ar calls don't re-jit the
    decode round.  Sessions hang off the model instance itself (not a
    global registry): they share its lifetime, so dropping the model
    releases the compiled rounds too."""
    per_model = getattr(model, "_ar_sessions", None)
    if per_model is None:
        per_model = model._ar_sessions = {}
    eng = per_model.get(temperature)
    if eng is None:
        eng = SDEngine(model,
                       make_proposer("none", model, temperature=temperature),
                       gamma=0, temperature=temperature)
        per_model[temperature] = eng
    return eng


def generate_ar(model: Model, params, prompts: jnp.ndarray,
                max_new_tokens: int, *, temperature: float = 0.0,
                lengths=None, key=None,
                prefill_kwargs: Optional[dict] = None) -> np.ndarray:
    """Plain autoregressive baseline (T_AR in the paper's speedup
    definition) — the gamma=0 / "none"-proposer path of SDEngine."""
    out, _ = _ar_session(model, temperature).generate(
        params, None, prompts, max_new_tokens, lengths=lengths, key=key,
        prefill_kwargs=prefill_kwargs)
    return out

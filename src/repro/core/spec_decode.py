"""Batched speculative-decoding engine (the paper's serving mechanism).

One SD round (Sec. 3.1):
  1. PROPOSE  — the draft model autoregressively emits gamma tokens per
     sequence (gamma+1 draft forwards of one token: the last one only
     writes d_gamma's KV so the draft cache stays aligned on full accept).
  2. VERIFY   — the target model processes [last_token, d_1..d_gamma]
     (gamma+1 tokens) in ONE forward, yielding gamma+1 next-token
     distributions.
  3. REJECT   — batched rejection sampling (rejection.py) accepts a per-
     sequence prefix of the drafts and emits one extra token (residual
     sample or bonus).  n_commit = n_accept + 1 ∈ [1, gamma+1].

Cache discipline:
  * target/draft attention KV: fresh tokens are written at offsets
    ``lengths``; a rejected suffix is simply left stale (masked by
    position) and ``lengths += n_commit``.
  * recurrent states (SSM/xLSTM targets or drafts): verify collects
    per-step states and ``commit`` gathers the state of the last accepted
    token (models/model.py).  Recurrent drafts re-run the verify pass from
    a pre-round snapshot (γ+1 cheap draft tokens) since their propose loop
    advances state destructively.

The engine never mixes tokens across sequences — per-sequence lengths make
the batch ragged, exactly like continuous batching in vLLM.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.rejection import probs_from_logits, rejection_sample, sample_from
from repro.models.model import Model


@dataclass
class SDStats:
    rounds: int = 0
    generated: int = 0                      # total committed tokens (all seqs)
    max_possible: int = 0                   # rounds * (gamma+1) * B
    accept_events: int = 0                  # accepted draft tokens
    draft_events: int = 0                   # proposed draft tokens
    propose_time: float = 0.0
    verify_time: float = 0.0
    reject_time: float = 0.0

    @property
    def sigma(self) -> float:               # paper's σ (Eq. 5 empirical)
        return self.generated / max(self.max_possible, 1)

    @property
    def alpha(self) -> float:               # empirical acceptance rate
        return self.accept_events / max(self.draft_events, 1)


def _gather_snapshot(snaps, n_commit):
    """snaps: pytree stacked (gamma+1, P, B, ...); pick index n_commit-1 per seq."""
    idx = n_commit - 1

    def g(a):
        moved = jnp.moveaxis(a, 2, 0)                   # (B, G+1, P, ...)
        sel = jax.vmap(lambda ab, n: ab[n])(moved, idx)
        return jnp.moveaxis(sel, 0, 1)                  # (G+1→, ...) -> (P,B,...)

    return jax.tree.map(g, snaps)


class SpecDecoder:
    """Pairs a target and a draft model for batched speculative decoding."""

    def __init__(self, target: Model, draft: Model, gamma: int = 4,
                 temperature: float = 0.0):
        self.target = target
        self.draft = draft
        self.gamma = gamma
        self.temperature = temperature
        self._round_jit = jax.jit(self._round)

    # ------------------------------------------------------------- one round
    def _propose(self, params_d, draft_cache, last_token, key):
        """gamma+1 single-token draft forwards; returns drafts, q-dists and
        the draft cache with all gamma+1 tokens written (lengths NOT bumped
        for attention slots; recurrent slots committed per step)."""
        gamma = self.gamma
        recurrent = self.draft.cfg.is_recurrent
        c = draft_cache
        token = last_token
        qs, ds = [], []
        snapshot = None
        if recurrent:
            snapshot = c                                    # pre-round state
        for i in range(gamma):
            if recurrent:
                logits, pend = self.draft.extend(params_d, token[:, None], c,
                                                 collect=True)
                c = self.draft.commit(pend, jnp.ones_like(c["lengths"]),
                                      collected=True)
            else:
                logits, c = self.draft.extend(params_d, token[:, None], c)
                c = dict(c, lengths=c["lengths"] + 1)
            key, k_s = jax.random.split(key)
            q = probs_from_logits(logits[:, 0], self.temperature)
            token = sample_from(q, k_s, self.temperature)
            qs.append(q)
            ds.append(token)
        # write d_gamma's KV so the cache is complete on full acceptance
        if recurrent:
            logits, pend = self.draft.extend(params_d, token[:, None], c, collect=True)
            c = self.draft.commit(pend, jnp.ones_like(c["lengths"]), collected=True)
        else:
            _, c = self.draft.extend(params_d, token[:, None], c)
        drafts = jnp.stack(ds, axis=1)                      # (B, gamma)
        q_dist = jnp.stack(qs, axis=1)                      # (B, gamma, V)
        return drafts, q_dist, c, snapshot

    def _round(self, params_t, params_d, target_cache, draft_cache,
               last_token, key):
        gamma = self.gamma
        B = last_token.shape[0]
        key, k_prop, k_rej = jax.random.split(key, 3)
        base_len = target_cache["lengths"]

        drafts, q_dist, d_cache, d_snapshot = self._propose(
            params_d, draft_cache, last_token, k_prop)

        # VERIFY: one target forward over [last, d_1..d_gamma]
        verify_tokens = jnp.concatenate([last_token[:, None], drafts], axis=1)
        logits_v, pend_t = self.target.extend(
            params_t, verify_tokens, target_cache, collect=True)
        p_dist = probs_from_logits(logits_v, self.temperature)  # (B, γ+1, V)

        # REJECT
        n_accept, next_token, accept_mask = rejection_sample(
            p_dist, q_dist, drafts, k_rej, self.temperature)
        n_commit = n_accept + 1

        # COMMIT target
        t_cache = self.target.commit(pend_t, n_commit, collected=True)

        # COMMIT draft
        if self.draft.cfg.is_recurrent:
            # re-run from the pre-round snapshot and gather accepted state
            _, pend_d = self.draft.extend(
                params_d, verify_tokens,
                dict(d_snapshot), collect=True)
            d_cache = self.draft.commit(pend_d, n_commit, collected=True)
        else:
            d_cache = dict(d_cache, lengths=base_len + n_commit)

        # committed new tokens this round: [d_1..d_n, next]  (n_commit each)
        slot = jnp.arange(gamma + 1)[None, :]
        drafts_pad = jnp.concatenate([drafts, jnp.zeros((B, 1), drafts.dtype)], 1)
        committed = jnp.where(slot < n_accept[:, None], drafts_pad,
                              next_token[:, None])          # (B, γ+1)
        return (t_cache, d_cache, next_token, committed, n_commit,
                jnp.sum(n_accept), key)

    # --------------------------------------------------------------- prefill
    def prefill(self, params_t, params_d, prompts: jnp.ndarray,
                max_seq: int, *, lengths=None, key=None,
                prefill_kwargs: Optional[dict] = None):
        """Prefill both models; returns (target_cache, draft_cache, last_token)."""
        B = prompts.shape[0]
        kw = prefill_kwargs or {}
        t_cache = self.target.init_cache(B, max_seq)
        d_cache = self.draft.init_cache(B, max_seq)
        last_t, t_cache = self.target.prefill(params_t, prompts, t_cache,
                                              lengths=lengths, **kw)
        _, d_cache = self.draft.prefill(params_d, prompts, d_cache,
                                        lengths=lengths)
        key = key if key is not None else jax.random.PRNGKey(0)
        p = probs_from_logits(last_t, self.temperature)
        last_token = sample_from(p, key, self.temperature)
        return t_cache, d_cache, last_token

    # -------------------------------------------------------------- generate
    def generate(
        self,
        params_t,
        params_d,
        prompts: jnp.ndarray,               # (B, T_prompt)
        max_new_tokens: int,
        *,
        lengths=None,
        key: Optional[jax.Array] = None,
        prefill_kwargs: Optional[dict] = None,
        timed: bool = False,
    ) -> Tuple[np.ndarray, SDStats]:
        """Run SD rounds until every sequence has >= max_new_tokens."""
        B, Tp = prompts.shape
        gamma = self.gamma
        key = key if key is not None else jax.random.PRNGKey(0)
        max_seq = Tp + max_new_tokens + gamma + 2
        t_cache, d_cache, last_token = self.prefill(
            params_t, params_d, prompts, max_seq, lengths=lengths, key=key,
            prefill_kwargs=prefill_kwargs)

        out = np.zeros((B, max_new_tokens + gamma + 1), np.int32)
        n_out = np.zeros((B,), np.int32)
        # the first sampled token (from prefill) counts as generated
        out[:, 0] = np.asarray(last_token)
        n_out += 1

        stats = SDStats()
        while int(n_out.min()) < max_new_tokens:
            t0 = time.perf_counter()
            (t_cache, d_cache, last_token, committed, n_commit, n_acc, key) = \
                self._round_jit(params_t, params_d, t_cache, d_cache,
                                last_token, key)
            committed = np.asarray(committed)
            n_commit_np = np.asarray(n_commit)
            if timed:
                jax.block_until_ready(last_token)
                stats.verify_time += time.perf_counter() - t0
            for b in range(B):
                n = int(n_commit_np[b])
                w = min(n, out.shape[1] - n_out[b])
                out[b, n_out[b]: n_out[b] + w] = committed[b, :w]
                n_out[b] += w
            stats.rounds += 1
            stats.generated += int(n_commit_np.sum())
            stats.max_possible += (gamma + 1) * B
            stats.accept_events += int(np.asarray(n_acc))
            stats.draft_events += gamma * B
        return out[:, :max_new_tokens], stats


# ---------------------------------------------------------------------------
# plain autoregressive baseline (T_AR in the paper's speedup definition)
# ---------------------------------------------------------------------------

def generate_ar(model: Model, params, prompts: jnp.ndarray,
                max_new_tokens: int, *, temperature: float = 0.0,
                lengths=None, key=None,
                prefill_kwargs: Optional[dict] = None) -> np.ndarray:
    B, Tp = prompts.shape
    key = key if key is not None else jax.random.PRNGKey(0)
    cache = model.init_cache(B, Tp + max_new_tokens + 2)
    kw = prefill_kwargs or {}
    last_logits, cache = model.prefill(params, prompts, cache,
                                       lengths=lengths, **kw)
    step = jax.jit(model.decode_step)
    out = np.zeros((B, max_new_tokens), np.int32)
    p = probs_from_logits(last_logits, temperature)
    key, k0 = jax.random.split(key)
    token = sample_from(p, k0, temperature)
    out[:, 0] = np.asarray(token)
    for t in range(1, max_new_tokens):
        logits, cache = step(params, token, cache)
        key, kt = jax.random.split(key)
        token = sample_from(probs_from_logits(logits, temperature), kt, temperature)
        out[:, t] = np.asarray(token)
    return out

"""Batched speculative-decoding engine (the paper's serving mechanism).

One SD round (Sec. 3.1), generic over any registered Proposer
(core/proposer.py):

  1. PROPOSE  — ``proposer.propose`` emits g <= gamma draft tokens per
     sequence with their draft distributions (a small model, an EAGLE
     head, or nothing at all for the AR baseline).
  2. VERIFY   — the target model processes [last_token, d_1..d_g]
     (g+1 tokens) in ONE forward, yielding g+1 next-token distributions.
  3. REJECT   — batched rejection sampling (rejection.py) accepts a per-
     sequence prefix of the drafts and emits one extra token (residual
     sample or bonus).  n_commit = n_accept + 1 ∈ [1, g+1].
  4. COMMIT   — target cache commit + ``proposer.commit`` reconcile both
     sides to the accepted prefix.

The AR baseline is the degenerate g=0 instance of the SAME loop (the
"none" proposer): the round collapses to one target forward of
``last_token`` plus a sample — so SD and AR timings come from identical
machinery, which is what the paper's speedup definition x = T_AR/T_SD
requires.

Cache discipline:
  * target/draft attention KV: fresh tokens are written at offsets
    ``lengths``; a rejected suffix is simply left stale (masked by
    position) and ``lengths += n_commit``.
  * recurrent states (SSM/xLSTM targets or drafts): verify collects
    per-step states and ``commit`` gathers the state of the last accepted
    token (models/model.py).  Recurrent drafts re-run the verify pass from
    a pre-round snapshot (γ+1 cheap draft tokens) since their propose loop
    advances state destructively.

Compile caching: each SDEngine instance is a long-lived *decoding
session*.  Per gamma it builds the fused round once (``_round_cache``)
and jax.jit then caches per batch/sequence shape; ``trace_log`` records
every (gamma, batch) retrace so serving code (and tests) can assert
reuse.  The engine never mixes tokens across sequences — per-sequence
lengths make the batch ragged, exactly like continuous batching in vLLM.
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.proposer import Proposer, make_proposer
from repro.core.rejection import probs_from_logits, rejection_sample, sample_from
from repro.models.model import Model
from repro.models.moe import warm_experts as moe_warm_experts


@dataclass
class SDStats:
    rounds: int = 0
    generated: int = 0                      # total committed tokens (all seqs)
    max_possible: int = 0                   # rounds * (gamma+1) * B
    accept_events: int = 0                  # accepted draft tokens
    draft_events: int = 0                   # proposed draft tokens
    round_time: float = 0.0                 # wall time across all rounds
    propose_time: float = 0.0               # per-phase (timed=True only)
    verify_time: float = 0.0
    reject_time: float = 0.0
    # expert-prefetch accounting (prefetch-aware proposers only): summed
    # over rounds, layers and periods — hits = activated AND warmed
    prefetch_hits: int = 0
    prefetch_actual: int = 0                # experts the verify passes hit
    prefetch_predicted: int = 0             # experts the plans warmed
    warm_time: float = 0.0                  # warm DISPATCH time (timed only)

    @property
    def sigma(self) -> float:               # paper's σ (Eq. 5 empirical)
        return self.generated / max(self.max_possible, 1)

    @property
    def alpha(self) -> float:               # empirical acceptance rate
        return self.accept_events / max(self.draft_events, 1)

    @property
    def prefetch_misses(self) -> int:       # activated but NOT warmed
        return self.prefetch_actual - self.prefetch_hits

    @property
    def prefetch_hit_rate(self) -> float:   # P(activated expert was warm)
        return self.prefetch_hits / max(self.prefetch_actual, 1)


class SDEngine:
    """One persistent decoding session: a target model + one Proposer.

    The propose/verify/reject/commit round is generic over the proposer;
    compiled rounds are cached per gamma (and, via jit, per shape), so a
    serving engine can hold one SDEngine per proposer kind and change
    gamma between waves without rebuilding anything.
    """

    def __init__(self, target: Model, proposer: Proposer, *,
                 gamma: int = 4, temperature: float = 0.0):
        self.target = target
        self.proposer = proposer
        self.gamma = gamma
        self.temperature = temperature
        self._round_cache: Dict[int, Callable] = {}      # gamma -> jitted round
        self._stage_cache: Dict[int, Tuple] = {}         # gamma -> stage jits
        self.trace_log: List[Tuple[int, int]] = []       # (gamma, B) per trace
        # session-lifetime expert-prefetch aggregates (prefetch proposers):
        # summed across every generate() call this session served
        self.prefetch_totals: Dict[str, int] = {
            "hits": 0, "actual": 0, "predicted": 0, "rounds": 0}

    def compiled_gammas(self) -> List[int]:
        """Gammas with a built round (fused or staged) in this session."""
        return sorted(set(self._round_cache) | set(self._stage_cache))

    # ----------------------------------------------------------- round pieces
    def _stages(self, gamma: int):
        """(propose, verify, finalize) pure stage functions for one gamma.

        Prefetch-aware proposers (``provides_prefetch``) get a verify stage
        that additionally takes the round's ``PrefetchPlan`` and returns the
        hit/miss counts scored by ``Model.extend_with_prefetch``; all other
        proposers' verify returns ``pf = None``.
        """
        target, proposer, temp = self.target, self.proposer, self.temperature
        pf_aware = getattr(proposer, "provides_prefetch", False)

        def propose(params, p_state, last_token, k_prop):
            return proposer.propose(params, p_state, last_token, gamma, k_prop)

        if pf_aware:
            def verify(params_t, t_cache, last_token, drafts, plan):
                verify_tokens = jnp.concatenate([last_token[:, None], drafts],
                                                1)
                logits, hidden, pend, pf = target.extend_with_prefetch(
                    params_t, verify_tokens, t_cache, plan, collect=True)
                if not proposer.needs_hidden:
                    hidden = None
                return probs_from_logits(logits, temp), hidden, pend, pf
        else:
            def verify(params_t, t_cache, last_token, drafts):
                verify_tokens = jnp.concatenate([last_token[:, None], drafts],
                                                1)
                if proposer.needs_hidden:
                    logits, hidden, pend = target.extend_with_hidden(
                        params_t, verify_tokens, t_cache, collect=True)
                else:
                    logits, pend = target.extend(params_t, verify_tokens,
                                                 t_cache, collect=True)
                    hidden = None
                return probs_from_logits(logits, temp), hidden, pend, None

        def finalize(params, pend, p_state, base_len, p_dist, q_dist, drafts,
                     hidden, last_token, k_rej):
            B, g = drafts.shape
            n_accept, next_token, _ = rejection_sample(
                p_dist, q_dist, drafts, k_rej, temp)
            n_commit = n_accept + 1
            t_cache = target.commit(pend, n_commit, collected=True)
            verify_tokens = jnp.concatenate([last_token[:, None], drafts], 1)
            p_state = proposer.commit(
                params, p_state, base_len=base_len, n_accept=n_accept,
                n_commit=n_commit, verify_tokens=verify_tokens, hidden=hidden)
            # committed new tokens this round: [d_1..d_n, next] (n_commit each)
            slot = jnp.arange(g + 1)[None, :]
            drafts_pad = jnp.concatenate(
                [drafts, jnp.zeros((B, 1), drafts.dtype)], 1)
            committed = jnp.where(slot < n_accept[:, None], drafts_pad,
                                  next_token[:, None])          # (B, g+1)
            return (t_cache, p_state, next_token, committed, n_commit,
                    jnp.sum(n_accept))

        return propose, verify, finalize

    def _round_fn(self, gamma: int) -> Callable:
        """Fused jitted round for one gamma (built once per session).

        Prefetch-aware proposers never take this path — inside one
        monolithic XLA computation the warm gather would be dead code, so
        ``generate`` always runs them staged (see ``_staged_jits``).
        """
        if getattr(self.proposer, "provides_prefetch", False):
            raise RuntimeError(
                "prefetch-aware proposers decode through staged rounds; "
                "the fused round cannot express the warm dispatch")
        fn = self._round_cache.get(gamma)
        if fn is None:
            propose, verify, finalize = self._stages(gamma)

            def round_fn(params, t_cache, p_state, last_token, k_prop, k_rej):
                # trace-time side effect: lets callers assert compile reuse
                self.trace_log.append((gamma, int(last_token.shape[0])))
                base_len = t_cache["lengths"]
                drafts, q_dist, p_work = propose(params, p_state, last_token,
                                                 k_prop)
                p_dist, hidden, pend, pf = verify(params["target"], t_cache,
                                                  last_token, drafts)
                out = finalize(params, pend, p_work, base_len, p_dist,
                               q_dist, drafts, hidden, last_token, k_rej)
                return out + (pf,)

            fn = jax.jit(round_fn)
            self._round_cache[gamma] = fn
        return fn

    def _staged_jits(self, gamma: int):
        """Separately-jitted (propose, verify, finalize, warm) stages.

        Used for ``timed=True`` (syncing between stages gives real per-phase
        wall times) and for prefetch-aware proposers even untimed: the round
        must be split so the host can dispatch the expert-warm gather
        *between* the propose and verify launches — that interleaving is the
        overlap (a fused round gives XLA one monolithic computation and the
        warm gather would be dead code).  ``warm`` is ``None`` for ordinary
        proposers.
        """
        fns = self._stage_cache.get(gamma)
        if fns is None:
            propose, verify, finalize = self._stages(gamma)

            def propose_logged(params, p_state, last_token, k_prop):
                self.trace_log.append((gamma, int(last_token.shape[0])))
                return propose(params, p_state, last_token, k_prop)

            warm = None
            if getattr(self.proposer, "provides_prefetch", False):
                target_cfg = self.target.cfg

                def warm(params_t, plan):
                    return moe_warm_experts(params_t["layers"], target_cfg,
                                            plan)
                warm = jax.jit(warm)

            fns = (jax.jit(propose_logged), jax.jit(verify),
                   jax.jit(finalize), warm)
            self._stage_cache[gamma] = fns
        return fns

    # --------------------------------------------------------------- prefill
    def prefill(self, params_t, params_p, prompts: jnp.ndarray, max_seq: int,
                *, lengths=None, key=None,
                prefill_kwargs: Optional[dict] = None):
        """Prefill target + proposer; returns (t_cache, p_state, last_token)."""
        B = prompts.shape[0]
        kw = prefill_kwargs or {}
        params = {"target": params_t, "draft": params_p}
        t_cache = self.target.init_cache(B, max_seq)
        if self.proposer.needs_hidden:
            last_t, last_hidden, t_cache = self.target.prefill_with_hidden(
                params_t, prompts, t_cache, lengths=lengths, **kw)
        else:
            last_t, t_cache = self.target.prefill(params_t, prompts, t_cache,
                                                  lengths=lengths, **kw)
            last_hidden = None
        p_state = self.proposer.init_state(params, prompts, max_seq,
                                           lengths=lengths,
                                           last_hidden=last_hidden)
        key = key if key is not None else jax.random.PRNGKey(0)
        p = probs_from_logits(last_t, self.temperature)
        last_token = sample_from(p, key, self.temperature)
        return t_cache, p_state, last_token

    # -------------------------------------------------------------- generate
    def generate(
        self,
        params_t,
        params_p,
        prompts: jnp.ndarray,               # (B, T_prompt)
        max_new_tokens: int,
        *,
        gamma: Optional[int] = None,
        max_seq: Optional[int] = None,
        lengths=None,
        key: Optional[jax.Array] = None,
        prefill_kwargs: Optional[dict] = None,
        timed: bool = False,
    ) -> Tuple[np.ndarray, SDStats]:
        """Run SD rounds until every sequence has >= max_new_tokens."""
        B, Tp = prompts.shape
        gamma = self.gamma if gamma is None else gamma
        key = key if key is not None else jax.random.PRNGKey(0)
        if max_seq is None:
            max_seq = Tp + max_new_tokens + gamma + 2
        key, k_pre = jax.random.split(key)
        t_cache, p_state, last_token = self.prefill(
            params_t, params_p, prompts, max_seq, lengths=lengths, key=k_pre,
            prefill_kwargs=prefill_kwargs)
        params = {"target": params_t, "draft": params_p}

        out = np.zeros((B, max_new_tokens + gamma + 1), np.int32)
        n_out = np.zeros((B,), np.int32)
        # the first sampled token (from prefill) counts as generated
        out[:, 0] = np.asarray(last_token)
        n_out += 1

        stats = SDStats()
        pf_aware = getattr(self.proposer, "provides_prefetch", False)
        # prefetch-aware rounds always run staged: the warm gather must be
        # dispatched between the propose and verify launches (see
        # _staged_jits); timed mode additionally syncs per phase
        staged = timed or pf_aware
        round_fn = None if staged else self._round_fn(gamma)
        stages = self._staged_jits(gamma) if staged else None
        while int(n_out.min()) < max_new_tokens:
            key, k_prop, k_rej = jax.random.split(key, 3)
            t_round = time.perf_counter()
            if staged:
                j_prop, j_verify, j_fin, j_warm = stages
                base_len = t_cache["lengths"]
                t0 = time.perf_counter()
                drafts, q_dist, p_work = j_prop(params, p_state, last_token,
                                                k_prop)
                if timed:
                    jax.block_until_ready(drafts)
                    stats.propose_time += time.perf_counter() - t0
                if j_warm is not None:
                    # async dispatch, never blocked on: the gather of the
                    # predicted experts' weights runs ahead of verify on the
                    # device queue while the host assembles the verify call
                    t0 = time.perf_counter()
                    j_warm(params["target"], p_work["plan"])
                    if timed:
                        # timed-only, like the other phase stats (and like
                        # them the first round includes trace+compile)
                        stats.warm_time += time.perf_counter() - t0
                t0 = time.perf_counter()
                if pf_aware:
                    p_dist, hidden, pend, pf = j_verify(
                        params["target"], t_cache, last_token, drafts,
                        p_work["plan"])
                else:
                    p_dist, hidden, pend, pf = j_verify(
                        params["target"], t_cache, last_token, drafts)
                if timed:
                    jax.block_until_ready(p_dist)
                    stats.verify_time += time.perf_counter() - t0
                t0 = time.perf_counter()
                (t_cache, p_state, last_token, committed, n_commit, n_acc) = \
                    j_fin(params, pend, p_work, base_len, p_dist, q_dist,
                          drafts, hidden, last_token, k_rej)
                if timed:
                    jax.block_until_ready(committed)
                    stats.reject_time += time.perf_counter() - t0
            else:
                (t_cache, p_state, last_token, committed, n_commit, n_acc,
                 pf) = round_fn(params, t_cache, p_state, last_token, k_prop,
                                k_rej)
            committed = np.asarray(committed)        # device sync
            n_commit_np = np.asarray(n_commit)
            stats.round_time += time.perf_counter() - t_round
            if pf is not None:
                stats.prefetch_hits += int(np.asarray(pf["hits"]))
                stats.prefetch_actual += int(np.asarray(pf["actual"]))
                stats.prefetch_predicted += int(np.asarray(pf["predicted"]))
            for b in range(B):
                n = int(n_commit_np[b])
                w = min(n, out.shape[1] - n_out[b])
                out[b, n_out[b]: n_out[b] + w] = committed[b, :w]
                n_out[b] += w
            width = committed.shape[1]               # actual g + 1
            stats.rounds += 1
            stats.generated += int(n_commit_np.sum())
            # sigma is accounted against the REQUESTED gamma: a proposer
            # that drafts fewer than gamma tokens (degenerate "none" path)
            # honestly scores sigma = generated/(gamma+1), not 1.0
            stats.max_possible += (gamma + 1) * B
            stats.accept_events += int(np.asarray(n_acc))
            stats.draft_events += (width - 1) * B
        if pf_aware:
            self.prefetch_totals["hits"] += stats.prefetch_hits
            self.prefetch_totals["actual"] += stats.prefetch_actual
            self.prefetch_totals["predicted"] += stats.prefetch_predicted
            self.prefetch_totals["rounds"] += stats.rounds
        return out[:, :max_new_tokens], stats


# ---------------------------------------------------------------------------
# backwards-compatible entry points (pre-Proposer API)
# ---------------------------------------------------------------------------

class SpecDecoder(SDEngine):
    """Legacy shim: target + draft *model* pair == SDEngine("model").

    Prefer ``SDEngine(target, make_proposer("model", target, draft))``.
    """

    def __init__(self, target: Model, draft: Model, gamma: int = 4,
                 temperature: float = 0.0):
        super().__init__(
            target,
            make_proposer("model", target, draft, temperature=temperature),
            gamma=gamma, temperature=temperature)
        self.draft = draft


def _ar_session(model: Model, temperature: float) -> SDEngine:
    """AR generation reuses one persistent "none" session per
    (model, temperature) so repeated generate_ar calls don't re-jit the
    decode round.  Sessions hang off the model instance itself (not a
    global registry): they share its lifetime, so dropping the model
    releases the compiled rounds too."""
    per_model = getattr(model, "_ar_sessions", None)
    if per_model is None:
        per_model = model._ar_sessions = {}
    eng = per_model.get(temperature)
    if eng is None:
        eng = SDEngine(model,
                       make_proposer("none", model, temperature=temperature),
                       gamma=0, temperature=temperature)
        per_model[temperature] = eng
    return eng


def generate_ar(model: Model, params, prompts: jnp.ndarray,
                max_new_tokens: int, *, temperature: float = 0.0,
                lengths=None, key=None,
                prefill_kwargs: Optional[dict] = None) -> np.ndarray:
    """Plain autoregressive baseline (T_AR in the paper's speedup
    definition) — the gamma=0 / "none"-proposer path of SDEngine."""
    out, _ = _ar_session(model, temperature).generate(
        params, None, prompts, max_new_tokens, lengths=lengths, key=key,
        prefill_kwargs=prefill_kwargs)
    return out

"""Target efficiency — the paper's new systemic metric (Sec. 3.1).

    eta_target(B, gamma) = T_T(B, 1) / T_T(B, gamma)

It isolates how the TARGET model's architecture + workload shape SD
speedup, independent of the draft algorithm's acceptance rate.  Two ways to
obtain it here:

  * ``measure``   — wall-clock the target's extend() for T=1 vs T=gamma+1
                    on the current backend (CPU: qualitative trends only).
  * ``predict``   — evaluate the analytic TPU-v5e simulator / fitted perf
                    model (core/simulator.py, core/perf_model.py) — the
                    quantitative path used in benchmarks.
"""
from __future__ import annotations

import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.model import Model


def measure_extend_time(model: Model, params, cache, n_tokens: int,
                        iters: int = 5, warmup: int = 2) -> float:
    """Median wall-clock seconds of one extend() of ``n_tokens``/sequence.

    Runs against a copy of the cache (never commits), so repeated calls see
    identical state."""
    B = cache["lengths"].shape[0]
    tokens = jnp.zeros((B, n_tokens), jnp.int32)
    fn = jax.jit(lambda p, t, c: model.extend(p, t, c)[0])
    times = []
    for i in range(warmup + iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(params, tokens, cache))
        if i >= warmup:
            times.append(time.perf_counter() - t0)
    return float(np.median(times))


def measure_target_efficiency(model: Model, params, cache, gamma: int,
                              iters: int = 5) -> dict:
    t1 = measure_extend_time(model, params, cache, 1, iters)
    tg = measure_extend_time(model, params, cache, gamma + 1, iters)
    return {"T_T_1": t1, "T_T_gamma": tg, "target_efficiency": t1 / tg}


def predicted_target_efficiency(sim, arch_cfg, batch: int, gamma: int) -> dict:
    """Analytic target efficiency from the v5e simulator (core/simulator.py)."""
    t1 = sim.forward_time(arch_cfg, batch, 1)
    tg = sim.forward_time(arch_cfg, batch, gamma + 1)
    return {"T_T_1": t1, "T_T_gamma": tg, "target_efficiency": t1 / tg}

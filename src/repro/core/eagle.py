"""EAGLE-style speculation head (arXiv:2401.15077) — the paper's second SD
configuration (its Mixtral experiments use an Eagle head as the draft).

Instead of a standalone small model, the draft is a single transformer
block grafted onto the TARGET's feature stream:

    f̂_{t+1} = Block( W_fuse [ embed(x_{t+1}) ; f_t ] )
    p̂(x_{t+2}) = TargetHead( f̂_{t+1} )

where f_t is the target's final hidden state at the last verified position.
During a propose chain the block feeds on its own predicted features
(EAGLE's autoregressive feature prediction); verification refreshes f from
the real target features, which is why acceptance stays high.

The head reuses the target's embedding and unembedding — its own params are
one fusion matrix + one block (~2 target layers' worth), matching the
paper's T_D/T_T ≪ 1 requirement.

``EagleProposer`` plugs the head into the generic SD round
(core/spec_decode.SDEngine) through the Proposer protocol: it declares
``needs_hidden`` so the engine's verify pass hands it the target's hidden
states, from which ``commit`` refreshes the feature carry.  Greedy
losslessness is preserved by construction and tested.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.proposer import register_proposer, stack_drafts
from repro.core.rejection import probs_from_logits, sample_from
from repro.core.spec_decode import SDEngine
from repro.models import transformer as tfm
from repro.models.layers import dense_init
from repro.models.model import Model


class EagleHead:
    """One-block speculation head bound to a target Model."""

    def __init__(self, target: Model):
        self.target = target
        cfg = target.cfg
        # the head's block is a plain dense-FFN attention block in the
        # target's hidden size (no MoE — drafts are dense, paper Sec. 3.3)
        self.cfg = cfg.with_overrides(
            name=f"{cfg.name}-eagle", num_layers=1, layer_pattern=("attn",),
            moe_pattern=(False,), num_experts=0, num_experts_per_tok=0,
            d_ff=4 * cfg.d_model,
            num_heads=max(4, cfg.num_heads // 4),
            num_kv_heads=max(2, cfg.num_kv_heads // 4),
            head_dim=64)

    def init(self, key: jax.Array) -> dict:
        cfg = self.cfg
        dt = jnp.dtype(cfg.dtype)
        k1, k2 = jax.random.split(key)
        return {
            "fuse": dense_init(k1, (2 * cfg.d_model, cfg.d_model), dt),
            "layers": tfm.init_stack(k2, cfg, dt),
        }

    def init_cache(self, batch: int, max_seq: int) -> dict:
        return {
            "layers": tfm.make_stack_cache(self.cfg, batch, max_seq,
                                           jnp.dtype(self.cfg.dtype)),
            "lengths": jnp.zeros((batch,), jnp.int32),
        }

    # ------------------------------------------------------------------ step
    def step(self, params_target, params, feat: jnp.ndarray,
             token: jnp.ndarray, cache: dict):
        """One propose step: (feature (B,d) at pos-1, token (B,) at pos) →
        (next-token logits (B,V), predicted next feature (B,d), cache)."""
        tgt = self.target
        emb = tgt._embed(params_target, token[:, None], cache["lengths"][:, None])
        x = jnp.concatenate([emb[:, 0], feat.astype(emb.dtype)], axis=-1)
        x = (x @ params["fuse"])[:, None]                   # (B, 1, d)
        positions = cache["lengths"][:, None]
        x, new_layers, _ = tfm.stack_forward(
            params["layers"], self.cfg, x, positions, cache["layers"],
            mode="extend")
        new_cache = dict(cache, layers=new_layers,
                         lengths=cache["lengths"] + 1)
        logits = tgt._head(params_target, x)[:, 0]          # tied target head
        return logits, x[:, 0], new_cache


@register_proposer("eagle")
class EagleProposer:
    """Proposer that chains an EagleHead on its own predicted features.

    State: ``{"cache": head_kv_cache, "feat": (B, d) feature carry}``; the
    carry is initialised from the target prefill's last hidden state and
    refreshed each round from the verify pass (``needs_hidden``).
    """

    kind = "eagle"
    needs_hidden = True

    def __init__(self, target: Model, draft: Optional[EagleHead] = None,
                 temperature: float = 0.0):
        assert not target.cfg.is_recurrent, \
            "Eagle feature-carry assumes attention targets"
        if draft is not None and not isinstance(draft, EagleHead):
            raise TypeError("EagleProposer draft must be an EagleHead "
                            f"(got {type(draft).__name__})")
        self.target = target
        self.head = draft if draft is not None else EagleHead(target)
        self.temperature = temperature

    def init_state(self, params, prompts, max_seq, *, lengths=None,
                   last_hidden=None):
        B, T = prompts.shape
        if lengths is None:
            lengths = jnp.full((B,), T, jnp.int32)
        cache = self.head.init_cache(B, max_seq)
        cache = dict(cache, lengths=lengths.astype(jnp.int32))
        return {"cache": cache, "feat": last_hidden}

    def propose(self, params, state, last_token, gamma, key):
        feat, token, ec = state["feat"], last_token, state["cache"]
        qs, ds = [], []
        for _ in range(gamma):
            logits, feat, ec = self.head.step(params["target"],
                                              params["draft"], feat, token, ec)
            key, ks = jax.random.split(key)
            q = probs_from_logits(logits, self.temperature)
            token = sample_from(q, ks, self.temperature)
            qs.append(q)
            ds.append(token)
        drafts, q_dist = stack_drafts(ds, qs, last_token.shape[0],
                                      self.target.cfg.vocab_size)
        return drafts, q_dist, {"cache": ec, "feat": state["feat"]}

    def commit(self, params, state, *, base_len, n_accept, n_commit,
               verify_tokens, hidden):
        # eagle cache is attention-only → lengths rollback; feature carry
        # refreshes to the hidden state of the LAST VERIFIED committed token
        cache = dict(state["cache"], lengths=base_len + n_commit)
        feat = jnp.take_along_axis(
            hidden, n_accept[:, None, None].astype(jnp.int32), axis=1)[:, 0]
        return {"cache": cache, "feat": feat}

    def merge_state(self, old, new, mask):
        """Admission merge: head KV cache rows + per-row feature carry."""
        from repro.models.model import merge_cache_rows
        return {"cache": merge_cache_rows(old["cache"], new["cache"], mask),
                "feat": jnp.where(mask[:, None], new["feat"], old["feat"])}

    def scatter_state(self, old, new, rows, *, valid=None):
        """Sliced admission: scatter head KV rows + feature carry."""
        from repro.models.model import scatter_cache_rows
        rows = jnp.asarray(rows, jnp.int32)
        B = old["feat"].shape[0]
        valid = (jnp.ones(rows.shape, bool) if valid is None
                 else jnp.asarray(valid, bool))
        rows_eff = jnp.where(valid, rows, B)
        return {"cache": scatter_cache_rows(old["cache"], new["cache"],
                                            rows, valid=valid),
                "feat": old["feat"].at[rows_eff].set(new["feat"],
                                                     mode="drop")}

    def grow_state(self, state, new_max_seq):
        """Pad the head's KV cache on session growth (feat has no seq axis)."""
        from repro.models.model import grow_cache_seq
        return {"cache": grow_cache_seq(state["cache"], self.head.cfg,
                                        new_max_seq),
                "feat": state["feat"]}


class EagleSpecDecoder(SDEngine):
    """Legacy shim: target + EagleHead == SDEngine("eagle").

    Prefer ``SDEngine(target, make_proposer("eagle", target, head))``.
    """

    def __init__(self, target: Model, head: EagleHead, gamma: int = 4,
                 temperature: float = 0.0):
        super().__init__(target,
                         EagleProposer(target, head, temperature=temperature),
                         gamma=gamma, temperature=temperature)
        self.head = head

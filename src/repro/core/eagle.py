"""EAGLE-style speculation head (arXiv:2401.15077) — the paper's second SD
configuration (its Mixtral experiments use an Eagle head as the draft).

Instead of a standalone small model, the draft is a single transformer
block grafted onto the TARGET's feature stream:

    f̂_{t+1} = Block( W_fuse [ embed(x_{t+1}) ; f_t ] )
    p̂(x_{t+2}) = TargetHead( f̂_{t+1} )

where f_t is the target's final hidden state at the last verified position.
During a propose chain the block feeds on its own predicted features
(EAGLE's autoregressive feature prediction); verification refreshes f from
the real target features, which is why acceptance stays high.

The head reuses the target's embedding and unembedding — its own params are
one fusion matrix + one block (~2 target layers' worth), matching the
paper's T_D/T_T ≪ 1 requirement.

``EagleSpecDecoder`` mirrors core/spec_decode.SpecDecoder (same rejection
sampling, same cache discipline) with the feature-carry threaded through
rounds; greedy losslessness is preserved by construction and tested.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.rejection import probs_from_logits, rejection_sample, sample_from
from repro.core.spec_decode import SDStats
from repro.models import transformer as tfm
from repro.models.layers import dense_init
from repro.models.model import Model


class EagleHead:
    """One-block speculation head bound to a target Model."""

    def __init__(self, target: Model):
        self.target = target
        cfg = target.cfg
        # the head's block is a plain dense-FFN attention block in the
        # target's hidden size (no MoE — drafts are dense, paper Sec. 3.3)
        self.cfg = cfg.with_overrides(
            name=f"{cfg.name}-eagle", num_layers=1, layer_pattern=("attn",),
            moe_pattern=(False,), num_experts=0, num_experts_per_tok=0,
            d_ff=4 * cfg.d_model,
            num_heads=max(4, cfg.num_heads // 4),
            num_kv_heads=max(2, cfg.num_kv_heads // 4),
            head_dim=64)

    def init(self, key: jax.Array) -> dict:
        cfg = self.cfg
        dt = jnp.dtype(cfg.dtype)
        k1, k2 = jax.random.split(key)
        return {
            "fuse": dense_init(k1, (2 * cfg.d_model, cfg.d_model), dt),
            "layers": tfm.init_stack(k2, cfg, dt),
        }

    def init_cache(self, batch: int, max_seq: int) -> dict:
        return {
            "layers": tfm.make_stack_cache(self.cfg, batch, max_seq,
                                           jnp.dtype(self.cfg.dtype)),
            "lengths": jnp.zeros((batch,), jnp.int32),
        }

    # ------------------------------------------------------------------ step
    def step(self, params_target, params, feat: jnp.ndarray,
             token: jnp.ndarray, cache: dict):
        """One propose step: (feature (B,d) at pos-1, token (B,) at pos) →
        (next-token logits (B,V), predicted next feature (B,d), cache)."""
        tgt = self.target
        emb = tgt._embed(params_target, token[:, None], cache["lengths"][:, None])
        x = jnp.concatenate([emb[:, 0], feat.astype(emb.dtype)], axis=-1)
        x = (x @ params["fuse"])[:, None]                   # (B, 1, d)
        positions = cache["lengths"][:, None]
        x, new_layers, _ = tfm.stack_forward(
            params["layers"], self.cfg, x, positions, cache["layers"],
            mode="extend")
        new_cache = dict(cache, layers=new_layers,
                         lengths=cache["lengths"] + 1)
        logits = tgt._head(params_target, x)[:, 0]          # tied target head
        return logits, x[:, 0], new_cache

    # ----------------------------------------------------------- prefill feat
    def prefill(self, params_target, params, prompts, max_seq, *,
                lengths=None):
        """Prefill the target AND capture its last hidden feature."""
        tgt = self.target
        B, T = prompts.shape
        if lengths is None:
            lengths = jnp.full((B,), T, jnp.int32)
        t_cache = tgt.init_cache(B, max_seq)
        # run prefill via extend_with_hidden from an empty cache
        logits, hidden, t_cache = tgt.extend_with_hidden(
            params_target, prompts, t_cache, collect=True)
        t_cache = tgt.commit(t_cache, lengths, collected=True)
        last_h = jnp.take_along_axis(
            hidden, (lengths - 1)[:, None, None].astype(jnp.int32), axis=1)[:, 0]
        last_logits = jnp.take_along_axis(
            logits, (lengths - 1)[:, None, None].astype(jnp.int32), axis=1)[:, 0]
        e_cache = self.init_cache(B, max_seq)
        e_cache = dict(e_cache, lengths=lengths.astype(jnp.int32))
        return last_logits, last_h, t_cache, e_cache


class EagleSpecDecoder:
    """SpecDecoder with an EagleHead draft (feature-carry across rounds)."""

    def __init__(self, target: Model, head: EagleHead, gamma: int = 4,
                 temperature: float = 0.0):
        assert not target.cfg.is_recurrent, \
            "Eagle feature-carry assumes attention targets"
        self.target, self.head = target, head
        self.gamma, self.temperature = gamma, temperature
        self._round_jit = jax.jit(self._round)

    def _round(self, params_t, params_e, t_cache, e_cache, last_token,
               last_feat, key):
        gamma = self.gamma
        B = last_token.shape[0]
        key, k_rej = jax.random.split(key)
        base_len = t_cache["lengths"]

        # PROPOSE: chain the head on its own predicted features
        feat, token = last_feat, last_token
        ec = e_cache
        qs, ds = [], []
        for i in range(gamma):
            logits, feat, ec = self.head.step(params_t, params_e, feat,
                                              token, ec)
            key, ks = jax.random.split(key)
            q = probs_from_logits(logits, self.temperature)
            token = sample_from(q, ks, self.temperature)
            qs.append(q)
            ds.append(token)
        drafts = jnp.stack(ds, 1)
        q_dist = jnp.stack(qs, 1)

        # VERIFY (with hidden capture)
        verify_tokens = jnp.concatenate([last_token[:, None], drafts], 1)
        logits_v, hidden_v, pend = self.target.extend_with_hidden(
            params_t, verify_tokens, t_cache, collect=True)
        p_dist = probs_from_logits(logits_v, self.temperature)

        n_accept, next_token, _ = rejection_sample(
            p_dist, q_dist, drafts, k_rej, self.temperature)
        n_commit = n_accept + 1
        t_cache = self.target.commit(pend, n_commit, collected=True)
        # eagle cache: attention-only → lengths rollback
        e_cache = dict(ec, lengths=base_len + n_commit)
        # feature of the LAST VERIFIED committed token = hidden at index n
        new_feat = jnp.take_along_axis(
            hidden_v, n_accept[:, None, None].astype(jnp.int32), axis=1)[:, 0]

        slot = jnp.arange(gamma + 1)[None, :]
        drafts_pad = jnp.concatenate([drafts, jnp.zeros((B, 1), drafts.dtype)], 1)
        committed = jnp.where(slot < n_accept[:, None], drafts_pad,
                              next_token[:, None])
        return (t_cache, e_cache, next_token, new_feat, committed, n_commit,
                jnp.sum(n_accept), key)

    def generate(self, params_t, params_e, prompts, max_new_tokens, *,
                 lengths=None, key=None) -> Tuple[np.ndarray, SDStats]:
        B, Tp = prompts.shape
        gamma = self.gamma
        key = key if key is not None else jax.random.PRNGKey(0)
        max_seq = Tp + max_new_tokens + gamma + 2
        last_logits, feat, t_cache, e_cache = self.head.prefill(
            params_t, params_e, prompts, max_seq, lengths=lengths)
        key, k0 = jax.random.split(key)
        last_token = sample_from(probs_from_logits(last_logits,
                                                   self.temperature), k0,
                                 self.temperature)
        out = np.zeros((B, max_new_tokens + gamma + 1), np.int32)
        out[:, 0] = np.asarray(last_token)
        n_out = np.ones((B,), np.int32)
        stats = SDStats()
        while int(n_out.min()) < max_new_tokens:
            (t_cache, e_cache, last_token, feat, committed, n_commit, n_acc,
             key) = self._round_jit(params_t, params_e, t_cache, e_cache,
                                    last_token, feat, key)
            committed = np.asarray(committed)
            ncn = np.asarray(n_commit)
            for b in range(B):
                n = int(ncn[b])
                w = min(n, out.shape[1] - n_out[b])
                out[b, n_out[b]: n_out[b] + w] = committed[b, :w]
                n_out[b] += w
            stats.rounds += 1
            stats.generated += int(ncn.sum())
            stats.max_possible += (gamma + 1) * B
            stats.accept_events += int(np.asarray(n_acc))
            stats.draft_events += gamma * B
        return out[:, :max_new_tokens], stats

"""Heterogeneous decoder block + scan-over-periods stack.

A *block* = mixer sublayer (attention or recurrent) + optional FFN sublayer
(dense or MoE) with pre-norms and residuals.  One *period* of blocks
(``cfg.layer_pattern``) is the scan unit: params/caches carry a leading
``num_periods`` axis, keeping HLO size O(period) instead of O(num_layers) —
essential for 62-layer models compiled against 512 host devices.
"""
from __future__ import annotations

from functools import partial
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.distributed.constraints import constrain
from repro.models import attention as attn
from repro.models import moe as moe_mod
from repro.models import ssm as ssm_mod
from repro.models.layers import apply_mlp, apply_norm, init_mlp, init_norm

ATTN_KINDS = ("attn", "swa", "mla")
RECURRENT_KINDS = ("mamba", "mlstm", "slstm")


def _has_ffn(cfg, kind: str, is_moe: bool) -> bool:
    if kind in ("mlstm", "slstm"):
        return False  # xLSTM blocks are self-contained
    return is_moe or cfg.d_ff > 0


# ---------------------------------------------------------------------------
# single block
# ---------------------------------------------------------------------------

def init_block(key, cfg, kind: str, is_moe: bool, dtype, cross: bool = False) -> dict:
    ks = jax.random.split(key, 6)
    p: Dict[str, Any] = {"norm1": init_norm(cfg, dtype)}
    if kind in ("attn", "swa"):
        p["mixer"] = attn.init_gqa(ks[0], cfg, dtype)
    elif kind == "mla":
        p["mixer"] = attn.init_mla(ks[0], cfg, dtype)
    else:
        p["mixer"] = ssm_mod.INIT[kind](ks[0], cfg, dtype)
    if cross:
        p["cross_norm"] = init_norm(cfg, dtype)
        p["cross"] = attn.init_cross_attn(ks[1], cfg, dtype)
    if _has_ffn(cfg, kind, is_moe):
        p["norm2"] = init_norm(cfg, dtype)
        if is_moe:
            p["ffn"] = moe_mod.init_moe(ks[2], cfg, dtype)
        else:
            p["ffn"] = init_mlp(ks[2], cfg.d_model, cfg.d_ff, dtype)
    return p


def make_block_cache(cfg, kind: str, batch: int, max_seq: int, dtype, *,
                     paged: bool = False, page_size: int = 64,
                     pool_pages: Optional[int] = None) -> dict:
    if kind in ATTN_KINDS:
        return attn.make_attn_cache(cfg, batch, max_seq, kind, dtype,
                                    paged=paged, page_size=page_size,
                                    pool_pages=pool_pages)
    return ssm_mod.MAKE_STATE[kind](cfg, batch, dtype)


def block_forward(
    params: dict,
    cfg,
    kind: str,
    is_moe: bool,
    x: jnp.ndarray,
    positions: jnp.ndarray,
    cache: Optional[dict],
    *,
    mode: str,                      # train | prefill | extend
    collect: bool = False,
    causal: bool = True,
    dispatch: str = "onehot",
    want_metrics: bool = True,
    use_flash: bool = False,
    cross_kv: Optional[dict] = None,
    mrope_positions=None,
    prefetch_mask: Optional[jnp.ndarray] = None,
    page_table: Optional[jnp.ndarray] = None,
    paged_attention: str = "kernel",
    mesh=None,
    mesh_layout: Optional[str] = None,
) -> Tuple[jnp.ndarray, Optional[dict], dict]:
    h = apply_norm(params["norm1"], x, cfg.norm_eps)
    if kind in ("attn", "swa"):
        out, new_cache = attn.gqa_forward(
            params["mixer"], cfg, h, positions, kind=kind, cache=cache,
            mode=mode, mrope_positions=mrope_positions, use_flash=use_flash,
            causal=causal, page_table=page_table,
            paged_attention=paged_attention)
    elif kind == "mla":
        out, new_cache = attn.mla_forward(
            params["mixer"], cfg, h, positions, cache=cache, mode=mode,
            page_table=page_table)
    else:
        state = cache if cache is not None else ssm_mod.MAKE_STATE[kind](
            cfg, x.shape[0], x.dtype)
        out, new_cache = ssm_mod.FORWARD[kind](
            params["mixer"], cfg, h, state, collect_states=(mode == "extend" and collect))
        if mode == "train":
            new_cache = None
    x = x + out

    if "cross" in params and cross_kv is not None:
        h = apply_norm(params["cross_norm"], x, cfg.norm_eps)
        x = x + attn.cross_attn_forward(params["cross"], cfg, h, cross_kv)

    # zero placeholders keep the metrics pytree uniform across layers for the
    # scan aggregation even when metric computation is skipped
    metrics = {"aux_loss": jnp.zeros((), jnp.float32),
               "expert_counts": jnp.zeros((max(cfg.num_experts, 1),), jnp.int32),
               "prefetch_hits": jnp.zeros((), jnp.int32),
               "prefetch_actual": jnp.zeros((), jnp.int32),
               "prefetch_predicted": jnp.zeros((), jnp.int32)}
    if "ffn" in params:
        h = apply_norm(params["norm2"], x, cfg.norm_eps)
        if is_moe:
            # want_metrics=False (decode/verify) skips the (N, K, E) one-hot
            # aux-loss/expert-count tensors entirely — the router still runs
            # (routing needs it) but no metric materialization happens
            y, m = moe_mod.moe_forward(params["ffn"], cfg, h, dispatch=dispatch,
                                       return_metrics=want_metrics,
                                       prefetch_mask=prefetch_mask,
                                       mesh=mesh, mesh_layout=mesh_layout)
            if want_metrics:
                metrics["aux_loss"] = m["aux_loss"]
                metrics["expert_counts"] = m["expert_counts"]
            if prefetch_mask is not None:
                for k in ("prefetch_hits", "prefetch_actual",
                          "prefetch_predicted"):
                    metrics[k] = m[k]
        else:
            y = apply_mlp(params["ffn"], h, cfg.mlp_activation)
        x = x + y
    return x, new_cache, metrics


# ---------------------------------------------------------------------------
# stacked decoder (scan over periods)
# ---------------------------------------------------------------------------

def init_stack(key, cfg, dtype, cross: bool = False) -> List[dict]:
    """Returns a list (len=period) of per-slot params, leaves stacked over
    the ``num_periods`` axis."""
    P = cfg.num_periods
    out = []
    for i, (kind, is_moe) in enumerate(zip(cfg.layer_pattern, cfg.moe_pattern)):
        keys = jax.random.split(jax.random.fold_in(key, i), P)
        slot = jax.vmap(lambda k: init_block(k, cfg, kind, is_moe, dtype, cross))(keys)
        out.append(slot)
    return out


def make_stack_cache(cfg, batch: int, max_seq: int, dtype, *,
                     paged: bool = False, page_size: int = 64,
                     pool_pages: Optional[int] = None) -> List[dict]:
    P = cfg.num_periods
    if paged and pool_pages is None:
        pool_pages = batch * (-(-max_seq // page_size)) + 1   # + trash page
    out = []
    for kind in cfg.layer_pattern:
        c = make_block_cache(cfg, kind, batch, max_seq, dtype, paged=paged,
                             page_size=page_size, pool_pages=pool_pages)
        out.append(jax.tree.map(lambda a: jnp.broadcast_to(a, (P,) + a.shape), c))
    return out


def stack_forward(
    layer_params: List[dict],
    cfg,
    x: jnp.ndarray,
    positions: jnp.ndarray,
    caches: Optional[List[dict]],
    *,
    mode: str,
    collect: bool = False,
    causal: bool = True,
    dispatch: str = "onehot",
    want_metrics: bool = True,
    use_flash: bool = False,
    remat: bool = False,
    cross_kvs: Optional[List[dict]] = None,
    mrope_positions=None,
    prefetch_masks: Optional[List[jnp.ndarray]] = None,
    page_table: Optional[jnp.ndarray] = None,
    paged_attention: str = "kernel",
    mesh=None,
    mesh_layout: Optional[str] = None,
) -> Tuple[jnp.ndarray, Optional[List[dict]], dict]:
    """Run the full stack.  caches/cross_kvs leaves carry leading (P, ...).

    ``want_metrics=False`` (the serving decode/verify path) skips router
    aux-loss/expert-count materialization; the returned metrics are zeros.

    ``prefetch_masks`` (optional) is a per-period-slot list of ``(P, E)``
    predicted-hot expert masks (models/moe.PrefetchPlan.masks); when given,
    the returned metrics include ``prefetch_hits/actual/predicted`` counts
    summed over all MoE layers.

    ``page_table`` (optional) is the (B, max_pages) logical→physical block
    table of a paged cache (models/model.py) — shared by every paged
    attention slot, carried as a scan closure constant.

    ``paged_attention`` selects the paged extend backend: "kernel" walks the
    block table inside the Pallas decode kernel; "gather" materializes the
    dense ``pool[table]`` view (the pre-kernel behaviour, kept as fallback).

    ``mesh``/``mesh_layout`` (optional) thread the device mesh down to the
    sharding constraints and the expert-parallel dispatch
    (docs/distributed.md) — no process-global mesh state.
    """

    def make_block(i, kind, is_moe):
        def blk(lp_i, h, lc_i, lx_i, lm_i):
            return block_forward(
                lp_i, cfg, kind, is_moe, h, positions, lc_i,
                mode=mode, collect=collect, causal=causal, dispatch=dispatch,
                want_metrics=want_metrics, use_flash=use_flash, cross_kv=lx_i,
                mrope_positions=mrope_positions, prefetch_mask=lm_i,
                page_table=page_table, paged_attention=paged_attention,
                mesh=mesh, mesh_layout=mesh_layout)
        # per-LAYER rematerialization: checkpointing the whole period keeps
        # every layer's FFN/attention intermediates live during the period's
        # backward (107 GB/device on jamba train_4k — §Perf C4); per-layer
        # checkpoints bound the live set to one layer.
        return jax.checkpoint(blk) if remat else blk

    blocks = [make_block(i, kind, is_moe)
              for i, (kind, is_moe)
              in enumerate(zip(cfg.layer_pattern, cfg.moe_pattern))]

    def period_fn(h, scanned):
        lp, lc, lx, lm = scanned
        new_caches = []
        agg = None
        for i in range(cfg.period):
            h, nc, m = blocks[i](
                lp[i], h,
                None if lc is None else lc[i],
                None if lx is None else lx[i],
                None if lm is None else lm[i])
            new_caches.append(nc if nc is not None else {})
            agg = m if agg is None else jax.tree.map(jnp.add, agg, m)
        return constrain(h, "hidden", mesh=mesh, layout=mesh_layout), \
            (new_caches, agg)

    xs = (layer_params, caches, cross_kvs, prefetch_masks)

    def scan_body(h, scanned):
        return period_fn(h, scanned)

    x, (new_caches, metrics) = jax.lax.scan(scan_body, x, xs)
    metrics = jax.tree.map(lambda a: jnp.sum(a, axis=0), metrics)
    if caches is None:
        return x, None, metrics
    return x, new_caches, metrics

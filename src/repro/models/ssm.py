"""Recurrent blocks: Mamba selective SSM (Jamba) and xLSTM (mLSTM / sLSTM).

All blocks share the interface

    forward(params, cfg, x, state, collect_states=False)
        -> (y, final_state) or (y, stacked_states)

where ``x`` is (B, T, d) processed sequentially from ``state``.  With
``collect_states=True`` every per-step state is returned with a leading time
axis (T, B, ...) — the speculative-decoding engine gathers the state at the
last *accepted* position instead of rolling back (recurrent states cannot be
rolled back in place; see DESIGN.md §5).

Recurrent states:
  mamba  {"conv": (B, c-1, d_in), "ssm": (B, d_in, n_state)}
  mlstm  {"conv": (B, c-1, d_in), "C": (B, H, hd, hd), "n": (B, H, hd), "m": (B, H)}
  slstm  {"c": (B, d), "n": (B, d), "m": (B, d), "h": (B, d)}
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.layers import dense_init

CONV_K = 4  # causal conv kernel size (mamba / mlstm)

# Backward through a T-step recurrent scan saves the carry at every step —
# O(T x state) residuals (the 1.5 TB/device xlstm train_4k baseline in
# EXPERIMENTS.md §Perf).  Chunking the time axis and jax.checkpoint-ing each
# chunk keeps only T/SCAN_CHUNK checkpoints and recomputes inside chunks.
SCAN_CHUNK = 256


def _scan_time(step, carry0, xs, collect: bool):
    """lax.scan over time with chunked rematerialization.

    xs: pytree with leading T axis.  With ``collect`` (SD verify: tiny T,
    needs per-step states) or non-divisible T, falls back to a plain scan."""
    T = jax.tree.leaves(xs)[0].shape[0]
    if collect or T <= SCAN_CHUNK or T % SCAN_CHUNK != 0:
        return jax.lax.scan(step, carry0, xs)
    n = T // SCAN_CHUNK

    def reshape(a):
        return a.reshape((n, SCAN_CHUNK) + a.shape[1:])

    @jax.checkpoint
    def outer(carry, xc):
        return jax.lax.scan(step, carry, xc)

    carry, ys = jax.lax.scan(outer, carry0, jax.tree.map(reshape, xs))
    ys = jax.tree.map(
        lambda a: a.reshape((T,) + a.shape[2:]) if a is not None else None, ys)
    return carry, ys


# ---------------------------------------------------------------------------
# Mamba (selective SSM, arXiv:2312.00752 as used by Jamba arXiv:2403.19887)
# ---------------------------------------------------------------------------

def _dt_rank(cfg) -> int:
    return cfg.ssm_dt_rank or -(-cfg.d_model // 16)


def init_mamba(key, cfg, dtype) -> dict:
    d = cfg.d_model
    d_in = cfg.ssm_expand * d
    n = cfg.ssm_state_dim
    r = _dt_rank(cfg)
    ks = jax.random.split(key, 6)
    A = jnp.broadcast_to(jnp.arange(1, n + 1, dtype=jnp.float32), (d_in, n))
    return {
        "w_in": dense_init(ks[0], (d, 2 * d_in), dtype),
        "conv_w": dense_init(ks[1], (CONV_K, d_in), dtype, scale=1.0),
        "conv_b": jnp.zeros((d_in,), dtype),
        "w_xdbc": dense_init(ks[2], (d_in, r + 2 * n), dtype),
        "w_dt": dense_init(ks[3], (r, d_in), dtype),
        "dt_bias": jnp.full((d_in,), -4.6, jnp.float32),  # softplus^-1(0.01)
        "A_log": jnp.log(A),
        "D": jnp.ones((d_in,), jnp.float32),
        "w_out": dense_init(ks[4], (d_in, d), dtype),
    }


def make_mamba_state(cfg, batch: int, dtype) -> dict:
    d_in = cfg.ssm_expand * cfg.d_model
    return {
        "conv": jnp.zeros((batch, CONV_K - 1, d_in), dtype),
        "ssm": jnp.zeros((batch, d_in, cfg.ssm_state_dim), jnp.float32),
    }


def mamba_forward(params, cfg, x, state, collect_states: bool = False):
    B, T, d = x.shape
    d_in = cfg.ssm_expand * d
    n = cfg.ssm_state_dim
    r = _dt_rank(cfg)

    xz = x @ params["w_in"]
    xb, z = jnp.split(xz, 2, axis=-1)                       # (B,T,d_in) each

    # causal depthwise conv with carried state
    conv_in = jnp.concatenate([state["conv"].astype(xb.dtype), xb], axis=1)  # (B,T+K-1,d_in)
    idx = jnp.arange(T)[:, None] + jnp.arange(CONV_K)[None, :]               # (T,K)
    windows = conv_in[:, idx, :]                            # (B,T,K,d_in)
    xc = jnp.einsum("btkd,kd->btd", windows, params["conv_w"]) + params["conv_b"]
    xc = jax.nn.silu(xc)
    new_conv = conv_in[:, T:, :]  # last K-1 inputs, any T

    dbc = xc @ params["w_xdbc"]
    dt = jax.nn.softplus(dbc[..., :r] @ params["w_dt"] + params["dt_bias"])  # (B,T,d_in)
    Bmat = dbc[..., r : r + n].astype(jnp.float32)           # (B,T,n)
    Cmat = dbc[..., r + n :].astype(jnp.float32)             # (B,T,n)
    A = -jnp.exp(params["A_log"])                            # (d_in,n)

    def step(h, inputs):
        # decay/drive computed per step IN f32 from half-precision inputs:
        # materializing them for the whole sequence costs
        # (B, T, d_in, n_state) f32 — 137 GB/device on jamba train_4k; and
        # keeping the scan inputs in model dtype (not f32) halves the
        # backward residuals again (EXPERIMENTS.md §Perf C2/C3).
        dt_t, B_t, C_t, x_t = inputs                         # (B,d_in)/(B,n)
        dt_f = dt_t.astype(jnp.float32)
        dec_t = jnp.exp(dt_f[..., None] * A)                 # (B,d_in,n)
        drv_t = (dt_f * x_t.astype(jnp.float32))[..., None] \
            * B_t.astype(jnp.float32)[:, None, :]
        h = dec_t * h + drv_t                                # (B,d_in,n)
        y = jnp.einsum("bdn,bn->bd", h, C_t.astype(jnp.float32))
        return h, (y, h) if collect_states else (y, None)

    md = x.dtype
    (h_final, ys_states) = _scan_time(
        step, state["ssm"],
        (dt.astype(md).transpose(1, 0, 2), Bmat.astype(md).transpose(1, 0, 2),
         Cmat.astype(md).transpose(1, 0, 2), xc.transpose(1, 0, 2)),
        collect_states,
    )
    ys, hs = ys_states
    y = ys.transpose(1, 0, 2)                                # (B,T,d_in)
    y = y + params["D"] * xc.astype(jnp.float32)
    y = y.astype(x.dtype) * jax.nn.silu(z)
    out = y @ params["w_out"]

    if collect_states:
        # stacked conv states: state after consuming tokens 0..t
        conv_hist = jnp.stack(
            [jax.lax.dynamic_slice_in_dim(conv_in, t + 1, CONV_K - 1, axis=1)
             for t in range(T)], axis=0)                      # (T,B,K-1,d_in)
        return out, {"conv": conv_hist, "ssm": hs}            # hs: (T,B,d_in,n)
    return out, {"conv": new_conv.astype(state["conv"].dtype), "ssm": h_final}


# ---------------------------------------------------------------------------
# mLSTM (xLSTM matrix memory, arXiv:2405.04517)
# ---------------------------------------------------------------------------

def init_mlstm(key, cfg, dtype) -> dict:
    d = cfg.d_model
    d_in = 2 * d
    H = cfg.num_heads
    ks = jax.random.split(key, 8)
    return {
        "w_up": dense_init(ks[0], (d, 2 * d_in), dtype),
        "conv_w": dense_init(ks[1], (CONV_K, d_in), dtype),
        "conv_b": jnp.zeros((d_in,), dtype),
        "wq": dense_init(ks[2], (d_in, d_in), dtype),
        "wk": dense_init(ks[3], (d_in, d_in), dtype),
        "wv": dense_init(ks[4], (d_in, d_in), dtype),
        "w_i": dense_init(ks[5], (d_in, H), jnp.float32),
        "w_f": dense_init(ks[6], (d_in, H), jnp.float32),
        "f_bias": jnp.full((H,), 3.0, jnp.float32),   # forget ~1 at init
        "i_bias": jnp.zeros((H,), jnp.float32),
        "w_down": dense_init(ks[7], (d_in, d), dtype),
    }


def make_mlstm_state(cfg, batch: int, dtype) -> dict:
    d_in = 2 * cfg.d_model
    H = cfg.num_heads
    hd = d_in // H
    return {
        "conv": jnp.zeros((batch, CONV_K - 1, d_in), dtype),
        "C": jnp.zeros((batch, H, hd, hd), jnp.float32),
        "n": jnp.zeros((batch, H, hd), jnp.float32),
        "m": jnp.zeros((batch, H), jnp.float32),
    }


def mlstm_forward(params, cfg, x, state, collect_states: bool = False):
    B, T, d = x.shape
    d_in = 2 * d
    H = cfg.num_heads
    hd = d_in // H

    up = x @ params["w_up"]
    xb, z = jnp.split(up, 2, axis=-1)

    conv_in = jnp.concatenate([state["conv"].astype(xb.dtype), xb], axis=1)
    idx = jnp.arange(T)[:, None] + jnp.arange(CONV_K)[None, :]
    windows = conv_in[:, idx, :]
    xc = jax.nn.silu(
        jnp.einsum("btkd,kd->btd", windows, params["conv_w"]) + params["conv_b"]
    )

    q = (xc @ params["wq"]).reshape(B, T, H, hd).astype(jnp.float32)
    k = (xc @ params["wk"]).reshape(B, T, H, hd).astype(jnp.float32) / jnp.sqrt(hd)
    v = (xc @ params["wv"]).reshape(B, T, H, hd).astype(jnp.float32)
    i_raw = xc.astype(jnp.float32) @ params["w_i"] + params["i_bias"]   # (B,T,H)
    f_raw = xc.astype(jnp.float32) @ params["w_f"] + params["f_bias"]
    f_log = jax.nn.log_sigmoid(f_raw)

    def step(carry, inputs):
        C, n_s, m = carry
        q_t, k_t, v_t, i_t, f_t = inputs                    # (B,H,hd) / (B,H)
        m_new = jnp.maximum(f_t + m, i_t)
        i_p = jnp.exp(i_t - m_new)[..., None]               # (B,H,1)
        f_p = jnp.exp(f_t + m - m_new)[..., None]
        C = f_p[..., None] * C + i_p[..., None] * (v_t[..., :, None] * k_t[..., None, :])
        n_s = f_p * n_s + i_p * k_t
        h_num = jnp.einsum("bhij,bhj->bhi", C, q_t)
        h_den = jnp.maximum(jnp.abs(jnp.einsum("bhd,bhd->bh", n_s, q_t)), 1.0)
        h = h_num / h_den[..., None]
        out = (C, n_s, m_new)
        return out, (h, out if collect_states else None)

    tq = lambda a: a.transpose(1, 0, 2, 3)
    tg = lambda a: a.transpose(1, 0, 2)
    (C_f, n_f, m_f), (hs, states) = _scan_time(
        step, (state["C"], state["n"], state["m"]),
        (tq(q), tq(k), tq(v), tg(i_raw), tg(f_log)),
        collect_states,
    )
    h = hs.transpose(1, 0, 2, 3).reshape(B, T, d_in).astype(x.dtype)
    out = (h * jax.nn.silu(z)) @ params["w_down"]

    if collect_states:
        Cs, ns, ms = states
        conv_hist = jnp.stack(
            [jax.lax.dynamic_slice_in_dim(conv_in, t + 1, CONV_K - 1, axis=1)
             for t in range(T)], axis=0)
        return out, {"conv": conv_hist, "C": Cs, "n": ns, "m": ms}
    return out, {
        "conv": conv_in[:, T:, :].astype(state["conv"].dtype),
        "C": C_f, "n": n_f, "m": m_f,
    }


# ---------------------------------------------------------------------------
# sLSTM (xLSTM scalar memory with exponential gating)
# ---------------------------------------------------------------------------

def init_slstm(key, cfg, dtype) -> dict:
    d = cfg.d_model
    f = (4 * d) // 3
    ks = jax.random.split(key, 11)
    p = {}
    for i, g in enumerate(("z", "i", "f", "o")):
        p[f"w_{g}"] = dense_init(ks[i], (d, d), dtype)
        p[f"r_{g}"] = dense_init(ks[4 + i], (d, d), dtype)
        p[f"b_{g}"] = (jnp.full((d,), 3.0, jnp.float32) if g == "f"
                       else jnp.zeros((d,), jnp.float32))
    p["w_ffn_up"] = dense_init(ks[8], (d, f), dtype)
    p["w_ffn_down"] = dense_init(ks[9], (f, d), dtype)
    return p


def make_slstm_state(cfg, batch: int, dtype) -> dict:
    d = cfg.d_model
    z = jnp.zeros((batch, d), jnp.float32)
    return {"c": z, "n": z, "m": z, "h": z}


def slstm_forward(params, cfg, x, state, collect_states: bool = False):
    B, T, d = x.shape
    x32 = x.astype(jnp.float32)
    pre = {g: x32 @ params[f"w_{g}"].astype(jnp.float32) for g in ("z", "i", "f", "o")}

    def step(carry, inputs):
        c, n, m, h = carry
        pz, pi, pf, po = inputs
        z_t = jnp.tanh(pz + h @ params["r_z"].astype(jnp.float32) + params["b_z"])
        i_t = pi + h @ params["r_i"].astype(jnp.float32) + params["b_i"]
        f_t = jax.nn.log_sigmoid(pf + h @ params["r_f"].astype(jnp.float32) + params["b_f"])
        o_t = jax.nn.sigmoid(po + h @ params["r_o"].astype(jnp.float32) + params["b_o"])
        m_new = jnp.maximum(f_t + m, i_t)
        i_p = jnp.exp(i_t - m_new)
        f_p = jnp.exp(f_t + m - m_new)
        c_new = f_p * c + i_p * z_t
        n_new = f_p * n + i_p
        h_new = o_t * c_new / jnp.maximum(n_new, 1e-6)
        out = (c_new, n_new, m_new, h_new)
        return out, (h_new, out if collect_states else None)

    t = lambda a: a.transpose(1, 0, 2)
    (c_f, n_f, m_f, h_f), (hs, states) = _scan_time(
        step, (state["c"], state["n"], state["m"], state["h"]),
        (t(pre["z"]), t(pre["i"]), t(pre["f"]), t(pre["o"])),
        collect_states,
    )
    h = hs.transpose(1, 0, 2).astype(x.dtype)
    out = jax.nn.gelu(h @ params["w_ffn_up"], approximate=True) @ params["w_ffn_down"]

    if collect_states:
        cs, ns, ms, hss = states
        return out, {"c": cs, "n": ns, "m": ms, "h": hss}
    return out, {"c": c_f, "n": n_f, "m": m_f, "h": h_f}


FORWARD = {"mamba": mamba_forward, "mlstm": mlstm_forward, "slstm": slstm_forward}
INIT = {"mamba": init_mamba, "mlstm": init_mlstm, "slstm": init_slstm}
MAKE_STATE = {"mamba": make_mamba_state, "mlstm": make_mlstm_state, "slstm": make_slstm_state}

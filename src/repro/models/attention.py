"""Attention blocks: GQA/MQA, sliding-window (ring cache), MLA, cross-attn.

Three execution modes share one code path:
  * ``train``   — full causal self-attention over (B, T), no cache.
  * ``prefill`` — causal over the prompt, writes the KV cache from pos 0.
  * ``extend``  — T new tokens (T=1 → plain decode, T=γ+1 → SD verify)
                  appended at per-sequence offsets ``lengths`` against a
                  populated cache.

Caches:
  full attention   {"k": (B, S, Hkv, D), "v": (B, S, Hkv, D)}
  sliding window   {"k": (B, W, Hkv, D), "v": ..., "pos": (B, W) int32}
                   ring buffer, slot = position % W, ``pos`` init −1
  MLA              {"latent": (B, S, r_kv), "k_rope": (B, S, Dr)}
  cross            {"k": (B, S_enc, Hkv, D), "v": ...} — static after prefill

RoPE is applied at write time for K (absolute positions), query side at read
time, so cached K never needs re-rotation.
"""
from __future__ import annotations

import functools
import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.layers import apply_mrope, apply_rope, dense_init, softcap

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def init_gqa(key, cfg, dtype) -> dict:
    d, hd = cfg.d_model, cfg.head_dim
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], (d, cfg.num_heads * hd), dtype),
        "wk": dense_init(ks[1], (d, cfg.num_kv_heads * hd), dtype),
        "wv": dense_init(ks[2], (d, cfg.num_kv_heads * hd), dtype),
        "wo": dense_init(ks[3], (cfg.num_heads * hd, d), dtype),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((cfg.num_heads * hd,), dtype)
        p["bk"] = jnp.zeros((cfg.num_kv_heads * hd,), dtype)
        p["bv"] = jnp.zeros((cfg.num_kv_heads * hd,), dtype)
    return p


def init_mla(key, cfg, dtype) -> dict:
    d = cfg.d_model
    r_kv, r_q = cfg.mla_kv_lora_rank, cfg.mla_q_lora_rank
    dn, dr, dv = cfg.mla_qk_nope_dim, cfg.mla_qk_rope_dim, cfg.mla_v_head_dim
    H = cfg.num_heads
    ks = jax.random.split(key, 8)
    p = {
        "w_dkv": dense_init(ks[0], (d, r_kv + dr), dtype),         # latent + k_rope
        "w_uk": dense_init(ks[1], (r_kv, H * dn), dtype),
        "w_uv": dense_init(ks[2], (r_kv, H * dv), dtype),
        "wo": dense_init(ks[3], (H * dv, d), dtype),
        "kv_norm": jnp.ones((r_kv,), dtype),
    }
    if r_q > 0:
        p["w_dq"] = dense_init(ks[4], (d, r_q), dtype)
        p["w_uq"] = dense_init(ks[5], (r_q, H * (dn + dr)), dtype)
        p["q_norm"] = jnp.ones((r_q,), dtype)
    else:
        p["wq"] = dense_init(ks[6], (d, H * (dn + dr)), dtype)
    return p


def init_cross_attn(key, cfg, dtype) -> dict:
    return init_gqa(key, cfg, dtype)


# ---------------------------------------------------------------------------
# cache constructors
# ---------------------------------------------------------------------------

# Extra ring slots so a batched extend of T ≤ SWA_RING_PAD+1 tokens never
# evicts an entry still inside an earlier query's window (SD verify writes
# gamma+1 tokens before any of them attends).
SWA_RING_PAD = 8


def make_attn_cache(cfg, batch: int, max_seq: int, kind: str, dtype, *,
                    paged: bool = False, page_size: int = 64,
                    pool_pages: Optional[int] = None) -> dict:
    """Per-layer decode cache.  ``paged=True`` stores full-attn / MLA
    sequence axes as a shared physical page pool (``*_pages`` leaves,
    (pool_pages, page_size, ...)) addressed through the model-level block
    table; SWA rings are already bounded per row and stay dense."""
    hd = cfg.head_dim
    if kind == "swa":
        w = min(cfg.sliding_window + SWA_RING_PAD, max_seq)
        return {
            "k": jnp.zeros((batch, w, cfg.num_kv_heads, hd), dtype),
            "v": jnp.zeros((batch, w, cfg.num_kv_heads, hd), dtype),
            "pos": jnp.full((batch, w), -1, jnp.int32),
        }
    if paged:
        npg = (batch * (-(-max_seq // page_size)) + 1
               if pool_pages is None else pool_pages)
        if kind == "mla":
            return {
                "latent_pages": jnp.zeros(
                    (npg, page_size, cfg.mla_kv_lora_rank), dtype),
                "k_rope_pages": jnp.zeros(
                    (npg, page_size, cfg.mla_qk_rope_dim), dtype),
            }
        return {
            "k_pages": jnp.zeros((npg, page_size, cfg.num_kv_heads, hd),
                                 dtype),
            "v_pages": jnp.zeros((npg, page_size, cfg.num_kv_heads, hd),
                                 dtype),
        }
    if kind == "mla":
        return {
            "latent": jnp.zeros((batch, max_seq, cfg.mla_kv_lora_rank), dtype),
            "k_rope": jnp.zeros((batch, max_seq, cfg.mla_qk_rope_dim), dtype),
        }
    return {
        "k": jnp.zeros((batch, max_seq, cfg.num_kv_heads, hd), dtype),
        "v": jnp.zeros((batch, max_seq, cfg.num_kv_heads, hd), dtype),
    }


def _paged_write(pool: jnp.ndarray, table: jnp.ndarray,
                 positions: jnp.ndarray, vals: jnp.ndarray) -> jnp.ndarray:
    """Scatter per-row values at logical ``positions`` (B, T) into the
    physical page pool (NP, page, ...) through the block table (B, MP).
    Unallocated positions resolve to the trash page — harmless."""
    ps = pool.shape[1]
    bidx = jnp.arange(positions.shape[0])[:, None]
    pid = table[bidx, positions // ps]                       # (B, T)
    return pool.at[pid, positions % ps].set(vals)


def _paged_view(pool: jnp.ndarray, table: jnp.ndarray):
    """Gather the (B, MP*page, ...) dense view of a paged pool plus its
    logical key positions.  Stale/trash content is masked the same way
    rejected SD suffixes are: the causal mask only admits positions the
    row has actually written (k_pos <= q_pos)."""
    B, MP = table.shape
    ps = pool.shape[1]
    view = pool[table].reshape((B, MP * ps) + pool.shape[2:])
    k_pos = jnp.broadcast_to(jnp.arange(MP * ps)[None, :], (B, MP * ps))
    return view, k_pos


# ---------------------------------------------------------------------------
# core scaled-dot-product with GQA grouping
# ---------------------------------------------------------------------------

def _sdpa(q, k, v, mask, scale, logit_cap: float = 0.0):
    """q: (B,T,Hq,D)  k/v: (B,S,Hkv,D)  mask: (B,1,T,S) bool → (B,T,Hq,Dv)."""
    B, T, Hq, D = q.shape
    Hkv = k.shape[2]
    g = Hq // Hkv
    qg = q.reshape(B, T, Hkv, g, D)
    logits = jnp.einsum("btkgd,bskd->bkgts", qg.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    if logit_cap > 0:
        logits = softcap(logits, logit_cap)
    logits = jnp.where(mask[:, :, None, :, :], logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bkgts,bskd->btkgd", probs, v.astype(jnp.float32))
    return out.reshape(B, T, Hq, v.shape[-1]).astype(q.dtype)


def _chunk_inputs(k, v, k_pos, chunk):
    B, S, Hkv, D = k.shape
    Dv = v.shape[-1]
    pad = (-S) % chunk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        k_pos = jnp.pad(k_pos, ((0, 0), (0, pad)), constant_values=-1)
    n = (S + pad) // chunk
    kc = k.reshape(B, n, chunk, Hkv, D).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(B, n, chunk, Hkv, Dv).transpose(1, 0, 2, 3, 4)
    pc = k_pos.reshape(B, n, chunk).transpose(1, 0, 2)
    return kc, vc, pc, pad


def _chunk_scores(qg, k_t, p_t, q_pos, scale, logit_cap, causal, window):
    """(B,Hkv,g,T,C) softcapped+masked scores for one KV chunk (f32)."""
    B, T = q_pos.shape
    C = p_t.shape[-1]
    s = jnp.einsum("btkgd,bckd->bkgtc", qg, k_t.astype(jnp.float32)) * scale
    if logit_cap > 0:
        s = softcap(s, logit_cap)
    valid = p_t[:, None, :] >= 0
    if causal:
        valid &= p_t[:, None, :] <= q_pos[:, :, None]
        if window > 0:
            valid &= p_t[:, None, :] > q_pos[:, :, None] - window
    else:
        valid = jnp.broadcast_to(valid, (B, T, C))
    return jnp.where(valid[:, None, None, :, :], s, NEG_INF)


def _chunked_fwd(q, k, v, q_pos, k_pos, scale, window, logit_cap, chunk, causal):
    B, T, Hq, D = q.shape
    Hkv = k.shape[2]
    Dv = v.shape[-1]
    g = Hq // Hkv
    kc, vc, pc, _ = _chunk_inputs(k, v, k_pos, chunk)
    qg = q.reshape(B, T, Hkv, g, D).astype(jnp.float32)

    def body(carry, inputs):
        m, l, acc = carry                       # (B,Hkv,g,T), ..., (...,Dv)
        k_t, v_t, p_t = inputs
        s = _chunk_scores(qg, k_t, p_t, q_pos, scale, logit_cap, causal, window)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + jnp.sum(p, axis=-1)
        acc_new = acc * corr[..., None] + jnp.einsum(
            "bkgtc,bckd->bkgtd", p, v_t.astype(jnp.float32))
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((B, Hkv, g, T), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, Hkv, g, T), jnp.float32)
    a0 = jnp.zeros((B, Hkv, g, T, Dv), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(body, (m0, l0, a0), (kc, vc, pc))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    out = out.transpose(0, 3, 1, 2, 4).reshape(B, T, Hq, Dv).astype(q.dtype)
    return out, (m, l)


@functools.lru_cache(maxsize=None)
def _make_chunked(scale, window, logit_cap, chunk, causal):
    """Flash-attention with a recompute backward (custom_vjp): neither pass
    materializes (T, S) scores, and — unlike autodiff through the forward
    scan — the backward saves only O(T + S) residuals (out, m, l), not
    per-chunk carries.  This is what makes 32k-token training lower with
    sane memory (EXPERIMENTS.md §Dry-run)."""

    @jax.custom_vjp
    def f(q, k, v, q_pos, k_pos):
        return _chunked_fwd(q, k, v, q_pos, k_pos, scale, window, logit_cap,
                            chunk, causal)[0]

    def fwd(q, k, v, q_pos, k_pos):
        out, (m, l) = _chunked_fwd(q, k, v, q_pos, k_pos, scale, window,
                                   logit_cap, chunk, causal)
        return out, (q, k, v, q_pos, k_pos, out, m, l)

    def bwd(res, dout):
        q, k, v, q_pos, k_pos, out, m, l = res
        B, T, Hq, D = q.shape
        S, Hkv = k.shape[1], k.shape[2]
        Dv = v.shape[-1]
        g = Hq // Hkv
        kc, vc, pc, pad = _chunk_inputs(k, v, k_pos, chunk)
        qg = q.reshape(B, T, Hkv, g, D).astype(jnp.float32)
        do = dout.reshape(B, T, Hkv, g, Dv).transpose(0, 2, 3, 1, 4).astype(jnp.float32)
        og = out.reshape(B, T, Hkv, g, Dv).transpose(0, 2, 3, 1, 4).astype(jnp.float32)
        l_safe = jnp.maximum(l, 1e-30)
        Drow = jnp.sum(do * og, axis=-1)                    # (B,Hkv,g,T)

        def body(dq_acc, inputs):
            k_t, v_t, p_t = inputs
            s = _chunk_scores(qg, k_t, p_t, q_pos, scale, logit_cap, causal,
                              window)
            p = jnp.exp(s - m[..., None]) / l_safe[..., None]
            dp = jnp.einsum("bkgtd,bckd->bkgtc", do, v_t.astype(jnp.float32))
            ds = p * (dp - Drow[..., None])
            if logit_cap > 0:
                ds = ds * (1.0 - jnp.square(jnp.tanh(
                    jnp.einsum("btkgd,bckd->bkgtc", qg,
                               k_t.astype(jnp.float32)) * scale / logit_cap)))
            dq_acc = dq_acc + jnp.einsum("bkgtc,bckd->btkgd", ds,
                                         k_t.astype(jnp.float32)) * scale
            dk_t = jnp.einsum("bkgtc,btkgd->bckd", ds, qg) * scale
            dv_t = jnp.einsum("bkgtc,bkgtd->bckd", p, do)
            return dq_acc, (dk_t, dv_t)

        dq0 = jnp.zeros((B, T, Hkv, g, D), jnp.float32)
        dq, (dkc, dvc) = jax.lax.scan(body, dq0, (kc, vc, pc))
        dq = dq.reshape(B, T, Hq, D).astype(q.dtype)
        dk = dkc.transpose(1, 0, 2, 3, 4).reshape(B, S + pad, Hkv, D)
        dv = dvc.transpose(1, 0, 2, 3, 4).reshape(B, S + pad, Hkv, Dv)
        dk = dk[:, :S].astype(k.dtype)
        dv = dv[:, :S].astype(v.dtype)
        import numpy as _np
        zq = _np.zeros(q_pos.shape, jax.dtypes.float0)
        zk = _np.zeros(k_pos.shape, jax.dtypes.float0)
        return dq, dk, dv, zq, zk

    f.defvjp(fwd, bwd)
    return f


def chunked_sdpa(
    q, k, v, q_pos, k_pos, *,
    scale: float,
    window: int = 0,
    logit_cap: float = 0.0,
    chunk: int = 1024,
    causal: bool = True,
):
    """Online-softmax attention, ``lax.scan`` over key chunks, flash-style
    recompute backward.  Never materializes the (T, S) score matrix in
    either pass.  q: (B,T,Hq,D), k/v: (B,S,Hkv,D), q_pos: (B,T),
    k_pos: (B,S).  Invalid slots carry k_pos < 0."""
    fn = _make_chunked(float(scale), int(window), float(logit_cap),
                       int(chunk), bool(causal))
    return fn(q, k, v, q_pos, k_pos)


def _causal_mask(q_pos: jnp.ndarray, k_pos: jnp.ndarray, window: int = 0):
    """q_pos: (B,T), k_pos: (B,S) → (B,1,T,S).  k visible iff k_pos <= q_pos
    (and within the window when window > 0) and k_pos >= 0 (valid slot)."""
    m = (k_pos[:, None, :] <= q_pos[:, :, None]) & (k_pos[:, None, :] >= 0)
    if window > 0:
        m &= k_pos[:, None, :] > q_pos[:, :, None] - window
    return m[:, None, :, :]


# ---------------------------------------------------------------------------
# GQA / SWA forward
# ---------------------------------------------------------------------------

def _project_qkv(params, cfg, x):
    hd = cfg.head_dim
    B, T, _ = x.shape
    q = x @ params["wq"]
    k = x @ params["wk"]
    v = x @ params["wv"]
    if "bq" in params:
        q = q + params["bq"]
        k = k + params["bk"]
        v = v + params["bv"]
    q = q.reshape(B, T, cfg.num_heads, hd)
    k = k.reshape(B, T, cfg.num_kv_heads, hd)
    v = v.reshape(B, T, cfg.num_kv_heads, hd)
    return q, k, v


def _rotate(cfg, q, k, positions, mrope_positions=None):
    if cfg.rope_type == "mrope":
        if mrope_positions is None:  # text-only: all three components equal
            mrope_positions = jnp.repeat(positions[..., None], 3, axis=-1)
        q = apply_mrope(q, mrope_positions, cfg.rope_theta, cfg.mrope_sections)
        k = apply_mrope(k, mrope_positions, cfg.rope_theta, cfg.mrope_sections)
    elif cfg.rope_type == "rope":
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    # "learned"/"sinusoidal": positions added at the embedding level
    return q, k


def gqa_forward(
    params: dict,
    cfg,
    x: jnp.ndarray,                  # (B, T, d)
    positions: jnp.ndarray,          # (B, T) absolute positions
    *,
    kind: str = "attn",              # "attn" | "swa"
    cache: Optional[dict] = None,
    mode: str = "train",             # train | prefill | extend
    mrope_positions=None,
    use_flash: bool = False,
    causal: bool = True,
    page_table: Optional[jnp.ndarray] = None,
    paged_attention: str = "kernel",
) -> Tuple[jnp.ndarray, Optional[dict]]:
    B, T, _ = x.shape
    window = cfg.sliding_window if kind == "swa" else 0
    scale = 1.0 / math.sqrt(cfg.head_dim)
    q, k, v = _project_qkv(params, cfg, x)
    q, k = _rotate(cfg, q, k, positions, mrope_positions)
    cap = cfg.attn_logit_softcap

    def attend(q_, k_, v_, q_pos, k_pos):
        """Backend selection: Pallas flash (train/prefill, TPU target),
        chunked online-softmax (long sequences), naive masked SDPA."""
        S = k_.shape[1]
        if use_flash and causal and T == S and T >= 128:
            from repro.kernels.flash_attention import ops as flash_ops
            return flash_ops.flash_attention(
                q_, k_, v_, causal=True, window=window, scale=scale,
                logit_cap=cap)
        if T * S > 2_097_152:  # avoid materializing big (T,S) score tensors
            return chunked_sdpa(q_, k_, v_, q_pos, k_pos, scale=scale,
                                window=window, logit_cap=cap, causal=causal)
        mask = _causal_mask(q_pos, k_pos, window) if causal else (
            (k_pos[:, None, :] >= 0)[:, None, :, :]
            & jnp.ones((B, 1, T, k_pos.shape[-1]), bool))
        return _sdpa(q_, k_, v_, mask, scale, cap)

    if mode in ("train", "prefill"):
        # attention over the in-flight K/V (never through the cache: avoids
        # ring-slot collisions for SWA and S_max-sized score tensors)
        out = attend(q, k, v, positions, positions)
        if mode == "prefill" and cache is not None:
            if kind == "swa":
                w = cache["k"].shape[1]
                tw = min(T, w)
                slots = positions[:, -tw:] % w
                bidx = jnp.arange(B)[:, None]
                cache = {
                    "k": cache["k"].at[bidx, slots].set(k[:, -tw:]),
                    "v": cache["v"].at[bidx, slots].set(v[:, -tw:]),
                    "pos": cache["pos"].at[bidx, slots].set(positions[:, -tw:]),
                }
            elif "k_pages" in cache:
                cache = {
                    "k_pages": _paged_write(cache["k_pages"], page_table,
                                            positions, k),
                    "v_pages": _paged_write(cache["v_pages"], page_table,
                                            positions, v),
                }
            else:
                bidx = jnp.arange(B)[:, None]
                cache = {
                    "k": cache["k"].at[bidx, positions].set(k),
                    "v": cache["v"].at[bidx, positions].set(v),
                }
        return out.reshape(B, T, -1) @ params["wo"], cache

    # mode == "extend": T new tokens against the populated cache
    bidx = jnp.arange(B)[:, None]
    if kind == "swa":
        w = cache["k"].shape[1]
        slots = positions % w
        cache = {
            "k": cache["k"].at[bidx, slots].set(k),
            "v": cache["v"].at[bidx, slots].set(v),
            "pos": cache["pos"].at[bidx, slots].set(positions),
        }
        k_pos = cache["pos"]
        out = attend(q, cache["k"], cache["v"], positions, k_pos)
    elif "k_pages" in cache:
        # paged: write the new tokens through the block table, then attend.
        # Decode/verify widths (T <= 8) take the block-table-walking Pallas
        # kernel — KV pages stream straight from the pool, no dense gather;
        # wider tail-prefill extends (chunked admission) and the explicit
        # paged_attention="gather" fallback materialize the dense view.
        cache = {
            "k_pages": _paged_write(cache["k_pages"], page_table, positions,
                                    k),
            "v_pages": _paged_write(cache["v_pages"], page_table, positions,
                                    v),
        }
        if paged_attention == "kernel" and causal and T <= 8:
            from repro.kernels.decode_attention import ops as dec_ops
            out = dec_ops.paged_decode_attention(
                q, cache["k_pages"], cache["v_pages"], positions[:, 0],
                page_table, scale=scale, logit_cap=cap)
        else:
            k_view, k_pos = _paged_view(cache["k_pages"], page_table)
            v_view, _ = _paged_view(cache["v_pages"], page_table)
            out = attend(q, k_view, v_view, positions, k_pos)
    else:
        cache = {
            "k": cache["k"].at[bidx, positions].set(k),
            "v": cache["v"].at[bidx, positions].set(v),
        }
        S = cache["k"].shape[1]
        if use_flash and window == 0 and cap == 0.0 and S >= 512:
            # Pallas decode/verify kernel: gamma+1 queries vs the long KV
            # cache, per-sequence lengths = first query position
            from repro.kernels.decode_attention import ops as dec_ops
            out = dec_ops.decode_attention(
                q, cache["k"], cache["v"], positions[:, 0], scale=scale)
        else:
            k_pos = jnp.broadcast_to(jnp.arange(S)[None, :], (B, S))
            out = attend(q, cache["k"], cache["v"], positions, k_pos)
    return out.reshape(B, T, -1) @ params["wo"], cache


# ---------------------------------------------------------------------------
# MLA forward (DeepSeek-V2 / MiniCPM3 multi-head latent attention)
# ---------------------------------------------------------------------------

def _mla_q(params, cfg, x):
    B, T, _ = x.shape
    H = cfg.num_heads
    dn, dr = cfg.mla_qk_nope_dim, cfg.mla_qk_rope_dim
    if "w_dq" in params:
        ql = x @ params["w_dq"]
        ql = _rms(ql, params["q_norm"], cfg.norm_eps)
        q = ql @ params["w_uq"]
    else:
        q = x @ params["wq"]
    q = q.reshape(B, T, H, dn + dr)
    return q[..., :dn], q[..., dn:]


def _rms(x, scale, eps):
    xf = x.astype(jnp.float32)
    out = xf * jax.lax.rsqrt(jnp.mean(jnp.square(xf), -1, keepdims=True) + eps)
    return (out * scale.astype(jnp.float32)).astype(x.dtype)


def mla_forward(
    params: dict,
    cfg,
    x: jnp.ndarray,
    positions: jnp.ndarray,
    *,
    cache: Optional[dict] = None,
    mode: str = "train",
    page_table: Optional[jnp.ndarray] = None,
) -> Tuple[jnp.ndarray, Optional[dict]]:
    B, T, _ = x.shape
    H = cfg.num_heads
    dn, dr, dv = cfg.mla_qk_nope_dim, cfg.mla_qk_rope_dim, cfg.mla_v_head_dim
    r_kv = cfg.mla_kv_lora_rank
    scale = 1.0 / math.sqrt(dn + dr)

    q_nope, q_rope = _mla_q(params, cfg, x)                     # (B,T,H,dn/(dr))
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)

    dkv = x @ params["w_dkv"]                                   # (B,T,r_kv+dr)
    latent = _rms(dkv[..., :r_kv], params["kv_norm"], cfg.norm_eps)
    k_rope_new = apply_rope(
        dkv[..., None, r_kv:], positions, cfg.rope_theta
    )[..., 0, :]                                                # (B,T,dr) single shared head

    if mode in ("train", "prefill") or cache is None:
        lat_all, k_rope_all = latent, k_rope_new
        k_pos = positions
        new_cache = None
        if mode == "prefill" and cache is not None:
            if "latent_pages" in cache:
                new_cache = {
                    "latent_pages": _paged_write(cache["latent_pages"],
                                                 page_table, positions,
                                                 latent),
                    "k_rope_pages": _paged_write(cache["k_rope_pages"],
                                                 page_table, positions,
                                                 k_rope_new),
                }
            else:
                bidx = jnp.arange(B)[:, None]
                new_cache = {
                    "latent": cache["latent"].at[bidx, positions].set(latent),
                    "k_rope": cache["k_rope"].at[bidx, positions].set(
                        k_rope_new),
                }
    elif "latent_pages" in cache:
        new_cache = {
            "latent_pages": _paged_write(cache["latent_pages"], page_table,
                                         positions, latent),
            "k_rope_pages": _paged_write(cache["k_rope_pages"], page_table,
                                         positions, k_rope_new),
        }
        lat_all, k_pos = _paged_view(new_cache["latent_pages"], page_table)
        k_rope_all, _ = _paged_view(new_cache["k_rope_pages"], page_table)
    else:
        bidx = jnp.arange(B)[:, None]
        lat_all = cache["latent"].at[bidx, positions].set(latent)
        k_rope_all = cache["k_rope"].at[bidx, positions].set(k_rope_new)
        new_cache = {"latent": lat_all, "k_rope": k_rope_all}
        S = lat_all.shape[1]
        k_pos = jnp.broadcast_to(jnp.arange(S)[None, :], (B, S))

    # expand latent → per-head K_nope and V; fold the shared rope-K into a
    # single concatenated head dim so standard SDPA applies:
    #   q·k = q_nope·k_nope + q_rope·k_rope
    S = lat_all.shape[1]
    k_nope = (lat_all @ params["w_uk"]).reshape(B, S, H, dn)
    v = (lat_all @ params["w_uv"]).reshape(B, S, H, dv)
    k_cat = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope_all[:, :, None, :], (B, S, H, dr))], axis=-1)
    q_cat = jnp.concatenate([q_nope, q_rope], axis=-1)

    if T * S > 2_097_152:
        out = chunked_sdpa(q_cat, k_cat, v, positions, k_pos, scale=scale)
    else:
        mask = _causal_mask(positions, k_pos, 0)
        out = _sdpa(q_cat, k_cat, v, mask, scale)
    out = out.reshape(B, T, H * dv)
    return out @ params["wo"], new_cache


# ---------------------------------------------------------------------------
# cross attention (whisper decoder → encoder output)
# ---------------------------------------------------------------------------

def cross_attn_prefill_cache(params: dict, cfg, enc_out: jnp.ndarray, dtype) -> dict:
    """Project encoder output to K/V once; static for the whole decode."""
    B, S, _ = enc_out.shape
    k = (enc_out @ params["wk"]).reshape(B, S, cfg.num_kv_heads, cfg.head_dim)
    v = (enc_out @ params["wv"]).reshape(B, S, cfg.num_kv_heads, cfg.head_dim)
    return {"k": k.astype(dtype), "v": v.astype(dtype)}


def cross_attn_forward(params: dict, cfg, x: jnp.ndarray, kv: dict) -> jnp.ndarray:
    B, T, _ = x.shape
    q = (x @ params["wq"]).reshape(B, T, cfg.num_heads, cfg.head_dim)
    S = kv["k"].shape[1]
    scale = 1.0 / math.sqrt(cfg.head_dim)
    if T * S > 2_097_152:  # chunked online softmax for long decoder sequences
        q_pos = jnp.zeros((B, T), jnp.int32)
        k_pos = jnp.zeros((B, S), jnp.int32)
        out = chunked_sdpa(q, kv["k"], kv["v"], q_pos, k_pos, scale=scale,
                           causal=False, chunk=min(1024, S))
    else:
        mask = jnp.ones((B, 1, T, S), bool)
        out = _sdpa(q, kv["k"], kv["v"], mask, scale)
    return out.reshape(B, T, -1) @ params["wo"]

"""Core layers: norms, gated MLPs, embeddings, rotary embeddings (+M-RoPE).

Everything is functional: ``init_*(key, cfg) -> params`` and
``apply(params, x) -> y`` with params as plain dict pytrees.
"""
from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

# ---------------------------------------------------------------------------
# initializers
# ---------------------------------------------------------------------------

def dense_init(key, shape, dtype, scale: float = 1.0):
    """Truncated-normal fan-in init (matches common LLM practice)."""
    fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
    std = scale / math.sqrt(fan_in)
    return (jax.random.truncated_normal(key, -3, 3, shape, jnp.float32) * std).astype(dtype)


def embed_init(key, shape, dtype):
    return (jax.random.normal(key, shape, jnp.float32) * 0.02).astype(dtype)


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------

def init_norm(cfg, dtype) -> dict:
    p = {"scale": jnp.ones((cfg.d_model,), dtype)}
    if cfg.norm_type == "layernorm":
        p["bias"] = jnp.zeros((cfg.d_model,), dtype)
    return p


def apply_norm(params: dict, x: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    if "bias" in params:  # layernorm
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.var(xf, axis=-1, keepdims=True)
        out = (xf - mu) * jax.lax.rsqrt(var + eps)
        out = out * params["scale"].astype(jnp.float32) + params["bias"].astype(jnp.float32)
    else:  # rmsnorm
        ms = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
        out = xf * jax.lax.rsqrt(ms + eps) * params["scale"].astype(jnp.float32)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# gated MLP (SwiGLU / GeGLU)
# ---------------------------------------------------------------------------

def init_mlp(key, d_model: int, d_ff: int, dtype) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "w_gate": dense_init(k1, (d_model, d_ff), dtype),
        "w_up": dense_init(k2, (d_model, d_ff), dtype),
        "w_down": dense_init(k3, (d_ff, d_model), dtype),
    }


def apply_mlp(params: dict, x: jnp.ndarray, activation: str = "silu") -> jnp.ndarray:
    gate = x @ params["w_gate"]
    up = x @ params["w_up"]
    if activation == "gelu":
        act = jax.nn.gelu(gate, approximate=True)
    else:
        act = jax.nn.silu(gate)
    return (act * up) @ params["w_down"]


# ---------------------------------------------------------------------------
# rotary position embeddings
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float) -> jnp.ndarray:
    """Inverse frequencies, shape (head_dim // 2,)."""
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """x: (..., T, H, D); positions: broadcastable to (..., T)."""
    d = x.shape[-1]
    inv = rope_freqs(d, theta)                              # (D/2,)
    ang = positions[..., None].astype(jnp.float32) * inv    # (..., T, D/2)
    sin = jnp.sin(ang)[..., None, :]                        # (..., T, 1, D/2)
    cos = jnp.cos(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def apply_mrope(
    x: jnp.ndarray,
    positions: jnp.ndarray,
    theta: float,
    sections: Tuple[int, ...],
) -> jnp.ndarray:
    """Multimodal RoPE (Qwen2-VL, arXiv:2409.12191).

    ``positions``: (..., T, 3) — (temporal, height, width) position ids.
    ``sections``: rotary half-dims assigned to each component; must sum to
    head_dim // 2.  For pure text all three components carry the same id, and
    M-RoPE degenerates to 1-D RoPE exactly.
    """
    d = x.shape[-1]
    assert sum(sections) == d // 2, (sections, d)
    inv = rope_freqs(d, theta)                              # (D/2,)
    # choose which position component drives each frequency band
    comp = jnp.concatenate(
        [jnp.full((s,), i, dtype=jnp.int32) for i, s in enumerate(sections)]
    )                                                        # (D/2,)
    pos = jnp.take_along_axis(
        positions.astype(jnp.float32),
        jnp.broadcast_to(comp, positions.shape[:-1] + (d // 2,)).astype(jnp.int32),
        axis=-1,
    )                                                        # (..., T, D/2)
    ang = pos * inv
    sin = jnp.sin(ang)[..., None, :]
    cos = jnp.cos(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_positions(num_pos: int, d_model: int) -> jnp.ndarray:
    """Standard sinusoidal table (whisper encoder)."""
    pos = jnp.arange(num_pos, dtype=jnp.float32)[:, None]
    i = jnp.arange(d_model // 2, dtype=jnp.float32)[None, :]
    ang = pos / jnp.power(10_000.0, 2 * i / d_model)
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


# ---------------------------------------------------------------------------
# embedding / unembedding
# ---------------------------------------------------------------------------

def init_embedding(key, vocab: int, d_model: int, dtype) -> dict:
    return {"table": embed_init(key, (vocab, d_model), dtype)}


def embed(params: dict, tokens: jnp.ndarray, scale: bool = False) -> jnp.ndarray:
    x = params["table"][tokens]
    if scale:  # gemma-style sqrt(d) scaling
        x = x * jnp.asarray(math.sqrt(x.shape[-1]), x.dtype)
    return x


def unembed(params: dict, x: jnp.ndarray, softcap: float = 0.0) -> jnp.ndarray:
    logits = x @ params["table"].T
    if softcap > 0:
        logits = jnp.tanh(logits / softcap) * softcap
    return logits


def softcap(x: jnp.ndarray, cap: float) -> jnp.ndarray:
    return jnp.tanh(x / cap) * cap if cap > 0 else x

"""Mixture-of-Experts FFN: top-k router, expert dispatch, load-balance loss.

Two dispatch strategies, one interface:

  * ``onehot``  — dense einsum over a (tokens, experts) one-hot combine
                  tensor.  GSPMD-friendly: the expert axis shards cleanly over
                  the ``model`` mesh axis (expert parallelism), XLA turns the
                  dispatch into all-to-all-ish collectives.  Used for
                  training, dry-runs and small tests.
  * ``gmm``     — tokens sorted by expert id, grouped matmul via the Pallas
                  ``gmm`` kernel (MXU-tiled, megablox-style).  Serving path.

The router also reports which experts were activated — the measurement
behind the paper's N(t) validation (Fig. 1a/b).

Expert prefetch (SP-MoE, arXiv:2510.10302): a ``PrefetchPlan`` names, per
period-slot, the experts a router probe over the draft token stream predicts
the next verify pass will hit.  ``warm_experts`` gathers exactly those
experts' FFN weights into fresh device buffers — dispatched during the SD
propose phase, so on an accelerator the HBM reads of the predicted experts
overlap drafting instead of serializing with verify.  ``moe_forward``
accepts the per-slot mask and scores the prediction against the experts the
verify pass actually activated (hit/miss counts, surfaced per wave by the
serving engine).
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.layers import dense_init


class PrefetchPlan(NamedTuple):
    """Per-period-slot expert-warmup prediction (a jit-safe pytree).

    Attributes
    ----------
    masks : tuple of jnp.ndarray
        One ``(P, E)`` bool array per period-slot (``P`` = num_periods,
        ``E`` = num_experts): True where the probe predicts the verify pass
        will activate that expert.  Non-MoE slots carry all-False masks.
    expert_ids : tuple of jnp.ndarray
        One ``(P, M)`` int32 array per period-slot — the top-M predicted
        expert ids backing each mask (``M`` static, so the warm gather has a
        fixed shape).  Non-MoE slots carry ``(P, 0)``.
    """

    masks: Tuple[jnp.ndarray, ...]
    expert_ids: Tuple[jnp.ndarray, ...]


# lint: allow[D602] prefetch is simulation-only until gmm takes donated buffers
def warm_experts(layer_params, cfg, plan: PrefetchPlan, *, mesh=None):
    """Gather the predicted experts' FFN weights into fresh buffers.

    Parameters
    ----------
    layer_params : list of dict
        ``params["layers"]`` — per period-slot params with leading ``P``
        axis (``w_gate``/``w_up``/``w_down`` are ``(P, E, d, f)``-shaped).
    cfg : ModelConfig
        Supplies ``moe_pattern`` (which slots have routed FFNs).
    plan : PrefetchPlan
        ``expert_ids[i]`` selects the ``(P, M)`` experts to warm in slot i.
    mesh : jax.sharding.Mesh, optional
        When the expert weights are sharded over a ``"model"`` axis
        (expert-parallel serving), the gather runs under shard_map so each
        shard warms ONLY the predicted ids in its LOCAL expert slice —
        warming never all-gathers remote experts' weights.

    Returns
    -------
    list of dict
        Per MoE slot, ``{"w_gate": (P, M, d, f), "w_up": ..., "w_down":
        (P, M, f, d)}`` gathered copies (an extra leading shard axis under
        a mesh, non-local ids zeroed).  The VALUES are not consumed — the
        point is the dispatch: issued right after propose, the gather
        streams the predicted experts' weights while the host is still
        assembling the verify launch.  NOTE this makes the warming a
        dispatch-level SIMULATION in this reproduction: verify still reads
        the original buffers, so the priced k2 saving (docs/metrics.md) is
        a model of what the measured hit rate is worth once warmed buffers
        are donated to the gmm dispatch (ROADMAP headroom).
    """
    ep = (mesh is not None and "model" in getattr(mesh, "axis_names", ())
          and mesh.shape["model"] > 1
          and cfg.num_experts % mesh.shape["model"] == 0)
    if ep:
        from jax.experimental.shard_map import shard_map
        from jax.sharding import PartitionSpec as SP

        def _local_gather(w, ids):
            # w: (P, e_local, ...) LOCAL slice; ids: (P, M) global ids.
            # Gather only the ids this shard owns; foreign ids read row 0
            # of the local slice (free) and are zeroed.
            e_local = w.shape[1]
            first = jax.lax.axis_index("model") * e_local
            mine = (ids >= first) & (ids < first + e_local)
            lids = jnp.clip(ids - first, 0, e_local - 1)
            g = jax.vmap(lambda wp, ip: jnp.take(wp, ip, axis=0))(w, lids)
            g = g * mine[..., None, None].astype(g.dtype)
            return g[None]                       # stack shard results

        def gather(w, ids):
            nd = w.ndim
            return shard_map(
                _local_gather, mesh=mesh,
                in_specs=(SP(None, "model", *([None] * (nd - 2))), SP()),
                out_specs=SP("model", *([None] * nd)),
                check_rep=False)(w, ids)
    else:
        gather = jax.vmap(lambda w, ids: jnp.take(w, ids, axis=0))
    warmed = []
    for i, is_moe in enumerate(cfg.moe_pattern):
        if not is_moe or plan.expert_ids[i].shape[-1] == 0:
            continue
        ffn = layer_params[i]["ffn"]
        ids = plan.expert_ids[i]
        warmed.append({k: gather(ffn[k], ids)
                       for k in ("w_gate", "w_up", "w_down")})
    return warmed


def prefetch_hit_stats(prefetch_mask: jnp.ndarray, indices: jnp.ndarray,
                       num_experts: int) -> dict:
    """Score one layer's prediction against the experts actually routed to.

    Parameters
    ----------
    prefetch_mask : jnp.ndarray
        ``(E,)`` bool — experts the plan predicted (and warmed).
    indices : jnp.ndarray
        ``(N, K)`` routed expert ids from this forward.
    num_experts : int
        E.

    Returns
    -------
    dict
        int32 scalars: ``hits`` (activated AND warmed), ``actual``
        (activated), ``predicted`` (warmed) — the per-wave hit/miss
        accounting aggregated by the engine.
    """
    actual = jnp.zeros((num_experts,), bool).at[indices.reshape(-1)].set(True)
    predicted = prefetch_mask.astype(bool)
    return {
        "prefetch_hits": jnp.sum(actual & predicted).astype(jnp.int32),
        "prefetch_actual": jnp.sum(actual).astype(jnp.int32),
        "prefetch_predicted": jnp.sum(predicted).astype(jnp.int32),
    }


def init_moe(key, cfg, dtype) -> dict:
    d, f = cfg.d_model, cfg.moe_d_ff
    E = cfg.num_experts
    ks = jax.random.split(key, 5)
    p = {
        "router": dense_init(ks[0], (d, E), jnp.float32),  # router in fp32
        "w_gate": dense_init(ks[1], (E, d, f), dtype),
        "w_up": dense_init(ks[2], (E, d, f), dtype),
        "w_down": dense_init(ks[3], (E, f, d), dtype),
    }
    if cfg.num_shared_experts:
        fs = f * cfg.num_shared_experts
        k1, k2, k3 = jax.random.split(ks[4], 3)
        p["shared"] = {
            "w_gate": dense_init(k1, (d, fs), dtype),
            "w_up": dense_init(k2, (d, fs), dtype),
            "w_down": dense_init(k3, (fs, d), dtype),
        }
    return p


def _act(x, activation: str):
    return jax.nn.gelu(x, approximate=True) if activation == "gelu" else jax.nn.silu(x)


def router_topk(
    params: dict, cfg, x: jnp.ndarray, rng: Optional[jax.Array] = None
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """x: (N, d) → (weights (N,K), indices (N,K), router_probs (N,E))."""
    logits = x.astype(jnp.float32) @ params["router"]
    if cfg.router_jitter > 0 and rng is not None:
        logits = logits + cfg.router_jitter * jax.random.normal(rng, logits.shape)
    probs = jax.nn.softmax(logits, axis=-1)
    weights, indices = jax.lax.top_k(probs, cfg.num_experts_per_tok)
    weights = weights / jnp.sum(weights, axis=-1, keepdims=True)
    return weights, indices, probs


def load_balance_loss(probs: jnp.ndarray, indices: jnp.ndarray, num_experts: int) -> jnp.ndarray:
    """Switch-transformer aux loss: E * sum_e f_e * P_e  (arXiv:2101.03961)."""
    one_hot = jax.nn.one_hot(indices, num_experts, dtype=jnp.float32)   # (N,K,E)
    f = jnp.mean(jnp.sum(one_hot, axis=1), axis=0)                      # fraction per expert
    p = jnp.mean(probs, axis=0)
    return num_experts * jnp.sum(f * p)


def expert_activation_counts(indices: jnp.ndarray, num_experts: int) -> jnp.ndarray:
    """Tokens routed to each expert — the paper's N(t)/T̄_exp measurement."""
    one_hot = jax.nn.one_hot(indices, num_experts, dtype=jnp.int32)
    return jnp.sum(one_hot, axis=tuple(range(one_hot.ndim - 1)))


def _dispatch_onehot(params, cfg, x, weights, indices):
    """(N,d) → (N,d) via dense one-hot combine.  Experts axis = leading dim of
    w_*: shards over the `model` mesh axis → expert parallelism under GSPMD."""
    E = cfg.num_experts
    combine = jnp.einsum(
        "nk,nke->ne", weights, jax.nn.one_hot(indices, E, dtype=weights.dtype)
    )                                                      # (N, E)
    # per-expert FFN on every token, weighted combine (dense but shardable)
    h_gate = jnp.einsum("nd,edf->enf", x, params["w_gate"])
    h_up = jnp.einsum("nd,edf->enf", x, params["w_up"])
    h = _act(h_gate, cfg.mlp_activation) * h_up
    y = jnp.einsum("enf,efd->end", h, params["w_down"])    # (E,N,d)
    return jnp.einsum("end,ne->nd", y, combine.astype(y.dtype))


def _dispatch_gmm(params, cfg, x, weights, indices):
    """Sort tokens by expert, ragged grouped matmul (kernels/gmm/ragged.py):
    fused gate+up launch then down launch — 2 Pallas calls per MoE FFN, and
    expert GEMM work scales with the routed token count N*K, not E*C."""
    from repro.kernels.gmm import ops as gmm_ops

    N, d = x.shape
    K, E = cfg.num_experts_per_tok, cfg.num_experts
    flat_expert = indices.reshape(-1)                       # (N*K,)
    order = jnp.argsort(flat_expert)
    token_of = order // K                                   # source token per slot
    xs = x[token_of]                                        # (N*K, d) sorted by expert
    group_sizes = jnp.bincount(flat_expert, length=E)

    ys = gmm_ops.ragged_moe_ffn(
        xs, params["w_gate"], params["w_up"], params["w_down"], group_sizes,
        activation=cfg.mlp_activation)                      # (N*K, d)

    w_flat = weights.reshape(-1)[order].astype(ys.dtype)    # (N*K,)
    out = jnp.zeros((N, d), ys.dtype)
    return out.at[token_of].add(ys * w_flat[:, None])


def moe_forward(
    params: dict,
    cfg,
    x: jnp.ndarray,                  # (B, T, d)
    *,
    dispatch: str = "onehot",        # "onehot" | "gmm" | "ep"
    rng: Optional[jax.Array] = None,
    return_metrics: bool = False,
    prefetch_mask: Optional[jnp.ndarray] = None,   # (E,) predicted-hot experts
    mesh=None,
    mesh_layout: Optional[str] = None,
):
    """Routed MoE FFN: top-k route, dispatch to experts, weighted combine.

    Parameters
    ----------
    params : dict
        ``init_moe`` params (router + per-expert FFN weights).
    cfg : ModelConfig
        Supplies E, K, activation, jitter.
    x : jnp.ndarray
        (B, T, d) input activations.
    dispatch : str
        "onehot" (dense, shardable — training), "gmm" (ragged grouped
        matmul — serving) or "ep" (expert-parallel shard_map).  Tradeoffs
        in docs/dispatch.md.
    rng : jax.Array, optional
        Router jitter key (train only).
    return_metrics : bool
        Compute aux-loss / expert-count metrics (train only — materializes
        (N, K, E) one-hots).
    prefetch_mask : jnp.ndarray, optional
        (E,) predicted-hot expert mask from a PrefetchPlan; when given, the
        returned metrics include prefetch hit/miss counts scored against
        this forward's actual routing.
    mesh, mesh_layout : optional
        Device mesh (and layout) threaded explicitly to the "ep" dispatch
        and ignored by the single-device dispatches — see
        docs/distributed.md.

    Returns
    -------
    (jnp.ndarray, dict or None)
        (B, T, d) output and the requested metrics (None if neither
        ``return_metrics`` nor ``prefetch_mask``).
    """
    B, T, d = x.shape
    if dispatch == "ep":
        # expert-parallel shard_map path (distributed/collectives.py);
        # router runs inside the shard, so metrics (and prefetch scoring)
        # come from a cheap replicated re-route below.
        from repro.distributed.collectives import moe_ep_forward
        y = moe_ep_forward(params, cfg, x, mesh=mesh, layout=mesh_layout)
        metrics = None
        if return_metrics or prefetch_mask is not None:
            xf = x.reshape(B * T, d)
            _, indices, probs = router_topk(params, cfg, xf, rng)
            if return_metrics:
                metrics = {
                    "aux_loss": load_balance_loss(probs, indices,
                                                  cfg.num_experts),
                    "expert_counts": expert_activation_counts(
                        indices, cfg.num_experts),
                }
            if prefetch_mask is not None:
                metrics = dict(metrics or {},
                               **prefetch_hit_stats(prefetch_mask, indices,
                                                    cfg.num_experts))
        return y, metrics
    xf = x.reshape(B * T, d)
    weights, indices, probs = router_topk(params, cfg, xf, rng)
    if dispatch == "gmm":
        y = _dispatch_gmm(params, cfg, xf, weights.astype(x.dtype), indices)
    else:
        y = _dispatch_onehot(params, cfg, xf, weights.astype(x.dtype), indices)
    if "shared" in params:
        s = params["shared"]
        h = _act(xf @ s["w_gate"], cfg.mlp_activation) * (xf @ s["w_up"])
        y = y + h @ s["w_down"]
    y = y.reshape(B, T, d)
    metrics = None
    if return_metrics:
        metrics = {
            "aux_loss": load_balance_loss(probs, indices, cfg.num_experts),
            "expert_counts": expert_activation_counts(indices, cfg.num_experts),
        }
    if prefetch_mask is not None:
        # score the warm plan against the experts this forward actually hit;
        # cheap (one (E,) scatter) and decode-only — train never passes a mask
        metrics = dict(metrics or {},
                       **prefetch_hit_stats(prefetch_mask, indices,
                                            cfg.num_experts))
    return y, metrics

"""Unified model: embedding → (optional encoder) → decoder stack → head.

API (all pure functions of params):

    model = Model(cfg)
    params = model.init(key)
    logits, metrics = model.forward_train(params, tokens)
    cache  = model.init_cache(batch, max_seq)
    logits, cache = model.prefill(params, tokens, cache, lengths=...)
    logits, pend  = model.extend(params, tokens, cache, collect=True)
    cache  = model.commit(pend, n_commit)

Cache layout::

    {"layers": [slot_0, ...], "lengths": (B,) int32,
     "cross": [slot_i ...] | None}

``extend`` consumes T tokens per sequence at offsets ``lengths`` — T=1 is
plain autoregressive decode, T=gamma+1 is a speculative-decoding verify
pass.  With ``collect=True`` recurrent slots return per-step states
(leading T axis); ``commit`` gathers the state of the last consumed-and-
accepted token and bumps ``lengths``.  Attention slots are committed in
place (stale entries are masked by position, see attention.py).

Paged KV (``init_cache(..., paged=True)``): full-attention and MLA slots
store their sequence axis in fixed-size pages drawn from a shared pool
(``{"k_pages": (P, NP, page, H, D), ...}``) indexed by a per-row block
table ``cache["pages"]["table"]`` (B, max_pages) — physical page 0 is a
reserved trash page that unallocated/retired rows point at, so stale lanes
write harmlessly and no index is ever negative.  The table is DATA: page
assignment (``PageAllocator``) never retraces, only pool growth
(``grow_cache_pages``) does.  SWA rings and recurrent states are bounded
per row already and stay dense inside a paged cache.
"""
from __future__ import annotations

import functools
import math
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.constraints import constrain
from repro.models import attention as attn_mod
from repro.models import transformer as tfm
from repro.models.layers import (
    apply_norm,
    embed,
    init_embedding,
    init_norm,
    sinusoidal_positions,
    unembed,
)
from repro.models.transformer import ATTN_KINDS, RECURRENT_KINDS


def _dtype(cfg: ModelConfig):
    return jnp.dtype(cfg.dtype)


def merge_cache_rows(old: dict, new: dict, mask: jnp.ndarray) -> dict:
    """Row-wise select between two same-shape decode caches.

    The continuous-batching admission primitive (core/spec_decode.
    SDEngine.admit): rows where ``mask`` is True take ``new`` (a freshly
    prefilled cache), all other rows keep ``old`` untouched — so new
    requests enter a live batch without disturbing in-flight sequences and
    without changing any compiled shape.

    Works on the ``{"layers": [...], "lengths": (B,)}`` cache layout:
    ``lengths`` carries batch on axis 0, stack-cache leaves on axis 1
    (leading ``num_periods`` axis — attention K/V, SWA ring ``pos``, MLA
    latents and recurrent states all follow it, see
    transformer.make_stack_cache).  Encoder-decoder ``cross`` caches are
    not supported (continuous admission would need per-row re-encoding).
    """
    if old.get("cross") is not None:
        raise NotImplementedError(
            "merge_cache_rows: encoder-decoder cross caches are static "
            "per-wave; continuous admission is decoder-only")
    if old.get("pages") is not None:
        raise NotImplementedError(
            "merge_cache_rows needs two same-shape caches; a paged cache "
            "admits through scatter_cache_rows (the sliced path)")
    mask = jnp.asarray(mask, bool)

    def pick(o, n):
        shape = [1] * o.ndim
        shape[1] = mask.shape[0]
        return jnp.where(mask.reshape(shape), n, o)

    layers = [jax.tree.map(pick, lo, ln)
              for lo, ln in zip(old["layers"], new["layers"])]
    lengths = jnp.where(mask, new["lengths"], old["lengths"])
    return dict(old, layers=layers, lengths=lengths)


_PAGED_LEAF_PAIRS = (("k_pages", "k"), ("v_pages", "v"),
                     ("latent_pages", "latent"), ("k_rope_pages", "k_rope"))


def scatter_cache_rows(old: dict, new: dict, rows: jnp.ndarray, *,
                       valid: Optional[jnp.ndarray] = None,
                       n_prompt: Optional[int] = None) -> dict:
    """Row-scatter a COMPACT (R-row) cache into a live B-row cache.

    The row-sliced admission primitive (core/spec_decode.SDEngine.
    admit_rows): ``new`` is a freshly prefilled cache holding only the R
    admitted rows, ``rows`` (R,) the pool row index each goes to.  Unlike
    :func:`merge_cache_rows` the fresh prefill's shape is (R, ...) — its
    cost scales with what was admitted, not the pool.

    ``valid`` (R,) bool marks real lanes; padding lanes (row-count
    bucketing replicates admissions round-robin, and at temperature>0 the
    replicas sample different first tokens) are dropped from the scatter so
    results never depend on lane order.  ``rows`` itself is data — which
    rows get admitted never retraces.

    Dense leaves (batch on axis 1, like merge_cache_rows) scatter whole
    rows.  Paged leaves scatter the first ``n_prompt`` positions (rounded
    up to whole pages) through ``old["pages"]["table"]``; the admitted
    row's decode-region pages are left stale — decode writes its positions
    before attending, the same discipline that makes rejected SD suffixes
    safe.  ``new`` must be a DENSE cache whose max_seq matches the live
    cache's logical capacity (so SWA ring widths line up).
    """
    if old.get("cross") is not None:
        raise NotImplementedError(
            "scatter_cache_rows: continuous admission is decoder-only")
    rows = jnp.asarray(rows, jnp.int32)
    R = rows.shape[0]
    B = old["lengths"].shape[0]
    if valid is None:
        valid = jnp.ones((R,), bool)
    else:
        valid = jnp.asarray(valid, bool)
    # invalid lanes target index B — out of bounds, dropped by the scatter
    rows_eff = jnp.where(valid, rows, B)
    table = None if old.get("pages") is None else old["pages"]["table"]

    def scatter_dense(o, n):
        return o.at[:, rows_eff].set(n, mode="drop")

    def scatter_paged(o, n):
        # o: (P, NP, ps, ...), n: (P, R, S_f, ...) — write the prompt pages
        # of each admitted row through the block table
        ps = o.shape[2]
        S_f = n.shape[2]
        span = S_f if n_prompt is None else min(-(-n_prompt // ps) * ps, S_f)
        pos = jnp.arange(span)
        pid = table[rows[:, None], (pos // ps)[None, :]]        # (R, span)
        pid = jnp.where(valid[:, None], pid, o.shape[1])        # drop pads
        return o.at[:, pid, (pos % ps)[None, :]].set(n[:, :, :span],
                                                     mode="drop")

    paged_to_dense = dict(_PAGED_LEAF_PAIRS)
    layers = []
    for lo, ln in zip(old["layers"], new["layers"]):
        slot = {k: (scatter_paged(leaf, ln[paged_to_dense[k]])
                    if k in paged_to_dense else scatter_dense(leaf, ln[k]))
                for k, leaf in lo.items()}
        layers.append(slot)
    lengths = old["lengths"].at[rows_eff].set(new["lengths"], mode="drop")
    return dict(old, layers=layers, lengths=lengths)


class PageAllocator:
    """Host-side block manager for a paged decode cache.

    Mirrors TensorRT-LLM's KV block manager at the granularity this repo
    needs: a free list over the physical pool, per-row page ownership, and
    a (B, max_pages) logical→physical table the jitted forwards consume as
    DATA.  Physical page 0 is the trash page — never allocated, the target
    of every unassigned table entry — so retired rows' frozen-lane writes
    land harmlessly and reads stay in bounds.

    ``alloc``/``free_row`` mutate ``self.table`` in place; callers push
    ``jnp.asarray(alloc.table)`` back into the session state after a
    change (an input-array swap, never a retrace).  When ``can_alloc``
    says no, ``grown_geometry`` returns the next pow2 (pool_pages,
    max_pages) to rebuild with via :func:`grow_cache_pages`.

    Pages are REFCOUNTED so rows can share a prompt prefix
    (:meth:`fork_prefix`): a shared page appears in several rows' tables
    and ``owned`` lists but returns to the free list only when its last
    reference drops (:meth:`free_row`).  A sharing row that must write
    into a shared page first detaches it via copy-on-write
    (:meth:`cow_range`), which hands the caller the (src, dst) physical
    pairs to copy device-side (:func:`copy_cache_pages`) before any
    write lands.
    """

    def __init__(self, batch: int, page_size: int, pool_pages: int,
                 max_pages: int):
        import numpy as np
        self.page_size = int(page_size)
        self.pool_pages = int(pool_pages)
        self.max_pages = int(max_pages)
        self.free: List[int] = list(range(1, self.pool_pages))
        self.owned: Dict[int, List[int]] = {}
        self.reserved: List[int] = []
        self.ref: Dict[int, int] = {}
        self.table = np.zeros((batch, self.max_pages), np.int32)

    def pages_for(self, n_positions: int) -> int:
        return -(-int(n_positions) // self.page_size)

    def can_alloc(self, n_positions: int) -> bool:
        need = self.pages_for(n_positions)
        return need <= len(self.free) and need <= self.max_pages

    def alloc(self, row: int, n_positions: int) -> None:
        """Assign pages covering ``n_positions`` to ``row`` (must be free)."""
        need = self.pages_for(n_positions)
        if row in self.owned:
            raise ValueError(f"row {row} already owns pages; free_row first")
        if need > len(self.free) or need > self.max_pages:
            raise ValueError(
                f"cannot allocate {need} pages (free={len(self.free)}, "
                f"max_pages={self.max_pages}); grow the pool first")
        pages = [self.free.pop() for _ in range(need)]
        for p in pages:
            self.ref[p] = 1
        self.owned[row] = pages
        self.table[row, :] = 0
        self.table[row, :need] = pages

    def fork_prefix(self, src: int, dst: int, n_positions: int) -> int:
        """Share ``src``'s pages covering its first ``n_positions`` with
        ``dst`` (must own nothing): each shared page's refcount bumps and
        appears in ``dst``'s table — zero device traffic, the pool is
        untouched.  ``dst`` must not write inside the shared range without
        first detaching via :meth:`cow_range`.  Returns the number of
        pages shared."""
        need = self.pages_for(n_positions)
        if dst in self.owned:
            raise ValueError(f"row {dst} already owns pages; free_row first")
        src_pages = self.owned.get(src)
        if src_pages is None or len(src_pages) < need:
            raise ValueError(
                f"row {src} owns {0 if src_pages is None else len(src_pages)}"
                f" pages, cannot share {need}")
        pages = list(src_pages[:need])
        for p in pages:
            self.ref[p] += 1
        self.owned[dst] = pages
        self.table[dst, :] = 0
        self.table[dst, :need] = pages
        return need

    def extend_row(self, row: int, n_positions: int) -> int:
        """Grow ``row``'s ownership with private pages until it covers
        ``n_positions`` total (the fork_prefix companion: shared prefix
        pages + private tail).  Returns the number of pages added."""
        if row not in self.owned:
            raise ValueError(f"row {row} owns no pages; alloc or "
                             "fork_prefix first")
        need = self.pages_for(n_positions)
        have = len(self.owned[row])
        extra = need - have
        if extra <= 0:
            return 0
        if extra > len(self.free) or need > self.max_pages:
            raise ValueError(
                f"cannot extend row {row} by {extra} pages "
                f"(free={len(self.free)}, max_pages={self.max_pages})")
        pages = [self.free.pop() for _ in range(extra)]
        for p in pages:
            self.ref[p] = 1
        self.owned[row].extend(pages)
        self.table[row, have:need] = pages
        return extra

    def cow_range(self, row: int, start: int, end: int) -> List[Tuple[int, int]]:
        """Detach every SHARED page of ``row`` covering logical positions
        [start, end): each gets a fresh private physical page swapped into
        the row's table/ownership (old refcount drops).  Returns the
        (src, dst) physical pairs; the caller MUST device-copy src→dst
        across all paged leaves (:func:`copy_cache_pages`) before writing,
        or the row loses its shared-prefix content.  Pages already private
        (ref == 1) are left alone."""
        pages = self.owned.get(row, [])
        pairs: List[Tuple[int, int]] = []
        lp0 = int(start) // self.page_size
        lp1 = min(-(-int(end) // self.page_size), len(pages))
        for lp in range(max(lp0, 0), lp1):
            p = pages[lp]
            if self.ref[p] > 1:
                if not self.free:
                    raise ValueError(
                        f"cow_range: no free page to detach page {p} of "
                        f"row {row}; grow the pool first")
                fresh = self.free.pop()
                self.ref[p] -= 1
                self.ref[fresh] = 1
                pages[lp] = fresh
                self.table[row, lp] = fresh
                pairs.append((p, fresh))
        return pairs

    def shared_page_count(self) -> int:
        """Number of physical pages currently referenced by more than one
        row — the pool-side prefix-sharing win ``assert_no_leaks`` and the
        serving stats report."""
        return sum(1 for c in self.ref.values() if c > 1)

    def free_row(self, row: int) -> None:
        """Drop ``row``'s references; pages return to the pool only at
        refcount zero.  Its table goes to trash.

        Freeing a row that owns nothing is a no-op (retired filler rows
        never allocated), but a page that is ALREADY free or untracked —
        ownership bookkeeping corrupted somewhere — raises instead of
        silently double-crediting the free list.  Pages still shared with
        sibling rows (refcount > 1) stay out of the free list, so
        preempting one fork never yanks a prefix out from under the
        others."""
        pages = self.owned.pop(row, [])
        for p in pages:
            c = self.ref.get(p)
            if c is None or p in self.free:
                raise ValueError(
                    f"double free: row {row} page {p} is already "
                    "free/untracked — page ownership is corrupted")
            if c > 1:
                self.ref[p] = c - 1
            else:
                del self.ref[p]
                self.free.append(p)
        self.table[row, :] = 0

    def free_fraction(self) -> float:
        """Fraction of allocatable pages (trash page excluded) currently
        free — the quantity admission watermarks compare against."""
        return len(self.free) / max(self.pool_pages - 1, 1)

    def reserve(self, n: int) -> List[int]:
        """Withdraw ``n`` pages from the free list without assigning them
        to any row (fault injection / headroom holds).  Reserved pages
        are real pressure: ``can_alloc``/``alloc`` cannot see them until
        :meth:`release` returns them."""
        if n > len(self.free):
            raise ValueError(f"cannot reserve {n} pages ({len(self.free)} "
                             "free)")
        pages = [self.free.pop() for _ in range(n)]
        self.reserved.extend(pages)
        return pages

    def release(self, pages: List[int]) -> None:
        """Return pages taken by :meth:`reserve`.  Releasing a page that
        was never reserved — or releasing twice — raises: that is a
        double free in the making."""
        for p in pages:
            if p not in self.reserved:
                raise ValueError(f"release of page {p} that is not "
                                 "reserved (double release?)")
            self.reserved.remove(p)
            if p in self.free:
                raise ValueError(f"double free: page {p} already in the "
                                 "free list")
            self.free.append(p)

    def assert_no_leaks(self) -> None:
        """End-of-stream invariant: every page is back in the free list.

        After a stream's final ``_free_retired`` (and the fault
        injector's ``release_all``) no row may own pages, no reservation
        may be outstanding, the free list must hold exactly
        ``pool_pages - 1`` pages (all but trash page 0), and every table
        entry must point at trash.  Raises ``RuntimeError`` listing every
        violated condition — leaked pages are how long-running serving
        pools die slowly."""
        import numpy as np
        problems = []
        if self.owned:
            problems.append(f"rows still own pages: {sorted(self.owned)}")
        if self.reserved:
            problems.append(f"outstanding reservations: "
                            f"{sorted(self.reserved)}")
        if len(self.free) != self.pool_pages - 1:
            problems.append(f"free list has {len(self.free)} pages, "
                            f"expected {self.pool_pages - 1}")
        if len(set(self.free)) != len(self.free):
            problems.append("free list contains duplicates")
        if self.ref:
            shared = self.shared_page_count()
            problems.append(
                f"{len(self.ref)} pages still refcounted "
                f"({shared} of them shared): {sorted(self.ref)[:16]}")
        if self.table.any():
            rows = sorted(set(np.nonzero(self.table)[0].tolist()))
            problems.append(f"table rows still mapped: {rows}")
        if problems:
            raise RuntimeError("PageAllocator leak check failed: "
                               + "; ".join(problems))

    def grown_geometry(self, n_positions: int) -> Tuple[int, int]:
        """(pool_pages, max_pages) after pow2 growth that fits an
        allocation of ``n_positions`` more positions."""
        need = self.pages_for(n_positions)
        max_pages = self.max_pages
        while need > max_pages:
            max_pages *= 2
        pool = self.pool_pages
        while need > pool - 1 - (self.pool_pages - 1 - len(self.free)):
            pool *= 2
        return pool, max_pages

    def grow(self, pool_pages: int, max_pages: int) -> None:
        """Adopt a grown geometry (pool/table already padded by
        :func:`grow_cache_pages` on the device side)."""
        import numpy as np
        assert pool_pages >= self.pool_pages and max_pages >= self.max_pages
        self.free.extend(range(self.pool_pages, pool_pages))
        self.table = np.pad(self.table,
                            ((0, 0), (0, max_pages - self.max_pages)))
        self.pool_pages, self.max_pages = pool_pages, max_pages


@functools.lru_cache(maxsize=None)
def _pad_tail_fn(ndim: int, axis: int, extra: int):
    # eager jnp.pad materializes its pad config as implicit host->device
    # scalar transfers on every call; growth runs between rounds on the
    # transfer-guarded serving path, so bake the geometry into a cached
    # jitted pad (jax then keys compilations on the leaf aval as usual)
    pad = [(0, 0)] * ndim
    pad[axis] = (0, extra)
    pad = tuple(pad)
    return jax.jit(lambda x: jnp.pad(x, pad))


def _pad_tail(leaf, axis: int, extra: int):
    """Zero-pad ``leaf`` by ``extra`` trailing slots along ``axis``."""
    return _pad_tail_fn(leaf.ndim, axis, extra)(leaf)


def grow_cache_pages(cache: dict, pool_pages: int, max_pages: int) -> dict:
    """Pad a paged cache to a larger pool / logical capacity.

    Pool leaves pad along the physical-page axis, the block table along
    the logical-page axis (new entries point at trash page 0).  Dense
    leaves inside the paged cache (SWA rings, recurrent states, lengths)
    are untouched — their per-row footprint is position-count independent.
    A growth changes leaf SHAPES, so the next round/admit call retraces:
    that is the amortized price of not sizing ``max_seq`` for the
    worst-case request up front.
    """
    if cache.get("pages") is None:
        raise ValueError("grow_cache_pages: not a paged cache")

    def grow_slot(slot):
        out = dict(slot)
        for paged_key, _ in _PAGED_LEAF_PAIRS:
            if paged_key in slot:
                leaf = slot[paged_key]
                extra = pool_pages - leaf.shape[1]
                if extra:
                    out[paged_key] = _pad_tail(leaf, 1, extra)
        return out

    table = cache["pages"]["table"]
    extra_lp = max_pages - table.shape[1]
    if extra_lp:
        table = _pad_tail(table, 1, extra_lp)
    return dict(cache, layers=[grow_slot(s) for s in cache["layers"]],
                pages=dict(cache["pages"], table=table))


def copy_cache_pages(cache: dict, pairs) -> dict:
    """Device-copy physical pages src→dst across every paged leaf.

    The copy-on-write materialization step: after
    :meth:`PageAllocator.cow_range` hands back (src, dst) physical page
    pairs, this clones their contents so the detached row keeps its
    shared-prefix KV.  Callers pad ``pairs`` to a bucketed count with
    (0, 0) entries — a trash-page self-copy is a harmless no-op — so the
    eager scatter keeps a stable shape across rounds.
    """
    if cache.get("pages") is None:
        raise ValueError("copy_cache_pages: not a paged cache")
    if not pairs:
        return cache
    src = jnp.asarray([p[0] for p in pairs], jnp.int32)
    dst = jnp.asarray([p[1] for p in pairs], jnp.int32)

    def copy_slot(slot):
        out = dict(slot)
        for paged_key, _ in _PAGED_LEAF_PAIRS:
            if paged_key in slot:
                leaf = slot[paged_key]
                out[paged_key] = leaf.at[:, dst].set(leaf[:, src])
        return out

    return dict(cache, layers=[copy_slot(s) for s in cache["layers"]])


def grow_cache_seq(cache: dict, cfg: ModelConfig, new_max_seq: int) -> dict:
    """Pad a DENSE cache's sequence axis to ``new_max_seq``.

    The draft-side companion of :func:`grow_cache_pages`: when a paged
    target session grows its logical capacity, the (cheap, dense) proposer
    caches must be able to address the same positions.  Full-attention K/V
    and MLA latents pad along axis 2 (leading period, batch axes);
    recurrent states and lengths have no sequence axis.  SWA rings only
    match if the window already fit the old capacity (a ring resize would
    remap ``pos % w`` slots of live data — unsupported, fail loudly).
    """
    from repro.models.attention import SWA_RING_PAD

    def grow_slot(slot, kind):
        if kind not in ATTN_KINDS:
            return slot
        if kind == "swa":
            w_new = min(cfg.sliding_window + SWA_RING_PAD, new_max_seq)
            if slot["k"].shape[2] != w_new:
                raise NotImplementedError(
                    "grow_cache_seq: SWA ring resize would remap live "
                    "slots; size the stream so capacity >= window + pad")
            return slot
        out = {}
        for k, leaf in slot.items():
            extra = new_max_seq - leaf.shape[2]
            if extra > 0:
                leaf = _pad_tail(leaf, 2, extra)
            out[k] = leaf
        return out

    layers = [grow_slot(s, kind)
              for s, kind in zip(cache["layers"], cfg.layer_pattern)]
    return dict(cache, layers=layers)


def _page_table(cache: dict) -> Optional[jnp.ndarray]:
    pages = cache.get("pages")
    return None if pages is None else pages["table"]


def sinusoidal_at(positions: jnp.ndarray, d_model: int) -> jnp.ndarray:
    """Sinusoidal embedding evaluated at arbitrary positions (B,T) → (B,T,d)."""
    pos = positions.astype(jnp.float32)[..., None]
    i = jnp.arange(d_model // 2, dtype=jnp.float32)
    ang = pos / jnp.power(10_000.0, 2 * i / d_model)
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


class Model:
    """Architecture-agnostic decoder(-encoder) language model."""

    def __init__(
        self,
        cfg: ModelConfig,
        *,
        moe_dispatch: str = "onehot",
        use_flash: bool = False,
        remat: bool = False,
        paged_attention: str = "kernel",
        mesh=None,
        mesh_layout: Optional[str] = None,
    ):
        if paged_attention not in ("kernel", "gather"):
            raise ValueError(
                f"paged_attention must be 'kernel' or 'gather', got "
                f"{paged_attention!r}")
        self.cfg = cfg
        self.moe_dispatch = moe_dispatch
        self.use_flash = use_flash
        self.remat = remat
        self.paged_attention = paged_attention
        # explicit mesh threading (docs/distributed.md): flows to every
        # constrain() and to the "ep" dispatch; None = single-device —
        # there is no process-global fallback
        if mesh is not None:
            from repro.distributed.constraints import resolve_mesh
            mesh, mesh_layout = resolve_mesh(mesh, mesh_layout)
        self.mesh = mesh
        self.mesh_layout = mesh_layout

    # ------------------------------------------------------------------ init
    def init(self, key: jax.Array) -> dict:
        cfg = self.cfg
        dt = _dtype(cfg)
        k_emb, k_stack, k_enc, k_head, k_fn = jax.random.split(key, 5)
        params: Dict[str, Any] = {
            "embed": init_embedding(k_emb, cfg.vocab_size, cfg.d_model, dt),
            "final_norm": init_norm(cfg, dt),
            "layers": tfm.init_stack(k_stack, cfg, dt, cross=cfg.is_encoder_decoder),
        }
        if not cfg.tie_embeddings:
            params["head"] = init_embedding(k_head, cfg.vocab_size, cfg.d_model, dt)
        if cfg.is_encoder_decoder:
            enc_cfg = cfg.with_overrides(
                num_layers=cfg.encoder_layers,
                layer_pattern=("attn",),
                moe_pattern=(False,),
                num_experts=0, num_experts_per_tok=0,
            )
            params["encoder"] = {
                "layers": tfm.init_stack(k_enc, enc_cfg, dt, cross=False),
                "final_norm": init_norm(enc_cfg, dt),
            }
            self._enc_cfg = enc_cfg
        return params

    @property
    def enc_cfg(self):
        cfg = self.cfg
        return cfg.with_overrides(
            num_layers=cfg.encoder_layers, layer_pattern=("attn",),
            moe_pattern=(False,), num_experts=0, num_experts_per_tok=0)

    # ----------------------------------------------------------------- embed
    def _embed(self, params, tokens, positions, inputs_embeds=None):
        cfg = self.cfg
        if inputs_embeds is not None:
            x = inputs_embeds.astype(_dtype(cfg))
        else:
            x = embed(params["embed"], tokens, scale=cfg.name.startswith("gemma"))
        if cfg.rope_type == "sinusoidal":
            x = x + sinusoidal_at(positions, cfg.d_model).astype(x.dtype)
        return constrain(x, "hidden", mesh=self.mesh, layout=self.mesh_layout)

    def _head(self, params, x):
        cfg = self.cfg
        x = apply_norm(params["final_norm"], x, cfg.norm_eps)
        table = params["embed"] if cfg.tie_embeddings else params["head"]
        return constrain(unembed(table, x, cfg.final_logit_softcap), "logits",
                         mesh=self.mesh, layout=self.mesh_layout)

    # --------------------------------------------------------------- encoder
    def encode(self, params, encoder_embeds: jnp.ndarray) -> jnp.ndarray:
        """Whisper-style encoder over stub frame embeddings (B, S_enc, d)."""
        cfg = self.enc_cfg
        B, S, _ = encoder_embeds.shape
        positions = jnp.broadcast_to(jnp.arange(S)[None, :], (B, S))
        x = encoder_embeds.astype(_dtype(cfg))
        x = x + sinusoidal_at(positions, cfg.d_model).astype(x.dtype)
        x, _, _ = tfm.stack_forward(
            params["encoder"]["layers"], cfg, x, positions, None,
            mode="train", causal=False, use_flash=False, remat=self.remat)
        return apply_norm(params["encoder"]["final_norm"], x, cfg.norm_eps)

    def _cross_kvs(self, params, enc_out):
        """Project encoder output through every decoder layer's cross-attn."""
        cfg = self.cfg
        out = []
        for i in range(cfg.period):
            slot = params["layers"][i]["cross"]
            kv = jax.vmap(
                lambda p: attn_mod.cross_attn_prefill_cache(p, cfg, enc_out, _dtype(cfg))
            )(slot)
            out.append(kv)
        return out

    # ----------------------------------------------------------------- train
    def forward_hidden(
        self,
        params,
        tokens: jnp.ndarray,                       # (B, T)
        *,
        inputs_embeds: Optional[jnp.ndarray] = None,
        encoder_embeds: Optional[jnp.ndarray] = None,
        mrope_positions: Optional[jnp.ndarray] = None,
    ) -> Tuple[jnp.ndarray, dict]:
        """Final pre-head hidden states (B, T, d) + MoE metrics.  The head is
        applied separately (chunked in training) so (B, T, vocab) logits are
        never materialized for long sequences."""
        cfg = self.cfg
        B, T = tokens.shape[:2] if tokens is not None else inputs_embeds.shape[:2]
        positions = jnp.broadcast_to(jnp.arange(T)[None, :], (B, T))
        x = self._embed(params, tokens, positions, inputs_embeds)
        cross_kvs = None
        if cfg.is_encoder_decoder:
            enc_out = self.encode(params, encoder_embeds)
            cross_kvs = self._cross_kvs(params, enc_out)
        x, _, metrics = tfm.stack_forward(
            params["layers"], cfg, x, positions, None,
            mode="train", dispatch=self.moe_dispatch, use_flash=self.use_flash,
            remat=self.remat, cross_kvs=cross_kvs,
            mrope_positions=mrope_positions,
            mesh=self.mesh, mesh_layout=self.mesh_layout)
        return x, metrics

    def forward_train(self, params, tokens, **kw) -> Tuple[jnp.ndarray, dict]:
        x, metrics = self.forward_hidden(params, tokens, **kw)
        return self._head(params, x), metrics

    # ----------------------------------------------------------------- cache
    def init_cache(self, batch: int, max_seq: int, *, paged: bool = False,
                   page_size: int = 64,
                   pool_pages: Optional[int] = None) -> dict:
        """Allocate a decode cache.

        Dense (default): every attention slot holds (B, max_seq) K/V.
        ``paged=True``: full-attn/MLA slots share a physical page pool of
        ``pool_pages`` pages of ``page_size`` positions (default: enough
        for every row at ``max_seq``, plus the trash page), addressed
        through ``cache["pages"]["table"]`` (B, ceil(max_seq/page_size)).
        ``max_seq`` becomes the LOGICAL capacity — growable later via
        :func:`grow_cache_pages` without resizing any row.
        """
        cfg = self.cfg
        dt = _dtype(cfg)
        cache: Dict[str, Any] = {
            "layers": tfm.make_stack_cache(cfg, batch, max_seq, dt,
                                           paged=paged, page_size=page_size,
                                           pool_pages=pool_pages),
            "lengths": jnp.zeros((batch,), jnp.int32),
        }
        if paged:
            max_pages = -(-max_seq // page_size)
            cache["pages"] = {
                "table": jnp.zeros((batch, max_pages), jnp.int32)}
        return cache

    # --------------------------------------------------------------- prefill
    def prefill(
        self,
        params,
        tokens: jnp.ndarray,                       # (B, T) padded prompts
        cache: dict,
        *,
        lengths: Optional[jnp.ndarray] = None,     # (B,) true prompt lengths
        inputs_embeds: Optional[jnp.ndarray] = None,
        encoder_embeds: Optional[jnp.ndarray] = None,
        mrope_positions: Optional[jnp.ndarray] = None,
    ) -> Tuple[jnp.ndarray, dict]:
        """Returns logits at each sequence's last prompt position (B, V)."""
        last, _, new_cache = self.prefill_with_hidden(
            params, tokens, cache, lengths=lengths,
            inputs_embeds=inputs_embeds, encoder_embeds=encoder_embeds,
            mrope_positions=mrope_positions)
        return last, new_cache

    def prefill_with_hidden(
        self,
        params,
        tokens: jnp.ndarray,                       # (B, T) padded prompts
        cache: dict,
        *,
        lengths: Optional[jnp.ndarray] = None,     # (B,) true prompt lengths
        inputs_embeds: Optional[jnp.ndarray] = None,
        encoder_embeds: Optional[jnp.ndarray] = None,
        mrope_positions: Optional[jnp.ndarray] = None,
    ) -> Tuple[jnp.ndarray, jnp.ndarray, dict]:
        """prefill() variant also returning the pre-head hidden state at each
        sequence's last prompt position (B, d) — the feature carry consumed
        by hidden-feeding proposers (core/eagle.EagleProposer)."""
        cfg = self.cfg
        B, T = tokens.shape
        if lengths is None:
            lengths = jnp.full((B,), T, jnp.int32)
        positions = jnp.broadcast_to(jnp.arange(T)[None, :], (B, T))
        x = self._embed(params, tokens, positions, inputs_embeds)
        cross_kvs = None
        if cfg.is_encoder_decoder:
            enc_out = self.encode(params, encoder_embeds)
            cross_kvs = self._cross_kvs(params, enc_out)
            cache = dict(cache, cross=cross_kvs)
        x, new_layers, _ = tfm.stack_forward(
            params["layers"], cfg, x, positions, cache["layers"],
            mode="prefill", dispatch=self.moe_dispatch, want_metrics=False,
            use_flash=self.use_flash, remat=self.remat, cross_kvs=cross_kvs,
            mrope_positions=mrope_positions, page_table=_page_table(cache),
            paged_attention=self.paged_attention,
            mesh=self.mesh, mesh_layout=self.mesh_layout)
        # head only at each sequence's last prompt position — never (B,T,V)
        last_h = jnp.take_along_axis(
            x, (lengths - 1)[:, None, None].astype(jnp.int32), axis=1)
        last = self._head(params, last_h)[:, 0]
        new_cache = dict(cache, layers=new_layers,
                         lengths=lengths.astype(jnp.int32))
        return last, last_h[:, 0], new_cache

    # ---------------------------------------------------------------- extend
    def _extend_impl(self, params, tokens, cache, *, collect=False,
                     prefetch_masks=None):
        """Shared decode/verify forward behind the three extend variants.

        decode/verify never consumes router metrics — want_metrics=False
        skips the (N, K, E) one-hot aux-loss/expert-count tensors that the
        SD verify hot path would otherwise materialize every round.
        """
        cfg = self.cfg
        B, T = tokens.shape
        positions = cache["lengths"][:, None] + jnp.arange(T)[None, :]
        x = self._embed(params, tokens, positions)
        x, new_layers, metrics = tfm.stack_forward(
            params["layers"], cfg, x, positions, cache["layers"],
            mode="extend", collect=collect, dispatch=self.moe_dispatch,
            want_metrics=False, use_flash=self.use_flash,
            cross_kvs=cache.get("cross"), prefetch_masks=prefetch_masks,
            page_table=_page_table(cache),
            paged_attention=self.paged_attention,
            mesh=self.mesh, mesh_layout=self.mesh_layout)
        logits = self._head(params, x)                           # (B, T, V)
        return logits, x, dict(cache, layers=new_layers), metrics

    def extend(
        self,
        params,
        tokens: jnp.ndarray,                       # (B, T) new tokens
        cache: dict,
        *,
        collect: bool = False,
    ) -> Tuple[jnp.ndarray, dict]:
        """Decode/verify T tokens per sequence at offsets ``lengths``.

        NOTE on recurrent prefill semantics: prefill must be called with
        unpadded (equal-length) prompts for recurrent archs, since states
        advance strictly sequentially.
        """
        logits, _, pend, _ = self._extend_impl(params, tokens, cache,
                                               collect=collect)
        return logits, pend

    def extend_with_prefetch(self, params, tokens, cache, plan, *,
                             collect: bool = False):
        """Verify forward that scores an expert-prefetch plan as it runs.

        Identical compute to :meth:`extend` (same logits, same cache
        discipline), but each MoE layer additionally compares the experts it
        actually routed to against ``plan.masks`` — the prediction whose
        weights were warmed during the propose phase.

        Parameters
        ----------
        params, tokens, cache
            As :meth:`extend`; ``tokens`` is the (B, gamma+1) verify stream.
        plan : models.moe.PrefetchPlan
            The warm plan built from the draft token stream.
        collect : bool
            As :meth:`extend` (recurrent per-step state collection).

        Returns
        -------
        logits : jnp.ndarray
            (B, T, V) next-token logits.
        hidden : jnp.ndarray
            (B, T, d) final pre-head hidden states (for hidden-feeding
            proposers; ignored otherwise).
        pend : dict
            Pending cache for :meth:`commit`.
        pf : dict
            int32 scalars ``{"hits", "actual", "predicted"}`` summed over
            all MoE layers and periods — the verify pass's prefetch
            hit/miss accounting.
        """
        logits, x, pend, metrics = self._extend_impl(
            params, tokens, cache, collect=collect,
            prefetch_masks=list(plan.masks))
        pf = {k: metrics[f"prefetch_{k}"]
              for k in ("hits", "actual", "predicted")}
        return logits, x, pend, pf

    def extend_with_hidden(self, params, tokens, cache, *, collect=False):
        """extend() variant that also returns the final hidden states
        (B, T, d) — consumed by EAGLE-style speculation heads
        (core/eagle.py), which predict the NEXT token's features from the
        target's current features."""
        logits, x, pend, _ = self._extend_impl(params, tokens, cache,
                                               collect=collect)
        return logits, x, pend

    # ---------------------------------------------------------------- commit
    def commit(self, pend: dict, n_commit: jnp.ndarray, collected: bool = False) -> dict:
        """Accept ``n_commit`` (B,) tokens of the last extend.

        Attention slots: lengths bump only (stale K/V masked out).
        Recurrent slots (when ``collected``): gather state index
        ``n_commit - 1`` per sequence from the (T, B, ...) pending stack.
        """
        cfg = self.cfg
        new_layers = []
        for i, kind in enumerate(cfg.layer_pattern):
            slot = pend["layers"][i]
            if kind in RECURRENT_KINDS and collected:
                idx = n_commit - 1                                # (B,)

                def gather(a):
                    # a: (P, T, B, ...) → (P, B, ...) selecting per-seq step
                    moved = jnp.moveaxis(a, 2, 0)                 # (B, P, T, ...)
                    sel = jax.vmap(lambda ab, n: ab[:, n])(moved, idx)
                    return jnp.moveaxis(sel, 0, 1)                # (P, B, ...)

                new_layers.append(jax.tree.map(gather, slot))
            else:
                new_layers.append(slot)
        return dict(pend, layers=new_layers,
                    lengths=pend["lengths"] + n_commit.astype(jnp.int32))

    # ------------------------------------------------------------ decode 1tk
    def decode_step(self, params, token: jnp.ndarray, cache: dict):
        """Plain AR decode of one token per sequence. token: (B,) → (B,V)."""
        logits, pend = self.extend(params, token[:, None], cache, collect=True)
        cache = self.commit(pend, jnp.ones_like(cache["lengths"]), collected=True)
        return logits[:, 0], cache


def build_model(cfg: ModelConfig, **kw) -> Model:
    return Model(cfg, **kw)

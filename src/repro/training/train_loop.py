"""Training step: causal-LM loss (+ MoE aux loss) + AdamW.

``make_train_step`` builds the pure step function used both by the real
training examples (examples/train_100m.py) and by the multi-pod dry-run
(launch/dryrun.py lowers exactly this function for train_4k shapes).
"""
from __future__ import annotations

from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, TrainConfig
from repro.models.model import Model
from repro.training.optimizer import AdamState, adamw_update, init_adam


def lm_loss(logits: jnp.ndarray, labels: jnp.ndarray,
            mask: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """Cross entropy; logits[:, t] predicts labels[:, t] (labels are
    pre-shifted by the data pipeline: labels = tokens >> 1)."""
    logits = logits.astype(jnp.float32)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
    if mask is None:
        mask = jnp.ones_like(labels, jnp.float32)
    return jnp.sum(nll * mask.astype(jnp.float32)) / jnp.maximum(
        jnp.sum(mask), 1.0)


def chunked_lm_loss(model: Model, params, hidden: jnp.ndarray,
                    labels: jnp.ndarray, mask: Optional[jnp.ndarray],
                    chunk: int = 512) -> jnp.ndarray:
    """Cross entropy without materializing (B, T, vocab): scan the head over
    T-chunks.  hidden[:, t] predicts labels[:, t] (labels pre-shifted by the
    pipeline: labels = tokens >> 1)."""
    B, T, d = hidden.shape
    chunk = min(chunk, T)
    pad = (-T) % chunk
    if pad:
        hidden = jnp.pad(hidden, ((0, 0), (0, pad), (0, 0)))
        labels = jnp.pad(labels, ((0, 0), (0, pad)))
        m = jnp.zeros((B, T + pad), jnp.float32).at[:, :T].set(
            jnp.ones((B, T), jnp.float32) if mask is None else mask.astype(jnp.float32))
    else:
        m = jnp.ones((B, T), jnp.float32) if mask is None else mask.astype(jnp.float32)
    n = hidden.shape[1] // chunk
    hc = hidden.reshape(B, n, chunk, d).transpose(1, 0, 2, 3)
    lc = labels.reshape(B, n, chunk).transpose(1, 0, 2)
    mc = m.reshape(B, n, chunk).transpose(1, 0, 2)

    @jax.checkpoint
    def body(carry, xs):
        # checkpointed: backward recomputes this chunk's logits instead of
        # the scan saving (n, B, chunk, vocab) residuals — the whole point
        # of chunking the loss.
        h, tgt, msk = xs
        logits = model._head(params, h).astype(jnp.float32)
        logp = jax.nn.log_softmax(logits, axis=-1)
        nll = -jnp.take_along_axis(logp, tgt[..., None], axis=-1)[..., 0]
        s, c = carry
        return (s + jnp.sum(nll * msk), c + jnp.sum(msk)), None

    (s, c), _ = jax.lax.scan(body, (jnp.zeros(()), jnp.zeros(())), (hc, lc, mc))
    return s / jnp.maximum(c, 1.0)


def make_train_step(model: Model, tcfg: TrainConfig):
    cfg = model.cfg

    def loss_fn(params, batch):
        kwargs = {}
        if cfg.is_encoder_decoder:
            kwargs["encoder_embeds"] = batch["encoder_embeds"]
        if cfg.frontend == "vision_stub" and "inputs_embeds" in batch:
            kwargs["inputs_embeds"] = batch["inputs_embeds"]
        hidden, metrics = model.forward_hidden(params, batch["tokens"], **kwargs)
        loss = chunked_lm_loss(model, params, hidden, batch["labels"],
                               batch.get("mask"))
        aux = metrics["aux_loss"] / max(cfg.num_layers, 1)
        total = loss + cfg.router_aux_loss_coef * aux
        return total, {"loss": loss, "aux_loss": aux,
                       "expert_counts": metrics["expert_counts"]}

    def train_step(params, opt_state: AdamState, batch):
        (total, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(
            params, batch)
        params, opt_state, opt_metrics = adamw_update(params, grads, opt_state, tcfg)
        metrics = dict(metrics, **opt_metrics, total_loss=total)
        return params, opt_state, metrics

    return train_step


def init_train_state(model: Model, key: jax.Array) -> Tuple[dict, AdamState]:
    params = model.init(key)
    return params, init_adam(params)

"""AdamW + cosine schedule + global-norm clipping, pure JAX (no optax).

State and update are plain pytree functions so they shard transparently
under pjit (moments inherit the parameter sharding — FSDP-friendly).
"""
from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import TrainConfig


class AdamState(NamedTuple):
    step: jnp.ndarray       # scalar int32
    mu: dict                # first moment  (pytree like params)
    nu: dict                # second moment


def init_adam(params) -> AdamState:
    zeros = lambda p: jax.tree.map(lambda a: jnp.zeros_like(a, jnp.float32), p)
    return AdamState(step=jnp.zeros((), jnp.int32), mu=zeros(params), nu=zeros(params))


def cosine_schedule(step, cfg: TrainConfig):
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return cfg.learning_rate * warm * (0.1 + 0.9 * cos)


def clip_by_global_norm(grads, max_norm: float):
    leaves = jax.tree.leaves(grads)
    gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in leaves))
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gnorm, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype), grads), gnorm


def adamw_update(
    params, grads, state: AdamState, cfg: TrainConfig
) -> Tuple[dict, AdamState, dict]:
    grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip)
    step = state.step + 1
    lr = cosine_schedule(step, cfg)
    b1, b2 = cfg.beta1, cfg.beta2
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g32 = g.astype(jnp.float32)
        m = b1 * m + (1 - b1) * g32
        v = b2 * v + (1 - b2) * jnp.square(g32)
        mh = m / bc1
        vh = v / bc2
        delta = mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state.mu)
    flat_v = treedef.flatten_up_to(state.nu)
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    return new_p, AdamState(step, new_m, new_v), {"lr": lr, "grad_norm": gnorm}

"""Checkpointing: pytree ↔ directory of .npy leaves + msgpack manifest.

No orbax in this environment; this writes every leaf as a .npy file keyed
by its tree path, plus a manifest with step / config metadata.  Restore
rebuilds into the *template's* structure and dtypes, so it round-trips
through sharded trees (leaves are fully gathered — fine at example scale).
"""
from __future__ import annotations

import json
import os
import re
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np


def _flatten(tree) -> dict:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(re.sub(r"[\[\]'\.]", "", str(p)) for p in path)
        flat[key] = leaf
    return flat


def save_checkpoint(ckpt_dir: str, step: int, tree: Any,
                    metadata: Optional[dict] = None) -> str:
    path = os.path.join(ckpt_dir, f"step_{step:08d}")
    os.makedirs(path, exist_ok=True)
    flat = _flatten(tree)
    for key, leaf in flat.items():
        arr = np.asarray(jax.device_get(leaf))
        np.save(os.path.join(path, key.replace("/", "__") + ".npy"), arr)
    manifest = {"step": step, "keys": sorted(flat.keys()),
                "metadata": metadata or {}}
    with open(os.path.join(path, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    return path


def latest_checkpoint(ckpt_dir: str) -> Optional[str]:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = [d for d in os.listdir(ckpt_dir) if d.startswith("step_")]
    return os.path.join(ckpt_dir, max(steps)) if steps else None


def restore_checkpoint(path: str, template: Any) -> tuple[Any, dict]:
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    flat_t = _flatten(template)
    if sorted(flat_t.keys()) != manifest["keys"]:
        missing = set(manifest["keys"]) ^ set(flat_t.keys())
        raise ValueError(f"checkpoint/template structure mismatch: {sorted(missing)[:5]}")
    loaded = {}
    for key in manifest["keys"]:
        arr = np.load(os.path.join(path, key.replace("/", "__") + ".npy"))
        loaded[key] = jnp.asarray(arr, dtype=flat_t[key].dtype)
    # rebuild in template order
    leaves_order = [loaded[k] for k in flat_t.keys()]
    treedef = jax.tree.structure(template)
    flat_template_order = list(flat_t.keys())
    # tree_flatten_with_path and tree.flatten agree on leaf order
    return treedef.unflatten(leaves_order), manifest

"""Shape/variant resolution + abstract input specs (shared by the dry-run
and the roofline analyzer; no jax device-count side effects here)."""
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import INPUT_SHAPES, ModelConfig, ShapeConfig
from repro.configs.registry import get_config
from repro.models.model import Model

SWA_VARIANT_WINDOW = 4096


def arch_for_shape(arch: str, shape: ShapeConfig, gamma: int = 0) -> ModelConfig:
    """Resolve the config actually lowered for a shape.

    long_500k on architectures without native sub-quadratic decode gets the
    documented SWA-4096 variant (DESIGN.md §5): every full-attention block
    kind ("attn"/"mla") becomes "swa".  MLA→SWA also switches the attention
    parameterization — an explicit, recorded deviation."""
    cfg = get_config(arch)
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        pattern = tuple("swa" if k in ("attn", "mla") else k
                        for k in cfg.layer_pattern)
        cfg = cfg.with_overrides(
            name=f"{cfg.name}+swa{SWA_VARIANT_WINDOW}",
            layer_pattern=pattern, sliding_window=SWA_VARIANT_WINDOW)
    if cfg.is_encoder_decoder and shape.kind == "decode":
        pattern = tuple("swa" if (shape.name == "long_500k" and k == "attn") else k
                        for k in cfg.layer_pattern)
        if shape.name == "long_500k":
            cfg = cfg.with_overrides(
                name=f"{cfg.name}+swa{SWA_VARIANT_WINDOW}",
                layer_pattern=pattern, sliding_window=SWA_VARIANT_WINDOW)
    return cfg


# ---------------------------------------------------------------------------
# ShapeDtypeStruct input builders (never allocate)
# ---------------------------------------------------------------------------

def sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(shape), jnp.dtype(dtype))


def input_specs(cfg: ModelConfig, shape: ShapeConfig, model: Model,
                gamma: int = 0) -> dict:
    """Abstract inputs for the step function of a shape.kind."""
    B, S = shape.global_batch, shape.seq_len
    if shape.kind == "train":
        batch = {"tokens": sds((B, S), jnp.int32),
                 "labels": sds((B, S), jnp.int32),
                 "mask": sds((B, S), jnp.float32)}
        if cfg.is_encoder_decoder:
            batch["encoder_embeds"] = sds((B, cfg.encoder_seq_len, cfg.d_model),
                                          jnp.dtype(cfg.dtype))
        return {"batch": batch}
    if shape.kind == "prefill":
        out = {"tokens": sds((B, S), jnp.int32),
               "cache": jax.eval_shape(lambda: model.init_cache(B, S)),
               "lengths": sds((B,), jnp.int32)}
        if cfg.is_encoder_decoder:
            out["encoder_embeds"] = sds((B, cfg.encoder_seq_len, cfg.d_model),
                                        jnp.dtype(cfg.dtype))
        return out
    # decode: ONE new token (or gamma+1 verify) against a seq_len cache
    cache = jax.eval_shape(lambda: model.init_cache(B, S))
    if cfg.is_encoder_decoder:
        # cross-attn K/V computed at prefill time: (P, B, S_enc, Hkv, hd)
        dt = jnp.dtype(cfg.dtype)
        kv = sds((cfg.num_periods, B, cfg.encoder_seq_len,
                  cfg.num_kv_heads, cfg.head_dim), dt)
        cache = dict(cache, cross=[{"k": kv, "v": kv}
                                   for _ in range(cfg.period)])
    if gamma > 0:
        return {"tokens": sds((B, gamma + 1), jnp.int32),
                "n_commit": sds((B,), jnp.int32), "cache": cache}
    return {"token": sds((B,), jnp.int32), "cache": cache}



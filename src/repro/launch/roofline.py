"""Roofline analysis over dry-run results (EXPERIMENTS.md §Roofline).

Reads the dry-run JSONL and derives, per (arch × shape × mesh):

    compute term    = HLO_FLOPs_per_device / (peak_FLOP/s)
    memory term     = HLO_bytes_per_device / HBM_bw
    collective term = collective_bytes_per_device / link_bw

(cost_analysis is per-device post-SPMD, verified empirically, so the
"/chips" in the spec formula is already applied), plus

    MODEL_FLOPS = 6·N·D (dense) or 6·N_active·D (MoE) for train,
                  2·N(_active)·D for inference forwards,
    usefulness  = MODEL_FLOPS / (HLO_FLOPs_per_device × devices)

which exposes remat recompute and redundant-dispatch waste (ratio < 1).

    PYTHONPATH=src python -m repro.launch.roofline results/sweep_sp_*.jsonl
"""
from __future__ import annotations

import argparse
import glob
import json
import sys

PEAK_FLOPS = 197e12          # bf16, per chip
HBM_BW = 819e9               # bytes/s per chip
ICI_BW = 50e9                # bytes/s per link


def model_flops(rec: dict) -> float:
    """Useful model FLOPs for the whole step (global, not per-device)."""
    from repro.configs.base import INPUT_SHAPES
    shape = INPUT_SHAPES[rec["shape"]]
    n_active = rec["active_params"]
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_active * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_active * tokens
    tokens = shape.global_batch * (1 + rec.get("gamma", 0))
    return 2.0 * n_active * tokens


def per_device_costs(rec: dict):
    """(flops, bytes, collective_bytes) per device.

    XLA's cost_analysis counts a while/scan body ONCE (verified
    empirically in tests/test_roofline.py), and our stacks nest scans
    (layers, loss chunks, SSM time steps), so raw HLO FLOPs/bytes are
    deflated by data-dependent factors.  We therefore derive the compute
    and memory numerators ANALYTICALLY from the architecture (the same
    census the v5e simulator prices, validated against HLO on scan-free
    lowers), divide by device count, and take the COLLECTIVE census from
    the partitioned HLO (x trip count, since collectives sit inside the
    layer-stack scan body)."""
    from repro.configs.base import INPUT_SHAPES
    from repro.configs.registry import get_config
    from repro.core.simulator import Simulator
    from repro.launch.specs import arch_for_shape
    shape = INPUT_SHAPES[rec["shape"]]
    cfg = arch_for_shape(rec["arch"], shape, rec.get("gamma", 0))
    n_dev = rec["devices"]
    sim = Simulator()
    if shape.kind == "train":
        costs = sim.forward_costs(cfg, shape.global_batch, shape.seq_len,
                                  context_len=shape.seq_len, train=True)
    elif shape.kind == "prefill":
        costs = sim.forward_costs(cfg, shape.global_batch, shape.seq_len,
                                  context_len=shape.seq_len)
    else:
        costs = sim.forward_costs(cfg, shape.global_batch,
                                  1 + rec.get("gamma", 0),
                                  context_len=shape.seq_len)
    P = cfg.num_periods
    coll = rec["collective_bytes_per_device"]
    if "in_loop" in coll:
        c = coll["in_loop"] * P + coll["outside"]
    else:  # legacy record without loop attribution: conservative x P
        c = coll["total"] * P
    return costs["flops"] / n_dev, costs["bytes"] / n_dev, c, P


def next_move(rec: dict, dominant: str, usefulness: float) -> str:
    """One sentence per (arch, shape): what would move the dominant term
    down (the §Roofline deliverable).  Grounded in the measured §Perf
    iterations, not generic advice."""
    from repro.configs.base import INPUT_SHAPES
    from repro.configs.registry import get_config
    shape = INPUT_SHAPES[rec["shape"]]
    try:
        cfg = get_config(rec["arch"])
    except KeyError:
        return ""
    is_moe = cfg.num_experts > 0
    if dominant == "collective":
        if shape.kind == "train" and is_moe:
            return ("--moe-dispatch ep --layout fsdp: a2a expert dispatch + "
                    "no-TP layout (measured -90% on jamba)")
        if shape.kind == "train":
            return ("--layout fsdp removes per-layer TP activation "
                    "all-reduces (measured -91% gemma3, -95% xlstm)")
        return ("decode/prefill collectives are cache-update resharding: "
                "align kv_mode with the head/seq split")
    if dominant == "memory":
        if shape.kind == "decode":
            return ("this is the paper's opportunity: SD verify rides the "
                    "same reads (gamma+1 tokens, +<3% t_mem); beyond that, "
                    "int8 weights / KV quantization")
        return "recompute less (remat policy) or raise arithmetic intensity"
    # compute-dominant
    if usefulness < 0.6 and is_moe:
        return "--moe-dispatch ep removes the E/K one-hot redundancy"
    return (f"at {usefulness:.0%} of useful-FLOP roofline: raise per-chip "
            "batch or trim remat recompute")


def analyze(rec: dict) -> dict:
    n_dev = rec["devices"]
    f, b, c, P = per_device_costs(rec)
    t_compute = f / PEAK_FLOPS
    t_memory = b / HBM_BW
    coll = rec["collective_bytes_per_device"]
    t_coll = c / ICI_BW
    terms = {"compute": t_compute, "memory": t_memory, "collective": t_coll}
    dominant = max(terms, key=terms.get)
    mf = model_flops(rec)
    useful = mf / max(f * n_dev, 1.0)
    bound = max(terms.values())
    return {
        "arch": rec["arch"], "shape": rec["shape"], "mesh": rec["mesh"],
        "t_compute_s": t_compute, "t_memory_s": t_memory,
        "t_collective_s": t_coll, "dominant": dominant,
        "model_flops": mf, "usefulness": useful,
        "scan_trip_count": P,
        "hlo_flops_per_device_raw": rec["flops_per_device"],
        "roofline_bound_s": bound,
        # XLA CPU memory analysis: peak ≈ argument residency (params, opt
        # state, caches); temp_bytes is the SUM of temp allocations — an
        # upper bound on intermediate traffic, not simultaneous residency.
        # Real TPU HBM peak lies between; both are reported.
        "peak_bytes_gb": rec["memory"].get("peak_bytes", 0) / 1e9,
        "temp_sum_gb": rec["memory"]["temp_bytes"] / 1e9,
        "fits_16gb": rec["memory"].get("peak_bytes", 0) < 16e9,
        "next_move": next_move(rec, dominant, useful),
        "collective_breakdown": coll,
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("files", nargs="+")
    ap.add_argument("--csv", action="store_true")
    args = ap.parse_args()
    recs = []
    for pat in args.files:
        for f in glob.glob(pat):
            with open(f) as fh:
                for ln in fh:
                    d = json.loads(ln)
                    if d.get("status") == "ok":
                        recs.append(analyze(d))
    recs.sort(key=lambda r: (r["arch"], r["shape"], r["mesh"]))
    if args.csv:
        print("arch,shape,mesh,t_compute_s,t_memory_s,t_collective_s,"
              "dominant,usefulness,peak_gb,temp_sum_gb,fits_16gb,next_move")
        for r in recs:
            print(f"{r['arch']},{r['shape']},{r['mesh']},"
                  f"{r['t_compute_s']:.4g},{r['t_memory_s']:.4g},"
                  f"{r['t_collective_s']:.4g},{r['dominant']},"
                  f"{r['usefulness']:.3f},{r['peak_bytes_gb']:.2f},"
                  f"{r['temp_sum_gb']:.2f},{r['fits_16gb']},"
                  f"\"{r['next_move']}\"")
    else:
        for r in recs:
            print(json.dumps(r))


if __name__ == "__main__":
    main()

"""Training launcher.

Reduced configs run for real on this host; full configs are for the
dry-run (use launch/dryrun.py).  On a real multi-host TPU deployment this
same file runs under `python -m repro.launch.train --arch ... --mesh prod`
after jax.distributed.initialize() — the step function and shardings are
identical to the dry-run's.

  PYTHONPATH=src python -m repro.launch.train --arch qwen2-7b --reduced \
      --steps 50 --batch 8 --seq 128
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import TrainConfig
from repro.configs.registry import get_config
from repro.data.pipeline import packed_batches
from repro.models.model import Model
from repro.training.checkpoint import save_checkpoint
from repro.training.train_loop import init_train_state, make_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--kind", default="code", choices=["code", "chat"])
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch, reduced=args.reduced)
    tcfg = TrainConfig(learning_rate=args.lr, total_steps=args.steps,
                       warmup_steps=max(args.steps // 10, 1), seed=args.seed)
    model = Model(cfg, remat=True)
    params, opt = init_train_state(model, jax.random.PRNGKey(args.seed))
    n_params = sum(x.size for x in jax.tree.leaves(params))
    print(f"arch={cfg.name} params={n_params/1e6:.1f}M "
          f"batch={args.batch}x{args.seq}")

    step_fn = jax.jit(make_train_step(model, tcfg), donate_argnums=(0, 1))
    it = packed_batches(cfg.vocab_size, args.batch, args.seq, kind=args.kind,
                        seed=args.seed)

    def make_batch():
        b = next(it)
        out = {k: jnp.asarray(v) for k, v in b.items()}
        if cfg.is_encoder_decoder:
            out["encoder_embeds"] = jax.random.normal(
                jax.random.PRNGKey(0),
                (args.batch, cfg.encoder_seq_len, cfg.d_model),
                jnp.dtype(cfg.dtype)) * 0.02
        return out

    t0 = time.perf_counter()
    for step in range(1, args.steps + 1):
        params, opt, metrics = step_fn(params, opt, make_batch())
        if step % args.log_every == 0 or step == 1:
            jax.block_until_ready(metrics["loss"])
            dt = time.perf_counter() - t0
            print(f"step {step:5d}  loss {float(metrics['loss']):.4f}  "
                  f"aux {float(metrics['aux_loss']):.4f}  "
                  f"gnorm {float(metrics['grad_norm']):.3f}  "
                  f"lr {float(metrics['lr']):.2e}  {dt:.1f}s")
    if args.ckpt_dir:
        path = save_checkpoint(args.ckpt_dir, args.steps,
                               {"params": params, "opt": opt},
                               {"arch": cfg.name})
        print("saved", path)


if __name__ == "__main__":
    main()

"""Production mesh construction.

Single pod: 16x16 = 256 v5e chips, axes ("data", "model").
Multi-pod:  2x16x16 = 512 chips, axes ("pod", "data", "model") — "pod"
carries data parallelism across the pod boundary (DCN-ish links), so only
gradient/all-reduce traffic crosses pods; "model" stays intra-pod.

A FUNCTION, not a module constant: importing this module must never touch
jax device state (device count is locked at first backend init; see
launch/dryrun.py which force-creates 512 host devices *before* any import).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_test_mesh(shape=(2, 2), axes=("data", "model")):
    """Small mesh for CPU integration tests (requires >= prod(shape) devices,
    e.g. via XLA_FLAGS=--xla_force_host_platform_device_count=4)."""
    return jax.make_mesh(shape, axes)


def make_ep_mesh(ep_degree: int, *, data_degree: int = 1, devices=None):
    """``("data", "model")`` mesh for expert-parallel serving: the model
    axis spans ``ep_degree`` devices (each holding E/ep_degree experts),
    the data axis spans ``data_degree``.  ``data_degree=1`` (the default)
    is the 1×N layout serving parity tests pin — batch stays whole, only
    expert weights and the a2a dispatch shard.  Uses the first
    ``data_degree*ep_degree`` of the available devices, so it works on
    forced host devices (XLA_FLAGS=--xla_force_host_platform_device_count=8)
    with any ep_degree dividing the forced count."""
    import numpy as np
    from jax.sharding import Mesh

    devs = list(devices) if devices is not None else jax.devices()
    need = data_degree * ep_degree
    if ep_degree < 1 or data_degree < 1:
        raise ValueError(f"degrees must be >= 1, got {data_degree}x{ep_degree}")
    if len(devs) < need:
        raise ValueError(
            f"mesh {data_degree}x{ep_degree} needs {need} devices, "
            f"have {len(devs)} (set XLA_FLAGS="
            f"--xla_force_host_platform_device_count={need} before import)")
    return Mesh(np.asarray(devs[:need]).reshape(data_degree, ep_degree),
                ("data", "model"))


def data_axes(mesh) -> tuple:
    """The batch-parallel axes of a mesh: ("pod","data") or ("data",)."""
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def model_axis_size(mesh) -> int:
    return mesh.shape["model"]


def data_axis_size(mesh) -> int:
    import math
    return math.prod(mesh.shape[a] for a in data_axes(mesh))

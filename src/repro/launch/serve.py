"""Serving launcher: batched speculative decoding with auto-tuned gamma.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen2-57b-a14b --reduced \
      --requests 16 --max-batch 8 --max-new 32 --proposer model

``--proposer`` selects the drafting strategy through the Proposer registry
(core/proposer.py): "model" (small draft model), "eagle" (speculation head
on the target's features), "prefetch" (small draft model + draft-phase
expert warming, printing per-wave hit rates — core/prefetch.py), or "none"
(plain AR baseline).

``--scheduler continuous`` switches from wave decoding to the slot
scheduler (serving/scheduler.py): a fixed pool of KV slots, per-slot
retirement, in-flight admission between rounds and {use_sd, gamma}
re-planned on the live slot count every round.  ``--arrival-rate`` replays
a Poisson arrival trace (mean arrivals per decode round) and
``--mixed-max-new`` draws each request's budget from a comma list — the
mixed-length traffic where wave padding costs the most.

Admission knobs (continuous mode): ``--admit-mode sliced`` (default)
prefills only the admitted rows per refill (``full`` keeps the legacy
pool-wide prefill for comparison); ``--prefill-chunk N`` prefills long
prompts N tokens per round boundary instead of stalling one round;
``--kv-layout paged --page-size N`` stores target KV in block-table pages
so capacity grows with the traffic instead of being sized for the
worst-case request up front.
"""
from __future__ import annotations

import argparse

import jax
import numpy as np

from repro.configs.registry import draft_for, get_config
from repro.core.analytics import occupancy_timeline
from repro.core.autotune import AutoTuner
from repro.core.proposer import registered_proposers
from repro.data.pipeline import prompt_batch
from repro.data.tokenizer import ByteTokenizer
from repro.models.model import Model
from repro.serving.engine import ServingEngine
from repro.serving.scheduler import submit_poisson


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--max-batch", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=32)
    ap.add_argument("--gamma", type=int, default=4)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--kind", default="chat", choices=["code", "chat"])
    ap.add_argument("--proposer", default="model",
                    choices=sorted(registered_proposers()),
                    help="drafting strategy (Proposer registry kind)")
    ap.add_argument("--prefetch-top-m", type=int, default=None,
                    help="experts to warm per period-slot with --proposer "
                         "prefetch (default: min(E, 2K))")
    ap.add_argument("--moe-dispatch", default="gmm",
                    choices=["onehot", "gmm", "ep"],
                    help="MoE dispatch for the decode path; the serving "
                         "default is the ragged grouped-matmul kernel "
                         "(training keeps onehot); ep = mesh-sharded "
                         "experts with all-to-all dispatch (--ep-degree)")
    ap.add_argument("--ep-degree", type=int, default=1,
                    help="expert-parallel shards: builds a (1, N) "
                         "('data','model') mesh, shards expert weights "
                         "over it and serves through the all-to-all "
                         "ragged dispatch (forces --moe-dispatch ep when "
                         "> 1; docs/distributed.md)")
    ap.add_argument("--mesh-layout", default="tp", choices=["tp", "fsdp"],
                    help="parameter layout on the mesh for the non-expert "
                         "weights (distributed/sharding.param_spec)")
    ap.add_argument("--scheduler", default="wave",
                    choices=["wave", "continuous"],
                    help="wave: static batch per wave; continuous: slot "
                         "pool with in-flight admission and per-round "
                         "N(t) re-planning (serving/scheduler.py)")
    ap.add_argument("--arrival-rate", type=float, default=0.0,
                    help="continuous mode: Poisson mean arrivals per decode "
                         "round (0 = everything arrives at round 0)")
    ap.add_argument("--mixed-max-new", default=None,
                    help="comma list of max_new_tokens choices drawn per "
                         "request (default: --max-new for every request)")
    ap.add_argument("--eos-id", type=int, default=None,
                    help="early-exit token id (per-request finish_reason)")
    ap.add_argument("--admit-mode", default="sliced",
                    choices=["sliced", "full"],
                    help="continuous admission: prefill only the admitted "
                         "rows (sliced, default) or the whole pool (full, "
                         "the legacy path kept for comparison)")
    ap.add_argument("--prefill-chunk", type=int, default=None,
                    help="continuous mode: prefill prompts longer than "
                         "this in chunks interleaved with decode rounds")
    ap.add_argument("--kv-layout", default="dense",
                    choices=["dense", "paged"],
                    help="target KV layout; paged = block-table pages "
                         "with on-demand growth (continuous mode)")
    ap.add_argument("--page-size", type=int, default=64,
                    help="positions per KV page with --kv-layout paged")
    ap.add_argument("--paged-attention", default="kernel",
                    choices=["kernel", "gather"],
                    help="paged decode/verify attention: the block-table-"
                         "walking Pallas kernel (default) or the dense "
                         "pool[table] gather fallback "
                         "(docs/paged_attention.md)")
    ap.add_argument("--prefix-sharing", action="store_true",
                    help="paged mode: admissions whose prompt shares a "
                         "page-aligned prefix with a live slot fork its "
                         "pages (refcounted CoW) and prefill only the tail")
    ap.add_argument("--admission-order", default="fifo",
                    choices=["fifo", "pressure"],
                    help="continuous refill order; pressure picks the "
                         "smallest-page-footprint admissible request when "
                         "the paged pool is under pressure")
    ap.add_argument("--shared-prefix", type=int, default=0,
                    help="prepend this many common system-prompt tokens to "
                         "every request (exercises --prefix-sharing)")
    ap.add_argument("--round-deadline-s", type=float, default=None,
                    help="resilience: per-round wall-clock deadline; "
                         "slower rounds count toward the degradation "
                         "ladder (docs/faults.md)")
    ap.add_argument("--max-rounds-per-request", type=int, default=None,
                    help="resilience: per-request round budget "
                         "(finish_reason='timeout' past it)")
    ap.add_argument("--free-page-watermark", type=float, default=0.0,
                    help="resilience: defer admissions that would leave "
                         "the paged pool's free fraction below this")
    ap.add_argument("--max-pool-pages", type=int, default=None,
                    help="resilience: hard cap on paged pool growth; at "
                         "the cap page pressure preempts the youngest "
                         "slot (vLLM-style recompute requeue)")
    ap.add_argument("--transfer-guard", action="store_true",
                    help="after the stream completes, replay the same "
                         "workload through the warm engine under "
                         "transfer_guard + sharding_guard and fail on any "
                         "implicit host transfer or second input-sharding "
                         "signature (docs/analysis.md)")
    ap.add_argument("--timed", action="store_true",
                    help="record per-phase propose/verify/reject timings")
    ap.add_argument("--no-autotune", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch, reduced=args.reduced)
    mesh = None
    if args.ep_degree > 1:
        from repro.launch.mesh import make_ep_mesh
        if args.moe_dispatch != "ep":
            print(f"--ep-degree {args.ep_degree}: forcing --moe-dispatch ep")
            args.moe_dispatch = "ep"
        if cfg.num_experts % args.ep_degree != 0:
            raise SystemExit(
                f"--ep-degree {args.ep_degree} does not divide "
                f"num_experts={cfg.num_experts} for {args.arch}")
        mesh = make_ep_mesh(args.ep_degree)
        print(f"mesh: {dict(mesh.shape)} layout={args.mesh_layout} "
              f"({len(mesh.devices.flat)} devices)")
    target = Model(cfg, moe_dispatch=args.moe_dispatch,
                   paged_attention=args.paged_attention, mesh=mesh,
                   mesh_layout=args.mesh_layout if mesh is not None else None)
    params_t = target.init(jax.random.PRNGKey(args.seed))

    if args.proposer == "eagle":
        from repro.core.eagle import EagleHead
        draft = EagleHead(target)
        params_d = draft.init(jax.random.PRNGKey(args.seed + 1))
    elif args.proposer == "none":
        draft, params_d = None, None
    else:
        dcfg = draft_for(cfg) if not args.reduced else \
            draft_for(cfg).with_overrides(
                num_layers=2, d_model=128, num_heads=4, num_kv_heads=2,
                d_ff=256, dtype="float32")
        draft = Model(dcfg)
        params_d = draft.init(jax.random.PRNGKey(args.seed + 1))

    if args.no_autotune or args.proposer == "none":
        tuner = None
    else:
        full_cfg = get_config(args.arch)
        if args.proposer == "eagle":
            # price the drafter as the head actually serving (one block on
            # the full target), not a standalone small model
            from repro.core.eagle import EagleHead
            tuner_draft = EagleHead(Model(full_cfg)).cfg
        else:
            tuner_draft = draft_for(full_cfg)
        tuner = AutoTuner(full_cfg, tuner_draft, alpha=0.7)
    proposer_opts = {}
    if args.proposer == "prefetch" and args.prefetch_top_m is not None:
        proposer_opts["top_m"] = args.prefetch_top_m
    from repro.serving.faults import ResilienceConfig
    resilience = ResilienceConfig(
        round_deadline_s=args.round_deadline_s,
        max_rounds_per_request=args.max_rounds_per_request,
        free_page_watermark=args.free_page_watermark,
        max_pool_pages=args.max_pool_pages)
    eng = ServingEngine(target, draft, params_t, params_d,
                        max_batch=args.max_batch, tuner=tuner,
                        gamma=args.gamma, temperature=args.temperature,
                        proposer=args.proposer, proposer_opts=proposer_opts,
                        seed=args.seed, timed=args.timed,
                        scheduler=args.scheduler, eos_id=args.eos_id,
                        admit_mode=args.admit_mode,
                        prefill_chunk=args.prefill_chunk,
                        kv_layout=args.kv_layout, page_size=args.page_size,
                        prefix_sharing=args.prefix_sharing,
                        admission_order=args.admission_order,
                        resilience=resilience, mesh=mesh,
                        mesh_layout=args.mesh_layout if mesh is not None
                        else None)

    pb = prompt_batch(cfg.vocab_size, args.requests, kind=args.kind,
                      seed=args.seed)
    if args.shared_prefix > 0:
        # one common system prompt ahead of every request — the workload
        # shape prefix sharing is built for
        rng = np.random.default_rng(args.seed + 17)
        sys_toks = rng.integers(1, cfg.vocab_size,
                                size=args.shared_prefix).astype(np.int32)
        pb["tokens"] = [np.concatenate([sys_toks, np.asarray(
            pb["tokens"][i][: int(pb["lengths"][i])], np.int32)])
            for i in range(len(pb["lengths"]))]
        pb["lengths"] = [int(n) + args.shared_prefix
                         for n in pb["lengths"]]
    max_new_choices = ([int(x) for x in args.mixed_max_new.split(",")]
                       if args.mixed_max_new else [args.max_new])
    submit_poisson(eng, pb["tokens"], pb["lengths"],
                   rate=args.arrival_rate, max_new_choices=max_new_choices,
                   seed=args.seed)

    reports = eng.run()
    tok = ByteTokenizer(cfg.vocab_size)
    for r in reports:
        # AR waves carry SDStats too (same loop) but sigma/alpha are
        # degenerate there — label them as the baseline
        sd = (f"sigma={r.stats.sigma:.3f} alpha={r.stats.alpha:.3f} "
              f"rounds={r.stats.rounds}" if r.used_sd and r.stats else "AR")
        timing = (f" propose={r.propose_time:.3f}s verify={r.verify_time:.3f}s"
                  f" reject={r.reject_time:.3f}s" if args.timed else "")
        if args.timed and r.warm_time:
            timing += f" warm={r.warm_time:.3f}s"
        # gate on the stats, not the kind string: any provides_prefetch
        # proposer populates the accounting
        pf = (f" prefetch_hit={r.prefetch_hit_rate:.2f} "
              f"({r.prefetch_hits}/{r.stats.prefetch_actual})"
              if r.stats and r.stats.prefetch_actual else "")
        print(f"{r.scheduler}: B={r.batch}/{r.bucket} gamma={r.gamma} "
              f"proposer={r.proposer} dispatch={r.moe_dispatch} "
              f"sd={r.used_sd} {r.tokens_per_second:.1f} tok/s  "
              f"{sd}{pf}{timing}")
        if r.steps:
            occ = occupancy_timeline([s.live for s in r.steps],
                                     [s.committed for s in r.steps])
            handoffs = sum(1 for a, b in zip(r.steps, r.steps[1:])
                           if a.used_sd != b.used_sd)
            print(f"  N(t): peak={occ['peak_live']:.0f} "
                  f"mean={occ['mean_live']:.2f} "
                  f"token_weighted={occ['token_weighted_live']:.2f} "
                  f"occupancy={occ['mean_occupancy']:.2f}  "
                  f"admitted={sum(s.admitted for s in r.steps)} "
                  f"retired={sum(s.retired for s in r.steps)} "
                  f"sd_handoffs={handoffs}")
            shared = sum(s.shared_tokens for s in r.steps)
            print(f"  admission: {sum(s.admit_rows for s in r.steps)} "
                  f"prefill rows, {sum(s.admit_tokens for s in r.steps)} "
                  f"row-tokens ({args.admit_mode})"
                  + (f", {shared} prefix-shared tokens" if shared else ""))
        if r.ep is not None:
            # expert-parallel wave telemetry: per-shard routed load of the
            # wave's outputs, skew, and modeled per-device a2a volume
            print(f"  ep: shards={r.ep['per_shard_load']} "
                  f"imbalance={r.ep['imbalance']:.2f} "
                  f"a2a={r.ep['a2a_bytes_per_device'] / 1e6:.3f} MB/device")
    for kind, s in eng.session_stats().items():
        if kind == "resilience":
            if s:                 # fault/preemption/recovery counters
                print("resilience:", " ".join(f"{k}={v}"
                                              for k, v in sorted(s.items())))
            continue
        print(f"session[{kind}]: constructed {s['constructions']}x, "
              f"gammas compiled {s['gammas_compiled']}, "
              f"{len(s['traces'])} round traces, "
              f"{len(s['admit_traces'])} admit traces, "
              f"{len(s['chunk_traces'])} chunk traces, "
              f"{len(s['growths'])} growths")
    sample = eng.done[1]
    print(f"sample completion ({sample.finish_reason}):",
          repr(tok.decode(sample.output)[:80]))

    if args.transfer_guard:
        # warm replay under the runtime guards: the first stream built
        # every program, so this one must move nothing implicitly and
        # keep one input-sharding signature per cached program
        from repro.analysis import sharding_guard, transfer_guard
        submit_poisson(eng, pb["tokens"], pb["lengths"],
                       rate=args.arrival_rate,
                       max_new_choices=max_new_choices, seed=args.seed)
        with transfer_guard() as tg, sharding_guard(eng) as sg:
            eng.run()
        print(f"transfer_guard: {tg.count} implicit transfer(s); "
              f"{sg.render()}")
        if tg.count or not sg.ok:
            for line in tg.lines[:10]:
                print(" ", line)
            raise SystemExit(
                "guard violation on the warm stream replay")


if __name__ == "__main__":
    main()

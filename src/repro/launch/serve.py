"""Serving launcher: batched speculative decoding with auto-tuned gamma.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen2-57b-a14b --reduced \
      --requests 16 --max-batch 8 --max-new 32
"""
from __future__ import annotations

import argparse

import jax
import numpy as np

from repro.configs.registry import draft_for, get_config
from repro.core.autotune import AutoTuner
from repro.data.pipeline import prompt_batch
from repro.data.tokenizer import ByteTokenizer
from repro.models.model import Model
from repro.serving.engine import ServingEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--max-batch", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=32)
    ap.add_argument("--gamma", type=int, default=4)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--kind", default="chat", choices=["code", "chat"])
    ap.add_argument("--no-autotune", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch, reduced=args.reduced)
    dcfg = draft_for(cfg) if not args.reduced else draft_for(cfg).with_overrides(
        num_layers=2, d_model=128, num_heads=4, num_kv_heads=2, d_ff=256,
        dtype="float32")
    target, draft = Model(cfg), Model(dcfg)
    params_t = target.init(jax.random.PRNGKey(args.seed))
    params_d = draft.init(jax.random.PRNGKey(args.seed + 1))

    tuner = None if args.no_autotune else AutoTuner(
        get_config(args.arch), draft_for(get_config(args.arch)), alpha=0.7)
    eng = ServingEngine(target, draft, params_t, params_d,
                        max_batch=args.max_batch, tuner=tuner,
                        gamma=args.gamma, temperature=args.temperature)

    pb = prompt_batch(cfg.vocab_size, args.requests, kind=args.kind,
                      seed=args.seed)
    for i in range(args.requests):
        eng.submit(pb["tokens"][i][: pb["lengths"][i]], args.max_new)

    reports = eng.run()
    tok = ByteTokenizer(cfg.vocab_size)
    for r in reports:
        sd = f"sigma={r.stats.sigma:.3f} alpha={r.stats.alpha:.3f} " \
             f"rounds={r.stats.rounds}" if r.stats else "AR"
        print(f"wave: B={r.batch} gamma={r.gamma} sd={r.used_sd} "
              f"{r.tokens_per_second:.1f} tok/s  {sd}")
    sample = eng.done[1]
    print("sample completion:", repr(tok.decode(sample.output)[:80]))


if __name__ == "__main__":
    main()

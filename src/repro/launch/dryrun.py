import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^^ MUST precede every other import: jax locks the device count at first
# backend initialization, and the production dry-run needs 512 placeholder
# host devices to build the 16x16 / 2x16x16 meshes.  (Never set globally —
# smoke tests and benches must see 1 device.)

"""Multi-pod dry-run: lower + compile every (arch x input-shape x mesh)
combination with production shardings, WITHOUT allocating a single real
array (ShapeDtypeStruct stand-ins all the way).

Per combination this emits: memory_analysis (fits/doesn't), cost_analysis
FLOPs/bytes, and the collective-byte census parsed from the partitioned
HLO — the three roofline terms of EXPERIMENTS.md §Roofline.

  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen2-7b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all --multi-pod --out results/
"""
import argparse
import json
import re
import time
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import INPUT_SHAPES, ModelConfig, ShapeConfig, TrainConfig
from repro.configs.registry import ASSIGNED, draft_for, get_config
from repro.distributed.sharding import (
    batch_sharding, shard_cache, shard_opt_state, shard_params)
from repro.launch.mesh import make_production_mesh
from repro.models.model import Model
from repro.serving.serve_step import make_decode_step, make_prefill_step, make_verify_step
from repro.training.optimizer import init_adam
from repro.training.train_loop import make_train_step

from repro.launch.specs import (SWA_VARIANT_WINDOW, arch_for_shape, input_specs, sds)

# ---------------------------------------------------------------------------
# lowering
# ---------------------------------------------------------------------------

def hlo_cost_analysis(compiled) -> dict:
    """Normalize ``compiled.cost_analysis()`` across jax versions: older
    releases return a dict, newer ones a per-device list of dicts."""
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    return cost


def _collective_bytes(hlo_text: str) -> dict:
    """Collective census over partitioned HLO.

    Sums operand bytes of every collective op, attributed to whether the op
    sits inside a while-loop body (the layer-stack scan: executes
    ``num_periods`` times — multiplied by the trip count downstream in
    launch/roofline.py) or in the entry computation (executes once, e.g.
    hoisted FSDP all-gathers)."""
    dt_bytes = {"f32": 4, "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "s8": 1,
                "u8": 1, "pred": 1, "s64": 8, "u64": 8, "f64": 8, "s16": 2,
                "u16": 2, "c64": 8, "f8e4m3fn": 1, "f8e5m2": 1}
    name_bytes = {}
    op_re = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(\([^)]*\)|\S+?)\s+([\w\-]+)\(")
    comp_re = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\([^=]*\)\s*->.*\{")
    body_re = re.compile(r"body=%?([\w.\-]+)")
    type_re = re.compile(r"(\w+?)\[([\d,]*)\]")

    def type_bytes(tstr: str) -> int:
        total = 0
        for m in type_re.finditer(tstr):
            dt, dims = m.group(1), m.group(2)
            n = 1
            if dims:
                for d in dims.split(","):
                    n *= int(d)
            total += n * dt_bytes.get(dt, 4)
        return total

    lines = hlo_text.splitlines()
    body_names = set()
    for ln in lines:
        for m in body_re.finditer(ln):
            body_names.add(m.group(1))

    ops = []
    current_comp = ""
    for ln in lines:
        cm = comp_re.match(ln)
        if cm and ln.rstrip().endswith("{"):
            current_comp = cm.group(1)
            continue
        m = op_re.match(ln)
        if not m:
            continue
        name, tstr, opcode = m.groups()
        name_bytes[name] = type_bytes(tstr)
        ops.append((name, opcode, ln, current_comp))

    kinds = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
             "collective-permute")
    out = {k: 0 for k in kinds}
    out["in_loop"] = 0
    out["outside"] = 0
    operand_re = re.compile(r"%?([\w.\-]+)")
    for name, opcode, ln, comp in ops:
        kind = next((k for k in kinds if opcode.startswith(k)), None)
        if kind is None:
            continue
        args = ln.split("(", 1)[1].split(")")[0]
        ob = 0
        for tok in args.split(","):
            tok = tok.strip()
            m = operand_re.match(tok.lstrip("%"))
            if m and m.group(1) in name_bytes:
                ob += name_bytes[m.group(1)]
        ob = ob if ob else name_bytes.get(name, 0)
        out[kind] += ob
        if comp in body_names:
            out["in_loop"] += ob
        else:
            out["outside"] += ob
    out["total"] = sum(out[k] for k in kinds)
    return out


def lower_combo(arch: str, shape_name: str, *, multi_pod: bool = False,
                gamma: int = 0, donate: bool = True,
                moe_dispatch: str = "onehot",
                fsdp_min_size: int = 0,
                kv_mode: str = "auto",
                layout: str = "tp",
                remat: Optional[bool] = None,
                extra_overrides: Optional[dict] = None) -> dict:
    shape = INPUT_SHAPES[shape_name]
    cfg = arch_for_shape(arch, shape, gamma)
    if extra_overrides:
        cfg = cfg.with_overrides(**extra_overrides)
    mesh = make_production_mesh(multi_pod=multi_pod)
    model = Model(cfg, moe_dispatch=moe_dispatch,
                  remat=(shape.kind == "train") if remat is None else remat,
                  mesh=mesh, mesh_layout=layout)

    params_shape = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    params_sh = shard_params(params_shape, mesh, fsdp=(shape.kind == "train"),
                             fsdp_min_size=fsdp_min_size, layout=layout)
    specs = input_specs(cfg, shape, model, gamma)
    t0 = time.time()

    with mesh:
        if shape.kind == "train":
            opt_shape = jax.eval_shape(init_adam, params_shape)
            opt_sh = shard_opt_state(opt_shape, params_sh, mesh)
            batch_sh = batch_sharding(mesh, specs["batch"], layout=layout)
            step = make_train_step(model, TrainConfig())
            jitted = jax.jit(step,
                             in_shardings=(params_sh, opt_sh, batch_sh),
                             out_shardings=(params_sh, opt_sh, None),
                             donate_argnums=(0, 1) if donate else ())
            lowered = jitted.lower(params_shape, opt_shape, specs["batch"])
        elif shape.kind == "prefill":
            cache_sh = shard_cache(specs["cache"], mesh, kv_mode=kv_mode)
            tok_sh = batch_sharding(mesh, specs["tokens"])
            len_sh = batch_sharding(mesh, specs["lengths"])
            step = make_prefill_step(model)
            if cfg.is_encoder_decoder:
                enc_sh = batch_sharding(mesh, specs["encoder_embeds"])
                jitted = jax.jit(
                    lambda p, t, c, l, e: step(p, t, c, lengths=l,
                                               encoder_embeds=e),
                    in_shardings=(params_sh, tok_sh, cache_sh, len_sh, enc_sh),
                    donate_argnums=(2,) if donate else ())
                lowered = jitted.lower(params_shape, specs["tokens"],
                                       specs["cache"], specs["lengths"],
                                       specs["encoder_embeds"])
            else:
                jitted = jax.jit(
                    lambda p, t, c, l: step(p, t, c, lengths=l),
                    in_shardings=(params_sh, tok_sh, cache_sh, len_sh),
                    donate_argnums=(2,) if donate else ())
                lowered = jitted.lower(params_shape, specs["tokens"],
                                       specs["cache"], specs["lengths"])
        else:  # decode
            cache_sh = shard_cache(specs["cache"], mesh, kv_mode=kv_mode)
            if gamma > 0:
                step = make_verify_step(model, gamma)
                tok_sh = batch_sharding(mesh, specs["tokens"])
                n_sh = batch_sharding(mesh, specs["n_commit"])
                jitted = jax.jit(step,
                                 in_shardings=(params_sh, tok_sh, n_sh, cache_sh),
                                 out_shardings=(None, cache_sh),
                                 donate_argnums=(3,) if donate else ())
                lowered = jitted.lower(params_shape, specs["tokens"],
                                       specs["n_commit"], specs["cache"])
            else:
                step = make_decode_step(model)
                tok_sh = batch_sharding(mesh, specs["token"])
                jitted = jax.jit(step,
                                 in_shardings=(params_sh, tok_sh, cache_sh),
                                 out_shardings=(None, cache_sh),
                                 donate_argnums=(2,) if donate else ())
                lowered = jitted.lower(params_shape, specs["token"],
                                       specs["cache"])

        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    mem = compiled.memory_analysis()
    cost = hlo_cost_analysis(compiled)
    coll = _collective_bytes(compiled.as_text())
    n_dev = mesh.devices.size
    result = {
        "arch": arch, "config": cfg.name, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16", "devices": int(n_dev),
        "gamma": gamma,
        "params": int(cfg.param_count()),
        "active_params": int(cfg.active_param_count()),
        "flops_per_device": float(cost.get("flops", -1.0)),
        "bytes_per_device": float(cost.get("bytes accessed", -1.0)),
        "collective_bytes_per_device": coll,
        "memory": {
            "argument_bytes": int(getattr(mem, "argument_size_in_bytes", 0)),
            "output_bytes": int(getattr(mem, "output_size_in_bytes", 0)),
            "temp_bytes": int(getattr(mem, "temp_size_in_bytes", 0)),
            # some backends report 0 peak; fall back to the conservative
            # bound arguments + outputs + temporaries all live at once
            "peak_bytes": int(
                getattr(mem, "peak_memory_in_bytes", 0) or 0) or (
                int(getattr(mem, "argument_size_in_bytes", 0))
                + int(getattr(mem, "output_size_in_bytes", 0))
                + int(getattr(mem, "temp_size_in_bytes", 0))),
        },
        "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
        "knobs": {"moe_dispatch": moe_dispatch, "kv_mode": kv_mode,
                  "fsdp_min_size": fsdp_min_size, "layout": layout},
    }
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=list(INPUT_SHAPES))
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--gamma", type=int, default=0,
                    help=">0 lowers the SD verify step instead of AR decode")
    ap.add_argument("--moe-dispatch", default="onehot")
    ap.add_argument("--fsdp-min-size", type=int, default=0)
    ap.add_argument("--kv-mode", default="auto", choices=["auto", "seq", "heads"])
    ap.add_argument("--layout", default="tp", choices=["tp", "fsdp"])
    ap.add_argument("--out", default=None, help="append JSONL here")
    args = ap.parse_args()

    combos = []
    if args.all:
        for a in ASSIGNED:
            for s in INPUT_SHAPES:
                combos.append((a, s))
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        combos = [(args.arch, args.shape)]

    for arch, shape in combos:
        try:
            res = lower_combo(arch, shape, multi_pod=args.multi_pod,
                              gamma=args.gamma, moe_dispatch=args.moe_dispatch,
                              fsdp_min_size=args.fsdp_min_size,
                              kv_mode=args.kv_mode, layout=args.layout)
            res["status"] = "ok"
        except Exception as e:  # noqa: BLE001 — report, don't abort the sweep
            res = {"arch": arch, "shape": shape,
                   "mesh": "2x16x16" if args.multi_pod else "16x16",
                   "status": "fail", "error": f"{type(e).__name__}: {e}"}
        print(json.dumps(res))
        if args.out:
            with open(args.out, "a") as f:
                f.write(json.dumps(res) + "\n")


if __name__ == "__main__":
    main()

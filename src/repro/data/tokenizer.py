"""Byte-level tokenizer (vocab 256 + specials), mapped into each model's
vocab space.  Enough for end-to-end training/serving examples without
external tokenizer assets."""
from __future__ import annotations

import numpy as np

PAD, BOS, EOS = 0, 1, 2
N_SPECIAL = 3


class ByteTokenizer:
    def __init__(self, vocab_size: int):
        assert vocab_size >= 256 + N_SPECIAL
        self.vocab_size = vocab_size

    def encode(self, text: str, add_bos: bool = True) -> np.ndarray:
        ids = np.frombuffer(text.encode("utf-8"), np.uint8).astype(np.int32) + N_SPECIAL
        if add_bos:
            ids = np.concatenate([[BOS], ids])
        return ids

    def decode(self, ids) -> str:
        ids = np.asarray(ids)
        ids = ids[(ids >= N_SPECIAL) & (ids < 256 + N_SPECIAL)] - N_SPECIAL
        return bytes(ids.astype(np.uint8)).decode("utf-8", errors="replace")

"""Tracer-safety lint: traced-ness dataflow from jit / Pallas entry points.

Walks every function reachable from a ``jax.jit`` / ``pl.pallas_call``
site (plus the registry's known entry points) and flags the classic
tracer leaks that either crash at trace time or — worse — silently bake a
traced value into the compiled program and force retraces:

========  ===========================================================
 T101     Python ``if`` (or ternary / comprehension filter) on a traced
          value — the branch is resolved at trace time.
 T102     Python ``while`` on a traced value.
 T103     ``int()``/``float()``/``bool()`` coercion of a traced value.
 T104     host sync: ``.item()``/``.tolist()``/``np.asarray`` on a tracer.
 T105     f-string / ``str.format`` / logging interpolation of a tracer.
 T106     mutation of captured Python state (closure list, ``self``
          attribute, global) inside a jitted body — runs once at trace
          time, not per call.
 T107     ``assert`` on a traced value.
 T108     ``range()`` bound by a traced value (loop unrolls or crashes).
========  ===========================================================

The traced-ness model (docs/analysis.md): entry-point params are traced
unless declared in ``static_argnames``/``static_argnums`` (or bound by a
``functools.partial``); traced-ness propagates through assignments,
arithmetic, subscripts and calls; ``.shape``/``.dtype``/``len()``/
``is None`` and friends are static sinks.  Calls that resolve to project
functions are analyzed interprocedurally with the call site's traced
arguments; protocol-dispatched method calls resolve by method name across
every project class (candidate set).  Mutating a *traced* ref
(``acc_ref[...] = ...`` in a Pallas kernel) is the supported idiom and is
never flagged — T106 fires only for non-traced captured state.
"""
from __future__ import annotations

import ast
from typing import Dict, FrozenSet, List, Optional, Sequence, Set, Tuple

from repro.analysis._astutil import (FuncInfo, ModuleInfo, Project,
                                     assigned_names, call_keywords,
                                     const_eval, dotted_name)
from repro.analysis.findings import Finding
from repro.analysis.registry import (ALWAYS_STATIC_PARAMS,
                                     KNOWN_ENTRY_POINTS, STATIC_RESULT_ATTRS,
                                     STATIC_RESULT_CALLS, lookup_entry)

_JIT_NAMES = ("jax.jit", "jit", "api.jit")
_PALLAS_NAMES = ("pl.pallas_call", "pallas_call", "pallas.pallas_call")
_PARTIAL_NAMES = ("functools.partial", "partial")
_MUTATORS = frozenset({
    "append", "extend", "add", "insert", "update", "pop", "popleft",
    "remove", "clear", "setdefault", "appendleft", "discard", "write",
})
_LOG_METHODS = frozenset({"debug", "info", "warning", "error", "exception",
                          "critical", "log"})
_MAX_DEPTH = 16
_MAX_CANDIDATES = 10
_MAX_ANALYSES = 6000


class TracerLint:
    """One run of the tracer-safety pass over a :class:`Project`."""

    def __init__(self, project: Project):
        self.project = project
        self.findings: Set[Finding] = set()
        self._memo: Dict[Tuple, bool] = {}
        self._active: Set[Tuple] = set()
        self._n_analyses = 0

    # ---------------------------------------------------------------- driver
    def run(self) -> List[Finding]:
        for mod in self.project.modules.values():
            self._discover_module(mod)
        for entry in KNOWN_ENTRY_POINTS:
            for mod in self.project.modules.values():
                if not mod.rel.endswith(entry.module):
                    continue
                fi = mod.functions.get(entry.qualname)
                if fi is not None:
                    self._analyze_entry(fi, static=entry.static)
        return sorted(self.findings, key=lambda f: (f.path, f.line, f.code))

    # ------------------------------------------------------- site discovery
    def _discover_module(self, mod: ModuleInfo) -> None:
        """Visit every node once, attributed to its innermost scope (so a
        ``kernel = functools.partial(...)`` local resolves from the right
        function, not from module level)."""
        scopes: List[Tuple[Optional[FuncInfo], List[ast.AST]]] = [
            (None, list(mod.tree.body))]
        scopes += [(fi, list(fi.body())) for fi in mod.functions.values()]
        for scope, roots in scopes:
            for node in _own_walk(roots):
                if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                    self._discover_def(node, mod, scope)
                elif isinstance(node, ast.Call):
                    self._discover_call(node, mod, scope)

    def _discover_def(self, node: ast.AST, mod: ModuleInfo,
                      scope: Optional[FuncInfo]) -> None:
        fi = self._func_info_for(node, mod, scope)
        if fi is None:
            return
        for dec in node.decorator_list:
            if dotted_name(dec) in _JIT_NAMES:
                self._analyze_entry(fi, static=())
            elif isinstance(dec, ast.Call):
                if dotted_name(dec.func) in _JIT_NAMES:
                    self._analyze_entry(fi, static=self._jit_statics(dec, fi))
                elif dotted_name(dec.func) in _PARTIAL_NAMES and dec.args \
                        and dotted_name(dec.args[0]) in _JIT_NAMES:
                    self._analyze_entry(fi, static=self._jit_statics(dec, fi))

    def _discover_call(self, call: ast.Call, mod: ModuleInfo,
                       scope: Optional[FuncInfo]) -> None:
        name = dotted_name(call.func)
        if name in _JIT_NAMES and call.args:
            statics: Tuple[str, ...] = ()
            for fi, bound in self._resolve_funcexpr(call.args[0], mod, scope):
                self._analyze_entry(
                    fi, static=self._jit_statics(call, fi) + tuple(bound))
        elif name in _PALLAS_NAMES and call.args:
            for fi, bound in self._resolve_funcexpr(call.args[0], mod, scope):
                # kernel refs (scalar + block + out + scratch) are traced;
                # partial-bound tile/config kwargs are static
                self._analyze_entry(fi, static=tuple(bound))

    def _jit_statics(self, call: ast.Call, fi: FuncInfo) -> Tuple[str, ...]:
        kw = call_keywords(call)
        out: List[str] = []
        names = const_eval(kw.get("static_argnames"), {})
        if isinstance(names, str):
            out.append(names)
        elif isinstance(names, tuple):
            out.extend(str(n) for n in names)
        nums = const_eval(kw.get("static_argnums"), {})
        if isinstance(nums, int):
            nums = (nums,)
        if isinstance(nums, tuple):
            pos = fi.positional_params()
            for i in nums:
                if isinstance(i, int) and 0 <= i < len(pos):
                    out.append(pos[i])
        return tuple(out)

    def _func_info_for(self, node: ast.AST, mod: ModuleInfo,
                       scope: Optional[FuncInfo]) -> Optional[FuncInfo]:
        pool = (scope.local_funcs.values() if scope is not None
                else mod.top_funcs.values())
        for cands in pool:
            for fi in cands:
                if fi.node is node:
                    return fi
        for fi in mod.functions.values():
            if fi.node is node:
                return fi
        return None

    def _resolve_funcexpr(self, expr: ast.expr, mod: ModuleInfo,
                          scope: Optional[FuncInfo]
                          ) -> List[Tuple[FuncInfo, Tuple[str, ...]]]:
        """Function candidates for an expression, with partial-bound
        param names (treated static)."""
        if isinstance(expr, ast.Lambda):
            return [(FuncInfo(expr, mod, "<lambda>", scope), ())]
        if isinstance(expr, ast.Name):
            cands = self.project.resolve_name(expr.id, mod, scope)
            if not cands:
                cands = self._resolve_local_assign(expr.id, mod, scope)
                return cands
            return [(c, ()) for c in cands]
        if isinstance(expr, ast.Call):
            name = dotted_name(expr.func)
            if name in _PARTIAL_NAMES and expr.args:
                inner = self._resolve_funcexpr(expr.args[0], mod, scope)
                out = []
                for fi, bound in inner:
                    extra = [kw.arg for kw in expr.keywords if kw.arg]
                    pos = fi.positional_params()
                    extra += pos[: len(expr.args) - 1]
                    out.append((fi, bound + tuple(extra)))
                return out
            # a call returning functions (builder idiom)
            targets = []
            if isinstance(expr.func, ast.Name):
                targets = self.project.resolve_name(expr.func.id, mod, scope)
            elif isinstance(expr.func, ast.Attribute):
                targets = self.project.resolve_attr_call(
                    expr.func.value, expr.func.attr, mod)
            out = []
            for t in targets[:_MAX_CANDIDATES]:
                for pos_cands in self.project.returned_functions(t):
                    for c in pos_cands:
                        out.append((c, ()))
            return out
        if isinstance(expr, ast.Attribute):
            cands = self.project.resolve_attr_call(expr.value, expr.attr, mod)
            return [(c, ()) for c in cands[:_MAX_CANDIDATES]]
        return []

    def _resolve_local_assign(self, name: str, mod: ModuleInfo,
                              scope: Optional[FuncInfo]
                              ) -> List[Tuple[FuncInfo, Tuple[str, ...]]]:
        """Follow ``name = functools.partial(...)`` / ``name = other`` /
        tuple-unpack-from-builder assignments in the enclosing scopes."""
        out: List[Tuple[FuncInfo, Tuple[str, ...]]] = []
        s = scope
        while s is not None and not out:
            for node in ast.walk(s.node):
                if not isinstance(node, ast.Assign):
                    continue
                for tgt in node.targets:
                    if isinstance(tgt, ast.Name) and tgt.id == name:
                        out.extend(self._resolve_funcexpr(node.value, mod, s))
                    elif isinstance(tgt, ast.Tuple):
                        names = [e.id if isinstance(e, ast.Name) else None
                                 for e in tgt.elts]
                        if name in names and isinstance(node.value, ast.Call):
                            idx = names.index(name)
                            for fi, _ in self._resolve_funcexpr(
                                    node.value, mod, s):
                                out.append((fi, ()))
                            # tuple-unpack from a builder: pick position
                            cands = self._builder_position(node.value, mod, s,
                                                           idx)
                            if cands:
                                out = [(c, ()) for c in cands]
            s = s.parent
        return out

    def _builder_position(self, call: ast.expr, mod: ModuleInfo,
                          scope: Optional[FuncInfo], idx: int
                          ) -> List[FuncInfo]:
        if not isinstance(call, ast.Call):
            return []
        targets: List[FuncInfo] = []
        if isinstance(call.func, ast.Name):
            targets = self.project.resolve_name(call.func.id, mod, scope)
        elif isinstance(call.func, ast.Attribute):
            targets = self.project.resolve_attr_call(
                call.func.value, call.func.attr, mod)
        out: List[FuncInfo] = []
        for t in targets[:_MAX_CANDIDATES]:
            rets = self.project.returned_functions(t)
            if idx < len(rets):
                out.extend(rets[idx])
        return out

    # --------------------------------------------------------- analysis core
    def _analyze_entry(self, fi: FuncInfo, static: Sequence[str]) -> None:
        statics = set(static) | ALWAYS_STATIC_PARAMS
        reg = lookup_entry(fi.module.rel, fi.qualname)
        if reg is not None:
            statics |= set(reg.static)
        traced = frozenset(p for p in fi.params() if p not in statics)
        self._analyze(fi, traced, {}, 0)

    def _analyze(self, fi: FuncInfo, traced: FrozenSet[str],
                 closure: Dict[str, bool], depth: int) -> bool:
        """Run the dataflow over one function; returns whether its return
        value is traced.  Memoized on (function, traced params, traced
        closure names)."""
        key = (id(fi.node), traced,
               frozenset(k for k, v in closure.items() if v))
        if key in self._memo:
            return self._memo[key]
        if key in self._active or depth > _MAX_DEPTH \
                or self._n_analyses > _MAX_ANALYSES:
            return bool(traced)               # recursion/limit: best guess
        self._active.add(key)
        self._n_analyses += 1
        walker = _Walker(self, fi, traced, closure, depth)
        result = walker.walk()
        self._active.discard(key)
        self._memo[key] = result
        return result

    def emit(self, fi: FuncInfo, node: ast.AST, code: str,
             message: str) -> None:
        line = getattr(node, "lineno", fi.line)
        self.findings.add(Finding(fi.module.rel, line, code, message))


class _Walker:
    """Single-function traced-ness dataflow + violation detection."""

    def __init__(self, lint: TracerLint, fi: FuncInfo,
                 traced_params: FrozenSet[str], closure: Dict[str, bool],
                 depth: int):
        self.lint = lint
        self.fi = fi
        self.closure = closure
        self.depth = depth
        self.bound: Set[str] = set(fi.params())
        self._collect_bound(fi.body())
        self.traced: Set[str] = set(traced_params)
        self.mutable_free: Set[str] = set()      # global/nonlocal decls
        self.returns_traced = False

    # -------------------------------------------------------------- binding
    def _collect_bound(self, body: Sequence[ast.stmt]) -> None:
        for stmt in body:
            for node in ast.walk(stmt):
                if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                                     ast.Lambda)) and node is not stmt:
                    continue
                if isinstance(node, ast.Assign):
                    for t in node.targets:
                        self.bound.update(assigned_names(t))
                elif isinstance(node, (ast.AugAssign, ast.AnnAssign)):
                    self.bound.update(assigned_names(node.target))
                elif isinstance(node, ast.For):
                    self.bound.update(assigned_names(node.target))
                elif isinstance(node, ast.With):
                    for item in node.items:
                        if item.optional_vars is not None:
                            self.bound.update(
                                assigned_names(item.optional_vars))
                elif isinstance(node, (ast.FunctionDef,
                                       ast.AsyncFunctionDef)):
                    self.bound.add(node.name)
                elif isinstance(node, ast.comprehension):
                    self.bound.update(assigned_names(node.target))

    def _snapshot_closure(self) -> Dict[str, bool]:
        env = dict(self.closure)
        for name in self.bound:
            env[name] = name in self.traced
        return env

    # ----------------------------------------------------------- statements
    def walk(self) -> bool:
        self._visit_body(self.fi.body())
        return self.returns_traced

    def _visit_body(self, body: Sequence[ast.stmt]) -> None:
        for stmt in body:
            self._visit(stmt)

    def _visit(self, stmt: ast.stmt) -> None:
        if isinstance(stmt, ast.Assign):
            t = self.is_traced(stmt.value)
            for tgt in stmt.targets:
                self._bind(tgt, t)
        elif isinstance(stmt, ast.AnnAssign):
            t = self.is_traced(stmt.value) if stmt.value is not None else False
            self._bind(stmt.target, t)
        elif isinstance(stmt, ast.AugAssign):
            t = self.is_traced(stmt.value)
            if isinstance(stmt.target, ast.Name):
                was = stmt.target.id in self.traced
                self._bind(stmt.target, t or was)
            else:
                self._bind(stmt.target, t)
        elif isinstance(stmt, ast.If):
            if self.is_traced(stmt.test):
                self._emit(stmt, "T101",
                           "Python `if` on traced value "
                           f"`{_src(stmt.test)}`")
            self._visit_body(stmt.body)
            self._visit_body(stmt.orelse)
        elif isinstance(stmt, ast.While):
            if self.is_traced(stmt.test):
                self._emit(stmt, "T102",
                           "Python `while` on traced value "
                           f"`{_src(stmt.test)}`")
            for _ in range(2):                  # fixpoint-lite
                self._visit_body(stmt.body)
            self._visit_body(stmt.orelse)
        elif isinstance(stmt, ast.For):
            it = self.is_traced(stmt.iter)
            self._bind(stmt.target, it)
            for _ in range(2):
                self._visit_body(stmt.body)
            self._visit_body(stmt.orelse)
        elif isinstance(stmt, ast.Return):
            if stmt.value is not None and self.is_traced(stmt.value):
                self.returns_traced = True
        elif isinstance(stmt, ast.Expr):
            self.is_traced(stmt.value)
        elif isinstance(stmt, ast.Assert):
            if self.is_traced(stmt.test):
                self._emit(stmt, "T107",
                           f"assert on traced value `{_src(stmt.test)}`")
            if stmt.msg is not None:
                self.is_traced(stmt.msg)
        elif isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef)):
            fi = self.lint._func_info_for(stmt, self.fi.module, self.fi)
            for dec in stmt.decorator_list:
                self.is_traced(dec)
            if stmt.decorator_list and fi is not None:
                # decorated nested def (pl.when idiom): runs at trace time
                self.lint._analyze(fi, frozenset(), self._snapshot_closure(),
                                   self.depth + 1)
        elif isinstance(stmt, (ast.Global, ast.Nonlocal)):
            self.mutable_free.update(stmt.names)
        elif isinstance(stmt, ast.With):
            for item in stmt.items:
                self.is_traced(item.context_expr)
                if item.optional_vars is not None:
                    self._bind(item.optional_vars, False)
            self._visit_body(stmt.body)
        elif isinstance(stmt, ast.Try):
            self._visit_body(stmt.body)
            for h in stmt.handlers:
                self._visit_body(h.body)
            self._visit_body(stmt.orelse)
            self._visit_body(stmt.finalbody)
        elif isinstance(stmt, ast.Raise):
            if stmt.exc is not None:
                self.is_traced(stmt.exc)

    def _bind(self, target: ast.expr, traced: bool) -> None:
        if isinstance(target, ast.Name):
            if traced:
                self.traced.add(target.id)
            else:
                self.traced.discard(target.id)
            return
        if isinstance(target, (ast.Tuple, ast.List)):
            for e in target.elts:
                self._bind(e, traced)
            return
        if isinstance(target, ast.Starred):
            self._bind(target.value, traced)
            return
        # subscript / attribute store: mutation — flag when the base is
        # captured non-traced Python state (T106); traced refs are fine
        base = _base_name(target)
        if base is not None and self._is_free_nontraced(base):
            self._emit(target, "T106",
                       f"mutation of captured `{_src(target)}` inside a "
                       "jitted body (runs at trace time, not per call)")
        if isinstance(target, (ast.Subscript, ast.Attribute)):
            self.is_traced(target.value)

    def _is_free_nontraced(self, name: str) -> bool:
        if name in self.traced:
            return False
        if name in self.mutable_free:
            return True
        if name in self.bound:
            return False
        return not self.closure.get(name, False)

    # ---------------------------------------------------------- expressions
    def is_traced(self, expr: Optional[ast.expr]) -> bool:
        if expr is None:
            return False
        if isinstance(expr, ast.Constant):
            return False
        if isinstance(expr, ast.Name):
            if expr.id in self.traced:
                return True
            if expr.id in self.bound:
                return False
            return self.closure.get(expr.id, False)
        if isinstance(expr, ast.Attribute):
            base = self.is_traced(expr.value)
            if expr.attr in STATIC_RESULT_ATTRS:
                return False
            return base
        if isinstance(expr, ast.Subscript):
            return self.is_traced(expr.value) or self.is_traced(expr.slice)
        if isinstance(expr, ast.Slice):
            return any(self.is_traced(e)
                       for e in (expr.lower, expr.upper, expr.step))
        if isinstance(expr, ast.BinOp):
            return self.is_traced(expr.left) | self.is_traced(expr.right)
        if isinstance(expr, ast.UnaryOp):
            return self.is_traced(expr.operand)
        if isinstance(expr, ast.BoolOp):
            return any(self.is_traced(v) for v in expr.values)
        if isinstance(expr, ast.Compare):
            if all(isinstance(op, (ast.Is, ast.IsNot)) for op in expr.ops):
                self.is_traced(expr.left)
                return False
            if all(isinstance(op, (ast.In, ast.NotIn)) for op in expr.ops) \
                    and isinstance(expr.left, ast.Constant) \
                    and isinstance(expr.left.value, str):
                return False                   # `"key" in pytree_dict`
            return self.is_traced(expr.left) or any(
                self.is_traced(c) for c in expr.comparators)
        if isinstance(expr, ast.Call):
            return self._handle_call(expr)
        if isinstance(expr, ast.IfExp):
            if self.is_traced(expr.test):
                self._emit(expr, "T101",
                           "conditional expression on traced value "
                           f"`{_src(expr.test)}`")
            return self.is_traced(expr.body) or self.is_traced(expr.orelse)
        if isinstance(expr, (ast.Tuple, ast.List, ast.Set)):
            return any(self.is_traced(e) for e in expr.elts)
        if isinstance(expr, ast.Dict):
            return any(self.is_traced(v) for v in expr.values) or any(
                self.is_traced(k) for k in expr.keys if k is not None)
        if isinstance(expr, ast.JoinedStr):
            for v in expr.values:
                if isinstance(v, ast.FormattedValue) \
                        and self.is_traced(v.value):
                    self._emit(v, "T105",
                               "f-string interpolation of traced value "
                               f"`{_src(v.value)}`")
            return False
        if isinstance(expr, ast.Starred):
            return self.is_traced(expr.value)
        if isinstance(expr, ast.Lambda):
            return False
        if isinstance(expr, (ast.ListComp, ast.SetComp, ast.GeneratorExp,
                             ast.DictComp)):
            return self._handle_comp(expr)
        if isinstance(expr, ast.FormattedValue):
            return self.is_traced(expr.value)
        return False

    def _handle_comp(self, expr) -> bool:
        it_traced = False
        for gen in expr.generators:
            gt = self.is_traced(gen.iter)
            it_traced |= gt
            self._bind(gen.target, gt)
            for cond in gen.ifs:
                if self.is_traced(cond):
                    self._emit(cond, "T101",
                               "comprehension filter on traced value "
                               f"`{_src(cond)}`")
        if isinstance(expr, ast.DictComp):
            return (self.is_traced(expr.key) or self.is_traced(expr.value)
                    or it_traced)
        return self.is_traced(expr.elt) or it_traced

    # ---------------------------------------------------------------- calls
    def _handle_call(self, call: ast.Call) -> bool:
        name = dotted_name(call.func) or ""
        arg_traced = [self.is_traced(a) for a in call.args]
        kw_traced = {kw.arg: self.is_traced(kw.value)
                     for kw in call.keywords}
        any_arg = any(arg_traced) or any(kw_traced.values())

        # ---- direct violation patterns
        if name in ("int", "float", "bool", "complex") and any_arg:
            self._emit(call, "T103",
                       f"{name}() coercion of traced value "
                       f"`{_src(call.args[0])}`")
            return False
        if name in ("np.asarray", "np.array", "numpy.asarray",
                    "numpy.array", "onp.asarray", "onp.array") and any_arg:
            self._emit(call, "T104",
                       "np.asarray() host sync of traced value "
                       f"`{_src(call.args[0])}`")
            return True
        if name == "print" and any_arg:
            self._emit(call, "T105",
                       "print() of traced value inside a jitted body")
            return False
        if name == "range" and any_arg:
            self._emit(call, "T108",
                       "range() bound by traced value "
                       f"`{_src(call.args[0])}`")
            return False
        if isinstance(call.func, ast.Attribute):
            recv_traced = self.is_traced(call.func.value)
            attr = call.func.attr
            if attr in ("item", "tolist") and recv_traced:
                self._emit(call, "T104",
                           f".{attr}() host sync of traced value "
                           f"`{_src(call.func.value)}`")
                return False
            if attr == "format" and any_arg:
                self._emit(call, "T105",
                           "str.format interpolation of a traced value")
                return False
            if attr in _LOG_METHODS and any_arg \
                    and _base_name(call.func) in ("logging", "logger",
                                                  "log", "LOG"):
                self._emit(call, "T105",
                           "logging interpolation of a traced value")
                return False
            if attr in _MUTATORS \
                    and attr not in self.lint.project.methods_by_name:
                # a project class defining `attr` (e.g. Model.extend) means
                # this is a method call, not a list/set/dict mutation
                base = _base_name(call.func)
                if base is not None and self._is_free_nontraced(base):
                    self._emit(call, "T106",
                               f"mutation of captured "
                               f"`{_src(call.func.value)}.{attr}(...)` "
                               "inside a jitted body (trace-time side "
                               "effect)")
                return recv_traced or any_arg

        if name in STATIC_RESULT_CALLS:
            return False

        # ---- interprocedural: resolve and analyze callees
        resolved = self._resolve_and_recurse(call, arg_traced, kw_traced)
        # ---- callbacks: function-valued args handed to control flow /
        # vmap get analyzed conservatively (all params traced,
        # partial-bound kwargs static).  partial/jit/pallas_call args are
        # NOT callbacks here: partial exprs are analyzed where *used* (so
        # their bound kwargs stay static) and jit/pallas sites are entry
        # points with their own static handling in discovery.
        if name not in _PARTIAL_NAMES and name not in _JIT_NAMES \
                and name not in _PALLAS_NAMES:
            for a in list(call.args) + [kw.value for kw in call.keywords]:
                self._analyze_callback(a)
        if resolved is not None:
            return resolved
        recv = (self.is_traced(call.func.value)
                if isinstance(call.func, ast.Attribute) else False)
        return any_arg or recv

    def _resolve_and_recurse(self, call: ast.Call,
                             arg_traced: List[bool],
                             kw_traced: Dict[Optional[str], bool]
                             ) -> Optional[bool]:
        cands: List[FuncInfo] = []
        method = False
        if isinstance(call.func, ast.Name):
            cands = self.lint.project.resolve_name(
                call.func.id, self.fi.module, self.fi)
        elif isinstance(call.func, ast.Attribute):
            cands = self.lint.project.resolve_attr_call(
                call.func.value, call.func.attr, self.fi.module)
            method = True
        if not cands:
            return None
        result = False
        for fi in cands[:_MAX_CANDIDATES]:
            params = fi.params()
            if method and params[:1] == ["self"]:
                params = params[1:]
            traced = set()
            for i, t in enumerate(arg_traced):
                if t and i < len(params):
                    traced.add(params[i])
            for k, t in kw_traced.items():
                if t and k in params:
                    traced.add(k)
            closure = (self._snapshot_closure()
                       if fi.module is self.fi.module else {})
            result |= self.lint._analyze(fi, frozenset(traced), closure,
                                         self.depth + 1)
        return result

    def _analyze_callback(self, expr: ast.expr) -> None:
        if isinstance(expr, (ast.Name, ast.Lambda)) \
                or (isinstance(expr, ast.Call)
                    and dotted_name(expr.func) in _PARTIAL_NAMES):
            for fi, bound in self.lint._resolve_funcexpr(
                    expr, self.fi.module, self.fi):
                traced = frozenset(p for p in fi.params() if p not in bound
                                   and p not in ALWAYS_STATIC_PARAMS)
                closure = (self._snapshot_closure()
                           if fi.module is self.fi.module else {})
                self.lint._analyze(fi, traced, closure, self.depth + 1)

    def _emit(self, node: ast.AST, code: str, message: str) -> None:
        self.lint.emit(self.fi, node, code, message)


def _own_walk(roots: Sequence[ast.AST]):
    """Walk nodes without descending into nested function/lambda bodies
    (those belong to the inner scope and are walked separately)."""
    stack: List[ast.AST] = list(roots)
    while stack:
        node = stack.pop()
        yield node
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                             ast.Lambda)):
            continue
        stack.extend(ast.iter_child_nodes(node))


def _base_name(expr: ast.expr) -> Optional[str]:
    while isinstance(expr, (ast.Attribute, ast.Subscript)):
        expr = expr.value
    if isinstance(expr, ast.Name):
        return expr.id
    return None


def _src(expr: ast.AST) -> str:
    try:
        text = ast.unparse(expr)
    except Exception:                            # pragma: no cover
        return "<expr>"
    return text if len(text) <= 40 else text[:37] + "..."


def run(project: Project) -> List[Finding]:
    """Entry point used by the driver: all tracer-safety findings."""
    return TracerLint(project).run()

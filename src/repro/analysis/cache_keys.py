"""Jit-cache-key audit: hand-rolled compiled-fn caches vs. what they key on.

The serving stack memoizes ``jax.jit`` results in plain dicts
(``SDEngine._round_cache``, ``_stage_cache``, ``_admit_cache``,
``_sliced_cache``, ``_chunk_cache``): a builder method computes a Python
tuple key, ``.get()``s the cache, and on miss closes a fresh function over
the builder's arguments and stores ``jax.jit(fn)`` under the key.  The
failure mode is silent: a builder argument that varies shapes or Python
branching but is *missing from the key* makes two different programs share
one cache slot — the second caller gets the first caller's compiled
artifact and wrong shapes/semantics, with no retrace to warn anyone.

This pass finds every builder (a function that both ``.get()``s and
stores into the same cache dict, where the stored value traces to a
``jax.jit`` call) and cross-checks:

========  ===========================================================
 K201     a builder parameter does not appear in the cache key.
 K202     a jitted-function parameter drives Python branching at trace
          time but is not in ``static_argnames``.
 K203     ``static_argnames`` names a parameter that does not exist.
 K204     the jitted closure captures a builder-scope variable that is
          neither derived from the key/self/module globals nor safe.
 K205     the ``.get()`` key and the store key are different expressions.
========  ===========================================================
"""
from __future__ import annotations

import ast
import builtins
from dataclasses import dataclass, field
from typing import Dict, Iterator, List, Optional, Set, Tuple

from repro.analysis._astutil import (FuncInfo, ModuleInfo, Project,
                                     assigned_names, call_keywords,
                                     const_eval, dotted_name)
from repro.analysis.findings import Finding

_JIT_NAMES = ("jax.jit", "jit", "api.jit")
_PARTIAL_NAMES = ("functools.partial", "partial")
_BUILTINS = frozenset(dir(builtins))


def _own_nodes(fi: FuncInfo) -> Iterator[ast.AST]:
    """All nodes in ``fi``'s own body, NOT descending into nested function
    bodies (their statements belong to the inner scope)."""
    stack: List[ast.AST] = list(fi.body())
    while stack:
        node = stack.pop()
        yield node
        for child in ast.iter_child_nodes(node):
            if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef,
                                  ast.Lambda)):
                yield child                       # the binding, not the body
                continue
            stack.append(child)


def _all_nodes(node: ast.AST) -> Iterator[ast.AST]:
    yield from ast.walk(node)


@dataclass
class _CacheUse:
    """One cache dict referenced from a builder: its gets and stores."""
    attr: str
    gets: List[Tuple[ast.expr, int]] = field(default_factory=list)
    stores: List[Tuple[ast.expr, ast.expr, int]] = field(default_factory=list)


class CacheKeyAudit:
    def __init__(self, project: Project):
        self.project = project
        self.findings: List[Finding] = []

    def run(self) -> List[Finding]:
        for mod in self.project.modules.values():
            for fi in mod.functions.values():
                self._audit_builder(fi)
                self._audit_static_argnames(fi)
        self.findings.sort(key=lambda f: (f.path, f.line, f.code))
        return self.findings

    # ------------------------------------------------------ builder detection
    def _audit_builder(self, fi: FuncInfo) -> None:
        uses: Dict[str, _CacheUse] = {}
        local_assigns = self._local_assigns(fi)
        for node in _own_nodes(fi):
            if isinstance(node, ast.Call) \
                    and isinstance(node.func, ast.Attribute) \
                    and node.func.attr == "get" and node.args:
                attr = self._cache_name(node.func.value)
                if attr:
                    uses.setdefault(attr, _CacheUse(attr)).gets.append(
                        (node.args[0], node.lineno))
            elif isinstance(node, ast.Assign) and len(node.targets) == 1 \
                    and isinstance(node.targets[0], ast.Subscript):
                tgt = node.targets[0]
                attr = self._cache_name(tgt.value)
                if attr:
                    uses.setdefault(attr, _CacheUse(attr)).stores.append(
                        (tgt.slice, node.value, node.lineno))
        for use in uses.values():
            if not use.stores:
                continue
            inner = self._jitted_inners(fi, use, local_assigns)
            if inner is None:
                continue                    # not a compiled-fn cache
            self._check_cache(fi, use, inner, local_assigns)

    def _cache_name(self, expr: ast.expr) -> Optional[str]:
        """``self.X`` -> X; bare local ``name`` -> name.  Anything deeper
        (``self.a.b``) is out of scope for the audit."""
        if isinstance(expr, ast.Attribute) \
                and isinstance(expr.value, ast.Name) \
                and expr.value.id == "self":
            return expr.attr
        if isinstance(expr, ast.Name):
            return expr.id
        return None

    def _local_assigns(self, fi: FuncInfo) -> Dict[str, List[ast.expr]]:
        out: Dict[str, List[ast.expr]] = {}
        for node in _own_nodes(fi):
            if isinstance(node, ast.Assign):
                for tgt in node.targets:
                    if isinstance(tgt, ast.Name):
                        out.setdefault(tgt.id, []).append(node.value)
                    elif isinstance(tgt, (ast.Tuple, ast.List)):
                        for name in assigned_names(tgt):
                            out.setdefault(name, []).append(node.value)
        return out

    def _jitted_inners(self, fi: FuncInfo, use: _CacheUse,
                       local_assigns: Dict[str, List[ast.expr]]
                       ) -> Optional[List[Tuple[ast.Call, FuncInfo]]]:
        """Resolve the stored value(s) to ``jax.jit(inner)`` calls.  None
        when the stored values never trace to a jit call (a data cache,
        not a compiled-fn cache)."""
        jit_calls: List[ast.Call] = []
        for _, value, _ in use.stores:
            jit_calls.extend(self._trace_to_jit(value, local_assigns, 0))
        if not jit_calls:
            return None
        out: List[Tuple[ast.Call, FuncInfo]] = []
        for call in jit_calls:
            if not call.args:
                continue
            arg = call.args[0]
            inners: List[FuncInfo] = []
            if isinstance(arg, ast.Name):
                inners = self.project.resolve_name(arg.id, fi.module, fi)
            elif isinstance(arg, ast.Lambda):
                inners = [FuncInfo(arg, fi.module,
                                   f"{fi.qualname}.<lambda>", fi)]
            for inner in inners:
                out.append((call, inner))
        return out

    def _trace_to_jit(self, value: ast.expr,
                      local_assigns: Dict[str, List[ast.expr]],
                      depth: int) -> List[ast.Call]:
        if depth > 4:
            return []
        if isinstance(value, ast.Call) \
                and dotted_name(value.func) in _JIT_NAMES:
            return [value]
        if isinstance(value, ast.Tuple):
            out: List[ast.Call] = []
            for e in value.elts:
                out.extend(self._trace_to_jit(e, local_assigns, depth + 1))
            return out
        if isinstance(value, ast.Name):
            out = []
            for rhs in local_assigns.get(value.id, []):
                out.extend(self._trace_to_jit(rhs, local_assigns, depth + 1))
            return out
        return []

    # ------------------------------------------------------------ the checks
    def _check_cache(self, fi: FuncInfo, use: _CacheUse,
                     inners: List[Tuple[ast.Call, FuncInfo]],
                     local_assigns: Dict[str, List[ast.expr]]) -> None:
        get_keys = [self._resolve_key(k, local_assigns) for k, _ in use.gets]
        store_keys = [self._resolve_key(k, local_assigns)
                      for k, _, _ in use.stores]
        key_names: Set[str] = set()
        for key in get_keys + store_keys:
            key_names |= {n.id for n in _all_nodes(key)
                          if isinstance(n, ast.Name)}

        # K205 — get key vs store key
        if get_keys and store_keys:
            get_repr = {ast.dump(k) for k in get_keys}
            store_repr = {ast.dump(k) for k in store_keys}
            if get_repr != store_repr:
                self._emit(fi, use.stores[0][2], "K205",
                           f"cache `{use.attr}` .get() key "
                           f"`{_src(use.gets[0][0])}` != store key "
                           f"`{_src(use.stores[0][0])}`")

        # K201 — builder params must all REACH the key: directly, or
        # through a derived local (`opts_key = tuple(sorted(
        # cache_opts.items()))` covers `cache_opts`)
        key_reads = set(key_names)
        changed = True
        while changed:
            changed = False
            for name in list(key_reads):
                for rhs in local_assigns.get(name, []):
                    reads = {n.id for n in _all_nodes(rhs)
                             if isinstance(n, ast.Name)}
                    if not reads <= key_reads:
                        key_reads |= reads
                        changed = True
        params = [p for p in fi.params() if p not in ("self", "cls")]
        for p in params:
            if p not in key_reads:
                self._emit(fi, fi.line, "K201",
                           f"builder param `{p}` of `{fi.qualname}` missing "
                           f"from cache key for `{use.attr}` — two call "
                           "shapes can share one compiled artifact")

        # per jitted inner: K202/K203 at the jit site, K204 on the closure
        safe = self._safe_names(fi, key_names, local_assigns)
        for call, inner in inners:
            statics = self._static_argnames(call, inner)
            self._check_k202(fi, call, inner, statics)
            self._check_k204(fi, use, inner, safe)

    def _resolve_key(self, key: ast.expr,
                     local_assigns: Dict[str, List[ast.expr]]) -> ast.expr:
        """``cache_key`` -> its assignment RHS so name-vs-literal spellings
        of the same key compare equal."""
        if isinstance(key, ast.Name):
            rhs = local_assigns.get(key.id, [])
            if len(rhs) == 1:
                return rhs[0]
        return key

    def _static_argnames(self, call: ast.Call,
                         inner: FuncInfo) -> Set[str]:
        kw = call_keywords(call)
        out: Set[str] = set()
        names = const_eval(kw.get("static_argnames"), {})
        if isinstance(names, str):
            out.add(names)
        elif isinstance(names, tuple):
            out |= {str(n) for n in names}
        nums = const_eval(kw.get("static_argnums"), {})
        if isinstance(nums, int):
            nums = (nums,)
        if isinstance(nums, tuple):
            pos = inner.positional_params()
            for i in nums:
                if isinstance(i, int) and 0 <= i < len(pos):
                    out.add(pos[i])
        return out

    def _check_k202(self, fi: FuncInfo, call: ast.Call, inner: FuncInfo,
                    statics: Set[str]) -> None:
        """Inner-fn params driving Python branching must be static."""
        params = set(inner.params()) - statics
        flagged: Set[str] = set()
        for node in _all_nodes(inner.node):
            test = None
            if isinstance(node, (ast.If, ast.While, ast.IfExp)):
                test = node.test
            elif isinstance(node, ast.Assert):
                test = node.test
            if test is None:
                continue
            for name in _all_nodes(test):
                if isinstance(name, ast.Name) and name.id in params \
                        and name.id not in flagged:
                    flagged.add(name.id)
                    self._emit(fi, getattr(node, "lineno", inner.line),
                               "K202",
                               f"param `{name.id}` of jitted "
                               f"`{inner.qualname}` drives a Python branch "
                               "at trace time but is not in "
                               "static_argnames")

    def _audit_static_argnames(self, fi: FuncInfo) -> None:
        """K203 on every jit site (call or decorator), cache or not."""
        sites: List[Tuple[ast.Call, FuncInfo]] = []
        if isinstance(fi.node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            for dec in fi.node.decorator_list:
                if isinstance(dec, ast.Call) and (
                        dotted_name(dec.func) in _JIT_NAMES
                        or (dotted_name(dec.func) in _PARTIAL_NAMES
                            and dec.args
                            and dotted_name(dec.args[0]) in _JIT_NAMES)):
                    sites.append((dec, fi))
        for node in _own_nodes(fi):
            if isinstance(node, ast.Call) \
                    and dotted_name(node.func) in _JIT_NAMES and node.args:
                arg = node.args[0]
                if isinstance(arg, ast.Name):
                    for inner in self.project.resolve_name(
                            arg.id, fi.module, fi):
                        sites.append((node, inner))
        for call, inner in sites:
            params = set(inner.params())
            for name in self._static_argnames(call, inner):
                if name not in params:
                    self._emit(fi, call.lineno, "K203",
                               f"static_argnames entry `{name}` matches no "
                               f"parameter of `{inner.qualname}`")

    # ------------------------------------------------------------------ K204
    def _safe_names(self, fi: FuncInfo, key_names: Set[str],
                    local_assigns: Dict[str, List[ast.expr]]) -> Set[str]:
        """Builder-scope names a jitted closure may capture: the key names,
        self, module globals/imports, builder params (K201 covers those),
        and locals transitively derived from safe names only."""
        mod = fi.module
        module_names: Set[str] = set(mod.imports) | set(mod.top_funcs) \
            | set(mod.classes)
        for node in mod.tree.body:
            if isinstance(node, ast.Assign):
                for tgt in node.targets:
                    module_names.update(assigned_names(tgt))
            elif isinstance(node, ast.AnnAssign):
                module_names.update(assigned_names(node.target))
        safe = set(key_names) | module_names | set(fi.params()) \
            | {"self", "cls"} | _BUILTINS
        # fixpoint: a local is safe when every name its RHS reads is safe
        changed = True
        while changed:
            changed = False
            for name, rhss in local_assigns.items():
                if name in safe:
                    continue
                reads: Set[str] = set()
                for rhs in rhss:
                    reads |= {n.id for n in _all_nodes(rhs)
                              if isinstance(n, ast.Name)}
                if reads <= safe:
                    safe.add(name)
                    changed = True
        return safe

    def _check_k204(self, fi: FuncInfo, use: _CacheUse, inner: FuncInfo,
                    safe: Set[str]) -> None:
        bound: Set[str] = set(inner.params())
        for node in _all_nodes(inner.node):
            if isinstance(node, ast.Assign):
                for tgt in node.targets:
                    bound.update(assigned_names(tgt))
            elif isinstance(node, (ast.AugAssign, ast.AnnAssign)):
                bound.update(assigned_names(node.target))
            elif isinstance(node, ast.For):
                bound.update(assigned_names(node.target))
            elif isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                bound.add(node.name)
                bound.update(p.arg for p in node.args.args
                             + node.args.kwonlyargs + node.args.posonlyargs)
            elif isinstance(node, ast.Lambda):
                bound.update(p.arg for p in node.args.args
                             + node.args.kwonlyargs + node.args.posonlyargs)
            elif isinstance(node, (ast.Import, ast.ImportFrom)):
                bound.update(a.asname or a.name.split(".")[0]
                             for a in node.names)
            elif isinstance(node, ast.comprehension):
                bound.update(assigned_names(node.target))
        used = {n.id for n in _all_nodes(inner.node)
                if isinstance(n, ast.Name) and isinstance(n.ctx, ast.Load)}
        for name in sorted(used - bound - safe):
            self._emit(fi, inner.line, "K204",
                       f"jitted `{inner.qualname}` captures builder-scope "
                       f"`{name}` which is not derived from the "
                       f"`{use.attr}` key")

    def _emit(self, fi: FuncInfo, line: int, code: str,
              message: str) -> None:
        self.findings.append(Finding(fi.module.rel, line, code, message))


def _src(expr: ast.AST) -> str:
    try:
        text = ast.unparse(expr)
    except Exception:                            # pragma: no cover
        return "<expr>"
    return text if len(text) <= 40 else text[:37] + "..."


def run(project: Project) -> List[Finding]:
    """Entry point used by the driver: all cache-key findings."""
    return CacheKeyAudit(project).run()

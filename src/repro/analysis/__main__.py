"""CLI driver: ``python -m repro.analysis [paths...]``.

Exit status is the CI contract: 0 when every finding is waived or
baselined, 1 when new findings exist.  ``--update-baseline`` regenerates
the ratchet file from the current findings (each entry then needs a
justification comment before review).  ``--json`` emits the full report
for tooling.
"""
from __future__ import annotations

import argparse
import sys

from repro.analysis import (analyze_paths, load_baseline, ratchet,
                            write_baseline)
from repro.analysis.pallas_lint import _DEFAULT_VMEM_BUDGET


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="Tracer-safety / cache-key / Pallas / sharding / "
                    "PRNG / donation analyzer.")
    ap.add_argument("paths", nargs="*", default=["src/repro"],
                    help="files or directories to analyze "
                         "(default: src/repro)")
    ap.add_argument("--baseline", default="scripts/lint_baseline.txt",
                    help="ratchet baseline file")
    ap.add_argument("--no-baseline", action="store_true",
                    help="report every finding, ignoring the baseline")
    ap.add_argument("--update-baseline", action="store_true",
                    help="rewrite the baseline from current findings")
    ap.add_argument("--json", action="store_true",
                    help="emit the report as JSON")
    ap.add_argument("--vmem-budget", type=int,
                    default=_DEFAULT_VMEM_BUDGET,
                    help="Pallas VMEM budget in bytes (P304)")
    args = ap.parse_args(argv)

    findings = analyze_paths(args.paths or ["src/repro"],
                             vmem_budget=args.vmem_budget)
    if args.update_baseline:
        write_baseline(args.baseline, findings)
        print(f"wrote {len(findings)} entr(ies) to {args.baseline}")
        return 0
    baseline = {} if args.no_baseline else load_baseline(args.baseline)
    report = ratchet(findings, baseline)
    print(report.as_json() if args.json else report.render())
    return 0 if report.ok else 1


if __name__ == "__main__":
    sys.exit(main())

"""Finding model, inline waivers and the ratchet baseline.

Every analysis pass emits ``Finding``s; the driver (``__main__``) renders
them as ``path:line: CODE message`` (the grep/editor-clickable format),
filters the ones the repo has explicitly accepted, and fails on the rest.

Two acceptance mechanisms, by design intent:

* **Inline waiver** — a ``# lint: allow[CODE] reason`` comment on (or one
  line above) the flagged line.  For violations that are *intentional
  behavior* (e.g. the SDEngine trace-log append: a deliberate trace-time
  side effect tests assert on).  The reason is mandatory: a waiver without
  one is itself a finding (``W001``).
* **Ratchet baseline** — ``scripts/lint_baseline.txt``, a checked-in list
  of ``path:CODE:fingerprint`` entries for *legacy debt*: findings that
  predate the analyzer and are queued for fixes.  The baseline only ever
  shrinks ("ratchet"): a finding NOT in the baseline fails CI, and a
  baseline entry whose finding disappeared is reported as stale so it gets
  deleted.  Fingerprints hash the finding message, not the line number, so
  unrelated edits above a baselined site don't churn the file.
"""
from __future__ import annotations

import hashlib
import json
import re
from dataclasses import dataclass, field
from typing import Dict, Iterable, List, Optional, Tuple

#: code -> one-line description (docs/analysis.md is generated-by-hand from
#: this table; tests assert the two stay in sync)
CODES: Dict[str, str] = {
    # tracer-safety lint (tracer_lint.py)
    "T101": "Python `if` on a traced value (trace-time branch)",
    "T102": "Python `while` on a traced value (trace-time loop)",
    "T103": "int()/float()/bool() coercion of a traced value",
    "T104": "host sync of a traced value (.item()/.tolist()/np.asarray)",
    "T105": "f-string/str.format interpolation of a traced value",
    "T106": "mutation of captured Python state inside a jitted body",
    "T107": "assert on a traced value",
    "T108": "range() bound by a traced value (unrolls or crashes)",
    # jit-cache-key audit (cache_keys.py)
    "K201": "hand-rolled cache key misses a builder parameter",
    "K202": "param branches/shapes at trace time but is not static",
    "K203": "static_argnames names a parameter that does not exist",
    "K204": "jitted closure captures a builder-scope variable not in the key",
    "K205": "cache .get() key and store key differ",
    # Pallas kernel-contract lint (pallas_lint.py)
    "P301": "index-map arity != grid dims + scalar-prefetch operands",
    "P302": "kernel parameter count != scalars + inputs + outputs + scratch",
    "P303": "BlockSpec block dims unaligned to the dtype's TPU tile",
    "P304": "VMEM footprint (blocks + scratch) exceeds the budget",
    "P305": "num_scalar_prefetch inconsistent with the grid spec",
    # sharding / collective contract lint (sharding_lint.py)
    "S401": "collective axis name not in the enclosing shard_map mesh/specs",
    "S402": "in_specs/out_specs arity != wrapped function signature",
    "S403": "host array enters a cached jit program without _host/constrain",
    "S404": "paged cache leaf not covered by an explicit cache_spec rule",
    "S405": "deprecated set_mesh process-global (thread the mesh explicitly)",
    # PRNG-hygiene lint (prng_lint.py)
    "R501": "PRNG key consumed twice without an interleaving split/fold_in",
    "R502": "jax.random.split result discarded (keys derived, never used)",
    "R503": "jitted closure captures a PRNG key (randomness baked at trace)",
    "R504": "fold_in with a loop-invariant constant (same key every iteration)",
    # buffer-donation lint (donation_lint.py)
    "D601": "donated argument is read again after the donating call",
    "D602": "donation-eligible hot-path buffer is never donated",
    "D603": "donate_argnums index out of range or names a static parameter",
    # waiver hygiene
    "W001": "lint waiver without a reason",
}

#: code prefix -> pass name, the ``--json`` per-pass accounting and the
#: docs/analysis.md section structure.  W001 is attributed to the waiver
#: machinery itself.
PASSES: Dict[str, str] = {
    "T1": "tracer_lint",
    "K2": "cache_keys",
    "P3": "pallas_lint",
    "S4": "sharding_lint",
    "R5": "prng_lint",
    "D6": "donation_lint",
    "W0": "waivers",
}


def pass_of(code: str) -> str:
    """Name of the analysis pass that owns a finding code."""
    return PASSES.get(code[:2], "unknown")

_WAIVER_RE = re.compile(r"#\s*lint:\s*allow\[([A-Z]\d{3}(?:,\s*[A-Z]\d{3})*)\]"
                        r"\s*(.*)")


@dataclass(frozen=True)
class Finding:
    """One analyzer result, anchored to a source location.

    ``path`` is repo-relative, ``line`` 1-indexed, ``code`` one of
    :data:`CODES`.  ``fingerprint`` (path + code + message, line-free) is
    what the ratchet baseline stores, so baselined findings survive
    unrelated edits shifting line numbers.
    """
    path: str
    line: int
    code: str
    message: str

    def __post_init__(self):
        assert self.code in CODES, f"unknown finding code {self.code}"

    @property
    def fingerprint(self) -> str:
        h = hashlib.sha256(
            f"{self.path}:{self.code}:{self.message}".encode()).hexdigest()
        return h[:12]

    def render(self) -> str:
        return f"{self.path}:{self.line}: {self.code} {self.message}"

    def as_json(self) -> dict:
        return {"path": self.path, "line": self.line, "code": self.code,
                "message": self.message, "fingerprint": self.fingerprint}


def parse_waivers(source: str) -> Dict[int, Tuple[Tuple[str, ...], str]]:
    """Map line number -> (waived codes, reason) for ``# lint: allow[...]``
    comments.  A waiver covers its own line and the line below it (so it
    can sit above a long statement)."""
    out: Dict[int, Tuple[Tuple[str, ...], str]] = {}
    for i, text in enumerate(source.splitlines(), start=1):
        m = _WAIVER_RE.search(text)
        if m:
            codes = tuple(c.strip() for c in m.group(1).split(","))
            out[i] = (codes, m.group(2).strip())
    return out


def apply_waivers(findings: Iterable[Finding],
                  waivers_by_path: Dict[str, Dict[int, Tuple[Tuple[str, ...],
                                                             str]]]
                  ) -> List[Finding]:
    """Drop findings covered by an inline waiver; emit W001 for waivers
    that carry no reason (waiving silently defeats the justification
    requirement the ratchet exists for)."""
    kept: List[Finding] = []
    used: set = set()
    for f in findings:
        waivers = waivers_by_path.get(f.path, {})
        hit = None
        for ln in (f.line, f.line - 1):
            w = waivers.get(ln)
            if w and f.code in w[0]:
                hit = (ln, w)
                break
        if hit is None:
            kept.append(f)
            continue
        used.add((f.path, hit[0]))
        if not hit[1][1]:
            kept.append(Finding(f.path, hit[0], "W001",
                                f"waiver for {f.code} has no reason"))
    return kept


# ------------------------------------------------------------------ baseline
def load_baseline(path: str) -> Dict[str, str]:
    """Parse a ratchet baseline file: ``path:CODE:fingerprint`` per line;
    ``#`` comments (the per-entry justifications) and blanks skipped.
    Returns fingerprint -> entry-line (for stale reporting)."""
    entries: Dict[str, str] = {}
    try:
        with open(path) as fh:
            for raw in fh:
                line = raw.strip()
                if not line or line.startswith("#"):
                    continue
                parts = line.rsplit(":", 2)
                if len(parts) != 3:
                    raise ValueError(f"malformed baseline entry: {line!r}")
                entries[parts[2]] = line
    except FileNotFoundError:
        pass
    return entries


def write_baseline(path: str, findings: Iterable[Finding]) -> None:
    """Regenerate the baseline from current findings (``--update-baseline``).
    Every entry gets a TODO-justify comment slot — CI does not parse the
    comments, reviewers do."""
    lines = [
        "# Ratchet baseline for `python -m repro.analysis` "
        "(scripts/lint.sh).",
        "# Format: path:CODE:fingerprint — one accepted finding per line.",
        "# Each entry MUST carry a justification comment; entries only ever",
        "# get deleted (fix the finding), never silently added.",
        "",
    ]
    for f in sorted(set(findings), key=lambda f: (f.path, f.code, f.line)):
        lines.append(f"# JUSTIFY: {f.message}")
        lines.append(f"{f.path}:{f.code}:{f.fingerprint}")
    with open(path, "w") as fh:
        fh.write("\n".join(lines) + "\n")


@dataclass
class Report:
    """Driver outcome: new findings (fail), baselined ones (pass, counted)
    and stale baseline entries (pass, nagged)."""
    new: List[Finding] = field(default_factory=list)
    baselined: List[Finding] = field(default_factory=list)
    stale: List[str] = field(default_factory=list)

    @property
    def ok(self) -> bool:
        return not self.new

    def render(self) -> str:
        out = [f.render() for f in self.new]
        if self.stale:
            out.append("stale baseline entries (fixed — delete them):")
            out.extend(f"  {e}" for e in self.stale)
        out.append(f"{len(self.new)} new finding(s), "
                   f"{len(self.baselined)} baselined, "
                   f"{len(self.stale)} stale baseline entr(ies)")
        return "\n".join(out)

    def per_pass(self) -> Dict[str, int]:
        """Finding counts (new + baselined) keyed by owning pass name.

        Every pass appears, zero or not, so dashboards diffing the JSON
        see a stable key set as passes are added."""
        counts = {name: 0 for name in PASSES.values()}
        for f in list(self.new) + list(self.baselined):
            counts[pass_of(f.code)] = counts.get(pass_of(f.code), 0) + 1
        return counts

    def as_json(self) -> str:
        return json.dumps({
            "ok": self.ok,
            "new": [f.as_json() for f in self.new],
            "baselined": [f.as_json() for f in self.baselined],
            "stale_baseline": list(self.stale),
            "per_pass": self.per_pass(),
        }, indent=2)


def ratchet(findings: Iterable[Finding],
            baseline: Dict[str, str]) -> Report:
    """Split findings by baseline membership and spot stale entries."""
    rep = Report()
    seen: set = set()
    for f in findings:
        if f.fingerprint in baseline:
            rep.baselined.append(f)
            seen.add(f.fingerprint)
        else:
            rep.new.append(f)
    rep.stale = [entry for fp, entry in baseline.items() if fp not in seen]
    rep.new.sort(key=lambda f: (f.path, f.line, f.code))
    return rep

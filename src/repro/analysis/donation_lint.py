"""Buffer-donation lint: use-after-donate and unclaimed donation headroom.

``donate_argnums`` is the only way the serving/training hot paths reuse
input buffers in place; it is also the easiest jax feature to corrupt
silently — a donated array is *deallocated* at the call, and reading it
afterwards returns garbage (or an error only on some backends).  The
inverse failure is quieter still: a functional-update loop that never
donates holds two copies of every buffer it touches, which is exactly the
HBM headroom the ROADMAP's prefetch item tracks.

========  ===========================================================
 D601     an argument at a donated position is read again after the
          donating call without being rebound from its results.
 D602     a ``registry.DONATION_CANDIDATES`` buffer is never donated
          by any jit site in the scanned tree — the tracked form of
          "until buffers are donated to the gmm" comments.
 D603     ``donate_argnums`` names an index out of the wrapped
          function's positional range, or one of its static
          parameters (jax ignores or rejects both at run time).
========  ===========================================================

Loop bodies are visited twice, so a step function that donates its state
but fails to rebind it is caught on the simulated second iteration.
"""
from __future__ import annotations

import ast
from dataclasses import dataclass
from typing import Dict, Iterator, List, Optional, Set, Tuple

from repro.analysis._astutil import (FuncInfo, ModuleInfo, Project,
                                     call_keywords, const_eval, dotted_name)
from repro.analysis.findings import Finding
from repro.analysis.registry import DONATION_CANDIDATES

_JIT_NAMES = ("jax.jit", "jit", "api.jit")
_PARTIAL_NAMES = ("functools.partial", "partial")


def _own_nodes(fi: FuncInfo) -> Iterator[ast.AST]:
    stack: List[ast.AST] = list(fi.body())
    while stack:
        node = stack.pop()
        yield node
        for child in ast.iter_child_nodes(node):
            if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef,
                                  ast.Lambda)):
                yield child
                continue
            stack.append(child)


def _flat_names(target: ast.expr) -> List[str]:
    if isinstance(target, ast.Name):
        return [target.id]
    if isinstance(target, (ast.Tuple, ast.List)):
        out: List[str] = []
        for e in target.elts:
            out.extend(_flat_names(e))
        return out
    if isinstance(target, ast.Starred):
        return _flat_names(target.value)
    return []


def _donated_indices(call: ast.Call) -> Optional[Tuple[int, ...]]:
    """Constant donate_argnums of a jit call; None when absent or symbolic
    (a symbolic value still counts as "donates" for D602)."""
    kws = call_keywords(call)
    expr = kws.get("donate_argnums")
    if expr is None:
        return None
    val = const_eval(expr, {})
    if isinstance(val, int):
        return (val,)
    if isinstance(val, tuple) and all(isinstance(v, int) for v in val):
        return tuple(val)
    return None


@dataclass
class _DonatingFn:
    """A name bound to a jit-compiled function with donated positions."""
    donated: Tuple[int, ...]


class DonationLint:
    def __init__(self, project: Project):
        self.project = project
        self.findings: List[Finding] = []
        self._seen: Set[Tuple[str, int, str]] = set()
        #: jit sites with ANY donate_argnums (constant or symbolic), by
        #: wrapped-candidate id — feeds D602
        self._donating_targets: Set[int] = set()

    def emit(self, mod: ModuleInfo, line: int, code: str, msg: str) -> None:
        k = (mod.rel, line, code)
        if k not in self._seen:
            self._seen.add(k)
            self.findings.append(Finding(mod.rel, line, code, msg))

    def run(self) -> List[Finding]:
        for mod in self.project.modules.values():
            for fi in mod.functions.values():
                self._check_scope(mod, fi)
        self._check_candidates()
        self.findings.sort(key=lambda f: (f.path, f.line, f.code))
        return self.findings

    # ------------------------------------------------------------- D602
    def _check_candidates(self) -> None:
        for cand in DONATION_CANDIDATES:
            for mod in self.project.modules.values():
                if not mod.rel.endswith(cand.module):
                    continue
                fi = mod.functions.get(cand.qualname)
                if fi is None:
                    continue
                if id(fi) not in self._donating_targets:
                    self.emit(mod, fi.line, "D602",
                              f"{cand.qualname}() buffer "
                              f"{cand.param!r} is donation-eligible but "
                              f"no jit site donates into it — "
                              f"{cand.note}")

    # --------------------------------------------------------- jit sites
    def _jit_call(self, node: ast.expr) -> Optional[ast.Call]:
        if isinstance(node, ast.Call) and dotted_name(node.func) in _JIT_NAMES:
            return node
        return None

    def _wrapped_candidates(self, mod: ModuleInfo, scope: Optional[FuncInfo],
                            jit: ast.Call) -> List[FuncInfo]:
        if not jit.args:
            return []
        f = jit.args[0]
        if isinstance(f, ast.Name):
            return self.project.resolve_name(f.id, mod, scope)
        if isinstance(f, ast.Attribute):
            return self.project.resolve_attr_call(f.value, f.attr, mod)
        if isinstance(f, ast.Lambda):
            return [FuncInfo(f, mod, "<lambda>", scope)]
        if isinstance(f, ast.Call):
            dn = dotted_name(f.func)
            if dn in _PARTIAL_NAMES and f.args:
                return self._wrapped_candidates(
                    mod, scope, ast.Call(func=ast.Name(id="jit",
                                                       ctx=ast.Load()),
                                         args=[f.args[0]], keywords=[]))
            # builder call (make_train_step(...)): follow returned fns
            inner: List[FuncInfo] = []
            for cand in self._wrapped_candidates(
                    mod, scope, ast.Call(func=ast.Name(id="jit",
                                                       ctx=ast.Load()),
                                         args=[f.func], keywords=[])):
                for pos in self.project.returned_functions(cand):
                    inner.extend(pos)
            return inner
        return []

    def _note_jit(self, mod: ModuleInfo, scope: Optional[FuncInfo],
                  jit: ast.Call) -> Optional[Tuple[int, ...]]:
        """Register the site for D602/D603 and return constant donated
        positions (None when absent/symbolic)."""
        kws = call_keywords(jit)
        has_donation = "donate_argnums" in kws or "donate_argnames" in kws
        donated = _donated_indices(jit)
        candidates = self._wrapped_candidates(mod, scope, jit)
        if has_donation:
            for cand in candidates:
                self._donating_targets.add(id(cand))
                # one transitive hop: `jit(step)` where step calls the
                # candidate still donates into it
                for node in ast.walk(cand.node):
                    if isinstance(node, ast.Call):
                        for inner in self._call_candidates(cand, node):
                            self._donating_targets.add(id(inner))
        if donated:
            statics = self._static_indices(jit, candidates)
            for cand in candidates:
                if cand.node.args.vararg is not None:
                    continue
                n_pos = len(cand.positional_params())
                for idx in donated:
                    if idx >= n_pos:
                        self.emit(mod, jit.lineno, "D603",
                                  f"donate_argnums={idx} but "
                                  f"{cand.name}() has only {n_pos} "
                                  f"positional parameter(s)")
                    elif idx in statics:
                        self.emit(mod, jit.lineno, "D603",
                                  f"donate_argnums={idx} names a static "
                                  f"parameter of {cand.name}() — jax "
                                  f"cannot donate static arguments")
        return donated

    def _static_indices(self, jit: ast.Call,
                        candidates: List[FuncInfo]) -> Set[int]:
        kws = call_keywords(jit)
        out: Set[int] = set()
        val = const_eval(kws.get("static_argnums"), {})
        if isinstance(val, int):
            out.add(val)
        elif isinstance(val, tuple):
            out.update(v for v in val if isinstance(v, int))
        names = const_eval(kws.get("static_argnames"), {})
        name_set = {names} if isinstance(names, str) else \
            set(names) if isinstance(names, tuple) else set()
        for cand in candidates:
            pos = cand.positional_params()
            out.update(i for i, p in enumerate(pos) if p in name_set)
        return out

    def _call_candidates(self, scope: FuncInfo,
                         call: ast.Call) -> List[FuncInfo]:
        if isinstance(call.func, ast.Name):
            return self.project.resolve_name(call.func.id, scope.module,
                                             scope)
        if isinstance(call.func, ast.Attribute):
            return self.project.resolve_attr_call(call.func.value,
                                                  call.func.attr,
                                                  scope.module)
        return []

    # ------------------------------------------------------------- D601
    def _check_scope(self, mod: ModuleInfo, fi: FuncInfo) -> None:
        donating: Dict[str, _DonatingFn] = {}
        for node in _own_nodes(fi):
            if isinstance(node, ast.Assign) and len(node.targets) == 1 \
                    and isinstance(node.targets[0], ast.Name):
                jit = self._jit_call(node.value)
                if jit is not None:
                    donated = self._note_jit(mod, fi, jit)
                    if donated:
                        donating[node.targets[0].id] = _DonatingFn(donated)
            elif isinstance(node, ast.Call):
                jit = self._jit_call(node)
                if jit is not None:
                    self._note_jit(mod, fi, jit)
        # decorated defs with donation, callable by bare name in this scope
        for name, cands in list(fi.local_funcs.items()) + \
                list(fi.module.top_funcs.items()):
            for cand in cands:
                if isinstance(cand.node, ast.Lambda):
                    continue
                for dec in cand.node.decorator_list:
                    if isinstance(dec, ast.Call) \
                            and dotted_name(dec.func) in _PARTIAL_NAMES \
                            and dec.args \
                            and dotted_name(dec.args[0]) in _JIT_NAMES:
                        donated = _donated_indices(dec)
                        if donated:
                            donating.setdefault(name,
                                                _DonatingFn(donated))
        if donating:
            _DeadScan(self, mod, fi, donating).run()


class _DeadScan:
    """Statement-ordered use-after-donate scan, loop bodies twice."""

    def __init__(self, lint: DonationLint, mod: ModuleInfo, fi: FuncInfo,
                 donating: Dict[str, _DonatingFn]):
        self.lint = lint
        self.mod = mod
        self.fi = fi
        self.donating = donating
        self.dead: Dict[str, int] = {}        # name -> donating call line

    def run(self) -> None:
        self.visit_block(self.fi.body())

    def visit_block(self, stmts: List[ast.stmt]) -> bool:
        """True when the block terminates (return/raise/break/continue),
        so If-merges drop the state of branches that never fall through."""
        terminated = False
        for stmt in stmts:
            if not terminated:
                terminated = self.visit_stmt(stmt)
        return terminated

    def visit_stmt(self, stmt: ast.stmt) -> bool:
        if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef,
                             ast.ClassDef)):
            return False
        if isinstance(stmt, (ast.For, ast.AsyncFor)):
            self._scan_expr(stmt.iter, set())
            self.visit_block(stmt.body)
            self.visit_block(stmt.body)          # simulated 2nd iteration
            self.visit_block(stmt.orelse)
            return False
        if isinstance(stmt, ast.While):
            self._scan_expr(stmt.test, set())
            self.visit_block(stmt.body)
            self.visit_block(stmt.body)
            self.visit_block(stmt.orelse)
            return False
        if isinstance(stmt, ast.If):
            self._scan_expr(stmt.test, set())
            saved = dict(self.dead)
            then_term = self.visit_block(stmt.body)
            after = self.dead
            self.dead = dict(saved)
            else_term = self.visit_block(stmt.orelse)
            if then_term and not else_term:
                pass                              # keep the else state
            elif else_term and not then_term:
                self.dead = after
            elif not then_term and not else_term:
                for name, line in after.items():
                    self.dead.setdefault(name, line)
            return then_term and else_term
        if isinstance(stmt, (ast.Return, ast.Raise)):
            val = stmt.value if isinstance(stmt, ast.Return) else stmt.exc
            if val is not None:
                self._scan_expr(val, set())
            return True
        if isinstance(stmt, (ast.Break, ast.Continue)):
            return True
        donated_here: Set[str] = set()
        newly_dead: Dict[str, int] = {}
        rebound: List[str] = []
        for node in ast.walk(stmt):
            if isinstance(node, ast.Call) \
                    and isinstance(node.func, ast.Name) \
                    and node.func.id in self.donating:
                for idx in self.donating[node.func.id].donated:
                    if idx < len(node.args) \
                            and isinstance(node.args[idx], ast.Name):
                        name = node.args[idx].id
                        donated_here.add(name)
                        newly_dead[name] = node.lineno
        if isinstance(stmt, ast.Assign):
            for t in stmt.targets:
                rebound.extend(_flat_names(t))
            self._scan_expr(stmt.value, donated_here)
        else:
            for child in ast.iter_child_nodes(stmt):
                if isinstance(child, ast.expr):
                    self._scan_expr(child, donated_here)
        for name in rebound:
            self.dead.pop(name, None)
            newly_dead.pop(name, None)
        self.dead.update(newly_dead)
        return False

    def _scan_expr(self, expr: ast.expr, donated_here: Set[str]) -> None:
        for node in ast.walk(expr):
            if isinstance(node, ast.Name) and isinstance(node.ctx, ast.Load) \
                    and node.id in self.dead and node.id not in donated_here:
                # no line numbers in the message (line-free fingerprints)
                self.lint.emit(self.mod, node.lineno, "D601",
                               f"{node.id!r} was donated by an earlier "
                               f"call and read again — donated buffers "
                               f"are deallocated at the donating call")
                self.dead.pop(node.id, None)     # one finding per donation


def run(project: Project) -> List[Finding]:
    """Entry point: D6xx findings over the project."""
    return DonationLint(project).run()

"""Shared AST machinery for the analysis passes.

Everything here is *source-level*: modules are parsed, never imported, so
the analyzer runs in milliseconds, needs no accelerator, and can lint
fixture files whose code would crash at runtime.

The model:

* :class:`Project` — parses every ``*.py`` under the given roots once and
  indexes functions (including nested defs and ``name = lambda`` bindings),
  classes/methods and import aliases.
* :class:`FuncInfo` — one function-ish definition with its lexical parent,
  so closures and nested defs resolve the way Python scoping does.
* Resolution helpers — best-effort, candidate-set based: a call like
  ``verify(...)`` where two conditional ``def verify`` branches exist
  resolves to *both* candidates and the caller analyzes each.  Anything
  genuinely unresolvable (dynamic dispatch, getattr) resolves to the empty
  set; passes degrade to intra-procedural analysis there rather than
  guessing.
"""
from __future__ import annotations

import ast
import os
from dataclasses import dataclass, field
from typing import Dict, Iterator, List, Optional, Sequence, Tuple, Union

FuncNode = Union[ast.FunctionDef, ast.AsyncFunctionDef, ast.Lambda]


@dataclass
class FuncInfo:
    """One function definition (def or bound lambda) in its lexical scope."""
    node: FuncNode
    module: "ModuleInfo"
    qualname: str
    parent: Optional["FuncInfo"] = None
    # bare name -> nested defs / `name = lambda` bindings in THIS body
    local_funcs: Dict[str, List["FuncInfo"]] = field(default_factory=dict)

    @property
    def name(self) -> str:
        return self.qualname.rsplit(".", 1)[-1]

    @property
    def line(self) -> int:
        return self.node.lineno

    def params(self) -> List[str]:
        a = self.node.args
        names = [p.arg for p in a.posonlyargs + a.args + a.kwonlyargs]
        if a.vararg:
            names.append(a.vararg.arg)
        if a.kwarg:
            names.append(a.kwarg.arg)
        return names

    def positional_params(self) -> List[str]:
        a = self.node.args
        return [p.arg for p in a.posonlyargs + a.args]

    def param_defaults(self) -> Dict[str, ast.expr]:
        """name -> default expression (positional and kw-only)."""
        a = self.node.args
        out: Dict[str, ast.expr] = {}
        pos = a.posonlyargs + a.args
        for p, d in zip(pos[len(pos) - len(a.defaults):], a.defaults):
            out[p.arg] = d
        for p, d in zip(a.kwonlyargs, a.kw_defaults):
            if d is not None:
                out[p.arg] = d
        return out

    def body(self) -> List[ast.stmt]:
        if isinstance(self.node, ast.Lambda):
            return [ast.Expr(self.node.body)]
        return self.node.body


@dataclass
class ClassInfo:
    name: str
    node: ast.ClassDef
    module: "ModuleInfo"
    methods: Dict[str, FuncInfo] = field(default_factory=dict)


class ModuleInfo:
    """Parsed module: tree + function/class/import indexes."""

    def __init__(self, path: str, rel: str, source: str):
        self.path = path
        self.rel = rel                       # repo-relative, for findings
        self.source = source
        self.tree = ast.parse(source, filename=path)
        self.imports: Dict[str, str] = {}    # alias -> dotted target
        self.functions: Dict[str, FuncInfo] = {}   # qualname -> info
        self.top_funcs: Dict[str, List[FuncInfo]] = {}
        self.classes: Dict[str, ClassInfo] = {}
        self._index()

    # ------------------------------------------------------------- indexing
    def _index(self) -> None:
        for node in self.tree.body:
            if isinstance(node, ast.Import):
                for a in node.names:
                    self.imports[a.asname or a.name.split(".")[0]] = a.name
            elif isinstance(node, ast.ImportFrom) and node.module:
                for a in node.names:
                    self.imports[a.asname or a.name] = \
                        f"{node.module}.{a.name}"
        for node in self.tree.body:
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                self._add_func(node, None, node.name)
            elif isinstance(node, ast.ClassDef):
                ci = ClassInfo(node.name, node, self)
                self.classes[node.name] = ci
                for item in node.body:
                    if isinstance(item, (ast.FunctionDef,
                                         ast.AsyncFunctionDef)):
                        fi = self._add_func(item, None,
                                            f"{node.name}.{item.name}")
                        ci.methods[item.name] = fi
        # module-level `name = lambda` bindings
        for node in self.tree.body:
            if (isinstance(node, ast.Assign)
                    and isinstance(node.value, ast.Lambda)
                    and len(node.targets) == 1
                    and isinstance(node.targets[0], ast.Name)):
                name = node.targets[0].id
                fi = FuncInfo(node.value, self, name)
                self.top_funcs.setdefault(name, []).append(fi)
                self.functions.setdefault(name, fi)

    def _add_func(self, node, parent: Optional[FuncInfo],
                  qualname: str) -> FuncInfo:
        fi = FuncInfo(node, self, qualname, parent)
        self.functions[qualname] = fi
        if parent is None:
            self.top_funcs.setdefault(node.name, []).append(fi)
        else:
            parent.local_funcs.setdefault(node.name, []).append(fi)
        self._index_nested(node, fi)
        return fi

    def _index_nested(self, node: ast.AST, owner: FuncInfo) -> None:
        for child in ast.iter_child_nodes(node):
            if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef)):
                self._add_func(child, owner,
                               f"{owner.qualname}.{child.name}")
            elif (isinstance(child, ast.Assign)
                    and isinstance(child.value, ast.Lambda)
                    and len(child.targets) == 1
                    and isinstance(child.targets[0], ast.Name)):
                name = child.targets[0].id
                fi = FuncInfo(child.value, self,
                              f"{owner.qualname}.{name}", owner)
                owner.local_funcs.setdefault(name, []).append(fi)
            elif not isinstance(child, ast.ClassDef):
                self._index_nested(child, owner)


class Project:
    """All parsed modules under the analysis roots, with repo-wide indexes."""

    def __init__(self, roots: Sequence[str], repo_root: str):
        from repro.analysis.registry import KNOWN_ENTRY_POINTS
        self.repo_root = os.path.abspath(repo_root)
        self.modules: Dict[str, ModuleInfo] = {}     # rel path -> info
        self.methods_by_name: Dict[str, List[FuncInfo]] = {}
        #: method names that resolve project-wide (protocol dispatch the
        #: registry vouches for); everything else resolves same-module only
        self.registry_method_names = frozenset(
            e.qualname.split(".")[1] for e in KNOWN_ENTRY_POINTS
            if "." in e.qualname)
        for root in roots:
            root = os.path.abspath(root)
            if os.path.isfile(root):
                self._load(root)
                continue
            for dirpath, dirnames, filenames in os.walk(root):
                dirnames[:] = [d for d in dirnames
                               if d not in ("__pycache__", ".git")]
                for fn in sorted(filenames):
                    if fn.endswith(".py"):
                        self._load(os.path.join(dirpath, fn))
        for mod in self.modules.values():
            for ci in mod.classes.values():
                for name, fi in ci.methods.items():
                    self.methods_by_name.setdefault(name, []).append(fi)

    def _load(self, path: str) -> None:
        rel = os.path.relpath(path, self.repo_root)
        with open(path) as fh:
            source = fh.read()
        try:
            self.modules[rel] = ModuleInfo(path, rel, source)
        except SyntaxError as exc:                     # pragma: no cover
            raise SyntaxError(f"{rel}: {exc}") from exc

    # ----------------------------------------------------------- resolution
    def module_for_dotted(self, dotted: str) -> Optional[ModuleInfo]:
        """Map an import target like ``repro.kernels.gmm.ops`` to a parsed
        module (only modules inside the analysis roots resolve)."""
        rel = dotted.replace(".", os.sep) + ".py"
        for known in self.modules:
            if known.endswith(rel):
                return self.modules[known]
        return None

    def resolve_name(self, name: str, mod: ModuleInfo,
                     scope: Optional[FuncInfo]) -> List[FuncInfo]:
        """Candidates for a bare ``name`` referenced from ``scope``."""
        s = scope
        while s is not None:
            if name in s.local_funcs:
                return list(s.local_funcs[name])
            s = s.parent
        if name in mod.top_funcs:
            return list(mod.top_funcs[name])
        target = mod.imports.get(name)
        if target and "." in target:
            owner, attr = target.rsplit(".", 1)
            owned = self.module_for_dotted(owner)
            if owned and attr in owned.top_funcs:
                return list(owned.top_funcs[attr])
        return []

    def resolve_attr_call(self, value: ast.expr, attr: str,
                          mod: ModuleInfo) -> List[FuncInfo]:
        """Candidates for ``value.attr(...)``.

        * ``module_alias.attr`` resolves through the import map;
        * anything else falls back to *method-name* resolution, scoped to
          keep candidate sets honest: methods named in the registry's
          ``KNOWN_ENTRY_POINTS`` (the protocol-dispatched surface:
          ``extend``, ``propose``, ``commit`` …) resolve project-wide;
          any other method name resolves only to classes defined in the
          *calling* module.  Dunder and list/dict-builtin-ish names are
          skipped to avoid resolving ``list.append`` and friends.
        """
        if isinstance(value, ast.Name):
            target = mod.imports.get(value.id)
            if target:
                owned = self.module_for_dotted(target)
                if owned:
                    if attr in owned.top_funcs:
                        return list(owned.top_funcs[attr])
                    return []                 # external module: unresolvable
        if attr.startswith("__"):
            return []
        cands = self.methods_by_name.get(attr, [])
        if attr in self.registry_method_names:
            return list(cands)
        if attr in _BUILTIN_METHODS:
            return []
        return [c for c in cands if c.module is mod]

    def returned_functions(self, fi: FuncInfo
                           ) -> List[List[FuncInfo]]:
        """Per-return-position candidates when ``fi`` returns local
        functions — ``return propose, verify, finalize`` or ``return fn``.
        Empty when the return value isn't function-shaped."""
        shapes: List[List[List[FuncInfo]]] = []
        for node in ast.walk(self.fn_body_root(fi)):
            if not isinstance(node, ast.Return) or node.value is None:
                continue
            elts = (node.value.elts
                    if isinstance(node.value, ast.Tuple) else [node.value])
            pos: List[List[FuncInfo]] = []
            for e in elts:
                if isinstance(e, ast.Name):
                    pos.append(self.resolve_name(e.id, fi.module, fi))
                elif isinstance(e, ast.Lambda):
                    pos.append([FuncInfo(e, fi.module,
                                         f"{fi.qualname}.<lambda>", fi)])
                else:
                    pos.append([])
            shapes.append(pos)
        if not shapes:
            return []
        width = max(len(s) for s in shapes)
        merged: List[List[FuncInfo]] = [[] for _ in range(width)]
        for s in shapes:
            for i, cands in enumerate(s):
                for c in cands:
                    if c not in merged[i]:
                        merged[i].append(c)
        return merged

    @staticmethod
    def fn_body_root(fi: FuncInfo) -> ast.AST:
        return fi.node


_BUILTIN_METHODS = frozenset({
    "append", "extend", "add", "pop", "popleft", "update", "get", "items",
    "keys", "values", "remove", "clear", "insert", "setdefault", "join",
    "split", "strip", "format", "sum", "mean", "min", "max", "reshape",
    "astype", "copy", "sort", "startswith", "endswith",
})


# -------------------------------------------------------------- const eval
_DTYPE_BYTES = {
    "float32": 4, "f32": 4, "int32": 4, "uint32": 4,
    "bfloat16": 2, "bf16": 2, "float16": 2, "int16": 2,
    "float64": 8, "int64": 8,
    "int8": 1, "uint8": 1, "bool": 1, "bool_": 1,
    "float8_e4m3fn": 1, "float8_e5m2": 1,
}


def dtype_token(expr: ast.expr) -> Optional[str]:
    """``jnp.float32`` / ``np.int8`` / ``"bfloat16"`` -> canonical token."""
    if isinstance(expr, ast.Attribute) and expr.attr in _DTYPE_BYTES:
        return expr.attr
    if isinstance(expr, ast.Constant) and isinstance(expr.value, str) \
            and expr.value in _DTYPE_BYTES:
        return expr.value
    return None


def dtype_bytes(token: Optional[str]) -> Optional[int]:
    return _DTYPE_BYTES.get(token or "")


def const_eval(expr: Optional[ast.expr],
               env: Dict[str, object]) -> Optional[object]:
    """Best-effort static evaluation: ints/strs/bools/tuples through
    arithmetic, names via ``env``.  Returns None when any leaf is unknown —
    callers treat None as "symbolic, skip the numeric check"."""
    if expr is None:
        return None
    if isinstance(expr, ast.Constant):
        return expr.value
    if isinstance(expr, ast.Name):
        return env.get(expr.id)
    if isinstance(expr, ast.Tuple):
        vals = [const_eval(e, env) for e in expr.elts]
        return None if any(v is None for v in vals) else tuple(vals)
    if isinstance(expr, ast.UnaryOp) and isinstance(expr.op, ast.USub):
        v = const_eval(expr.operand, env)
        return None if not isinstance(v, (int, float)) else -v
    if isinstance(expr, ast.BinOp):
        lhs = const_eval(expr.left, env)
        rhs = const_eval(expr.right, env)
        if not (isinstance(lhs, (int, float))
                and isinstance(rhs, (int, float))):
            return None
        try:
            if isinstance(expr.op, ast.Add):
                return lhs + rhs
            if isinstance(expr.op, ast.Sub):
                return lhs - rhs
            if isinstance(expr.op, ast.Mult):
                return lhs * rhs
            if isinstance(expr.op, ast.FloorDiv):
                return lhs // rhs
            if isinstance(expr.op, ast.Div):
                return lhs / rhs
            if isinstance(expr.op, ast.Mod):
                return lhs % rhs
            if isinstance(expr.op, ast.Pow):
                return lhs ** rhs
        except (ZeroDivisionError, OverflowError, ValueError):
            return None
    if isinstance(expr, ast.Call) and isinstance(expr.func, ast.Name) \
            and expr.func.id in ("min", "max") and expr.args:
        vals = [const_eval(a, env) for a in expr.args]
        if all(isinstance(v, (int, float)) for v in vals):
            return (min if expr.func.id == "min" else max)(vals)
    return None


def call_keywords(call: ast.Call) -> Dict[str, ast.expr]:
    return {kw.arg: kw.value for kw in call.keywords if kw.arg}


def is_dotted(expr: ast.expr, *paths: str) -> bool:
    """True when ``expr`` spells one of the dotted ``paths``
    (e.g. ``is_dotted(node, "jax.jit", "jit")``)."""
    return dotted_name(expr) in paths


def dotted_name(expr: ast.expr) -> Optional[str]:
    if isinstance(expr, ast.Name):
        return expr.id
    if isinstance(expr, ast.Attribute):
        base = dotted_name(expr.value)
        return f"{base}.{expr.attr}" if base else None
    return None


def iter_calls(root: ast.AST) -> Iterator[ast.Call]:
    for node in ast.walk(root):
        if isinstance(node, ast.Call):
            yield node


def assigned_names(target: ast.expr) -> List[str]:
    if isinstance(target, ast.Name):
        return [target.id]
    if isinstance(target, (ast.Tuple, ast.List)):
        out: List[str] = []
        for e in target.elts:
            out.extend(assigned_names(e))
        return out
    if isinstance(target, ast.Starred):
        return assigned_names(target.value)
    return []

"""Pallas kernel-contract lint: BlockSpecs, grids, scalar prefetch, VMEM.

Statically parses every ``pl.pallas_call`` site and checks the contracts
the TPU lowering enforces at runtime (or worse, silently pads around):

========  ===========================================================
 P301     index-map arity != len(grid) + num_scalar_prefetch.
 P302     kernel positional-parameter count != scalar-prefetch operands
          + inputs + outputs + scratch refs.
 P303     BlockSpec block dims unaligned to the dtype's TPU tile
          (last dim % 128, second-to-last % 8 fp32 / % 16 bf16 /
          % 32 int8-fp8).
 P304     statically-resolvable VMEM footprint (blocks + scratch)
          exceeds the budget (default 16 MiB/core).
 P305     grid-spec inconsistency: ``grid_spec=`` combined with direct
          ``grid``/``in_specs``/``out_specs``/``scratch_shapes`` kwargs,
          a non-constant ``num_scalar_prefetch``, or a
          ``PrefetchScalarGridSpec`` with no grid.
========  ===========================================================

Everything is best-effort symbolic: a dim that does not const-evaluate
(e.g. a runtime ``d``) is skipped, never guessed, so the checks that do
fire are real.  Counts (P302) are only checked when ``in_specs`` /
``out_shape`` / ``scratch_shapes`` are statically-sized literals — the
ragged-GMM builder assembles its spec lists dynamically and is skipped by
design.  P304 sums only resolvable footprints, so it can under-count but
never false-positives.
"""
from __future__ import annotations

import ast
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

from repro.analysis._astutil import (FuncInfo, ModuleInfo, Project,
                                     call_keywords, const_eval, dotted_name,
                                     dtype_bytes, dtype_token)
from repro.analysis.findings import Finding

_PALLAS_NAMES = ("pl.pallas_call", "pallas_call", "pallas.pallas_call")
_PARTIAL_NAMES = ("functools.partial", "partial")
#: second-to-last-dim tile requirement per dtype token (last dim is 128)
_SUBLANE = {"float32": 8, "f32": 8, "int32": 8, "uint32": 8,
            "bfloat16": 16, "bf16": 16, "float16": 16,
            "int8": 32, "uint8": 32, "float8_e4m3fn": 32,
            "float8_e5m2": 32}
_LANE = 128
_DEFAULT_VMEM_BUDGET = 16 * 1024 * 1024        # bytes/core (TPU v4/v5)


@dataclass
class _Site:
    call: ast.Call
    mod: ModuleInfo
    scope: Optional[FuncInfo]
    env: Dict[str, object]
    local_assigns: Dict[str, ast.expr]


class PallasLint:
    def __init__(self, project: Project,
                 vmem_budget: int = _DEFAULT_VMEM_BUDGET):
        self.project = project
        self.vmem_budget = vmem_budget
        self.findings: List[Finding] = []

    def run(self) -> List[Finding]:
        for mod in self.project.modules.values():
            module_env = self._module_env(mod)
            seen: set = set()
            for fi in mod.functions.values():
                env = dict(module_env)
                for name, default in fi.param_defaults().items():
                    v = const_eval(default, env)
                    if v is not None:
                        env[name] = v
                assigns = self._scope_assigns(fi, env)
                for node in ast.walk(fi.node):
                    if isinstance(node, ast.Call) \
                            and dotted_name(node.func) in _PALLAS_NAMES \
                            and id(node) not in seen:
                        seen.add(id(node))
                        self._check_site(_Site(node, mod, fi, env, assigns))
            for node in ast.walk(mod.tree):
                if isinstance(node, ast.Call) \
                        and dotted_name(node.func) in _PALLAS_NAMES \
                        and id(node) not in seen:
                    seen.add(id(node))
                    self._check_site(_Site(node, mod, None,
                                           dict(module_env), {}))
        self.findings.sort(key=lambda f: (f.path, f.line, f.code))
        return self.findings

    # ------------------------------------------------------------------- env
    def _module_env(self, mod: ModuleInfo) -> Dict[str, object]:
        env: Dict[str, object] = {}
        for node in mod.tree.body:
            if isinstance(node, ast.Assign) and len(node.targets) == 1 \
                    and isinstance(node.targets[0], ast.Name):
                v = const_eval(node.value, env)
                if v is not None:
                    env[node.targets[0].id] = v
        return env

    def _scope_assigns(self, fi: FuncInfo,
                       env: Dict[str, object]) -> Dict[str, ast.expr]:
        """Single-assignment locals in the scope chain (name -> RHS), with
        const-evaluatable ones also folded into ``env``."""
        out: Dict[str, ast.expr] = {}
        counts: Dict[str, int] = {}
        s: Optional[FuncInfo] = fi
        while s is not None:
            for node in ast.walk(s.node):
                if isinstance(node, ast.Assign) and len(node.targets) == 1 \
                        and isinstance(node.targets[0], ast.Name):
                    name = node.targets[0].id
                    counts[name] = counts.get(name, 0) + 1
                    out.setdefault(name, node.value)
            s = s.parent
        for name, rhs in out.items():
            if counts.get(name, 0) == 1 and name not in env:
                v = const_eval(rhs, env)
                if v is not None:
                    env[name] = v
        return {n: e for n, e in out.items() if counts.get(n, 0) == 1}

    # ------------------------------------------------------------- the site
    def _check_site(self, site: _Site) -> None:
        call, kw = site.call, call_keywords(site.call)
        nsp = 0
        grid_expr: Optional[ast.expr] = None
        spec_kw: Dict[str, ast.expr] = kw
        gs = kw.get("grid_spec")
        if gs is not None and isinstance(gs, ast.Call) \
                and (dotted_name(gs.func) or "").endswith(
                    "PrefetchScalarGridSpec"):
            gkw = call_keywords(gs)
            # P305 — conflicting direct kwargs alongside a grid spec
            overlap = [k for k in ("grid", "in_specs", "out_specs",
                                   "scratch_shapes") if k in kw]
            if overlap:
                self._emit(site, call, "P305",
                           "grid_spec= combined with direct "
                           f"{'/'.join(overlap)} kwarg(s)")
            n = const_eval(gkw.get("num_scalar_prefetch"), site.env)
            if "num_scalar_prefetch" in gkw and (not isinstance(n, int)
                                                 or n < 0):
                self._emit(site, gs, "P305",
                           "num_scalar_prefetch is not a non-negative "
                           "int constant")
                n = None
            nsp = n if isinstance(n, int) else 0
            if "grid" not in gkw:
                self._emit(site, gs, "P305",
                           "PrefetchScalarGridSpec without a grid")
            grid_expr = gkw.get("grid")
            spec_kw = gkw
        else:
            grid_expr = kw.get("grid")

        grid_len = self._grid_len(grid_expr, site)
        in_specs = spec_kw.get("in_specs")
        out_specs = spec_kw.get("out_specs")
        scratch = spec_kw.get("scratch_shapes")
        out_dtype = self._out_dtype(kw.get("out_shape"))

        # ---- P301: every resolvable index map must take grid + scalars
        if grid_len is not None:
            want = grid_len + nsp
            for spec in self._blockspecs(in_specs) \
                    + self._blockspecs(out_specs):
                for fn, arity in self._index_maps(spec, site):
                    if arity != want:
                        self._emit(site, spec, "P301",
                                   f"index map `{fn}` takes {arity} args; "
                                   f"grid has {grid_len} dim(s) + {nsp} "
                                   "scalar-prefetch operand(s) = "
                                   f"{want} expected")

        # ---- P303: tile alignment of every resolvable block shape
        for spec in self._blockspecs(in_specs):
            self._check_tile(site, spec, self._block_dims(spec, site), None)
        for spec in self._blockspecs(out_specs):
            self._check_tile(site, spec, self._block_dims(spec, site),
                             out_dtype)
        for vm in self._vmem_calls(scratch):
            dims = const_eval(vm.args[0] if vm.args else None, site.env)
            tok = dtype_token(vm.args[1]) if len(vm.args) > 1 else None
            if isinstance(dims, tuple):
                self._check_tile(site, vm, list(dims), tok)

        # ---- P302: ref count, only when everything is statically sized
        self._check_param_count(site, nsp, in_specs, scratch,
                                kw.get("out_shape"), out_specs)

        # ---- P304: resolvable VMEM footprint vs budget
        self._check_vmem(site, in_specs, out_specs, scratch, out_dtype)

    # ------------------------------------------------------------ resolution
    def _grid_len(self, grid_expr: Optional[ast.expr],
                  site: _Site) -> Optional[int]:
        if isinstance(grid_expr, ast.Name):
            grid_expr = site.local_assigns.get(grid_expr.id, grid_expr)
        if isinstance(grid_expr, ast.Tuple):
            return len(grid_expr.elts)
        v = const_eval(grid_expr, site.env)
        if isinstance(v, tuple):
            return len(v)
        if isinstance(v, int):
            return 1
        return None

    def _blockspecs(self, expr: Optional[ast.expr]) -> List[ast.Call]:
        if expr is None:
            return []
        return [n for n in ast.walk(expr)
                if isinstance(n, ast.Call)
                and (dotted_name(n.func) or "").endswith("BlockSpec")]

    def _vmem_calls(self, expr: Optional[ast.expr]) -> List[ast.Call]:
        if expr is None:
            return []
        return [n for n in ast.walk(expr)
                if isinstance(n, ast.Call)
                and (dotted_name(n.func) or "").endswith("VMEM")]

    def _index_maps(self, spec: ast.Call,
                    site: _Site) -> List[Tuple[str, int]]:
        expr = None
        if len(spec.args) > 1:
            expr = spec.args[1]
        else:
            expr = call_keywords(spec).get("index_map")
        if expr is None:
            return []
        out: List[Tuple[str, int]] = []
        if isinstance(expr, ast.Lambda):
            out.append(("<lambda>", len(expr.args.args)))
        elif isinstance(expr, ast.Name):
            cands = self.project.resolve_name(expr.id, site.mod, site.scope)
            if not cands:
                cands = self._tuple_unpacked(expr.id, site)
            for fi in cands:
                out.append((fi.qualname, len(fi.positional_params())))
        return out

    def _tuple_unpacked(self, name: str, site: _Site) -> List[FuncInfo]:
        """Resolve ``x_map, w_map, o_map = _scalar_maps()`` bindings: find
        the builder, take the lambda candidates at the matching position."""
        s = site.scope
        while s is not None:
            for node in ast.walk(s.node):
                if not (isinstance(node, ast.Assign)
                        and len(node.targets) == 1
                        and isinstance(node.targets[0], ast.Tuple)
                        and isinstance(node.value, ast.Call)):
                    continue
                names = [e.id if isinstance(e, ast.Name) else None
                         for e in node.targets[0].elts]
                if name not in names:
                    continue
                idx = names.index(name)
                targets: List[FuncInfo] = []
                if isinstance(node.value.func, ast.Name):
                    targets = self.project.resolve_name(
                        node.value.func.id, site.mod, s)
                for t in targets:
                    rets = self.project.returned_functions(t)
                    if idx < len(rets):
                        return rets[idx]
            s = s.parent
        return []

    def _block_dims(self, spec: ast.Call,
                    site: _Site) -> List[Optional[object]]:
        shape = spec.args[0] if spec.args \
            else call_keywords(spec).get("block_shape")
        if isinstance(shape, ast.Tuple):
            return [const_eval(e, site.env) for e in shape.elts]
        v = const_eval(shape, site.env)
        if isinstance(v, tuple):
            return list(v)
        return []

    def _out_dtype(self, out_shape: Optional[ast.expr]) -> Optional[str]:
        if out_shape is None:
            return None
        for n in ast.walk(out_shape):
            if isinstance(n, ast.Call) \
                    and (dotted_name(n.func) or "").endswith(
                        "ShapeDtypeStruct"):
                dt = (n.args[1] if len(n.args) > 1
                      else call_keywords(n).get("dtype"))
                if dt is not None:
                    return dtype_token(dt)
        return None

    # ---------------------------------------------------------------- checks
    def _check_tile(self, site: _Site, node: ast.AST,
                    dims: Sequence[Optional[object]],
                    dtype: Optional[str]) -> None:
        if len(dims) < 1:
            return
        sublane = _SUBLANE.get(dtype or "float32", 8)
        last = dims[-1]
        if isinstance(last, int) and last != 1 and last % _LANE:
            self._emit(site, node, "P303",
                       f"block last dim {last} not a multiple of {_LANE} "
                       f"(dtype {dtype or 'float32'})")
        if len(dims) >= 2:
            sub = dims[-2]
            if isinstance(sub, int) and sub != 1 and sub % sublane:
                self._emit(site, node, "P303",
                           f"block second-to-last dim {sub} not a multiple "
                           f"of {sublane} (dtype {dtype or 'float32'})")

    def _count(self, expr: Optional[ast.expr],
               env: Dict[str, object]) -> Optional[int]:
        """Static element count of a spec list: literal list, or
        ``[x] * k`` with constant k.  None = not statically sized."""
        if expr is None:
            return 0
        if isinstance(expr, ast.List):
            return len(expr.elts)
        if isinstance(expr, ast.BinOp) and isinstance(expr.op, ast.Mult):
            left = self._count(expr.left, env)
            k = const_eval(expr.right, env)
            if left is not None and isinstance(k, int):
                return left * k
        if isinstance(expr, ast.Call) \
                and (dotted_name(expr.func) or "").endswith("BlockSpec"):
            return 1
        return None

    def _check_param_count(self, site: _Site, nsp: int,
                           in_specs: Optional[ast.expr],
                           scratch: Optional[ast.expr],
                           out_shape: Optional[ast.expr],
                           out_specs: Optional[ast.expr]) -> None:
        n_in = self._count(in_specs, site.env)
        n_scratch = self._count(scratch, site.env) if scratch is not None \
            else 0
        n_out = self._n_out(out_shape, out_specs, site)
        kernel = self._kernel_params(site)
        if None in (n_in, n_scratch, n_out) or kernel is None:
            return
        name, n_params = kernel
        want = nsp + n_in + n_out + n_scratch
        if n_params != want:
            self._emit(site, site.call, "P302",
                       f"kernel `{name}` takes {n_params} positional "
                       f"ref(s); {nsp} scalar + {n_in} in + {n_out} out + "
                       f"{n_scratch} scratch = {want} expected")

    def _n_out(self, out_shape: Optional[ast.expr],
               out_specs: Optional[ast.expr],
               site: _Site) -> Optional[int]:
        if isinstance(out_shape, (ast.List, ast.Tuple)):
            return len(out_shape.elts)
        if isinstance(out_shape, ast.Call) \
                and (dotted_name(out_shape.func) or "").endswith(
                    "ShapeDtypeStruct"):
            return 1
        if out_specs is not None:
            return self._count(out_specs, site.env)
        return None

    def _kernel_params(self, site: _Site) -> Optional[Tuple[str, int]]:
        """Resolve the kernel arg (name / lambda / functools.partial) to
        (display name, unbound positional-param count)."""
        if not site.call.args:
            return None
        expr: ast.expr = site.call.args[0]
        if isinstance(expr, ast.Name) and expr.id in site.local_assigns:
            cands = self.project.resolve_name(expr.id, site.mod, site.scope)
            if not cands:
                expr = site.local_assigns[expr.id]
        bound_pos = 0
        bound_kw: set = set()
        if isinstance(expr, ast.Call) \
                and dotted_name(expr.func) in _PARTIAL_NAMES and expr.args:
            bound_pos = len(expr.args) - 1
            bound_kw = {k.arg for k in expr.keywords if k.arg}
            expr = expr.args[0]
        if isinstance(expr, ast.Lambda):
            return ("<lambda>", len(expr.args.args) - bound_pos)
        if isinstance(expr, ast.Name):
            cands = self.project.resolve_name(expr.id, site.mod, site.scope)
            if len(cands) == 1:
                fi = cands[0]
                pos = [p for p in fi.positional_params()
                       if p not in bound_kw]
                return (fi.qualname, len(pos) - bound_pos)
        return None

    def _check_vmem(self, site: _Site, in_specs: Optional[ast.expr],
                    out_specs: Optional[ast.expr],
                    scratch: Optional[ast.expr],
                    out_dtype: Optional[str]) -> None:
        total = 0
        for spec in self._blockspecs(in_specs):
            total += self._footprint(self._block_dims(spec, site), "float32")
        for spec in self._blockspecs(out_specs):
            total += self._footprint(self._block_dims(spec, site),
                                     out_dtype or "float32")
        scratch_bytes = 0
        for vm in self._vmem_calls(scratch):
            dims = const_eval(vm.args[0] if vm.args else None, site.env)
            tok = dtype_token(vm.args[1]) if len(vm.args) > 1 else None
            if isinstance(dims, tuple):
                scratch_bytes += self._footprint(list(dims),
                                                 tok or "float32")
        # `[VMEM(...)] * k` replicates the footprint k times
        if scratch is not None and isinstance(scratch, ast.BinOp) \
                and isinstance(scratch.op, ast.Mult):
            k = const_eval(scratch.right, site.env)
            if isinstance(k, int) and k > 1:
                scratch_bytes *= k
        total += scratch_bytes
        if total > self.vmem_budget:
            self._emit(site, site.call, "P304",
                       f"resolvable VMEM footprint {total / 2**20:.1f} MiB "
                       f"exceeds the {self.vmem_budget / 2**20:.0f} MiB "
                       "budget")

    def _footprint(self, dims: Sequence[Optional[object]],
                   dtype: Optional[str]) -> int:
        if not dims or not all(isinstance(d, int) for d in dims):
            return 0
        n = 1
        for d in dims:
            n *= int(d)                      # type: ignore[arg-type]
        return n * (dtype_bytes(dtype) or 4)

    def _emit(self, site: _Site, node: ast.AST, code: str,
              message: str) -> None:
        line = getattr(node, "lineno", site.call.lineno)
        self.findings.append(Finding(site.mod.rel, line, code, message))


def run(project: Project, vmem_budget: int = _DEFAULT_VMEM_BUDGET
        ) -> List[Finding]:
    """Entry point used by the driver: all Pallas-contract findings."""
    return PallasLint(project, vmem_budget=vmem_budget).run()

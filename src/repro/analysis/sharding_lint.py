"""Sharding/collective contract lint: the shard_map + host-boundary rules.

PR 9 made serving mesh-native; these are the contracts that keep it
correct and retrace-free (docs/distributed.md), none of which jax checks
statically:

========  ===========================================================
 S401     a collective inside a shard_map body names an axis that is
          neither mentioned in the site's ``in_specs``/``out_specs``
          literals nor one of the repo's known mesh axes
          (``registry.KNOWN_MESH_AXES``) — a typo'd axis name fails at
          run time on the first sharded deployment, not in CI.
 S402     ``in_specs`` arity does not match the wrapped function's
          positional signature (after ``functools.partial`` binding),
          or a tuple ``out_specs`` disagrees with the body's returned
          tuple length.
 S403     a host array (``np.*``-derived) is passed straight into a
          cached jit program instead of flowing through the class's
          ``_host`` boundary helper / ``constrain`` — the second
          sharding signature that silently retraces every program.
 S404     a paged cache-pool leaf (``*_pages`` / ``pages/*``) is not
          covered by an explicit ``cache_spec`` placement rule, or a
          literal ``cache_spec(path)`` call falls through to the
          default batch rule.
 S405     deprecated ``set_mesh`` process-global — thread the mesh
          explicitly (``Model(cfg, mesh=...)``, ``constrain(mesh=...)``).
========  ===========================================================

Like every pass here the analysis is source-level and best-effort: axis
names and spec arities are checked where they resolve to literals (via
one level of local assignment and ``partial`` keyword binding) and
skipped where they stay symbolic.
"""
from __future__ import annotations

import ast
import re
from typing import Dict, Iterator, List, Optional, Set, Tuple

from repro.analysis._astutil import (FuncInfo, ModuleInfo, Project,
                                     call_keywords, dotted_name)
from repro.analysis.findings import Finding
from repro.analysis.registry import KNOWN_MESH_AXES

_SHARD_MAP_NAMES = ("shard_map", "jax.experimental.shard_map.shard_map",
                    "shmap")
_PARTIAL_NAMES = ("functools.partial", "partial")

#: collective name -> positional index of its axis-name argument
_COLLECTIVES: Dict[str, int] = {
    "psum": 1, "pmean": 1, "pmax": 1, "pmin": 1, "all_gather": 1,
    "all_to_all": 1, "ppermute": 1, "pshuffle": 1, "pswapaxes": 1,
    "axis_index": 0, "psum_scatter": 1,
}

#: argument expressions S403 accepts at a cached-program boundary; anything
#: demonstrably numpy-derived must cross through one of these instead
_HOST_BOUNDARY_CALLS = ("_host", "constrain", "device_put")

_NP_PREFIXES = ("np.", "numpy.")


def _own_nodes(fi: FuncInfo) -> Iterator[ast.AST]:
    """Nodes of ``fi``'s own body, not descending into nested defs."""
    stack: List[ast.AST] = list(fi.body())
    while stack:
        node = stack.pop()
        yield node
        for child in ast.iter_child_nodes(node):
            if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef,
                                  ast.Lambda)):
                yield child
                continue
            stack.append(child)


def _module_scope_nodes(mod: ModuleInfo) -> Iterator[ast.AST]:
    """Top-level nodes (module pseudo-scope), not descending into defs."""
    stack: List[ast.AST] = list(mod.tree.body)
    while stack:
        node = stack.pop()
        yield node
        for child in ast.iter_child_nodes(node):
            if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef,
                                  ast.Lambda, ast.ClassDef)):
                continue
            stack.append(child)


class ShardingLint:
    def __init__(self, project: Project):
        self.project = project
        self.findings: List[Finding] = []
        self._seen: Set[Tuple[str, int, str]] = set()

    def emit(self, mod: ModuleInfo, line: int, code: str, msg: str) -> None:
        key = (mod.rel, line, code)
        if key not in self._seen:
            self._seen.add(key)
            self.findings.append(Finding(mod.rel, line, code, msg))

    def run(self) -> List[Finding]:
        for mod in self.project.modules.values():
            self._check_set_mesh(mod)
            self._check_cache_spec_calls(mod)
            for fi in mod.functions.values():
                for node in _own_nodes(fi):
                    if isinstance(node, ast.Call) and self._is_shard_map(node):
                        self._check_shard_map_site(mod, fi, node)
            for node in _module_scope_nodes(mod):
                if isinstance(node, ast.Call) and self._is_shard_map(node):
                    self._check_shard_map_site(mod, None, node)
            self._check_host_boundaries(mod)
        self._check_cache_spec_rules()
        self.findings.sort(key=lambda f: (f.path, f.line, f.code))
        return self.findings

    # ----------------------------------------------------------------- S405
    def _check_set_mesh(self, mod: ModuleInfo) -> None:
        if mod.rel.endswith("distributed/constraints.py"):
            return
        for node in ast.walk(mod.tree):
            if isinstance(node, ast.Call):
                dn = dotted_name(node.func)
                if dn and (dn == "set_mesh" or dn.endswith(".set_mesh")):
                    self.emit(mod, node.lineno, "S405",
                              "set_mesh is a removed process-global; thread "
                              "the mesh explicitly (Model(cfg, mesh=...))")

    # ------------------------------------------------------- shard_map sites
    def _is_shard_map(self, call: ast.Call) -> bool:
        dn = dotted_name(call.func)
        return bool(dn) and (dn in _SHARD_MAP_NAMES
                             or dn.endswith(".shard_map"))

    def _resolve_local(self, scope: Optional[FuncInfo],
                       name: str) -> Optional[ast.expr]:
        """Last ``name = <expr>`` assignment in the scope's own body."""
        if scope is None:
            return None
        found = None
        for node in _own_nodes(scope):
            if isinstance(node, ast.Assign) and len(node.targets) == 1 \
                    and isinstance(node.targets[0], ast.Name) \
                    and node.targets[0].id == name:
                found = node.value
        return found

    def _spec_expr(self, scope: Optional[FuncInfo],
                   expr: Optional[ast.expr]) -> Optional[ast.expr]:
        if isinstance(expr, ast.Name):
            return self._resolve_local(scope, expr.id)
        return expr

    def _check_shard_map_site(self, mod: ModuleInfo,
                              scope: Optional[FuncInfo],
                              call: ast.Call) -> None:
        kws = call_keywords(call)
        fn_expr = call.args[0] if call.args else kws.get("f")
        if fn_expr is None:
            return
        in_specs = self._spec_expr(scope, kws.get("in_specs"))
        out_specs = self._spec_expr(scope, kws.get("out_specs"))

        # axes mentioned as string literals anywhere in the spec exprs
        spec_axes: Set[str] = set()
        for spec in (in_specs, out_specs):
            if spec is not None:
                for node in ast.walk(spec):
                    if isinstance(node, ast.Constant) \
                            and isinstance(node.value, str):
                        spec_axes.add(node.value)
        allowed = spec_axes | set(KNOWN_MESH_AXES)

        bound_kw: Dict[str, ast.expr] = {}
        n_bound_pos = 0
        body_expr = fn_expr
        if isinstance(fn_expr, ast.Call):
            dn = dotted_name(fn_expr.func)
            if dn in _PARTIAL_NAMES and fn_expr.args:
                body_expr = fn_expr.args[0]
                n_bound_pos = len(fn_expr.args) - 1
                bound_kw = call_keywords(fn_expr)
        candidates = self._resolve_fn(mod, scope, body_expr)

        for body in candidates:
            self._check_collective_axes(mod, body, bound_kw, allowed)
            self._check_spec_arity(mod, call, body, n_bound_pos, bound_kw,
                                   in_specs, out_specs)

    def _resolve_fn(self, mod: ModuleInfo, scope: Optional[FuncInfo],
                    expr: ast.expr) -> List[FuncInfo]:
        if isinstance(expr, ast.Name):
            return self.project.resolve_name(expr.id, mod, scope)
        if isinstance(expr, ast.Attribute):
            return self.project.resolve_attr_call(expr.value, expr.attr, mod)
        if isinstance(expr, ast.Lambda):
            return [FuncInfo(expr, mod, "<lambda>", scope)]
        return []

    # ----------------------------------------------------------------- S401
    def _collective_calls(self, body: FuncInfo
                          ) -> Iterator[Tuple[ast.Call, str, int]]:
        for node in ast.walk(body.node):
            if not isinstance(node, ast.Call):
                continue
            dn = dotted_name(node.func)
            if not dn:
                continue
            tail = dn.rsplit(".", 1)[-1]
            if tail not in _COLLECTIVES:
                continue
            # jax.lax.psum / lax.psum / a `from jax import lax` alias; a
            # bare name must import from jax.lax to count
            if "." not in dn:
                target = body.module.imports.get(dn, "")
                if not target.startswith("jax.lax"):
                    continue
            elif not (dn.startswith("jax.lax.") or dn.startswith("lax.")):
                continue
            yield node, tail, _COLLECTIVES[tail]

    def _check_collective_axes(self, mod: ModuleInfo, body: FuncInfo,
                               bound_kw: Dict[str, ast.expr],
                               allowed: Set[str]) -> None:
        for call, name, axis_pos in self._collective_calls(body):
            kws = call_keywords(call)
            axis_expr = kws.get("axis_name")
            if axis_expr is None and len(call.args) > axis_pos:
                axis_expr = call.args[axis_pos]
            for axis in self._axis_strings(body, bound_kw, axis_expr):
                if axis not in allowed:
                    self.emit(mod, call.lineno, "S401",
                              f"{name} over axis {axis!r}: not in the "
                              f"shard_map site's specs or the known mesh "
                              f"axes {sorted(allowed)}")

    def _axis_strings(self, body: FuncInfo, bound_kw: Dict[str, ast.expr],
                      expr: Optional[ast.expr]) -> List[str]:
        """Statically-known axis names in an ``axis_name`` argument:
        string literals, tuples of them, or a parameter bound to a string
        constant by the site's ``partial``."""
        if expr is None:
            return []
        if isinstance(expr, ast.Constant) and isinstance(expr.value, str):
            return [expr.value]
        if isinstance(expr, (ast.Tuple, ast.List)):
            out: List[str] = []
            for e in expr.elts:
                out.extend(self._axis_strings(body, bound_kw, e))
            return out
        if isinstance(expr, ast.Name):
            bound = bound_kw.get(expr.id)
            if isinstance(bound, ast.Constant) \
                    and isinstance(bound.value, str):
                return [bound.value]
            local = self._resolve_local(body, expr.id)
            if isinstance(local, ast.Constant) \
                    and isinstance(local.value, str):
                return [local.value]
        return []

    # ----------------------------------------------------------------- S402
    def _check_spec_arity(self, mod: ModuleInfo, site: ast.Call,
                          body: FuncInfo, n_bound_pos: int,
                          bound_kw: Dict[str, ast.expr],
                          in_specs: Optional[ast.expr],
                          out_specs: Optional[ast.expr]) -> None:
        if body.node.args.vararg is None \
                and isinstance(in_specs, (ast.Tuple, ast.List)):
            pos = body.positional_params()
            n_defaults = len(body.node.args.defaults)
            bound = n_bound_pos + sum(1 for k in bound_kw if k in pos)
            required = len(pos) - bound
            n_specs = len(in_specs.elts)
            if not (required - n_defaults <= n_specs <= required):
                self.emit(mod, site.lineno, "S402",
                          f"in_specs has {n_specs} entr(ies) but "
                          f"{body.name}() takes {required} positional "
                          f"arg(s) after partial binding")
        if isinstance(out_specs, ast.Tuple):
            want = len(out_specs.elts)
            for node in ast.walk(body.node):
                if isinstance(node, ast.Return) and node.value is not None:
                    got = (len(node.value.elts)
                           if isinstance(node.value, ast.Tuple) else 1)
                    if got != want:
                        self.emit(mod, site.lineno, "S402",
                                  f"out_specs is a {want}-tuple but "
                                  f"{body.name}() returns {got} value(s)")

    # ----------------------------------------------------------------- S403
    def _check_host_boundaries(self, mod: ModuleInfo) -> None:
        for ci in mod.classes.values():
            if "_host" not in ci.methods:
                continue
            builders = {name for name, fi in ci.methods.items()
                        if self._contains_jit(fi)}
            if not builders:
                continue
            for name, fi in ci.methods.items():
                if name in builders:
                    continue
                self._check_boundary_method(mod, fi, builders)

    def _contains_jit(self, fi: FuncInfo) -> bool:
        for node in ast.walk(fi.node):
            if isinstance(node, ast.Call):
                dn = dotted_name(node.func)
                if dn in ("jax.jit", "jit", "api.jit"):
                    return True
        return False

    def _check_boundary_method(self, mod: ModuleInfo, fi: FuncInfo,
                               builders: Set[str]) -> None:
        # one statement-ordered sweep: assigns reclassify names as they
        # execute (``toks = np.full(...)`` then ``toks = self._host(toks)``
        # is clean), calls are checked against the state at their line —
        # a call embedded in an assignment sees the pre-assignment state.
        events: List[ast.AST] = []
        for node in _own_nodes(fi):
            if isinstance(node, ast.Assign):
                events.append(node)
            elif isinstance(node, ast.Call) \
                    and isinstance(node.func, ast.Name):
                events.append(node)
        events.sort(key=lambda n: (n.lineno, isinstance(n, ast.Assign)))
        program_vars: Set[str] = set()
        np_locals: Set[str] = set()
        for node in events:
            if isinstance(node, ast.Call):
                if node.func.id not in program_vars:
                    continue
                for arg in list(node.args) + [kw.value for kw in
                                              node.keywords]:
                    bad = self._host_arg(arg, np_locals)
                    if bad:
                        self.emit(mod, node.lineno, "S403",
                                  f"{bad} passed into cached program "
                                  f"{node.func.id}() without the _host/"
                                  f"constrain boundary — second sharding "
                                  f"signature, silent retrace")
                continue
            value = node.value
            if isinstance(value, ast.Call) \
                    and isinstance(value.func, ast.Attribute) \
                    and isinstance(value.func.value, ast.Name) \
                    and value.func.value.id == "self" \
                    and value.func.attr in builders:
                for t in node.targets:
                    for n in _flat_names(t):
                        program_vars.add(n)
                        np_locals.discard(n)
                continue
            if self._is_np_expr(value):
                for t in node.targets:
                    for n in _flat_names(t):
                        np_locals.add(n)
                        program_vars.discard(n)
                continue
            for t in node.targets:
                for n in _flat_names(t):
                    np_locals.discard(n)
                    program_vars.discard(n)

    def _is_np_expr(self, expr: ast.expr) -> bool:
        if isinstance(expr, ast.Call):
            dn = dotted_name(expr.func) or ""
            return dn.startswith(_NP_PREFIXES)
        return False

    def _host_arg(self, arg: ast.expr,
                  np_locals: Set[str]) -> Optional[str]:
        if isinstance(arg, ast.Call):
            dn = dotted_name(arg.func) or ""
            tail = dn.rsplit(".", 1)[-1]
            if tail in _HOST_BOUNDARY_CALLS:
                return None
            if dn.startswith(_NP_PREFIXES):
                return f"host array {dn}(...)"
        if isinstance(arg, ast.Name) and arg.id in np_locals:
            return f"host array {arg.id!r}"
        return None

    # ----------------------------------------------------------------- S404
    def _cache_spec_patterns(self) -> Optional[List[str]]:
        """Ordered ``re.search`` pattern literals inside the scanned tree's
        ``cache_spec`` definition (None when no definition is in scope)."""
        for mod in self.project.modules.values():
            fi = mod.functions.get("cache_spec")
            if fi is None:
                continue
            pats: List[str] = []
            for node in ast.walk(fi.node):
                if isinstance(node, ast.Call):
                    dn = dotted_name(node.func)
                    if dn and dn.endswith("search") and node.args \
                            and isinstance(node.args[0], ast.Constant) \
                            and isinstance(node.args[0].value, str):
                        pats.append(node.args[0].value)
            return pats
        return None

    def _covered(self, leaf: str, patterns: List[str]) -> bool:
        return any(re.search(p, leaf) for p in patterns)

    def _check_cache_spec_rules(self) -> None:
        patterns = self._cache_spec_patterns()
        if patterns is None:
            return
        for mod in self.project.modules.values():
            if mod.rel.endswith("distributed/sharding.py"):
                continue
            # only dict literals built inside cache constructors count as
            # pool pytrees; a config dict elsewhere may reuse leaf-ish keys
            for fi in mod.functions.values():
                if "cache" not in fi.name.lower():
                    continue
                for node in ast.walk(fi.node):
                    if not isinstance(node, ast.Dict):
                        continue
                    for key in node.keys:
                        if isinstance(key, ast.Constant) \
                                and isinstance(key.value, str) \
                                and key.value.endswith("_pages") \
                                and not self._covered(key.value, patterns):
                            self.emit(mod, key.lineno, "S404",
                                      f"paged pool leaf {key.value!r} "
                                      f"matches no cache_spec placement "
                                      f"rule — it would fall through to "
                                      f"the default batch rule")

    def _check_cache_spec_calls(self, mod: ModuleInfo) -> None:
        patterns = self._cache_spec_patterns()
        if patterns is None or mod.rel.endswith("distributed/sharding.py"):
            return
        for node in ast.walk(mod.tree):
            if isinstance(node, ast.Call):
                dn = dotted_name(node.func)
                if not (dn and dn.rsplit(".", 1)[-1] == "cache_spec"):
                    continue
                if node.args and isinstance(node.args[0], ast.Constant) \
                        and isinstance(node.args[0].value, str):
                    path = node.args[0].value
                    if ("pages" in path or path.endswith("_pages")) \
                            and not self._covered(path, patterns):
                        self.emit(mod, node.lineno, "S404",
                                  f"cache_spec({path!r}) matches no paged "
                                  f"placement rule — check the path "
                                  f"spelling against _PARAM/cache rules")


def _flat_names(target: ast.expr) -> List[str]:
    if isinstance(target, ast.Name):
        return [target.id]
    if isinstance(target, (ast.Tuple, ast.List)):
        out: List[str] = []
        for e in target.elts:
            out.extend(_flat_names(e))
        return out
    return []


def run(project: Project) -> List[Finding]:
    """Entry point: S4xx findings over the project."""
    return ShardingLint(project).run()
